# Build-time artifact generation (python AOT -> HLO text + weights) and the
# tier-1 verify loop.

.PHONY: artifacts test verify

artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

test:
	cargo test -q

verify:
	cargo build --release && cargo test -q
