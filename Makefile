# Build-time artifact generation (python AOT -> HLO text + weights), the
# tier-1 verify loop, and the determinism lint.

.PHONY: artifacts test verify lint lint-selftest

artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

test:
	cargo test -q

verify:
	cargo build --release && cargo test -q

# Dependency-free source lint (see tools/lint/main.rs): compiled with bare
# rustc so it needs no lockfile entry and runs before any cargo build.
target/ssr-lint: tools/lint/main.rs
	mkdir -p target
	rustc -O --edition 2021 -o target/ssr-lint tools/lint/main.rs

lint: target/ssr-lint
	./target/ssr-lint --allow .lint-allow rust/src

lint-selftest: target/ssr-lint
	./target/ssr-lint --self-test
