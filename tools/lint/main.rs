//! ssr-lint — dependency-free determinism lint for the SSR rust tree.
//!
//! The simulator, DSE, and artifact writers promise bit-identical output
//! for identical inputs; that promise is easy to break with one innocuous
//! `HashMap` iteration or `partial_cmp().unwrap()`. This binary enforces
//! the source-level invariants behind the promise with a token scan over
//! `rust/src/` (comments and string literals stripped first, so prose and
//! test fixtures never trip it):
//!
//! * **L001** — `HashMap`/`HashSet` in serialization/export modules
//!   (`util/json.rs`, `obs/export.rs`, `obs/metrics.rs`). Those files
//!   write artifacts byte-for-byte; only ordered containers may appear.
//! * **L002** — `std::time` / `Instant` / `SystemTime` outside `bench/`.
//!   Wall-clock reads in model/sim code make results machine-dependent.
//!   Audited exceptions (live PJRT serving paths that genuinely measure
//!   wall time) live in `.lint-allow`.
//! * **L003** — `partial_cmp` anywhere. Float orderings must use
//!   `total_cmp`: a NaN-poisoned `partial_cmp().unwrap()` panics, and
//!   `sort_by` with a non-total order is unspecified.
//! * **L004** — entropy seeding (`from_entropy`, `thread_rng`, `OsRng`,
//!   `getrandom`, `RandomState`). All randomness flows from the
//!   split-stream `util::rng` seeded by explicit u64s.
//! * **L005** — every `rec.record(` in `sim/` or `cluster/` must sit
//!   inside a `rec.enabled()`-gated scope, so the recorder-off event loop
//!   monomorphizes to the pre-observability loop (no event construction
//!   cost when tracing is off).
//!
//! Usage: `ssr-lint [--allow .lint-allow] [--self-test] <dir>...`
//! Exit 0 clean, 1 on violations, 2 on usage/IO errors.
//!
//! Built standalone (`make lint`) with `rustc -O`; deliberately NOT a
//! cargo workspace member so it needs no lockfile entry and compiles on
//! any stable toolchain.

use std::env;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

#[derive(Debug, Clone, PartialEq, Eq)]
struct Violation {
    code: &'static str,
    file: String,
    line: usize,
    msg: String,
}

fn main() -> ExitCode {
    let mut allow_path: Option<String> = None;
    let mut dirs: Vec<String> = Vec::new();
    let mut self_test = false;
    let mut args = env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--allow" => match args.next() {
                Some(p) => allow_path = Some(p),
                None => {
                    eprintln!("--allow requires a file argument");
                    return ExitCode::from(2);
                }
            },
            "--self-test" => self_test = true,
            _ => dirs.push(a),
        }
    }

    if self_test {
        return run_self_test();
    }
    if dirs.is_empty() {
        eprintln!("usage: ssr-lint [--allow .lint-allow] [--self-test] <dir>...");
        return ExitCode::from(2);
    }

    let allow = match &allow_path {
        None => Vec::new(),
        Some(p) => match fs::read_to_string(p) {
            Ok(s) => parse_allow(&s),
            Err(e) => {
                eprintln!("reading allow file {p}: {e}");
                return ExitCode::from(2);
            }
        },
    };

    let mut files: Vec<PathBuf> = Vec::new();
    for d in &dirs {
        walk(Path::new(d), &mut files);
    }
    files.sort();

    let mut violations = Vec::new();
    let mut scanned = 0usize;
    for f in &files {
        let src = match fs::read_to_string(f) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("reading {}: {e}", f.display());
                return ExitCode::from(2);
            }
        };
        scanned += 1;
        let path = f.to_string_lossy().replace('\\', "/");
        violations.extend(check_file(&path, &src));
    }

    let mut used = vec![false; allow.len()];
    violations.retain(|v| {
        for (i, a) in allow.iter().enumerate() {
            if a.code == v.code && v.file.ends_with(&a.path) {
                used[i] = true;
                return false;
            }
        }
        true
    });
    for (i, a) in allow.iter().enumerate() {
        if !used[i] {
            eprintln!(
                "warning: stale .lint-allow entry `{} {}` matched nothing (line {})",
                a.code, a.path, a.line
            );
        }
    }

    for v in &violations {
        println!("error[{}] {}:{}: {}", v.code, v.file, v.line, v.msg);
    }
    if violations.is_empty() {
        println!("ssr-lint: {scanned} files clean");
        ExitCode::SUCCESS
    } else {
        println!("ssr-lint: {} violation(s) in {scanned} files", violations.len());
        ExitCode::from(1)
    }
}

struct Allow {
    code: String,
    path: String,
    line: usize,
}

/// `.lint-allow` lines: `CODE path # justification`. The justification is
/// mandatory — an exception nobody can explain is a bug, not an exception.
fn parse_allow(s: &str) -> Vec<Allow> {
    let mut out = Vec::new();
    for (i, raw) in s.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (entry, justification) = match line.split_once('#') {
            Some((e, j)) => (e.trim(), j.trim()),
            None => (line, ""),
        };
        let mut parts = entry.split_whitespace();
        let (code, path) = (parts.next(), parts.next());
        match (code, path) {
            (Some(c), Some(p)) if justification.len() >= 8 => out.push(Allow {
                code: c.to_string(),
                path: p.to_string(),
                line: i + 1,
            }),
            _ => eprintln!(
                "warning: .lint-allow line {} malformed (want `CODE path # why`): {raw}",
                i + 1
            ),
        }
    }
    out
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = fs::read_dir(dir) else { return };
    let mut entries: Vec<PathBuf> = rd.flatten().map(|e| e.path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out);
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
}

// ---------------------------------------------------------------------------
// Comment / string stripping
// ---------------------------------------------------------------------------

/// Replace comments and string/char-literal contents with spaces,
/// preserving every newline so byte offsets map to the original lines.
/// Handles line comments, nested block comments, escapes, raw strings
/// (`r"…"`, `r#"…"#`, byte variants), and distinguishes char literals
/// from lifetimes (`'a`, `'static`).
fn strip(src: &str) -> String {
    let b = src.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0;
    let keep = |c: u8| -> u8 {
        if c == b'\n' {
            b'\n'
        } else {
            b' '
        }
    };
    while i < b.len() {
        let c = b[i];
        // line comment
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            while i < b.len() && b[i] != b'\n' {
                out.push(b' ');
                i += 1;
            }
            continue;
        }
        // block comment (nested)
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let mut depth = 1;
            out.push(b' ');
            out.push(b' ');
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    depth += 1;
                    out.push(b' ');
                    out.push(b' ');
                    i += 2;
                } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    depth -= 1;
                    out.push(b' ');
                    out.push(b' ');
                    i += 2;
                } else {
                    out.push(keep(b[i]));
                    i += 1;
                }
            }
            continue;
        }
        // raw string: r"…", r#"…"#, br"…", br#"…"# (word boundary before r/b)
        let bounded = i == 0 || !is_ident(b[i - 1]);
        if bounded && (c == b'r' || (c == b'b' && i + 1 < b.len() && b[i + 1] == b'r')) {
            let start = if c == b'b' { i + 1 } else { i };
            let mut j = start + 1;
            while j < b.len() && b[j] == b'#' {
                j += 1;
            }
            if j < b.len() && b[j] == b'"' && b[start] == b'r' {
                let hashes = j - (start + 1);
                // emit the prefix verbatim-as-spaces
                for _ in i..=j {
                    out.push(b' ');
                }
                i = j + 1;
                // scan for `"` followed by `hashes` of `#`
                'raw: while i < b.len() {
                    if b[i] == b'"' {
                        let mut k = 0;
                        while k < hashes && i + 1 + k < b.len() && b[i + 1 + k] == b'#' {
                            k += 1;
                        }
                        if k == hashes {
                            for _ in 0..=hashes {
                                out.push(b' ');
                            }
                            i += 1 + hashes;
                            break 'raw;
                        }
                    }
                    out.push(keep(b[i]));
                    i += 1;
                }
                continue;
            }
        }
        // plain / byte string
        if c == b'"' {
            out.push(b' ');
            i += 1;
            while i < b.len() {
                if b[i] == b'\\' && i + 1 < b.len() {
                    out.push(b' ');
                    out.push(keep(b[i + 1]));
                    i += 2;
                    continue;
                }
                if b[i] == b'"' {
                    out.push(b' ');
                    i += 1;
                    break;
                }
                out.push(keep(b[i]));
                i += 1;
            }
            continue;
        }
        // char literal vs lifetime
        if c == b'\'' {
            if i + 1 < b.len() && b[i + 1] == b'\\' {
                // escaped char literal: '\n', '\\', '\u{…}'
                out.push(b' ');
                out.push(b' ');
                i += 2;
                while i < b.len() && b[i] != b'\'' {
                    out.push(keep(b[i]));
                    i += 1;
                }
                if i < b.len() {
                    out.push(b' ');
                    i += 1;
                }
                continue;
            }
            if i + 2 < b.len() && b[i + 2] == b'\'' {
                // plain char literal 'x'
                out.push(b' ');
                out.push(b' ');
                out.push(b' ');
                i += 3;
                continue;
            }
            // lifetime — pass through
            out.push(c);
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    String::from_utf8(out).expect("stripper emits ascii-or-original bytes")
}

fn is_ident(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

/// Byte offsets of word-boundary occurrences of `tok` in `s`.
fn token_offsets(s: &str, tok: &str) -> Vec<usize> {
    let sb = s.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = s[from..].find(tok) {
        let at = from + pos;
        let before_ok = at == 0 || !is_ident(sb[at - 1]);
        let end = at + tok.len();
        let last = tok.as_bytes()[tok.len() - 1];
        let after_ok = !is_ident(last) || end >= sb.len() || !is_ident(sb[end]);
        if before_ok && after_ok {
            out.push(at);
        }
        from = at + tok.len();
    }
    out
}

fn line_of(s: &str, off: usize) -> usize {
    s.as_bytes()[..off].iter().filter(|&&c| c == b'\n').count() + 1
}

fn in_dir(path: &str, dir: &str) -> bool {
    path.contains(&format!("/{dir}/"))
}

fn check_file(path: &str, src: &str) -> Vec<Violation> {
    let s = strip(src);
    let mut out: Vec<Violation> = Vec::new();
    let mut push = |code: &'static str, line: usize, msg: String| {
        if !out.iter().any(|v| v.code == code && v.line == line) {
            out.push(Violation { code, file: path.to_string(), line, msg });
        }
    };

    // L001 — unordered containers in byte-exact serialization modules.
    let l001_files = ["util/json.rs", "obs/export.rs", "obs/metrics.rs"];
    if l001_files.iter().any(|f| path.ends_with(f)) {
        for tok in ["HashMap", "HashSet"] {
            for off in token_offsets(&s, tok) {
                push(
                    "L001",
                    line_of(&s, off),
                    format!("{tok} in a byte-exact serialization module (use BTreeMap/BTreeSet)"),
                );
            }
        }
    }

    // L002 — wall-clock reads outside bench/.
    if !in_dir(path, "bench") {
        for tok in ["std::time", "Instant", "SystemTime"] {
            for off in token_offsets(&s, tok) {
                push(
                    "L002",
                    line_of(&s, off),
                    format!("wall-clock ({tok}) outside bench/ breaks run-to-run determinism"),
                );
            }
        }
    }

    // L003 — non-total float ordering.
    for off in token_offsets(&s, "partial_cmp") {
        push(
            "L003",
            line_of(&s, off),
            "partial_cmp on floats (use total_cmp: NaN-safe, total order)".to_string(),
        );
    }

    // L004 — entropy seeding.
    for tok in ["from_entropy", "thread_rng", "OsRng", "getrandom", "RandomState"] {
        for off in token_offsets(&s, tok) {
            push(
                "L004",
                line_of(&s, off),
                format!("{tok} draws OS entropy; seed util::rng split streams explicitly"),
            );
        }
    }

    // L005 — ungated recorder calls in the hot simulation loops.
    if in_dir(path, "sim") || in_dir(path, "cluster") {
        for (off, gated) in record_sites(&s) {
            if !gated {
                push(
                    "L005",
                    line_of(&s, off),
                    "rec.record(..) outside a rec.enabled() gate (event construction \
                     must cost nothing when tracing is off)"
                        .to_string(),
                );
            }
        }
    }

    out.sort_by(|a, b| a.line.cmp(&b.line).then(a.code.cmp(b.code)));
    out
}

/// Every `rec.record(` site in stripped source, with whether any enclosing
/// brace scope was opened under a `rec.enabled()` condition. Scope gating
/// is cumulative: a scope inherits its parent's gate.
fn record_sites(s: &str) -> Vec<(usize, bool)> {
    let b = s.as_bytes();
    let mut stack: Vec<bool> = Vec::new();
    let mut cond_start = 0usize; // slice since last `{`/`}`/`;`
    let mut out = Vec::new();
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'{' => {
                let parent = stack.last().copied().unwrap_or(false);
                let cond = &s[cond_start..i];
                stack.push(parent || cond.contains("rec.enabled()"));
                cond_start = i + 1;
            }
            b'}' => {
                stack.pop();
                cond_start = i + 1;
            }
            b';' => cond_start = i + 1,
            b'r' => {
                if s[i..].starts_with("rec.record(") && (i == 0 || !is_ident(b[i - 1])) {
                    out.push((i, stack.last().copied().unwrap_or(false)));
                }
            }
            _ => {}
        }
        i += 1;
    }
    out
}

// ---------------------------------------------------------------------------
// Self test
// ---------------------------------------------------------------------------

fn run_self_test() -> ExitCode {
    struct Case {
        name: &'static str,
        path: &'static str,
        src: &'static str,
        expect: &'static [&'static str],
    }
    let cases = [
        Case {
            name: "hashmap_in_json",
            path: "rust/src/util/json.rs",
            src: "use std::collections::HashMap;\n",
            expect: &["L001"],
        },
        Case {
            name: "hashmap_elsewhere_ok",
            path: "rust/src/dse/ea.rs",
            src: "use std::collections::HashMap;\n",
            expect: &[],
        },
        Case {
            name: "wallclock_in_sim",
            path: "rust/src/sim/device.rs",
            src: "fn f() { let t0 = std::time::Instant::now(); }\n",
            expect: &["L002"],
        },
        Case {
            name: "wallclock_in_bench_ok",
            path: "rust/src/bench/mod.rs",
            src: "fn f() { let t0 = std::time::Instant::now(); }\n",
            expect: &[],
        },
        Case {
            name: "partial_cmp",
            path: "rust/src/dse/x.rs",
            src: "fn f(xs: &mut [f64]) { xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n",
            expect: &["L003"],
        },
        Case {
            name: "partial_cmp_in_comment_ok",
            path: "rust/src/dse/x.rs",
            src: "// total_cmp, not partial_cmp().unwrap()\nfn f() {}\n",
            expect: &[],
        },
        Case {
            name: "partial_cmp_in_string_ok",
            path: "rust/src/dse/x.rs",
            src: "fn f() -> &'static str { \"partial_cmp\" }\n",
            expect: &[],
        },
        Case {
            name: "partial_cmp_in_raw_string_ok",
            path: "rust/src/dse/x.rs",
            src: "fn f() -> &'static str { r#\"partial_cmp\"# }\n",
            expect: &[],
        },
        Case {
            name: "entropy_seed",
            path: "rust/src/util/rng.rs",
            src: "fn f() { let r = StdRng::from_entropy(); }\n",
            expect: &["L004"],
        },
        Case {
            name: "gated_record_ok",
            path: "rust/src/sim/device.rs",
            src: "fn f() { if rec.enabled() { rec.record(ev); } }\n",
            expect: &[],
        },
        Case {
            name: "nested_gated_record_ok",
            path: "rust/src/sim/device.rs",
            src: "fn f() { if rec.enabled() { if admitted { rec.record(a); } else { rec.record(b); } } }\n",
            expect: &[],
        },
        Case {
            name: "ungated_record",
            path: "rust/src/cluster/fleet.rs",
            src: "fn f() { for x in xs { rec.record(x); } }\n",
            expect: &["L005"],
        },
        Case {
            name: "record_in_comment_ok",
            path: "rust/src/sim/device.rs",
            src: "/// every `rec.record(..)` call is gated\nfn f() {}\n",
            expect: &[],
        },
        Case {
            name: "lifetimes_do_not_derail_stripper",
            path: "rust/src/dse/x.rs",
            src: "fn f<'a>(x: &'a str) -> &'a str { x }\n// partial_cmp mention after lifetimes\n",
            expect: &[],
        },
        Case {
            name: "char_literal_ok",
            path: "rust/src/dse/x.rs",
            src: "fn f(c: char) -> bool { c == '\"' || c == '\\n' } // partial_cmp\n",
            expect: &[],
        },
    ];

    let mut failed = 0;
    for c in &cases {
        let got: Vec<&str> = check_file(c.path, c.src).iter().map(|v| v.code).collect();
        if got != c.expect {
            eprintln!("self-test FAIL {}: expected {:?}, got {:?}", c.name, c.expect, got);
            failed += 1;
        }
    }
    if failed == 0 {
        println!("ssr-lint self-test: {} cases ok", cases.len());
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
