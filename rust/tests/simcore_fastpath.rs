//! Fast-path pins for the sim core rework: the streaming arrival source,
//! the sketched O(1)-memory stats path, and the sharded parallel sweep
//! must all be observationally equivalent to the exact materializing
//! paths they replace — bit-identical where the contract is exactness,
//! inside the advertised error bound where it is the sketch.

use ssr::coordinator::scheduler::{
    ArrivalStream, RampSpec, SchedulerCfg, TrafficClass, TrafficMix,
};
use ssr::plan::front::{FrontEntry, PlanFront};
use ssr::sim::device::{
    run_timeline, run_timeline_controlled, run_timeline_sketched, DeviceSim, NoControl,
    TimelineOutcome,
};
use ssr::sim::serving::serve_ramp;
use ssr::sim::sweep::{run_sweep, SweepCfg, SweepReport};
use ssr::util::rng::Rng;
use ssr::util::stats::SKETCH_GAMMA;

fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
    FrontEntry {
        assign: vec![0; 8],
        batch,
        latency_ms: lat_ms,
        tops: rps * 2.5e-3,
        rps,
        nacc: 1,
        label: label.to_string(),
    }
}

fn front() -> PlanFront {
    PlanFront::new(
        "synthetic",
        12,
        vec![
            entry("seq", 1, 0.2, 5000.0),
            entry("hybrid", 6, 1.0, 6000.0),
            entry("spatial", 24, 2.0, 12000.0),
        ],
    )
    .unwrap()
}

fn cfg() -> SchedulerCfg {
    SchedulerCfg { slo_ms: 20.0, ..Default::default() }
}

/// Three-class mix with staggered phases, a zero-rate opening phase, and
/// unequal durations — the shapes that stress the k-way merge.
fn mixed() -> TrafficMix {
    TrafficMix {
        classes: vec![
            TrafficClass {
                model: "a".to_string(),
                ramp: RampSpec::parse("4000:1000", 0.3).unwrap(),
            },
            TrafficClass {
                model: "b".to_string(),
                ramp: RampSpec::parse("0:6000:2000", 0.2).unwrap(),
            },
            TrafficClass {
                model: "c".to_string(),
                ramp: RampSpec::parse("2500", 0.55).unwrap(),
            },
        ],
    }
}

fn assert_outcomes_identical(a: &TimelineOutcome, b: &TimelineOutcome, tag: &str) {
    assert_eq!(a.arrivals, b.arrivals, "{tag}: arrivals");
    assert_eq!(a.unroutable, b.unroutable, "{tag}: unroutable");
    assert_eq!(a.requeued, b.requeued, "{tag}: requeued");
    assert_eq!(a.requeue_lost, b.requeue_lost, "{tag}: requeue_lost");
    assert_eq!(a.n_windows, b.n_windows, "{tag}: n_windows");
    assert_eq!(a.events, b.events, "{tag}: events");
    assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "{tag}: makespan");
    assert_eq!(a.completions, b.completions, "{tag}: completion sequence");
    for q in [0.0, 0.25, 0.50, 0.90, 0.99, 1.0] {
        assert_eq!(
            a.latency.percentile(q).to_bits(),
            b.latency.percentile(q).to_bits(),
            "{tag}: p{q}"
        );
    }
}

#[test]
fn streaming_arrivals_replay_bit_identical_to_the_materialized_timeline() {
    for seed in [1u64, 42, 0xDEAD_BEEF] {
        let mix = mixed();
        let timeline = mix.arrivals(seed);
        assert!(timeline.len() > 1000, "thin timeline ({})", timeline.len());

        let mut devs_a = vec![
            DeviceSim::new(front(), cfg()),
            DeviceSim::new(front(), cfg()),
        ];
        let a = run_timeline(
            &mut devs_a,
            &timeline,
            mix.duration_s(),
            cfg().window_s,
            |devs, class, _| Some(class % devs.len()),
        );

        let mut stream = ArrivalStream::new(&mix, seed);
        let mut devs_b = vec![
            DeviceSim::new(front(), cfg()),
            DeviceSim::new(front(), cfg()),
        ];
        let b = run_timeline_controlled(
            &mut devs_b,
            &mut stream,
            mix.duration_s(),
            cfg().window_s,
            |devs, class, _| Some(class % devs.len()),
            &mut NoControl,
        );

        assert_outcomes_identical(&a, &b, &format!("seed {seed}"));
        for (da, db) in devs_a.into_iter().zip(devs_b) {
            let (ra, rb) = (da.into_report(), db.into_report());
            assert_eq!(ra.routed, rb.routed, "seed {seed}: routed");
            assert_eq!(ra.served, rb.served, "seed {seed}: served");
            assert_eq!(ra.shed, rb.shed, "seed {seed}: shed");
            assert_eq!(ra.windows, rb.windows, "seed {seed}: window trace");
        }
    }
}

#[test]
fn sketched_path_matches_exact_tallies_and_bounds_every_quantile() {
    for seed in [7u64, 0xFEED, 3141] {
        let mix = mixed();
        let run_exact = || {
            let mut stream = ArrivalStream::new(&mix, seed);
            let mut devs = vec![
                DeviceSim::new(front(), cfg()),
                DeviceSim::new(front(), cfg()),
            ];
            run_timeline_controlled(
                &mut devs,
                &mut stream,
                mix.duration_s(),
                cfg().window_s,
                |devs, class, _| Some(class % devs.len()),
                &mut NoControl,
            )
        };
        let exact = run_exact();

        let mut stream = ArrivalStream::new(&mix, seed);
        let mut devs = vec![
            DeviceSim::new(front(), cfg()).without_latency_samples(),
            DeviceSim::new(front(), cfg()).without_latency_samples(),
        ];
        let sk = run_timeline_sketched(
            &mut devs,
            &mut stream,
            mix.duration_s(),
            cfg().window_s,
            |devs, class, _| Some(class % devs.len()),
            &mut NoControl,
        );

        // Same event sequence: every integer tally and the makespan agree
        // exactly; the sketch sum is unbinned, so the mean is bit-equal.
        assert_eq!(sk.arrivals, exact.arrivals);
        assert_eq!(sk.unroutable, exact.unroutable);
        assert_eq!(sk.events, exact.events);
        assert_eq!(sk.n_windows, exact.n_windows);
        assert_eq!(sk.makespan_s.to_bits(), exact.makespan_s.to_bits());
        assert_eq!(sk.latency.count(), exact.latency.len() as u64);
        assert_eq!(sk.latency.mean().to_bits(), exact.latency.mean().to_bits());
        assert_eq!(sk.latency.max_s().to_bits(), exact.latency.max().to_bits());

        // Bounded error: against the nearest-rank exact sample (the rank
        // the sketch targets), every quantile is within a factor gamma.
        let mut sorted: Vec<f64> = exact.latency.samples().to_vec();
        sorted.sort_by(f64::total_cmp);
        for q in [0.0, 0.01, 0.10, 0.25, 0.50, 0.75, 0.90, 0.99, 0.999, 1.0] {
            let rank = (q * (sorted.len() - 1) as f64).round() as usize;
            let want = sorted[rank];
            let got = sk.latency.quantile(q);
            let tol = SKETCH_GAMMA * 1.000_001;
            assert!(
                got / want < tol && want / got < tol,
                "seed {seed} q{q}: sketch {got} vs exact rank sample {want}"
            );
        }
        // No sample vectors anywhere on this path.
        for d in devs {
            assert!(d.into_report().latency.is_empty());
        }
    }
}

fn assert_sweeps_identical(a: &SweepReport, b: &SweepReport, tag: &str) {
    assert_eq!(a.arrivals, b.arrivals, "{tag}: arrivals");
    assert_eq!(a.served, b.served, "{tag}: served");
    assert_eq!(a.shed, b.shed, "{tag}: shed");
    assert_eq!(a.unroutable, b.unroutable, "{tag}: unroutable");
    assert_eq!(a.events, b.events, "{tag}: events");
    assert_eq!(a.slo_violations, b.slo_violations, "{tag}: slo_violations");
    assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "{tag}: makespan");
    assert_eq!(a.latency.count(), b.latency.count(), "{tag}: sketch count");
    assert_eq!(a.cells.len(), b.cells.len(), "{tag}: cell count");
    for (ca, cb) in a.cells.iter().zip(&b.cells) {
        assert_eq!(ca.seed, cb.seed, "{tag}: cell seed");
        assert_eq!(ca.arrivals, cb.arrivals, "{tag}: cell arrivals");
        assert_eq!(ca.served, cb.served, "{tag}: cell served");
        assert_eq!(ca.shed, cb.shed, "{tag}: cell shed");
        assert_eq!(ca.events, cb.events, "{tag}: cell events");
        assert_eq!(ca.makespan_s.to_bits(), cb.makespan_s.to_bits(), "{tag}: cell makespan");
    }
    for q in [0.01, 0.50, 0.99] {
        assert_eq!(
            a.latency.quantile(q).to_bits(),
            b.latency.quantile(q).to_bits(),
            "{tag}: sketch q{q}"
        );
    }
}

#[test]
fn sweep_report_is_invariant_under_thread_count() {
    let ramp = RampSpec::parse("3000:9000:3000", 0.25).unwrap();
    let grid = |threads| SweepCfg { seeds: 3, shards: 4, threads, exact: false };
    let r1 = run_sweep(&front(), &ramp, &cfg(), &grid(1), 99);
    let r3 = run_sweep(&front(), &ramp, &cfg(), &grid(3), 99);
    let r4 = run_sweep(&front(), &ramp, &cfg(), &grid(4), 99);
    assert_sweeps_identical(&r1, &r3, "1 vs 3 threads");
    assert_sweeps_identical(&r1, &r4, "1 vs 4 threads");
    assert_eq!(r1.served + r1.shed, r1.arrivals);
}

#[test]
fn degenerate_exact_sweep_is_a_seeded_serve_ramp() {
    // A 1x1 exact-mode grid is literally serve_ramp under the cell's
    // derived seed: the sweep's value-add is the grid, not a new sim.
    let ramp = RampSpec::parse("2000:5000:2000", 0.3).unwrap();
    let base_seed = 4242u64;
    let sweep = SweepCfg { seeds: 1, shards: 1, threads: 1, exact: true };
    let r = run_sweep(&front(), &ramp, &cfg(), &sweep, base_seed);
    let cell_seed = Rng::new(base_seed).split(0).next_u64();
    let s = serve_ramp(&front(), &ramp, &cfg(), cell_seed);

    assert_eq!(r.cells.len(), 1);
    assert_eq!(r.cells[0].seed, cell_seed);
    assert_eq!(r.arrivals, s.arrivals);
    assert_eq!(r.served, s.served);
    assert_eq!(r.shed, s.shed);
    assert_eq!(r.slo_violations, s.slo_violations);
    assert_eq!(r.makespan_s.to_bits(), s.makespan_s.to_bits());
    let exact = r.exact_latency.as_ref().expect("exact mode");
    for q in [0.0, 0.25, 0.50, 0.99, 1.0] {
        assert_eq!(
            exact.percentile(q).to_bits(),
            s.latency.percentile(q).to_bits(),
            "q{q}"
        );
    }
}
