//! `ssr check` end-to-end: every artifact the repo generates passes the
//! static verifier clean, and seeded single-field mutations of each
//! artifact kind are rejected with a diagnostic pointing at the mutated
//! field (`json_path`), not a generic parse failure.

use std::collections::BTreeMap;

use ssr::check::{self, check_artifact, detect, ArtifactKind, CheckOpts};
use ssr::cluster::fleet::{device_front, parse_mix, synth_fleet};
use ssr::dse::Assignment;
use ssr::plan::ExecutionPlan;
use ssr::sim::service::ServiceModel;
use ssr::traffic::trace::{ArrivalProcess, RateCurve, TraceClass, TraceSpec};
use ssr::util::json::Json;

fn obj(j: &mut Json) -> &mut BTreeMap<String, Json> {
    match j {
        Json::Obj(m) => m,
        _ => panic!("expected object"),
    }
}

fn arr(j: &mut Json) -> &mut Vec<Json> {
    match j {
        Json::Arr(a) => a,
        _ => panic!("expected array"),
    }
}

fn hybrid5() -> Assignment {
    Assignment::new(vec![0, 1, 2, 2, 1, 3, 4, 0])
}

fn assert_clean(j: &Json, kind: ArtifactKind, opts: &CheckOpts) {
    let diags = check_artifact(j, kind, opts);
    assert!(diags.is_empty(), "expected a clean {:?} check, got: {diags:?}", kind);
}

fn assert_rejected(j: &Json, kind: ArtifactKind, opts: &CheckOpts, code: &str, path: &str) {
    let diags = check_artifact(j, kind, opts);
    assert!(check::has_errors(&diags), "expected errors for {:?}, got: {diags:?}", kind);
    assert!(
        diags.iter().any(|d| d.code == code && d.json_path == path),
        "expected {code} at {path}, got: {diags:?}"
    );
}

// One class per curve kind, and one class per service-model kind, so the
// clean pass exercises every S5xx domain alongside every T40x domain.
fn mixed_trace() -> TraceSpec {
    TraceSpec::new(vec![
        TraceClass {
            model: "deit_t".into(),
            curve: RateCurve::Constant { rate_rps: 40.0, duration_s: 20.0 },
            process: ArrivalProcess::Poisson,
            service: ServiceModel::Deterministic,
        },
        TraceClass {
            model: "deit_t".into(),
            curve: RateCurve::Piecewise { rates_rps: vec![10.0, 30.0, 20.0], phase_s: 5.0 },
            process: ArrivalProcess::LognormalGaps { sigma: 1.2 },
            service: ServiceModel::LognormalFactor { sigma: 0.8 },
        },
        TraceClass {
            model: "deit_t".into(),
            curve: RateCurve::Diurnal {
                base_rps: 25.0,
                amplitude_rps: 15.0,
                period_s: 60.0,
                duration_s: 120.0,
            },
            process: ArrivalProcess::ParetoGaps { alpha: 1.8 },
            service: ServiceModel::TokenPruning { alpha: 2.0, beta: 3.0 },
        },
        TraceClass {
            model: "deit_t".into(),
            curve: RateCurve::Flash {
                base_rps: 10.0,
                peak_rps: 80.0,
                at_s: 30.0,
                ramp_s: 5.0,
                decay_s: 10.0,
                duration_s: 90.0,
            },
            process: ArrivalProcess::Poisson,
            service: ServiceModel::EarlyExit {
                exit_probs: vec![0.3, 0.2],
                stage_fractions: vec![0.25, 0.5],
            },
        },
    ])
    .unwrap()
}

// ---------------------------------------------------------------------------
// Repo-generated artifacts pass clean
// ---------------------------------------------------------------------------

#[test]
fn generated_fronts_pass_clean() {
    let versal = device_front("vck190", "deit_t", &[1, 2, 4, 6]).unwrap().to_json();
    assert_eq!(detect(&versal), Some(ArtifactKind::Front));
    assert_clean(&versal, ArtifactKind::Front, &CheckOpts::default());
    // With the board named, the TOPS budget pass runs too.
    assert_clean(&versal, ArtifactKind::Front, &CheckOpts { arch: Some("vck190"), trace: None });

    let mono = device_front("u250", "deit_t", &[1, 4]).unwrap().to_json();
    assert_clean(&mono, ArtifactKind::Front, &CheckOpts { arch: Some("u250"), trace: None });
}

#[test]
fn generated_fleet_passes_clean_with_trace_coverage() {
    let mix = parse_mix("vck190:2,u250:1").unwrap();
    let fleet = synth_fleet("edge", "deit_t", &mix, &[1, 6]).unwrap().to_json();
    assert_eq!(detect(&fleet), Some(ArtifactKind::Fleet));
    let trace = mixed_trace().to_json();
    assert_clean(&fleet, ArtifactKind::Fleet, &CheckOpts { arch: None, trace: Some(&trace) });
}

#[test]
fn generated_traces_pass_clean() {
    let t = mixed_trace().to_json();
    assert_eq!(detect(&t), Some(ArtifactKind::Trace));
    assert_clean(&t, ArtifactKind::Trace, &CheckOpts::default());

    let zipf = TraceSpec::zipf_mix(
        &["deit_t", "deit_t_160", "lv_vit_t"],
        &RateCurve::Constant { rate_rps: 120.0, duration_s: 30.0 },
        ArrivalProcess::Poisson,
        1.0,
    )
    .unwrap()
    .to_json();
    assert_clean(&zipf, ArtifactKind::Trace, &CheckOpts::default());
}

#[test]
fn generated_plans_pass_clean() {
    let opts = CheckOpts { arch: Some("vck190"), trace: None };
    for plan in [
        ExecutionPlan::from_depth("deit_t", 12, &hybrid5(), 6),
        ExecutionPlan::from_depth("deit_t", 12, &Assignment::spatial(), 1),
        ExecutionPlan::from_depth("deit_t", 12, &Assignment::sequential(), 1),
        ExecutionPlan::from_depth("deit_t", 12, &hybrid5(), 6).coarsen().0,
    ] {
        let j = plan.to_json();
        assert_eq!(detect(&j), Some(ArtifactKind::Plan));
        assert_clean(&j, ArtifactKind::Plan, &opts);
    }
}

#[test]
fn zero_load_trace_warns_but_does_not_fail() {
    let t = TraceSpec::single(
        "deit_t",
        RateCurve::Constant { rate_rps: 0.0, duration_s: 10.0 },
        ArrivalProcess::Poisson,
    )
    .to_json();
    let diags = check_artifact(&t, ArtifactKind::Trace, &CheckOpts::default());
    assert!(!check::has_errors(&diags), "zero load is a warning, got: {diags:?}");
    assert!(diags.iter().any(|d| d.code == "T406"), "expected T406, got: {diags:?}");
}

// ---------------------------------------------------------------------------
// Seeded mutations are each rejected with a pointing diagnostic
// ---------------------------------------------------------------------------

#[test]
fn mutation_negative_rate_is_rejected() {
    let mut t = mixed_trace().to_json();
    let classes = arr(obj(&mut t).get_mut("classes").unwrap());
    let curve = obj(&mut classes[0]).get_mut("curve").unwrap();
    obj(curve).insert("rate_rps".into(), Json::Num(-5.0));
    assert_rejected(
        &t,
        ArtifactKind::Trace,
        &CheckOpts::default(),
        "T404",
        "/classes/0/curve/rate_rps",
    );
}

#[test]
fn mutation_nan_latency_is_rejected() {
    let mut f = device_front("vck190", "deit_t", &[1, 2, 4, 6]).unwrap().to_json();
    let entries = arr(obj(&mut f).get_mut("entries").unwrap());
    assert!(entries.len() >= 2, "front too small to mutate entry 1");
    obj(&mut entries[1]).insert("latency_ms".into(), Json::Num(f64::NAN));
    assert_rejected(
        &f,
        ArtifactKind::Front,
        &CheckOpts::default(),
        "F202",
        "/entries/1/latency_ms",
    );
}

#[test]
fn mutation_dominated_entry_is_rejected() {
    // Entry 0 is strictly worse on both axes — a front must be pruned.
    let mk = |lat: f64, rps: f64| {
        let mut e = BTreeMap::new();
        e.insert("assign".into(), Json::Arr(vec![Json::Num(0.0); 8]));
        e.insert("batch".into(), Json::Num(1.0));
        e.insert("latency_ms".into(), Json::Num(lat));
        e.insert("rps".into(), Json::Num(rps));
        e.insert("label".into(), Json::Str("test".into()));
        Json::Obj(e)
    };
    let mut top = BTreeMap::new();
    top.insert("model".into(), Json::Str("deit_t".into()));
    top.insert("depth".into(), Json::Num(12.0));
    top.insert("entries".into(), Json::Arr(vec![mk(10.0, 50.0), mk(5.0, 100.0)]));
    let f = Json::Obj(top);
    assert_rejected(&f, ArtifactKind::Front, &CheckOpts::default(), "F204", "/entries/0");
}

#[test]
fn mutation_cyclic_forwarding_edge_is_rejected() {
    let mut p = ExecutionPlan::from_depth("deit_t", 12, &hybrid5(), 6).to_json();
    let edges = arr(obj(&mut p).get_mut("edges").unwrap());
    assert!(!edges.is_empty(), "hybrid plan must have forwarding edges");
    let k = edges.len() - 1;
    // Point the last edge back at step 0: from >= to is a cycle by
    // construction in a topological schedule.
    obj(&mut edges[k]).insert("to".into(), Json::Num(0.0));
    assert_rejected(
        &p,
        ArtifactKind::Plan,
        &CheckOpts::default(),
        "P104",
        &format!("/edges/{k}/to"),
    );
}

#[test]
fn mutation_dropped_stage_is_rejected() {
    let mut p = ExecutionPlan::from_depth("deit_t", 12, &hybrid5(), 1).to_json();
    let steps = arr(obj(&mut p).get_mut("steps").unwrap());
    let qkv = steps
        .iter()
        .position(|s| s.get("unit").and_then(Json::as_str) == Some("qkv"))
        .expect("class plan has qkv steps");
    steps.remove(qkv);
    let diags = check_artifact(&p, ArtifactKind::Plan, &CheckOpts::default());
    assert!(check::has_errors(&diags));
    assert!(
        diags.iter().any(|d| d.code == "P106"
            && d.json_path == "/steps"
            && d.message.contains("missing")
            && d.message.contains("qkv")),
        "expected a P106 missing-qkv diagnostic, got: {diags:?}"
    );
}

/// Fetch class `i`'s mutable `service` object from a serialized trace.
fn service_of(t: &mut Json, i: usize) -> &mut BTreeMap<String, Json> {
    let classes = arr(obj(t).get_mut("classes").unwrap());
    obj(obj(&mut classes[i]).get_mut("service").expect("class has a service object"))
}

#[test]
fn mutation_unknown_service_kind_is_rejected() {
    let mut t = mixed_trace().to_json();
    service_of(&mut t, 1).insert("kind".into(), Json::Str("speculative".into()));
    assert_rejected(
        &t,
        ArtifactKind::Trace,
        &CheckOpts::default(),
        "S500",
        "/classes/1/service/kind",
    );
}

#[test]
fn mutation_bad_lognormal_sigma_is_rejected() {
    // Out-of-domain and NaN both land S501 at the exact field.
    for bad in [Json::Num(-0.5), Json::Num(5.0), Json::Num(f64::NAN)] {
        let mut t = mixed_trace().to_json();
        service_of(&mut t, 1).insert("sigma".into(), bad);
        assert_rejected(
            &t,
            ArtifactKind::Trace,
            &CheckOpts::default(),
            "S501",
            "/classes/1/service/sigma",
        );
    }
}

#[test]
fn mutation_bad_prune_shape_is_rejected() {
    let mut t = mixed_trace().to_json();
    service_of(&mut t, 2).insert("alpha".into(), Json::Num(0.0));
    assert_rejected(
        &t,
        ArtifactKind::Trace,
        &CheckOpts::default(),
        "S502",
        "/classes/2/service/alpha",
    );
    let mut t = mixed_trace().to_json();
    service_of(&mut t, 2).insert("beta".into(), Json::Num(f64::NAN));
    assert_rejected(
        &t,
        ArtifactKind::Trace,
        &CheckOpts::default(),
        "S502",
        "/classes/2/service/beta",
    );
}

#[test]
fn mutation_bad_exit_probability_element_is_rejected() {
    let mut t = mixed_trace().to_json();
    service_of(&mut t, 3)
        .insert("exit_probs".into(), Json::Arr(vec![Json::Num(1.5), Json::Num(0.2)]));
    assert_rejected(
        &t,
        ArtifactKind::Trace,
        &CheckOpts::default(),
        "S503",
        "/classes/3/service/exit_probs/0",
    );
}

#[test]
fn mutation_exit_probabilities_summing_past_one_are_rejected() {
    let mut t = mixed_trace().to_json();
    service_of(&mut t, 3)
        .insert("exit_probs".into(), Json::Arr(vec![Json::Num(0.7), Json::Num(0.6)]));
    assert_rejected(
        &t,
        ArtifactKind::Trace,
        &CheckOpts::default(),
        "S504",
        "/classes/3/service/exit_probs",
    );
}

#[test]
fn mutation_bad_stage_fraction_is_rejected() {
    let mut t = mixed_trace().to_json();
    service_of(&mut t, 3)
        .insert("stage_fractions".into(), Json::Arr(vec![Json::Num(0.0), Json::Num(0.5)]));
    assert_rejected(
        &t,
        ArtifactKind::Trace,
        &CheckOpts::default(),
        "S505",
        "/classes/3/service/stage_fractions/0",
    );
    // Length mismatch points at the stage_fractions array itself.
    let mut t = mixed_trace().to_json();
    service_of(&mut t, 3).insert("stage_fractions".into(), Json::Arr(vec![Json::Num(0.5)]));
    assert_rejected(
        &t,
        ArtifactKind::Trace,
        &CheckOpts::default(),
        "S505",
        "/classes/3/service/stage_fractions",
    );
}

#[test]
fn mutation_unknown_platform_is_rejected() {
    let mix = parse_mix("vck190:1,u250:1").unwrap();
    let mut f = synth_fleet("edge", "deit_t", &mix, &[1, 6]).unwrap().to_json();
    let devices = arr(obj(&mut f).get_mut("devices").unwrap());
    obj(&mut devices[0]).insert("platform".into(), Json::Str("tpu_v9".into()));
    assert_rejected(
        &f,
        ArtifactKind::Fleet,
        &CheckOpts::default(),
        "C303",
        "/devices/0/platform",
    );
}

#[test]
fn mutation_uncovered_trace_model_is_rejected() {
    let mix = parse_mix("vck190:1").unwrap();
    let fleet = synth_fleet("edge", "deit_t", &mix, &[1, 6]).unwrap().to_json();
    let trace = TraceSpec::single(
        "deit_s",
        RateCurve::Constant { rate_rps: 10.0, duration_s: 5.0 },
        ArrivalProcess::Poisson,
    )
    .to_json();
    assert_rejected(
        &fleet,
        ArtifactKind::Fleet,
        &CheckOpts { arch: None, trace: Some(&trace) },
        "C305",
        "/devices",
    );
}

#[test]
fn spatial_plan_on_monolithic_board_is_rejected() {
    let p = ExecutionPlan::from_depth("deit_t", 12, &hybrid5(), 1).to_json();
    assert_rejected(
        &p,
        ArtifactKind::Plan,
        &CheckOpts { arch: Some("u250"), trace: None },
        "P110",
        "/nacc",
    );
}

// ---------------------------------------------------------------------------
// Verified loads: the CLI boundary helpers
// ---------------------------------------------------------------------------

#[test]
fn verified_loads_round_trip_clean_files() {
    let dir = std::env::temp_dir().join(format!("ssr-check-rt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let plan = ExecutionPlan::from_depth("deit_t", 12, &hybrid5(), 6);
    plan.save(&dir.join("plan.json")).unwrap();
    assert_eq!(check::load_plan(&dir.join("plan.json")).unwrap(), plan);

    let front = device_front("vck190", "deit_t", &[1, 6]).unwrap();
    front.save(&dir.join("front.json")).unwrap();
    assert_eq!(check::load_front(&dir.join("front.json")).unwrap(), front);

    let trace = mixed_trace();
    trace.save(&dir.join("trace.json")).unwrap();
    assert_eq!(check::load_trace(&dir.join("trace.json")).unwrap(), trace);

    let mix = parse_mix("vck190:2,u250:1").unwrap();
    let fleet = synth_fleet("edge", "deit_t", &mix, &[1, 6]).unwrap();
    fleet.save(&dir.join("fleet.json")).unwrap();
    assert_eq!(check::load_fleet(&dir.join("fleet.json")).unwrap(), fleet);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn verified_load_refuses_a_corrupt_file_with_the_diagnostic() {
    let dir = std::env::temp_dir().join(format!("ssr-check-bad-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let mix = parse_mix("vck190:1").unwrap();
    let mut f = synth_fleet("edge", "deit_t", &mix, &[1, 6]).unwrap().to_json();
    let devices = arr(obj(&mut f).get_mut("devices").unwrap());
    obj(&mut devices[0]).insert("platform".into(), Json::Str("tpu_v9".into()));
    let path = dir.join("fleet.json");
    std::fs::write(&path, f.to_string() + "\n").unwrap();

    let err = check::load_fleet(&path).unwrap_err();
    assert!(err.contains("C303"), "error should carry the diagnostic code: {err}");
    assert!(err.contains("tpu_v9"), "error should name the bad platform: {err}");
    assert!(err.contains("ssr check"), "error should point at the full report: {err}");

    // Wrong-kind load: a trace file handed to --fleet is refused up front.
    let trace = mixed_trace();
    trace.save(&dir.join("trace.json")).unwrap();
    let err = check::load_fleet(&dir.join("trace.json")).unwrap_err();
    assert!(err.contains("trace-spec"), "kind mismatch should name both kinds: {err}");

    std::fs::remove_dir_all(&dir).ok();
}
