//! ExecutionPlan round-trip tests: the DSE's 8-class hybrid designs must be
//! servable *as found* — DSE assignment → ExecutionPlan → {simulator, live
//! pipeline server} — including designs the old 4-stage projection could
//! not represent (`nacc > 4`, attention split across accelerators).
//!
//! Tests that need compiled artifacts skip themselves (with a log line)
//! when `artifacts/` is absent, so `cargo test` stays runnable before
//! `make artifacts`.

use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

use ssr::analytical::{Calib, Features};
use ssr::arch::vck190;
use ssr::coordinator::pipeline::{synth_images, PipelineServer, SequentialServer};
use ssr::coordinator::StageAssign;
use ssr::dse::eval::build_design;
use ssr::dse::Assignment;
use ssr::graph::{vit_graph, DEIT_T};
use ssr::plan::front::{FrontEntry, PlanFront};
use ssr::plan::{project_stage4, ExecutionPlan, Granularity};
use ssr::runtime::exec::Engine;

/// The acceptance-criterion design: attention split across two accs
/// (qkv+proj on acc 1, bmm0+bmm1 on acc 2), MLP split across two more —
/// nacc = 5, strictly outside the 4-stage representable set.
fn hybrid5() -> Assignment {
    Assignment::new(vec![0, 1, 2, 2, 1, 3, 4, 0])
}

fn try_engine() -> Option<Arc<Engine>> {
    static E: OnceLock<Option<Arc<Engine>>> = OnceLock::new();
    E.get_or_init(|| Engine::load(&PathBuf::from("artifacts")).ok()).clone()
}

fn close(a: &[f32], b: &[f32], tol: f32) {
    assert_eq!(a.len(), b.len());
    let max = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
    assert!(max <= tol, "max diff {max} > {tol}");
}

#[test]
fn old_projection_cannot_represent_hybrid5_but_plan_can() {
    // DSE side: the design builds and its emitted plan keeps all 5 accs.
    let platform = vck190();
    let graph = vit_graph(&DEIT_T);
    let a = hybrid5();
    assert_eq!(a.nacc(), 5);
    let ev = build_design(&platform, &Calib::default(), &graph, &a, Features::all(), true)
        .expect("hybrid5 must be feasible on vck190");
    assert_eq!(ev.plan.nacc, 5);
    assert_eq!(ev.plan.granularity, Granularity::Class);
    ev.plan.validate().unwrap();

    // The old 4-stage projection loses the attention split entirely.
    let (accs, report) = project_stage4(&a);
    let proj_nacc = accs.iter().copied().max().unwrap() + 1;
    assert!(proj_nacc < a.nacc(), "projection kept {proj_nacc} accs of {}", a.nacc());
    assert!(!report.is_lossless());
    assert!(
        report.merges.iter().any(|m| m.class.is_attention()),
        "the dropped separations include the attention split: {}",
        report.describe()
    );
    let (shim, shim_report) = StageAssign::try_from_assignment(&a);
    assert_eq!(shim.nacc(), proj_nacc);
    assert!(!shim_report.is_lossless());

    // The plan-driven simulator schedules the full design: all 5 accs busy.
    let sim = ssr::sim::simulate(&platform, &ev, &graph, 4);
    assert_eq!(sim.acc_busy_s.len(), 5);
    assert!(sim.acc_busy_s.iter().all(|&b| b > 0.0), "{:?}", sim.acc_busy_s);
}

#[test]
fn hybrid5_plan_roundtrips_through_live_server_with_correct_logits() {
    let Some(engine) = try_engine() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let a = hybrid5();
    let depth = engine.manifest.models["deit_t"].depth;
    let plan = ExecutionPlan::from_depth("deit_t", depth, &a, 1);
    let server = PipelineServer::from_plan(Arc::clone(&engine), &plan).unwrap();

    if engine.manifest.has_class_stages("deit_t", 1) {
        // Full round-trip: the served plan is the DSE design, not a shim.
        assert_eq!(server.plan().granularity, Granularity::Class);
        assert_eq!(server.plan().nacc, 5, "all 5 accelerators must be live");
    } else {
        eprintln!(
            "note: manifest predates class-granular stages; served \
             coarsened plan ({} accs)",
            server.plan().nacc
        );
    }

    // Logits must match the monolithic executable bit-for-tolerance.
    let seq = SequentialServer::new(Arc::clone(&engine), "deit_t", &[1]).unwrap();
    let imgs: Vec<_> = (0..3).map(|i| synth_images(1, 224, 500 + i)).collect();
    let expected: Vec<_> = imgs.iter().map(|im| seq.run_batch(1, im).unwrap()).collect();
    let (report, outs) = server.serve(imgs).unwrap();
    assert_eq!(report.requests, 3);
    for (got, want) in outs.iter().zip(&expected) {
        assert_eq!(got.shape, vec![1, 1000]);
        close(&got.data, &want.data, 2e-3);
    }
}

// ---------------------------------------------------------------------------
// PlanFront edge cases (serialization + selection boundaries): the front is
// the DSE→serving interchange artifact, so its JSON and its SLO selection
// must be exact at the extremes.
// ---------------------------------------------------------------------------

fn front_entry(label: &str, assign: Vec<usize>, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
    let nacc = assign.iter().copied().max().unwrap() + 1;
    FrontEntry {
        assign,
        batch,
        latency_ms: lat_ms,
        tops: rps * 2.5e-3,
        rps,
        nacc,
        label: label.to_string(),
    }
}

#[test]
fn front_save_load_survives_non_finite_adjacent_floats() {
    // Denormal-scale latency, a magnitude just under f64::MAX, and a value
    // needing all 17 significant digits (0.1 + 0.2): save/load must
    // round-trip them bit-exactly (PartialEq on f64 fields).
    let mut tiny = front_entry("tiny", vec![0; 8], 1, 4.9e-308, 1e-3);
    tiny.tops = 0.1 + 0.2; // 0.30000000000000004
    let mut big = front_entry("big", (0..8).collect(), 6, 0.1 + 0.2, 1e4);
    big.tops = 8.5e307;
    let f = PlanFront::new("deit_t", 12, vec![tiny, big]).unwrap();
    assert_eq!(f.len(), 2, "tradeoff pair must both survive pruning");
    let path = std::env::temp_dir().join("ssr_front_edge_roundtrip.json");
    f.save(&path).unwrap();
    let back = PlanFront::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(back, f);
}

#[test]
fn best_under_exact_boundary_slo_is_inclusive() {
    let f = PlanFront::new(
        "deit_t",
        12,
        vec![
            front_entry("fast", vec![0; 8], 1, 0.25, 4000.0),
            front_entry("big", (0..8).collect(), 6, 2.0, 10000.0),
        ],
    )
    .unwrap();
    // an SLO exactly equal to an entry's latency admits that entry
    assert_eq!(f.best_under(2.0), Some(1));
    assert_eq!(f.best_under(0.25), Some(0));
    // one ulp-scale step below the boundary excludes it again
    assert_eq!(f.best_under(2.0 - 1e-12), Some(0));
    assert_eq!(f.best_under(0.25 - 1e-12), None);
    assert_eq!(f.best_under(f64::NEG_INFINITY), None);
}

#[test]
fn duplicate_metric_entries_dedup_with_provenance_intact() {
    // Two distinct designs land on identical (latency, rate) metrics:
    // pareto_indices dedups them to one survivor, and that survivor's
    // genome/label/batch come through untouched (provenance, not a merge).
    let a = front_entry("ea-0", vec![0, 1, 1, 1, 0, 2, 2, 0], 6, 1.0, 6000.0);
    let b = front_entry("ea-1", vec![0, 1, 2, 2, 1, 3, 4, 0], 6, 1.0, 6000.0);
    let tail = front_entry("spatial", (0..8).collect(), 6, 2.0, 12000.0);
    let f = PlanFront::new("deit_t", 12, vec![a.clone(), b, tail]).unwrap();
    assert_eq!(f.len(), 2, "duplicate-metric entry must dedup");
    let kept = &f.entries[0];
    assert_eq!(kept.label, "ea-0");
    assert_eq!(kept.assign, a.assign);
    assert_eq!(kept.batch, a.batch);
    // the survivor still materializes its own executable plan
    let plan = kept.plan("deit_t", 12);
    assert_eq!(plan.nacc, 3);
    plan.validate().unwrap();
}

#[test]
fn plan_sim_and_plan_server_agree_on_execution_model_ordering() {
    // Satellite consistency check: the plan-driven simulator and the
    // plan-driven live server must agree on the paper's Fig. 2 ordering for
    // a fixed seed design pair — sequential wins latency at batch 1,
    // pipelining wins throughput once requests overlap.
    let platform = vck190();
    let graph = vit_graph(&DEIT_T);
    let cal = Calib::default();
    let seq_ev =
        build_design(&platform, &cal, &graph, &Assignment::sequential(), Features::all(), true)
            .unwrap();
    let spa_ev =
        build_design(&platform, &cal, &graph, &Assignment::spatial(), Features::all(), true)
            .unwrap();

    // Simulator side (always runs).
    let sim_seq1 = ssr::sim::simulate(&platform, &seq_ev, &graph, 1);
    let sim_spa1 = ssr::sim::simulate(&platform, &spa_ev, &graph, 1);
    assert!(sim_seq1.makespan_s <= sim_spa1.makespan_s);
    let sim_seq6 = ssr::sim::simulate(&platform, &seq_ev, &graph, 6);
    let sim_spa6 = ssr::sim::simulate(&platform, &spa_ev, &graph, 6);
    assert!(sim_spa6.tops >= sim_seq6.tops);

    // Server side (needs artifacts).
    let Some(engine) = try_engine() else {
        eprintln!("skipping server half: artifacts not built (run `make artifacts`)");
        return;
    };
    let seq = SequentialServer::new(Arc::clone(&engine), "deit_t", &[1]).unwrap();
    let spa_plan = ExecutionPlan::from_depth(
        "deit_t",
        engine.manifest.models["deit_t"].depth,
        &Assignment::spatial(),
        1,
    );
    let spa = PipelineServer::from_plan(Arc::clone(&engine), &spa_plan).unwrap();

    // Warm both paths, then measure.
    let warm = synth_images(1, 224, 0);
    let _ = seq.run_batch(1, &warm).unwrap();
    let _ = spa.serve(vec![synth_images(1, 224, 1)]).unwrap();

    let reqs: Vec<_> = (0..6).map(|i| synth_images(1, 224, 10 + i)).collect();
    let (seq_rep, _) = seq.serve(1, &reqs).unwrap();
    let (spa1_rep, _) = spa.serve(vec![synth_images(1, 224, 40)]).unwrap();
    // Sequential batch-1 latency <= staged-pipeline batch-1 latency (the
    // pipeline pays per-stage upload/download + hop overhead); 1.25 slack
    // absorbs host timing noise.
    assert!(
        seq_rep.latency.p50() <= spa1_rep.latency.p50() * 1.25,
        "server disagrees with sim on batch-1 latency ordering: seq {} vs spatial {}",
        seq_rep.latency.p50(),
        spa1_rep.latency.p50()
    );

    // Pipelining throughput: 8 overlapped requests finish well under 8x the
    // single-request latency — the server-side analog of spatial winning
    // throughput at large batch.
    let imgs: Vec<_> = (0..8).map(|i| synth_images(1, 224, 60 + i)).collect();
    let (spa8_rep, _) = spa.serve(imgs).unwrap();
    assert!(
        spa8_rep.wall_s < 8.0 * spa1_rep.latency.p50() * 0.9,
        "pipeline does not overlap: wall {} vs 8 x {}",
        spa8_rep.wall_s,
        spa1_rep.latency.p50()
    );
}
