//! Property-based tests over DSE/scheduler/simulator invariants, using the
//! in-repo `util::prop` mini-framework with deterministic seeds.

use ssr::analytical::{AccConfig, Calib, Features};
use ssr::arch::vck190;
use ssr::dse::eval::build_design;
use ssr::dse::pareto::{best_under, pareto_front, Point};
use ssr::dse::Assignment;
use ssr::graph::{vit_graph, DEIT_T, ALL_CLASSES};
use ssr::plan::{expand_stage4, project_stage4, ExecutionPlan};
use ssr::sim;
use ssr::util::prop::{check, check_with, shrink_usize_vec, Config};
use ssr::util::rng::Rng;

fn rand_assignment(r: &mut Rng) -> Vec<usize> {
    let nacc = 1 + r.usize_below(8);
    (0..ALL_CLASSES.len()).map(|_| r.usize_below(nacc)).collect()
}

#[test]
fn prop_normalize_idempotent_and_canonical() {
    check_with(
        &Config { cases: 200, ..Default::default() },
        "normalize-idempotent",
        rand_assignment,
        |v| {
            let a = Assignment::new(v.clone());
            let mut b = a.clone();
            b.normalize();
            if a.acc_of != b.acc_of {
                return Err(format!("not idempotent: {:?} -> {:?}", a.acc_of, b.acc_of));
            }
            // canonical form: first appearance order => acc_of[0] == 0 and
            // every id <= 1 + max of earlier ids
            let mut max_seen = 0usize;
            for (i, &x) in a.acc_of.iter().enumerate() {
                if i == 0 && x != 0 {
                    return Err("first class not acc 0".into());
                }
                if x > max_seen + 1 {
                    return Err(format!("gap in ids at {i}: {:?}", a.acc_of));
                }
                max_seen = max_seen.max(x);
            }
            Ok(())
        },
        shrink_usize_vec,
    );
}

#[test]
fn prop_classes_on_partitions_exactly() {
    check(
        &Config { cases: 100, ..Default::default() },
        "classes-partition",
        rand_assignment,
        |v| {
            let a = Assignment::new(v.clone());
            let mut seen = vec![false; ALL_CLASSES.len()];
            for acc in 0..a.nacc() {
                for c in a.classes_on(acc) {
                    if seen[c.index()] {
                        return Err(format!("class {c:?} on two accs"));
                    }
                    seen[c.index()] = true;
                }
            }
            if !seen.iter().all(|&s| s) {
                return Err("class on no acc".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_design_eval_invariants() {
    // For any feasible assignment: latency > 0, monotone in batch, tops
    // below platform peak, sim within 25% of analytical.
    let platform = vck190();
    let calib = Calib::default();
    let graph = vit_graph(&DEIT_T);
    check_with(
        &Config { cases: 30, ..Default::default() },
        "design-eval-invariants",
        rand_assignment,
        |v| {
            let a = Assignment::new(v.clone());
            let Some(ev) =
                build_design(&platform, &calib, &graph, &a, Features::all(), true)
            else {
                return Ok(()); // infeasible is allowed
            };
            let e1 = ev.evaluate(&platform, &graph, 1);
            let e6 = ev.evaluate(&platform, &graph, 6);
            if !(e1.latency_s > 0.0) || !(e6.latency_s >= e1.latency_s) {
                return Err(format!("latency not monotone: {} vs {}", e1.latency_s, e6.latency_s));
            }
            if e6.tops > platform.peak_int8_tops() {
                return Err(format!("tops {} above peak", e6.tops));
            }
            let s = sim::simulate(&platform, &ev, &graph, 6);
            let err = (e6.latency_s - s.makespan_s).abs() / s.makespan_s;
            if err > 0.25 {
                return Err(format!("sim diverges {err:.2} for {:?}", a.acc_of));
            }
            // busy seconds conservation: sim busy == sum of node busy x batch
            let node_busy: f64 = ev.node_costs.iter().map(|c| c.busy_s()).sum();
            let sim_busy: f64 = s.acc_busy_s.iter().sum();
            if (sim_busy - 6.0 * node_busy).abs() > 1e-9 {
                return Err(format!("busy not conserved: {sim_busy} vs {}", 6.0 * node_busy));
            }
            Ok(())
        },
        shrink_usize_vec,
    );
}

#[test]
fn prop_pareto_front_sound_and_complete() {
    check(
        &Config { cases: 200, ..Default::default() },
        "pareto-front",
        |r| {
            let n = 1 + r.usize_below(20);
            (0..n)
                .map(|_| (1.0 + 10.0 * r.f64(), 1.0 + 30.0 * r.f64()))
                .collect::<Vec<(f64, f64)>>()
        },
        |pts| {
            let points: Vec<Point> = pts
                .iter()
                .map(|&(l, t)| Point { latency_ms: l, tops: t, batch: 1, nacc: 1 })
                .collect();
            let front = pareto_front(&points);
            // soundness: no front point dominated by any input point
            for f in &front {
                if points.iter().any(|p| p.dominates(f)) {
                    return Err(format!("dominated point on front: {f:?}"));
                }
            }
            // completeness: every input point is dominated-or-equal by a front point
            for p in &points {
                let covered = front
                    .iter()
                    .any(|f| f.latency_ms <= p.latency_ms && f.tops >= p.tops);
                if !covered {
                    return Err(format!("point not covered: {p:?}"));
                }
            }
            // best_under consistency: optimum under any cut lies on the front
            let cut = 1.0 + 10.0 * 0.5;
            if let Some(b) = best_under(&points, cut) {
                let fb = best_under(&front, cut).unwrap();
                if (b.tops - fb.tops).abs() > 1e-12 {
                    return Err("front lost the constrained optimum".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_alignment_symmetric_in_divisibility() {
    check(
        &Config { cases: 300, ..Default::default() },
        "alignment-divisibility",
        |r| {
            let vals = [1u64, 2, 3, 4, 6, 8, 12, 16];
            (
                *r.choose(&vals),
                *r.choose(&vals),
                *r.choose(&vals),
                *r.choose(&vals),
            )
        },
        |&(pa, pc, ca, cb)| {
            let prod = AccConfig { h1: 8, w1: 8, w2: 8, a: pa, b: 1, c: pc, part: (1, 1, 1) };
            let cons = AccConfig { h1: 8, w1: 8, w2: 8, a: ca, b: cb, c: 1, part: (1, 1, 1) };
            let aligned = prod.aligned_with(&cons);
            let expect = (pa % ca == 0 || ca % pa == 0) && (pc % cb == 0 || cb % pc == 0);
            if aligned != expect {
                return Err(format!("alignment({pa},{pc} vs {ca},{cb}) = {aligned}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_plan_covers_graph_and_preserves_assignment() {
    // For any genome: the materialized plan is structurally valid, keeps
    // the full nacc (no silent coarsening), schedules every class on its
    // assigned acc, and its from_depth twin matches the from_graph build.
    let graph = vit_graph(&DEIT_T);
    check_with(
        &Config { cases: 100, ..Default::default() },
        "plan-covers-graph",
        rand_assignment,
        |v| {
            let a = Assignment::new(v.clone());
            let p = ExecutionPlan::from_graph(&graph, &a, 1);
            p.validate().map_err(|e| format!("invalid plan for {:?}: {e}", a.acc_of))?;
            if p.nacc != a.nacc() {
                return Err(format!("plan nacc {} != assignment {}", p.nacc, a.nacc()));
            }
            if p.steps.len() != graph.nodes.len() {
                return Err("plan does not cover the graph".into());
            }
            for (s, n) in p.steps.iter().zip(&graph.nodes) {
                if s.acc != a.acc_of(n.class) {
                    return Err(format!("{:?} scheduled on acc {}", n.class, s.acc));
                }
            }
            let q = ExecutionPlan::from_depth("deit_t", graph.depth, &a, 1);
            if q.steps != p.steps {
                return Err("from_depth disagrees with from_graph".into());
            }
            Ok(())
        },
        shrink_usize_vec,
    );
}

#[test]
fn prop_stage4_projection_lossless_iff_representable() {
    // The coarsening report is truthful: lossless exactly when re-expanding
    // the projected stage grouping reproduces the original assignment.
    check_with(
        &Config { cases: 200, ..Default::default() },
        "projection-report-truthful",
        rand_assignment,
        |v| {
            let a = Assignment::new(v.clone());
            let (accs, report) = project_stage4(&a);
            let nacc_proj = accs.iter().copied().max().unwrap() + 1;
            if nacc_proj > a.nacc() || nacc_proj > 4 {
                return Err(format!("projection invented accs: {accs:?}"));
            }
            if report.nacc_after != nacc_proj {
                return Err("report nacc_after wrong".into());
            }
            // expand the 4-stage grouping back to 8 classes
            let representable = expand_stage4(accs) == a;
            if report.is_lossless() != representable {
                return Err(format!(
                    "report lossless={} but representable={} for {:?}",
                    report.is_lossless(),
                    representable,
                    a.acc_of
                ));
            }
            Ok(())
        },
        shrink_usize_vec,
    );
}

#[test]
fn prop_sim_batch_done_monotone_and_bounded() {
    let platform = vck190();
    let calib = Calib::default();
    let graph = vit_graph(&DEIT_T);
    check(
        &Config { cases: 15, ..Default::default() },
        "sim-batch-monotone",
        |r| (rand_assignment(r), 1 + r.usize_below(6)),
        |(v, batches)| {
            let a = Assignment::new(v.clone());
            let Some(ev) =
                build_design(&platform, &calib, &graph, &a, Features::all(), true)
            else {
                return Ok(());
            };
            let s = sim::simulate(&platform, &ev, &graph, *batches);
            for w in s.batch_done_s.windows(2) {
                if w[1] < w[0] {
                    return Err(format!("batch completion not monotone: {:?}", s.batch_done_s));
                }
            }
            let max_busy = s.acc_busy_s.iter().cloned().fold(0.0f64, f64::max);
            if s.makespan_s < max_busy - 1e-12 {
                return Err("makespan below busiest acc".into());
            }
            for &u in &s.acc_util {
                if !(0.0..=1.0 + 1e-9).contains(&u) {
                    return Err(format!("util out of range: {u}"));
                }
            }
            Ok(())
        },
    );
}
