//! Deterministic sim-driven tests of the adaptive plan scheduler
//! (ISSUE 2 acceptance invariants):
//!
//! (a) the active plan changes at most once per decision window, and
//!     consecutive switches are at least `patience` windows apart;
//! (b) no request is dropped during drain-and-swap — every arrival is
//!     either served or explicitly shed by admission control;
//! (c) p99 stays under the SLO when a feasible plan exists for the load.
//!
//! The front is synthetic (controlled capacities), the load is seeded
//! Poisson — the whole run is replayable, no artifacts required.
//!
//! Seed note: since the sim unification, `serve_ramp` draws its arrivals
//! through `TrafficMix::single` (class-0 split stream), exactly as a
//! 1-device fleet does, instead of seeding the ramp directly. Same
//! Poisson distribution, different concrete draw — the assertions here
//! are rate-level properties (switch direction, conservation, p99 under
//! a 3-sigma-margined ramp), each revalidated against the new streams
//! with a bit-faithful offline replay of the PRNG + sim core (under seed
//! 1234 the up_down ramp switches 0→1 at window 14 and 1→0 at window 25,
//! p99 ≈ 2.1 ms, zero shed).

use ssr::coordinator::scheduler::{RampSpec, SchedulerCfg};
use ssr::plan::front::{FrontEntry, PlanFront};
use ssr::sim::serving::{serve_ramp, ServeSimReport};

fn entry(label: &str, assign: Vec<usize>, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
    let nacc = assign.iter().copied().max().unwrap() + 1;
    FrontEntry {
        assign,
        batch,
        latency_ms: lat_ms,
        tops: rps * 2.5e-3,
        rps,
        nacc,
        label: label.to_string(),
    }
}

/// Three-point front with controlled capacities: a fast low-rate point, a
/// mid hybrid, and a slow high-rate point — the shape of Fig. 2's tradeoff.
fn front() -> PlanFront {
    PlanFront::new(
        "synthetic",
        12,
        vec![
            entry("seq", vec![0; 8], 1, 0.2, 5000.0),
            entry("hybrid", vec![0, 1, 1, 1, 0, 2, 2, 0], 8, 1.0, 8000.0),
            entry("spatial", (0..8).collect(), 24, 2.0, 12000.0),
        ],
    )
    .unwrap()
}

fn cfg() -> SchedulerCfg {
    SchedulerCfg {
        slo_ms: 20.0,
        window_s: 0.05,
        patience: 2,
        headroom: 0.75,
        shed_slack: 4.0,
        horizon_windows: 2,
        p99_aware: false,
    }
}

/// Rate ramp 1000 -> 4400 -> 1000 req/s: crosses the seq point's
/// headroom-adjusted capacity (demand 4400 / 0.75 ≈ 5870 > 5000) on the
/// way up and re-enters it on the way down, while staying several sigma
/// inside the hybrid point's capacity — a feasible plan exists throughout,
/// and the switch fires *before* the seq point saturates (4400 < 5000).
fn up_down() -> ServeSimReport {
    let ramp = RampSpec::parse("1000:4400:1000", 0.6).unwrap();
    serve_ramp(&front(), &ramp, &cfg(), 1234)
}

#[test]
fn ramp_up_and_down_switches_plans() {
    let r = up_down();
    assert!(
        r.switches.len() >= 2,
        "expected an up-switch and a down-switch, got {:?}",
        r.switches
    );
    // up: seq -> hybrid once the demand outgrows seq's headroom
    assert_eq!(r.switches[0].from, 0);
    assert_eq!(r.switches[0].to, 1);
    // down: back to the low-latency point when the rate drops
    assert_eq!(r.switches.last().unwrap().to, 0);
    assert_eq!(r.final_committed, 0);
    assert_eq!(r.final_draining, None);
}

#[test]
fn at_most_one_switch_per_window_and_patience_gaps() {
    let r = up_down();
    let c = cfg();
    for pair in r.switches.windows(2) {
        assert!(
            pair[1].window > pair[0].window,
            "two switches in one window: {:?}",
            r.switches
        );
        assert!(
            pair[1].window - pair[0].window >= c.patience,
            "switches closer than patience: {:?}",
            r.switches
        );
    }
    // and the per-window trace shows a single committed plan per window
    for ws in r.windows.windows(2) {
        let jump = ws[1].committed != ws[0].committed;
        if jump {
            let in_window = r.switches.iter().filter(|s| s.window == ws[1].window).count();
            assert!(in_window <= 1);
        }
    }
}

#[test]
fn drain_and_swap_drops_nothing() {
    let r = up_down();
    assert_eq!(
        r.served + r.shed,
        r.arrivals,
        "requests lost: {} served + {} shed != {} arrivals",
        r.served,
        r.shed,
        r.arrivals
    );
    // a feasible plan exists at every phase: admission control never fires
    assert_eq!(r.shed, 0, "shed under feasible load");
    assert_eq!(r.served, r.arrivals);
    assert_eq!(r.latency.len(), r.served);
}

#[test]
fn p99_stays_under_slo_when_a_feasible_plan_exists() {
    let r = up_down();
    let c = cfg();
    assert!(
        r.p99_ms() <= c.slo_ms,
        "p99 {:.2} ms exceeds the {} ms SLO (switches: {:?})",
        r.p99_ms(),
        c.slo_ms,
        r.switches
    );
    assert!(r.slo_attainment() >= 0.99);
}

#[test]
fn saturation_sheds_instead_of_growing_the_queue_unboundedly() {
    // Only the seq point (5000 img/s) against 20000 req/s offered: even the
    // throughput-optimal plan is saturated, so admission control must shed
    // while the queue stays bounded by the shed_slack budget.
    let f = PlanFront::new(
        "synthetic",
        12,
        vec![entry("seq", vec![0; 8], 1, 0.2, 5000.0)],
    )
    .unwrap();
    let ramp = RampSpec::parse("20000", 0.5).unwrap();
    let c = cfg();
    let r = serve_ramp(&f, &ramp, &c, 99);
    assert_eq!(r.served + r.shed, r.arrivals);
    assert!(r.shed > 1000, "expected heavy shedding, shed {}", r.shed);
    // admit() bound: queue wait <= shed_slack * slo => depth <= rps * budget
    let depth_cap = (5000.0 * c.shed_slack * c.slo_ms * 1e-3) as usize + 1;
    assert!(
        r.max_queue_depth <= depth_cap,
        "queue {} exceeds admission bound {}",
        r.max_queue_depth,
        depth_cap
    );
    assert!(r.switches.is_empty(), "single-entry front cannot switch");
}

#[test]
fn oscillating_load_does_not_flap_plans() {
    // Rate alternates across the switch threshold every single window; with
    // patience 2 no target persists long enough to commit a switch.
    let f = front();
    let mut c = cfg();
    c.horizon_windows = 1; // estimator tracks the instantaneous phase rate
    let ramp = RampSpec::parse("4000:1000:4000:1000:4000:1000:4000:1000", 0.05).unwrap();
    let r = serve_ramp(&f, &ramp, &c, 2024);
    assert!(
        r.switches.is_empty(),
        "hysteresis must damp per-window flapping, got {:?}",
        r.switches
    );
    assert_eq!(r.served + r.shed, r.arrivals);
}

#[test]
fn front_file_round_trip_drives_identical_schedule() {
    // The `ssr simulate --front front.json` path: saving and reloading the
    // front must reproduce the in-memory run exactly.
    let f = front();
    let path = std::env::temp_dir().join("ssr_adaptive_front_roundtrip.json");
    f.save(&path).unwrap();
    let loaded = PlanFront::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded, f);
    let ramp = RampSpec::parse("1000:4400:1000", 0.6).unwrap();
    let a = serve_ramp(&f, &ramp, &cfg(), 1234);
    let b = serve_ramp(&loaded, &ramp, &cfg(), 1234);
    assert_eq!(a.switches, b.switches);
    assert_eq!(a.served, b.served);
    assert_eq!(a.latency.p99(), b.latency.p99());
}
