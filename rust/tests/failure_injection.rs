//! Failure-injection tests: the runtime must fail loudly and precisely on
//! corrupted artifacts, not serve garbage.

use std::fs;
use std::path::{Path, PathBuf};

use ssr::runtime::exec::Engine;
use ssr::runtime::manifest::Manifest;
use ssr::runtime::weights::WeightStore;

/// Clone the smoke part of the real artifacts dir into a temp dir we can
/// corrupt. (Only manifest + smoke HLO + first weight blob are copied.)
fn scratch_dir(tag: &str) -> PathBuf {
    let src = PathBuf::from("artifacts");
    let dst = std::env::temp_dir().join(format!("ssr-failinj-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dst);
    fs::create_dir_all(dst.join("weights/deit_t")).unwrap();
    for f in ["manifest.json", "smoke.hlo.txt", "smoke_pallas.hlo.txt"] {
        fs::copy(src.join(f), dst.join(f)).unwrap();
    }
    dst
}

fn minimal_manifest(hlo: &str) -> String {
    format!(
        r#"{{"format_version":1,"models":{{}},"weights":[],
            "executables":[{{"name":"smoke","hlo":"{hlo}",
            "args":[{{"kind":"input","name":"x","shape":[2,2]}},
                    {{"kind":"input","name":"y","shape":[2,2]}}],
            "outputs":[[2,2]]}}]}}"#
    )
}

#[test]
fn malformed_manifest_json_rejected() {
    let dir = scratch_dir("badjson");
    fs::write(dir.join("manifest.json"), "{ not json ]").unwrap();
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("manifest parse"), "{err}");
}

#[test]
fn missing_manifest_fails_with_path() {
    let dir = std::env::temp_dir().join("ssr-failinj-nodir");
    let _ = fs::remove_dir_all(&dir);
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("manifest.json"), "{err}");
}

#[test]
fn non_dense_weight_ids_rejected() {
    let dir = scratch_dir("ids");
    fs::write(
        dir.join("manifest.json"),
        r#"{"format_version":1,"models":{},"executables":[],
            "weights":[{"id":5,"name":"w","shape":[1],"file":"weights/deit_t/w0005.bin"}]}"#,
    )
    .unwrap();
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("dense"), "{err}");
}

#[test]
fn truncated_weight_blob_rejected() {
    let dir = scratch_dir("trunc");
    fs::write(
        dir.join("manifest.json"),
        r#"{"format_version":1,"models":{},"executables":[],
            "weights":[{"id":0,"name":"w","shape":[4,4],"file":"weights/deit_t/w0000.bin"}]}"#,
    )
    .unwrap();
    // 4x4 f32 needs 64 bytes; write 60.
    fs::write(dir.join("weights/deit_t/w0000.bin"), vec![0u8; 60]).unwrap();
    let m = Manifest::load(&dir).unwrap();
    let err = WeightStore::load(&m).unwrap_err().to_string();
    assert!(err.contains("expected 64"), "{err}");
}

#[test]
fn missing_hlo_file_fails_at_compile_not_load() {
    let dir = scratch_dir("nohlo");
    fs::write(dir.join("manifest.json"), minimal_manifest("does_not_exist.hlo.txt")).unwrap();
    let engine = Engine::load(&dir).unwrap(); // load is lazy about HLO
    let err = engine.compile("smoke").unwrap_err().to_string();
    assert!(err.contains("does_not_exist"), "{err}");
}

#[test]
fn garbage_hlo_text_fails_to_parse() {
    let dir = scratch_dir("badhlo");
    fs::write(dir.join("smoke.hlo.txt"), "HloModule nonsense ha ha {{{{").unwrap();
    fs::write(dir.join("manifest.json"), minimal_manifest("smoke.hlo.txt")).unwrap();
    let engine = Engine::load(&dir).unwrap();
    assert!(engine.compile("smoke").is_err());
}

#[test]
fn unknown_arg_kind_rejected() {
    let dir = scratch_dir("argkind");
    fs::write(
        dir.join("manifest.json"),
        r#"{"format_version":1,"models":{},"weights":[],
            "executables":[{"name":"x","hlo":"smoke.hlo.txt",
            "args":[{"kind":"mystery"}],"outputs":[]}]}"#,
    )
    .unwrap();
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("unknown arg kind"), "{err}");
}

#[test]
fn weight_ref_out_of_range_fails_compile() {
    let dir = scratch_dir("wref");
    fs::write(
        dir.join("manifest.json"),
        r#"{"format_version":1,"models":{},"weights":[],
            "executables":[{"name":"smoke","hlo":"smoke.hlo.txt",
            "args":[{"kind":"weight","weight":42},
                    {"kind":"input","name":"y","shape":[2,2]}],
            "outputs":[[2,2]]}]}"#,
    )
    .unwrap();
    let engine = Engine::load(&dir).unwrap();
    let err = engine.compile("smoke").unwrap_err().to_string();
    assert!(err.contains("42"), "{err}");
}

/// Guard: corrupting a real weight file changes outputs (the runtime truly
/// reads the blobs — no silent caching of stale weights).
#[test]
fn weights_actually_flow_into_results() {
    let src = Path::new("artifacts");
    let m = Manifest::load(src).unwrap();
    let s = WeightStore::load(&m).unwrap();
    // pick the qkv weight of block 0 and verify non-trivial content
    let some = (0..s.len())
        .map(|i| s.get(i).unwrap())
        .find(|w| w.name.contains("wqkv"))
        .expect("qkv weight present");
    let nonzero = some.data.iter().filter(|x| **x != 0.0).count();
    assert!(nonzero > some.data.len() / 2, "qkv weights look empty");
}
