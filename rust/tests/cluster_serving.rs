//! Fleet-serving invariants (ISSUE 3 acceptance criteria):
//!
//! (a) conservation — per device and fleet-wide, `served + shed ==
//!     arrivals`: routing and drain-and-swap never lose a request;
//! (b) determinism — an identical seed reproduces identical per-device
//!     tallies across two simulation runs;
//! (c) provisioning — under the same forecast + SLO, the heterogeneous
//!     hybrid fleet needs no more devices than either homogeneous
//!     seq-only or spatial-only fleet (no more power on a device-count
//!     tie), and the provisioned fleet's simulated p99 meets the SLO
//!     when the load is feasible.
//!
//! Everything runs on the analytical fronts + the deterministic fleet
//! sim — no artifacts required.

use ssr::cluster::fleet::strategy_front;
use ssr::cluster::{provision, simulate_fleet, PlatformOption, RoutePolicy, TrafficMix};
use ssr::coordinator::scheduler::{RampSpec, SchedulerCfg};

const SLO_MS: f64 = 25.0;
const HEADROOM: f64 = 0.8;
const BATCHES: [usize; 3] = [1, 3, 6];

fn cfg() -> SchedulerCfg {
    SchedulerCfg { slo_ms: SLO_MS, ..Default::default() }
}

/// The provisioning forecast: peaks at 12k req/s.
fn forecast() -> RampSpec {
    RampSpec::parse("3000:12000:3000", 0.4).unwrap()
}

fn het_options() -> Vec<PlatformOption> {
    // Full hybrid front on the Versal board, plus the monolithic FPGA
    // baselines as cheap-capacity options (no stratix here: the test's
    // ramp shape is tuned to the vck190 capacity scale).
    vec![
        PlatformOption::synth("vck190", "deit_t", &BATCHES).unwrap(),
        PlatformOption::synth("u250", "deit_t", &BATCHES).unwrap(),
        PlatformOption::synth("zcu102", "deit_t", &BATCHES).unwrap(),
    ]
}

fn homogeneous_option(strategy: &str) -> PlatformOption {
    PlatformOption {
        platform: "vck190".to_string(),
        front: strategy_front("vck190", "deit_t", strategy, &BATCHES).unwrap(),
    }
}

/// A load ramp expressed as fractions of the fleet's provisioned
/// capacity, peaking at 72% — feasible throughout. Every up-step grows by
/// at most 1.25x, so each phase's offered load stays below the *previous*
/// phase's demand estimate (rate / headroom, headroom = 0.8): whatever
/// plan the per-device scheduler switched to last phase already covers
/// this phase's offered load, and the proactive switch always lands
/// before saturation — the fleet-scale version of the single-device
/// adaptive-scheduler test's "switch fires before the seq point
/// saturates" setup.
fn sim_ramp(capacity_rps: f64) -> RampSpec {
    let fracs = [0.3, 0.5, 0.6, 0.72, 0.6, 0.5, 0.3];
    let spec: Vec<String> =
        fracs.iter().map(|f| format!("{:.0}", f * capacity_rps)).collect();
    RampSpec::parse(&spec.join(":"), 0.3).unwrap()
}

#[test]
fn conservation_per_device_and_fleet_wide_on_a_provisioned_fleet() {
    let p = provision("het", &het_options(), &forecast(), SLO_MS, HEADROOM).unwrap();
    let mix = TrafficMix::single("deit_t", sim_ramp(p.capacity_rps));
    for policy in
        [RoutePolicy::RoundRobin, RoutePolicy::ShortestQueue, RoutePolicy::PowerOfTwoSlo]
    {
        let r = simulate_fleet(&p.fleet, &mix, &cfg(), policy, 42).unwrap();
        assert!(r.arrivals > 1000, "load generator produced {}", r.arrivals);
        assert_eq!(r.served + r.shed, r.arrivals, "{policy:?}: fleet lost requests");
        assert_eq!(r.latency.len(), r.served);
        let routed: usize = r.devices.iter().map(|d| d.routed).sum();
        assert_eq!(routed + r.unroutable, r.arrivals, "{policy:?}: routing lost requests");
        assert_eq!(r.unroutable, 0, "every device serves deit_t");
        for d in &r.devices {
            assert_eq!(
                d.served + d.shed,
                d.routed,
                "{policy:?}: device {} lost requests",
                d.id
            );
        }
    }
}

#[test]
fn identical_seed_identical_per_device_tallies() {
    let p = provision("het", &het_options(), &forecast(), SLO_MS, HEADROOM).unwrap();
    let mix = TrafficMix::single("deit_t", sim_ramp(p.capacity_rps));
    let a = simulate_fleet(&p.fleet, &mix, &cfg(), RoutePolicy::PowerOfTwoSlo, 7).unwrap();
    let b = simulate_fleet(&p.fleet, &mix, &cfg(), RoutePolicy::PowerOfTwoSlo, 7).unwrap();
    assert_eq!(a.arrivals, b.arrivals);
    assert_eq!(a.served, b.served);
    assert_eq!(a.shed, b.shed);
    assert_eq!(a.makespan_s, b.makespan_s);
    assert_eq!(a.latency.percentiles(&[0.5, 0.99]), b.latency.percentiles(&[0.5, 0.99]));
    assert_eq!(a.devices.len(), b.devices.len());
    for (da, db) in a.devices.iter().zip(&b.devices) {
        assert_eq!(da.id, db.id);
        assert_eq!(da.routed, db.routed, "device {} tallies diverged", da.id);
        assert_eq!(da.served, db.served);
        assert_eq!(da.shed, db.shed);
        assert_eq!(da.switches, db.switches);
        assert_eq!(da.max_queue_depth, db.max_queue_depth);
    }
}

#[test]
fn heterogeneous_hybrid_provisions_no_worse_than_homogeneous_fleets() {
    let fc = forecast();
    let het = provision("het", &het_options(), &fc, SLO_MS, HEADROOM).unwrap();
    let seq = provision("seq", &[homogeneous_option("sequential")], &fc, SLO_MS, HEADROOM)
        .unwrap();
    let spa = provision("spa", &[homogeneous_option("spatial")], &fc, SLO_MS, HEADROOM)
        .unwrap();
    // The paper's tradeoff at fleet scale: sequential-only fleets buy
    // latency with device count; the hybrid candidate pool contains every
    // pure-strategy point, so it can never need more devices.
    assert!(
        het.devices <= seq.devices,
        "het {} devices > seq-only {}",
        het.devices,
        seq.devices
    );
    assert!(
        het.devices <= spa.devices,
        "het {} devices > spatial-only {}",
        het.devices,
        spa.devices
    );
    // On a device-count tie the hybrid fleet must not be strictly worse:
    // no more power, unless the extra power bought strictly more capacity.
    for homo in [&seq, &spa] {
        if het.devices == homo.devices {
            assert!(
                het.power_w <= homo.power_w + 1e-9
                    || het.capacity_rps > homo.capacity_rps + 1e-9,
                "equal devices but {} W > {} W at no capacity gain ({} vs {} req/s)",
                het.power_w,
                homo.power_w,
                het.capacity_rps,
                homo.capacity_rps
            );
        }
    }
    // sequential-only really is the expensive corner at this peak
    assert!(seq.devices > spa.devices, "expected seq-only to need extra devices");
    // every provisioned fleet covers its forecast peak
    for p in [&het, &seq, &spa] {
        assert!(p.capacity_rps + 1e-9 >= p.peak_rps, "{} under-provisioned", p.fleet.name);
    }
}

#[test]
fn provisioned_fleet_meets_the_slo_under_a_feasible_ramp() {
    let p = provision("het", &het_options(), &forecast(), SLO_MS, HEADROOM).unwrap();
    assert!(p.devices >= 2, "ramp-shape assumptions need a multi-device fleet");
    let mix = TrafficMix::single("deit_t", sim_ramp(p.capacity_rps));
    let r = simulate_fleet(&p.fleet, &mix, &cfg(), RoutePolicy::PowerOfTwoSlo, 2024).unwrap();
    assert_eq!(r.served + r.shed, r.arrivals);
    assert_eq!(r.shed, 0, "shed under a feasible (<=72% capacity) ramp");
    assert!(
        r.p99_ms() <= SLO_MS,
        "fleet p99 {:.2} ms exceeds the {SLO_MS} ms SLO ({})",
        r.p99_ms(),
        r.summary_line()
    );
    assert!(r.slo_attainment() >= 0.99);
    // the adaptive layer is actually exercised: the ramp crosses the
    // low-latency plans' demand thresholds on the way up and back down
    assert!(
        r.total_switches() >= 2,
        "expected per-device up/down switches, got {}",
        r.total_switches()
    );
}
