//! Cross-module integration tests: graph -> DSE -> analytical -> simulator
//! -> report, exercising the full L3 stack without the PJRT runtime.

use ssr::analytical::{Calib, Features};
use ssr::arch::{stratix10nx, vck190, vck190_hbm};
use ssr::dse::ea::{run_ea, EaParams};
use ssr::dse::enumerate;
use ssr::dse::eval::build_design;
use ssr::dse::pareto::{front_dominates, pareto_front, Point};
use ssr::dse::Assignment;
use ssr::graph::{vit_graph, DEIT_T, DEIT_T_160, DEIT_T_256, LV_VIT_T};
use ssr::report::tables::{self, Ctx};
use ssr::sim;
use ssr::util::stats::rel_err;

fn ctx() -> Ctx {
    Ctx::quick()
}

#[test]
fn all_models_have_feasible_designs_for_all_strategies() {
    let c = ctx();
    for cfg in [&DEIT_T, &DEIT_T_160, &DEIT_T_256, &LV_VIT_T] {
        let g = vit_graph(cfg);
        for a in [
            Assignment::sequential(),
            Assignment::spatial(),
            Assignment::new(vec![0, 1, 1, 1, 0, 2, 2, 0]),
        ] {
            let ev = build_design(&c.platform, &c.calib, &g, &a, Features::all(), true)
                .unwrap_or_else(|| panic!("{}: {:?} infeasible", cfg.name, a.acc_of));
            let e = ev.evaluate(&c.platform, &g, 6);
            assert!(e.latency_s > 0.0 && e.latency_s < 0.1, "{}: {}", cfg.name, e.latency_s);
            assert!(e.tops > 0.5 && e.tops < c.platform.peak_int8_tops());
        }
    }
}

#[test]
fn bigger_models_take_longer() {
    let c = ctx();
    let mut latencies = Vec::new();
    for cfg in [&DEIT_T_160, &DEIT_T, &LV_VIT_T, &DEIT_T_256] {
        let g = vit_graph(cfg);
        let ev = build_design(&c.platform, &c.calib, &g, &Assignment::sequential(), Features::all(), true)
            .unwrap();
        latencies.push(ev.evaluate(&c.platform, &g, 1).latency_s);
    }
    for w in latencies.windows(2) {
        assert!(w[1] > w[0] * 0.95, "latency ordering violated: {latencies:?}");
    }
}

#[test]
fn sim_and_analytical_agree_across_strategies() {
    let c = ctx();
    let g = vit_graph(&DEIT_T);
    for a in [
        Assignment::sequential(),
        Assignment::spatial(),
        Assignment::new(vec![0, 1, 2, 1, 0, 2, 2, 0]),
    ] {
        let ev = build_design(&c.platform, &c.calib, &g, &a, Features::all(), true).unwrap();
        let ana = ev.evaluate(&c.platform, &g, 6).latency_s;
        let s = sim::simulate(&c.platform, &ev, &g, 6).makespan_s;
        assert!(
            rel_err(ana, s) < 0.20,
            "{:?}: analytical {ana} vs sim {s}",
            a.acc_of
        );
    }
}

#[test]
fn ea_matches_exhaustive_on_small_space() {
    // With max_acc = 2 the space is 128 genomes: the EA with memoization
    // must find the same optimum as brute force.
    let c = ctx();
    let g = vit_graph(&DEIT_T);
    let brute = enumerate::all_up_to(2)
        .iter()
        .filter_map(|a| {
            build_design(&c.platform, &c.calib, &g, a, Features::all(), true)
                .map(|ev| ev.evaluate(&c.platform, &g, 6).tops)
        })
        .fold(0.0f64, f64::max);
    let ea = run_ea(
        &c.platform,
        &c.calib,
        &g,
        Features::all(),
        true,
        &EaParams { max_acc: Some(2), n_pop: 16, n_child: 16, n_iter: 10, seed: 1, ..Default::default() },
    );
    let ea_best = ea.best.map(|(_, e)| e.tops).unwrap_or(0.0);
    assert!(
        (ea_best - brute).abs() / brute < 0.02,
        "EA {ea_best} vs brute {brute}"
    );
}

#[test]
fn hybrid_front_dominates_both_pure_fronts() {
    let f = tables::fig2(&ctx());
    let front = f.hybrid_front();
    assert!(front_dominates(&front, &f.seq));
    assert!(front_dominates(&front, &f.spatial));
    // and the front itself is non-dominated
    assert_eq!(pareto_front(&front).len(), front.len());
}

#[test]
fn platform_ordering_stratix_vs_vck190() {
    // §6 Q1: Stratix 10 NX (more compute + HBM) should map DeiT-T at a
    // latency comparable-or-better than VCK190.
    let rows = tables::multi_platform(true);
    let get = |name: &str| rows.iter().find(|r| r.platform == name).unwrap().latency_ms;
    let vck = get("vck190");
    let hbm = get("vck190_hbm");
    let stx = get("stratix10nx");
    assert!(hbm <= vck * 1.001, "HBM variant should not be slower");
    assert!(stx < vck * 1.3, "stratix {stx} vs vck {vck}");
}

#[test]
fn feature_flags_monotone() {
    // Enabling each optimization never hurts end-to-end latency.
    let c = ctx();
    let g = vit_graph(&DEIT_T);
    let base = build_design(
        &c.platform, &c.calib, &g, &Assignment::sequential(), Features::baseline(), false,
    )
    .unwrap()
    .evaluate(&c.platform, &g, 6)
    .latency_s;
    let full = build_design(
        &c.platform, &c.calib, &g, &Assignment::spatial(), Features::all(), true,
    )
    .unwrap()
    .evaluate(&c.platform, &g, 6)
    .latency_s;
    assert!(full < base / 5.0, "full SSR {full} vs baseline {base}");
}

#[test]
fn batch_sweep_monotone_throughput_for_spatial() {
    let c = ctx();
    let g = vit_graph(&DEIT_T);
    let ev = build_design(&c.platform, &c.calib, &g, &Assignment::spatial(), Features::all(), true)
        .unwrap();
    let mut last = 0.0;
    for b in 1..=6 {
        let t = ev.evaluate(&c.platform, &g, b).tops;
        assert!(t >= last, "throughput dropped at batch {b}");
        last = t;
    }
}

#[test]
fn pareto_points_from_different_backends_compose() {
    // Points from the analytical model and the simulator can be mixed in
    // one front (the report pipeline does this for Table 6).
    let c = ctx();
    let g = vit_graph(&DEIT_T);
    let ev = build_design(&c.platform, &c.calib, &g, &Assignment::spatial(), Features::all(), true)
        .unwrap();
    let ana = ev.evaluate(&c.platform, &g, 6);
    let s = sim::simulate(&c.platform, &ev, &g, 6);
    let pts = [
        Point { latency_ms: ana.latency_s * 1e3, tops: ana.tops, batch: 6, nacc: 8 },
        Point { latency_ms: s.makespan_s * 1e3, tops: s.tops, batch: 6, nacc: 8 },
    ];
    assert!(!pareto_front(&pts).is_empty());
}

#[test]
fn other_platforms_support_full_dse() {
    for p in [vck190(), vck190_hbm(), stratix10nx()] {
        let g = vit_graph(&DEIT_T);
        let cal = Calib::default();
        let ev = build_design(&p, &cal, &g, &Assignment::spatial(), Features::all(), true)
            .unwrap_or_else(|| panic!("{} infeasible", p.name));
        let e = ev.evaluate(&p, &g, 6);
        assert!(e.latency_s > 0.0 && e.tops > 1.0, "{}: {e:?}", p.name);
    }
}
