//! PJRT runtime integration tests (require `make artifacts`).
//!
//! These exercise the full build-time -> serve-time contract: manifest,
//! weight blobs, HLO text compilation, per-block weight indirection, and —
//! crucially — that the Pallas-kernel block artifact (L1 lowered into HLO)
//! matches the plain-jnp stage executables numerically on the PJRT CPU.

use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

use ssr::coordinator::pipeline::{synth_images, PipelineServer, SequentialServer};
use ssr::coordinator::StageAssign;
use ssr::runtime::exec::{Engine, Tensor};

fn engine() -> Arc<Engine> {
    static E: OnceLock<Arc<Engine>> = OnceLock::new();
    Arc::clone(E.get_or_init(|| {
        Engine::load(&PathBuf::from("artifacts")).expect("run `make artifacts` first")
    }))
}

fn close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    assert_eq!(a.len(), b.len());
    let max = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    if max > tol {
        return Err(format!("max diff {max} > {tol}"));
    }
    Ok(())
}

#[test]
fn pallas_block_artifact_matches_jnp_stages() {
    // deit_t_block_pallas_b1 is the whole transformer block built from the
    // L1 Pallas kernels (matmul/softmax/layernorm/gelu) and lowered into
    // HLO. Running it must equal attn_b1 + mlp_b1 (plain-jnp path) on the
    // same block weights.
    let e = engine();
    let pallas = e.compile("deit_t_block_pallas_b1").unwrap();
    let attn = e.compile("deit_t_attn_b1").unwrap();
    let mlp = e.compile("deit_t_mlp_b1").unwrap();

    let mut rng = ssr::util::rng::Rng::new(99);
    let x = Tensor::new(
        vec![1, 197, 192],
        (0..197 * 192).map(|_| (rng.f64() as f32 - 0.5) * 2.0).collect(),
    );
    for block in [0usize, 5, 11] {
        let want = mlp
            .run(&e, &[attn.run(&e, &[x.clone()], Some(block)).unwrap()], Some(block))
            .unwrap();
        let got = pallas.run(&e, &[x.clone()], Some(block)).unwrap();
        close(&got.data, &want.data, 5e-3).unwrap_or_else(|m| {
            panic!("block {block}: pallas vs jnp {m}")
        });
    }
}

#[test]
fn full_model_equals_staged_pipeline_b1_and_b6() {
    let e = engine();
    let seq = SequentialServer::new(Arc::clone(&e), "deit_t", &[1, 6]).unwrap();
    // b1 through the 4-stage pipeline
    let pipe = PipelineServer::new(Arc::clone(&e), "deit_t", &StageAssign::spatial(), 1).unwrap();
    let img = synth_images(1, 224, 3);
    let a = seq.run_batch(1, &img).unwrap();
    let (_, outs) = pipe.serve(vec![img]).unwrap();
    close(&a.data, &outs[0].data, 2e-3).unwrap();

    // b6 through the b6-stage pipeline
    let pipe6 = PipelineServer::new(Arc::clone(&e), "deit_t", &StageAssign::spatial(), 6).unwrap();
    let img6 = synth_images(6, 224, 4);
    let a6 = seq.run_batch(6, &img6).unwrap();
    let (_, outs6) = pipe6.serve(vec![img6]).unwrap();
    close(&a6.data, &outs6[0].data, 2e-3).unwrap();
}

#[test]
fn batch_rows_independent_on_runtime() {
    // Row 0 of a batch-6 run equals a batch-1 run of the same image.
    let e = engine();
    let seq = SequentialServer::new(Arc::clone(&e), "deit_t", &[1, 6]).unwrap();
    let img6 = synth_images(6, 224, 7);
    let img1 = Tensor::new(vec![1, 224, 224, 3], img6.data[..224 * 224 * 3].to_vec());
    let out6 = seq.run_batch(6, &img6).unwrap();
    let out1 = seq.run_batch(1, &img1).unwrap();
    close(&out6.data[..1000], &out1.data, 2e-3).unwrap();
}

#[test]
fn logits_deterministic_across_runs() {
    let e = engine();
    let seq = SequentialServer::new(Arc::clone(&e), "deit_t", &[1]).unwrap();
    let img = synth_images(1, 224, 11);
    let a = seq.run_batch(1, &img).unwrap();
    let b = seq.run_batch(1, &img).unwrap();
    assert_eq!(a.data, b.data);
}

#[test]
fn pipeline_interleaves_many_requests() {
    let e = engine();
    let pipe = PipelineServer::new(Arc::clone(&e), "deit_t", &StageAssign::spatial(), 1).unwrap();
    let imgs: Vec<_> = (0..8).map(|i| synth_images(1, 224, 100 + i)).collect();
    let expected: Vec<_> = {
        let seq = SequentialServer::new(Arc::clone(&e), "deit_t", &[1]).unwrap();
        imgs.iter().map(|im| seq.run_batch(1, im).unwrap()).collect()
    };
    let (report, outs) = pipe.serve(imgs).unwrap();
    assert_eq!(report.requests, 8);
    for (got, want) in outs.iter().zip(&expected) {
        close(&got.data, &want.data, 2e-3).unwrap();
    }
}

#[test]
fn all_manifest_models_have_required_stages() {
    let e = engine();
    for model in e.manifest.models.keys() {
        for stage in ["embed", "attn", "mlp", "head"] {
            e.manifest
                .find_stage(model, stage, 1)
                .unwrap_or_else(|_| panic!("{model} missing stage {stage}"));
        }
        e.manifest.find(&format!("{model}_full_b1")).unwrap();
    }
}

#[test]
fn batching_server_matches_individual_runs() {
    use ssr::coordinator::batcher::BatchingServer;
    let e = engine();
    let seq = SequentialServer::new(Arc::clone(&e), "deit_t", &[1, 3, 6]).unwrap();
    let expected: Vec<Tensor> = (0..7)
        .map(|i| seq.run_batch(1, &synth_images(1, 224, 200 + i)).unwrap())
        .collect();
    let batcher = BatchingServer::new(seq);
    assert_eq!(batcher.policy().plan(7), vec![6, 1]);
    let reqs: Vec<Tensor> = (0..7).map(|i| synth_images(1, 224, 200 + i)).collect();
    let (report, outs) = batcher.serve(&reqs).unwrap();
    assert_eq!(report.requests, 7);
    assert_eq!(outs.len(), 7);
    for (got, want) in outs.iter().zip(&expected) {
        close(&got.data, &want.data, 2e-3).unwrap();
    }
}
