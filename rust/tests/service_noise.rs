//! Input-dynamic serving invariant torture suite (ISSUE 10 acceptance):
//!
//! (a) differential pin — [`ServiceModel::Deterministic`] replays the
//!     pre-noise path *bit-identically*: a trace with an explicit
//!     `Deterministic` service equals the same trace with no service at
//!     all, and `serve_ramp` equals a 1-device `simulate_fleet` twin on
//!     every shared field (the `sim_unification` equivalence), for
//!     deterministic AND stochastic service models alike — the service
//!     stream is split per device index, so both entry points draw the
//!     exact same factors;
//! (b) property torture — over randomized service models (all four
//!     kinds) x fleets x seeds, for all three route policies: fleet-wide
//!     and per-device `served + shed == arrivals` conservation, seed
//!     determinism of the full recorded event log (not just tallies),
//!     trace-reconstructed tallies equal to the report, and the
//!     scheduler's hysteresis contract (at most one switch per window;
//!     consecutive switches at least `patience` windows apart) holding
//!     under arbitrarily noisy service times;
//! (c) requeue ledgers — drains, faults, and front swaps under
//!     stochastic service times keep every autoscale requeue identity
//!     exact (`sum(requeued_away) == requeued`, placed requeues ==
//!     `sum(requeued_in)`, per-device `served + shed + requeued_away ==
//!     routed`);
//! (d) p99-aware scheduling — on a heavy-tail workload the
//!     `p99_aware` scheduler sheds strictly fewer requests than the
//!     mean-based one at the same SLO (the headline tradeoff, pinned at
//!     a fixed seed).
//!
//! Everything is deterministic and artifact-free.

use ssr::cluster::controller::FaultEvent;
use ssr::cluster::fleet::{DeviceSpec, FleetSpec};
use ssr::cluster::{
    simulate_autoscale, simulate_fleet, simulate_fleet_observed, AutoscaleCfg, AutoscaleReport,
    AutoscaleSpec, FaultSpec, RoutePolicy, TrafficClass, TrafficMix,
};
use ssr::coordinator::scheduler::{RampSpec, SchedulerCfg};
use ssr::obs::{trace_tallies, TraceEvent, TraceRecorder};
use ssr::plan::front::{FrontEntry, PlanFront};
use ssr::sim::serving::serve_ramp;
use ssr::sim::service::ServiceModel;
use ssr::traffic::TraceSpec;
use ssr::util::prop::{check, Config};
use ssr::util::rng::Rng;

const POLICIES: [RoutePolicy; 3] =
    [RoutePolicy::RoundRobin, RoutePolicy::ShortestQueue, RoutePolicy::PowerOfTwoSlo];

fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
    FrontEntry {
        assign: vec![0; 8],
        batch,
        latency_ms: lat_ms,
        tops: rps * 2.5e-3,
        rps,
        nacc: 1,
        label: label.to_string(),
    }
}

fn front3(model: &str) -> PlanFront {
    PlanFront::new(
        model,
        12,
        vec![
            entry("seq", 1, 0.2, 5000.0),
            entry("hybrid", 6, 1.0, 6000.0),
            entry("spatial", 24, 2.0, 12000.0),
        ],
    )
    .unwrap()
}

fn one_device_fleet(front: PlanFront) -> FleetSpec {
    FleetSpec::new(
        "solo",
        vec![DeviceSpec {
            id: "vck190-0".to_string(),
            platform: "vck190".to_string(),
            front,
        }],
    )
    .unwrap()
}

/// One stochastic representative per non-deterministic kind.
fn noisy_models() -> Vec<ServiceModel> {
    vec![
        ServiceModel::LognormalFactor { sigma: 1.0 },
        ServiceModel::TokenPruning { alpha: 2.0, beta: 3.0 },
        ServiceModel::EarlyExit {
            exit_probs: vec![0.35, 0.25],
            stage_fractions: vec![0.25, 0.55],
        },
    ]
}

/// Random service model over all four kinds, always within
/// `ServiceModel::validate`'s domain.
fn gen_service(rng: &mut Rng) -> ServiceModel {
    match rng.usize_below(4) {
        0 => ServiceModel::Deterministic,
        1 => ServiceModel::LognormalFactor { sigma: 0.2 + rng.f64() * 1.8 },
        2 => ServiceModel::TokenPruning {
            alpha: 0.5 + rng.f64() * 3.0,
            beta: 0.5 + rng.f64() * 3.0,
        },
        _ => {
            let stages = 1 + rng.usize_below(3);
            // Spend a shrinking probability budget so the sum stays < 1.
            let mut budget = 1.0;
            let mut exit_probs = Vec::new();
            for _ in 0..stages {
                let p = budget * rng.f64() * 0.8;
                exit_probs.push(p);
                budget -= p;
            }
            let stage_fractions = (0..stages)
                .map(|i| (0.2 + 0.8 * (i as f64 + rng.f64()) / stages as f64).min(1.0))
                .collect();
            ServiceModel::EarlyExit { exit_probs, stage_fractions }
        }
    }
}

/// Assert every field the two reports share is identical (the
/// `sim_unification` twin sweep, reused so noise cannot split the two
/// entry points).
fn assert_equivalent(
    r1: &ssr::sim::serving::ServeSimReport,
    fleet_r: &ssr::cluster::sim::FleetSimReport,
    ctx: &str,
) {
    assert_eq!(fleet_r.devices.len(), 1, "{ctx}: not a 1-device fleet");
    let d = &fleet_r.devices[0];
    assert_eq!(r1.arrivals, fleet_r.arrivals, "{ctx}: arrivals");
    assert_eq!(r1.served, fleet_r.served, "{ctx}: served");
    assert_eq!(r1.shed, fleet_r.shed, "{ctx}: shed");
    assert_eq!(fleet_r.unroutable, 0, "{ctx}: unroutable in a matched 1-device fleet");
    assert_eq!(r1.served, d.served, "{ctx}: device served");
    assert_eq!(r1.switches, d.switches, "{ctx}: switches");
    assert_eq!(r1.windows, d.windows, "{ctx}: per-window stats");
    assert_eq!(r1.max_queue_depth, d.max_queue_depth, "{ctx}: max queue depth");
    assert_eq!(r1.slo_violations, fleet_r.slo_violations, "{ctx}: slo violations");
    assert_eq!(r1.final_committed, d.final_committed, "{ctx}: final committed");
    assert_eq!(r1.final_draining, d.final_draining, "{ctx}: final draining");
    assert_eq!(
        r1.makespan_s.to_bits(),
        fleet_r.makespan_s.to_bits(),
        "{ctx}: makespan diverged ({} vs {})",
        r1.makespan_s,
        fleet_r.makespan_s
    );
    let qs = [0.0, 0.01, 0.25, 0.50, 0.75, 0.90, 0.99, 1.0];
    let p1 = r1.latency.percentiles(&qs);
    let p2 = fleet_r.latency.percentiles(&qs);
    for (a, b) in p1.iter().zip(&p2) {
        assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: latency quantiles diverged");
    }
}

// ---------------------------------------------------------------------------
// (a) Differential pins
// ---------------------------------------------------------------------------

#[test]
fn explicit_deterministic_service_is_the_pre_noise_path_to_the_bit() {
    let ramp = RampSpec::parse("1000:4400:1000", 0.6).unwrap();
    let mix = TrafficMix::single("m", ramp);
    let cfg = SchedulerCfg { slo_ms: 20.0, ..Default::default() };
    for seed in [1u64, 7, 1234, 0xDEAD] {
        // No service key at all (the pre-noise artifact shape) ...
        let bare = serve_ramp(&front3("m"), TraceSpec::from(&mix), &cfg, seed);
        // ... an explicit Deterministic override ...
        let explicit = serve_ramp(
            &front3("m"),
            TraceSpec::from(&mix).with_service(&ServiceModel::Deterministic),
            &cfg,
            seed,
        );
        // ... and the raw mix, which never heard of service models.
        let legacy = serve_ramp(&front3("m"), &mix, &cfg, seed);
        for (r, ctx) in [(&explicit, "explicit det"), (&legacy, "legacy mix")] {
            assert_eq!(bare.arrivals, r.arrivals, "{ctx} seed {seed}: arrivals");
            assert_eq!(bare.served, r.served, "{ctx} seed {seed}: served");
            assert_eq!(bare.shed, r.shed, "{ctx} seed {seed}: shed");
            assert_eq!(bare.switches, r.switches, "{ctx} seed {seed}: switches");
            assert_eq!(bare.windows, r.windows, "{ctx} seed {seed}: windows");
            assert_eq!(
                bare.makespan_s.to_bits(),
                r.makespan_s.to_bits(),
                "{ctx} seed {seed}: makespan"
            );
        }
    }
}

#[test]
fn noisy_twins_serve_ramp_equals_one_device_fleet() {
    // The service stream is `Rng::new(seed).split(SERVICE_STREAM).split(0)`
    // on both entry points, so the twin equivalence must survive noise.
    let ramp = RampSpec::parse("1000:4400:1000", 0.6).unwrap();
    let mix = TrafficMix::single("m", ramp);
    let cfg = SchedulerCfg { slo_ms: 20.0, ..Default::default() };
    let mut services = noisy_models();
    services.push(ServiceModel::Deterministic);
    for service in &services {
        let trace = TraceSpec::from(&mix).with_service(service);
        for seed in [7u64, 0xDEAD] {
            for policy in POLICIES {
                let r1 = serve_ramp(&front3("m"), trace.clone(), &cfg, seed);
                let r2 = simulate_fleet(
                    &one_device_fleet(front3("m")),
                    trace.clone(),
                    &cfg,
                    policy,
                    seed,
                )
                .unwrap();
                assert_equivalent(&r1, &r2, &format!("{} seed {seed} {policy:?}", service.label()));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// (b) Property torture over randomized noisy scenarios
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
struct Scenario {
    fleet: FleetSpec,
    trace: TraceSpec,
    cfg: SchedulerCfg,
    seed: u64,
}

/// Random front for `model`: 1..=3 entries with strictly increasing
/// latency and rate (so none is Pareto-pruned) at controlled scales.
fn gen_front(rng: &mut Rng, model: &str) -> PlanFront {
    let n = 1 + rng.usize_below(3);
    let mut lat_ms = 0.1 + rng.f64() * 0.9;
    let mut rps = 2000.0 + rng.f64() * 4000.0;
    let mut entries = Vec::new();
    for (i, &batch) in [1usize, 6, 24].iter().enumerate().take(n) {
        entries.push(entry(&format!("e{i}"), batch, lat_ms, rps));
        lat_ms *= 2.0 + rng.f64() * 2.0;
        rps *= 1.3 + rng.f64();
    }
    PlanFront::new(model, 12, entries).unwrap()
}

fn gen_ramp(rng: &mut Rng) -> RampSpec {
    let phases = 1 + rng.usize_below(3);
    let spec: Vec<String> =
        (0..phases).map(|_| (500 + rng.usize_below(7500)).to_string()).collect();
    RampSpec::parse(&spec.join(":"), 0.1 + rng.f64() * 0.2).unwrap()
}

fn gen_scenario(rng: &mut Rng) -> Scenario {
    let n_classes = 1 + rng.usize_below(2);
    let models: Vec<String> = (0..n_classes).map(|i| format!("m{i}")).collect();
    let n_devices = 1 + rng.usize_below(3);
    let devices: Vec<DeviceSpec> = (0..n_devices)
        .map(|i| DeviceSpec {
            id: format!("vck190-{i}"),
            platform: "vck190".to_string(),
            front: gen_front(rng, rng.choose(&models)),
        })
        .collect();
    let classes: Vec<TrafficClass> = models
        .iter()
        .map(|m| TrafficClass { model: m.clone(), ramp: gen_ramp(rng) })
        .collect();
    // Each class gets its own randomly drawn service model.
    let mut trace = TraceSpec::from(&TrafficMix { classes });
    for c in &mut trace.classes {
        c.service = gen_service(rng);
    }
    Scenario {
        fleet: FleetSpec::new("prop", devices).unwrap(),
        trace,
        cfg: SchedulerCfg {
            slo_ms: 5.0 + rng.f64() * 25.0,
            patience: 1 + rng.usize_below(3),
            shed_slack: 1.0 + rng.f64() * 4.0,
            p99_aware: rng.usize_below(2) == 1,
            ..Default::default()
        },
        seed: rng.next_u64(),
    }
}

#[test]
fn prop_noise_torture_conservation_hysteresis_and_event_determinism() {
    let prop_cfg = Config { cases: 24, seed: 0x5E11_ACE5, max_shrink_steps: 0 };
    check(
        &prop_cfg,
        "service_noise",
        gen_scenario,
        |s: &Scenario| {
            for policy in POLICIES {
                let mut rec = TraceRecorder::new();
                let r = simulate_fleet_observed(
                    &s.fleet,
                    s.trace.clone(),
                    &s.cfg,
                    policy,
                    s.seed,
                    &mut rec,
                )
                .map_err(|e| format!("{policy:?}: {e}"))?;
                let events = rec.into_events();
                // conservation, fleet-wide and per device
                if r.served + r.shed != r.arrivals {
                    return Err(format!(
                        "{policy:?}: fleet lost requests ({} + {} != {})",
                        r.served, r.shed, r.arrivals
                    ));
                }
                let routed: usize = r.devices.iter().map(|d| d.routed).sum();
                if routed + r.unroutable != r.arrivals {
                    return Err(format!("{policy:?}: routing lost requests"));
                }
                if r.latency.len() != r.served {
                    return Err(format!("{policy:?}: latency samples != served"));
                }
                for d in &r.devices {
                    if d.served + d.shed != d.routed {
                        return Err(format!("{policy:?}: device {} lost requests", d.id));
                    }
                    if d.final_draining.is_some() {
                        return Err(format!("{policy:?}: device {} ended mid-drain", d.id));
                    }
                    // hysteresis: at most one switch per window, and
                    // consecutive commits at least `patience` windows apart
                    let min_gap = s.cfg.patience.max(1);
                    let mut prev: Option<usize> = None;
                    for sw in &d.switches {
                        if sw.from == sw.to {
                            return Err(format!("{policy:?}: no-op switch on {}", d.id));
                        }
                        if let Some(p) = prev {
                            if sw.window <= p {
                                return Err(format!(
                                    "{policy:?}: device {} committed two switches in window {p}",
                                    d.id
                                ));
                            }
                            if sw.window - p < min_gap {
                                return Err(format!(
                                    "{policy:?}: device {} switched {} windows after the \
                                     last commit (patience {min_gap})",
                                    d.id,
                                    sw.window - p
                                ));
                            }
                        }
                        prev = Some(sw.window);
                    }
                }
                // the trace IS the run, noise or not
                let t = trace_tallies(&events);
                if t.served as usize != r.served
                    || t.shed as usize != r.shed
                    || t.arrivals as usize != r.arrivals
                {
                    return Err(format!("{policy:?}: trace tallies diverge from the report"));
                }
                if !t.conserved() {
                    return Err(format!("{policy:?}: trace tallies violate conservation"));
                }
                // event-log determinism: same seed, same full stream
                let mut rec2 = TraceRecorder::new();
                let r2 = simulate_fleet_observed(
                    &s.fleet,
                    s.trace.clone(),
                    &s.cfg,
                    policy,
                    s.seed,
                    &mut rec2,
                )
                .map_err(|e| format!("{policy:?}: {e}"))?;
                if events != rec2.into_events() {
                    return Err(format!("{policy:?}: non-deterministic event log"));
                }
                if r.served != r2.served
                    || r.shed != r2.shed
                    || r.makespan_s.to_bits() != r2.makespan_s.to_bits()
                {
                    return Err(format!("{policy:?}: non-deterministic fleet tallies"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// (c) Requeue ledgers under drains, faults, and noise
// ---------------------------------------------------------------------------

fn dev(id: &str) -> DeviceSpec {
    DeviceSpec { id: id.to_string(), platform: "vck190".to_string(), front: front3("m") }
}

/// Scale-outs, a scale-in, and a mid-run fault: every requeue source.
fn eventful_spec() -> AutoscaleSpec {
    AutoscaleSpec {
        fleet: FleetSpec::new("t", vec![dev("d0"), dev("d1")]).unwrap(),
        pool: vec![dev("p0"), dev("p1")],
        faults: FaultSpec { events: vec![FaultEvent { at_s: 0.7, device: Some("d1".into()) }] },
        swap: None,
    }
}

fn assert_requeue_ledger(r: &AutoscaleReport, ctx: &str) {
    assert_eq!(r.served + r.shed, r.arrivals, "{ctx}: arrivals leaked");
    assert_eq!(r.latency.len(), r.served, "{ctx}: latency samples != served");
    let routed: usize = r.devices.iter().map(|d| d.routed).sum();
    let placed = r.requeued - r.requeue_lost;
    assert_eq!(
        routed + r.unroutable,
        r.arrivals + placed,
        "{ctx}: routing identity broken (requeues are re-dispatches)"
    );
    let away: usize = r.devices.iter().map(|d| d.requeued_away).sum();
    let taken: usize = r.devices.iter().map(|d| d.requeued_in).sum();
    assert_eq!(away, r.requeued, "{ctx}: requeue events != per-device requeued_away");
    assert_eq!(taken, placed, "{ctx}: placed requeues != per-device requeued_in");
    for d in &r.devices {
        assert_eq!(
            d.served + d.shed + d.requeued_away,
            d.routed,
            "{ctx}: device {} leaked requests",
            d.id
        );
    }
}

#[test]
fn requeue_ledger_is_exact_under_stochastic_service_times() {
    let mix = TrafficMix::single("m", RampSpec::parse("3000:20000:20000:3000:3000", 0.5).unwrap());
    let cfg = SchedulerCfg { slo_ms: 20.0, ..Default::default() };
    let ctl = AutoscaleCfg {
        high_water: 0.8,
        low_water: 0.35,
        patience: 2,
        control_windows: 2,
        min_devices: 1,
    };
    let mut services = noisy_models();
    services.push(ServiceModel::Deterministic);
    for service in &services {
        let trace = TraceSpec::from(&mix).with_service(service);
        for seed in [11u64, 42] {
            let r = simulate_autoscale(
                &eventful_spec(),
                trace.clone(),
                &cfg,
                &ctl,
                RoutePolicy::PowerOfTwoSlo,
                seed,
            )
            .unwrap();
            let ctx = format!("{} seed {seed}", service.label());
            assert!(r.requeued > 0, "{ctx}: the fault must displace in-flight work");
            assert_requeue_ledger(&r, &ctx);
        }
    }
}

// ---------------------------------------------------------------------------
// (d) p99-aware scheduling beats mean-based on heavy tails
// ---------------------------------------------------------------------------

#[test]
fn p99_aware_sheds_strictly_less_than_mean_based_on_heavy_tails() {
    // Offered 4200 rps with a sigma-2 lognormal service factor: the
    // mean-based scheduler sizes for the mean (demand 4200/0.8 = 5250 →
    // the 6 k hybrid plan) and drowns in tail-length launches; the
    // p99-aware scheduler sees the observed p99 blow past the plan's
    // nominal latency and escalates to the 12 k spatial plan, which
    // absorbs the same tail inside its deeper admission budget.
    let ramp = RampSpec::parse("4200:4200:4200:4200", 0.6).unwrap();
    let mix = TrafficMix::single("m", ramp);
    let heavy = TraceSpec::from(&mix).with_service(&ServiceModel::LognormalFactor { sigma: 2.0 });
    let seed = 42u64;
    let mean_cfg = SchedulerCfg { slo_ms: 5.0, ..Default::default() };
    let p99_cfg = SchedulerCfg { slo_ms: 5.0, p99_aware: true, ..Default::default() };

    let mean_r = serve_ramp(&front3("m"), heavy.clone(), &mean_cfg, seed);
    let p99_r = serve_ramp(&front3("m"), heavy, &p99_cfg, seed);

    // Same seed, same arrival stream: the service stream never perturbs it.
    assert_eq!(mean_r.arrivals, p99_r.arrivals, "arrival stream must not depend on the policy");
    assert_eq!(mean_r.served + mean_r.shed, mean_r.arrivals, "mean-based leaked requests");
    assert_eq!(p99_r.served + p99_r.shed, p99_r.arrivals, "p99-aware leaked requests");
    assert!(
        mean_r.shed > 0,
        "scenario must stress the mean-based scheduler (shed {})",
        mean_r.shed
    );
    assert!(
        p99_r.shed < mean_r.shed,
        "p99-aware must shed strictly less: {} vs {}",
        p99_r.shed,
        mean_r.shed
    );
}
