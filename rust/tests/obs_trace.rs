//! Observability-layer invariants (ISSUE 8 acceptance):
//!
//! (a) the trace IS the run — tallies reconstructed purely from the
//!     recorded [`TraceEvent`] stream equal the sim reports' own counters
//!     (arrivals, served, shed, requeues, switches), and the conservation
//!     identity `served + shed == arrivals` holds from events alone;
//! (b) observing is free — reports from the observed entry points are
//!     bit-identical to the unobserved ones (same control-event log, same
//!     per-device tallies), so attaching a recorder can never perturb a
//!     seeded run;
//! (c) byte-stable exports — the Chrome trace JSON and the Prometheus
//!     exposition of the same seeded run are byte-identical across
//!     repeated invocations, and the exposition parses back and
//!     re-renders to the identical text;
//! (d) audit unification — the controller's scale/drain/fail/swap log
//!     (the old `FleetEvent`, now an [`ssr::obs::TraceEvent`] alias)
//!     splices into the hot-path stream after each window marker, in
//!     order.
//!
//! Everything runs on synthetic fronts + the deterministic sims — no
//! artifacts required.

use ssr::cluster::controller::{FaultEvent, FleetEvent};
use ssr::cluster::fleet::DeviceSpec;
use ssr::cluster::{
    simulate_autoscale, simulate_autoscale_observed, AutoscaleCfg, AutoscaleSpec, FaultSpec,
    FleetSpec, FrontSwap, RoutePolicy, TrafficMix,
};
use ssr::coordinator::scheduler::{RampSpec, SchedulerCfg};
use ssr::obs::{
    annotate_slo, chrome_trace_json, merge_audit, parse_prometheus, render_prometheus,
    tallies_from_json, trace_tallies, MetricsRegistry, SloCfg, TraceEvent, TraceRecorder,
};
use ssr::plan::front::{FrontEntry, PlanFront};
use ssr::sim::service::ServiceModel;
use ssr::sim::sweep::{run_sweep_observed, SweepCfg};
use ssr::traffic::TraceSpec;
use ssr::util::json::Json;

const SLO_MS: f64 = 20.0;

fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
    FrontEntry {
        assign: vec![0; 8],
        batch,
        latency_ms: lat_ms,
        tops: rps * 2.5e-3,
        rps,
        nacc: 1,
        label: label.to_string(),
    }
}

fn front() -> PlanFront {
    PlanFront::new(
        "m",
        12,
        vec![entry("seq", 1, 0.2, 5000.0), entry("spatial", 24, 2.0, 12000.0)],
    )
    .unwrap()
}

fn dev(id: &str) -> DeviceSpec {
    DeviceSpec { id: id.to_string(), platform: "vck190".to_string(), front: front() }
}

fn cfg() -> SchedulerCfg {
    SchedulerCfg { slo_ms: SLO_MS, ..Default::default() }
}

fn ctl() -> AutoscaleCfg {
    AutoscaleCfg {
        high_water: 0.8,
        low_water: 0.35,
        patience: 2,
        control_windows: 2,
        min_devices: 1,
    }
}

/// A scenario that exercises every audit-event kind: a burst past one
/// device (scale-out + later scale-in), a mid-run fault, and a rolling
/// front swap.
fn eventful_spec() -> AutoscaleSpec {
    AutoscaleSpec {
        fleet: FleetSpec::new("t", vec![dev("d0"), dev("d1")]).unwrap(),
        pool: vec![dev("p0"), dev("p1")],
        faults: FaultSpec { events: vec![FaultEvent { at_s: 0.7, device: Some("d1".into()) }] },
        swap: Some(FrontSwap {
            at_s: 1.2,
            model: "m".to_string(),
            fronts: [("vck190".to_string(), front())].into_iter().collect(),
        }),
    }
}

fn bursty() -> TrafficMix {
    TrafficMix::single("m", RampSpec::parse("3000:20000:20000:3000:3000", 0.5).unwrap())
}

/// Run the eventful scenario observed; return (report, merged trace).
fn observed_run(seed: u64) -> (ssr::cluster::AutoscaleReport, Vec<TraceEvent>) {
    let mut rec = TraceRecorder::new();
    let r = simulate_autoscale_observed(
        &eventful_spec(),
        &bursty(),
        &cfg(),
        &ctl(),
        RoutePolicy::PowerOfTwoSlo,
        seed,
        &mut rec,
    )
    .unwrap();
    let merged = merge_audit(rec.into_events(), &r.events);
    (r, merged)
}

/// The eventful scenario's traffic with stochastic (lognormal) service
/// times attached to every class.
fn noisy_traffic() -> TraceSpec {
    TraceSpec::from(&bursty()).with_service(&ServiceModel::LognormalFactor { sigma: 0.9 })
}

/// [`observed_run`] over [`noisy_traffic`].
fn noisy_observed_run(seed: u64) -> (ssr::cluster::AutoscaleReport, Vec<TraceEvent>) {
    let mut rec = TraceRecorder::new();
    let r = simulate_autoscale_observed(
        &eventful_spec(),
        noisy_traffic(),
        &cfg(),
        &ctl(),
        RoutePolicy::PowerOfTwoSlo,
        seed,
        &mut rec,
    )
    .unwrap();
    let merged = merge_audit(rec.into_events(), &r.events);
    (r, merged)
}

#[test]
fn trace_tallies_equal_the_autoscale_report() {
    let (r, events) = observed_run(11);
    let t = trace_tallies(&events);
    assert_eq!(t.arrivals as usize, r.arrivals);
    assert_eq!(t.served as usize, r.served);
    assert_eq!(t.shed as usize, r.shed);
    assert_eq!(t.unroutable as usize, r.unroutable);
    assert_eq!(t.requeued as usize, r.requeued);
    assert_eq!(t.requeue_lost as usize, r.requeue_lost);
    assert_eq!(t.audit as usize, r.events.len(), "every audit event lands in the trace");
    let switches: usize = r.devices.iter().map(|d| d.switches).sum();
    assert_eq!(t.plan_switches as usize, switches);
    assert!(t.conserved(), "served {} + shed {} > arrivals {}", t.served, t.shed, t.arrivals);
    // The autoscale sim drains every in-flight launch before returning.
    assert_eq!(t.in_flight(), 0, "trace left requests in flight");
    assert!((t.makespan_s - r.makespan_s).abs() < 1e-9);
}

#[test]
fn conservation_holds_from_the_serialized_trace_alone() {
    let (_, events) = observed_run(11);
    let text = chrome_trace_json(&events);
    let root = Json::parse(&text).expect("trace JSON parses");
    let mut from_json = tallies_from_json(&root).expect("tallies from JSON");
    let direct = trace_tallies(&events);
    // Timestamps ride through the file in microseconds; the µs→s
    // conversion can differ from the in-memory value by an ulp, so the
    // float field gets a tolerance and every counter must match exactly.
    assert!((from_json.makespan_s - direct.makespan_s).abs() < 1e-9);
    from_json.makespan_s = direct.makespan_s;
    assert_eq!(from_json, direct, "serialization must not change the tallies");
    assert!(from_json.conserved());
}

#[test]
fn observing_does_not_perturb_the_run() {
    let spec = eventful_spec();
    let plain = simulate_autoscale(
        &spec,
        &bursty(),
        &cfg(),
        &ctl(),
        RoutePolicy::PowerOfTwoSlo,
        11,
    )
    .unwrap();
    let (observed, _) = observed_run(11);
    assert_eq!(plain.arrivals, observed.arrivals);
    assert_eq!(plain.served, observed.served);
    assert_eq!(plain.shed, observed.shed);
    assert_eq!(plain.requeued, observed.requeued);
    assert_eq!(plain.makespan_s, observed.makespan_s);
    assert_eq!(plain.events, observed.events, "audit log must be bit-identical");
    assert_eq!(plain.devices.len(), observed.devices.len());
    for (a, b) in plain.devices.iter().zip(&observed.devices) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.routed, b.routed);
        assert_eq!(a.served, b.served);
        assert_eq!(a.shed, b.shed);
        assert_eq!(a.windows, b.windows);
        assert_eq!(a.final_state, b.final_state);
    }
}

#[test]
fn exports_are_byte_identical_across_repeated_seeded_runs() {
    let (_, e1) = observed_run(7);
    let (_, e2) = observed_run(7);
    assert_eq!(e1, e2, "event streams diverged at equal seeds");
    let slo_s = SLO_MS * 1e-3;
    let a1 = annotate_slo(e1, slo_s, &SloCfg::default());
    let a2 = annotate_slo(e2, slo_s, &SloCfg::default());
    assert_eq!(chrome_trace_json(&a1), chrome_trace_json(&a2));
    let mut m1 = MetricsRegistry::new(slo_s);
    m1.observe_all(&a1);
    let mut m2 = MetricsRegistry::new(slo_s);
    m2.observe_all(&a2);
    assert_eq!(m1.to_prometheus(), m2.to_prometheus());
    assert_eq!(m1.to_json().to_string(), m2.to_json().to_string());
    // A different seed must actually change the trace (the determinism
    // above is not vacuous).
    let (_, e3) = observed_run(8);
    let a3 = annotate_slo(e3, slo_s, &SloCfg::default());
    assert_ne!(chrome_trace_json(&a1), chrome_trace_json(&a3));
}

#[test]
fn prometheus_exposition_round_trips_and_json_metrics_parse() {
    let (r, events) = observed_run(11);
    let slo_s = SLO_MS * 1e-3;
    let events = annotate_slo(events, slo_s, &SloCfg::default());
    let mut reg = MetricsRegistry::new(slo_s);
    reg.observe_all(&events);
    let text = reg.to_prometheus();
    let fams = parse_prometheus(&text).expect("exposition parses");
    assert_eq!(render_prometheus(&fams), text, "parse -> render is a fixed point");
    assert_eq!(reg.counter("served_total"), r.served as u64);
    assert_eq!(reg.counter("requests_total"), r.arrivals as u64);
    let json = Json::parse(&reg.to_json().to_string()).expect("metrics JSON parses");
    let served = json
        .get("counters")
        .and_then(|c| c.get("served_total"))
        .and_then(Json::as_f64)
        .expect("served_total in JSON metrics");
    assert_eq!(served as usize, r.served);
}

#[test]
fn stochastic_service_trace_reconstructs_and_conserves() {
    let (r, events) = noisy_observed_run(11);
    // The noise is real: draws were recorded and at least one landed off
    // the 1x deterministic factor.
    let draws =
        events.iter().filter(|e| matches!(e, TraceEvent::ServiceDraw { .. })).count();
    assert!(draws > 0, "noisy run recorded no service draws");
    assert!(
        events.iter().any(
            |e| matches!(e, TraceEvent::ServiceDraw { factor, .. } if (factor - 1.0).abs() > 1e-6)
        ),
        "every service factor was exactly 1x"
    );
    // Trace-reconstructed tallies stay conservation-exact under noise.
    let t = trace_tallies(&events);
    assert_eq!(t.arrivals as usize, r.arrivals);
    assert_eq!(t.served as usize, r.served);
    assert_eq!(t.shed as usize, r.shed);
    assert_eq!(t.requeued as usize, r.requeued);
    assert!(t.conserved(), "served {} + shed {} > arrivals {}", t.served, t.shed, t.arrivals);
    assert_eq!(t.in_flight(), 0, "noisy trace left requests in flight");
    // ... and survive the serialized round trip with every counter exact.
    let text = chrome_trace_json(&events);
    let root = Json::parse(&text).expect("noisy trace JSON parses");
    let from_json = tallies_from_json(&root).expect("tallies from JSON");
    assert_eq!(from_json.arrivals, t.arrivals);
    assert_eq!(from_json.served, t.served);
    assert_eq!(from_json.shed, t.shed);
    assert!(from_json.conserved());
}

#[test]
fn stochastic_exports_and_tail_gauges_are_byte_stable() {
    let (_, e1) = noisy_observed_run(7);
    let (_, e2) = noisy_observed_run(7);
    assert_eq!(e1, e2, "noisy event streams diverged at equal seeds");
    let slo_s = SLO_MS * 1e-3;
    let a1 = annotate_slo(e1, slo_s, &SloCfg::default());
    let a2 = annotate_slo(e2, slo_s, &SloCfg::default());
    assert_eq!(chrome_trace_json(&a1), chrome_trace_json(&a2));
    let mut m1 = MetricsRegistry::new(slo_s);
    m1.observe_all(&a1);
    let mut m2 = MetricsRegistry::new(slo_s);
    m2.observe_all(&a2);
    assert_eq!(m1.to_prometheus(), m2.to_prometheus());
    assert_eq!(m1.to_json().to_string(), m2.to_json().to_string());
    // The tail gauges populate: one draw counted per recorded ServiceDraw,
    // and a lognormal run's factor p99 sits strictly above the 1x mean.
    let draws =
        a1.iter().filter(|e| matches!(e, TraceEvent::ServiceDraw { .. })).count() as u64;
    assert!(draws > 0);
    assert_eq!(m1.counter("service_draws_total"), draws);
    assert!(m1.service_factor_p99() > 1.0, "p99 factor {} not a tail", m1.service_factor_p99());
    assert!(m1.to_prometheus().contains("ssr_service_factor_p99"));
    // A deterministic run keeps the gauge at its neutral 1.0 with zero
    // draws — the pre-noise exposition is unchanged in meaning.
    let (_, det) = observed_run(7);
    let det = annotate_slo(det, slo_s, &SloCfg::default());
    let mut md = MetricsRegistry::new(slo_s);
    md.observe_all(&det);
    assert_eq!(md.counter("service_draws_total"), 0);
    assert_eq!(md.service_factor_p99(), 1.0);
}

#[test]
fn noisy_sweep_exports_are_byte_stable_across_thread_counts() {
    // Same sharded sweep, same noisy trace, 1 vs 4 worker threads: the
    // merged event stream, Chrome trace, and Prometheus exposition must
    // be byte-identical — thread scheduling can never touch the service
    // draw streams (each cell splits its own SERVICE_STREAM).
    let trace = TraceSpec::from(&bursty())
        .with_service(&ServiceModel::TokenPruning { alpha: 2.0, beta: 3.5 });
    let one = SweepCfg { seeds: 2, shards: 3, threads: 1, exact: false };
    let four = SweepCfg { seeds: 2, shards: 3, threads: 4, exact: false };
    let (r1, e1) = run_sweep_observed(&front(), trace.clone(), &cfg(), &one, 5);
    let (r4, e4) = run_sweep_observed(&front(), trace, &cfg(), &four, 5);
    assert_eq!(e1, e4, "thread count leaked into the noisy event stream");
    assert_eq!(r1.served, r4.served);
    assert_eq!(r1.shed, r4.shed);
    assert_eq!(r1.makespan_s.to_bits(), r4.makespan_s.to_bits());
    let slo_s = SLO_MS * 1e-3;
    let a1 = annotate_slo(e1, slo_s, &SloCfg::default());
    let a4 = annotate_slo(e4, slo_s, &SloCfg::default());
    assert_eq!(chrome_trace_json(&a1), chrome_trace_json(&a4));
    let mut m1 = MetricsRegistry::new(slo_s);
    m1.observe_all(&a1);
    let mut m4 = MetricsRegistry::new(slo_s);
    m4.observe_all(&a4);
    assert_eq!(m1.to_prometheus(), m4.to_prometheus());
    // Conservation holds from the merged sweep trace alone.
    let t = trace_tallies(&a1);
    assert_eq!(t.served as usize, r1.served);
    assert_eq!(t.arrivals as usize, r1.arrivals);
    assert!(t.conserved());
}

#[test]
fn audit_events_splice_in_after_their_window_marker() {
    let (r, events) = observed_run(11);
    assert!(!r.events.is_empty(), "eventful scenario produced no audit events");
    for (i, ev) in events.iter().enumerate() {
        if !ev.is_audit() {
            continue;
        }
        let w = ev.window().expect("audit events carry their window");
        // The most recent Window marker before this audit event must be
        // window >= w (audit splices after its own window closes).
        let last_window = events[..i]
            .iter()
            .rev()
            .find_map(|e| match e {
                TraceEvent::Window { window, .. } => Some(*window),
                _ => None,
            })
            .expect("audit event before any window marker");
        assert!(last_window >= w, "audit for window {w} spliced before marker {last_window}");
    }
    // Order within the merged stream preserves the controller's commit
    // order.
    let audit_only: Vec<&TraceEvent> = events.iter().filter(|e| e.is_audit()).collect();
    let expected: Vec<&FleetEvent> = r.events.iter().collect();
    assert_eq!(audit_only, expected);
}
