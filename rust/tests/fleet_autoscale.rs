//! Closed-loop fleet autoscaling invariants (ISSUE 5 acceptance):
//!
//! (a) conservation — with devices scaling out, draining in, failing
//!     over, and swapping fronts mid-run, every arrival still terminates
//!     as exactly one of served / shed; requeues are internal
//!     re-dispatches and the full routing identity holds:
//!     `sum(routed) + unroutable == arrivals + (requeued - requeue_lost)`;
//! (b) determinism — an identical seed reproduces the identical control
//!     event log and per-device tallies, fault victims included;
//! (c) economics — on a bursty ramp the autoscaled fleet meets the SLO on
//!     the feasible phases while spending strictly fewer device-seconds
//!     than static peak provisioning for the same trace;
//! (d) hitless lifecycle — scale-in and rolling front swaps drain onto
//!     peers (never two swap drains at once), and a killed device's
//!     in-flight + queued work lands on survivors.
//!
//! Everything runs on synthetic fronts + the deterministic fleet sim — no
//! artifacts required.

use ssr::cluster::controller::{DrainReason, FaultEvent, FleetEvent};
use ssr::cluster::fleet::DeviceSpec;
use ssr::cluster::{
    provision, simulate_autoscale, AutoscaleCfg, AutoscaleReport, AutoscaleSpec, FaultSpec,
    FleetSpec, FrontSwap, PlatformOption, RoutePolicy, TrafficClass, TrafficMix,
};
use ssr::coordinator::scheduler::{RampSpec, SchedulerCfg};
use ssr::plan::front::{FrontEntry, PlanFront};
use ssr::sim::device::DeviceState;

const SLO_MS: f64 = 20.0;

fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
    FrontEntry {
        assign: vec![0; 8],
        batch,
        latency_ms: lat_ms,
        tops: rps * 2.5e-3,
        rps,
        nacc: 1,
        label: label.to_string(),
    }
}

/// The controlled two-point front every scenario runs on: a 5k req/s
/// latency point and a 12k req/s throughput point.
fn front_for(model: &str) -> PlanFront {
    PlanFront::new(
        model,
        12,
        vec![entry("seq", 1, 0.2, 5000.0), entry("spatial", 24, 2.0, 12000.0)],
    )
    .unwrap()
}

fn front() -> PlanFront {
    front_for("m")
}

fn dev(id: &str) -> DeviceSpec {
    DeviceSpec { id: id.to_string(), platform: "vck190".to_string(), front: front() }
}

fn cfg() -> SchedulerCfg {
    SchedulerCfg { slo_ms: SLO_MS, ..Default::default() }
}

fn ctl() -> AutoscaleCfg {
    AutoscaleCfg {
        high_water: 0.8,
        low_water: 0.35,
        patience: 2,
        control_windows: 2,
        min_devices: 1,
    }
}

fn spec(initial: &[&str], pool: &[&str]) -> AutoscaleSpec {
    AutoscaleSpec {
        fleet: FleetSpec::new("t", initial.iter().map(|id| dev(id)).collect()).unwrap(),
        pool: pool.iter().map(|id| dev(id)).collect(),
        faults: FaultSpec::none(),
        swap: None,
    }
}

/// The headline bursty trace: 0.5 s at 3 k, 1 s burst at 20 k (beyond any
/// single device), 1 s back at 3 k.
fn bursty() -> TrafficMix {
    TrafficMix::single("m", RampSpec::parse("3000:20000:20000:3000:3000", 0.5).unwrap())
}

/// Every conservation identity the autoscaled report must satisfy, in one
/// place so all scenarios assert the same thing.
fn assert_conservation(r: &AutoscaleReport, ctx: &str) {
    assert_eq!(r.served + r.shed, r.arrivals, "{ctx}: arrivals leaked");
    assert_eq!(r.latency.len(), r.served, "{ctx}: latency samples != served");
    assert_eq!(r.completions.len(), r.served, "{ctx}: completion records != served");
    let routed: usize = r.devices.iter().map(|d| d.routed).sum();
    let placed = r.requeued - r.requeue_lost;
    assert_eq!(
        routed + r.unroutable,
        r.arrivals + placed,
        "{ctx}: routing identity broken (requeues are re-dispatches)"
    );
    let away: usize = r.devices.iter().map(|d| d.requeued_away).sum();
    let taken: usize = r.devices.iter().map(|d| d.requeued_in).sum();
    assert_eq!(away, r.requeued, "{ctx}: requeue events != per-device requeued_away");
    assert_eq!(taken, placed, "{ctx}: placed requeues != per-device requeued_in");
    for d in &r.devices {
        assert_eq!(
            d.served + d.shed + d.requeued_away,
            d.routed,
            "{ctx}: device {} leaked requests",
            d.id
        );
    }
}

#[test]
fn conservation_holds_under_autoscaling_for_every_policy() {
    for policy in
        [RoutePolicy::RoundRobin, RoutePolicy::ShortestQueue, RoutePolicy::PowerOfTwoSlo]
    {
        let r = simulate_autoscale(&spec(&["d0"], &["p0", "p1"]), &bursty(), &cfg(), &ctl(),
                                   policy, 42)
            .unwrap();
        assert!(r.arrivals > 10_000, "{policy:?}: load generator produced {}", r.arrivals);
        assert_conservation(&r, &format!("{policy:?}"));
    }
}

#[test]
fn bursty_ramp_scales_out_then_back_in_hitless() {
    let r = simulate_autoscale(&spec(&["d0"], &["p0", "p1"]), &bursty(), &cfg(), &ctl(),
                               RoutePolicy::PowerOfTwoSlo, 42)
        .unwrap();
    assert_conservation(&r, "bursty");
    let scale_outs = r
        .events
        .iter()
        .filter(|e| matches!(e, FleetEvent::ScaleOut { .. }))
        .count();
    let scale_ins = r
        .events
        .iter()
        .filter(|e| matches!(e, FleetEvent::DrainStart { reason: DrainReason::ScaleIn, .. }))
        .count();
    assert!(scale_outs >= 1, "burst never scaled out: {:?}", r.events);
    assert!(scale_ins >= 1, "recovery never scaled in: {:?}", r.events);
    // the 20k burst is beyond one device (12k): the pool actually serves
    let pool_served: usize = r
        .devices
        .iter()
        .filter(|d| d.id.starts_with('p'))
        .map(|d| d.served)
        .sum();
    assert!(pool_served > 0, "scale-out devices never took traffic");
    // scale-in is hitless: drained devices end Retired (never Failed) and
    // their handed-off work is in the requeue ledger checked above
    for d in &r.devices {
        assert_ne!(d.final_state, DeviceState::Failed, "no faults were injected");
        if d.final_state == DeviceState::Retired {
            assert!(d.ended_s.is_some(), "retired device {} has no end time", d.id);
        }
    }
}

#[test]
fn autoscaling_beats_static_peak_provisioning_on_device_seconds() {
    // Static sizing for the same trace: provision for the 20k peak with
    // the scheduler's 0.8 headroom over the same front.
    let opt = PlatformOption { platform: "vck190".to_string(), front: front() };
    let peak_fleet =
        provision("static", &[opt], &RampSpec::parse("3000:20000:3000", 0.5).unwrap(),
                  SLO_MS, 0.8)
            .unwrap();
    assert_eq!(peak_fleet.devices, 3, "peak sizing changed; re-derive this scenario");

    let mix = bursty();
    let r = simulate_autoscale(&spec(&["d0"], &["p0", "p1"]), &mix, &cfg(), &ctl(),
                               RoutePolicy::PowerOfTwoSlo, 42)
        .unwrap();
    assert_conservation(&r, "economics");
    let duration = mix.duration_s();
    let static_device_s = peak_fleet.devices as f64 * duration;
    assert!(
        r.device_seconds() < 0.9 * static_device_s,
        "autoscaled {:.2} device-s not under static peak {:.2}",
        r.device_seconds(),
        static_device_s
    );
    // the autoscaler never exceeds what static provisioning would buy
    assert!(r.peak_live_devices() <= peak_fleet.devices);
    // SLO on the feasible phases: before the burst, and after recovery
    let pre = r.latency_for_arrivals_in(0.0, 0.5);
    let post = r.latency_for_arrivals_in(2.0, 2.5);
    assert!(!pre.is_empty() && !post.is_empty());
    assert!(
        pre.p99() * 1e3 <= SLO_MS,
        "pre-burst p99 {:.2} ms breaches the SLO",
        pre.p99() * 1e3
    );
    assert!(
        post.p99() * 1e3 <= SLO_MS,
        "post-recovery p99 {:.2} ms breaches the SLO",
        post.p99() * 1e3
    );
}

#[test]
fn identical_seed_identical_events_and_tallies() {
    let mut s = spec(&["d0", "d1"], &["p0"]);
    s.faults = FaultSpec::at(&[0.6]); // random victim: determinism must cover it
    let mix = bursty();
    let a = simulate_autoscale(&s, &mix, &cfg(), &ctl(), RoutePolicy::PowerOfTwoSlo, 7)
        .unwrap();
    let b = simulate_autoscale(&s, &mix, &cfg(), &ctl(), RoutePolicy::PowerOfTwoSlo, 7)
        .unwrap();
    assert_eq!(a.events, b.events, "control event log diverged");
    assert_eq!(a.served, b.served);
    assert_eq!(a.shed, b.shed);
    assert_eq!(a.requeued, b.requeued);
    assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
    assert_eq!(a.devices.len(), b.devices.len());
    for (da, db) in a.devices.iter().zip(&b.devices) {
        assert_eq!(da.id, db.id);
        assert_eq!(da.routed, db.routed, "device {} diverged", da.id);
        assert_eq!(da.served, db.served);
        assert_eq!(da.shed, db.shed);
        assert_eq!(da.requeued_away, db.requeued_away);
        assert_eq!(da.windows, db.windows);
        assert_eq!(da.final_state, db.final_state);
    }
    let c = simulate_autoscale(&s, &mix, &cfg(), &ctl(), RoutePolicy::PowerOfTwoSlo, 8)
        .unwrap();
    assert_ne!(
        a.devices.iter().map(|d| d.routed).collect::<Vec<_>>(),
        c.devices.iter().map(|d| d.routed).collect::<Vec<_>>(),
        "different seed produced identical routing"
    );
}

#[test]
fn failover_requeues_the_dead_devices_work_onto_survivors() {
    let mut s = spec(&["d0", "d1"], &[]);
    s.faults = FaultSpec {
        events: vec![FaultEvent { at_s: 0.3, device: Some("d1".to_string()) }],
    };
    // 24k req/s over two 12k devices: both saturated, so d1 is guaranteed
    // a standing queue when it dies.
    let mix = TrafficMix::single("m", RampSpec::parse("24000:24000", 0.4).unwrap());
    let r = simulate_autoscale(&s, &mix, &cfg(), &ctl(), RoutePolicy::PowerOfTwoSlo, 13)
        .unwrap();
    assert_conservation(&r, "failover");
    let fails: Vec<&FleetEvent> = r
        .events
        .iter()
        .filter(|e| matches!(e, FleetEvent::Failed { .. }))
        .collect();
    assert_eq!(fails.len(), 1);
    let FleetEvent::Failed { id, requeued, .. } = fails[0] else { unreachable!() };
    assert_eq!(id, "d1");
    assert!(*requeued > 50, "saturated device died with only {requeued} requests to move");
    assert!(r.requeued >= *requeued);
    assert_eq!(r.requeue_lost, 0, "d0 serves the same model; nothing may be lost");
    let d1 = r.devices.iter().find(|d| d.id == "d1").unwrap();
    assert_eq!(d1.final_state, DeviceState::Failed);
    let ended = d1.ended_s.expect("failed device must have an end time");
    assert!((ended - 0.3).abs() < 0.051, "fault applied at {ended}, want ~0.3");
    // the survivor absorbed the displaced work
    let d0 = r.devices.iter().find(|d| d.id == "d0").unwrap();
    assert_eq!(d0.final_state, DeviceState::Active);
    assert_eq!(d0.requeued_in, r.requeued - r.requeue_lost);
    assert!(d0.served > d1.served, "survivor served the second half alone");
    // billing stops at the failure
    assert!(r.device_seconds() < 2.0 * mix.duration_s() - 0.05);
}

#[test]
fn front_swap_rolls_one_device_at_a_time_and_stays_hitless() {
    let new_front = PlanFront::new(
        "m",
        12,
        vec![entry("turbo", 1, 0.15, 5500.0), entry("spatial2", 24, 2.0, 14000.0)],
    )
    .unwrap();
    let mut s = spec(&["d0", "d1"], &[]);
    s.swap = Some(FrontSwap {
        at_s: 0.3,
        model: "m".to_string(),
        fronts: [("vck190".to_string(), new_front)].into_iter().collect(),
    });
    // 4 k req/s: either device alone covers it on its 5 k seq point, so
    // the rollout must not cost latency. min_devices pins the fleet at
    // two — this test is about the swap, not post-rollout scale economics
    // (without the floor, 4 k on the roomier new fronts legitimately
    // triggers a scale-in of one replacement).
    let mut c = ctl();
    c.min_devices = 2;
    let mix = TrafficMix::single("m", RampSpec::parse("4000:4000:4000", 0.4).unwrap());
    let r = simulate_autoscale(&s, &mix, &cfg(), &c, RoutePolicy::PowerOfTwoSlo, 21)
        .unwrap();
    assert_conservation(&r, "swap");
    // both originals retired, both replacements up and serving
    for old in ["d0", "d1"] {
        let d = r.devices.iter().find(|d| d.id == old).unwrap();
        assert_eq!(d.final_state, DeviceState::Retired, "{old} not retired");
        let swapped = r
            .devices
            .iter()
            .find(|d| d.id == format!("{old}+swap"))
            .unwrap_or_else(|| panic!("{old} has no replacement"));
        assert_eq!(swapped.final_state, DeviceState::Active);
        assert!(swapped.served > 0, "replacement {} never served", swapped.id);
    }
    let replaces = r
        .events
        .iter()
        .filter(|e| matches!(e, FleetEvent::SwapReplace { .. }))
        .count();
    assert_eq!(replaces, 2);
    // strictly one device down at a time: the second swap drain starts
    // only after the first device retired
    let pos = |pred: &dyn Fn(&FleetEvent) -> bool| r.events.iter().position(|e| pred(e));
    let first_retired = pos(&|e| matches!(e, FleetEvent::Retired { id, .. } if id == "d0"))
        .expect("d0 retirement logged");
    let second_drain = pos(&|e| {
        matches!(e, FleetEvent::DrainStart { id, reason: DrainReason::Swap, .. } if id == "d1")
    })
    .expect("d1 swap drain logged");
    assert!(
        second_drain > first_retired,
        "d1 drained before d0 retired: {:?}",
        r.events
    );
    // hitless: feasible load keeps its SLO straight through the rollout
    assert!(
        r.p99_ms() <= SLO_MS,
        "rollout cost latency: p99 {:.2} ms ({})",
        r.p99_ms(),
        r.summary_line()
    );
    assert_eq!(r.requeue_lost, 0);
}

#[test]
fn front_swap_of_a_lone_device_surges_the_replacement_before_draining() {
    // One serving device, no pool, a front swap due: draining first would
    // leave a routing gap, so the controller must bring the replacement
    // up *before* the drain (surge) — zero unroutable, zero requeue-lost,
    // SLO intact, exactly one replacement.
    let new_front = PlanFront::new(
        "m",
        12,
        vec![entry("turbo", 1, 0.15, 5500.0), entry("spatial2", 24, 2.0, 14000.0)],
    )
    .unwrap();
    let mut s = spec(&["d0"], &[]);
    s.swap = Some(FrontSwap {
        at_s: 0.3,
        model: "m".to_string(),
        fronts: [("vck190".to_string(), new_front)].into_iter().collect(),
    });
    let mix = TrafficMix::single("m", RampSpec::parse("3000:3000:3000", 0.3).unwrap());
    let r = simulate_autoscale(&s, &mix, &cfg(), &ctl(), RoutePolicy::PowerOfTwoSlo, 17)
        .unwrap();
    assert_conservation(&r, "lone swap");
    assert_eq!(r.unroutable, 0, "surge must leave no routing gap");
    assert_eq!(r.requeue_lost, 0);
    let replace = r
        .events
        .iter()
        .position(|e| matches!(e, FleetEvent::SwapReplace { .. }))
        .expect("replacement logged");
    let drain = r
        .events
        .iter()
        .position(|e| matches!(e, FleetEvent::DrainStart { .. }))
        .expect("drain logged");
    assert!(replace < drain, "replacement must surge up before the drain: {:?}", r.events);
    assert_eq!(
        r.events
            .iter()
            .filter(|e| matches!(e, FleetEvent::SwapReplace { .. }))
            .count(),
        1,
        "surged slot must not spawn a second replacement at retirement"
    );
    let d0 = r.devices.iter().find(|d| d.id == "d0").unwrap();
    assert_eq!(d0.final_state, DeviceState::Retired);
    let nd = r.devices.iter().find(|d| d.id == "d0+swap").unwrap();
    assert_eq!(nd.final_state, DeviceState::Active);
    assert!(nd.served > 0);
    assert!(
        r.p99_ms() <= SLO_MS,
        "lone-device rollout cost latency: p99 {:.2} ms",
        r.p99_ms()
    );
}

#[test]
fn losing_every_device_recovers_from_the_pool_in_the_same_window() {
    // Kill the only device. Disaster recovery must bring up a pool device
    // in the same window — before the dead device's work is re-dispatched
    // — so nothing is unroutable and no requeue is lost.
    let mut s = spec(&["d0"], &["p0"]);
    s.faults = FaultSpec {
        events: vec![FaultEvent { at_s: 0.3, device: Some("d0".to_string()) }],
    };
    let mix = TrafficMix::single("m", RampSpec::parse("3000:3000:3000", 0.3).unwrap());
    let r = simulate_autoscale(&s, &mix, &cfg(), &ctl(), RoutePolicy::PowerOfTwoSlo, 9)
        .unwrap();
    assert_conservation(&r, "recovery");
    assert_eq!(r.unroutable, 0, "recovery must leave no routing gap");
    assert_eq!(r.requeue_lost, 0, "the replacement takes the dead device's work");
    let kill = r
        .events
        .iter()
        .position(|e| matches!(e, FleetEvent::Failed { .. }))
        .expect("fault logged");
    let revive = r
        .events
        .iter()
        .position(|e| matches!(e, FleetEvent::ScaleOut { .. }))
        .expect("recovery scale-out logged");
    assert!(revive > kill, "recovery precedes the failure? {:?}", r.events);
    let p0 = r.devices.iter().find(|d| d.id == "p0").unwrap();
    assert_eq!(p0.final_state, DeviceState::Active);
    assert!(p0.served > 0, "replacement never served");
    let d0 = r.devices.iter().find(|d| d.id == "d0").unwrap();
    assert!((p0.added_s - d0.ended_s.unwrap()).abs() < 1e-9, "not the same window");
}

#[test]
fn recovery_is_per_model_not_fleet_wide() {
    // Model-blind recovery would starve model b here: model a's device
    // stays up, so fleet-wide "anyone serving?" remains true — yet b's
    // only device died. Recovery must check coverage per traffic model
    // and pull a *b-capable* candidate from the pool.
    let dev_m = |id: &str, model: &str| DeviceSpec {
        id: id.to_string(),
        platform: "vck190".to_string(),
        front: front_for(model),
    };
    let s = AutoscaleSpec {
        fleet: FleetSpec::new("mm", vec![dev_m("a0", "a"), dev_m("b0", "b")]).unwrap(),
        pool: vec![dev_m("poolb", "b")],
        faults: FaultSpec {
            events: vec![FaultEvent { at_s: 0.3, device: Some("b0".to_string()) }],
        },
        swap: None,
    };
    let ramp = RampSpec::parse("2500:2500:2500", 0.3).unwrap();
    let mix = TrafficMix {
        classes: vec![
            TrafficClass { model: "a".to_string(), ramp: ramp.clone() },
            TrafficClass { model: "b".to_string(), ramp },
        ],
    };
    let r = simulate_autoscale(&s, &mix, &cfg(), &ctl(), RoutePolicy::PowerOfTwoSlo, 31)
        .unwrap();
    assert_conservation(&r, "per-model recovery");
    assert_eq!(r.unroutable, 0, "model b must be re-covered in the same window");
    assert_eq!(r.requeue_lost, 0);
    // the b-capable pool device came up although model a stayed healthy
    let pb = r.devices.iter().find(|d| d.id == "poolb").unwrap();
    assert_eq!(pb.final_state, DeviceState::Active);
    assert!(pb.served > 0, "replacement never served model b");
    let a0 = r.devices.iter().find(|d| d.id == "a0").unwrap();
    assert_eq!(a0.final_state, DeviceState::Active, "model a must be untouched");
}

#[test]
fn min_devices_floor_is_respected() {
    let mut c = ctl();
    c.min_devices = 2;
    // far below the low-water mark on two devices: still no scale-in
    let mix = TrafficMix::single("m", RampSpec::parse("500:500:500", 0.3).unwrap());
    let r = simulate_autoscale(&spec(&["d0", "d1"], &[]), &mix, &cfg(), &c,
                               RoutePolicy::PowerOfTwoSlo, 3)
        .unwrap();
    assert!(
        !r.events.iter().any(|e| matches!(e, FleetEvent::DrainStart { .. })),
        "scaled in below min_devices: {:?}",
        r.events
    );
    assert_eq!(r.peak_live_devices(), 2);
    assert_conservation(&r, "floor");
}
