//! One queueing truth (ISSUE 4 acceptance): the single-device serving sim
//! and the fleet sim are the same per-device core behind two entry
//! points, and can no longer diverge.
//!
//! (a) differential — `serve_ramp(front, ramp, cfg, seed)` is
//!     *bit-identical* to `simulate_fleet` over a 1-device fleet serving
//!     a single-class mix with the same seed: same arrivals, served,
//!     shed, switches, per-window stats, p50/p99, max queue depth,
//!     makespan, and final {committed, draining} plan;
//! (b) property — over randomized fronts, mixes, scheduler configs, and
//!     seeds, for all three routing policies: fleet-wide and per-device
//!     `served + shed == arrivals`, seed determinism of every tally, and
//!     the (a) equivalence whenever the scenario is 1-device/1-class.
//!
//! Everything is deterministic and artifact-free.

use ssr::cluster::fleet::{DeviceSpec, FleetSpec};
use ssr::cluster::{simulate_fleet, RoutePolicy, TrafficClass, TrafficMix};
use ssr::coordinator::scheduler::{RampSpec, SchedulerCfg};
use ssr::plan::front::{FrontEntry, PlanFront};
use ssr::sim::serving::serve_ramp;
use ssr::util::prop::{check, Config};
use ssr::util::rng::Rng;

const POLICIES: [RoutePolicy; 3] =
    [RoutePolicy::RoundRobin, RoutePolicy::ShortestQueue, RoutePolicy::PowerOfTwoSlo];

fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
    FrontEntry {
        assign: vec![0; 8],
        batch,
        latency_ms: lat_ms,
        tops: rps * 2.5e-3,
        rps,
        nacc: 1,
        label: label.to_string(),
    }
}

fn front3(model: &str) -> PlanFront {
    PlanFront::new(
        model,
        12,
        vec![
            entry("seq", 1, 0.2, 5000.0),
            entry("hybrid", 6, 1.0, 6000.0),
            entry("spatial", 24, 2.0, 12000.0),
        ],
    )
    .unwrap()
}

fn one_device_fleet(front: PlanFront) -> FleetSpec {
    FleetSpec::new(
        "solo",
        vec![DeviceSpec {
            id: "vck190-0".to_string(),
            platform: "vck190".to_string(),
            front,
        }],
    )
    .unwrap()
}

/// Assert every field the two reports share is identical. `latency` is
/// compared through its full percentile sweep (same samples in the same
/// multiset => identical quantiles at every cut).
fn assert_equivalent(
    r1: &ssr::sim::serving::ServeSimReport,
    fleet_r: &ssr::cluster::sim::FleetSimReport,
    ctx: &str,
) {
    assert_eq!(fleet_r.devices.len(), 1, "{ctx}: not a 1-device fleet");
    let d = &fleet_r.devices[0];
    assert_eq!(r1.arrivals, fleet_r.arrivals, "{ctx}: arrivals");
    assert_eq!(r1.served, fleet_r.served, "{ctx}: served");
    assert_eq!(r1.shed, fleet_r.shed, "{ctx}: shed");
    assert_eq!(fleet_r.unroutable, 0, "{ctx}: unroutable in a matched 1-device fleet");
    assert_eq!(r1.served, d.served, "{ctx}: device served");
    assert_eq!(r1.switches, d.switches, "{ctx}: switches");
    assert_eq!(r1.windows, d.windows, "{ctx}: per-window stats");
    assert_eq!(r1.max_queue_depth, d.max_queue_depth, "{ctx}: max queue depth");
    assert_eq!(r1.slo_violations, fleet_r.slo_violations, "{ctx}: slo violations");
    assert_eq!(r1.final_committed, d.final_committed, "{ctx}: final committed");
    assert_eq!(r1.final_draining, d.final_draining, "{ctx}: final draining");
    // makespan and quantiles must match to the bit, not within epsilon:
    // both runs replay the exact same event sequence
    assert_eq!(
        r1.makespan_s.to_bits(),
        fleet_r.makespan_s.to_bits(),
        "{ctx}: makespan diverged ({} vs {})",
        r1.makespan_s,
        fleet_r.makespan_s
    );
    let qs = [0.0, 0.01, 0.25, 0.50, 0.75, 0.90, 0.99, 1.0];
    let p1 = r1.latency.percentiles(&qs);
    let p2 = fleet_r.latency.percentiles(&qs);
    for (a, b) in p1.iter().zip(&p2) {
        assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: latency quantiles diverged");
    }
}

#[test]
fn serve_ramp_is_a_one_device_fleet_sim() {
    let model = "deit_t";
    let ramp = RampSpec::parse("1000:4400:1000", 0.6).unwrap();
    let cfg = SchedulerCfg { slo_ms: 20.0, ..Default::default() };
    for seed in [1u64, 7, 1234, 0xDEAD] {
        for policy in POLICIES {
            let r1 = serve_ramp(&front3(model), &ramp, &cfg, seed);
            let fleet = one_device_fleet(front3(model));
            let mix = TrafficMix::single(model, ramp.clone());
            let r2 = simulate_fleet(&fleet, &mix, &cfg, policy, seed).unwrap();
            assert_equivalent(&r1, &r2, &format!("seed {seed} {policy:?}"));
        }
    }
}

#[test]
fn equivalence_survives_saturation_and_shedding() {
    // A single seq-only point against 4x its capacity: heavy shedding and
    // a bounded queue on both paths, still bit-identical.
    let front = PlanFront::new("m", 12, vec![entry("seq", 1, 0.2, 5000.0)]).unwrap();
    let ramp = RampSpec::parse("20000", 0.5).unwrap();
    let cfg = SchedulerCfg { slo_ms: 20.0, ..Default::default() };
    let r1 = serve_ramp(&front, &ramp, &cfg, 99);
    let mix = TrafficMix::single("m", ramp);
    let r2 = simulate_fleet(&one_device_fleet(front), &mix, &cfg, RoutePolicy::PowerOfTwoSlo, 99)
        .unwrap();
    assert!(r1.shed > 1000, "scenario must actually shed (shed {})", r1.shed);
    assert_equivalent(&r1, &r2, "saturated");
}

// ---------------------------------------------------------------------------
// Property tests over randomized scenarios
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
struct Scenario {
    fleet: FleetSpec,
    mix: TrafficMix,
    cfg: SchedulerCfg,
    seed: u64,
}

/// Random front for `model`: 1..=3 entries with strictly increasing
/// latency and rate (so none is Pareto-pruned) at controlled scales.
fn gen_front(rng: &mut Rng, model: &str) -> PlanFront {
    let n = 1 + rng.usize_below(3);
    let mut lat_ms = 0.1 + rng.f64() * 0.9;
    let mut rps = 2000.0 + rng.f64() * 4000.0;
    let mut entries = Vec::new();
    for (i, &batch) in [1usize, 6, 24].iter().enumerate().take(n) {
        entries.push(entry(&format!("e{i}"), batch, lat_ms, rps));
        lat_ms *= 2.0 + rng.f64() * 2.0;
        rps *= 1.3 + rng.f64();
    }
    PlanFront::new(model, 12, entries).unwrap()
}

fn gen_ramp(rng: &mut Rng) -> RampSpec {
    let phases = 1 + rng.usize_below(3);
    let spec: Vec<String> =
        (0..phases).map(|_| (500 + rng.usize_below(7500)).to_string()).collect();
    RampSpec::parse(&spec.join(":"), 0.1 + rng.f64() * 0.2).unwrap()
}

fn gen_scenario(rng: &mut Rng) -> Scenario {
    let n_classes = 1 + rng.usize_below(2);
    let models: Vec<String> = (0..n_classes).map(|i| format!("m{i}")).collect();
    let n_devices = 1 + rng.usize_below(3);
    let devices: Vec<DeviceSpec> = (0..n_devices)
        .map(|i| DeviceSpec {
            id: format!("vck190-{i}"),
            platform: "vck190".to_string(),
            // each device serves a random one of the models; some classes
            // may end up with no device at all (unroutable traffic)
            front: gen_front(rng, rng.choose(&models)),
        })
        .collect();
    let classes: Vec<TrafficClass> = models
        .iter()
        .map(|m| TrafficClass { model: m.clone(), ramp: gen_ramp(rng) })
        .collect();
    Scenario {
        fleet: FleetSpec::new("prop", devices).unwrap(),
        mix: TrafficMix { classes },
        cfg: SchedulerCfg {
            slo_ms: 5.0 + rng.f64() * 25.0,
            patience: 1 + rng.usize_below(3),
            shed_slack: 1.0 + rng.f64() * 4.0,
            ..Default::default()
        },
        seed: rng.next_u64(),
    }
}

#[test]
fn prop_conservation_determinism_and_equivalence_for_all_policies() {
    let cfg = Config { cases: 24, seed: 0x51A1_F00D, max_shrink_steps: 0 };
    check(
        &cfg,
        "sim_unification",
        gen_scenario,
        |s: &Scenario| {
            for policy in POLICIES {
                let r = simulate_fleet(&s.fleet, &s.mix, &s.cfg, policy, s.seed)
                    .map_err(|e| format!("{policy:?}: {e}"))?;
                // conservation, fleet-wide and per device
                if r.served + r.shed != r.arrivals {
                    return Err(format!(
                        "{policy:?}: fleet lost requests ({} + {} != {})",
                        r.served, r.shed, r.arrivals
                    ));
                }
                let routed: usize = r.devices.iter().map(|d| d.routed).sum();
                if routed + r.unroutable != r.arrivals {
                    return Err(format!("{policy:?}: routing lost requests"));
                }
                if r.latency.len() != r.served {
                    return Err(format!("{policy:?}: latency samples != served"));
                }
                for d in &r.devices {
                    if d.served + d.shed != d.routed {
                        return Err(format!("{policy:?}: device {} lost requests", d.id));
                    }
                    if d.final_draining.is_some() {
                        return Err(format!("{policy:?}: device {} ended mid-drain", d.id));
                    }
                }
                // seed determinism of every tally
                let r2 = simulate_fleet(&s.fleet, &s.mix, &s.cfg, policy, s.seed)
                    .map_err(|e| format!("{policy:?}: {e}"))?;
                if r.served != r2.served
                    || r.shed != r2.shed
                    || r.makespan_s.to_bits() != r2.makespan_s.to_bits()
                {
                    return Err(format!("{policy:?}: non-deterministic fleet tallies"));
                }
                for (a, b) in r.devices.iter().zip(&r2.devices) {
                    if a.routed != b.routed
                        || a.served != b.served
                        || a.shed != b.shed
                        || a.switches != b.switches
                        || a.windows != b.windows
                    {
                        return Err(format!(
                            "{policy:?}: non-deterministic device {} tallies",
                            a.id
                        ));
                    }
                }
                // the tentpole equivalence whenever the scenario collapses
                // to the single-device sim's shape
                if s.fleet.devices.len() == 1
                    && s.mix.classes.len() == 1
                    && s.fleet.devices[0].front.model == s.mix.classes[0].model
                {
                    let r1 = serve_ramp(
                        &s.fleet.devices[0].front,
                        &s.mix.classes[0].ramp,
                        &s.cfg,
                        s.seed,
                    );
                    let d = &r.devices[0];
                    if r1.served != d.served
                        || r1.shed != d.shed
                        || r1.switches != d.switches
                        || r1.windows != d.windows
                        || r1.max_queue_depth != d.max_queue_depth
                        || r1.makespan_s.to_bits() != r.makespan_s.to_bits()
                    {
                        return Err(format!(
                            "{policy:?}: serve_ramp != 1-device fleet (served {} vs {})",
                            r1.served, d.served
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}
