//! The traffic API contract (ISSUE 7 acceptance):
//!
//! (a) compatibility — a ramp-shaped [`TrafficMix`] converted to a
//!     [`TraceSpec`] replays **bit-identical** arrivals through
//!     [`ArrivalStream::from_trace`], so the legacy entry points lost
//!     nothing in the redesign;
//! (b) serialization — every rate-curve and arrival-process variant
//!     survives a JSON round trip (in memory and through `save`/`load`),
//!     and malformed specs are rejected at validation;
//! (c) synthesis — [`TraceSpec::zipf_mix`] splits a shared curve by
//!     Zipf popularity without changing the total offered rate;
//! (d) closed loop — heavy-tailed and flash-crowd traces drive the
//!     autoscaled fleet sim with full request conservation, the serving
//!     ledger never dips below `min_devices`, and on a flash crowd the
//!     Holt-forecast pre-warm (`simulate_autoscale_predictive`) sheds
//!     strictly fewer requests than the reactive controller at equal
//!     budget — the bench claim (`benches/trace_serving.rs`), pinned as
//!     a test.
//!
//! Everything runs on synthetic fronts + the deterministic sim — no
//! artifacts required.

use ssr::cluster::controller::FleetEvent;
use ssr::cluster::{
    simulate_autoscale, simulate_autoscale_predictive, AutoscaleCfg, AutoscaleReport,
    AutoscaleSpec, DeviceSpec, FaultSpec, FleetSpec, ForecastCfg, RoutePolicy,
};
use ssr::coordinator::scheduler::SchedulerCfg;
use ssr::plan::front::{FrontEntry, PlanFront};
use ssr::sim::device::ArrivalSource;
use ssr::sim::service::ServiceModel;
use ssr::traffic::{
    ArrivalProcess, ArrivalStream, RampSpec, RateCurve, TraceClass, TraceSpec, TrafficClass,
    TrafficMix,
};
use ssr::util::json::Json;

const SLO_MS: f64 = 25.0;

fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
    FrontEntry {
        assign: vec![0; 8],
        batch,
        latency_ms: lat_ms,
        tops: rps * 2.5e-3,
        rps,
        nacc: 1,
        label: label.to_string(),
    }
}

/// The same two-point front the autoscale suite runs on: a 5k req/s
/// latency point and a 12k req/s throughput point.
fn front_for(model: &str) -> PlanFront {
    PlanFront::new(
        model,
        12,
        vec![entry("seq", 1, 0.2, 5000.0), entry("spatial", 24, 2.0, 12000.0)],
    )
    .unwrap()
}

fn dev_for(id: &str, model: &str) -> DeviceSpec {
    DeviceSpec { id: id.to_string(), platform: "vck190".to_string(), front: front_for(model) }
}

fn cfg() -> SchedulerCfg {
    SchedulerCfg { slo_ms: SLO_MS, ..Default::default() }
}

fn ctl() -> AutoscaleCfg {
    AutoscaleCfg { high_water: 0.85, low_water: 0.40, ..Default::default() }
}

/// The bench scenario (`benches/trace_serving.rs`), constant for
/// constant: baseline 3k req/s, flash crowd to 30k at t = 0.7 s.
fn flash_trace() -> TraceSpec {
    TraceSpec::single(
        "deit_t",
        RateCurve::Flash {
            base_rps: 3000.0,
            peak_rps: 30000.0,
            at_s: 0.7,
            ramp_s: 0.4,
            decay_s: 0.3,
            duration_s: 3.0,
        },
        ArrivalProcess::Poisson,
    )
}

fn flash_spec() -> AutoscaleSpec {
    AutoscaleSpec {
        fleet: FleetSpec::new("t", vec![dev_for("d0", "deit_t")]).unwrap(),
        pool: (0..3).map(|i| dev_for(&format!("p{i}"), "deit_t")).collect(),
        faults: FaultSpec::none(),
        swap: None,
    }
}

/// Every conservation identity the autoscaled report must satisfy
/// (mirrors `rust/tests/fleet_autoscale.rs`), so trace-driven runs are
/// held to the same ledger as ramp-driven ones.
fn assert_conservation(r: &AutoscaleReport, ctx: &str) {
    assert_eq!(r.served + r.shed, r.arrivals, "{ctx}: arrivals leaked");
    assert_eq!(r.latency.len(), r.served, "{ctx}: latency samples != served");
    assert_eq!(r.completions.len(), r.served, "{ctx}: completion records != served");
    let routed: usize = r.devices.iter().map(|d| d.routed).sum();
    let placed = r.requeued - r.requeue_lost;
    assert_eq!(
        routed + r.unroutable,
        r.arrivals + placed,
        "{ctx}: routing identity broken (requeues are re-dispatches)"
    );
    for d in &r.devices {
        assert_eq!(
            d.served + d.shed + d.requeued_away,
            d.routed,
            "{ctx}: device {} leaked requests",
            d.id
        );
    }
}

/// Replay the control-event log as a serving-headcount ledger: scale-outs
/// and swap bring-ups add a device, drain starts and failures remove one.
/// Returns `(min, max)` live serving devices over the run.
fn serving_ledger(initial: usize, events: &[FleetEvent]) -> (usize, usize) {
    let (mut live, mut lo, mut hi) = (initial, initial, initial);
    for e in events {
        match e {
            FleetEvent::ScaleOut { .. } | FleetEvent::SwapReplace { .. } => live += 1,
            FleetEvent::DrainStart { .. } | FleetEvent::Failed { .. } => live -= 1,
            FleetEvent::Retired { .. } => {}
        }
        lo = lo.min(live);
        hi = hi.max(live);
    }
    (lo, hi)
}

fn drain(mut s: ArrivalStream) -> Vec<(u64, usize)> {
    let mut out = Vec::new();
    while let Some((t, class)) = s.pop() {
        out.push((t.to_bits(), class));
    }
    out
}

// ---------------------------------------------------------------------------
// (a) compatibility: ramps as traces are bit-identical
// ---------------------------------------------------------------------------

#[test]
fn ramp_mix_as_trace_replays_bit_identical_arrivals() {
    let mix = TrafficMix {
        classes: vec![
            TrafficClass {
                model: "a".to_string(),
                ramp: RampSpec::parse("2000:0:1500", 0.3).unwrap(),
            },
            TrafficClass { model: "b".to_string(), ramp: RampSpec::parse("900", 0.7).unwrap() },
            TrafficClass {
                model: "c".to_string(),
                ramp: RampSpec::parse("0:4000", 0.25).unwrap(),
            },
        ],
    };
    let trace = TraceSpec::from(&mix);
    for seed in [1_u64, 42, 2025] {
        let legacy = drain(ArrivalStream::new(&mix, seed));
        let traced = drain(ArrivalStream::from_trace(&trace, seed));
        assert!(legacy.len() > 1000, "seed {seed}: thin stream ({})", legacy.len());
        assert_eq!(legacy, traced, "seed {seed}: trace path diverged from legacy path");
    }
}

#[test]
fn bare_ramp_as_trace_replays_bit_identical_arrivals() {
    let ramp = RampSpec::parse("3000:8000:1000", 0.4).unwrap();
    let mix = TrafficMix::single("m", ramp.clone());
    let legacy = drain(ArrivalStream::new(&mix, 7));
    let traced = drain(ArrivalStream::from_trace(&TraceSpec::from(&ramp), 7));
    assert!(legacy.len() > 1000, "thin stream ({})", legacy.len());
    assert_eq!(legacy, traced, "bare-ramp trace diverged from legacy path");
}

// ---------------------------------------------------------------------------
// (b) serialization
// ---------------------------------------------------------------------------

/// One class per (curve kind, process kind) pairing.
fn kitchen_sink() -> TraceSpec {
    TraceSpec::new(vec![
        TraceClass {
            model: "a".to_string(),
            curve: RateCurve::Constant { rate_rps: 1234.5, duration_s: 2.5 },
            process: ArrivalProcess::Poisson,
            service: ServiceModel::Deterministic,
        },
        TraceClass {
            model: "b".to_string(),
            curve: RateCurve::Piecewise { rates_rps: vec![100.0, 0.0, 250.25], phase_s: 0.3 },
            process: ArrivalProcess::LognormalGaps { sigma: 0.8 },
            service: ServiceModel::LognormalFactor { sigma: 0.6 },
        },
        TraceClass {
            model: "c".to_string(),
            curve: RateCurve::Diurnal {
                base_rps: 400.0,
                amplitude_rps: 350.125,
                period_s: 1.75,
                duration_s: 4.0,
            },
            process: ArrivalProcess::ParetoGaps { alpha: 1.7 },
            service: ServiceModel::TokenPruning { alpha: 2.0, beta: 3.5 },
        },
        TraceClass {
            model: "d".to_string(),
            curve: RateCurve::Flash {
                base_rps: 100.0,
                peak_rps: 9000.0,
                at_s: 0.5,
                ramp_s: 0.25,
                decay_s: 0.125,
                duration_s: 3.0,
            },
            process: ArrivalProcess::Poisson,
            service: ServiceModel::EarlyExit {
                exit_probs: vec![0.3, 0.2],
                stage_fractions: vec![0.25, 0.5],
            },
        },
    ])
    .unwrap()
}

#[test]
fn every_curve_and_process_round_trips_through_json() {
    let t = kitchen_sink();
    let text = t.to_json().to_string();
    let back = TraceSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, t, "in-memory JSON round trip changed the trace");

    let path = std::env::temp_dir().join(format!("ssr_trace_rt_{}.json", std::process::id()));
    t.save(&path).unwrap();
    let loaded = TraceSpec::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded, t, "save/load round trip changed the trace");
}

#[test]
fn malformed_specs_are_rejected() {
    assert!(TraceSpec::new(vec![]).is_err(), "empty trace accepted");
    assert!(
        TraceSpec::new(vec![TraceClass {
            model: String::new(),
            curve: RateCurve::Constant { rate_rps: 10.0, duration_s: 1.0 },
            process: ArrivalProcess::Poisson,
            service: ServiceModel::Deterministic,
        }])
        .is_err(),
        "empty model accepted"
    );
    let curve = RateCurve::Constant { rate_rps: 10.0, duration_s: 1.0 };
    assert!(
        TraceSpec::zipf_mix(&[], &curve, ArrivalProcess::Poisson, 1.0).is_err(),
        "zipf over no models accepted"
    );
    assert!(
        TraceSpec::zipf_mix(&["a"], &curve, ArrivalProcess::Poisson, f64::NAN).is_err(),
        "NaN zipf exponent accepted"
    );
}

// ---------------------------------------------------------------------------
// (c) Zipf synthesis
// ---------------------------------------------------------------------------

#[test]
fn zipf_mix_preserves_total_rate_and_orders_by_rank() {
    let curve = RateCurve::Constant { rate_rps: 9000.0, duration_s: 1.0 };
    let t =
        TraceSpec::zipf_mix(&["a", "b", "c"], &curve, ArrivalProcess::Poisson, 1.0).unwrap();
    assert_eq!(t.models(), vec!["a", "b", "c"]);
    assert!(
        (t.peak_rps() - 9000.0).abs() < 1e-6,
        "zipf split changed the offered rate: {}",
        t.peak_rps()
    );
    let rates: Vec<f64> = t.classes.iter().map(|c| c.curve.peak_rps()).collect();
    assert!(rates[0] > rates[1] && rates[1] > rates[2], "ranks out of order: {rates:?}");
    // Exponent 0 is a uniform split.
    let u = TraceSpec::zipf_mix(&["a", "b", "c"], &curve, ArrivalProcess::Poisson, 0.0).unwrap();
    for c in &u.classes {
        assert!((c.curve.peak_rps() - 3000.0).abs() < 1e-6);
    }
}

// ---------------------------------------------------------------------------
// (d) closed loop: traces through the autoscaled fleet sim
// ---------------------------------------------------------------------------

#[test]
fn heavy_tail_zipf_trace_conserves_requests_through_autoscaling() {
    // Two models, diurnal load, Pareto gaps — none of which existed
    // pre-trace — through the full controller loop.
    let curve = RateCurve::Diurnal {
        base_rps: 6000.0,
        amplitude_rps: 4000.0,
        period_s: 1.0,
        duration_s: 2.0,
    };
    let trace = TraceSpec::zipf_mix(
        &["a", "b"],
        &curve,
        ArrivalProcess::ParetoGaps { alpha: 1.7 },
        1.0,
    )
    .unwrap();
    let spec = AutoscaleSpec {
        fleet: FleetSpec::new("t", vec![dev_for("a0", "a"), dev_for("b0", "b")]).unwrap(),
        pool: vec![dev_for("a1", "a"), dev_for("b1", "b")],
        faults: FaultSpec::none(),
        swap: None,
    };
    let r = simulate_autoscale(&spec, &trace, &cfg(), &ctl(), RoutePolicy::RoundRobin, 42)
        .unwrap();
    assert!(r.arrivals > 10_000, "load generator produced {}", r.arrivals);
    assert_conservation(&r, "heavy-tail zipf");
}

#[test]
fn predictive_flash_crowd_sheds_strictly_less_than_reactive() {
    // The bench claim (`benches/trace_serving.rs`) as a test: same spec,
    // same trace, same seed — the Holt forecast's pre-warm lead time must
    // convert into strictly fewer shed requests, at equal device budget.
    let trace = flash_trace();
    let reactive = simulate_autoscale(
        &flash_spec(),
        &trace,
        &cfg(),
        &ctl(),
        RoutePolicy::RoundRobin,
        2025,
    )
    .unwrap();
    let predictive = simulate_autoscale_predictive(
        &flash_spec(),
        &trace,
        &cfg(),
        &ctl(),
        &ForecastCfg::default(),
        RoutePolicy::RoundRobin,
        2025,
    )
    .unwrap();
    assert_conservation(&reactive, "reactive flash");
    assert_conservation(&predictive, "predictive flash");
    assert_eq!(
        reactive.arrivals, predictive.arrivals,
        "same trace + seed must offer identical arrivals"
    );
    assert!(
        predictive.shed < reactive.shed,
        "predictive pre-warm shed {} >= reactive {}",
        predictive.shed,
        reactive.shed
    );
    // Equal budget: the static fleet sized for the spike top would spend
    // 4 devices x 3 s; both controllers must stay under it.
    let static_device_s = 4.0 * trace.duration_s();
    for (name, r) in [("reactive", &reactive), ("predictive", &predictive)] {
        assert!(
            r.device_seconds() < static_device_s,
            "{name} spent {:.2} device-s, static peak {static_device_s:.2}",
            r.device_seconds()
        );
        let (lo, hi) = serving_ledger(1, &r.events);
        assert!(lo >= 1, "{name}: serving devices dipped below min_devices");
        assert!(hi <= 4, "{name}: more devices live than fleet + pool");
    }
    // The forecast fires on projected (not observed) overload, so its
    // first scale-out cannot come later than the reactive one.
    let first_out = |r: &AutoscaleReport| {
        r.events.iter().find_map(|e| match e {
            FleetEvent::ScaleOut { at_s, .. } => Some(*at_s),
            _ => None,
        })
    };
    let (p, q) = (first_out(&predictive), first_out(&reactive));
    assert!(p.is_some(), "predictive never scaled out on a 10x flash");
    assert!(q.is_some(), "reactive never scaled out on a 10x flash");
    assert!(
        p.unwrap() <= q.unwrap(),
        "forecast pre-warm ({:.2} s) came after reactive scale-out ({:.2} s)",
        p.unwrap(),
        q.unwrap()
    );
}

#[test]
fn predictive_on_steady_feasible_load_matches_reactive() {
    // Flat, comfortably feasible load: the forecast projects exactly the
    // observed rate (zero trend), stays under the high-water mark, and
    // the two controllers take identical actions.
    let trace = TraceSpec::single(
        "deit_t",
        RateCurve::Constant { rate_rps: 2500.0, duration_s: 1.5 },
        ArrivalProcess::Poisson,
    );
    let reactive = simulate_autoscale(
        &flash_spec(),
        &trace,
        &cfg(),
        &ctl(),
        RoutePolicy::RoundRobin,
        9,
    )
    .unwrap();
    let predictive = simulate_autoscale_predictive(
        &flash_spec(),
        &trace,
        &cfg(),
        &ctl(),
        &ForecastCfg::default(),
        RoutePolicy::RoundRobin,
        9,
    )
    .unwrap();
    assert_eq!(predictive.events, reactive.events, "steady load: controllers diverged");
    assert_eq!(predictive.served, reactive.served);
    assert_eq!(predictive.shed, reactive.shed);
}
