//! Application-layer IR: the transformer layer graph the SSR framework maps
//! onto accelerators (paper Fig. 4).
//!
//! The schedulable unit is an **MM-type node** (MM or BMM) carrying its
//! fused pre/post HCE ops (LayerNorm, Softmax, GELU, Transpose, Reformat,
//! Add) — exactly the granularity SSR schedules: MM/BMM layers go to the AIE
//! HMM units, the attached non-MM layers ride along on the owning
//! accelerator's PL-side HCE engine (paper Sec. 2, "SSR explores hybrid
//! strategies when mapping MM and BMM layers").

pub mod builder;

pub use builder::{vit_graph, ModelCfg, DEIT_T, DEIT_T_160, DEIT_T_256, LV_VIT_T};

/// Non-MM (HCE) op kinds from the paper's kernel profile (Fig. 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum HceKind {
    Softmax,
    LayerNorm,
    Gelu,
    Transpose,
    Reformat,
    Add,
}

impl HceKind {
    /// Reduce ops have data-reuse distance > 1 (need the line-buffer
    /// pipeline, Fig. 7); elementwise ops fuse for free (reuse distance 1).
    pub fn is_reduction(self) -> bool {
        matches!(self, HceKind::Softmax | HceKind::LayerNorm)
    }
}

/// One fused non-MM op attached to an MM node.
#[derive(Clone, Copy, Debug)]
pub struct HceOp {
    pub kind: HceKind,
    /// Elements processed per image.
    pub elems: u64,
}

/// Layer classes: the paper's per-block node identities (Fig. 4 / Fig. 9
/// "specialized MM accelerators for every node within one block").
/// Assignment genomes map classes -> accelerators; all 12 blocks of a class
/// share the accelerator, which is what makes hybrid schedules expressible
/// with 1..=8 accelerators.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LayerClass {
    Embed,
    Qkv,
    Bmm0,
    Bmm1,
    Proj,
    Fc1,
    Fc2,
    Head,
}

pub const ALL_CLASSES: [LayerClass; 8] = [
    LayerClass::Embed,
    LayerClass::Qkv,
    LayerClass::Bmm0,
    LayerClass::Bmm1,
    LayerClass::Proj,
    LayerClass::Fc1,
    LayerClass::Fc2,
    LayerClass::Head,
];

impl LayerClass {
    pub fn index(self) -> usize {
        ALL_CLASSES.iter().position(|&c| c == self).unwrap()
    }

    /// Attention BMMs have two activation operands => need HMM-type1
    /// (no weight pinning possible).
    pub fn is_attention(self) -> bool {
        matches!(self, LayerClass::Bmm0 | LayerClass::Bmm1)
    }
}

/// MM dimensions per image: `bmm_mult` independent (M,K,N) products
/// (= #heads for attention BMMs, 1 otherwise).
#[derive(Clone, Copy, Debug)]
pub struct MmDims {
    pub m: u64,
    pub k: u64,
    pub n: u64,
    pub bmm_mult: u64,
}

impl MmDims {
    pub fn macs(&self) -> u64 {
        self.m * self.k * self.n * self.bmm_mult
    }

    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }
}

/// A schedulable MM-type node with fused HCE ops and graph dependencies.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: usize,
    pub name: String,
    pub class: LayerClass,
    pub block: usize,
    pub dims: MmDims,
    /// HCE ops executed on the owning acc around this MM (per image).
    pub hce: Vec<HceOp>,
    /// Node ids that must complete first (same image).
    pub deps: Vec<usize>,
    /// Weight bytes (INT8) — 0 for HMM-type1 (activation x activation).
    pub weight_bytes: u64,
    /// Activation bytes in / out per image (INT8 activations).
    pub in_bytes: u64,
    pub out_bytes: u64,
}

impl Node {
    pub fn is_attention(&self) -> bool {
        self.class.is_attention()
    }
}

/// The application graph for one model (all blocks unrolled).
#[derive(Clone, Debug)]
pub struct Graph {
    pub model: String,
    pub nodes: Vec<Node>,
    pub depth: usize,
    pub macs_per_image: u64,
}

impl Graph {
    pub fn ops_per_image(&self) -> u64 {
        2 * self.macs_per_image
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Nodes of one class, in block order.
    pub fn nodes_of(&self, class: LayerClass) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(move |n| n.class == class)
    }

    /// Validate the DAG: deps point backwards, ids are dense, MAC totals add up.
    pub fn validate(&self) -> Result<(), String> {
        for (i, n) in self.nodes.iter().enumerate() {
            if n.id != i {
                return Err(format!("node {} has id {}", i, n.id));
            }
            for &d in &n.deps {
                if d >= i {
                    return Err(format!("node {} dep {} not topological", i, d));
                }
            }
        }
        let sum: u64 = self.nodes.iter().map(|n| n.dims.macs()).sum();
        if sum != self.macs_per_image {
            return Err(format!(
                "mac sum {} != macs_per_image {}",
                sum, self.macs_per_image
            ));
        }
        Ok(())
    }

    /// Total HCE elements per image (for PL-side sizing).
    pub fn hce_elems(&self) -> u64 {
        self.nodes.iter().flat_map(|n| &n.hce).map(|h| h.elems).sum()
    }

    /// A topological order honoring deps (nodes are already topological).
    pub fn topo_order(&self) -> Vec<usize> {
        (0..self.nodes.len()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deit_t_structure() {
        let g = vit_graph(&DEIT_T);
        // embed + 12 blocks x 6 MM nodes + head = 74
        assert_eq!(g.node_count(), 74);
        g.validate().unwrap();
    }

    #[test]
    fn all_models_validate() {
        for cfg in [&DEIT_T, &DEIT_T_160, &DEIT_T_256, &LV_VIT_T] {
            let g = vit_graph(cfg);
            g.validate().unwrap();
            assert_eq!(g.depth, 12);
        }
    }

    #[test]
    fn macs_match_table3() {
        // Table 3 MACs column (G): DeiT-T 1.3, DeiT-T-160 0.9, DeiT-T-256
        // 2.1, LV-ViT-T 1.6. Analytical count within 20% (paper rounds).
        for (cfg, paper) in [
            (&DEIT_T, 1.3e9),
            (&DEIT_T_160, 0.9e9),
            (&DEIT_T_256, 2.1e9),
            (&LV_VIT_T, 1.6e9),
        ] {
            let g = vit_graph(cfg);
            let rel = (g.macs_per_image as f64 - paper).abs() / paper;
            assert!(rel < 0.20, "{}: {} vs {}", cfg.name, g.macs_per_image, paper);
        }
    }

    #[test]
    fn attention_nodes_are_type1() {
        let g = vit_graph(&DEIT_T);
        for n in &g.nodes {
            assert_eq!(n.is_attention(), n.weight_bytes == 0, "{}", n.name);
        }
    }

    #[test]
    fn chain_dependencies_within_block() {
        let g = vit_graph(&DEIT_T);
        // qkv of block 0 depends on embed; bmm0 on qkv; etc.
        let qkv0 = g.nodes.iter().find(|n| n.name == "b0/qkv").unwrap();
        let embed = g.nodes.iter().find(|n| n.class == LayerClass::Embed).unwrap();
        assert_eq!(qkv0.deps, vec![embed.id]);
        let bmm0 = g.nodes.iter().find(|n| n.name == "b0/bmm0").unwrap();
        assert_eq!(bmm0.deps, vec![qkv0.id]);
    }

    #[test]
    fn class_counts() {
        let g = vit_graph(&DEIT_T);
        assert_eq!(g.nodes_of(LayerClass::Embed).count(), 1);
        assert_eq!(g.nodes_of(LayerClass::Head).count(), 1);
        for c in [LayerClass::Qkv, LayerClass::Bmm0, LayerClass::Bmm1,
                  LayerClass::Proj, LayerClass::Fc1, LayerClass::Fc2] {
            assert_eq!(g.nodes_of(c).count(), 12, "{c:?}");
        }
    }

    #[test]
    fn softmax_attached_to_bmm0() {
        let g = vit_graph(&DEIT_T);
        let bmm0 = g.nodes.iter().find(|n| n.name == "b3/bmm0").unwrap();
        assert!(bmm0.hce.iter().any(|h| h.kind == HceKind::Softmax));
        let fc1 = g.nodes.iter().find(|n| n.name == "b3/fc1").unwrap();
        assert!(fc1.hce.iter().any(|h| h.kind == HceKind::Gelu));
    }

    #[test]
    fn weight_bytes_total_close_to_param_count() {
        // DeiT-T = 5.6M params (Table 3); INT8 weights ~ 5.6 MB.
        let g = vit_graph(&DEIT_T);
        let wb: u64 = g.nodes.iter().map(|n| n.weight_bytes).sum();
        assert!((4.8e6..6.5e6).contains(&(wb as f64)), "weight bytes {wb}");
    }
}
