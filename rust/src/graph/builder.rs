//! Builders for the four evaluated ViT variants (paper Table 3).

use super::{Graph, HceKind, HceOp, LayerClass, MmDims, Node};

/// Model hyperparameters (mirrors `python/compile/model.py::ModelConfig`).
#[derive(Clone, Copy, Debug)]
pub struct ModelCfg {
    pub name: &'static str,
    pub embed_dim: u64,
    pub num_heads: u64,
    pub depth: usize,
    pub mlp_ratio: u64,
    pub img_size: u64,
    pub patch_size: u64,
    pub num_classes: u64,
}

impl ModelCfg {
    pub const fn tokens(&self) -> u64 {
        let p = self.img_size / self.patch_size;
        p * p + 1
    }

    pub const fn head_dim(&self) -> u64 {
        self.embed_dim / self.num_heads
    }

    pub const fn patch_dim(&self) -> u64 {
        self.patch_size * self.patch_size * 3
    }
}

pub const DEIT_T: ModelCfg = ModelCfg {
    name: "deit_t",
    embed_dim: 192,
    num_heads: 3,
    depth: 12,
    mlp_ratio: 4,
    img_size: 224,
    patch_size: 16,
    num_classes: 1000,
};

pub const DEIT_T_160: ModelCfg = ModelCfg {
    name: "deit_t_160",
    embed_dim: 160,
    num_heads: 4,
    ..DEIT_T
};

pub const DEIT_T_256: ModelCfg = ModelCfg {
    name: "deit_t_256",
    embed_dim: 256,
    num_heads: 4,
    ..DEIT_T
};

pub const LV_VIT_T: ModelCfg = ModelCfg {
    name: "lv_vit_t",
    embed_dim: 240,
    num_heads: 4,
    ..DEIT_T
};

pub fn by_name(name: &str) -> Option<&'static ModelCfg> {
    match name {
        "deit_t" => Some(&DEIT_T),
        "deit_t_160" => Some(&DEIT_T_160),
        "deit_t_256" => Some(&DEIT_T_256),
        "lv_vit_t" => Some(&LV_VIT_T),
        _ => None,
    }
}

struct GraphBuilder {
    nodes: Vec<Node>,
}

impl GraphBuilder {
    fn push(
        &mut self,
        name: String,
        class: LayerClass,
        block: usize,
        dims: MmDims,
        hce: Vec<HceOp>,
        deps: Vec<usize>,
        has_weights: bool,
    ) -> usize {
        let id = self.nodes.len();
        // INT8 activations; BMMs stream two activations (both counted in).
        let in_bytes = if class.is_attention() {
            dims.bmm_mult * (dims.m * dims.k + dims.k * dims.n)
        } else {
            dims.m * dims.k
        };
        let out_bytes = dims.bmm_mult * dims.m * dims.n;
        let weight_bytes = if has_weights { dims.k * dims.n } else { 0 };
        self.nodes.push(Node {
            id,
            name,
            class,
            block,
            dims,
            hce,
            deps,
            weight_bytes,
            in_bytes,
            out_bytes,
        });
        id
    }
}

/// Unroll the ViT layer graph (Fig. 4) for `cfg`.
pub fn vit_graph(cfg: &ModelCfg) -> Graph {
    let t = cfg.tokens();
    let np = t - 1; // patches (cls token added after embed MM)
    let d = cfg.embed_dim;
    let h = cfg.num_heads;
    let dh = cfg.head_dim();
    let hid = cfg.mlp_ratio * d;
    let mut b = GraphBuilder { nodes: Vec::new() };

    // Patch embedding: conv-as-MM (np x patch_dim x d), plus the reformat of
    // raw image data into the patch layout (Fig. 3 profiles this as a
    // matmul-type kernel + layout change).
    let embed = b.push(
        "embed".into(),
        LayerClass::Embed,
        0,
        MmDims { m: np, k: cfg.patch_dim(), n: d, bmm_mult: 1 },
        vec![
            HceOp { kind: HceKind::Transpose, elems: np * cfg.patch_dim() },
            HceOp { kind: HceKind::Add, elems: t * d }, // +pos embed
        ],
        vec![],
        true,
    );

    let mut prev = embed;
    for blk in 0..cfg.depth {
        // LN1 rides on QKV's accelerator (pre-op); reformat covers the
        // INT32->INT8 requantization after the MM accumulators.
        let qkv = b.push(
            format!("b{blk}/qkv"),
            LayerClass::Qkv,
            blk,
            MmDims { m: t, k: d, n: 3 * d, bmm_mult: 1 },
            vec![
                HceOp { kind: HceKind::LayerNorm, elems: t * d },
                HceOp { kind: HceKind::Reformat, elems: t * 3 * d },
                HceOp { kind: HceKind::Transpose, elems: t * 3 * d }, // head split
            ],
            vec![prev],
            true,
        );
        // BMM0: scores = Q @ K^T per head, softmax attached.
        let bmm0 = b.push(
            format!("b{blk}/bmm0"),
            LayerClass::Bmm0,
            blk,
            MmDims { m: t, k: dh, n: t, bmm_mult: h },
            vec![
                HceOp { kind: HceKind::Softmax, elems: h * t * t },
                HceOp { kind: HceKind::Reformat, elems: h * t * t },
            ],
            vec![qkv],
            false,
        );
        // BMM1: ctx = P @ V per head; transpose merges heads back.
        let bmm1 = b.push(
            format!("b{blk}/bmm1"),
            LayerClass::Bmm1,
            blk,
            MmDims { m: t, k: t, n: dh, bmm_mult: h },
            vec![HceOp { kind: HceKind::Transpose, elems: t * d }],
            vec![bmm0],
            false,
        );
        let proj = b.push(
            format!("b{blk}/proj"),
            LayerClass::Proj,
            blk,
            MmDims { m: t, k: d, n: d, bmm_mult: 1 },
            vec![
                HceOp { kind: HceKind::Add, elems: t * d }, // residual
                HceOp { kind: HceKind::Reformat, elems: t * d },
            ],
            vec![bmm1],
            true,
        );
        let fc1 = b.push(
            format!("b{blk}/fc1"),
            LayerClass::Fc1,
            blk,
            MmDims { m: t, k: d, n: hid, bmm_mult: 1 },
            vec![
                HceOp { kind: HceKind::LayerNorm, elems: t * d },
                HceOp { kind: HceKind::Gelu, elems: t * hid },
                HceOp { kind: HceKind::Reformat, elems: t * hid },
            ],
            vec![proj],
            true,
        );
        let fc2 = b.push(
            format!("b{blk}/fc2"),
            LayerClass::Fc2,
            blk,
            MmDims { m: t, k: hid, n: d, bmm_mult: 1 },
            vec![
                HceOp { kind: HceKind::Add, elems: t * d }, // residual
                HceOp { kind: HceKind::Reformat, elems: t * d },
            ],
            vec![fc1],
            true,
        );
        prev = fc2;
    }

    // Classifier head: final LN + (1 x d x classes) MM on the cls token.
    b.push(
        "head".into(),
        LayerClass::Head,
        cfg.depth - 1,
        MmDims { m: 1, k: d, n: cfg.num_classes, bmm_mult: 1 },
        vec![HceOp { kind: HceKind::LayerNorm, elems: t * d }],
        vec![prev],
        true,
    );

    let macs: u64 = b.nodes.iter().map(|n| n.dims.macs()).sum();
    Graph {
        model: cfg.name.to_string(),
        nodes: b.nodes,
        depth: cfg.depth,
        macs_per_image: macs,
    }
}
