//! `ssr` — CLI for the SSR reproduction.
//!
//! Subcommands:
//!   report            regenerate every paper table/figure (analytical + sim)
//!   dse               run the evolutionary Layer→Acc search
//!                     (--emit-front writes the Pareto front of plans as JSON)
//!   simulate          run the event-driven simulator on a named strategy, or
//!                     replay the adaptive SLO scheduler over a plan front
//!                     (--front front.json --slo-ms 2 --ramp 1000:4000:1000)
//!   serve             serve DeiT-T on the PJRT runtime (sequential/spatial/hybrid,
//!                     any 8-class DSE design via --assign c0,..,c7, or the whole
//!                     front adaptively via --front)
//!   cluster           fleet layer: `provision` a platform mix for a traffic
//!                     forecast, `simulate` a fleet deterministically, `serve`
//!                     it live (one adaptive server per device + router), or
//!                     `autoscale` it closed-loop (scale out/in against the
//!                     observed load — optionally forecast-pre-warmed via
//!                     --predictive — deterministic failure injection via
//!                     --fail, hitless rolling front swaps via --swap-at)
//!   trace             workload traces: `synth` a TraceSpec JSON (constant/
//!                     ramp/diurnal/flash curves, poisson/lognormal/pareto
//!                     arrivals, optional Zipf model mix), `show` one; every
//!                     simulation verb accepts it via --trace
//!   obs               observability: `report` summarizes a saved trace
//!                     (event tallies + conservation check); `simulate` and
//!                     `cluster simulate|autoscale` emit traces/metrics via
//!                     --trace-out / --metrics-out
//!   check             statically verify artifact JSON (plan front / fleet /
//!                     trace / execution plan) with pointing diagnostics;
//!                     every --front/--fleet/--trace load runs the same passes
//!   calibrate         print model-vs-paper residuals for the anchor points

use std::path::Path;

use ssr::analytical::{Calib, Features};
use ssr::arch;
use ssr::cluster::fleet::{parse_mix, synth_fleet};
use ssr::cluster::router::FleetServer;
use ssr::cluster::{
    simulate_fleet, AutoscaleCfg, AutoscaleSpec, FaultSpec, FleetSpec, ForecastCfg, FrontSwap,
    PlatformOption, RoutePolicy, TrafficMix,
};
use ssr::coordinator::pipeline::{synth_images, PipelineServer, SequentialServer};
use ssr::coordinator::scheduler::{AdaptiveServer, RampSpec, SchedulerCfg};
use ssr::coordinator::StageAssign;
use ssr::dse::ea::{run_ea, EaParams, EaResult};
use ssr::dse::eval::build_design;
use ssr::dse::Assignment;
use ssr::graph::{builder, vit_graph, Graph};
use ssr::obs::{TraceEvent, TraceRecorder};
use ssr::plan::front::{analytical_front, PlanFront};
use ssr::plan::ExecutionPlan;
use ssr::report::tables::{self, Ctx};
use ssr::runtime::exec::Engine;
use ssr::sim::device::DeviceState;
use ssr::sim::service::ServiceModel;
use ssr::traffic::{ArrivalProcess, RateCurve, TraceSpec};
use ssr::util::cli::{Command, Matches};

/// Parse an 8-class Layer→Acc genome like `0,1,1,1,0,2,2,0`.
fn parse_assignment(s: &str) -> Result<Assignment, String> {
    let v: Result<Vec<usize>, _> = s.split(',').map(|x| x.trim().parse::<usize>()).collect();
    let v = v.map_err(|e| format!("bad genome '{s}': {e}"))?;
    if v.len() != 8 {
        return Err(format!("genome '{s}' must list 8 classes, got {}", v.len()));
    }
    if let Some(bad) = v.iter().find(|&&a| a >= 8) {
        return Err(format!("genome '{s}' has acc id {bad}; ids must be < 8"));
    }
    Ok(Assignment::new(v))
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sub = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { vec![] } else { args[1..].to_vec() };
    let code = match sub {
        "report" => cmd_report(&rest),
        "dse" => cmd_dse(&rest),
        "simulate" => cmd_simulate(&rest),
        "serve" => cmd_serve(&rest),
        "cluster" => cmd_cluster(&rest),
        "trace" => cmd_trace(&rest),
        "obs" => cmd_obs(&rest),
        "check" => cmd_check(&rest),
        "calibrate" => cmd_calibrate(&rest),
        _ => {
            eprintln!(
                "usage: ssr <report|dse|simulate|serve|cluster|trace|obs|check|calibrate> [flags]\n\
                 run `ssr <subcommand> --help` for flags"
            );
            if sub == "help" {
                0
            } else {
                2
            }
        }
    };
    std::process::exit(code);
}

fn parse_or_exit(cmd: Command, args: &[String]) -> ssr::util::cli::Matches {
    match cmd.parse(args) {
        Ok(m) => m,
        Err(usage) => {
            eprintln!("{usage}");
            std::process::exit(2);
        }
    }
}

/// Resolve `--model` gracefully: an unknown name is a usage error (exit 2),
/// not a panic.
fn model_or_exit(name: &str) -> Result<&'static builder::ModelCfg, i32> {
    builder::by_name(name).ok_or_else(|| {
        eprintln!("unknown model '{name}' (known: deit_t, deit_t_160, deit_t_256, lv_vit_t)");
        2
    })
}

fn cmd_report(args: &[String]) -> i32 {
    let cmd = Command::new("ssr report", "regenerate paper tables/figures")
        .flag("only", Some("all"), "fig2|fig3|table5|table6|table7|table8|fig10|steps|platforms")
        .switch("quick", "trimmed sweeps (CI mode)");
    let m = parse_or_exit(cmd, args);
    let ctx = if m.bool("quick") { Ctx::quick() } else { Ctx::vck190() };
    let only = m.str("only");
    let want = |k: &str| only == "all" || only == k;

    if want("fig2") {
        let f = tables::fig2(&ctx);
        println!("== Fig. 2: latency-throughput tradeoff (DeiT-T, VCK190) ==");
        println!("{}", tables::fig2_table(&f).render());
        println!("hybrid Pareto front:");
        for p in f.hybrid_front() {
            println!(
                "  {:.3} ms  {:.2} TOPS  (batch {}, {} accs)",
                p.latency_ms, p.tops, p.batch, p.nacc
            );
        }
    }
    if want("fig3") {
        let (_, t) = tables::fig3_table(6);
        println!("\n== Fig. 3: DeiT-T kernel breakdown on A10G (batch 6) ==");
        println!("{}", t.render());
    }
    if want("table5") {
        let models = if ctx.quick {
            vec!["deit_t"]
        } else {
            vec!["deit_t", "deit_t_160", "deit_t_256", "lv_vit_t"]
        };
        let rows = tables::table5(&ctx, &models);
        println!("\n== Table 5: cross-platform comparison ==");
        println!("{}", tables::table5_table(&rows).render());
    }
    if want("table6") {
        let rows = tables::table6(&ctx, &[2.0, 1.0, 0.5, 0.4]);
        println!("\n== Table 6: optimal TOPS under latency constraints (DeiT-T) ==");
        println!("{}", tables::table6_table(&rows).render());
    }
    if want("table7") {
        let rows = tables::table7(&ctx, 6);
        println!("\n== Table 7: analytical vs simulated 'board' latency ==");
        println!("{}", tables::table7_table(&rows).render());
    }
    if want("table8") {
        let t8 = tables::table8(&ctx);
        println!("\n== Table 8: SSR-spatial resource utilization ==");
        println!("{}", tables::table8_table(&t8, &ctx.platform).render());
    }
    if want("fig10") {
        let f = tables::fig10(&ctx, 6, 2.0e-3);
        println!("\n== Fig. 10: search efficiency ==");
        println!(
            "inter-acc-aware EA : {:.2} s, {} configs, best {:.2} TOPS",
            f.aware_secs, f.aware_configs, f.aware_best_tops
        );
        println!(
            "exhaustive         : {:.2} s, {} configs, best {:.2} TOPS",
            f.exhaustive_secs, f.exhaustive_configs, f.exhaustive_best_tops
        );
    }
    if want("steps") {
        let rows = tables::step_opt(&ctx, 6);
        println!("\n== §5.2.6: step-by-step optimization ==");
        println!("{}", tables::step_table(&rows).render());
    }
    if want("platforms") {
        println!("\n== §6 Q1: SSR on other platforms (DeiT-T, batch 6) ==");
        for r in tables::multi_platform(ctx.quick) {
            println!("  {:<14} {:.3} ms  {:.2} TOPS", r.platform, r.latency_ms, r.tops);
        }
        let (lat, thr) = tables::scaleout(&ctx, 16, 12, 0.1);
        println!("\n== §6 Q2: DeiT-Base (16x) over 12 boards, 0.1 ms hops ==");
        println!("  batch-1 latency {lat:.2} ms, steady-state {thr:.0} imgs/s");
    }
    0
}

/// The adaptive-scheduler flags shared by `simulate --front` and
/// `serve --front`.
fn scheduler_flags(cmd: Command) -> Command {
    cmd.flag("front", Some(""), "plan-front JSON from `ssr dse --emit-front` (enables the adaptive scheduler)")
        .flag("slo-ms", Some("2.0"), "per-request latency SLO (ms)")
        .flag("ramp", Some("1000:4000:1000"), "arrival-rate ramp, req/s per phase (a:b:c)")
        .flag("phase-s", Some("0.5"), "seconds per ramp phase")
        .flag("trace", Some(""), "TraceSpec JSON (from `ssr trace synth`); overrides --ramp")
        .flag("window-ms", Some("50"), "scheduler decision window (ms)")
        .flag("patience", Some("2"), "hysteresis: windows before a switch commits")
        .flag("load-seed", Some("7"), "load-generator seed")
        .flag(
            "service",
            Some("det"),
            "service-time model: det | lognormal:S | prune:A:B | exit:P@F,... \
             (overrides every trace class)",
        )
        .switch("p99-aware", "size plan switches for the observed p99 tail, not the mean")
}

fn scheduler_cfg(m: &Matches) -> SchedulerCfg {
    SchedulerCfg {
        slo_ms: m.f64("slo-ms"),
        window_s: m.f64("window-ms") * 1e-3,
        patience: m.usize("patience"),
        p99_aware: m.bool("p99-aware"),
        ..Default::default()
    }
}

fn parse_ramp_or_exit(m: &Matches) -> RampSpec {
    match RampSpec::parse(&m.str("ramp"), m.f64("phase-s")) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
}

/// `--trace trace.json` when given (verified by the `check` passes before
/// deserializing), else the `--ramp`/`--phase-s` ramp desugared to a
/// single-class Poisson [`TraceSpec`] for `model`. A non-`det` `--service`
/// flag (where the verb registers one) overrides every class's
/// service-time model; commands without the flag read `""`, which parses
/// to `Deterministic` and leaves the trace untouched.
fn load_trace_or_exit(m: &Matches, model: &str) -> TraceSpec {
    let path = m.str("trace");
    let trace = if path.is_empty() {
        let ramp = parse_ramp_or_exit(m);
        TraceSpec::single(model, RateCurve::from(&ramp), ArrivalProcess::Poisson)
    } else {
        match ssr::check::load_trace(Path::new(&path)) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    };
    match ServiceModel::parse(&m.str("service")) {
        Ok(s) if !s.is_deterministic() => trace.with_service(&s),
        Ok(_) => trace,
        Err(e) => {
            eprintln!("--service: {e}");
            std::process::exit(2);
        }
    }
}

/// `ssr check` — run the static artifact verifier on one or more files.
fn cmd_check(args: &[String]) -> i32 {
    let cmd = Command::new(
        "ssr check",
        "statically verify artifact JSON (plan front / fleet / trace / execution plan)",
    )
    .flag("trace", Some(""), "TraceSpec JSON to check fleet model coverage against")
    .flag("arch", Some(""), "board name for resource-budget checks (e.g. vck190)")
    .switch("json", "render diagnostics as a JSON report on stdout")
    .switch("strict", "treat warnings as errors");
    let m = parse_or_exit(cmd, args);
    if m.positionals.is_empty() {
        eprintln!(
            "usage: ssr check <artifact.json>... [--trace t.json] [--arch NAME] [--json] [--strict]"
        );
        return 2;
    }
    let tracep = m.str("trace");
    let trace_json = if tracep.is_empty() {
        None
    } else {
        match ssr::check::load_json(Path::new(&tracep)) {
            Ok(j) => Some(j),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    };
    let archp = m.str("arch");
    let as_json = m.bool("json");
    let mut failed = false;
    let mut report = Vec::new();
    for path in &m.positionals {
        let j = match ssr::check::load_json(Path::new(path)) {
            Ok(j) => j,
            Err(e) => {
                eprintln!("{e}");
                failed = true;
                continue;
            }
        };
        let Some(kind) = ssr::check::detect(&j) else {
            eprintln!(
                "{path}: not a recognized SSR artifact (expected a top-level \
                 steps/entries/devices/classes key)"
            );
            failed = true;
            continue;
        };
        let opts = ssr::check::CheckOpts {
            arch: if archp.is_empty() { None } else { Some(&archp) },
            trace: trace_json.as_ref(),
        };
        let diags = ssr::check::check_artifact(&j, kind, &opts);
        let errors =
            diags.iter().filter(|d| d.severity == ssr::check::Severity::Error).count();
        let warnings = diags.len() - errors;
        if errors > 0 || (m.bool("strict") && warnings > 0) {
            failed = true;
        }
        if as_json {
            let mut o = std::collections::BTreeMap::new();
            o.insert("file".to_string(), ssr::util::json::Json::Str(path.clone()));
            o.insert(
                "kind".to_string(),
                ssr::util::json::Json::Str(kind.name().to_string()),
            );
            o.insert("diagnostics".to_string(), ssr::check::render_json(&diags));
            report.push(ssr::util::json::Json::Obj(o));
        } else {
            if !diags.is_empty() {
                println!("{}", ssr::check::render_text(&diags, path));
            }
            if errors > 0 {
                println!(
                    "{path}: {} FAILED ({errors} error{}, {warnings} warning{})",
                    kind.name(),
                    if errors == 1 { "" } else { "s" },
                    if warnings == 1 { "" } else { "s" },
                );
            } else {
                println!(
                    "{path}: {} ok ({warnings} warning{})",
                    kind.name(),
                    if warnings == 1 { "" } else { "s" },
                );
            }
        }
    }
    if as_json {
        let rendered = ssr::util::json::Json::Arr(report).to_string();
        println!("{rendered}");
    }
    if failed {
        1
    } else {
        0
    }
}

/// The observability flags every simulation verb shares.
fn obs_flags(cmd: Command) -> Command {
    cmd.flag("trace-out", Some(""), "write a Chrome trace-event JSON of the run here")
        .flag(
            "metrics-out",
            Some(""),
            "write run metrics here (.json suffix = JSON, else Prometheus text)",
        )
}

/// True when the run must actually collect a [`TraceEvent`] stream.
fn obs_wanted(m: &Matches) -> bool {
    !m.str("trace-out").is_empty() || !m.str("metrics-out").is_empty()
}

/// Post-process and write a collected stream: annotate SLO burn-rate
/// alerts, render the Chrome trace, replay the stream into the metrics
/// registry. Both outputs are byte-stable for a fixed seeded run.
fn write_obs_outputs(m: &Matches, events: Vec<TraceEvent>, slo_s: f64) -> i32 {
    let events = ssr::obs::annotate_slo(events, slo_s, &ssr::obs::SloCfg::default());
    let trace_out = m.str("trace-out");
    if !trace_out.is_empty() {
        if let Err(e) = std::fs::write(&trace_out, ssr::obs::chrome_trace_json(&events)) {
            eprintln!("writing {trace_out}: {e}");
            return 1;
        }
        println!("wrote {trace_out} ({} events)", events.len());
    }
    let metrics_out = m.str("metrics-out");
    if !metrics_out.is_empty() {
        let mut reg = ssr::obs::MetricsRegistry::new(slo_s);
        reg.observe_all(&events);
        let text = if metrics_out.ends_with(".json") {
            reg.to_json().to_string()
        } else {
            reg.to_prometheus()
        };
        if let Err(e) = std::fs::write(&metrics_out, text) {
            eprintln!("writing {metrics_out}: {e}");
            return 1;
        }
        println!("wrote {metrics_out}");
    }
    0
}

fn cmd_dse(args: &[String]) -> i32 {
    let cmd = Command::new("ssr dse", "evolutionary Layer→Acc search")
        .flag("model", Some("deit_t"), "model name")
        .flag("batch", Some("6"), "batch size")
        .flag("lat-cons-ms", Some("inf"), "latency constraint (ms)")
        .flag("pop", Some("24"), "population size")
        .flag("iters", Some("12"), "EA generations")
        .flag("seed", Some("57005"), "EA seed")
        .flag("emit-front", Some(""), "write the latency-throughput front of plans to this JSON path")
        .flag("front-batches", Some("1,2,3,4,6"), "batch sizes evaluated when emitting the front");
    let m = parse_or_exit(cmd, args);
    let cfg = match model_or_exit(&m.str("model")) {
        Ok(c) => c,
        Err(code) => return code,
    };
    let g = vit_graph(cfg);
    let platform = arch::vck190();
    let lat = m.str("lat-cons-ms");
    let lat_cons = if lat == "inf" {
        f64::INFINITY
    } else {
        match lat.parse::<f64>() {
            Ok(v) => v * 1e-3,
            Err(e) => {
                eprintln!("bad --lat-cons-ms '{lat}': {e}");
                return 2;
            }
        }
    };
    let params = EaParams {
        batch: m.usize("batch"),
        lat_cons,
        n_pop: m.usize("pop"),
        n_child: m.usize("pop"),
        n_iter: m.usize("iters"),
        seed: m.usize("seed") as u64,
        ..Default::default()
    };
    let r = run_ea(&platform, &Calib::default(), &g, Features::all(), true, &params);
    let emit = m.str("emit-front");
    if !emit.is_empty() {
        match emit_front(&platform, &g, &r, &m.usize_list("front-batches"), Path::new(&emit)) {
            Ok(n) => println!(
                "wrote {emit}: {n} non-dominated plans ({} EA candidates + pure strategies)",
                r.pareto_candidates.len()
            ),
            Err(e) => {
                eprintln!("emit-front failed: {e}");
                return 1;
            }
        }
    }
    match r.best {
        Some((ev, e)) => {
            println!(
                "best assignment: {:?} ({} accs)",
                ev.design.assignment.acc_of,
                ev.design.assignment.nacc()
            );
            println!("execution plan: {}", ev.plan.summary());
            println!(
                "  serve with: ssr serve --assign {}",
                ev.design
                    .assignment
                    .acc_of
                    .iter()
                    .map(usize::to_string)
                    .collect::<Vec<_>>()
                    .join(",")
            );
            for req in ev.plan.requirements() {
                println!("  requires executable {}", req.exe_name);
            }
            for (i, c) in ev.design.configs.iter().enumerate() {
                println!(
                    "  acc{i}: classes {:?} config (h1={},w1={},w2={},A={},B={},C={}) AIE={} PLIO={}",
                    ev.design.assignment.classes_on(i),
                    c.h1, c.w1, c.w2, c.a, c.b, c.c,
                    c.aie(),
                    c.plio()
                );
            }
            println!(
                "latency {:.3} ms, throughput {:.2} TOPS, {:.0} GOPS/W ({} designs, {} configs searched)",
                e.latency_s * 1e3,
                e.tops,
                e.gops_per_w,
                r.designs_evaluated,
                r.configs_evaluated
            );
            0
        }
        None => {
            eprintln!("no feasible design under the constraint");
            1
        }
    }
}

/// Build and save the serve-time plan front: EA Pareto candidates plus the
/// two pure strategies, each evaluated across `batches`, pruned to the
/// non-dominated (latency, rate) set.
fn emit_front(
    platform: &arch::Platform,
    g: &Graph,
    r: &EaResult,
    batches: &[usize],
    path: &Path,
) -> Result<usize, String> {
    let mut candidates: Vec<(String, Assignment)> = vec![
        ("sequential".to_string(), Assignment::sequential()),
        ("spatial".to_string(), Assignment::spatial()),
    ];
    for (i, (a, _)) in r.pareto_candidates.iter().enumerate() {
        candidates.push((format!("ea-{i}"), a.clone()));
    }
    let front = analytical_front(platform, &Calib::default(), g, &candidates, batches)?;
    front.save(path).map_err(|e| format!("writing {}: {e}", path.display()))?;
    Ok(front.len())
}

/// Print a simulated adaptive run: per-window trace, switches, summary.
/// "committed plan" is the plan actually executing at the window boundary;
/// while a switch drains the target shows up as `-> [i]` until the
/// in-flight launch completes.
fn print_sim_report(front: &PlanFront, r: &ssr::sim::serving::ServeSimReport) {
    let mut t = ssr::bench::Table::new(&[
        "window", "t (s)", "rate (req/s)", "queue", "p99 (ms)", "committed plan",
    ]);
    for ws in &r.windows {
        let draining = match ws.draining {
            Some(d) => format!(" -> [{d}] draining"),
            None => String::new(),
        };
        t.row(&[
            ws.window.to_string(),
            format!("{:.2}", ws.end_s),
            format!("{:.0}", ws.rate_rps),
            ws.queue_depth.to_string(),
            format!("{:.2}", ws.p99_s * 1e3),
            format!("[{}] {}{draining}", ws.committed, front.entries[ws.committed].label),
        ]);
    }
    println!("{}", t.render());
    for s in &r.switches {
        println!(
            "switch @ {:.3} s (window {}): [{}] {} -> [{}] {} at {:.0} req/s observed",
            s.at_s,
            s.window,
            s.from,
            front.entries[s.from].label,
            s.to,
            front.entries[s.to].label,
            s.rate_rps
        );
    }
    println!("{}", r.summary_line());
}

fn cmd_simulate(args: &[String]) -> i32 {
    let cmd = obs_flags(scheduler_flags(
        Command::new("ssr simulate", "event-driven simulation of a strategy")
            .flag("model", Some("deit_t"), "model name")
            .flag("strategy", Some("spatial"), "sequential|spatial|hybrid")
            .flag("assign", Some(""), "8-class genome c0,..,c7 (overrides --strategy)")
            .flag("batch", Some("6"), "batch size")
            .switch("sweep", "sharded parallel replay over the front (seeds x shards grid)")
            .flag("sweep-seeds", Some("4"), "sweep: independent arrival-process replications")
            .flag("sweep-shards", Some("8"), "sweep: traffic shards per seed (rate splits evenly)")
            .flag("threads", Some("0"), "sweep: worker threads (0 = all cores)")
            .switch("exact", "sweep: exact full-sample stats instead of the sketched fast path"),
    ));
    let m = parse_or_exit(cmd, args);
    let frontp = m.str("front");
    if !frontp.is_empty() {
        // Adaptive-scheduler replay: deterministic queueing sim over the
        // serialized front, no artifacts required.
        let front = match ssr::check::load_front(Path::new(&frontp)) {
            Ok(f) => f,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let trace = load_trace_or_exit(&m, &m.str("model"));
        let cfg = scheduler_cfg(&m);
        print!("{}", front.describe());
        println!(
            "slo {} ms, window {} ms, patience {}",
            cfg.slo_ms,
            cfg.window_s * 1e3,
            cfg.patience
        );
        print!("{}", trace.describe());
        if m.bool("sweep") {
            let sweep = ssr::sim::sweep::SweepCfg {
                seeds: m.usize("sweep-seeds"),
                shards: m.usize("sweep-shards"),
                threads: m.usize("threads"),
                exact: m.bool("exact"),
            };
            let t0 = std::time::Instant::now();
            let (r, events) = if obs_wanted(&m) {
                ssr::sim::sweep::run_sweep_observed(
                    &front,
                    &trace,
                    &cfg,
                    &sweep,
                    m.usize("load-seed") as u64,
                )
            } else {
                let r = ssr::sim::sweep::run_sweep(
                    &front,
                    &trace,
                    &cfg,
                    &sweep,
                    m.usize("load-seed") as u64,
                );
                (r, Vec::new())
            };
            let wall = t0.elapsed().as_secs_f64();
            let mut t = ssr::bench::Table::new(&[
                "seed", "shard", "arrivals", "served", "shed", "makespan (s)",
            ]);
            for c in &r.cells {
                t.row(&[
                    c.seed_idx.to_string(),
                    c.shard_idx.to_string(),
                    c.arrivals.to_string(),
                    c.served.to_string(),
                    c.shed.to_string(),
                    format!("{:.3}", c.makespan_s),
                ]);
            }
            println!("{}", t.render());
            println!("{}", r.summary_line());
            println!(
                "wall {:.3} s | {:.2} M events/s | {:.2} M req/s replayed",
                wall,
                r.events as f64 / wall / 1e6,
                r.arrivals as f64 / wall / 1e6
            );
            if obs_wanted(&m) {
                return write_obs_outputs(&m, events, cfg.slo_ms * 1e-3);
            }
            return 0;
        }
        let seed = m.usize("load-seed") as u64;
        let mut rec = TraceRecorder::new();
        let r = if obs_wanted(&m) {
            ssr::sim::serving::serve_ramp_observed(&front, &trace, &cfg, seed, &mut rec)
        } else {
            ssr::sim::serving::serve_ramp(&front, &trace, &cfg, seed)
        };
        print_sim_report(&front, &r);
        if obs_wanted(&m) {
            return write_obs_outputs(&m, rec.into_events(), cfg.slo_ms * 1e-3);
        }
        return 0;
    }
    let cfg = match model_or_exit(&m.str("model")) {
        Ok(c) => c,
        Err(code) => return code,
    };
    let g = vit_graph(cfg);
    let platform = arch::vck190();
    let genome = m.str("assign");
    let assignment = if !genome.is_empty() {
        match parse_assignment(&genome) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    } else {
        match m.str("strategy").as_str() {
            "sequential" => Assignment::sequential(),
            "spatial" => Assignment::spatial(),
            "hybrid" => Assignment::new(vec![0, 1, 1, 1, 0, 2, 2, 0]),
            other => {
                eprintln!("unknown strategy {other}");
                return 2;
            }
        }
    };
    let ev = build_design(&platform, &Calib::default(), &g, &assignment, Features::all(), true)
        .expect("design");
    let batch = m.usize("batch");
    println!("{}", ev.plan.summary());
    let ana = ev.evaluate(&platform, &g, batch);
    let sim = ssr::sim::simulate(&platform, &ev, &g, batch);
    println!("analytical: {:.3} ms, {:.2} TOPS", ana.latency_s * 1e3, ana.tops);
    println!("simulated : {:.3} ms, {:.2} TOPS", sim.makespan_s * 1e3, sim.tops);
    for (i, u) in sim.acc_util.iter().enumerate() {
        println!(
            "  acc{i} utilization {:.1}%  (classes {:?})",
            u * 100.0,
            assignment.classes_on(i)
        );
    }
    0
}

fn cmd_serve(args: &[String]) -> i32 {
    let cmd = scheduler_flags(
        Command::new("ssr serve", "serve DeiT-T on the PJRT runtime")
            .flag("artifacts", None, "artifacts dir (default ./artifacts)")
            .flag("model", Some("deit_t"), "model name")
            .flag("mode", Some("spatial"), "sequential|spatial|hybrid")
            .flag(
                "assign",
                Some(""),
                "8-class genome c0,..,c7 (plan-driven serve of a DSE design; overrides --mode)",
            )
            .flag("requests", Some("16"), "number of requests")
            .flag("batch", Some("1"), "images per request (sequential: 1|3|6)"),
    );
    let m = parse_or_exit(cmd, args);
    let dir = ssr::runtime::artifacts_dir(m.get("artifacts"));
    let engine = match Engine::load(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("loading artifacts from {}: {e} (run `make artifacts`)", dir.display());
            return 1;
        }
    };
    println!(
        "engine on {} ({} executables)",
        engine.platform(),
        engine.manifest.executables.len()
    );
    let model = m.str("model");
    let n = m.usize("requests");
    let batch = m.usize("batch");
    let mode = m.str("mode");
    let genome = m.str("assign");
    let frontp = m.str("front");
    if !frontp.is_empty() {
        // Adaptive serving of the DSE front: hold every plan live, switch
        // against the SLO under the generated load ramp.
        let front = match ssr::check::load_front(Path::new(&frontp)) {
            Ok(f) => f,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let ramp = parse_ramp_or_exit(&m);
        let cfg = scheduler_cfg(&m);
        println!("loaded {} with {} front entries", frontp, front.len());
        let mut server = match AdaptiveServer::new(engine, front, cfg) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("adaptive server: {e}");
                return 1;
            }
        };
        // Describe the *servable* front: entries the manifest cannot serve
        // were dropped above, and all later [i] indices refer to this list.
        print!("{}", server.scheduler().front.describe());
        let r = match server.serve_ramp(&ramp, m.usize("load-seed") as u64) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("adaptive serve: {e}");
                return 1;
            }
        };
        let sched = server.scheduler();
        let slo_s = cfg.slo_ms * 1e-3;
        let (mut slo_met, mut slo_total) = (0usize, 0usize);
        for wr in &r.windows {
            let label = &sched.front.entries[wr.active].label;
            let shed = if wr.shed > 0 { format!("  shed {}", wr.shed) } else { String::new() };
            match &wr.report {
                Some(rep) => {
                    slo_met += rep.latency.count_leq(slo_s);
                    slo_total += rep.latency.len();
                    println!(
                        "window {:>3}  {:>6.0} req/s  [{}] {:<12} {}  slo {:.0}%{shed}",
                        wr.window,
                        wr.rate_rps,
                        wr.active,
                        label,
                        rep.summary_line(),
                        rep.slo_attainment(slo_s) * 100.0
                    );
                }
                None => println!(
                    "window {:>3}  {:>6.0} req/s  [{}] {:<12} idle{shed}",
                    wr.window, wr.rate_rps, wr.active, label
                ),
            }
        }
        for s in &r.switches {
            println!(
                "switch @ window {}: [{}] {} -> [{}] {} at {:.0} req/s",
                s.window,
                s.from,
                sched.front.entries[s.from].label,
                s.to,
                sched.front.entries[s.to].label,
                s.rate_rps
            );
        }
        let attainment = if slo_total > 0 {
            slo_met as f64 / slo_total as f64 * 100.0
        } else {
            100.0
        };
        println!(
            "{} images served, {} shed over {} windows, {} plan switches, SLO attainment \
             {attainment:.1}% (per-launch)",
            r.total_images,
            r.total_shed,
            r.windows.len(),
            r.switches.len()
        );
        return 0;
    }
    if !genome.is_empty() {
        // DSE → ExecutionPlan → live serving: any nacc ∈ 1..=8 grouping.
        let a = match parse_assignment(&genome) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let Some(info) = engine.manifest.models.get(&model).cloned() else {
            let known: Vec<&str> =
                engine.manifest.models.keys().map(String::as_str).collect();
            eprintln!("model '{model}' not in manifest (available: {})", known.join(", "));
            return 2;
        };
        let plan = ExecutionPlan::from_depth(&model, info.depth, &a, batch);
        println!("{}", plan.summary());
        let s = PipelineServer::from_plan(engine, &plan).expect("compile plan stages");
        println!("serving plan: {}", s.plan().summary());
        let reqs: Vec<_> =
            (0..n).map(|i| synth_images(batch, info.img_size, i as u64)).collect();
        let (r, _) = s.serve(reqs).expect("serve");
        println!("{}", r.summary_line());
        return 0;
    }
    let report = match mode.as_str() {
        "sequential" => {
            let s = SequentialServer::new(engine, &model, &[batch]).expect("compile full model");
            let reqs: Vec<_> =
                (0..n).map(|i| synth_images(batch, s.img_size(), i as u64)).collect();
            let (r, _) = s.serve(batch, &reqs).expect("serve");
            r
        }
        "spatial" | "hybrid" => {
            let assign = if mode == "spatial" {
                StageAssign::spatial()
            } else {
                StageAssign { acc_of: [0, 1, 0, 0] }
            };
            let s = PipelineServer::new(engine, &model, &assign, batch).expect("compile stages");
            let reqs: Vec<_> = (0..n).map(|i| synth_images(batch, 224, i as u64)).collect();
            let (r, _) = s.serve(reqs).expect("serve");
            r
        }
        other => {
            eprintln!("unknown mode {other}");
            return 2;
        }
    };
    println!("{}", report.summary_line());
    0
}

// ---------------------------------------------------------------------------
// `ssr cluster` — fleet provisioning / simulation / live serving.
// ---------------------------------------------------------------------------

fn cmd_cluster(args: &[String]) -> i32 {
    let verb = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { vec![] } else { args[1..].to_vec() };
    match verb {
        "provision" => cluster_provision(&rest),
        "simulate" => cluster_simulate(&rest),
        "serve" => cluster_serve(&rest),
        "autoscale" => cluster_autoscale(&rest),
        _ => {
            eprintln!(
                "usage: ssr cluster <provision|simulate|serve|autoscale> [flags]\n\
                 run `ssr cluster <verb> --help` for flags"
            );
            if verb == "help" {
                0
            } else {
                2
            }
        }
    }
}

/// Flags shared by the three cluster verbs (load shape + scheduler knobs).
fn cluster_flags(cmd: Command) -> Command {
    cmd.flag("model", Some("deit_t"), "model of the traffic (and of --synth fronts)")
        .flag("slo-ms", Some("2.0"), "per-request latency SLO (ms)")
        .flag("ramp", Some("4000:12000:4000"), "offered/forecast req/s per phase (a:b:c)")
        .flag("phase-s", Some("0.5"), "seconds per ramp phase")
        .flag("trace", Some(""), "TraceSpec JSON (from `ssr trace synth`); overrides --ramp")
        .flag("window-ms", Some("50"), "scheduler decision window (ms)")
        .flag("patience", Some("2"), "hysteresis: windows before a switch commits")
        .flag("load-seed", Some("7"), "base seed (split per class/device/router)")
        .flag("policy", Some("p2c"), "routing policy: rr|jsq|p2c")
        .flag("batches", Some("1,3,6"), "batch sizes for synthesized fronts")
        .flag(
            "service",
            Some("det"),
            "service-time model: det | lognormal:S | prune:A:B | exit:P@F,... \
             (overrides every trace class)",
        )
        .switch("p99-aware", "size plan switches for the observed p99 tail, not the mean")
}

/// `--fleet fleet.json` when given (verified by the `check` passes before
/// deserializing), else synthesize from `--synth`.
fn load_fleet(m: &Matches) -> Result<FleetSpec, String> {
    let path = m.str("fleet");
    if !path.is_empty() {
        ssr::check::load_fleet(Path::new(&path))
    } else {
        let mix = parse_mix(&m.str("synth"))?;
        synth_fleet("synthetic", &m.str("model"), &mix, &m.usize_list("batches"))
    }
}

fn cluster_provision(args: &[String]) -> i32 {
    let cmd = cluster_flags(Command::new(
        "ssr cluster provision",
        "size a platform mix + per-device plans for a traffic forecast",
    ))
    .flag("headroom", Some("0.8"), "target utilization devices are sized at")
    .flag(
        "platforms",
        Some("vck190,stratix10nx,zcu102,u250"),
        "candidate platforms (csv of arch names)",
    )
    .flag("out", Some(""), "write the provisioned FleetSpec JSON here");
    let m = parse_or_exit(cmd, args);
    let forecast = load_trace_or_exit(&m, &m.str("model"));
    let batches = m.usize_list("batches");
    let model = m.str("model");
    let mut options = Vec::new();
    for p in m.str("platforms").split(',').map(str::trim).filter(|s| !s.is_empty()) {
        match PlatformOption::synth(p, &model, &batches) {
            Ok(o) => options.push(o),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    match ssr::cluster::provision(
        "provisioned",
        &options,
        &forecast,
        m.f64("slo-ms"),
        m.f64("headroom"),
    ) {
        Ok(r) => {
            print!("{}", r.describe());
            print!("{}", r.fleet.describe());
            let out = m.str("out");
            if !out.is_empty() {
                if let Err(e) = r.fleet.save(Path::new(&out)) {
                    eprintln!("writing {out}: {e}");
                    return 1;
                }
                println!("wrote {out}");
            }
            0
        }
        Err(e) => {
            eprintln!("provisioning failed: {e}");
            1
        }
    }
}

fn cluster_simulate(args: &[String]) -> i32 {
    let cmd = obs_flags(cluster_flags(Command::new(
        "ssr cluster simulate",
        "deterministic discrete-event replay of fleet serving",
    )))
    .flag("fleet", Some(""), "FleetSpec JSON (from `ssr cluster provision --out`)")
    .flag("synth", Some("vck190:2,u250:1"), "fleet to synthesize when --fleet is absent");
    let m = parse_or_exit(cmd, args);
    let fleet = match load_fleet(&m) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let policy = match RoutePolicy::parse(&m.str("policy")) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let trace = load_trace_or_exit(&m, &m.str("model"));
    let cfg = scheduler_cfg(&m);
    print!("{}", fleet.describe());
    println!(
        "policy {}, slo {} ms, window {} ms",
        policy.name(),
        cfg.slo_ms,
        cfg.window_s * 1e3
    );
    print!("{}", trace.describe());
    let seed = m.usize("load-seed") as u64;
    let mut rec = TraceRecorder::new();
    let outcome = if obs_wanted(&m) {
        ssr::cluster::simulate_fleet_observed(&fleet, &trace, &cfg, policy, seed, &mut rec)
    } else {
        simulate_fleet(&fleet, &trace, &cfg, policy, seed)
    };
    let r = match outcome {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let mut t = ssr::bench::Table::new(&[
        "device", "platform", "routed", "served", "shed", "p50 (ms)", "p99 (ms)",
        "max queue", "switches", "final plan",
    ]);
    for d in &r.devices {
        // committed = plan executing at end of run; a still-draining
        // switch target would show as `-> [i]` (cannot survive a clean
        // drain, but the report distinguishes the two notions).
        let final_plan = match d.final_draining {
            Some(to) => format!("[{}] -> [{to}] draining", d.final_committed),
            None => format!("[{}]", d.final_committed),
        };
        t.row(&[
            d.id.clone(),
            d.platform.clone(),
            d.routed.to_string(),
            d.served.to_string(),
            d.shed.to_string(),
            // a device that never served has no latency samples (NaN)
            if d.served > 0 { format!("{:.3}", d.p50_ms) } else { "-".to_string() },
            if d.served > 0 { format!("{:.3}", d.p99_ms) } else { "-".to_string() },
            d.max_queue_depth.to_string(),
            d.switches.len().to_string(),
            final_plan,
        ]);
    }
    println!("{}", t.render());
    println!("{}", r.summary_line());
    if obs_wanted(&m) {
        return write_obs_outputs(&m, rec.into_events(), cfg.slo_ms * 1e-3);
    }
    0
}

fn cluster_serve(args: &[String]) -> i32 {
    let cmd = cluster_flags(Command::new(
        "ssr cluster serve",
        "live fleet serving on the PJRT runtime (one adaptive server per device)",
    ))
    .flag("artifacts", None, "artifacts dir (default ./artifacts)")
    .flag("fleet", Some(""), "FleetSpec JSON (from `ssr cluster provision --out`)")
    .flag("synth", Some("vck190:2"), "fleet to synthesize when --fleet is absent");
    let m = parse_or_exit(cmd, args);
    let fleet = match load_fleet(&m) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let policy = match RoutePolicy::parse(&m.str("policy")) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let ramp = parse_ramp_or_exit(&m);
    let cfg = scheduler_cfg(&m);
    let seed = m.usize("load-seed") as u64;
    let dir = ssr::runtime::artifacts_dir(m.get("artifacts"));
    let engine = match Engine::load(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("loading artifacts from {}: {e} (run `make artifacts`)", dir.display());
            return 1;
        }
    };
    print!("{}", fleet.describe());
    let mut server = match FleetServer::new(engine, &fleet, cfg, policy, seed) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("fleet server: {e}");
            return 1;
        }
    };
    let mix = TrafficMix::single(&m.str("model"), ramp);
    let outcome = match server.serve_mix(&mix, seed) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("fleet serve: {e}");
            return 1;
        }
    };
    let (mut served, mut shed) = (0usize, 0usize);
    for (id, rep) in &outcome.per_device {
        println!(
            "{id}: {} served, {} shed, {} plan switches over {} windows",
            rep.total_images,
            rep.total_shed,
            rep.switches.len(),
            rep.windows.len()
        );
        served += rep.total_images;
        shed += rep.total_shed;
    }
    println!(
        "fleet: {served} served, {shed} shed, {} unroutable ({} devices, policy {})",
        outcome.unroutable,
        outcome.per_device.len(),
        policy.name()
    );
    0
}

fn cluster_autoscale(args: &[String]) -> i32 {
    let cmd = obs_flags(cluster_flags(Command::new(
        "ssr cluster autoscale",
        "closed-loop fleet autoscaling: scale out/in, fail over, hitless front swaps",
    )))
    .flag("fleet", Some(""), "initial FleetSpec JSON (from `ssr cluster provision --out`)")
    .flag("synth", Some("vck190:1"), "initial fleet to synthesize when --fleet is absent")
    .flag("pool", Some("vck190:2"), "scale-out candidate pool (platform:count,...; \"\" = none)")
    .flag("high-water", Some("0.85"), "fleet utilization that arms scale-out")
    .flag("low-water", Some("0.30"), "fleet utilization that arms scale-in")
    .flag("ctl-patience", Some("2"), "control intervals a breach persists before acting")
    .flag("ctl-every", Some("2"), "control interval, in decision windows")
    .flag("min-devices", Some("1"), "never scale in below this many serving devices")
    .flag("fail", Some(""), "fault injection: kill times in seconds (t1,t2,...)")
    .flag("swap-at", Some(""), "roll out new fronts at this time (hitless, one device at a time)")
    .flag("swap-batches", Some("1,2,3,6"), "batch grid of the swapped-in fronts")
    .switch("predictive", "pre-warm scale-out from a Holt forecast of the arrival rate")
    .flag("forecast-alpha", Some("0.5"), "predictive: level smoothing in (0, 1]")
    .flag("forecast-beta", Some("0.5"), "predictive: trend smoothing in [0, 1]")
    .flag("forecast-horizon", Some("3"), "predictive: control intervals extrapolated ahead");
    let m = parse_or_exit(cmd, args);
    let fleet = match load_fleet(&m) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let policy = match RoutePolicy::parse(&m.str("policy")) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let trace = load_trace_or_exit(&m, &m.str("model"));
    let cfg = scheduler_cfg(&m);
    let model = m.str("model");
    let ctl_cfg = AutoscaleCfg {
        high_water: m.f64("high-water"),
        low_water: m.f64("low-water"),
        patience: m.usize("ctl-patience"),
        control_windows: m.usize("ctl-every"),
        min_devices: m.usize("min-devices"),
    };
    // Scale-out candidates: synthesized like the fleet, ids prefixed so
    // they can never collide with the initial devices'. An empty --pool
    // means no pool (failover/scale-in-only runs).
    let pool: Vec<ssr::cluster::DeviceSpec> = if m.str("pool").trim().is_empty() {
        Vec::new()
    } else {
        match parse_mix(&m.str("pool"))
            .and_then(|mix| synth_fleet("pool", &model, &mix, &m.usize_list("batches")))
        {
            Ok(p) => p
                .devices
                .into_iter()
                .map(|mut d| {
                    d.id = format!("pool-{}", d.id);
                    d
                })
                .collect(),
            Err(e) => {
                eprintln!("bad --pool: {e}");
                return 2;
            }
        }
    };
    let faults = match FaultSpec::parse(&m.str("fail")) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let swap_at = m.str("swap-at");
    let swap = if swap_at.is_empty() {
        None
    } else {
        let at_s: f64 = match swap_at.parse() {
            Ok(t) => t,
            Err(e) => {
                eprintln!("bad --swap-at '{swap_at}': {e}");
                return 2;
            }
        };
        // One replacement front per platform present in fleet + pool,
        // re-synthesized on the --swap-batches grid.
        let mut platforms: Vec<String> =
            fleet.devices.iter().map(|d| d.platform.clone()).collect();
        platforms.extend(pool.iter().map(|d: &ssr::cluster::DeviceSpec| d.platform.clone()));
        platforms.sort();
        platforms.dedup();
        let mut fronts = std::collections::BTreeMap::new();
        for p in &platforms {
            match ssr::cluster::fleet::device_front(p, &model, &m.usize_list("swap-batches")) {
                Ok(f) => {
                    fronts.insert(p.clone(), f);
                }
                Err(e) => {
                    eprintln!("swap front for {p}: {e}");
                    return 2;
                }
            }
        }
        Some(FrontSwap { at_s, model: model.clone(), fronts })
    };
    let spec = AutoscaleSpec { fleet, pool, faults, swap };
    print!("{}", spec.fleet.describe());
    println!(
        "policy {}, slo {} ms, window {} ms, water {:.2}/{:.2}, control every {} windows \
         (patience {}), pool of {}{}",
        policy.name(),
        cfg.slo_ms,
        cfg.window_s * 1e3,
        ctl_cfg.low_water,
        ctl_cfg.high_water,
        ctl_cfg.control_windows,
        ctl_cfg.patience,
        spec.pool.len(),
        if m.bool("predictive") { ", predictive pre-warm" } else { "" }
    );
    print!("{}", trace.describe());
    let seed = m.usize("load-seed") as u64;
    let mut rec = TraceRecorder::new();
    let observe = obs_wanted(&m);
    let outcome = if m.bool("predictive") {
        let forecast = ForecastCfg {
            alpha: m.f64("forecast-alpha"),
            beta: m.f64("forecast-beta"),
            horizon: m.f64("forecast-horizon"),
        };
        if observe {
            ssr::cluster::simulate_autoscale_predictive_observed(
                &spec, &trace, &cfg, &ctl_cfg, &forecast, policy, seed, &mut rec,
            )
        } else {
            ssr::cluster::simulate_autoscale_predictive(
                &spec, &trace, &cfg, &ctl_cfg, &forecast, policy, seed,
            )
        }
    } else if observe {
        ssr::cluster::simulate_autoscale_observed(
            &spec, &trace, &cfg, &ctl_cfg, policy, seed, &mut rec,
        )
    } else {
        ssr::cluster::simulate_autoscale(&spec, &trace, &cfg, &ctl_cfg, policy, seed)
    };
    let r = match outcome {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    if r.events.is_empty() {
        println!("no control events (load stayed between the water marks)");
    }
    for e in &r.events {
        println!("{}", e.describe());
    }
    let mut t = ssr::bench::Table::new(&[
        "device", "platform", "live (s)", "state", "routed", "served", "shed", "req out",
        "req in", "p99 (ms)", "switches", "final plan",
    ]);
    for d in &r.devices {
        let ended = d.ended_s.unwrap_or(r.duration_s);
        let state = match d.final_state {
            DeviceState::Active => "active",
            DeviceState::Draining => "draining",
            DeviceState::Retired => "retired",
            DeviceState::Failed => "FAILED",
        };
        t.row(&[
            d.id.clone(),
            d.platform.clone(),
            format!("{:.2}-{:.2}", d.added_s, ended),
            state.to_string(),
            d.routed.to_string(),
            d.served.to_string(),
            d.shed.to_string(),
            d.requeued_away.to_string(),
            d.requeued_in.to_string(),
            // a device that never served has no latency samples (NaN)
            if d.served > 0 { format!("{:.3}", d.p99_ms) } else { "-".to_string() },
            d.switches.to_string(),
            format!("[{}]", d.final_committed),
        ]);
    }
    println!("{}", t.render());
    println!("{}", r.summary_line());
    let peak = r.peak_live_devices();
    println!(
        "device-time: {:.2} device-s autoscaled vs {:.2} device-s at static peak \
         ({} devices x {:.2} s)",
        r.device_seconds(),
        peak as f64 * r.duration_s,
        peak,
        r.duration_s
    );
    if observe {
        // One unified trace: the controller's audit log spliced in after
        // each window marker of the hot-path stream.
        let merged = ssr::obs::merge_audit(rec.into_events(), &r.events);
        return write_obs_outputs(&m, merged, cfg.slo_ms * 1e-3);
    }
    0
}

fn cmd_trace(args: &[String]) -> i32 {
    let verb = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { vec![] } else { args[1..].to_vec() };
    match verb {
        "synth" => trace_synth(&rest),
        "show" => trace_show(&rest),
        _ => {
            eprintln!(
                "usage: ssr trace <synth|show> [flags]\n\
                 run `ssr trace <verb> --help` for flags"
            );
            if verb == "help" {
                0
            } else {
                2
            }
        }
    }
}

fn trace_synth(args: &[String]) -> i32 {
    let cmd = Command::new("ssr trace synth", "synthesize a TraceSpec workload JSON")
        .flag("model", Some("deit_t"), "model the trace targets")
        .flag("models", Some(""), "csv of models for a Zipf popularity mix (overrides --model)")
        .flag("zipf-exp", Some("1.0"), "Zipf popularity exponent (0 = uniform split)")
        .flag("curve", Some("ramp"), "rate shape: constant|ramp|diurnal|flash")
        .flag("ramp", Some("1000:4000:1000"), "ramp curve: req/s per phase (a:b:c)")
        .flag("phase-s", Some("0.5"), "ramp curve: seconds per phase")
        .flag("rate", Some("4000"), "constant rate / diurnal base / flash base (req/s)")
        .flag("duration", Some("2.0"), "constant|diurnal|flash: trace length (s)")
        .flag("amplitude", Some("2000"), "diurnal: sinusoid amplitude (req/s)")
        .flag("period", Some("1.0"), "diurnal: sinusoid period (s)")
        .flag("peak", Some("12000"), "flash: spike peak (req/s)")
        .flag("at", Some("0.8"), "flash: spike onset (s)")
        .flag("rise", Some("0.2"), "flash: linear climb duration (s)")
        .flag("decay", Some("0.3"), "flash: exponential decay time constant (s)")
        .flag("process", Some("poisson"), "arrival process: poisson|lognormal|pareto")
        .flag("sigma", Some("1.0"), "lognormal process: gap sigma")
        .flag("alpha", Some("2.5"), "pareto process: gap shape (> 1)")
        .flag(
            "service",
            Some("det"),
            "service-time model: det | lognormal:S | prune:A:B | exit:P@F,...",
        )
        .flag("out", Some("trace.json"), "write the TraceSpec JSON here");
    let m = parse_or_exit(cmd, args);
    let curve = match m.str("curve").as_str() {
        "constant" => {
            RateCurve::Constant { rate_rps: m.f64("rate"), duration_s: m.f64("duration") }
        }
        "ramp" => RateCurve::from(&parse_ramp_or_exit(&m)),
        "diurnal" => RateCurve::Diurnal {
            base_rps: m.f64("rate"),
            amplitude_rps: m.f64("amplitude"),
            period_s: m.f64("period"),
            duration_s: m.f64("duration"),
        },
        "flash" => RateCurve::Flash {
            base_rps: m.f64("rate"),
            peak_rps: m.f64("peak"),
            at_s: m.f64("at"),
            ramp_s: m.f64("rise"),
            decay_s: m.f64("decay"),
            duration_s: m.f64("duration"),
        },
        other => {
            eprintln!("unknown curve '{other}' (constant|ramp|diurnal|flash)");
            return 2;
        }
    };
    let process = match m.str("process").as_str() {
        "poisson" => ArrivalProcess::Poisson,
        "lognormal" => ArrivalProcess::LognormalGaps { sigma: m.f64("sigma") },
        "pareto" => ArrivalProcess::ParetoGaps { alpha: m.f64("alpha") },
        other => {
            eprintln!("unknown process '{other}' (poisson|lognormal|pareto)");
            return 2;
        }
    };
    let service = match ServiceModel::parse(&m.str("service")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("--service: {e}");
            return 2;
        }
    };
    let models_csv = m.str("models");
    let trace = if models_csv.trim().is_empty() {
        TraceSpec::new(vec![ssr::traffic::TraceClass {
            model: m.str("model"),
            curve,
            process,
            service: service.clone(),
        }])
    } else {
        let models: Vec<&str> =
            models_csv.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
        TraceSpec::zipf_mix(&models, &curve, process, m.f64("zipf-exp"))
            .map(|t| t.with_service(&service))
    };
    let trace = match trace {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let out = m.str("out");
    if let Err(e) = trace.save(Path::new(&out)) {
        eprintln!("writing {out}: {e}");
        return 1;
    }
    print!("{}", trace.describe());
    println!("wrote {out}");
    0
}

fn trace_show(args: &[String]) -> i32 {
    let cmd = Command::new("ssr trace show", "describe a TraceSpec JSON")
        .flag("trace", Some("trace.json"), "TraceSpec JSON path");
    let m = parse_or_exit(cmd, args);
    match ssr::check::load_trace(Path::new(&m.str("trace"))) {
        Ok(t) => {
            print!("{}", t.describe());
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

// ---------------------------------------------------------------------------
// `ssr obs` — summarize saved traces / metrics.
// ---------------------------------------------------------------------------

fn cmd_obs(args: &[String]) -> i32 {
    let verb = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { vec![] } else { args[1..].to_vec() };
    match verb {
        "report" => obs_report(&rest),
        _ => {
            eprintln!(
                "usage: ssr obs report <trace.json> [--metrics m.prom]\n\
                 run `ssr obs report --help` for flags"
            );
            if verb == "help" {
                0
            } else {
                2
            }
        }
    }
}

/// Summarize a saved Chrome trace: per-event tallies, the conservation
/// identity, and (optionally) a Prometheus exposition round-trip check.
fn obs_report(args: &[String]) -> i32 {
    let cmd = Command::new("ssr obs report", "summarize a saved Chrome trace-event JSON")
        .flag("metrics", Some(""), "also check this Prometheus file parses and round-trips");
    let m = parse_or_exit(cmd, args);
    let Some(path) = m.positionals.first() else {
        eprintln!("usage: ssr obs report <trace.json> [--metrics m.prom]");
        return 2;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("reading {path}: {e}");
            return 1;
        }
    };
    let root = match ssr::util::json::Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("{path}: {e}");
            return 1;
        }
    };
    let t = match ssr::obs::tallies_from_json(&root) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{path}: {e}");
            return 1;
        }
    };
    let total: u64 = t.by_name.values().sum();
    println!("{path}: {total} events over {:.3} s", t.makespan_s);
    let mut table = ssr::bench::Table::new(&["event", "count"]);
    for (name, n) in &t.by_name {
        table.row(&[name.clone(), n.to_string()]);
    }
    println!("{}", table.render());
    println!(
        "{} arrivals | {} served | {} dropped ({} unroutable) | {} requeues ({} lost) | \
         {} windows | {} audit events | {} slo alerts | {} in flight at end",
        t.arrivals,
        t.served,
        t.shed,
        t.unroutable,
        t.requeued,
        t.requeue_lost,
        t.windows,
        t.audit,
        t.slo_alerts,
        t.in_flight()
    );
    if !t.conserved() {
        eprintln!(
            "CONSERVATION VIOLATED: served {} + dropped {} > arrivals {}",
            t.served, t.shed, t.arrivals
        );
        return 1;
    }
    println!("conservation holds: served + dropped <= arrivals");
    let mp = m.str("metrics");
    if !mp.is_empty() {
        let mtext = match std::fs::read_to_string(&mp) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("reading {mp}: {e}");
                return 1;
            }
        };
        match ssr::obs::parse_prometheus(&mtext) {
            Ok(fams) => {
                if ssr::obs::render_prometheus(&fams) != mtext {
                    eprintln!("{mp}: exposition does not round-trip byte-identically");
                    return 1;
                }
                println!("{mp}: {} families, exposition round-trips byte-identically", fams.len());
            }
            Err(e) => {
                eprintln!("{mp}: {e}");
                return 1;
            }
        }
    }
    0
}

fn cmd_calibrate(args: &[String]) -> i32 {
    let cmd = Command::new("ssr calibrate", "model-vs-paper residuals at the anchor points");
    let _ = parse_or_exit(cmd, args);
    let ctx = Ctx::vck190();
    let g = vit_graph(&builder::DEIT_T);
    println!("{:<30} {:>10} {:>10} {:>9}", "anchor", "paper", "model", "rel.err");
    let check = |name: &str, paper: f64, got: f64| {
        println!(
            "{name:<30} {paper:>10.3} {got:>10.3} {:>8.1}%",
            (got - paper) / paper * 100.0
        );
    };
    let anchors: [(Assignment, usize, f64, f64); 4] = [
        (Assignment::sequential(), 1, 0.22, 10.90),
        (Assignment::sequential(), 6, 1.30, 11.17),
        (Assignment::spatial(), 1, 2.0 * 1.25e9 / 5.66e12 * 1e3, 5.66),
        (Assignment::spatial(), 6, 0.58, 26.70),
    ];
    for (a, b, paper_ms, paper_tops) in anchors {
        let ev =
            build_design(&ctx.platform, &ctx.calib, &g, &a, Features::all(), true).unwrap();
        let e = ev.evaluate(&ctx.platform, &g, b);
        let tag = if a.nacc() == 1 { "seq" } else { "spatial" };
        check(&format!("{tag} b{b} latency (ms)"), paper_ms, e.latency_s * 1e3);
        check(&format!("{tag} b{b} TOPS"), paper_tops, e.tops);
    }
    0
}
