//! L3 serving coordinator: execute a Layer→Acc schedule on real compiled
//! PJRT stage executables.
//!
//! This is the runtime half of the reproduction: where the paper programs
//! AIE+PL accelerators, we map each *accelerator* to a worker thread owning
//! the compiled stage executables assigned to it, with channels as the
//! on-chip forwarding paths. The three paper execution models all run on
//! the same machinery:
//!
//! * **sequential** — one worker owning the monolithic `full_bN`
//!   executable (one acc runs every layer);
//! * **spatial**    — one worker per stage (embed / attn / mlp / head),
//!   images pipelined across them (Fig. 1b);
//! * **hybrid**     — any grouping of stages onto workers (Fig. 1c),
//!   derived from a DSE assignment via [`StageAssign::from_assignment`].
//!
//! Python never runs here; requests are f32 image tensors in, logits out.

pub mod batcher;
pub mod metrics;
pub mod pipeline;

pub use metrics::ServeReport;
pub use batcher::{BatchPolicy, BatchingServer};
pub use pipeline::{PipelineServer, SequentialServer};

use crate::dse::Assignment;
use crate::graph::LayerClass;

/// The four runtime stages the AOT path emits executables for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum StageKind {
    Embed,
    Attn,
    Mlp,
    Head,
}

pub const STAGE_KINDS: [StageKind; 4] =
    [StageKind::Embed, StageKind::Attn, StageKind::Mlp, StageKind::Head];

impl StageKind {
    pub fn name(self) -> &'static str {
        match self {
            StageKind::Embed => "embed",
            StageKind::Attn => "attn",
            StageKind::Mlp => "mlp",
            StageKind::Head => "head",
        }
    }
}

/// Grouping of the four runtime stages onto worker "accelerators".
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageAssign {
    pub acc_of: [usize; 4], // indexed by STAGE_KINDS order
}

impl StageAssign {
    pub fn sequential() -> Self {
        StageAssign { acc_of: [0; 4] }
    }

    pub fn spatial() -> Self {
        StageAssign { acc_of: [0, 1, 2, 3] }
    }

    /// Project an 8-class DSE assignment onto the 4 runtime stages: each
    /// stage goes to the acc hosting the majority of its classes (ties to
    /// the lowest acc id), then acc ids are re-densified.
    pub fn from_assignment(a: &Assignment) -> Self {
        let classes_of = |k: StageKind| -> Vec<LayerClass> {
            match k {
                StageKind::Embed => vec![LayerClass::Embed],
                StageKind::Attn => vec![
                    LayerClass::Qkv,
                    LayerClass::Bmm0,
                    LayerClass::Bmm1,
                    LayerClass::Proj,
                ],
                StageKind::Mlp => vec![LayerClass::Fc1, LayerClass::Fc2],
                StageKind::Head => vec![LayerClass::Head],
            }
        };
        let mut acc_of = [0usize; 4];
        for (i, k) in STAGE_KINDS.iter().enumerate() {
            let mut counts = std::collections::BTreeMap::new();
            for c in classes_of(*k) {
                *counts.entry(a.acc_of(c)).or_insert(0usize) += 1;
            }
            acc_of[i] = counts
                .iter()
                .max_by_key(|(acc, n)| (**n, usize::MAX - **acc))
                .map(|(acc, _)| *acc)
                .unwrap();
        }
        // densify
        let mut seen = Vec::new();
        for a in acc_of.iter_mut() {
            if let Some(pos) = seen.iter().position(|s| s == a) {
                *a = pos;
            } else {
                seen.push(*a);
                *a = seen.len() - 1;
            }
        }
        StageAssign { acc_of }
    }

    pub fn nacc(&self) -> usize {
        self.acc_of.iter().copied().max().unwrap() + 1
    }

    pub fn acc_of(&self, k: StageKind) -> usize {
        self.acc_of[STAGE_KINDS.iter().position(|s| *s == k).unwrap()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_one_acc() {
        assert_eq!(StageAssign::sequential().nacc(), 1);
    }

    #[test]
    fn spatial_four_accs() {
        let s = StageAssign::spatial();
        assert_eq!(s.nacc(), 4);
        assert_eq!(s.acc_of(StageKind::Head), 3);
    }

    #[test]
    fn projection_from_dse_assignment() {
        // attention classes on acc 1, everything else acc 0
        let a = Assignment::new(vec![0, 1, 1, 1, 1, 0, 0, 0]);
        let s = StageAssign::from_assignment(&a);
        assert_eq!(s.acc_of(StageKind::Embed), s.acc_of(StageKind::Mlp));
        assert_ne!(s.acc_of(StageKind::Embed), s.acc_of(StageKind::Attn));
        assert_eq!(s.nacc(), 2);
    }

    #[test]
    fn projection_of_sequential_is_sequential() {
        let s = StageAssign::from_assignment(&Assignment::sequential());
        assert_eq!(s, StageAssign::sequential());
    }

    #[test]
    fn projection_densifies_ids() {
        let a = Assignment::new(vec![3, 3, 3, 3, 3, 7, 7, 1]);
        let s = StageAssign::from_assignment(&a);
        assert!(s.nacc() <= 3);
        assert_eq!(s.acc_of(StageKind::Embed), 0);
    }
}
