//! L3 serving coordinator: execute an [`ExecutionPlan`] on real compiled
//! PJRT stage executables.
//!
//! This is the runtime half of the reproduction: where the paper programs
//! AIE+PL accelerators, we map each *accelerator* to a worker thread owning
//! the compiled stage executables assigned to it, with channels as the
//! on-chip forwarding paths. The three paper execution models all run on
//! the same machinery:
//!
//! * **sequential** — one worker owning the monolithic `full_bN`
//!   executable (one acc runs every layer);
//! * **spatial**    — one worker per layer class, images pipelined across
//!   them (Fig. 1b);
//! * **hybrid**     — any grouping of the 8 layer classes onto 1..=8
//!   workers (Fig. 1c), served directly from the DSE's [`ExecutionPlan`]
//!   via [`PipelineServer::from_plan`].
//!
//! On top of the single-plan servers, [`scheduler`] keeps the DSE's whole
//! latency-throughput Pareto front live and switches the active plan
//! against a latency SLO under observed load (drain-and-swap, hysteresis,
//! admission control) — the serve-time counterpart of Table 6's
//! "highest throughput under a latency constraint" column.
//!
//! [`StageAssign`] survives as the thin 4-stage compatibility shim for
//! manifests that only carry fused embed/attn/mlp/head executables; its
//! projection from an 8-class assignment now reports (instead of silently
//! dropping) every accelerator separation the coarsening destroys.
//!
//! Python never runs here; requests are f32 image tensors in, logits out.

pub mod batcher;
pub mod metrics;
pub mod pipeline;
pub mod scheduler;

pub use batcher::{BatchPolicy, BatchingServer};
pub use metrics::ServeReport;
pub use pipeline::{PipelineServer, SequentialServer};
pub use scheduler::{
    AdaptiveScheduler, AdaptiveServer, RampSpec, SchedulerCfg, TrafficClass, TrafficMix,
};

use crate::dse::Assignment;
use crate::plan::{expand_stage4, project_stage4, CoarsenReport, ExecutionPlan};

/// The four fused runtime stages the 4-stage AOT path emits executables for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum StageKind {
    Embed,
    Attn,
    Mlp,
    Head,
}

pub const STAGE_KINDS: [StageKind; 4] =
    [StageKind::Embed, StageKind::Attn, StageKind::Mlp, StageKind::Head];

impl StageKind {
    pub fn name(self) -> &'static str {
        match self {
            StageKind::Embed => "embed",
            StageKind::Attn => "attn",
            StageKind::Mlp => "mlp",
            StageKind::Head => "head",
        }
    }
}

/// Grouping of the four fused runtime stages onto worker "accelerators" —
/// the coarse compatibility representation. Full-granularity designs should
/// flow through [`ExecutionPlan`] instead.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageAssign {
    pub acc_of: [usize; 4], // indexed by STAGE_KINDS order
}

impl StageAssign {
    pub fn sequential() -> Self {
        StageAssign { acc_of: [0; 4] }
    }

    pub fn spatial() -> Self {
        StageAssign { acc_of: [0, 1, 2, 3] }
    }

    /// Project an 8-class DSE assignment onto the 4 runtime stages,
    /// returning the projection together with the [`CoarsenReport`] of
    /// every class placement the majority vote dropped.
    pub fn try_from_assignment(a: &Assignment) -> (Self, CoarsenReport) {
        let (acc_of, report) = project_stage4(a);
        (StageAssign { acc_of }, report)
    }

    /// Project an 8-class DSE assignment onto the 4 runtime stages: each
    /// stage goes to the acc hosting the majority of its classes (ties to
    /// the lowest acc id), then acc ids are re-densified. Logs a warning
    /// when the projection merges accs the DSE kept separate — use
    /// [`StageAssign::try_from_assignment`] to inspect the loss instead.
    pub fn from_assignment(a: &Assignment) -> Self {
        let (assign, report) = Self::try_from_assignment(a);
        if !report.is_lossless() {
            eprintln!(
                "[coordinator] 4-stage projection of assignment {:?} is {}",
                a.acc_of,
                report.describe()
            );
        }
        assign
    }

    /// The 8-class view of this grouping (exact: every class of a fused
    /// stage runs on that stage's acc).
    pub fn to_assignment(&self) -> Assignment {
        expand_stage4(self.acc_of)
    }

    /// Materialize the fused execution plan for this grouping.
    pub fn to_plan(&self, model: &str, depth: usize, micro_batch: usize) -> ExecutionPlan {
        ExecutionPlan::fused(model, depth, micro_batch, self.acc_of, self.to_assignment())
    }

    pub fn nacc(&self) -> usize {
        self.acc_of.iter().copied().max().unwrap() + 1
    }

    pub fn acc_of(&self, k: StageKind) -> usize {
        self.acc_of[STAGE_KINDS.iter().position(|s| *s == k).unwrap()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_one_acc() {
        assert_eq!(StageAssign::sequential().nacc(), 1);
    }

    #[test]
    fn spatial_four_accs() {
        let s = StageAssign::spatial();
        assert_eq!(s.nacc(), 4);
        assert_eq!(s.acc_of(StageKind::Head), 3);
    }

    #[test]
    fn projection_from_dse_assignment() {
        // attention classes on acc 1, everything else acc 0
        let a = Assignment::new(vec![0, 1, 1, 1, 1, 0, 0, 0]);
        let s = StageAssign::from_assignment(&a);
        assert_eq!(s.acc_of(StageKind::Embed), s.acc_of(StageKind::Mlp));
        assert_ne!(s.acc_of(StageKind::Embed), s.acc_of(StageKind::Attn));
        assert_eq!(s.nacc(), 2);
    }

    #[test]
    fn projection_of_sequential_is_sequential() {
        let s = StageAssign::from_assignment(&Assignment::sequential());
        assert_eq!(s, StageAssign::sequential());
    }

    #[test]
    fn projection_densifies_ids() {
        let a = Assignment::new(vec![3, 3, 3, 3, 3, 7, 7, 1]);
        let s = StageAssign::from_assignment(&a);
        assert!(s.nacc() <= 3);
        assert_eq!(s.acc_of(StageKind::Embed), 0);
    }

    #[test]
    fn lossless_projection_reports_lossless() {
        let a = Assignment::new(vec![0, 1, 1, 1, 1, 2, 2, 3]);
        let (s, report) = StageAssign::try_from_assignment(&a);
        assert_eq!(s.nacc(), 4);
        assert!(report.is_lossless());
    }

    #[test]
    fn lossy_projection_reports_merged_classes() {
        // attention split across accs 1 and 2 — unrepresentable in 4 stages
        let a = Assignment::new(vec![0, 1, 2, 2, 1, 3, 4, 0]);
        let (s, report) = StageAssign::try_from_assignment(&a);
        assert!(s.nacc() < a.nacc());
        assert!(!report.is_lossless());
        assert!(report.merges.iter().any(|m| m.class.is_attention()));
    }

    #[test]
    fn to_assignment_round_trips_losslessly() {
        for s in [
            StageAssign::sequential(),
            StageAssign::spatial(),
            StageAssign { acc_of: [0, 1, 0, 0] },
            StageAssign { acc_of: [0, 1, 2, 0] },
        ] {
            let a = s.to_assignment();
            let (back, report) = StageAssign::try_from_assignment(&a);
            assert_eq!(back, s);
            assert!(report.is_lossless(), "{:?}: {}", s.acc_of, report.describe());
        }
    }

    #[test]
    fn to_plan_preserves_grouping() {
        let s = StageAssign { acc_of: [0, 1, 2, 0] };
        let p = s.to_plan("deit_t", 12, 1);
        assert_eq!(p.nacc, 3);
        assert_eq!(p.steps.len(), 2 + 2 * 12);
        p.validate().unwrap();
    }
}
