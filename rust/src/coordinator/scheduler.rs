//! SLA-aware adaptive plan scheduler: serve the Pareto front, not one point.
//!
//! The DSE (and the paper's Table 6) picks one design per latency
//! constraint *offline*; which point is right at serve time depends on the
//! arrival rate (paper Fig. 2: sequential wins latency at low batch,
//! spatial wins throughput at high batch). This module holds the whole
//! [`PlanFront`] live and selects against the observed load:
//!
//! * [`LoadEstimator`] — sliding-window estimate over `ServeReport`-style
//!   metrics: arrival rate, queue depth, completion p99.
//! * [`AdaptiveScheduler`] — the switch policy. Per window it targets the
//!   *lowest-latency* front entry whose sustainable rate covers the
//!   demand (observed rate / headroom) within the SLO, falling back to
//!   the throughput-optimal entry under the SLO when saturated
//!   (`best_under`, Table 6 semantics). Hysteresis: a different target
//!   must persist for `patience` consecutive windows before a switch
//!   commits, so the active plan changes at most once per window and
//!   oscillating load cannot flap plans. Admission control sheds arrivals
//!   once the estimated queue wait exceeds `shed_slack` SLOs.
//! * [`AdaptiveServer`] — the live PJRT side: lazily compiles one
//!   [`PipelineServer`] per front entry (micro-batch variant picked with
//!   the SLA-aware [`BatchPolicy::choose_under`]) and swaps the active
//!   server at window boundaries. Window serving is synchronous, so every
//!   in-flight request finishes on the old plan before the swap —
//!   drain-and-swap by construction.
//!
//! The deterministic queueing counterpart (drain-and-swap mid-batch, real
//! backlog, shedding) lives in [`crate::sim::serving`], which drives this
//! same scheduler without artifacts.
//!
//! The load-generation half that used to live here — [`RampSpec`] ramps,
//! [`ClassArrivals`], [`TrafficClass`]/[`TrafficMix`], and the streaming
//! [`ArrivalStream`] merge — moved verbatim to [`crate::traffic`] when the
//! traffic API was unified around [`crate::traffic::TraceSpec`]; the
//! re-exports below keep every pre-move path compiling.

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::batcher::BatchPolicy;
use super::metrics::ServeReport;
use super::pipeline::{synth_images, PipelineServer};
use crate::plan::front::{FrontEntry, PlanFront};
use crate::runtime::exec::{Engine, Tensor};
use crate::util::stats::Summary;

// Moved to `crate::traffic` (see module docs); re-exported for the
// pre-move `coordinator::scheduler::*` paths.
pub use crate::traffic::{ArrivalStream, ClassArrivals, RampSpec, TrafficClass, TrafficMix};

// ---------------------------------------------------------------------------
// Policy configuration
// ---------------------------------------------------------------------------

/// Knobs of the adaptive scheduler.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerCfg {
    /// Per-request latency SLO (milliseconds).
    pub slo_ms: f64,
    /// Decision window (seconds): load is re-estimated and the switch
    /// policy runs once per window.
    pub window_s: f64,
    /// Hysteresis: consecutive windows a different target must persist
    /// before a switch commits (>= 1).
    pub patience: usize,
    /// Target utilization: a plan is considered sufficient while the
    /// observed rate stays below `headroom * plan.rps`, so switches fire
    /// *before* the active plan saturates.
    pub headroom: f64,
    /// Admission control: shed arrivals once the estimated queue wait
    /// exceeds `shed_slack` SLOs.
    pub shed_slack: f64,
    /// Sliding-window estimate horizon, in windows.
    pub horizon_windows: usize,
    /// Variance-aware capacity: when set, the switch policy inflates the
    /// demand by the observed tail factor (window p99 over the active
    /// plan's nominal latency, clamped to `[1, 8]`) before sizing a plan,
    /// so stochastic service times trigger the capacity escalation a mean
    /// estimate only sees after the queue has already built. Off by
    /// default: with `false` the policy is the historical mean-based
    /// [`choose_plan`] bit for bit.
    pub p99_aware: bool,
}

impl Default for SchedulerCfg {
    fn default() -> Self {
        SchedulerCfg {
            slo_ms: 2.0,
            window_s: 0.05,
            patience: 2,
            headroom: 0.8,
            shed_slack: 4.0,
            horizon_windows: 4,
            p99_aware: false,
        }
    }
}

impl SchedulerCfg {
    pub fn horizon_s(&self) -> f64 {
        self.window_s * self.horizon_windows.max(1) as f64
    }
}

// ---------------------------------------------------------------------------
// Load estimation
// ---------------------------------------------------------------------------

/// One sliding-window load snapshot (`ServeReport`-style metrics).
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadEstimate {
    /// Observed arrival rate over the horizon (req/s).
    pub rate_rps: f64,
    /// Queue depth at estimation time.
    pub queue_depth: usize,
    /// p99 completion latency over the horizon (0 when nothing completed).
    pub p99_s: f64,
    /// Completions inside the horizon.
    pub completed: usize,
}

/// Sliding-window estimator over raw arrival/completion events.
#[derive(Clone, Debug)]
pub struct LoadEstimator {
    horizon_s: f64,
    arrivals: VecDeque<f64>,
    completions: VecDeque<(f64, f64)>, // (completion time, latency_s)
}

impl LoadEstimator {
    pub fn new(horizon_s: f64) -> LoadEstimator {
        assert!(horizon_s > 0.0, "estimator horizon must be positive");
        LoadEstimator { horizon_s, arrivals: VecDeque::new(), completions: VecDeque::new() }
    }

    /// The sliding-window span this estimator averages over (seconds).
    pub fn horizon_s(&self) -> f64 {
        self.horizon_s
    }

    /// Estimate the load at `now_s` without mutating the estimator: the
    /// read-only twin of [`LoadEstimator::estimate`] (same numbers —
    /// pruning only discards events the estimate ignores anyway). This is
    /// what a fleet controller polls when making scale decisions between
    /// the device's own decision windows.
    pub fn peek(&self, now_s: f64, queue_depth: usize) -> LoadEstimate {
        let cut = now_s - self.horizon_s;
        // Early in the run the horizon has not filled yet: divide by the
        // elapsed span, not the full horizon, or rates read low.
        let span = self.horizon_s.min(now_s).max(1e-9);
        // Events are recorded in nondecreasing time order (asserted in
        // record_*), so both deques are sorted: binary-search the stale
        // prefix instead of re-scanning the whole window per call. With
        // `estimate`'s pruning, per-window cost is O(evictions + live
        // completions), not O(everything ever recorded).
        let stale = self.arrivals.partition_point(|&t| t < cut);
        let n_arrivals = self.arrivals.len() - stale;
        let first_live = self.completions.partition_point(|&(t, _)| t < cut);
        let completed = self.completions.len() - first_live;
        let mut lat = Summary::new();
        for &(_, l) in self.completions.range(first_live..) {
            lat.push(l);
        }
        LoadEstimate {
            rate_rps: n_arrivals as f64 / span,
            queue_depth,
            p99_s: if lat.is_empty() { 0.0 } else { lat.p99() },
            completed,
        }
    }

    pub fn record_arrival(&mut self, t_s: f64) {
        // Sortedness is what lets peek binary-search: the event loop
        // feeds each device's estimator in fleet-clock order (requeues
        // record the window time, and later arrivals are past the window).
        debug_assert!(
            self.arrivals.back().is_none_or(|&last| t_s >= last),
            "arrivals must be recorded in nondecreasing time order"
        );
        self.arrivals.push_back(t_s);
    }

    pub fn record_completion(&mut self, t_s: f64, latency_s: f64) {
        debug_assert!(
            self.completions.back().is_none_or(|&(last, _)| t_s >= last),
            "completions must be recorded in nondecreasing time order"
        );
        self.completions.push_back((t_s, latency_s));
    }

    /// Estimate the load at `now_s`. Prunes events older than the
    /// horizon, then computes through [`LoadEstimator::peek`] — one body
    /// for the math, so the mutating and read-only faces cannot drift.
    pub fn estimate(&mut self, now_s: f64, queue_depth: usize) -> LoadEstimate {
        let cut = now_s - self.horizon_s;
        while self.arrivals.front().is_some_and(|&t| t < cut) {
            self.arrivals.pop_front();
        }
        while self.completions.front().is_some_and(|&(t, _)| t < cut) {
            self.completions.pop_front();
        }
        self.peek(now_s, queue_depth)
    }
}

// ---------------------------------------------------------------------------
// Switch policy
// ---------------------------------------------------------------------------

/// Pick the front entry to serve `demand_rps` under `slo_ms`:
/// the lowest-latency entry with capacity for the demand within the SLO;
/// when saturated, the throughput-optimal entry under the SLO
/// ([`PlanFront::best_under`], Table 6 semantics); when nothing meets the
/// SLO at all, the lowest-latency entry (best effort).
pub fn choose_plan(front: &PlanFront, slo_ms: f64, demand_rps: f64) -> usize {
    choose_plan_p99(front, slo_ms, demand_rps, 1.0)
}

/// The p99-headroom variant of [`choose_plan`]: size a plan for the tail,
/// not the mean. `inflation >= 1` is the predicted tail factor of the
/// service-time distribution (observed window p99 over the plan's nominal
/// latency); a plan only counts as having capacity when its nominal rate
/// covers `demand_rps * inflation`, i.e. its effective rate under tail
/// service times (`rps / inflation`) covers the raw demand. At
/// `inflation == 1.0` this is exactly [`choose_plan`] — `demand_rps *
/// 1.0` is the identity on f64, so the mean-based path is bit-identical
/// by construction. The SLO filter and both fallback tiers are shared.
pub fn choose_plan_p99(front: &PlanFront, slo_ms: f64, demand_rps: f64, inflation: f64) -> usize {
    let effective_demand = demand_rps * inflation;
    // Entries are sorted by latency ascending, so the first hit is optimal.
    if let Some((i, _)) = front
        .entries
        .iter()
        .enumerate()
        .find(|(_, e)| e.latency_ms <= slo_ms && e.rps >= effective_demand)
    {
        return i;
    }
    if let Some(i) = front.best_under(slo_ms) {
        return i;
    }
    front.min_latency_idx()
}

/// One committed plan switch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SwitchRecord {
    pub at_s: f64,
    /// Decision-window index the switch committed in.
    pub window: usize,
    pub from: usize,
    pub to: usize,
    /// Observed rate that motivated the switch.
    pub rate_rps: f64,
}

/// The windowed switch policy with hysteresis + admission control. Pure
/// decision logic: both the deterministic simulator and the live
/// [`AdaptiveServer`] drive this same struct.
pub struct AdaptiveScheduler {
    pub front: PlanFront,
    pub cfg: SchedulerCfg,
    active: usize,
    candidate: Option<usize>,
    streak: usize,
    pub switches: Vec<SwitchRecord>,
}

impl AdaptiveScheduler {
    /// Start on the plan an idle system wants: lowest latency under SLO.
    pub fn new(front: PlanFront, cfg: SchedulerCfg) -> AdaptiveScheduler {
        assert!(!front.is_empty(), "scheduler needs a non-empty front");
        let active = choose_plan(&front, cfg.slo_ms, 0.0);
        AdaptiveScheduler { front, cfg, active, candidate: None, streak: 0, switches: Vec::new() }
    }

    pub fn active(&self) -> usize {
        self.active
    }

    pub fn active_entry(&self) -> &FrontEntry {
        &self.front.entries[self.active]
    }

    /// Run the switch policy for one decision window. Returns the new plan
    /// index when a switch commits (at most one per window; a committed
    /// switch resets the hysteresis state, so consecutive switches are at
    /// least `patience` windows apart).
    pub fn on_window(&mut self, window: usize, now_s: f64, est: &LoadEstimate) -> Option<usize> {
        let demand = est.rate_rps / self.cfg.headroom.max(1e-9);
        // Tail factor: how much slower the observed p99 completion runs
        // than the active plan's nominal latency. 1.0 when the window saw
        // no completions (p99_s == 0) or service times are deterministic;
        // clamped at 8 so one pathological window cannot demand a plan
        // beyond the front. Inactive (exactly 1.0) unless `p99_aware`.
        let inflation = if self.cfg.p99_aware {
            (est.p99_s / self.active_entry().latency_s()).clamp(1.0, 8.0)
        } else {
            1.0
        };
        let target = choose_plan_p99(&self.front, self.cfg.slo_ms, demand, inflation);
        if target == self.active {
            self.candidate = None;
            self.streak = 0;
            return None;
        }
        if self.candidate == Some(target) {
            self.streak += 1;
        } else {
            self.candidate = Some(target);
            self.streak = 1;
        }
        if self.streak < self.cfg.patience.max(1) {
            return None;
        }
        let from = self.active;
        self.active = target;
        self.candidate = None;
        self.streak = 0;
        self.switches.push(SwitchRecord { at_s: now_s, window, from, to: target, rate_rps: est.rate_rps });
        Some(target)
    }

    /// Admission control: admit while the estimated queue wait on the
    /// active plan stays within `shed_slack` SLOs.
    pub fn admit(&self, queue_depth: usize) -> bool {
        if queue_depth == 0 {
            return true;
        }
        let wait_s = queue_depth as f64 / self.active_entry().rps;
        wait_s <= self.cfg.shed_slack * self.cfg.slo_ms * 1e-3
    }
}

// ---------------------------------------------------------------------------
// Live serving of a front (PJRT runtime)
// ---------------------------------------------------------------------------

/// Per-window outcome of a live adaptive run.
pub struct WindowReport {
    pub window: usize,
    /// Offered arrival rate this window (req/s).
    pub rate_rps: f64,
    /// Front entry that served the window.
    pub active: usize,
    /// Requests admitted (and served) this window.
    pub admitted: usize,
    /// Requests shed by admission control this window.
    pub shed: usize,
    /// None for idle or fully-shed windows.
    pub report: Option<ServeReport>,
}

/// Outcome of [`AdaptiveServer::serve_ramp`].
pub struct AdaptiveServeReport {
    pub windows: Vec<WindowReport>,
    pub switches: Vec<SwitchRecord>,
    /// Requests actually served (excludes shed and launch padding).
    pub total_images: usize,
    /// Requests shed by admission control across the run.
    pub total_shed: usize,
}

/// Live adaptive serving over compiled PJRT stage executables: one lazily
/// compiled [`PipelineServer`] per front entry, swapped at window
/// boundaries. Windows serve synchronously, so a swap never interrupts an
/// in-flight request (drain-and-swap).
pub struct AdaptiveServer {
    engine: Arc<Engine>,
    sched: AdaptiveScheduler,
    /// Compiled micro-batch variant per front entry.
    micro_batch: Vec<usize>,
    servers: Vec<Option<PipelineServer>>,
    img_size: usize,
    est: LoadEstimator,
    /// Accumulated service overrun (seconds) carried across windows.
    backlog_s: f64,
}

impl AdaptiveServer {
    /// Bind a front to the engine: entries whose stage executables are
    /// absent at every compiled micro-batch (or whose per-launch latency
    /// cannot fit the SLO at any compiled variant) are dropped with a log
    /// line; the rest serve as found.
    pub fn new(engine: Arc<Engine>, front: PlanFront, cfg: SchedulerCfg) -> Result<AdaptiveServer> {
        let info = engine
            .manifest
            .models
            .get(&front.model)
            .ok_or_else(|| anyhow!("model {} not in manifest", front.model))?
            .clone();
        let mut variants: Vec<usize> = engine
            .manifest
            .executables
            .iter()
            .filter(|e| e.model.as_deref() == Some(front.model.as_str()))
            .filter_map(|e| e.batch)
            .collect();
        variants.sort_unstable();
        variants.dedup();
        if variants.is_empty() {
            return Err(anyhow!("manifest has no batch variants for {}", front.model));
        }
        let policy = BatchPolicy::new(variants);
        let mut entries = Vec::new();
        let mut micro_batch = Vec::new();
        // Lowest-latency entry that has executables but cannot meet the SLO
        // at any compiled variant — kept as the best-effort fallback so the
        // live path matches choose_plan's third tier instead of refusing
        // to start (the sim serves best-effort under an infeasible SLO too).
        let mut best_effort: Option<(FrontEntry, usize)> = None;
        for e in &front.entries {
            // Estimated per-launch service time of a b-deep variant, from
            // the entry's analytical metrics (linear in batch depth).
            let per_image_s = e.latency_s() / e.batch as f64;
            let (mb, fits_slo) =
                match policy.choose_under(e.batch, cfg.slo_ms * 1e-3, |b| per_image_s * b as f64)
                {
                    Some(mb) => (mb, true),
                    // choose(1) is the smallest compiled variant.
                    None => (policy.choose(1), false),
                };
            let plan = e.plan(&front.model, front.depth).with_micro_batch(mb);
            let class_ok = plan
                .requirements()
                .iter()
                .all(|r| engine.manifest.has_stage(&front.model, r.unit.name(), mb));
            let fused_ok = plan
                .coarsen()
                .0
                .requirements()
                .iter()
                .all(|r| engine.manifest.has_stage(&front.model, r.unit.name(), mb));
            if !class_ok && !fused_ok {
                eprintln!(
                    "[scheduler] dropping front entry '{}': manifest lacks its stage \
                     executables at b{mb}",
                    e.label
                );
                continue;
            }
            let mut e = e.clone();
            if mb < e.batch {
                // The entry's metrics were evaluated at its full batch; a
                // smaller compiled variant cannot be assumed to keep that
                // throughput (pipelining gains are sublinear in batch).
                // Derate capacity to the guaranteed lower bound — mb images
                // per launch, launch no slower than the full-batch latency —
                // so choose_plan/admit never promise more than the variant
                // can deliver. latency_ms stays as the (upper-bound) full
                // launch estimate.
                e.rps = e.rps * mb as f64 / e.batch as f64;
                eprintln!(
                    "[scheduler] entry '{}': serving the b{mb} variant, capacity derated to \
                     {:.0} img/s",
                    e.label, e.rps
                );
            }
            if fits_slo {
                entries.push(e);
                micro_batch.push(mb);
            } else if best_effort.is_none() {
                best_effort = Some((e, mb));
            }
        }
        if entries.is_empty() {
            let Some((e, mb)) = best_effort else {
                return Err(anyhow!("no servable entries in the front"));
            };
            eprintln!(
                "[scheduler] no front entry fits the {} ms SLO at any compiled variant; \
                 serving '{}' (b{mb}) best-effort",
                cfg.slo_ms, e.label
            );
            entries.push(e);
            micro_batch.push(mb);
        }
        let n = entries.len();
        let front = PlanFront { model: front.model.clone(), depth: front.depth, entries };
        Ok(AdaptiveServer {
            engine,
            sched: AdaptiveScheduler::new(front, cfg),
            micro_batch,
            servers: (0..n).map(|_| None).collect(),
            img_size: info.img_size,
            est: LoadEstimator::new(cfg.horizon_s()),
            backlog_s: 0.0,
        })
    }

    pub fn scheduler(&self) -> &AdaptiveScheduler {
        &self.sched
    }

    /// Accumulated service overrun expressed as a queue depth on the
    /// active plan — the live analog of the sim's queue length. A cluster
    /// router reads this (plus [`Self::active_entry`]) to build its
    /// per-device load view.
    pub fn queue_depth(&self) -> usize {
        (self.backlog_s * self.sched.active_entry().rps) as usize
    }

    pub fn active_entry(&self) -> &FrontEntry {
        self.sched.active_entry()
    }

    pub fn model(&self) -> &str {
        &self.sched.front.model
    }

    fn server(&mut self, idx: usize) -> Result<&PipelineServer> {
        if self.servers[idx].is_none() {
            let e = &self.sched.front.entries[idx];
            let plan = e
                .plan(&self.sched.front.model, self.sched.front.depth)
                .with_micro_batch(self.micro_batch[idx]);
            let server = PipelineServer::from_plan(Arc::clone(&self.engine), &plan)?;
            self.servers[idx] = Some(server);
        }
        Ok(self.servers[idx].as_ref().unwrap())
    }

    /// Serve one decision window: `arrivals` are this window's offered
    /// arrival times (absolute seconds), handed over by the caller — the
    /// single-device ramp loop below, or a cluster-level router splitting
    /// a traffic mix across devices ([`crate::cluster::router`]). The
    /// window's arrival count becomes synchronous launches on the active
    /// plan's server, then the measured window metrics feed the switch
    /// policy. Synchronous windows mean drain-and-swap by construction;
    /// overload shows up as service wall time exceeding the window budget,
    /// which carries forward as backlog — admission control sheds whole
    /// windows (the granularity of this open-loop harness) once the
    /// backlog-equivalent queue depth breaches the shed budget, mirroring
    /// the sim's per-request policy.
    pub fn serve_window(&mut self, w: usize, arrivals: &[f64], seed: u64) -> Result<WindowReport> {
        let window_s = self.sched.cfg.window_s;
        let end_s = (w + 1) as f64 * window_s;
        for &t in arrivals {
            self.est.record_arrival(t);
        }
        let count = arrivals.len();
        let active = self.sched.active();
        let mb = self.micro_batch[active];
        let queue_depth = self.queue_depth();
        let admitted = if count > 0 && self.sched.admit(queue_depth) { count } else { 0 };
        let shed = count - admitted;
        let report = if admitted > 0 {
            let launches = admitted.div_ceil(mb);
            let img_size = self.img_size;
            let reqs: Vec<Tensor> = (0..launches)
                .map(|i| synth_images(mb, img_size, seed ^ ((w as u64) << 24) ^ i as u64))
                .collect();
            let (report, _) = self.server(active)?.serve(reqs)?;
            // Service wall time beyond the window budget carries over.
            self.backlog_s = (self.backlog_s + report.wall_s - window_s).max(0.0);
            Some(report)
        } else {
            self.backlog_s = (self.backlog_s - window_s).max(0.0);
            None
        };
        // The policy sees the same sliding-window estimate as the sim
        // (horizon_windows applies identically); only p99/completed come
        // from the measured window since Summary keeps no raw samples.
        let mut snapshot = self.est.estimate(end_s, queue_depth);
        snapshot.p99_s = report.as_ref().map(|r| r.latency.p99()).unwrap_or(0.0);
        snapshot.completed = admitted;
        self.sched.on_window(w, end_s, &snapshot);
        let rate_rps = count as f64 / window_s; // offered, for display
        Ok(WindowReport { window: w, rate_rps, active, admitted, shed, report })
    }

    /// Drive the ramp window by window over [`Self::serve_window`].
    pub fn serve_ramp(&mut self, ramp: &RampSpec, seed: u64) -> Result<AdaptiveServeReport> {
        // A ramp is a complete run from t=0: discard load state left by a
        // previous run (serve_window's clock restarts, so stale estimator
        // timestamps would sit past the horizon prune and inflate the
        // rate; carried backlog would shed a fresh ramp's first windows).
        self.est = LoadEstimator::new(self.sched.cfg.horizon_s());
        self.backlog_s = 0.0;
        let window_s = self.sched.cfg.window_s;
        let arrivals = ramp.arrivals(seed);
        // ceil (with a float-error guard) so a partial final window still
        // serves its arrivals; the sim rounds instead, since its event loop
        // drains remaining arrivals without a tick.
        let n_windows = (ramp.duration_s() / window_s - 1e-9).ceil() as usize;
        let mut windows = Vec::with_capacity(n_windows);
        let mut total_images = 0usize;
        let mut total_shed = 0usize;
        let mut ai = 0usize;
        for w in 0..n_windows {
            let end_s = (w + 1) as f64 * window_s;
            let start = ai;
            while ai < arrivals.len() && arrivals[ai] < end_s {
                ai += 1;
            }
            let wr = self.serve_window(w, &arrivals[start..ai], seed)?;
            // Count offered requests, not launch capacity: the last launch
            // pads up to mb images and padding is not demand.
            total_images += wr.admitted;
            total_shed += wr.shed;
            windows.push(wr);
        }
        Ok(AdaptiveServeReport {
            windows,
            switches: self.sched.switches.clone(),
            total_images,
            total_shed,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
        FrontEntry {
            assign: vec![0; 8],
            batch,
            latency_ms: lat_ms,
            tops: rps * 2.5e-3,
            rps,
            nacc: 1,
            label: label.to_string(),
        }
    }

    /// seq-like (fast, low rate) / hybrid / spatial-like (slow, high rate).
    fn front3() -> PlanFront {
        PlanFront::new(
            "synthetic",
            12,
            vec![
                entry("seq", 1, 0.2, 5000.0),
                entry("hybrid", 6, 1.0, 6000.0),
                entry("spatial", 24, 2.0, 12000.0),
            ],
        )
        .unwrap()
    }

    fn est(rate: f64) -> LoadEstimate {
        LoadEstimate { rate_rps: rate, queue_depth: 0, p99_s: 0.0, completed: 0 }
    }

    #[test]
    fn choose_plan_low_latency_until_demand_exceeds_capacity() {
        let f = front3();
        assert_eq!(choose_plan(&f, 20.0, 0.0), 0);
        assert_eq!(choose_plan(&f, 20.0, 4900.0), 0);
        assert_eq!(choose_plan(&f, 20.0, 5500.0), 1); // seq saturated, hybrid fits
        assert_eq!(choose_plan(&f, 20.0, 11000.0), 2); // only spatial covers
    }

    #[test]
    fn choose_plan_saturated_takes_best_under_slo() {
        let f = front3();
        // demand beyond every entry: throughput-optimal under SLO
        assert_eq!(choose_plan(&f, 20.0, 1e9), 2);
        // SLO excludes spatial: best under 1.5 ms is hybrid
        assert_eq!(choose_plan(&f, 1.5, 1e9), 1);
        // SLO excludes everything: best-effort lowest latency
        assert_eq!(choose_plan(&f, 0.05, 1e9), 0);
    }

    #[test]
    fn choose_plan_p99_unity_is_identity_and_inflation_escalates() {
        let f = front3();
        // inflation 1.0 is choose_plan bit for bit across the demand sweep
        for d in [0.0, 4900.0, 5500.0, 11000.0, 1e9] {
            assert_eq!(choose_plan_p99(&f, 20.0, d, 1.0), choose_plan(&f, 20.0, d));
        }
        // demand 4000 fits seq at the mean; a 1.5x tail needs hybrid, a
        // 2.5x tail needs spatial
        assert_eq!(choose_plan_p99(&f, 20.0, 4000.0, 1.0), 0);
        assert_eq!(choose_plan_p99(&f, 20.0, 4000.0, 1.5), 1);
        assert_eq!(choose_plan_p99(&f, 20.0, 4000.0, 2.5), 2);
        // fallback tiers are shared: saturated under a tight SLO takes
        // best_under, an infeasible SLO stays best-effort lowest latency
        assert_eq!(choose_plan_p99(&f, 1.5, 4000.0, 8.0), 1);
        assert_eq!(choose_plan_p99(&f, 0.05, 4000.0, 8.0), 0);
    }

    #[test]
    fn p99_aware_policy_escalates_where_mean_based_holds() {
        let mk = |p99_aware| {
            AdaptiveScheduler::new(
                front3(),
                SchedulerCfg { slo_ms: 20.0, patience: 1, p99_aware, ..Default::default() },
            )
        };
        // rate 3000 -> demand 3750: seq (5000 rps) covers the mean, but
        // completions run at 2x seq's nominal 0.2 ms, so the tail-adjusted
        // demand 7500 outgrows hybrid (6000) too — p99-aware jumps to
        // spatial while the mean-based policy holds seq.
        let tail =
            LoadEstimate { rate_rps: 3000.0, queue_depth: 0, p99_s: 4.0e-4, completed: 50 };
        let mut mean = mk(false);
        assert_eq!(mean.on_window(0, 0.05, &tail), None);
        assert_eq!(mean.active(), 0);
        let mut p99 = mk(true);
        assert_eq!(p99.on_window(0, 0.05, &tail), Some(2));
        assert_eq!(p99.active(), 2);
        // no completions in the window (p99_s == 0): inflation clamps to
        // 1.0 and the p99-aware policy is the mean-based one
        let mut quiet = mk(true);
        assert_eq!(quiet.on_window(0, 0.05, &est(3000.0)), None);
        assert_eq!(quiet.active(), 0);
    }

    #[test]
    fn hysteresis_commits_after_patience_windows() {
        let cfg = SchedulerCfg { slo_ms: 20.0, patience: 2, ..Default::default() };
        let mut s = AdaptiveScheduler::new(front3(), cfg);
        assert_eq!(s.active(), 0);
        // sustained rate 4400: demand 4400 / 0.8 = 5500 outgrows seq (5000)
        // but fits hybrid (6000); window 0 arms the candidate, window 1
        // commits the switch
        assert_eq!(s.on_window(0, 0.05, &est(4400.0)), None);
        assert_eq!(s.on_window(1, 0.10, &est(4400.0)), Some(1));
        assert_eq!(s.active(), 1);
        assert_eq!(s.switches.len(), 1);
        assert_eq!(s.switches[0].from, 0);
        assert_eq!(s.switches[0].to, 1);
        // rate falls again: two quiet windows later we are back on seq
        assert_eq!(s.on_window(2, 0.15, &est(1000.0)), None);
        assert_eq!(s.on_window(3, 0.20, &est(1000.0)), Some(0));
        // consecutive switches are >= patience windows apart
        assert!(s.switches[1].window - s.switches[0].window >= cfg.patience);
    }

    #[test]
    fn alternating_targets_never_switch() {
        let cfg = SchedulerCfg { slo_ms: 20.0, patience: 2, ..Default::default() };
        let mut s = AdaptiveScheduler::new(front3(), cfg);
        for w in 0..20 {
            let rate = if w % 2 == 0 { 5500.0 } else { 1000.0 };
            assert_eq!(s.on_window(w, w as f64 * 0.05, &est(rate)), None);
        }
        assert!(s.switches.is_empty());
        assert_eq!(s.active(), 0);
    }

    #[test]
    fn admission_sheds_only_past_the_slack() {
        let cfg = SchedulerCfg { slo_ms: 20.0, shed_slack: 4.0, ..Default::default() };
        let s = AdaptiveScheduler::new(front3(), cfg);
        // active = seq (5000 rps); budget = 4 * 20 ms = 80 ms => 400 queued
        assert!(s.admit(0));
        assert!(s.admit(400));
        assert!(!s.admit(401));
    }

    #[test]
    fn estimator_rates_and_pruning() {
        let mut e = LoadEstimator::new(0.2);
        for i in 0..100 {
            e.record_arrival(i as f64 * 1e-3); // 100 arrivals in 0.1 s
        }
        e.record_completion(0.09, 1e-3);
        let est = e.estimate(0.1, 3);
        assert!((est.rate_rps - 1000.0).abs() < 1.0, "rate {}", est.rate_rps);
        assert_eq!(est.queue_depth, 3);
        assert_eq!(est.completed, 1);
        // an hour later everything has aged out
        let est = e.estimate(3600.0, 0);
        assert_eq!(est.rate_rps, 0.0);
        assert_eq!(est.completed, 0);
        assert_eq!(est.p99_s, 0.0);
    }

    #[test]
    fn peek_matches_estimate_and_does_not_mutate() {
        let mut e = LoadEstimator::new(0.2);
        for i in 0..50 {
            e.record_arrival(i as f64 * 2e-3);
        }
        e.record_completion(0.09, 1e-3);
        let peeked = e.peek(0.1, 2);
        let estimated = e.estimate(0.1, 2);
        assert_eq!(peeked.rate_rps, estimated.rate_rps);
        assert_eq!(peeked.completed, estimated.completed);
        assert_eq!(peeked.p99_s, estimated.p99_s);
        assert_eq!(peeked.queue_depth, 2);
        // peek after estimate's pruning still agrees (pruned events were
        // outside the horizon either way)
        let again = e.peek(0.1, 2);
        assert_eq!(again.rate_rps, estimated.rate_rps);
        assert!((e.horizon_s() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn scheduler_starts_on_lowest_latency_under_slo() {
        let s = AdaptiveScheduler::new(front3(), SchedulerCfg { slo_ms: 20.0, ..Default::default() });
        assert_eq!(s.active(), 0);
        // SLO that only spatial-class throughput plans could meet does not
        // exist here; with SLO below every entry we still serve best effort
        let s = AdaptiveScheduler::new(front3(), SchedulerCfg { slo_ms: 0.05, ..Default::default() });
        assert_eq!(s.active(), 0);
    }

    #[test]
    fn peek_binary_search_matches_naive_filter_scan() {
        // The satellite pin: the partition_point suffix counts must equal
        // the old full-window filter re-scan on randomized (sorted) event
        // sequences — including p99 over exactly the live completions.
        let mut g = Rng::new(0x0E57);
        for case in 0..20 {
            let mut e = LoadEstimator::new(0.05 + g.f64() * 0.3);
            let mut t = 0.0f64;
            for _ in 0..(50 + g.usize_below(200)) {
                t += g.f64() * 0.01;
                if g.bool(0.7) {
                    e.record_arrival(t);
                } else {
                    e.record_completion(t, g.f64() * 5e-3);
                }
            }
            let now = t + g.f64() * 0.05;
            let cut = now - e.horizon_s();
            let naive_arrivals = e.arrivals.iter().filter(|&&x| x >= cut).count();
            let mut naive_lat = Summary::new();
            let mut naive_completed = 0usize;
            for &(ct, l) in &e.completions {
                if ct >= cut {
                    naive_lat.push(l);
                    naive_completed += 1;
                }
            }
            let naive_p99 = if naive_lat.is_empty() { 0.0 } else { naive_lat.p99() };
            let span = e.horizon_s().min(now).max(1e-9);
            let got = e.peek(now, case);
            assert_eq!(
                got.rate_rps.to_bits(),
                (naive_arrivals as f64 / span).to_bits(),
                "case {case}: rate"
            );
            assert_eq!(got.completed, naive_completed, "case {case}: completed");
            assert_eq!(got.p99_s.to_bits(), naive_p99.to_bits(), "case {case}: p99");
            assert_eq!(got.queue_depth, case);
        }
    }
}
