//! Serve-time metrics: per-request latency distribution + throughput.

use crate::util::stats::{fmt_ms, Summary};

/// Outcome of a serve run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub requests: usize,
    pub wall_s: f64,
    pub latency: Summary,
    /// MACs per image (for effective-TOPS accounting).
    pub macs_per_image: u64,
}

impl ServeReport {
    pub fn throughput_rps(&self) -> f64 {
        self.requests as f64 / self.wall_s
    }

    /// Effective TOPS over the run (2 ops per MAC).
    pub fn effective_tops(&self) -> f64 {
        (self.requests as f64 * self.macs_per_image as f64 * 2.0) / self.wall_s / 1e12
    }

    /// Fraction of requests whose latency met the SLO (1.0 on an empty run:
    /// no request violated anything).
    pub fn slo_attainment(&self, slo_s: f64) -> f64 {
        if self.latency.is_empty() {
            return 1.0;
        }
        self.latency.count_leq(slo_s) as f64 / self.latency.len() as f64
    }

    pub fn summary_line(&self) -> String {
        // one sort for both quantiles — this prints per window in the
        // adaptive serving loop; empty windows yield NaN percentiles,
        // which fmt_ms prints as "-"
        let pct = self.latency.percentiles(&[0.50, 0.99]);
        format!(
            "{} reqs in {:.3} s | {:.2} req/s | lat p50 {} ms p99 {} ms | {:.4} effective TOPS",
            self.requests,
            self.wall_s,
            self.throughput_rps(),
            fmt_ms(pct[0]),
            fmt_ms(pct[1]),
            self.effective_tops()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> ServeReport {
        let mut latency = Summary::new();
        for i in 1..=10 {
            latency.push(i as f64 * 1e-3);
        }
        ServeReport { requests: 10, wall_s: 2.0, latency, macs_per_image: 1_250_000_000 }
    }

    #[test]
    fn throughput_math() {
        let r = report();
        assert_eq!(r.throughput_rps(), 5.0);
        // 10 * 1.25G * 2 / 2s = 12.5 GOPS
        assert!((r.effective_tops() - 0.0125).abs() < 1e-9);
    }

    #[test]
    fn slo_attainment_counts_fraction_under() {
        let r = report(); // latencies 1..=10 ms
        assert!((r.slo_attainment(5e-3) - 0.5).abs() < 1e-12);
        assert_eq!(r.slo_attainment(100e-3), 1.0);
        assert_eq!(r.slo_attainment(0.1e-3), 0.0);
    }

    #[test]
    fn summary_line_contains_fields() {
        let s = report().summary_line();
        assert!(s.contains("req/s"));
        assert!(s.contains("p99"));
    }

    #[test]
    fn empty_window_summary_prints_dashes_not_nan() {
        // An idle serve window has zero completions; percentiles of an
        // empty Summary are NaN and must never reach the printed line.
        let r = ServeReport {
            requests: 0,
            wall_s: 0.05,
            latency: Summary::new(),
            macs_per_image: 1_250_000_000,
        };
        let s = r.summary_line();
        assert!(s.contains("p50 - ms p99 - ms"), "{s}");
        assert!(!s.contains("NaN"), "{s}");
    }
}
