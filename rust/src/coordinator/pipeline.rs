//! Worker-thread execution of an [`ExecutionPlan`].
//!
//! Each worker thread is one "accelerator": it owns the compiled stage
//! executables assigned to it and processes jobs FIFO from its channel —
//! the software analog of an acc consuming its PLIO stream. Channels
//! between workers are the on-chip forwarding paths; images in flight
//! pipeline across workers exactly as batches do across spatial accs in
//! Fig. 1(b-c).
//!
//! [`PipelineServer::from_plan`] serves any class-granular plan directly
//! (one executable per `LayerClass`, so every `nacc ∈ 1..=8` hybrid the
//! DSE emits is servable as found). When the artifact manifest only
//! carries the four fused stage executables, the plan is coarsened through
//! the compatibility shim and the lost accelerator separations are logged.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::metrics::ServeReport;
use super::StageAssign;
use crate::plan::{ExecutionPlan, Granularity, PlanStep, StageUnit};
use crate::runtime::exec::{Engine, Stage, Tensor};
use crate::util::stats::Summary;

struct WorkItem {
    req_id: usize,
    step: usize,
    tensor: Tensor,
    submitted: Instant,
}

enum Job {
    Work(WorkItem),
    Stop,
}

/// Pipelined (spatial / hybrid) server: one worker per plan accelerator.
pub struct PipelineServer {
    engine: Arc<Engine>,
    txs: Vec<Sender<Job>>,
    done_rx: Receiver<(usize, Tensor, Instant)>,
    handles: Vec<thread::JoinHandle<()>>,
    /// The plan actually being served (coarsened if the manifest forced it).
    plan: ExecutionPlan,
    macs_per_image: u64,
}

impl PipelineServer {
    /// Serve `plan` directly: compile every required stage executable at
    /// the plan's micro-batch and spawn one worker per accelerator.
    ///
    /// If the manifest lacks executables for a class-granular plan, the
    /// plan is coarsened to the 4-stage compatibility grouping and the
    /// [`crate::plan::CoarsenReport`] is logged — serving degrades
    /// gracefully instead of failing, but never silently.
    pub fn from_plan(engine: Arc<Engine>, plan: &ExecutionPlan) -> Result<PipelineServer> {
        let info = engine
            .manifest
            .models
            .get(&plan.model)
            .ok_or_else(|| anyhow!("model {} not in manifest", plan.model))?
            .clone();
        if info.depth != plan.depth {
            return Err(anyhow!(
                "plan depth {} != manifest depth {} for {}",
                plan.depth,
                info.depth,
                plan.model
            ));
        }

        let missing: Vec<String> = plan
            .requirements()
            .iter()
            .filter(|r| !engine.manifest.has_stage(&plan.model, r.unit.name(), plan.micro_batch))
            .map(|r| r.exe_name.clone())
            .collect();
        let plan = if missing.is_empty() {
            plan.clone()
        } else if plan.granularity == Granularity::Class {
            let (coarse, report) = plan.coarsen();
            eprintln!(
                "[pipeline] manifest lacks {:?}; serving the 4-stage shim instead \
                 (projection {})",
                missing,
                report.describe()
            );
            coarse
        } else {
            return Err(anyhow!("manifest lacks stage executables {missing:?}"));
        };

        // Compile each required stage once, share with every worker using it.
        let mut stages: BTreeMap<StageUnit, Arc<Stage>> = BTreeMap::new();
        for req in plan.requirements() {
            let stage = engine
                .compile(&req.exe_name)
                .with_context(|| format!("compiling stage {}", req.exe_name))?;
            stages.insert(req.unit, Arc::new(stage));
        }

        let nacc = plan.nacc;
        let (done_tx, done_rx) = channel::<(usize, Tensor, Instant)>();
        let mut txs = Vec::with_capacity(nacc);
        let mut rxs = Vec::with_capacity(nacc);
        for _ in 0..nacc {
            let (tx, rx) = channel::<Job>();
            txs.push(tx);
            rxs.push(Some(rx));
        }

        let mut handles = Vec::with_capacity(nacc);
        for acc in 0..nacc {
            let rx = rxs[acc].take().unwrap();
            let my_stages: BTreeMap<StageUnit, Arc<Stage>> = plan
                .steps
                .iter()
                .filter(|s| s.acc == acc)
                .map(|s| (s.unit, Arc::clone(&stages[&s.unit])))
                .collect();
            let fwd: Vec<Sender<Job>> = txs.clone();
            let done = done_tx.clone();
            let eng = Arc::clone(&engine);
            let sched: Vec<PlanStep> = plan.steps.clone();
            handles.push(
                thread::Builder::new()
                    .name(format!("ssr-acc-{acc}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            let item = match job {
                                Job::Stop => break,
                                Job::Work(w) => w,
                            };
                            let step = sched[item.step];
                            let stage = &my_stages[&step.unit];
                            // Weight-free stages (attention BMMs) take no
                            // block index even though they sit inside a block.
                            let block = if stage.needs_block() { step.block } else { None };
                            let out = stage
                                .run(&eng, &[item.tensor], block)
                                .expect("stage execution failed");
                            let next = item.step + 1;
                            if next == sched.len() {
                                let _ = done.send((item.req_id, out, item.submitted));
                            } else {
                                let _ = fwd[sched[next].acc].send(Job::Work(WorkItem {
                                    req_id: item.req_id,
                                    step: next,
                                    tensor: out,
                                    submitted: item.submitted,
                                }));
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }

        Ok(PipelineServer {
            engine,
            txs,
            done_rx,
            handles,
            plan,
            macs_per_image: info.macs_per_image,
        })
    }

    /// 4-stage compatibility entry point: build the fused plan for `assign`
    /// and serve it (kept for callers that predate the ExecutionPlan IR).
    pub fn new(
        engine: Arc<Engine>,
        model: &str,
        assign: &StageAssign,
        micro_batch: usize,
    ) -> Result<PipelineServer> {
        let depth = engine
            .manifest
            .models
            .get(model)
            .ok_or_else(|| anyhow!("model {model} not in manifest"))?
            .depth;
        let plan = assign.to_plan(model, depth, micro_batch);
        Self::from_plan(engine, &plan)
    }

    /// The plan actually being served (after any compatibility coarsening).
    pub fn plan(&self) -> &ExecutionPlan {
        &self.plan
    }

    /// Serve `images` (each shaped `[micro_batch, H, W, 3]`); returns the
    /// report and the logits per request, in request order.
    pub fn serve(&self, images: Vec<Tensor>) -> Result<(ServeReport, Vec<Tensor>)> {
        let n = images.len();
        let t0 = Instant::now();
        for (i, img) in images.into_iter().enumerate() {
            self.txs[self.plan.steps[0].acc]
                .send(Job::Work(WorkItem {
                    req_id: i,
                    step: 0,
                    tensor: img,
                    submitted: Instant::now(),
                }))
                .map_err(|_| anyhow!("pipeline worker died"))?;
        }
        let mut outs: Vec<Option<Tensor>> = (0..n).map(|_| None).collect();
        let mut latency = Summary::new();
        for _ in 0..n {
            let (req, tensor, submitted) =
                self.done_rx.recv().map_err(|_| anyhow!("pipeline closed early"))?;
            latency.push(submitted.elapsed().as_secs_f64());
            outs[req] = Some(tensor);
        }
        let wall = t0.elapsed().as_secs_f64();
        let report = ServeReport {
            requests: n * self.plan.micro_batch,
            wall_s: wall,
            latency,
            macs_per_image: self.macs_per_image,
        };
        Ok((report, outs.into_iter().map(Option::unwrap).collect()))
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }
}

impl Drop for PipelineServer {
    fn drop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Job::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Sequential (monolithic) server: one full-model executable per batch size.
pub struct SequentialServer {
    engine: Arc<Engine>,
    full: BTreeMap<usize, Stage>,
    macs_per_image: u64,
    img_size: usize,
}

impl SequentialServer {
    /// Compile the `full_bN` executables for `batches`.
    pub fn new(engine: Arc<Engine>, model: &str, batches: &[usize]) -> Result<SequentialServer> {
        let info = engine
            .manifest
            .models
            .get(model)
            .ok_or_else(|| anyhow!("model {model} not in manifest"))?
            .clone();
        let mut full = BTreeMap::new();
        for &b in batches {
            let name = format!("{model}_full_b{b}");
            full.insert(b, engine.compile(&name)?);
        }
        Ok(SequentialServer {
            engine,
            full,
            macs_per_image: info.macs_per_image,
            img_size: info.img_size,
        })
    }

    pub fn batch_sizes(&self) -> Vec<usize> {
        self.full.keys().copied().collect()
    }

    /// Run one batch tensor `[B, H, W, 3]` -> logits `[B, classes]`.
    pub fn run_batch(&self, batch: usize, images: &Tensor) -> Result<Tensor> {
        let stage = self
            .full
            .get(&batch)
            .ok_or_else(|| anyhow!("no full_b{batch} compiled"))?;
        stage.run(&self.engine, std::slice::from_ref(images), None)
    }

    /// Serve `reqs` batch tensors serially (the monolithic acc timeline of
    /// Fig. 1a) and report latency/throughput.
    pub fn serve(&self, batch: usize, reqs: &[Tensor]) -> Result<(ServeReport, Vec<Tensor>)> {
        let t0 = Instant::now();
        let mut latency = Summary::new();
        let mut outs = Vec::with_capacity(reqs.len());
        for r in reqs {
            let t = Instant::now();
            outs.push(self.run_batch(batch, r)?);
            latency.push(t.elapsed().as_secs_f64());
        }
        let report = ServeReport {
            requests: reqs.len() * batch,
            wall_s: t0.elapsed().as_secs_f64(),
            latency,
            macs_per_image: self.macs_per_image,
        };
        Ok((report, outs))
    }

    pub fn img_size(&self) -> usize {
        self.img_size
    }

    pub fn macs_per_image(&self) -> u64 {
        self.macs_per_image
    }
}

/// Deterministic synthetic image batch (seeded, int8-range values).
pub fn synth_images(batch: usize, img_size: usize, seed: u64) -> Tensor {
    let mut rng = crate::util::rng::Rng::new(seed);
    let n = batch * img_size * img_size * 3;
    let data: Vec<f32> = (0..n)
        .map(|_| (rng.f64() as f32 * 2.0 - 1.0) * 1.5)
        .collect();
    Tensor::new(vec![batch, img_size, img_size, 3], data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;
    use std::sync::OnceLock;

    fn engine() -> Arc<Engine> {
        static E: OnceLock<Arc<Engine>> = OnceLock::new();
        Arc::clone(E.get_or_init(|| Engine::load(&PathBuf::from("artifacts")).unwrap()))
    }

    #[test]
    fn sequential_matches_pipeline_numerics() {
        // The monolithic executable and the stage pipeline must produce the
        // same logits — the runtime analog of the stage-composition test.
        let eng = engine();
        let seq = SequentialServer::new(Arc::clone(&eng), "deit_t", &[1]).unwrap();
        let pipe =
            PipelineServer::new(Arc::clone(&eng), "deit_t", &StageAssign::spatial(), 1)
                .unwrap();
        let img = synth_images(1, 224, 42);
        let a = seq.run_batch(1, &img).unwrap();
        let (_, outs) = pipe.serve(vec![img]).unwrap();
        assert_eq!(a.shape, outs[0].shape);
        for (x, y) in a.data.iter().zip(&outs[0].data) {
            assert!((x - y).abs() < 2e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn hybrid_grouping_same_numerics() {
        let eng = engine();
        let seq = SequentialServer::new(Arc::clone(&eng), "deit_t", &[1]).unwrap();
        let hybrid = StageAssign { acc_of: [0, 1, 1, 0] };
        let pipe = PipelineServer::new(Arc::clone(&eng), "deit_t", &hybrid, 1).unwrap();
        let img = synth_images(1, 224, 7);
        let a = seq.run_batch(1, &img).unwrap();
        let (_, outs) = pipe.serve(vec![img]).unwrap();
        for (x, y) in a.data.iter().zip(&outs[0].data) {
            assert!((x - y).abs() < 2e-3);
        }
    }

    #[test]
    fn pipeline_reports_all_requests() {
        let eng = engine();
        let pipe =
            PipelineServer::new(Arc::clone(&eng), "deit_t", &StageAssign::spatial(), 1)
                .unwrap();
        let imgs: Vec<Tensor> = (0..4).map(|i| synth_images(1, 224, i)).collect();
        let (report, outs) = pipe.serve(imgs).unwrap();
        assert_eq!(report.requests, 4);
        assert_eq!(outs.len(), 4);
        assert_eq!(report.latency.len(), 4);
        assert!(report.effective_tops() > 0.0);
    }

    #[test]
    fn sequential_batch3_runs() {
        let eng = engine();
        let seq = SequentialServer::new(Arc::clone(&eng), "deit_t", &[3]).unwrap();
        let img = synth_images(3, 224, 1);
        let out = seq.run_batch(3, &img).unwrap();
        assert_eq!(out.shape, vec![3, 1000]);
        assert!(out.data.iter().all(|x| x.is_finite()));
    }
}
