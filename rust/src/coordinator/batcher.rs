//! Dynamic batcher for the sequential (monolithic-acc) server.
//!
//! The paper's GPU baseline explores latency-throughput purely by batch
//! size; the serving analog is a batcher that packs a request queue into
//! the pre-compiled `full_bN` executables: deepest batch that the queue
//! fills, padding the final partial batch (padded rows are discarded).
//! This is the "dynamic batching" half of the L3 coordinator; the
//! pipeline server covers the spatial/hybrid half.

use anyhow::{anyhow, Result};

use super::metrics::ServeReport;
use super::pipeline::SequentialServer;
use crate::runtime::exec::Tensor;
use crate::util::stats::Summary;

/// Greedy batch-size policy over the compiled batch variants.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Available `full_bN` sizes, ascending (e.g. [1, 3, 6]).
    sizes: Vec<usize>,
}

impl BatchPolicy {
    pub fn new(mut sizes: Vec<usize>) -> Self {
        assert!(!sizes.is_empty(), "need at least one batch size");
        sizes.sort_unstable();
        sizes.dedup();
        BatchPolicy { sizes }
    }

    /// Largest compiled batch the queue can fill; if the queue is smaller
    /// than every size, the smallest executable that covers it (padding).
    pub fn choose(&self, queued: usize) -> usize {
        assert!(queued > 0);
        self.sizes
            .iter()
            .rev()
            .find(|&&s| s <= queued)
            .copied()
            .unwrap_or_else(|| {
                *self
                    .sizes
                    .iter()
                    .find(|&&s| s >= queued)
                    .unwrap_or(self.sizes.last().unwrap())
            })
    }

    /// SLA-aware variant of [`BatchPolicy::choose`]: the deepest compiled
    /// batch that the queue fills AND whose estimated service time fits
    /// `budget_s` (falling back to the smallest covering executable that
    /// fits). `service_s` maps a batch size to its estimated service time.
    /// Returns None when no compiled size meets the budget — the caller
    /// must shed or switch plans instead of batching deeper.
    ///
    /// Boundary contract: the budget is **inclusive** — a size with
    /// `service_s(s) == budget_s` exactly is feasible. An SLO is "complete
    /// within the budget", and the estimate is itself derived from the
    /// same analytic model the budget came from, so exact equality is the
    /// common case (e.g. a b6 launch sized from a 6-image budget), not a
    /// tie-break curiosity. Rejecting it (`<`) would drop the deepest
    /// exactly-fitting variant and silently halve throughput at round
    /// numbers. Callers composing a safety margin must shrink the budget,
    /// not rely on the comparison.
    pub fn choose_under<F: Fn(usize) -> f64>(
        &self,
        queued: usize,
        budget_s: f64,
        service_s: F,
    ) -> Option<usize> {
        assert!(queued > 0);
        let fits: Vec<usize> =
            self.sizes.iter().copied().filter(|&s| service_s(s) <= budget_s).collect();
        fits.iter()
            .rev()
            .find(|&&s| s <= queued)
            .or_else(|| fits.iter().find(|&&s| s >= queued))
            .copied()
    }

    /// Slack-aware batch composition under stochastic service times: pick
    /// the batch whose **predicted tail** service time fits the budget.
    /// `q_factor >= 1` is the service-time distribution's quantile factor
    /// at the operating quantile (e.g. [`crate::sim::service::ServiceModel::tail_q`]
    /// at 0.99): every candidate's mean estimate `service_s(s)` is scaled
    /// by it before the inclusive budget test, so the launch still fits
    /// the SLO when the draw lands on the tail, at the cost of shallower
    /// batches. `q_factor == 1.0` is exactly [`BatchPolicy::choose_under`]
    /// (scaling by 1.0 is the f64 identity), so deterministic service
    /// models lose nothing.
    pub fn choose_under_quantile<F: Fn(usize) -> f64>(
        &self,
        queued: usize,
        budget_s: f64,
        q_factor: f64,
        service_s: F,
    ) -> Option<usize> {
        self.choose_under(queued, budget_s, |s| service_s(s) * q_factor)
    }

    /// Split a queue length into concrete batch launches.
    pub fn plan(&self, mut queued: usize) -> Vec<usize> {
        let mut plan = Vec::new();
        while queued > 0 {
            let b = self.choose(queued);
            plan.push(b);
            queued = queued.saturating_sub(b);
        }
        plan
    }
}

/// Source request index for each row of a packed `b`-deep batch launch
/// starting at request `next` of `n` total: real rows map 1:1, padding rows
/// repeat the last real request (their logits are discarded after the run).
pub fn row_sources(next: usize, n: usize, b: usize) -> Vec<usize> {
    assert!(next < n, "launch must cover at least one real request");
    let real = b.min(n - next);
    (0..b).map(|i| next + i.min(real - 1)).collect()
}

/// Batching front-end over a [`SequentialServer`].
pub struct BatchingServer {
    seq: SequentialServer,
    policy: BatchPolicy,
}

impl BatchingServer {
    pub fn new(seq: SequentialServer) -> Self {
        let policy = BatchPolicy::new(seq.batch_sizes());
        BatchingServer { seq, policy }
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// Serve single-image requests (`[1, H, W, 3]` each): pack into the
    /// deepest available batches, pad the tail, unpack logits per request.
    pub fn serve(&self, requests: &[Tensor]) -> Result<(ServeReport, Vec<Tensor>)> {
        let n = requests.len();
        if n == 0 {
            return Err(anyhow!("empty request set"));
        }
        let img = self.seq.img_size();
        let img_elems = img * img * 3;
        for (i, r) in requests.iter().enumerate() {
            if r.shape != vec![1, img, img, 3] {
                return Err(anyhow!("request {i} has shape {:?}", r.shape));
            }
        }

        let t0 = std::time::Instant::now();
        let mut latency = Summary::new();
        let mut outs: Vec<Tensor> = Vec::with_capacity(n);
        let mut next = 0usize;
        for b in self.policy.plan(n) {
            // pack b images (padding by repeating the last one)
            let mut data = Vec::with_capacity(b * img_elems);
            let real = b.min(n - next);
            for &src_idx in &row_sources(next, n, b) {
                data.extend_from_slice(&requests[src_idx].data);
            }
            let batch_tensor = Tensor::new(vec![b, img, img, 3], data);
            let t = std::time::Instant::now();
            let logits = self.seq.run_batch(b, &batch_tensor)?;
            let dt = t.elapsed().as_secs_f64();
            let classes = logits.shape[1];
            for i in 0..real {
                latency.push(dt); // whole-batch latency attributed per request
                outs.push(Tensor::new(
                    vec![1, classes],
                    logits.data[i * classes..(i + 1) * classes].to_vec(),
                ));
            }
            next += real;
        }
        let report = ServeReport {
            requests: n,
            wall_s: t0.elapsed().as_secs_f64(),
            latency,
            macs_per_image: self.seq_macs(),
        };
        Ok((report, outs))
    }

    fn seq_macs(&self) -> u64 {
        self.seq.macs_per_image()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(vec![6, 1, 3])
    }

    #[test]
    fn choose_prefers_deepest_fillable() {
        let p = policy();
        assert_eq!(p.choose(10), 6);
        assert_eq!(p.choose(6), 6);
        assert_eq!(p.choose(5), 3);
        assert_eq!(p.choose(2), 1);
        assert_eq!(p.choose(1), 1);
    }

    #[test]
    fn plan_covers_queue_exactly_or_with_padding() {
        let p = policy();
        assert_eq!(p.plan(14), vec![6, 6, 1, 1]);
        assert_eq!(p.plan(7), vec![6, 1]);
        assert_eq!(p.plan(3), vec![3]);
        assert_eq!(p.plan(2), vec![1, 1]);
    }

    #[test]
    fn plan_total_geq_queue() {
        let p = BatchPolicy::new(vec![3, 6]);
        for q in 1..=20 {
            let total: usize = p.plan(q).iter().sum();
            assert!(total >= q, "q={q} plan under-covers");
            assert!(total - q < 6, "q={q} over-pads");
        }
    }

    #[test]
    fn choose_under_respects_the_latency_budget() {
        let p = policy(); // sizes [1, 3, 6]
        let service = |b: usize| b as f64 * 1e-3; // 1 ms per image
        // budget admits every size: same as choose
        assert_eq!(p.choose_under(10, 10e-3, service), Some(6));
        // budget only admits b1/b3: cap the launch depth
        assert_eq!(p.choose_under(10, 3e-3, service), Some(3));
        assert_eq!(p.choose_under(2, 3e-3, service), Some(1));
        // padding fallback still honors the budget
        assert_eq!(p.choose_under(2, 1e-3, service), Some(1));
        // nothing fits: the caller must shed/switch, not batch
        assert_eq!(p.choose_under(10, 0.5e-3, service), None);
    }

    #[test]
    fn choose_under_budget_boundary_is_inclusive() {
        let p = policy(); // sizes [1, 3, 6]
        let service = |b: usize| b as f64 * 1e-3;
        // exact equality at every compiled size is feasible (<= contract):
        // a budget of exactly service(b) admits the bN variant itself
        assert_eq!(p.choose_under(10, service(6), service), Some(6));
        assert_eq!(p.choose_under(3, service(3), service), Some(3));
        assert_eq!(p.choose_under(1, service(1), service), Some(1));
        // one ulp under the boundary excludes the size again
        let just_under = f64::from_bits(service(6).to_bits() - 1);
        assert_eq!(p.choose_under(10, just_under, service), Some(3));
    }

    #[test]
    fn choose_under_empty_feasible_set_is_none_not_fallback() {
        let p = policy();
        let service = |b: usize| b as f64 * 1e-3;
        // budget below the cheapest size: no silent fallback to choose()
        assert_eq!(p.choose_under(10, 0.0, service), None);
        assert_eq!(p.choose_under(1, 0.9e-3, service), None);
        // negative budget (caller's slack already spent) is also empty
        assert_eq!(p.choose_under(4, -1.0, service), None);
    }

    #[test]
    fn choose_under_quantile_shrinks_with_the_tail_and_unity_is_identity() {
        let p = policy(); // sizes [1, 3, 6]
        let service = |b: usize| b as f64 * 1e-3;
        // q_factor 1.0 is choose_under bit for bit
        for (q, budget) in [(10usize, 10e-3), (10, 3e-3), (2, 1e-3), (10, 0.5e-3)] {
            assert_eq!(
                p.choose_under_quantile(q, budget, 1.0, service),
                p.choose_under(q, budget, service)
            );
        }
        // a 10 ms budget admits b6 at the mean; a 2x tail factor caps the
        // launch at b3 (6 ms tail-adjusted), a 4x tail at b1, a 20x tail
        // sheds
        assert_eq!(p.choose_under_quantile(10, 10e-3, 2.0, service), Some(3));
        assert_eq!(p.choose_under_quantile(10, 10e-3, 4.0, service), Some(1));
        assert_eq!(p.choose_under_quantile(10, 10e-3, 20.0, service), None);
    }

    #[test]
    fn dedup_and_sort() {
        let p = BatchPolicy::new(vec![6, 6, 1, 3, 1]);
        assert_eq!(p.choose(4), 3);
    }

    // ---- property tests (util::prop mini-framework) ----------------------

    use crate::util::prop::{check, Config};

    /// Random compiled-size set + queue length.
    fn gen_case(r: &mut crate::util::rng::Rng) -> (Vec<usize>, usize) {
        let n_sizes = 1 + r.usize_below(4);
        let sizes: Vec<usize> = (0..n_sizes).map(|_| 1 + r.usize_below(8)).collect();
        let queued = 1 + r.usize_below(64);
        (sizes, queued)
    }

    #[test]
    fn prop_choose_is_compiled_and_covers_or_fills() {
        check(
            &Config { cases: 300, ..Default::default() },
            "choose-compiled-covers",
            gen_case,
            |(sizes, queued)| {
                let p = BatchPolicy::new(sizes.clone());
                let b = p.choose(*queued);
                let mut s = sizes.clone();
                s.sort_unstable();
                s.dedup();
                if !s.contains(&b) {
                    return Err(format!("chose uncompiled size {b}"));
                }
                let min = *s.first().unwrap();
                if *queued >= min && b > *queued {
                    return Err(format!(
                        "padded (b={b}) although queue {queued} fills size {min}"
                    ));
                }
                if *queued < min && b != min {
                    return Err(format!(
                        "tail of {queued} must take the smallest executable {min}, got {b}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_plan_covers_queue_with_bounded_padding() {
        check(
            &Config { cases: 300, ..Default::default() },
            "plan-covers-bounded",
            gen_case,
            |(sizes, queued)| {
                let p = BatchPolicy::new(sizes.clone());
                let plan = p.plan(*queued);
                let total: usize = plan.iter().sum();
                if total < *queued {
                    return Err(format!("plan {plan:?} under-covers queue {queued}"));
                }
                let max = *sizes.iter().max().unwrap();
                if total - *queued >= max {
                    return Err(format!("plan {plan:?} over-pads queue {queued}"));
                }
                // every launch must have at least one real request: the
                // partial sum before the last launch stays below the queue
                let before_last: usize = total - plan.last().unwrap();
                if before_last >= *queued {
                    return Err(format!("plan {plan:?} launches an all-padding batch"));
                }
                // the final launch covers the whole remaining tail
                if before_last + plan.last().unwrap() < *queued {
                    return Err(format!("plan {plan:?} leaves a tail"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_row_sources_identity_then_repeat_last() {
        check(
            &Config { cases: 300, ..Default::default() },
            "row-sources-padding",
            |r| {
                let n = 1 + r.usize_below(32);
                let next = r.usize_below(n);
                let b = 1 + r.usize_below(8);
                (next, n, b)
            },
            |&(next, n, b)| {
                let rows = row_sources(next, n, b);
                let real = b.min(n - next);
                if rows.len() != b {
                    return Err(format!("{} rows for batch {b}", rows.len()));
                }
                for (i, &src) in rows.iter().enumerate() {
                    let want = if i < real { next + i } else { next + real - 1 };
                    if src != want {
                        return Err(format!(
                            "row {i} sources request {src}, want {want} (real={real})"
                        ));
                    }
                    if src >= n {
                        return Err(format!("row {i} out of range: {src} >= {n}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_plan_rows_discard_exactly_the_padding() {
        // Walking a plan with row_sources reconstructs every request exactly
        // once among the real rows — padded rows never surface as outputs.
        check(
            &Config { cases: 200, ..Default::default() },
            "plan-rows-partition",
            gen_case,
            |(sizes, queued)| {
                let p = BatchPolicy::new(sizes.clone());
                let mut next = 0usize;
                let mut served = vec![0usize; *queued];
                for b in p.plan(*queued) {
                    let rows = row_sources(next, *queued, b);
                    let real = b.min(*queued - next);
                    for &src in rows.iter().take(real) {
                        served[src] += 1;
                    }
                    next += real;
                }
                if next != *queued {
                    return Err(format!("served {next} of {queued}"));
                }
                if served.iter().any(|&c| c != 1) {
                    return Err(format!("requests not served exactly once: {served:?}"));
                }
                Ok(())
            },
        );
    }
}
