//! Dynamic batcher for the sequential (monolithic-acc) server.
//!
//! The paper's GPU baseline explores latency-throughput purely by batch
//! size; the serving analog is a batcher that packs a request queue into
//! the pre-compiled `full_bN` executables: deepest batch that the queue
//! fills, padding the final partial batch (padded rows are discarded).
//! This is the "dynamic batching" half of the L3 coordinator; the
//! pipeline server covers the spatial/hybrid half.

use anyhow::{anyhow, Result};

use super::metrics::ServeReport;
use super::pipeline::SequentialServer;
use crate::runtime::exec::Tensor;
use crate::util::stats::Summary;

/// Greedy batch-size policy over the compiled batch variants.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Available `full_bN` sizes, ascending (e.g. [1, 3, 6]).
    sizes: Vec<usize>,
}

impl BatchPolicy {
    pub fn new(mut sizes: Vec<usize>) -> Self {
        assert!(!sizes.is_empty(), "need at least one batch size");
        sizes.sort_unstable();
        sizes.dedup();
        BatchPolicy { sizes }
    }

    /// Largest compiled batch the queue can fill; if the queue is smaller
    /// than every size, the smallest executable that covers it (padding).
    pub fn choose(&self, queued: usize) -> usize {
        assert!(queued > 0);
        self.sizes
            .iter()
            .rev()
            .find(|&&s| s <= queued)
            .copied()
            .unwrap_or_else(|| {
                *self
                    .sizes
                    .iter()
                    .find(|&&s| s >= queued)
                    .unwrap_or(self.sizes.last().unwrap())
            })
    }

    /// Split a queue length into concrete batch launches.
    pub fn plan(&self, mut queued: usize) -> Vec<usize> {
        let mut plan = Vec::new();
        while queued > 0 {
            let b = self.choose(queued);
            plan.push(b);
            queued = queued.saturating_sub(b);
        }
        plan
    }
}

/// Batching front-end over a [`SequentialServer`].
pub struct BatchingServer {
    seq: SequentialServer,
    policy: BatchPolicy,
}

impl BatchingServer {
    pub fn new(seq: SequentialServer) -> Self {
        let policy = BatchPolicy::new(seq.batch_sizes());
        BatchingServer { seq, policy }
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// Serve single-image requests (`[1, H, W, 3]` each): pack into the
    /// deepest available batches, pad the tail, unpack logits per request.
    pub fn serve(&self, requests: &[Tensor]) -> Result<(ServeReport, Vec<Tensor>)> {
        let n = requests.len();
        if n == 0 {
            return Err(anyhow!("empty request set"));
        }
        let img = self.seq.img_size();
        let img_elems = img * img * 3;
        for (i, r) in requests.iter().enumerate() {
            if r.shape != vec![1, img, img, 3] {
                return Err(anyhow!("request {i} has shape {:?}", r.shape));
            }
        }

        let t0 = std::time::Instant::now();
        let mut latency = Summary::new();
        let mut outs: Vec<Tensor> = Vec::with_capacity(n);
        let mut next = 0usize;
        for b in self.policy.plan(n) {
            // pack b images (padding by repeating the last one)
            let mut data = Vec::with_capacity(b * img_elems);
            let real = b.min(n - next);
            for i in 0..b {
                let src = &requests[next + i.min(real - 1)];
                data.extend_from_slice(&src.data);
            }
            let batch_tensor = Tensor::new(vec![b, img, img, 3], data);
            let t = std::time::Instant::now();
            let logits = self.seq.run_batch(b, &batch_tensor)?;
            let dt = t.elapsed().as_secs_f64();
            let classes = logits.shape[1];
            for i in 0..real {
                latency.push(dt); // whole-batch latency attributed per request
                outs.push(Tensor::new(
                    vec![1, classes],
                    logits.data[i * classes..(i + 1) * classes].to_vec(),
                ));
            }
            next += real;
        }
        let report = ServeReport {
            requests: n,
            wall_s: t0.elapsed().as_secs_f64(),
            latency,
            macs_per_image: self.seq_macs(),
        };
        Ok((report, outs))
    }

    fn seq_macs(&self) -> u64 {
        self.seq.macs_per_image()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(vec![6, 1, 3])
    }

    #[test]
    fn choose_prefers_deepest_fillable() {
        let p = policy();
        assert_eq!(p.choose(10), 6);
        assert_eq!(p.choose(6), 6);
        assert_eq!(p.choose(5), 3);
        assert_eq!(p.choose(2), 1);
        assert_eq!(p.choose(1), 1);
    }

    #[test]
    fn plan_covers_queue_exactly_or_with_padding() {
        let p = policy();
        assert_eq!(p.plan(14), vec![6, 6, 1, 1]);
        assert_eq!(p.plan(7), vec![6, 1]);
        assert_eq!(p.plan(3), vec![3]);
        assert_eq!(p.plan(2), vec![1, 1]);
    }

    #[test]
    fn plan_total_geq_queue() {
        let p = BatchPolicy::new(vec![3, 6]);
        for q in 1..=20 {
            let total: usize = p.plan(q).iter().sum();
            assert!(total >= q, "q={q} plan under-covers");
            assert!(total - q < 6, "q={q} over-pads");
        }
    }

    #[test]
    fn dedup_and_sort() {
        let p = BatchPolicy::new(vec![6, 6, 1, 3, 1]);
        assert_eq!(p.choose(4), 3);
    }
}
