//! ExecutionPlan IR — the single mapping representation shared by search,
//! simulation, and live serving.
//!
//! The DSE emits 8-class [`Assignment`] genomes (`LayerClass` → accelerator),
//! but before this module existed only a lossy majority-vote projection onto
//! four hardcoded runtime stages was servable: most hybrid points the EA
//! finds (SSR Sec. 4.4, Fig. 1c) were analytical-only. An [`ExecutionPlan`]
//! materializes, for a concrete `Graph` + `Assignment` (+ micro-batch
//! variant):
//!
//! * **per-accelerator step schedules** ([`PlanStep`]) at full `LayerClass`
//!   granularity — one step per MM node instance (embed, then per block
//!   qkv → bmm0 → bmm1 → proj → fc1 → fc2, then head);
//! * **inter-accelerator forwarding edges** ([`ForwardEdge`]) — the data
//!   dependencies between steps, flagged when they cross accelerators (the
//!   on-chip PLIO forwarding paths of the paper);
//! * **stage-executable requirements** ([`StageReq`]) — exactly which
//!   compiled artifacts (`{model}_{unit}_b{N}`) the runtime must load.
//!
//! The three consumers all flow through it:
//!
//! ```text
//!   dse::eval::build_design ──► Evaluated { plan, .. }
//!                                  │
//!            ┌─────────────────────┼──────────────────────┐
//!            ▼                     ▼                      ▼
//!   Evaluated::evaluate     sim::simulate_plan    PipelineServer::from_plan
//!   (analytical estimate)   (event-driven board   (live PJRT serving, any
//!                            substitute)           nacc ∈ 1..=8)
//! ```
//!
//! When the artifact manifest only contains the four fused stage
//! executables (embed/attn/mlp/head), [`ExecutionPlan::coarsen`] projects a
//! class-granular plan down to them and returns a [`CoarsenReport`] naming
//! every accelerator separation the projection destroyed — the projection
//! is a compatibility shim now, never a silent default.

pub mod front;

pub use front::{FrontEntry, PlanFront};

use std::path::Path;

use crate::dse::Assignment;
use crate::graph::{Graph, LayerClass, ALL_CLASSES};
use crate::util::json::Json;

/// Execution granularity of a plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One step per `LayerClass` node — serves any `nacc` in `1..=8`.
    Class,
    /// Coarsened to the four fused runtime stages (embed/attn/mlp/head).
    Fused,
}

impl Granularity {
    /// Serialized name (`granularity` field of a plan artifact).
    pub fn name(self) -> &'static str {
        match self {
            Granularity::Class => "class",
            Granularity::Fused => "fused",
        }
    }

    pub fn parse(s: &str) -> Option<Granularity> {
        match s {
            "class" => Some(Granularity::Class),
            "fused" => Some(Granularity::Fused),
            _ => None,
        }
    }
}

/// The executable unit a plan step runs. Class units map 1:1 onto
/// `LayerClass`; `Attn`/`Mlp` are the fused 4-stage units the compatibility
/// shim coarsens to. `name()` matches the manifest `stage` field.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum StageUnit {
    Embed,
    Qkv,
    Bmm0,
    Bmm1,
    Proj,
    Fc1,
    Fc2,
    Head,
    /// Fused attention sublayer (qkv + bmm0 + bmm1 + proj).
    Attn,
    /// Fused MLP sublayer (fc1 + fc2).
    Mlp,
}

impl StageUnit {
    /// Manifest stage name (`{model}_{name}_b{N}` executables).
    pub fn name(self) -> &'static str {
        match self {
            StageUnit::Embed => "embed",
            StageUnit::Qkv => "qkv",
            StageUnit::Bmm0 => "bmm0",
            StageUnit::Bmm1 => "bmm1",
            StageUnit::Proj => "proj",
            StageUnit::Fc1 => "fc1",
            StageUnit::Fc2 => "fc2",
            StageUnit::Head => "head",
            StageUnit::Attn => "attn",
            StageUnit::Mlp => "mlp",
        }
    }

    /// The class-granular unit executing `class`.
    pub fn of_class(class: LayerClass) -> StageUnit {
        match class {
            LayerClass::Embed => StageUnit::Embed,
            LayerClass::Qkv => StageUnit::Qkv,
            LayerClass::Bmm0 => StageUnit::Bmm0,
            LayerClass::Bmm1 => StageUnit::Bmm1,
            LayerClass::Proj => StageUnit::Proj,
            LayerClass::Fc1 => StageUnit::Fc1,
            LayerClass::Fc2 => StageUnit::Fc2,
            LayerClass::Head => StageUnit::Head,
        }
    }

    /// The fused 4-stage unit that covers `class`.
    pub fn fused_of_class(class: LayerClass) -> StageUnit {
        match class {
            LayerClass::Embed => StageUnit::Embed,
            LayerClass::Qkv | LayerClass::Bmm0 | LayerClass::Bmm1 | LayerClass::Proj => {
                StageUnit::Attn
            }
            LayerClass::Fc1 | LayerClass::Fc2 => StageUnit::Mlp,
            LayerClass::Head => StageUnit::Head,
        }
    }

    /// Layer classes this unit executes.
    pub fn classes(self) -> &'static [LayerClass] {
        match self {
            StageUnit::Embed => &[LayerClass::Embed],
            StageUnit::Qkv => &[LayerClass::Qkv],
            StageUnit::Bmm0 => &[LayerClass::Bmm0],
            StageUnit::Bmm1 => &[LayerClass::Bmm1],
            StageUnit::Proj => &[LayerClass::Proj],
            StageUnit::Fc1 => &[LayerClass::Fc1],
            StageUnit::Fc2 => &[LayerClass::Fc2],
            StageUnit::Head => &[LayerClass::Head],
            StageUnit::Attn => &[
                LayerClass::Qkv,
                LayerClass::Bmm0,
                LayerClass::Bmm1,
                LayerClass::Proj,
            ],
            StageUnit::Mlp => &[LayerClass::Fc1, LayerClass::Fc2],
        }
    }

    pub fn is_fused(self) -> bool {
        matches!(self, StageUnit::Attn | StageUnit::Mlp)
    }

    /// Inverse of [`StageUnit::name`] (plan deserialization).
    pub fn parse(s: &str) -> Option<StageUnit> {
        match s {
            "embed" => Some(StageUnit::Embed),
            "qkv" => Some(StageUnit::Qkv),
            "bmm0" => Some(StageUnit::Bmm0),
            "bmm1" => Some(StageUnit::Bmm1),
            "proj" => Some(StageUnit::Proj),
            "fc1" => Some(StageUnit::Fc1),
            "fc2" => Some(StageUnit::Fc2),
            "head" => Some(StageUnit::Head),
            "attn" => Some(StageUnit::Attn),
            "mlp" => Some(StageUnit::Mlp),
            _ => None,
        }
    }
}

/// One step of the per-image schedule: run `unit` (with `block`'s weights
/// where applicable) on accelerator `acc`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanStep {
    pub unit: StageUnit,
    /// Transformer block index for per-block units; None for embed/head.
    pub block: Option<usize>,
    /// Accelerator (worker) executing this step.
    pub acc: usize,
    /// Graph node id this step covers (None for fused units, which cover
    /// several nodes).
    pub node: Option<usize>,
}

/// A data dependency between two plan steps (producer → consumer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ForwardEdge {
    pub from: usize,
    pub to: usize,
    /// Producer output bytes (0 when built without a `Graph`).
    pub bytes: u64,
    /// Whether the edge crosses accelerators (an inter-acc forwarding path).
    pub cross_acc: bool,
}

/// One stage executable the runtime must compile to serve a plan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageReq {
    pub unit: StageUnit,
    /// Manifest executable name, e.g. `deit_t_qkv_b1`.
    pub exe_name: String,
}

/// A class whose DSE accelerator was dropped by 4-stage coarsening.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClassMerge {
    pub class: LayerClass,
    /// The fused unit the class was folded into.
    pub unit: StageUnit,
    /// Accelerator the DSE assignment placed the class on (pre-densify id).
    pub from_acc: usize,
    /// Accelerator the fused unit runs on (pre-densify id).
    pub into_acc: usize,
}

/// What 4-stage coarsening lost, if anything. Returned instead of dropping
/// the information on the floor.
#[derive(Clone, Debug, Default)]
pub struct CoarsenReport {
    pub merges: Vec<ClassMerge>,
    pub nacc_before: usize,
    pub nacc_after: usize,
}

impl CoarsenReport {
    /// True when the 4-stage projection represents the assignment exactly.
    pub fn is_lossless(&self) -> bool {
        self.merges.is_empty() && self.nacc_before == self.nacc_after
    }

    /// Human-readable account of the lost separations.
    pub fn describe(&self) -> String {
        if self.is_lossless() {
            return "lossless (assignment is 4-stage representable)".to_string();
        }
        let moved: Vec<String> = self
            .merges
            .iter()
            .map(|m| {
                format!(
                    "{:?}: acc{} -> acc{} ({})",
                    m.class,
                    m.from_acc,
                    m.into_acc,
                    m.unit.name()
                )
            })
            .collect();
        format!(
            "lossy: {} -> {} accs, merged [{}]",
            self.nacc_before,
            self.nacc_after,
            moved.join(", ")
        )
    }
}

/// Expand a 4-stage grouping (embed/attn/mlp/head accs) back to the exact
/// 8-class assignment it serves — the inverse direction of
/// [`project_stage4`] (lossless by construction).
pub fn expand_stage4(accs: [usize; 4]) -> Assignment {
    Assignment::new(
        ALL_CLASSES
            .iter()
            .map(|&c| {
                let stage = match StageUnit::fused_of_class(c) {
                    StageUnit::Embed => 0,
                    StageUnit::Attn => 1,
                    StageUnit::Mlp => 2,
                    _ => 3,
                };
                accs[stage]
            })
            .collect(),
    )
}

/// Project an 8-class assignment onto the four runtime stages
/// (embed/attn/mlp/head order): each stage goes to the acc hosting the
/// majority of its classes (ties to the lowest acc id), then acc ids are
/// re-densified. Returns the projection together with a [`CoarsenReport`]
/// naming every class whose DSE placement the projection dropped.
pub fn project_stage4(a: &Assignment) -> ([usize; 4], CoarsenReport) {
    let stage_units = [StageUnit::Embed, StageUnit::Attn, StageUnit::Mlp, StageUnit::Head];
    let mut acc_of = [0usize; 4];
    let mut merges = Vec::new();
    for (i, unit) in stage_units.iter().enumerate() {
        let mut counts = std::collections::BTreeMap::new();
        for &c in unit.classes() {
            *counts.entry(a.acc_of(c)).or_insert(0usize) += 1;
        }
        let chosen = *counts
            .iter()
            .max_by_key(|(acc, n)| (**n, usize::MAX - **acc))
            .map(|(acc, _)| acc)
            .unwrap();
        acc_of[i] = chosen;
        for &c in unit.classes() {
            if a.acc_of(c) != chosen {
                merges.push(ClassMerge {
                    class: c,
                    unit: *unit,
                    from_acc: a.acc_of(c),
                    into_acc: chosen,
                });
            }
        }
    }
    // densify acc ids in order of first appearance
    let mut seen: Vec<usize> = Vec::new();
    for acc in acc_of.iter_mut() {
        if let Some(pos) = seen.iter().position(|s| s == acc) {
            *acc = pos;
        } else {
            seen.push(*acc);
            *acc = seen.len() - 1;
        }
    }
    let report = CoarsenReport {
        merges,
        nacc_before: a.nacc(),
        nacc_after: seen.len(),
    };
    (acc_of, report)
}

/// The materialized execution plan for one design point.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExecutionPlan {
    pub model: String,
    pub depth: usize,
    /// Images per step invocation (the runtime micro-batch / `bN` variant).
    pub micro_batch: usize,
    pub granularity: Granularity,
    /// The 8-class assignment this plan realizes (for a fused plan, the
    /// coarsened assignment actually being served).
    pub assignment: Assignment,
    pub nacc: usize,
    /// Per-image step schedule in dependency (topological) order.
    pub steps: Vec<PlanStep>,
    /// Data dependencies between steps (producer index < consumer index).
    pub edges: Vec<ForwardEdge>,
}

impl ExecutionPlan {
    /// Materialize a class-granular plan from an application graph and a
    /// DSE assignment. One step per graph node, edges from node deps.
    pub fn from_graph(graph: &Graph, assignment: &Assignment, micro_batch: usize) -> ExecutionPlan {
        let steps: Vec<PlanStep> = graph
            .nodes
            .iter()
            .map(|n| PlanStep {
                unit: StageUnit::of_class(n.class),
                block: match n.class {
                    LayerClass::Embed | LayerClass::Head => None,
                    _ => Some(n.block),
                },
                acc: assignment.acc_of(n.class),
                node: Some(n.id),
            })
            .collect();
        let mut edges = Vec::new();
        for (to, n) in graph.nodes.iter().enumerate() {
            for &d in &n.deps {
                edges.push(ForwardEdge {
                    from: d,
                    to,
                    bytes: graph.nodes[d].out_bytes,
                    cross_acc: steps[d].acc != steps[to].acc,
                });
            }
        }
        ExecutionPlan {
            model: graph.model.clone(),
            depth: graph.depth,
            micro_batch,
            granularity: Granularity::Class,
            assignment: assignment.clone(),
            nacc: assignment.nacc(),
            steps,
            edges,
        }
    }

    /// Materialize a class-granular plan from model metadata alone (the
    /// serving path, where no `Graph` is in scope): the canonical ViT chain
    /// embed → (qkv bmm0 bmm1 proj fc1 fc2) × depth → head. Node ids follow
    /// the same numbering `graph::vit_graph` uses.
    pub fn from_depth(
        model: &str,
        depth: usize,
        assignment: &Assignment,
        micro_batch: usize,
    ) -> ExecutionPlan {
        const BLOCK_CLASSES: [LayerClass; 6] = [
            LayerClass::Qkv,
            LayerClass::Bmm0,
            LayerClass::Bmm1,
            LayerClass::Proj,
            LayerClass::Fc1,
            LayerClass::Fc2,
        ];
        let mut steps = Vec::with_capacity(2 + 6 * depth);
        steps.push(PlanStep {
            unit: StageUnit::Embed,
            block: None,
            acc: assignment.acc_of(LayerClass::Embed),
            node: Some(0),
        });
        for b in 0..depth {
            for c in BLOCK_CLASSES {
                steps.push(PlanStep {
                    unit: StageUnit::of_class(c),
                    block: Some(b),
                    acc: assignment.acc_of(c),
                    node: Some(steps.len()),
                });
            }
        }
        steps.push(PlanStep {
            unit: StageUnit::Head,
            block: None,
            acc: assignment.acc_of(LayerClass::Head),
            node: Some(steps.len()),
        });
        let edges = chain_edges(&steps);
        ExecutionPlan {
            model: model.to_string(),
            depth,
            micro_batch,
            granularity: Granularity::Class,
            assignment: assignment.clone(),
            nacc: assignment.nacc(),
            steps,
            edges,
        }
    }

    /// Materialize a fused (4-stage) plan directly from a stage grouping
    /// (`accs` in embed/attn/mlp/head order). `assignment` records the
    /// 8-class view of the grouping being served.
    pub fn fused(
        model: &str,
        depth: usize,
        micro_batch: usize,
        accs: [usize; 4],
        assignment: Assignment,
    ) -> ExecutionPlan {
        let mut steps = Vec::with_capacity(2 + 2 * depth);
        steps.push(PlanStep { unit: StageUnit::Embed, block: None, acc: accs[0], node: None });
        for b in 0..depth {
            steps.push(PlanStep {
                unit: StageUnit::Attn,
                block: Some(b),
                acc: accs[1],
                node: None,
            });
            steps.push(PlanStep { unit: StageUnit::Mlp, block: Some(b), acc: accs[2], node: None });
        }
        steps.push(PlanStep { unit: StageUnit::Head, block: None, acc: accs[3], node: None });
        let edges = chain_edges(&steps);
        let nacc = accs.iter().copied().max().unwrap() + 1;
        ExecutionPlan {
            model: model.to_string(),
            depth,
            micro_batch,
            granularity: Granularity::Fused,
            assignment,
            nacc,
            steps,
            edges,
        }
    }

    /// Project a class-granular plan down to the four fused runtime stages
    /// (the compatibility shim for manifests that only carry
    /// embed/attn/mlp/head executables). Returns the coarse plan and the
    /// report of what the projection lost.
    pub fn coarsen(&self) -> (ExecutionPlan, CoarsenReport) {
        let (accs, report) = project_stage4(&self.assignment);
        let plan = ExecutionPlan::fused(
            &self.model,
            self.depth,
            self.micro_batch,
            accs,
            expand_stage4(accs),
        );
        (plan, report)
    }

    /// Same plan at a different runtime micro-batch.
    pub fn with_micro_batch(mut self, micro_batch: usize) -> ExecutionPlan {
        self.micro_batch = micro_batch;
        self
    }

    /// Distinct stage units the plan schedules, in first-use order.
    pub fn required_units(&self) -> Vec<StageUnit> {
        let mut units = Vec::new();
        for s in &self.steps {
            if !units.contains(&s.unit) {
                units.push(s.unit);
            }
        }
        units
    }

    /// Stage executables the runtime must compile to serve this plan.
    pub fn requirements(&self) -> Vec<StageReq> {
        self.required_units()
            .into_iter()
            .map(|unit| StageReq {
                unit,
                exe_name: format!("{}_{}_b{}", self.model, unit.name(), self.micro_batch),
            })
            .collect()
    }

    /// Stage units scheduled on accelerator `acc`, in first-use order.
    pub fn units_on(&self, acc: usize) -> Vec<StageUnit> {
        let mut units = Vec::new();
        for s in self.steps.iter().filter(|s| s.acc == acc) {
            if !units.contains(&s.unit) {
                units.push(s.unit);
            }
        }
        units
    }

    /// Number of inter-accelerator forwarding edges per image.
    pub fn cross_acc_edges(&self) -> usize {
        self.edges.iter().filter(|e| e.cross_acc).count()
    }

    /// Structural invariants: dense acc ids, topological edges, chain ends.
    pub fn validate(&self) -> Result<(), String> {
        if self.steps.is_empty() {
            return Err("empty plan".into());
        }
        let mut used = vec![false; self.nacc];
        for (i, s) in self.steps.iter().enumerate() {
            if s.acc >= self.nacc {
                return Err(format!("step {i} acc {} >= nacc {}", s.acc, self.nacc));
            }
            used[s.acc] = true;
            if s.unit.is_fused() != (self.granularity == Granularity::Fused) {
                return Err(format!("step {i} unit {:?} vs granularity", s.unit));
            }
        }
        if !used.iter().all(|&u| u) {
            return Err("acc ids not dense".into());
        }
        for e in &self.edges {
            if e.from >= e.to || e.to >= self.steps.len() {
                return Err(format!("edge {} -> {} not topological", e.from, e.to));
            }
            if e.cross_acc != (self.steps[e.from].acc != self.steps[e.to].acc) {
                return Err(format!("edge {} -> {} cross_acc flag wrong", e.from, e.to));
            }
        }
        if self.steps.first().unwrap().unit != StageUnit::Embed
            || self.steps.last().unwrap().unit != StageUnit::Head
        {
            return Err("plan must start at embed and end at head".into());
        }
        Ok(())
    }

    /// One-paragraph human summary (CLI / logs).
    pub fn summary(&self) -> String {
        let per_acc: Vec<String> = (0..self.nacc)
            .map(|a| {
                let units: Vec<&str> =
                    self.units_on(a).into_iter().map(|u| u.name()).collect();
                format!("acc{a}:{{{}}}", units.join(","))
            })
            .collect();
        format!(
            "{} plan for {} (depth {}, micro-batch {}): {} accs [{}], {} steps, {} fwd edges ({} cross-acc)",
            match self.granularity {
                Granularity::Class => "class-granular",
                Granularity::Fused => "4-stage fused",
            },
            self.model,
            self.depth,
            self.micro_batch,
            self.nacc,
            per_acc.join(" "),
            self.steps.len(),
            self.edges.len(),
            self.cross_acc_edges(),
        )
    }

    /// Serialize as the plan artifact JSON (deterministic key order via
    /// `BTreeMap`, like every other artifact).
    pub fn to_json(&self) -> Json {
        let steps: Vec<Json> = self
            .steps
            .iter()
            .map(|s| {
                let mut m = std::collections::BTreeMap::new();
                m.insert("unit".to_string(), Json::Str(s.unit.name().to_string()));
                m.insert(
                    "block".to_string(),
                    s.block.map_or(Json::Null, |b| Json::Num(b as f64)),
                );
                m.insert("acc".to_string(), Json::Num(s.acc as f64));
                m.insert(
                    "node".to_string(),
                    s.node.map_or(Json::Null, |n| Json::Num(n as f64)),
                );
                Json::Obj(m)
            })
            .collect();
        let edges: Vec<Json> = self
            .edges
            .iter()
            .map(|e| {
                let mut m = std::collections::BTreeMap::new();
                m.insert("from".to_string(), Json::Num(e.from as f64));
                m.insert("to".to_string(), Json::Num(e.to as f64));
                m.insert("bytes".to_string(), Json::Num(e.bytes as f64));
                m.insert("cross_acc".to_string(), Json::Bool(e.cross_acc));
                Json::Obj(m)
            })
            .collect();
        let assignment: Vec<Json> = ALL_CLASSES
            .iter()
            .map(|&c| Json::Num(self.assignment.acc_of(c) as f64))
            .collect();
        let mut m = std::collections::BTreeMap::new();
        m.insert("model".to_string(), Json::Str(self.model.clone()));
        m.insert("depth".to_string(), Json::Num(self.depth as f64));
        m.insert("micro_batch".to_string(), Json::Num(self.micro_batch as f64));
        m.insert(
            "granularity".to_string(),
            Json::Str(self.granularity.name().to_string()),
        );
        m.insert("assignment".to_string(), Json::Arr(assignment));
        m.insert("nacc".to_string(), Json::Num(self.nacc as f64));
        m.insert("steps".to_string(), Json::Arr(steps));
        m.insert("edges".to_string(), Json::Arr(edges));
        Json::Obj(m)
    }

    /// Deserialize a plan artifact; runs [`ExecutionPlan::validate`] so a
    /// structurally broken plan never reaches a consumer.
    pub fn from_json(j: &Json) -> Result<ExecutionPlan, String> {
        let model = j
            .get("model")
            .and_then(Json::as_str)
            .ok_or("plan missing 'model'")?
            .to_string();
        let depth = j.get("depth").and_then(Json::as_usize).ok_or("plan missing 'depth'")?;
        let micro_batch = j
            .get("micro_batch")
            .and_then(Json::as_usize)
            .ok_or("plan missing 'micro_batch'")?;
        let granularity = j
            .get("granularity")
            .and_then(Json::as_str)
            .and_then(Granularity::parse)
            .ok_or("plan missing or bad 'granularity'")?;
        let acc_of: Vec<usize> = j
            .get("assignment")
            .and_then(Json::as_arr)
            .ok_or("plan missing 'assignment'")?
            .iter()
            .map(|x| x.as_usize().ok_or("bad assignment acc id"))
            .collect::<Result<_, _>>()?;
        if acc_of.len() != ALL_CLASSES.len() {
            return Err(format!("assignment has {} classes, expected 8", acc_of.len()));
        }
        let nacc = j.get("nacc").and_then(Json::as_usize).ok_or("plan missing 'nacc'")?;
        let mut steps = Vec::new();
        for (i, s) in j
            .get("steps")
            .and_then(Json::as_arr)
            .ok_or("plan missing 'steps'")?
            .iter()
            .enumerate()
        {
            steps.push(PlanStep {
                unit: s
                    .get("unit")
                    .and_then(Json::as_str)
                    .and_then(StageUnit::parse)
                    .ok_or_else(|| format!("step {i} missing or bad 'unit'"))?,
                block: s.get("block").and_then(Json::as_usize),
                acc: s
                    .get("acc")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| format!("step {i} missing 'acc'"))?,
                node: s.get("node").and_then(Json::as_usize),
            });
        }
        let mut edges = Vec::new();
        for (i, e) in j
            .get("edges")
            .and_then(Json::as_arr)
            .ok_or("plan missing 'edges'")?
            .iter()
            .enumerate()
        {
            edges.push(ForwardEdge {
                from: e
                    .get("from")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| format!("edge {i} missing 'from'"))?,
                to: e
                    .get("to")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| format!("edge {i} missing 'to'"))?,
                bytes: e
                    .get("bytes")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("edge {i} missing 'bytes'"))? as u64,
                cross_acc: e
                    .get("cross_acc")
                    .and_then(Json::as_bool)
                    .ok_or_else(|| format!("edge {i} missing 'cross_acc'"))?,
            });
        }
        let plan = ExecutionPlan {
            model,
            depth,
            micro_batch,
            granularity,
            assignment: Assignment::new(acc_of),
            nacc,
            steps,
            edges,
        };
        plan.validate()?;
        Ok(plan)
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string() + "\n")
    }

    pub fn load(path: &Path) -> Result<ExecutionPlan, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        ExecutionPlan::from_json(&Json::parse(&text)?)
    }
}

/// Chain edges (step i-1 → step i) for single-stream plans.
fn chain_edges(steps: &[PlanStep]) -> Vec<ForwardEdge> {
    (1..steps.len())
        .map(|i| ForwardEdge {
            from: i - 1,
            to: i,
            bytes: 0,
            cross_acc: steps[i - 1].acc != steps[i].acc,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{vit_graph, DEIT_T};

    /// An 8-class hybrid with attention split across two accs (nacc = 5) —
    /// the kind of EA output the 4-stage projection cannot represent.
    fn hybrid5() -> Assignment {
        Assignment::new(vec![0, 1, 2, 2, 1, 3, 4, 0])
    }

    #[test]
    fn from_depth_matches_graph_shape() {
        let g = vit_graph(&DEIT_T);
        let a = Assignment::spatial();
        let pd = ExecutionPlan::from_depth("deit_t", 12, &a, 1);
        let pg = ExecutionPlan::from_graph(&g, &a, 1);
        assert_eq!(pd.steps.len(), g.nodes.len());
        assert_eq!(pd.steps.len(), pg.steps.len());
        for (s, t) in pd.steps.iter().zip(&pg.steps) {
            assert_eq!(s.unit, t.unit);
            assert_eq!(s.block, t.block);
            assert_eq!(s.acc, t.acc);
            assert_eq!(s.node, t.node);
        }
        assert_eq!(pd.edges.len(), pg.edges.len());
        pd.validate().unwrap();
        pg.validate().unwrap();
    }

    #[test]
    fn plan_preserves_full_hybrid_granularity() {
        let a = hybrid5();
        assert_eq!(a.nacc(), 5);
        let p = ExecutionPlan::from_depth("deit_t", 12, &a, 1);
        assert_eq!(p.nacc, 5, "plan must keep all 5 accs");
        // attention classes land on their own accs, not one fused stage
        let qkv = p.steps.iter().find(|s| s.unit == StageUnit::Qkv).unwrap();
        let bmm0 = p.steps.iter().find(|s| s.unit == StageUnit::Bmm0).unwrap();
        assert_ne!(qkv.acc, bmm0.acc);
        p.validate().unwrap();
    }

    #[test]
    fn stage4_projection_cannot_represent_hybrid5() {
        // The acceptance-criterion witness: the old 4-stage path collapses
        // the attention split, the plan does not.
        let a = hybrid5();
        let (accs, report) = project_stage4(&a);
        let nacc_proj = accs.iter().copied().max().unwrap() + 1;
        assert!(nacc_proj < a.nacc(), "projection must lose accs: {accs:?}");
        assert!(!report.is_lossless());
        assert!(report.merges.iter().any(|m| m.class.is_attention()));
        assert_eq!(report.nacc_before, 5);
        assert!(report.describe().contains("lossy"));
    }

    #[test]
    fn projection_lossless_for_stage_aligned_assignment() {
        // embed | attn | mlp | head on four separate accs — exactly 4-stage
        // representable, so coarsening must report lossless.
        let a = Assignment::new(vec![0, 1, 1, 1, 1, 2, 2, 3]);
        let (accs, report) = project_stage4(&a);
        assert_eq!(accs, [0, 1, 2, 3]);
        assert!(report.is_lossless(), "{}", report.describe());
    }

    #[test]
    fn coarsen_produces_valid_fused_plan() {
        let p = ExecutionPlan::from_depth("deit_t", 12, &hybrid5(), 1);
        let (coarse, report) = p.coarsen();
        assert_eq!(coarse.granularity, Granularity::Fused);
        assert_eq!(coarse.steps.len(), 2 + 2 * 12);
        assert!(coarse.nacc <= 4);
        assert_eq!(coarse.nacc, report.nacc_after);
        coarse.validate().unwrap();
    }

    #[test]
    fn sequential_plan_has_no_cross_acc_edges() {
        let p = ExecutionPlan::from_depth("deit_t", 12, &Assignment::sequential(), 1);
        assert_eq!(p.nacc, 1);
        assert_eq!(p.cross_acc_edges(), 0);
    }

    #[test]
    fn spatial_plan_crosses_on_every_class_boundary() {
        let g = vit_graph(&DEIT_T);
        let p = ExecutionPlan::from_graph(&g, &Assignment::spatial(), 1);
        assert_eq!(p.nacc, 8);
        // chain of 74 nodes, every consecutive pair on different accs
        assert_eq!(p.cross_acc_edges(), p.edges.len());
        assert!(p.edges.iter().all(|e| e.bytes > 0));
    }

    #[test]
    fn requirements_name_the_manifest_executables() {
        let p = ExecutionPlan::from_depth("deit_t", 12, &Assignment::spatial(), 6);
        let names: Vec<String> = p.requirements().into_iter().map(|r| r.exe_name).collect();
        assert_eq!(names.len(), 8);
        assert!(names.contains(&"deit_t_qkv_b6".to_string()));
        assert!(names.contains(&"deit_t_bmm0_b6".to_string()));
        let (coarse, _) = p.coarsen();
        let cnames: Vec<String> =
            coarse.requirements().into_iter().map(|r| r.exe_name).collect();
        assert_eq!(cnames.len(), 4);
        assert!(cnames.contains(&"deit_t_attn_b6".to_string()));
    }

    #[test]
    fn units_on_partitions_the_schedule() {
        let p = ExecutionPlan::from_depth("deit_t", 12, &hybrid5(), 1);
        let total: usize = (0..p.nacc).map(|a| p.units_on(a).len()).sum();
        assert_eq!(total, 8);
        assert!(p.summary().contains("5 accs"));
    }

    #[test]
    fn json_round_trip_is_exact() {
        let g = vit_graph(&DEIT_T);
        for plan in [
            ExecutionPlan::from_graph(&g, &hybrid5(), 6),
            ExecutionPlan::from_depth("deit_t", 12, &Assignment::spatial(), 1),
            ExecutionPlan::from_depth("deit_t", 12, &hybrid5(), 6).coarsen().0,
        ] {
            let back = ExecutionPlan::from_json(&plan.to_json()).unwrap();
            assert_eq!(back, plan);
        }
    }

    #[test]
    fn from_json_rejects_structural_breakage() {
        let p = ExecutionPlan::from_depth("deit_t", 2, &hybrid5(), 1);
        let mut j = p.to_json();
        // Reverse an edge: from >= to is a forwarding cycle.
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Arr(edges)) = m.get_mut("edges") {
                if let Json::Obj(e) = &mut edges[3] {
                    e.insert("to".to_string(), Json::Num(0.0));
                }
            }
        }
        let err = ExecutionPlan::from_json(&j).unwrap_err();
        assert!(err.contains("not topological"), "{err}");
    }

    #[test]
    fn stage_unit_parse_inverts_name() {
        for unit in [
            StageUnit::Embed,
            StageUnit::Qkv,
            StageUnit::Bmm0,
            StageUnit::Bmm1,
            StageUnit::Proj,
            StageUnit::Fc1,
            StageUnit::Fc2,
            StageUnit::Head,
            StageUnit::Attn,
            StageUnit::Mlp,
        ] {
            assert_eq!(StageUnit::parse(unit.name()), Some(unit));
        }
        assert_eq!(StageUnit::parse("conv"), None);
        assert_eq!(Granularity::parse("class"), Some(Granularity::Class));
        assert_eq!(Granularity::parse("fused"), Some(Granularity::Fused));
        assert_eq!(Granularity::parse("mixed"), None);
    }

    #[test]
    fn with_micro_batch_renames_requirements() {
        let p = ExecutionPlan::from_depth("deit_t", 12, &Assignment::sequential(), 1)
            .with_micro_batch(6);
        assert!(p.requirements().iter().all(|r| r.exe_name.ends_with("_b6")));
    }
}
