//! Serialized Pareto front of execution plans — the artifact the DSE hands
//! to the serving layer.
//!
//! The paper's Table 6 picks one design ("highest throughput under a
//! latency constraint") ahead of time; the adaptive scheduler instead keeps
//! the whole latency-throughput front live and chooses against the observed
//! load (see [`crate::coordinator::scheduler`]). A [`PlanFront`] is the
//! interchange format between the two sides:
//!
//! ```text
//!   ssr dse --emit-front front.json       # search → pruned front on disk
//!   ssr simulate --front front.json ...   # deterministic scheduler replay
//!   ssr serve    --front front.json ...   # live PJRT serving of the front
//! ```
//!
//! Each [`FrontEntry`] carries the 8-class assignment genome plus the
//! analytical metrics the scheduler selects on, so any entry can be
//! re-materialized into an [`ExecutionPlan`] without re-running the search.

use std::path::Path;

use crate::dse::pareto::{pareto_indices, Point};
use crate::dse::Assignment;
use crate::graph::ALL_CLASSES;
use crate::plan::ExecutionPlan;
use crate::util::json::Json;

/// One design point of the front: a servable plan plus its metrics.
#[derive(Clone, Debug, PartialEq)]
pub struct FrontEntry {
    /// 8-class Layer→Acc genome (same encoding as `ssr serve --assign`).
    pub assign: Vec<usize>,
    /// Batch size the metrics were evaluated at (also the plan micro-batch).
    pub batch: usize,
    pub latency_ms: f64,
    pub tops: f64,
    /// Sustainable service rate (images/s) under back-to-back launches.
    pub rps: f64,
    pub nacc: usize,
    /// Provenance tag ("sequential", "spatial", "ea", ...).
    pub label: String,
}

impl FrontEntry {
    pub fn from_eval(label: &str, assignment: &Assignment, e: &crate::dse::Eval) -> FrontEntry {
        FrontEntry {
            assign: assignment.acc_of.clone(),
            batch: e.batch,
            latency_ms: e.latency_s * 1e3,
            tops: e.tops,
            rps: e.imgs_per_s(),
            nacc: assignment.nacc(),
            label: label.to_string(),
        }
    }

    pub fn latency_s(&self) -> f64 {
        self.latency_ms * 1e-3
    }

    pub fn assignment(&self) -> Assignment {
        Assignment::new(self.assign.clone())
    }

    /// Materialize the class-granular execution plan this entry names.
    pub fn plan(&self, model: &str, depth: usize) -> ExecutionPlan {
        ExecutionPlan::from_depth(model, depth, &self.assignment(), self.batch)
    }

    /// The (latency, throughput) view the Pareto pruning runs on. Rate in
    /// images/s stands in for TOPS — proportional within one model, and it
    /// is the unit the scheduler compares against arrival rates.
    fn point(&self) -> Point {
        Point {
            latency_ms: self.latency_ms,
            tops: self.rps,
            batch: self.batch,
            nacc: self.nacc,
        }
    }

    fn validate(&self) -> Result<(), String> {
        if self.assign.len() != ALL_CLASSES.len() {
            return Err(format!(
                "entry '{}' has {} classes, want {}",
                self.label,
                self.assign.len(),
                ALL_CLASSES.len()
            ));
        }
        if let Some(bad) = self.assign.iter().find(|&&a| a >= ALL_CLASSES.len()) {
            return Err(format!("entry '{}' has acc id {bad} >= 8", self.label));
        }
        if self.batch == 0 {
            return Err(format!("entry '{}' has batch 0", self.label));
        }
        if !(self.latency_ms > 0.0 && self.latency_ms.is_finite()) {
            return Err(format!("entry '{}' latency {} not positive", self.label, self.latency_ms));
        }
        if !(self.rps > 0.0 && self.rps.is_finite()) {
            return Err(format!("entry '{}' rps {} not positive", self.label, self.rps));
        }
        Ok(())
    }
}

/// The full front for one model, pruned to non-dominated entries and
/// sorted by latency ascending.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanFront {
    pub model: String,
    pub depth: usize,
    pub entries: Vec<FrontEntry>,
}

impl PlanFront {
    /// Build a front from raw candidates: validates every entry, drops the
    /// dominated ones, sorts by latency ascending.
    pub fn new(model: &str, depth: usize, candidates: Vec<FrontEntry>) -> Result<PlanFront, String> {
        for c in &candidates {
            c.validate()?;
        }
        let points: Vec<Point> = candidates.iter().map(FrontEntry::point).collect();
        let entries: Vec<FrontEntry> = pareto_indices(&points)
            .into_iter()
            .map(|i| candidates[i].clone())
            .collect();
        if entries.is_empty() {
            return Err("empty plan front".into());
        }
        Ok(PlanFront { model: model.to_string(), depth, entries })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Index of the lowest-latency entry (entries are latency-sorted).
    pub fn min_latency_idx(&self) -> usize {
        0
    }

    /// Highest-rate entry meeting the latency SLO (Table 6 semantics on
    /// the serve-time front); None when nothing fits.
    ///
    /// ```
    /// use ssr::plan::front::{FrontEntry, PlanFront};
    ///
    /// let entry = |assign: Vec<usize>, lat_ms: f64, rps: f64, label: &str| FrontEntry {
    ///     nacc: assign.iter().max().unwrap() + 1,
    ///     assign, batch: 1, latency_ms: lat_ms, tops: 0.0, rps,
    ///     label: label.to_string(),
    /// };
    /// let front = PlanFront::new("deit_t", 12, vec![
    ///     entry(vec![0; 8], 0.22, 4545.0, "sequential"),
    ///     entry((0..8).collect(), 0.58, 10344.0, "spatial"),
    /// ]).unwrap();
    /// assert_eq!(front.best_under(2.0), Some(1)); // throughput point fits
    /// assert_eq!(front.best_under(0.3), Some(0)); // only the latency point
    /// assert_eq!(front.best_under(0.1), None);    // the Table 6 "x" cell
    /// ```
    pub fn best_under(&self, slo_ms: f64) -> Option<usize> {
        self.entries
            .iter()
            .enumerate()
            .filter(|(_, e)| e.latency_ms <= slo_ms)
            .max_by(|(_, a), (_, b)| a.rps.total_cmp(&b.rps))
            .map(|(i, _)| i)
    }

    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                let mut m = std::collections::BTreeMap::new();
                m.insert(
                    "assign".to_string(),
                    Json::Arr(e.assign.iter().map(|&a| Json::Num(a as f64)).collect()),
                );
                m.insert("batch".to_string(), Json::Num(e.batch as f64));
                m.insert("latency_ms".to_string(), Json::Num(e.latency_ms));
                m.insert("tops".to_string(), Json::Num(e.tops));
                m.insert("rps".to_string(), Json::Num(e.rps));
                m.insert("nacc".to_string(), Json::Num(e.nacc as f64));
                m.insert("label".to_string(), Json::Str(e.label.clone()));
                Json::Obj(m)
            })
            .collect();
        let mut m = std::collections::BTreeMap::new();
        m.insert("model".to_string(), Json::Str(self.model.clone()));
        m.insert("depth".to_string(), Json::Num(self.depth as f64));
        m.insert("entries".to_string(), Json::Arr(entries));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<PlanFront, String> {
        let model = j
            .get("model")
            .and_then(Json::as_str)
            .ok_or("front missing 'model'")?
            .to_string();
        let depth = j
            .get("depth")
            .and_then(Json::as_usize)
            .ok_or("front missing 'depth'")?;
        let mut candidates = Vec::new();
        for (i, e) in j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or("front missing 'entries'")?
            .iter()
            .enumerate()
        {
            let assign: Vec<usize> = e
                .get("assign")
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("entry {i} missing 'assign'"))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| format!("entry {i} bad acc id")))
                .collect::<Result<_, _>>()?;
            candidates.push(FrontEntry {
                assign,
                batch: e
                    .get("batch")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| format!("entry {i} missing 'batch'"))?,
                latency_ms: e
                    .get("latency_ms")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("entry {i} missing 'latency_ms'"))?,
                tops: e.get("tops").and_then(Json::as_f64).unwrap_or(0.0),
                rps: e
                    .get("rps")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("entry {i} missing 'rps'"))?,
                nacc: e.get("nacc").and_then(Json::as_usize).unwrap_or(1),
                label: e
                    .get("label")
                    .and_then(Json::as_str)
                    .unwrap_or("plan")
                    .to_string(),
            });
        }
        PlanFront::new(&model, depth, candidates)
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string() + "\n")
    }

    pub fn load(path: &Path) -> Result<PlanFront, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        PlanFront::from_json(&Json::parse(&text)?)
    }

    /// One line per entry, for CLI output.
    pub fn describe(&self) -> String {
        let mut out = format!("plan front for {} ({} entries):\n", self.model, self.len());
        for (i, e) in self.entries.iter().enumerate() {
            out.push_str(&format!(
                "  [{i}] {:<12} assign {:?} batch {} nacc {}  {:.3} ms  {:.0} img/s  {:.2} TOPS\n",
                e.label, e.assign, e.batch, e.nacc, e.latency_ms, e.rps, e.tops
            ));
        }
        out
    }
}

/// Evaluate labeled assignments across `batches` on the analytical model
/// and prune to the serving front — the shared construction behind
/// `ssr dse --emit-front`, the adaptive bench, and the examples.
/// Infeasible assignments are skipped.
pub fn analytical_front(
    platform: &crate::arch::Platform,
    calib: &crate::analytical::Calib,
    graph: &crate::graph::Graph,
    candidates: &[(String, Assignment)],
    batches: &[usize],
) -> Result<PlanFront, String> {
    if batches.is_empty() {
        return Err("need at least one batch size".into());
    }
    let mut entries = Vec::new();
    for (label, a) in candidates {
        let Some(ev) = crate::dse::eval::build_design(
            platform,
            calib,
            graph,
            a,
            crate::analytical::Features::all(),
            true,
        ) else {
            continue;
        };
        for &b in batches {
            entries.push(FrontEntry::from_eval(label, a, &ev.evaluate(platform, graph, b)));
        }
    }
    PlanFront::new(&graph.model, graph.depth, entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn entry(label: &str, assign: Vec<usize>, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
        let nacc = assign.iter().copied().max().unwrap() + 1;
        FrontEntry {
            assign,
            batch,
            latency_ms: lat_ms,
            tops: rps * 2.5e-3,
            rps,
            nacc,
            label: label.to_string(),
        }
    }

    fn sample() -> PlanFront {
        PlanFront::new(
            "deit_t",
            12,
            vec![
                entry("sequential", vec![0; 8], 1, 0.22, 4545.0),
                entry("dominated", vec![0; 8], 1, 0.5, 4000.0),
                entry("spatial", (0..8).collect(), 6, 0.58, 10344.0),
            ],
        )
        .unwrap()
    }

    #[test]
    fn new_prunes_dominated_and_sorts() {
        let f = sample();
        assert_eq!(f.len(), 2);
        assert_eq!(f.entries[0].label, "sequential");
        assert_eq!(f.entries[1].label, "spatial");
        assert!(f.entries.windows(2).all(|w| w[0].latency_ms <= w[1].latency_ms));
    }

    #[test]
    fn best_under_matches_table6_semantics() {
        let f = sample();
        assert_eq!(f.best_under(2.0), Some(1)); // spatial: max rate under SLO
        assert_eq!(f.best_under(0.3), Some(0)); // only sequential fits
        assert_eq!(f.best_under(0.1), None); // the "x" cell
    }

    #[test]
    fn json_round_trip() {
        let f = sample();
        let back = PlanFront::from_json(&Json::parse(&f.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, f);
    }

    #[test]
    fn save_load_round_trip() {
        let f = sample();
        let path = std::env::temp_dir().join("ssr_front_test.json");
        f.save(&path).unwrap();
        let back = PlanFront::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back, f);
    }

    #[test]
    fn entry_materializes_a_valid_plan() {
        let f = sample();
        let p = f.entries[1].plan("deit_t", 12);
        assert_eq!(p.nacc, 8);
        assert_eq!(p.micro_batch, 6);
        p.validate().unwrap();
    }

    #[test]
    fn analytical_front_spans_the_tradeoff() {
        let platform = crate::arch::vck190();
        let calib = crate::analytical::Calib::default();
        let g = crate::graph::vit_graph(&crate::graph::DEIT_T);
        let cands = vec![
            ("sequential".to_string(), Assignment::sequential()),
            ("spatial".to_string(), Assignment::spatial()),
        ];
        let f = analytical_front(&platform, &calib, &g, &cands, &[1, 6]).unwrap();
        assert!(!f.is_empty());
        // latency-sorted and non-dominated: rate must rise with latency
        assert!(f
            .entries
            .windows(2)
            .all(|w| w[0].latency_ms <= w[1].latency_ms && w[0].rps <= w[1].rps));
        assert!(analytical_front(&platform, &calib, &g, &cands, &[]).is_err());
    }

    #[test]
    fn rejects_malformed_entries() {
        assert!(PlanFront::new("m", 12, vec![]).is_err());
        assert!(PlanFront::new("m", 12, vec![entry("bad", vec![0; 3], 1, 1.0, 1.0)]).is_err());
        let mut e = entry("bad", vec![0; 8], 1, 1.0, 1.0);
        e.latency_ms = -1.0;
        assert!(PlanFront::new("m", 12, vec![e]).is_err());
    }
}
