//! Request routing across a fleet: pluggable dispatch policies plus the
//! multi-model traffic generator, and the live [`FleetServer`] that
//! drives one [`AdaptiveServer`] per device over the PJRT runtime.
//!
//! The router only sees what a real dispatcher could observe — each
//! device's current queue depth and the latency/rate of the plan it is
//! *currently* serving (which moves as the per-device adaptive schedulers
//! switch plans) — never oracle knowledge of future arrivals.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::cluster::fleet::FleetSpec;
use crate::coordinator::scheduler::{
    AdaptiveServeReport, AdaptiveServer, SchedulerCfg, WindowReport,
};
use crate::runtime::exec::Engine;
use crate::util::rng::Rng;

/// Stream id the router's RNG splits off the base seed (traffic classes
/// use 0..n_classes, live per-device serving uses u64::MAX-1-dev).
pub const ROUTER_STREAM: u64 = u64::MAX;

// ---------------------------------------------------------------------------
// Routing policies
// ---------------------------------------------------------------------------

/// Pluggable dispatch policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through the eligible devices.
    RoundRobin,
    /// Join-shortest-queue over the eligible devices (ties: lowest index).
    ShortestQueue,
    /// SLO-aware power-of-two-choices: sample two eligible devices,
    /// estimate each one's completion time for one more request (queue
    /// drain at the current plan's rate + the plan's latency), prefer the
    /// one that would still meet the SLO, else the smaller estimate.
    PowerOfTwoSlo,
}

impl RoutePolicy {
    pub fn parse(s: &str) -> Result<RoutePolicy, String> {
        match s {
            "rr" | "round-robin" => Ok(RoutePolicy::RoundRobin),
            "jsq" | "shortest-queue" => Ok(RoutePolicy::ShortestQueue),
            "p2c" | "slo-p2c" | "power-of-two" => Ok(RoutePolicy::PowerOfTwoSlo),
            other => Err(format!("unknown routing policy '{other}' (rr|jsq|p2c)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::ShortestQueue => "shortest-queue",
            RoutePolicy::PowerOfTwoSlo => "slo-p2c",
        }
    }
}

/// What the router may know about one device at dispatch time.
#[derive(Clone, Copy, Debug)]
pub struct DeviceView {
    /// Requests queued or in flight on the device.
    pub depth: usize,
    /// Latency of the plan the device is currently serving (ms).
    pub latency_ms: f64,
    /// Sustainable rate of that plan (req/s).
    pub rps: f64,
}

impl DeviceView {
    /// Estimated completion time for one more request (seconds): drain
    /// the standing depth at the plan's rate, then one service latency.
    pub fn est_completion_s(&self) -> f64 {
        self.depth as f64 / self.rps.max(1e-9) + self.latency_ms * 1e-3
    }
}

/// Stateful dispatcher. Deterministic for a given RNG stream: replaying
/// the same arrival sequence over the same views reproduces every pick.
pub struct Router {
    pub policy: RoutePolicy,
    /// Round-robin cursor per traffic class. One global cursor indexed
    /// into per-class eligible sets of different sizes skews the cycle
    /// under a multi-model mix (e.g. classes with 2- and 3-device sets
    /// interleaved 1:1 pin each class to a single device forever) — each
    /// class cycles its own set independently instead. The cursor is
    /// reduced mod the *current* set size at every pick, so an eligible
    /// set that grows or shrinks mid-run (autoscaling, drains, failures)
    /// re-normalizes instead of indexing out of range.
    rr_next: Vec<usize>,
    rng: Rng,
}

impl Router {
    pub fn new(policy: RoutePolicy, rng: Rng) -> Router {
        Router { policy, rr_next: Vec::new(), rng }
    }

    /// Pick a device among `eligible` (indices into `views`, i.e. the
    /// devices serving the request's model) for a request of traffic
    /// class `class`. `None` = unroutable.
    pub fn pick(
        &mut self,
        views: &[DeviceView],
        class: usize,
        eligible: &[usize],
        slo_ms: f64,
    ) -> Option<usize> {
        match eligible.len() {
            0 => None,
            1 => Some(eligible[0]),
            n => Some(match self.policy {
                RoutePolicy::RoundRobin => {
                    if class >= self.rr_next.len() {
                        self.rr_next.resize(class + 1, 0);
                    }
                    let cursor = &mut self.rr_next[class];
                    let d = eligible[*cursor % n];
                    *cursor = (*cursor + 1) % n;
                    d
                }
                RoutePolicy::ShortestQueue => eligible
                    .iter()
                    .copied()
                    .min_by_key(|&d| (views[d].depth, d))
                    .expect("non-empty eligible set"),
                RoutePolicy::PowerOfTwoSlo => {
                    let i = self.rng.usize_below(n);
                    let mut j = self.rng.usize_below(n - 1);
                    if j >= i {
                        j += 1; // uniform over unordered distinct pairs
                    }
                    better_of(views, eligible[i], eligible[j], slo_ms)
                }
            }),
        }
    }
}

/// The SLO-aware comparison behind power-of-two-choices.
fn better_of(views: &[DeviceView], a: usize, b: usize, slo_ms: f64) -> usize {
    let (ca, cb) = (views[a].est_completion_s(), views[b].est_completion_s());
    let slo_s = slo_ms * 1e-3;
    match (ca <= slo_s, cb <= slo_s) {
        (true, false) => a,
        (false, true) => b,
        // both (or neither) can make it: less loaded wins, ties to the
        // lower index for determinism
        _ => match ca.total_cmp(&cb) {
            std::cmp::Ordering::Less => a,
            std::cmp::Ordering::Greater => b,
            std::cmp::Ordering::Equal => a.min(b),
        },
    }
}

// ---------------------------------------------------------------------------
// Multi-model traffic
// ---------------------------------------------------------------------------

// The traffic generator lives beside `RampSpec` in the coordinator (the
// single-device sim replays a single-class mix through the same shared
// per-device core); re-exported here so fleet-facing code keeps importing
// it from the cluster.
pub use crate::coordinator::scheduler::{TrafficClass, TrafficMix};

// ---------------------------------------------------------------------------
// Live fleet serving (PJRT runtime)
// ---------------------------------------------------------------------------

/// Outcome of a live fleet run: per-device adaptive reports plus the
/// requests no device could take.
pub struct FleetServeOutcome {
    /// `(device id, report)` in fleet order.
    pub per_device: Vec<(String, AdaptiveServeReport)>,
    /// Arrivals whose model no servable device carries.
    pub unroutable: usize,
}

/// Live fleet serving: one [`AdaptiveServer`] per device, the router
/// splitting each window's arrivals across them. All devices share the
/// engine's compiled artifacts — this emulates N boards on one host; a
/// real deployment would hand each device its own engine. Devices whose
/// front the manifest cannot serve are dropped with a log line, exactly
/// like single-device adaptive serving drops unservable front entries.
pub struct FleetServer {
    ids: Vec<String>,
    servers: Vec<AdaptiveServer>,
    router: Router,
    cfg: SchedulerCfg,
}

impl FleetServer {
    pub fn new(
        engine: Arc<Engine>,
        fleet: &FleetSpec,
        cfg: SchedulerCfg,
        policy: RoutePolicy,
        seed: u64,
    ) -> Result<FleetServer> {
        let mut ids = Vec::new();
        let mut servers = Vec::new();
        for d in &fleet.devices {
            match AdaptiveServer::new(Arc::clone(&engine), d.front.clone(), cfg) {
                Ok(s) => {
                    ids.push(d.id.clone());
                    servers.push(s);
                }
                Err(e) => eprintln!("[cluster] dropping device '{}': {e}", d.id),
            }
        }
        if servers.is_empty() {
            return Err(anyhow!("no servable devices in fleet '{}'", fleet.name));
        }
        let router = Router::new(policy, Rng::new(seed).split(ROUTER_STREAM));
        Ok(FleetServer { ids, servers, router, cfg })
    }

    pub fn device_ids(&self) -> &[String] {
        &self.ids
    }

    /// Drive the mix window by window: arrivals inside a window are routed
    /// one by one against the devices' observable state (standing backlog
    /// plus what this window already routed to them), then every device
    /// serves its share of the window via
    /// [`AdaptiveServer::serve_window`].
    pub fn serve_mix(&mut self, mix: &TrafficMix, seed: u64) -> Result<FleetServeOutcome> {
        let window_s = self.cfg.window_s;
        let arrivals = mix.arrivals(seed);
        let n_windows = (mix.duration_s() / window_s - 1e-9).ceil() as usize;
        let eligible: Vec<Vec<usize>> = mix
            .classes
            .iter()
            .map(|c| {
                self.servers
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| s.model() == c.model)
                    .map(|(i, _)| i)
                    .collect()
            })
            .collect();
        let base = Rng::new(seed);
        let dev_seeds: Vec<u64> = (0..self.servers.len())
            .map(|i| base.split(ROUTER_STREAM - 1 - i as u64).next_u64())
            .collect();
        let mut reports: Vec<Vec<WindowReport>> =
            (0..self.servers.len()).map(|_| Vec::new()).collect();
        let mut unroutable = 0usize;
        let mut ai = 0usize;
        for w in 0..n_windows {
            let end_s = (w + 1) as f64 * window_s;
            let mut buckets: Vec<Vec<f64>> =
                (0..self.servers.len()).map(|_| Vec::new()).collect();
            while ai < arrivals.len() && arrivals[ai].0 < end_s {
                let (t, class) = arrivals[ai];
                let views: Vec<DeviceView> = self
                    .servers
                    .iter()
                    .enumerate()
                    .map(|(i, s)| {
                        let e = s.active_entry();
                        DeviceView {
                            depth: s.queue_depth() + buckets[i].len(),
                            latency_ms: e.latency_ms,
                            rps: e.rps,
                        }
                    })
                    .collect();
                match self.router.pick(&views, class, &eligible[class], self.cfg.slo_ms) {
                    Some(d) => buckets[d].push(t),
                    None => unroutable += 1,
                }
                ai += 1;
            }
            for (d, server) in self.servers.iter_mut().enumerate() {
                reports[d].push(server.serve_window(w, &buckets[d], dev_seeds[d])?);
            }
        }
        let per_device = self
            .ids
            .iter()
            .zip(reports)
            .zip(&self.servers)
            .map(|((id, windows), s)| {
                let total_images = windows.iter().map(|w| w.admitted).sum();
                let total_shed = windows.iter().map(|w| w.shed).sum();
                let report = AdaptiveServeReport {
                    windows,
                    switches: s.scheduler().switches.clone(),
                    total_images,
                    total_shed,
                };
                (id.clone(), report)
            })
            .collect();
        Ok(FleetServeOutcome { per_device, unroutable })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::RampSpec;

    fn views(depths: &[usize]) -> Vec<DeviceView> {
        depths
            .iter()
            .map(|&d| DeviceView { depth: d, latency_ms: 1.0, rps: 1000.0 })
            .collect()
    }

    #[test]
    fn round_robin_cycles_eligible_only() {
        let mut r = Router::new(RoutePolicy::RoundRobin, Rng::new(1));
        let v = views(&[0, 0, 0, 0]);
        let picks: Vec<usize> =
            (0..6).map(|_| r.pick(&v, 0, &[1, 3], 2.0).unwrap()).collect();
        assert_eq!(picks, vec![1, 3, 1, 3, 1, 3]);
        assert_eq!(r.pick(&v, 0, &[], 2.0), None);
        assert_eq!(r.pick(&v, 0, &[2], 2.0), Some(2));
    }

    #[test]
    fn round_robin_cursor_is_per_class() {
        // Regression: a single global cursor indexed into per-class
        // eligible sets of different sizes skews the cycle. With class 0
        // on {0,1} and class 1 on {2,3,4} interleaved 1:1, the old global
        // cursor pinned class 0 to device 0 and class 1 to device 3
        // forever (cursor 0 -> pick e[0], cursor 1 -> pick e[1], cursor
        // wraps to 0/1 alternately for each set size) — starving devices
        // 1, 2, and 4 within their classes. Per-class cursors keep every
        // split exactly even.
        let mut r = Router::new(RoutePolicy::RoundRobin, Rng::new(1));
        let v = views(&[0, 0, 0, 0, 0]);
        let mut hit = [0usize; 5];
        for _ in 0..30 {
            hit[r.pick(&v, 0, &[0, 1], 2.0).unwrap()] += 1;
            hit[r.pick(&v, 1, &[2, 3, 4], 2.0).unwrap()] += 1;
        }
        assert_eq!(hit[0], 15, "class-0 split skewed: {hit:?}");
        assert_eq!(hit[1], 15, "class-0 split skewed: {hit:?}");
        assert_eq!(hit[2], 10, "class-1 split skewed: {hit:?}");
        assert_eq!(hit[3], 10, "class-1 split skewed: {hit:?}");
        assert_eq!(hit[4], 10, "class-1 split skewed: {hit:?}");
    }

    #[test]
    fn round_robin_cursor_renormalizes_when_the_eligible_set_changes() {
        // Autoscaling regression: a device added or removed mid-run
        // changes the eligible set's size between picks. The per-class
        // cursor must reduce mod the *new* size — never index out of
        // range — and keep cycling the devices that remain.
        let mut r = Router::new(RoutePolicy::RoundRobin, Rng::new(1));
        let v = views(&[0, 0, 0, 0]);
        // three devices: cursor walks 0, 1 and now sits at 2
        assert_eq!(r.pick(&v, 0, &[0, 1, 2], 2.0), Some(0));
        assert_eq!(r.pick(&v, 0, &[0, 1, 2], 2.0), Some(1));
        // the set shrinks to two (device 2 drained): cursor 2 % 2 = 0
        assert_eq!(r.pick(&v, 0, &[0, 1], 2.0), Some(0));
        assert_eq!(r.pick(&v, 0, &[0, 1], 2.0), Some(1));
        // the set grows to four (scale-out): cycling resumes evenly over
        // the new membership
        let picks: Vec<usize> =
            (0..8).map(|_| r.pick(&v, 0, &[0, 1, 2, 3], 2.0).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 3, 0, 1, 2, 3]);
        // a different class keeps its own independent cursor throughout
        assert_eq!(r.pick(&v, 1, &[1, 3], 2.0), Some(1));
        assert_eq!(r.pick(&v, 1, &[1, 3], 2.0), Some(3));
    }

    #[test]
    fn shortest_queue_picks_min_depth_ties_low_index() {
        let mut r = Router::new(RoutePolicy::ShortestQueue, Rng::new(1));
        assert_eq!(r.pick(&views(&[5, 2, 9]), 0, &[0, 1, 2], 2.0), Some(1));
        assert_eq!(r.pick(&views(&[4, 4, 4]), 0, &[0, 1, 2], 2.0), Some(0));
        assert_eq!(r.pick(&views(&[4, 4, 0]), 0, &[0, 1], 2.0), Some(0));
    }

    #[test]
    fn p2c_prefers_slo_feasible_and_is_deterministic() {
        // device 0 deep (est completion 101 ms), device 1 idle (1 ms):
        // whichever pair is sampled, the SLO-feasible device must win
        let v = vec![
            DeviceView { depth: 100, latency_ms: 1.0, rps: 1000.0 },
            DeviceView { depth: 0, latency_ms: 1.0, rps: 1000.0 },
        ];
        let mut a = Router::new(RoutePolicy::PowerOfTwoSlo, Rng::new(42).split(0));
        let mut b = Router::new(RoutePolicy::PowerOfTwoSlo, Rng::new(42).split(0));
        for _ in 0..100 {
            let pa = a.pick(&v, 0, &[0, 1], 5.0).unwrap();
            assert_eq!(pa, 1, "p2c routed into the SLO-violating queue");
            assert_eq!(pa, b.pick(&v, 0, &[0, 1], 5.0).unwrap());
        }
    }

    #[test]
    fn p2c_load_orders_the_pick_frequencies() {
        // depths 9 > 7 > 6 > 5: the less-loaded member of every sampled
        // pair wins, so pick frequency must be inversely ordered by depth
        // and the deepest device (in every pair it loses) gets nothing.
        let v = views(&[9, 5, 7, 6]);
        let mut r = Router::new(RoutePolicy::PowerOfTwoSlo, Rng::new(7));
        let mut hit = [0usize; 4];
        for _ in 0..600 {
            hit[r.pick(&v, 0, &[0, 1, 2, 3], 1000.0).unwrap()] += 1;
        }
        assert_eq!(hit[0], 0, "deepest device still picked: {hit:?}");
        assert!(hit[1] > hit[3] && hit[3] > hit[2], "not load-ordered: {hit:?}");
        assert!(hit[2] > 0, "second-deepest starved: {hit:?}");
    }

    #[test]
    fn traffic_mix_merges_sorted_and_streams_are_independent() {
        let ramp = RampSpec::parse("2000:500", 0.25).unwrap();
        let mix = TrafficMix {
            classes: vec![
                TrafficClass { model: "deit_t".to_string(), ramp: ramp.clone() },
                TrafficClass { model: "deit_t_256".to_string(), ramp: ramp.clone() },
            ],
        };
        let a = mix.arrivals(9);
        assert_eq!(a, mix.arrivals(9));
        assert!(a.windows(2).all(|w| w[0].0 <= w[1].0));
        assert!(a.iter().any(|&(_, c)| c == 0) && a.iter().any(|&(_, c)| c == 1));
        // class 0's own arrival times are unchanged by the second class
        let single = TrafficMix::single("deit_t", ramp);
        let solo: Vec<f64> = single.arrivals(9).into_iter().map(|(t, _)| t).collect();
        let merged: Vec<f64> =
            a.iter().filter(|&&(_, c)| c == 0).map(|&(t, _)| t).collect();
        assert_eq!(solo, merged);
        assert!((mix.duration_s() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn policy_parse_round_trip() {
        for (s, p) in [
            ("rr", RoutePolicy::RoundRobin),
            ("jsq", RoutePolicy::ShortestQueue),
            ("p2c", RoutePolicy::PowerOfTwoSlo),
        ] {
            assert_eq!(RoutePolicy::parse(s).unwrap(), p);
        }
        assert!(RoutePolicy::parse("random").is_err());
    }
}
