//! Fleet-level serving: many devices, heterogeneous platforms, one queue
//! of multi-model traffic.
//!
//! The paper's §6 analytical models show SSR generalizing across boards
//! (VCK190, Stratix 10 NX) alongside the monolithic-FPGA baselines
//! (ZCU102, U250); one board, however, caps out at its front's
//! throughput-optimal point. This subsystem layers the missing scale
//! dimension on top of the single-device plan/scheduler stack:
//!
//! * [`fleet`] — the serializable [`fleet::FleetSpec`]: N devices, each a
//!   named `arch` board plus the [`crate::plan::front::PlanFront`] it
//!   serves, loadable from JSON and synthesizable from the analytical
//!   fronts.
//! * [`router`] — pluggable dispatch (round-robin, join-shortest-queue,
//!   SLO-aware power-of-two-choices) of a multi-model traffic mix onto
//!   per-device [`crate::coordinator::AdaptiveScheduler`]s, plus the live
//!   [`router::FleetServer`] over PJRT.
//! * [`sim`] — deterministic discrete-event replay of the whole fleet
//!   (the N-device extension of [`crate::sim::serving::serve_ramp`]), so
//!   routing and provisioning behavior is testable without hardware.
//! * [`provision`] — given a traffic forecast and an SLO, search the
//!   platform mix + per-device plan selection that minimizes device count
//!   then power, emitting a ready-to-serve `FleetSpec`.
//! * [`controller`] — the online closed loop over all of the above:
//!   watches per-device load estimates and scales the fleet out/in
//!   (reactively, or pre-warmed by a Holt forecast via
//!   [`controller::simulate_autoscale_predictive`]), fails devices over
//!   (deterministic [`controller::FaultSpec`] injection), and rolls out
//!   fleet-level front updates one hitless drain-and-swap at a time.
//!
//! Every simulation entry point here takes its workload as
//! `impl Into<`[`crate::traffic::TraceSpec`]`>` — a [`TrafficMix`], a
//! bare ramp, or a full diurnal/flash-crowd/heavy-tail trace.
//!
//! CLI: `ssr cluster provision|simulate|serve|autoscale`. Invariants
//! (conservation, determinism, heterogeneous-vs-homogeneous
//! provisioning, autoscale-vs-static device-hours) are pinned in
//! `rust/tests/cluster_serving.rs` and `rust/tests/fleet_autoscale.rs`.

pub mod controller;
pub mod fleet;
pub mod provision;
pub mod router;
pub mod sim;

pub use controller::{
    simulate_autoscale, simulate_autoscale_observed, simulate_autoscale_predictive,
    simulate_autoscale_predictive_observed, AutoscaleCfg, AutoscaleReport, AutoscaleSpec,
    FaultSpec, ForecastCfg, FrontSwap,
};
pub use fleet::{DeviceSpec, FleetSpec};
pub use provision::{provision, PlatformOption, ProvisionResult};
pub use router::{DeviceView, RoutePolicy, Router, TrafficClass, TrafficMix};
pub use sim::{simulate_fleet, simulate_fleet_observed, DeviceStat, FleetSimReport};

pub use crate::traffic::TraceSpec;
