//! Deterministic discrete-event replay of a whole fleet — the N-device
//! face of the shared per-device core in [`crate::sim::device`].
//!
//! Every device is a [`DeviceSim`] — the *same* struct (and therefore the
//! same [`AdaptiveScheduler`] wiring, queue, exact drain-and-swap at
//! launch completion, admission control, and per-window [`WindowStat`]
//! recording) that the single-device [`crate::sim::serving::serve_ramp`]
//! drives; the router sits in front, dispatching each arrival of the
//! multi-model mix against the devices' observable state. The event loop
//! and its deterministic tie order — on time ties: completion (lowest
//! device index first), then the window tick, then the arrival — live in
//! [`run_timeline_recorded`], shared with the single-device sim (with
//! arrivals streamed lazily via
//! [`crate::traffic::ArrivalStream`]), so a seed fully
//! determines every tally, fleet-wide and per device, and the two sims
//! cannot diverge (`rust/tests/sim_unification.rs` pins `serve_ramp`
//! bit-identical to a 1-device fleet). The only ways a request is not
//! served are explicit: per-device admission shedding, or no device
//! serving its model at all (`unroutable`). `served + shed == arrivals`
//! holds per device and fleet-wide, pinned by `tests/cluster_serving.rs`.
//!
//! [`AdaptiveScheduler`]: crate::coordinator::scheduler::AdaptiveScheduler

use crate::cluster::fleet::FleetSpec;
use crate::cluster::router::{DeviceView, RoutePolicy, Router, ROUTER_STREAM};
use crate::coordinator::scheduler::{SchedulerCfg, SwitchRecord};
use crate::obs::{NoopRecorder, Recorder};
use crate::sim::device::{run_timeline_recorded, DeviceSim, NoControl, WindowStat};
use crate::sim::service::SERVICE_STREAM;
use crate::traffic::{ArrivalStream, TraceSpec};
use crate::util::rng::Rng;
use crate::util::stats::{fmt_ms, Summary};

/// Per-device outcome of a fleet simulation.
#[derive(Clone, Debug)]
pub struct DeviceStat {
    pub id: String,
    pub platform: String,
    /// Requests the router sent here (`served + shed`).
    pub routed: usize,
    pub served: usize,
    pub shed: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub max_queue_depth: usize,
    pub switches: Vec<SwitchRecord>,
    /// Per-window snapshots — same shape the single-device sim reports.
    pub windows: Vec<WindowStat>,
    /// Plan executing when the run ended.
    pub final_committed: usize,
    /// Switch target still draining at the end (`None` after a clean
    /// drain; the event loop always completes in-flight launches).
    pub final_draining: Option<usize>,
}

/// Outcome of a simulated fleet run.
#[derive(Clone, Debug)]
pub struct FleetSimReport {
    pub arrivals: usize,
    pub served: usize,
    /// All requests not served: per-device admission shedding plus the
    /// `unroutable` ones.
    pub shed: usize,
    /// Subset of `shed` whose model no device serves.
    pub unroutable: usize,
    /// Fleet-wide per-request sojourn times (served requests).
    pub latency: Summary,
    pub slo_violations: usize,
    /// Completion time of the last served request.
    pub makespan_s: f64,
    pub devices: Vec<DeviceStat>,
}

impl FleetSimReport {
    /// `(p50, p99)` sojourn in ms, from one sort.
    pub fn latency_ms(&self) -> (f64, f64) {
        let p = self.latency.percentiles(&[0.50, 0.99]);
        (p[0] * 1e3, p[1] * 1e3)
    }

    pub fn p99_ms(&self) -> f64 {
        self.latency_ms().1
    }

    pub fn slo_attainment(&self) -> f64 {
        if self.served == 0 {
            return 1.0;
        }
        1.0 - self.slo_violations as f64 / self.served as f64
    }

    pub fn total_switches(&self) -> usize {
        self.devices.iter().map(|d| d.switches.len()).sum()
    }

    pub fn summary_line(&self) -> String {
        // Empty-latency runs yield NaN percentiles; fmt_ms prints "-".
        let pct = self.latency.percentiles(&[0.50, 0.99]);
        let (p50, p99) = (fmt_ms(pct[0]), fmt_ms(pct[1]));
        format!(
            "{} devices | {} arrivals | {} served, {} shed ({} unroutable) | p50 {p50} ms \
             p99 {p99} ms | SLO attainment {:.1}% | {} plan switches",
            self.devices.len(),
            self.arrivals,
            self.served,
            self.shed,
            self.unroutable,
            self.slo_attainment() * 100.0,
            self.total_switches()
        )
    }
}

/// Simulate serving `traffic` (anything `Into<`[`TraceSpec`]`>`: a
/// [`crate::cluster::TrafficMix`], a bare ramp, or a full workload trace
/// with diurnal/flash curves and heavy-tail bursts) on `fleet` with
/// per-device adaptive scheduling under `cfg` and the given routing
/// policy. Fully deterministic for a given seed: per-class arrival
/// streams and the router's sampling stream are all [`Rng::split`] off
/// the one base seed. All queueing semantics live in the shared
/// per-device core ([`crate::sim::device`]); this function only assembles
/// devices, routes arrivals, and rolls up the report.
///
/// ```
/// use ssr::cluster::fleet::{parse_mix, synth_fleet};
/// use ssr::cluster::{simulate_fleet, RoutePolicy, TrafficMix};
/// use ssr::coordinator::scheduler::{RampSpec, SchedulerCfg};
///
/// let fleet = synth_fleet("demo", "deit_t", &parse_mix("vck190:2").unwrap(), &[1, 6]).unwrap();
/// let mix = TrafficMix::single("deit_t", RampSpec::parse("2000:4000", 0.2).unwrap());
/// let cfg = SchedulerCfg { slo_ms: 25.0, ..Default::default() };
/// let r = simulate_fleet(&fleet, &mix, &cfg, RoutePolicy::PowerOfTwoSlo, 7).unwrap();
/// assert_eq!(r.served + r.shed, r.arrivals); // conservation, always
/// assert_eq!(r.devices.len(), 2);
/// ```
pub fn simulate_fleet(
    fleet: &FleetSpec,
    traffic: impl Into<TraceSpec>,
    cfg: &SchedulerCfg,
    policy: RoutePolicy,
    seed: u64,
) -> Result<FleetSimReport, String> {
    let mut rec = NoopRecorder;
    simulate_fleet_observed(fleet, traffic, cfg, policy, seed, &mut rec)
}

/// [`simulate_fleet`] with a [`Recorder`] observing the run. The report
/// is bit-identical to the unobserved run; the recorder additionally
/// captures the structured event stream ([`crate::obs::TraceEvent`]).
pub fn simulate_fleet_observed(
    fleet: &FleetSpec,
    traffic: impl Into<TraceSpec>,
    cfg: &SchedulerCfg,
    policy: RoutePolicy,
    seed: u64,
    rec: &mut impl Recorder,
) -> Result<FleetSimReport, String> {
    let trace: TraceSpec = traffic.into();
    if fleet.is_empty() {
        return Err("cannot simulate an empty fleet".into());
    }
    if trace.classes.is_empty() {
        return Err("traffic trace has no classes".into());
    }
    // Arrivals stream lazily from per-class split RNGs — same merged
    // order the materialized timeline had, O(classes) memory.
    let mut arrivals = ArrivalStream::from_trace(&trace, seed);
    let base = Rng::new(seed);
    let mut router = Router::new(policy, base.split(ROUTER_STREAM));

    // Class -> devices serving that model.
    let eligible: Vec<Vec<usize>> = trace
        .classes
        .iter()
        .map(|c| {
            fleet
                .devices
                .iter()
                .enumerate()
                .filter(|(_, d)| d.front.model == c.model)
                .map(|(i, _)| i)
                .collect()
        })
        .collect();

    // Each device samples service factors for the model it serves, from
    // its own split of the dedicated SERVICE_STREAM — deterministic per
    // (seed, device index) and invisible to arrivals and routing.
    let service_base = base.split(SERVICE_STREAM);
    let mut devs: Vec<DeviceSim> = fleet
        .devices
        .iter()
        .enumerate()
        .map(|(i, d)| {
            DeviceSim::new(d.front.clone(), *cfg)
                .with_service(trace.service_for(&d.front.model), service_base.split(i as u64))
        })
        .collect();

    let outcome = run_timeline_recorded(
        &mut devs,
        &mut arrivals,
        trace.duration_s(),
        cfg.window_s,
        |devs, class, _t| {
            // The router sees only observable state: each device's standing
            // depth and the service curve of the plan it is *executing*.
            let views: Vec<DeviceView> = devs
                .iter()
                .map(|d| {
                    let e = d.committed_entry();
                    DeviceView { depth: d.depth(), latency_ms: e.latency_ms, rps: e.rps }
                })
                .collect();
            router.pick(&views, class, &eligible[class], cfg.slo_ms)
        },
        &mut NoControl,
        rec,
    );

    let devices: Vec<DeviceStat> = fleet
        .devices
        .iter()
        .zip(devs)
        .map(|(spec, d)| {
            let r = d.into_report();
            let p = r.latency.percentiles(&[0.50, 0.99]);
            DeviceStat {
                id: spec.id.clone(),
                platform: spec.platform.clone(),
                routed: r.routed,
                served: r.served,
                shed: r.shed,
                p50_ms: p[0] * 1e3,
                p99_ms: p[1] * 1e3,
                max_queue_depth: r.max_queue_depth,
                switches: r.switches,
                windows: r.windows,
                final_committed: r.final_committed,
                final_draining: r.final_draining,
            }
        })
        .collect();
    let served: usize = devices.iter().map(|d| d.served).sum();
    let dev_shed: usize = devices.iter().map(|d| d.shed).sum();
    let slo_violations = served - outcome.latency.count_leq(cfg.slo_ms * 1e-3);

    Ok(FleetSimReport {
        arrivals: outcome.arrivals,
        served,
        shed: dev_shed + outcome.unroutable,
        unroutable: outcome.unroutable,
        latency: outcome.latency,
        slo_violations,
        makespan_s: outcome.makespan_s,
        devices,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::fleet::{DeviceSpec, FleetSpec};
    use crate::cluster::router::{TrafficClass, TrafficMix};
    use crate::coordinator::scheduler::RampSpec;
    use crate::plan::front::{FrontEntry, PlanFront};

    fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
        FrontEntry {
            assign: vec![0; 8],
            batch,
            latency_ms: lat_ms,
            tops: rps * 2.5e-3,
            rps,
            nacc: 1,
            label: label.to_string(),
        }
    }

    /// Synthetic two-device fleet over controlled capacities (same shape
    /// as the single-device scheduler tests).
    fn fleet(model: &str) -> FleetSpec {
        let front = PlanFront::new(
            model,
            12,
            vec![
                entry("seq", 1, 0.2, 5000.0),
                entry("hybrid", 6, 1.0, 6000.0),
                entry("spatial", 24, 2.0, 12000.0),
            ],
        )
        .unwrap();
        FleetSpec::new(
            "synthetic",
            vec![
                DeviceSpec {
                    id: "vck190-0".to_string(),
                    platform: "vck190".to_string(),
                    front: front.clone(),
                },
                DeviceSpec {
                    id: "vck190-1".to_string(),
                    platform: "vck190".to_string(),
                    front,
                },
            ],
        )
        .unwrap()
    }

    fn cfg() -> SchedulerCfg {
        SchedulerCfg { slo_ms: 20.0, ..Default::default() }
    }

    #[test]
    fn conservation_per_device_and_fleet_wide() {
        let mix = TrafficMix::single("m", RampSpec::parse("2000:8000:2000", 0.4).unwrap());
        for policy in
            [RoutePolicy::RoundRobin, RoutePolicy::ShortestQueue, RoutePolicy::PowerOfTwoSlo]
        {
            let r = simulate_fleet(&fleet("m"), &mix, &cfg(), policy, 11).unwrap();
            assert_eq!(r.served + r.shed, r.arrivals, "{policy:?} lost requests");
            let routed: usize = r.devices.iter().map(|d| d.routed).sum();
            assert_eq!(routed + r.unroutable, r.arrivals);
            for d in &r.devices {
                assert_eq!(d.served + d.shed, d.routed, "device {} lost requests", d.id);
            }
            assert_eq!(r.latency.len(), r.served);
            // two equal devices under a load-aware policy: neither starves
            assert!(r.devices.iter().all(|d| d.routed > 0), "{policy:?} starved a device");
        }
    }

    #[test]
    fn identical_seed_identical_per_device_tallies() {
        let mix = TrafficMix::single("m", RampSpec::parse("3000:9000", 0.3).unwrap());
        let a = simulate_fleet(&fleet("m"), &mix, &cfg(), RoutePolicy::PowerOfTwoSlo, 5).unwrap();
        let b = simulate_fleet(&fleet("m"), &mix, &cfg(), RoutePolicy::PowerOfTwoSlo, 5).unwrap();
        assert_eq!(a.served, b.served);
        assert_eq!(a.makespan_s, b.makespan_s);
        for (da, db) in a.devices.iter().zip(&b.devices) {
            assert_eq!(da.routed, db.routed);
            assert_eq!(da.served, db.served);
            assert_eq!(da.shed, db.shed);
            assert_eq!(da.switches, db.switches);
            assert_eq!(da.windows, db.windows);
        }
        let c = simulate_fleet(&fleet("m"), &mix, &cfg(), RoutePolicy::PowerOfTwoSlo, 6).unwrap();
        assert_ne!(
            a.devices.iter().map(|d| d.routed).collect::<Vec<_>>(),
            c.devices.iter().map(|d| d.routed).collect::<Vec<_>>()
        );
    }

    #[test]
    fn every_device_records_every_window() {
        // Divergence fixed by the unification: the fleet sim used to
        // record no per-window stats at all. Now each device reports the
        // same WindowStat trace shape as the single-device sim.
        let mix = TrafficMix::single("m", RampSpec::parse("2000:6000", 0.25).unwrap());
        let r = simulate_fleet(&fleet("m"), &mix, &cfg(), RoutePolicy::RoundRobin, 9).unwrap();
        let n_windows = (0.5 / cfg().window_s).round() as usize;
        for d in &r.devices {
            assert_eq!(d.windows.len(), n_windows, "device {} missing windows", d.id);
            for (i, ws) in d.windows.iter().enumerate() {
                assert_eq!(ws.window, i);
            }
            assert_eq!(d.final_draining, None, "launches must drain before the run ends");
        }
    }

    #[test]
    fn unroutable_model_is_accounted_not_lost() {
        let ramp = RampSpec::parse("1000", 0.3).unwrap();
        let mix = TrafficMix {
            classes: vec![
                TrafficClass { model: "m".to_string(), ramp: ramp.clone() },
                TrafficClass { model: "other".to_string(), ramp },
            ],
        };
        let r = simulate_fleet(&fleet("m"), &mix, &cfg(), RoutePolicy::RoundRobin, 3).unwrap();
        assert!(r.unroutable > 0, "class with no eligible device must be unroutable");
        assert_eq!(r.served + r.shed, r.arrivals);
        // the routable class is still fully served under this light load
        assert_eq!(r.shed, r.unroutable);
    }

    #[test]
    fn two_devices_halve_the_per_device_load() {
        // 8000 req/s across two devices ≈ 4000 each: under each device's
        // seq capacity, so no shedding and p99 well under the SLO.
        let mix = TrafficMix::single("m", RampSpec::parse("2000:8000:2000", 0.4).unwrap());
        let r =
            simulate_fleet(&fleet("m"), &mix, &cfg(), RoutePolicy::PowerOfTwoSlo, 17).unwrap();
        assert_eq!(r.shed, 0, "two-device fleet shed under feasible load");
        assert!(r.p99_ms() <= cfg().slo_ms, "p99 {:.2} ms", r.p99_ms());
        // both devices took a meaningful share of the peak
        let shares: Vec<f64> = r
            .devices
            .iter()
            .map(|d| d.routed as f64 / r.arrivals as f64)
            .collect();
        assert!(shares.iter().all(|&s| s > 0.2), "lopsided split {shares:?}");
    }

    #[test]
    fn all_unroutable_summary_prints_dashes_not_nan() {
        // Nothing served → empty latency summary → NaN percentiles; the
        // human-facing line must print "-" instead of "NaN".
        let mix = TrafficMix::single("other", RampSpec::parse("1000", 0.2).unwrap());
        let r = simulate_fleet(&fleet("m"), &mix, &cfg(), RoutePolicy::RoundRobin, 3).unwrap();
        assert_eq!(r.served, 0);
        let line = r.summary_line();
        assert!(line.contains("p50 - ms p99 - ms"), "{line}");
        assert!(!line.contains("NaN"), "{line}");
    }

    #[test]
    fn observed_fleet_run_is_bit_identical_to_unobserved() {
        use crate::obs::{trace_tallies, TraceRecorder};
        let mix = TrafficMix::single("m", RampSpec::parse("2000:8000:2000", 0.4).unwrap());
        let a = simulate_fleet(&fleet("m"), &mix, &cfg(), RoutePolicy::PowerOfTwoSlo, 11).unwrap();
        let mut rec = TraceRecorder::new();
        let b = simulate_fleet_observed(
            &fleet("m"),
            &mix,
            &cfg(),
            RoutePolicy::PowerOfTwoSlo,
            11,
            &mut rec,
        )
        .unwrap();
        assert_eq!(a.arrivals, b.arrivals);
        assert_eq!(a.served, b.served);
        assert_eq!(a.shed, b.shed);
        assert_eq!(a.makespan_s, b.makespan_s);
        // Tallies fold unroutables into shed, matching the report.
        let t = trace_tallies(&rec.events);
        assert_eq!(t.arrivals as usize, b.arrivals);
        assert_eq!(t.served as usize, b.served);
        assert_eq!(t.shed as usize, b.shed);
        assert_eq!(t.unroutable as usize, b.unroutable);
    }

    #[test]
    fn rejects_empty_mix() {
        let empty = TrafficMix { classes: vec![] };
        assert!(
            simulate_fleet(&fleet("m"), &empty, &cfg(), RoutePolicy::RoundRobin, 1).is_err()
        );
    }
}
