//! Deterministic discrete-event replay of a whole fleet — the N-device
//! extension of [`crate::sim::serving::serve_ramp`].
//!
//! Every device runs the *same* per-device machinery as the single-device
//! sim (its own [`AdaptiveScheduler`] with hysteresis + admission control,
//! its own queue, exact drain-and-swap at launch completion); the router
//! sits in front, dispatching each arrival of the multi-model mix against
//! the devices' observable state. Event order is deterministic — on time
//! ties: completion (lowest device index first), then the window tick,
//! then the arrival — so a seed fully determines every tally, fleet-wide
//! and per device. The only ways a request is not served are explicit:
//! per-device admission shedding, or no device serving its model at all
//! (`unroutable`). `served + shed == arrivals` holds per device and
//! fleet-wide, pinned by `tests/cluster_serving.rs`.

use std::collections::VecDeque;

use crate::cluster::fleet::FleetSpec;
use crate::cluster::router::{DeviceView, RoutePolicy, Router, TrafficMix, ROUTER_STREAM};
use crate::coordinator::scheduler::{
    AdaptiveScheduler, LoadEstimator, SchedulerCfg, SwitchRecord,
};
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// One in-flight launch: the arrival times it serves and its completion.
struct Launch {
    done_s: f64,
    arrivals: Vec<f64>,
}

/// Per-device simulation state.
struct Dev {
    sched: AdaptiveScheduler,
    est: LoadEstimator,
    queue: VecDeque<f64>,
    in_flight: Option<Launch>,
    /// Plan executing the current launch (lags `sched.active()` while a
    /// committed switch drains).
    serving: usize,
    pending_switch: Option<usize>,
    routed: usize,
    served: usize,
    shed: usize,
    latency: Summary,
    max_queue_depth: usize,
}

impl Dev {
    /// Requests queued or in flight — the router-visible depth.
    fn depth(&self) -> usize {
        self.queue.len() + self.in_flight.as_ref().map_or(0, |l| l.arrivals.len())
    }

    fn view(&self) -> DeviceView {
        let e = &self.sched.front.entries[self.serving];
        DeviceView { depth: self.depth(), latency_ms: e.latency_ms, rps: e.rps }
    }

    /// Start the next launch from the queue if the device is idle.
    fn start_launch(&mut self, t: f64) {
        if self.queue.is_empty() || self.in_flight.is_some() {
            return;
        }
        let e = &self.sched.front.entries[self.serving];
        let take = e.batch.min(self.queue.len());
        let batch: Vec<f64> = self.queue.drain(..take).collect();
        self.in_flight = Some(Launch { done_s: t + e.latency_s(), arrivals: batch });
    }
}

/// Per-device outcome of a fleet simulation.
#[derive(Clone, Debug)]
pub struct DeviceStat {
    pub id: String,
    pub platform: String,
    /// Requests the router sent here (`served + shed`).
    pub routed: usize,
    pub served: usize,
    pub shed: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub max_queue_depth: usize,
    pub switches: Vec<SwitchRecord>,
    pub final_active: usize,
}

/// Outcome of a simulated fleet run.
#[derive(Clone, Debug)]
pub struct FleetSimReport {
    pub arrivals: usize,
    pub served: usize,
    /// All requests not served: per-device admission shedding plus the
    /// `unroutable` ones.
    pub shed: usize,
    /// Subset of `shed` whose model no device serves.
    pub unroutable: usize,
    /// Fleet-wide per-request sojourn times (served requests).
    pub latency: Summary,
    pub slo_violations: usize,
    /// Completion time of the last served request.
    pub makespan_s: f64,
    pub devices: Vec<DeviceStat>,
}

impl FleetSimReport {
    /// `(p50, p99)` sojourn in ms, from one sort.
    pub fn latency_ms(&self) -> (f64, f64) {
        let p = self.latency.percentiles(&[0.50, 0.99]);
        (p[0] * 1e3, p[1] * 1e3)
    }

    pub fn p99_ms(&self) -> f64 {
        self.latency_ms().1
    }

    pub fn slo_attainment(&self) -> f64 {
        if self.served == 0 {
            return 1.0;
        }
        1.0 - self.slo_violations as f64 / self.served as f64
    }

    pub fn total_switches(&self) -> usize {
        self.devices.iter().map(|d| d.switches.len()).sum()
    }

    pub fn summary_line(&self) -> String {
        let (p50, p99) = self.latency_ms();
        format!(
            "{} devices | {} arrivals | {} served, {} shed ({} unroutable) | p50 {p50:.2} ms \
             p99 {p99:.2} ms | SLO attainment {:.1}% | {} plan switches",
            self.devices.len(),
            self.arrivals,
            self.served,
            self.shed,
            self.unroutable,
            self.slo_attainment() * 100.0,
            self.total_switches()
        )
    }
}

/// Simulate serving `mix` on `fleet` with per-device adaptive scheduling
/// under `cfg` and the given routing policy. Fully deterministic for a
/// given seed: per-class arrival streams and the router's sampling stream
/// are all [`Rng::split`] off the one base seed.
pub fn simulate_fleet(
    fleet: &FleetSpec,
    mix: &TrafficMix,
    cfg: &SchedulerCfg,
    policy: RoutePolicy,
    seed: u64,
) -> Result<FleetSimReport, String> {
    if fleet.is_empty() {
        return Err("cannot simulate an empty fleet".into());
    }
    if mix.classes.is_empty() {
        return Err("traffic mix has no classes".into());
    }
    let arrivals = mix.arrivals(seed);
    let base = Rng::new(seed);
    let mut router = Router::new(policy, base.split(ROUTER_STREAM));

    // Class -> devices serving that model.
    let eligible: Vec<Vec<usize>> = mix
        .classes
        .iter()
        .map(|c| {
            fleet
                .devices
                .iter()
                .enumerate()
                .filter(|(_, d)| d.front.model == c.model)
                .map(|(i, _)| i)
                .collect()
        })
        .collect();

    let mut devs: Vec<Dev> = fleet
        .devices
        .iter()
        .map(|d| {
            let sched = AdaptiveScheduler::new(d.front.clone(), *cfg);
            let serving = sched.active();
            Dev {
                sched,
                est: LoadEstimator::new(cfg.horizon_s()),
                queue: VecDeque::new(),
                in_flight: None,
                serving,
                pending_switch: None,
                routed: 0,
                served: 0,
                shed: 0,
                latency: Summary::new(),
                max_queue_depth: 0,
            }
        })
        .collect();

    // round(): same float-truncation guard as the single-device sim.
    let n_windows = (mix.duration_s() / cfg.window_s).round() as usize;
    let slo_s = cfg.slo_ms * 1e-3;

    let mut fleet_latency = Summary::new();
    let mut unroutable = 0usize;
    let mut makespan_s = 0.0f64;
    let mut ai = 0usize; // next arrival index
    let mut w = 0usize; // next window index

    loop {
        let t_arr = arrivals.get(ai).map(|&(t, _)| t).unwrap_or(f64::INFINITY);
        // Earliest completion across devices (tie: lowest device index).
        let mut t_done = f64::INFINITY;
        let mut done_dev = 0usize;
        for (i, d) in devs.iter().enumerate() {
            if let Some(l) = &d.in_flight {
                if l.done_s < t_done {
                    t_done = l.done_s;
                    done_dev = i;
                }
            }
        }
        let t_win = if w < n_windows { (w + 1) as f64 * cfg.window_s } else { f64::INFINITY };
        if t_arr == f64::INFINITY && t_done == f64::INFINITY && t_win == f64::INFINITY {
            break;
        }

        // Same deterministic tie order as the single-device sim:
        // completion, then window tick, then arrival.
        if t_done <= t_win && t_done <= t_arr {
            // -- launch completion (and switch drain point) --------------
            let d = &mut devs[done_dev];
            let launch = d.in_flight.take().unwrap();
            for &a in &launch.arrivals {
                let sojourn = launch.done_s - a;
                d.latency.push(sojourn);
                fleet_latency.push(sojourn);
                d.est.record_completion(launch.done_s, sojourn);
                d.served += 1;
            }
            makespan_s = makespan_s.max(launch.done_s);
            if let Some(to) = d.pending_switch.take() {
                d.serving = to; // drain complete: swap now
            }
            d.start_launch(launch.done_s);
        } else if t_win <= t_arr {
            // -- decision window boundary (all devices) ------------------
            for d in devs.iter_mut() {
                let queue_depth = d.queue.len();
                let snapshot = d.est.estimate(t_win, queue_depth);
                if d.pending_switch.is_none() {
                    if let Some(to) = d.sched.on_window(w, t_win, &snapshot) {
                        if d.in_flight.is_some() {
                            d.pending_switch = Some(to); // drain-and-swap
                        } else {
                            d.serving = to;
                        }
                    }
                }
            }
            w += 1;
        } else {
            // -- arrival: route, then per-device admission ---------------
            let (t, class) = arrivals[ai];
            let views: Vec<DeviceView> = devs.iter().map(Dev::view).collect();
            match router.pick(&views, &eligible[class], cfg.slo_ms) {
                None => unroutable += 1,
                Some(di) => {
                    let d = &mut devs[di];
                    d.routed += 1;
                    d.est.record_arrival(t);
                    if d.sched.admit(d.queue.len()) {
                        d.queue.push_back(t);
                        d.max_queue_depth = d.max_queue_depth.max(d.queue.len());
                        d.start_launch(t);
                    } else {
                        d.shed += 1;
                    }
                }
            }
            ai += 1;
        }
    }

    let served: usize = devs.iter().map(|d| d.served).sum();
    let dev_shed: usize = devs.iter().map(|d| d.shed).sum();
    let slo_violations = served - fleet_latency.count_leq(slo_s);
    let devices: Vec<DeviceStat> = fleet
        .devices
        .iter()
        .zip(devs)
        .map(|(spec, d)| {
            let p = d.latency.percentiles(&[0.50, 0.99]);
            DeviceStat {
                id: spec.id.clone(),
                platform: spec.platform.clone(),
                routed: d.routed,
                served: d.served,
                shed: d.shed,
                p50_ms: p[0] * 1e3,
                p99_ms: p[1] * 1e3,
                max_queue_depth: d.max_queue_depth,
                switches: d.sched.switches.clone(),
                final_active: d.sched.active(),
            }
        })
        .collect();

    Ok(FleetSimReport {
        arrivals: arrivals.len(),
        served,
        shed: dev_shed + unroutable,
        unroutable,
        latency: fleet_latency,
        slo_violations,
        makespan_s,
        devices,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::fleet::{DeviceSpec, FleetSpec};
    use crate::cluster::router::TrafficClass;
    use crate::coordinator::scheduler::RampSpec;
    use crate::plan::front::{FrontEntry, PlanFront};

    fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
        FrontEntry {
            assign: vec![0; 8],
            batch,
            latency_ms: lat_ms,
            tops: rps * 2.5e-3,
            rps,
            nacc: 1,
            label: label.to_string(),
        }
    }

    /// Synthetic two-device fleet over controlled capacities (same shape
    /// as the single-device scheduler tests).
    fn fleet(model: &str) -> FleetSpec {
        let front = PlanFront::new(
            model,
            12,
            vec![
                entry("seq", 1, 0.2, 5000.0),
                entry("hybrid", 6, 1.0, 6000.0),
                entry("spatial", 24, 2.0, 12000.0),
            ],
        )
        .unwrap();
        FleetSpec::new(
            "synthetic",
            vec![
                DeviceSpec {
                    id: "vck190-0".to_string(),
                    platform: "vck190".to_string(),
                    front: front.clone(),
                },
                DeviceSpec {
                    id: "vck190-1".to_string(),
                    platform: "vck190".to_string(),
                    front,
                },
            ],
        )
        .unwrap()
    }

    fn cfg() -> SchedulerCfg {
        SchedulerCfg { slo_ms: 20.0, ..Default::default() }
    }

    #[test]
    fn conservation_per_device_and_fleet_wide() {
        let mix = TrafficMix::single("m", RampSpec::parse("2000:8000:2000", 0.4).unwrap());
        for policy in
            [RoutePolicy::RoundRobin, RoutePolicy::ShortestQueue, RoutePolicy::PowerOfTwoSlo]
        {
            let r = simulate_fleet(&fleet("m"), &mix, &cfg(), policy, 11).unwrap();
            assert_eq!(r.served + r.shed, r.arrivals, "{policy:?} lost requests");
            let routed: usize = r.devices.iter().map(|d| d.routed).sum();
            assert_eq!(routed + r.unroutable, r.arrivals);
            for d in &r.devices {
                assert_eq!(d.served + d.shed, d.routed, "device {} lost requests", d.id);
            }
            assert_eq!(r.latency.len(), r.served);
            // two equal devices under a load-aware policy: neither starves
            assert!(r.devices.iter().all(|d| d.routed > 0), "{policy:?} starved a device");
        }
    }

    #[test]
    fn identical_seed_identical_per_device_tallies() {
        let mix = TrafficMix::single("m", RampSpec::parse("3000:9000", 0.3).unwrap());
        let a = simulate_fleet(&fleet("m"), &mix, &cfg(), RoutePolicy::PowerOfTwoSlo, 5).unwrap();
        let b = simulate_fleet(&fleet("m"), &mix, &cfg(), RoutePolicy::PowerOfTwoSlo, 5).unwrap();
        assert_eq!(a.served, b.served);
        assert_eq!(a.makespan_s, b.makespan_s);
        for (da, db) in a.devices.iter().zip(&b.devices) {
            assert_eq!(da.routed, db.routed);
            assert_eq!(da.served, db.served);
            assert_eq!(da.shed, db.shed);
            assert_eq!(da.switches, db.switches);
        }
        let c = simulate_fleet(&fleet("m"), &mix, &cfg(), RoutePolicy::PowerOfTwoSlo, 6).unwrap();
        assert_ne!(
            a.devices.iter().map(|d| d.routed).collect::<Vec<_>>(),
            c.devices.iter().map(|d| d.routed).collect::<Vec<_>>()
        );
    }

    #[test]
    fn unroutable_model_is_accounted_not_lost() {
        let ramp = RampSpec::parse("1000", 0.3).unwrap();
        let mix = TrafficMix {
            classes: vec![
                TrafficClass { model: "m".to_string(), ramp: ramp.clone() },
                TrafficClass { model: "other".to_string(), ramp },
            ],
        };
        let r = simulate_fleet(&fleet("m"), &mix, &cfg(), RoutePolicy::RoundRobin, 3).unwrap();
        assert!(r.unroutable > 0, "class with no eligible device must be unroutable");
        assert_eq!(r.served + r.shed, r.arrivals);
        // the routable class is still fully served under this light load
        assert_eq!(r.shed, r.unroutable);
    }

    #[test]
    fn two_devices_halve_the_per_device_load() {
        // 8000 req/s across two devices ≈ 4000 each: under each device's
        // seq capacity, so no shedding and p99 well under the SLO.
        let mix = TrafficMix::single("m", RampSpec::parse("2000:8000:2000", 0.4).unwrap());
        let r =
            simulate_fleet(&fleet("m"), &mix, &cfg(), RoutePolicy::PowerOfTwoSlo, 17).unwrap();
        assert_eq!(r.shed, 0, "two-device fleet shed under feasible load");
        assert!(r.p99_ms() <= cfg().slo_ms, "p99 {:.2} ms", r.p99_ms());
        // both devices took a meaningful share of the peak
        let shares: Vec<f64> = r
            .devices
            .iter()
            .map(|d| d.routed as f64 / r.arrivals as f64)
            .collect();
        assert!(shares.iter().all(|&s| s > 0.2), "lopsided split {shares:?}");
    }

    #[test]
    fn rejects_empty_mix() {
        let empty = TrafficMix { classes: vec![] };
        assert!(
            simulate_fleet(&fleet("m"), &empty, &cfg(), RoutePolicy::RoundRobin, 1).is_err()
        );
    }
}
