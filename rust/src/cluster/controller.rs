//! Closed-loop fleet autoscaling: the online counterpart of the offline
//! provisioner.
//!
//! [`crate::cluster::provision`] sizes a fleet *once* against a forecast
//! — the fleet-scale analog of the paper's Table 6 picking one design per
//! latency constraint offline. Real load diverges from forecasts and real
//! devices die, so this module closes the loop: a controller rides the
//! shared event loop ([`run_timeline_recorded`]) and, each decision
//! window, reads every device's [`LoadEstimator`] output (through
//! [`DeviceSim::load_estimate`]) and acts:
//!
//! * **scale out** — fleet utilization (or backlog) above
//!   [`AutoscaleCfg::high_water`] for [`AutoscaleCfg::patience`] control
//!   intervals adds the next device from the provisioner-supplied
//!   candidate pool;
//! * **predictive pre-warm** (opt-in, [`simulate_autoscale_predictive`])
//!   — a Holt double-exponential forecast ([`ForecastCfg`]) over the same
//!   per-device [`LoadEstimator`] rates projects the fleet rate
//!   [`ForecastCfg::horizon`] control intervals ahead; a projected
//!   high-water breach scales out *immediately*, without waiting out the
//!   patience, so capacity is up before a flash crowd lands rather than
//!   after it has already shed;
//! * **scale in** — utilization below [`AutoscaleCfg::low_water`] for
//!   `patience` intervals drains the least-utilized device: the router
//!   stops sending it traffic, its queued requests requeue onto peers,
//!   and it retires when its in-flight launch lands — hitless
//!   decommission;
//! * **fail over** — a deterministic [`FaultSpec`] schedule (seeded via
//!   [`Rng::split`], stream [`FAULT_STREAM`]) kills a device mid-run; its
//!   in-flight and queued work requeues onto survivors with original
//!   arrival times preserved, so the retry cost shows up honestly in the
//!   latency tally;
//! * **hitless front swap** — a fleet-level plan-front update
//!   ([`FrontSwap`], e.g. after a model update) rolls through the fleet
//!   one device at a time: drain onto peers, retire, bring up the
//!   replacement on the new front — never a fleet-wide restart, never two
//!   devices down at once.
//!
//! Requeues are *internal re-dispatches*, not terminal outcomes: every
//! arrival still ends as exactly one of served / shed (admission, no
//! eligible device, or a requeue no survivor could take). Conservation,
//! determinism under a fixed seed, and "autoscaling beats static peak
//! provisioning on device-hours while meeting the SLO on feasible
//! phases" are pinned in `rust/tests/fleet_autoscale.rs`.
//!
//! [`LoadEstimator`]: crate::coordinator::scheduler::LoadEstimator
//! [`Rng::split`]: crate::util::rng::Rng::split

use std::collections::{BTreeMap, VecDeque};

use crate::cluster::fleet::{DeviceSpec, FleetSpec};
use crate::cluster::router::{DeviceView, RoutePolicy, Router, ROUTER_STREAM};
use crate::coordinator::scheduler::SchedulerCfg;
use crate::obs::{NoopRecorder, Recorder};
use crate::plan::front::PlanFront;
use crate::sim::device::{
    run_timeline_recorded, DeviceSim, DeviceState, FleetControl, Req, WindowStat,
};
use crate::sim::service::{ServiceModel, SERVICE_STREAM};
use crate::traffic::{ArrivalStream, TraceSpec};
use crate::util::rng::Rng;
use crate::util::stats::{fmt_ms, Summary};

/// Stream id the fault-injection RNG splits off the base seed (disjoint
/// from the router's `u64::MAX`, the per-class `0..n_classes`, and the
/// live per-device `u64::MAX - 1 - dev` streams).
pub const FAULT_STREAM: u64 = u64::MAX / 2;

// ---------------------------------------------------------------------------
// Control inputs
// ---------------------------------------------------------------------------

/// Knobs of the autoscaling controller (the scheduler-level knobs stay in
/// [`SchedulerCfg`]).
#[derive(Clone, Copy, Debug)]
pub struct AutoscaleCfg {
    /// Fleet utilization (observed rate / committed capacity) above which
    /// the scale-out signal arms.
    pub high_water: f64,
    /// Utilization below which the scale-in signal arms.
    pub low_water: f64,
    /// Consecutive control intervals a breach must persist before the
    /// controller acts (the controller's own hysteresis, distinct from
    /// the per-device scheduler's [`SchedulerCfg::patience`]).
    pub patience: usize,
    /// Control interval, in decision windows: the controller evaluates
    /// the fleet every `control_windows`-th window.
    pub control_windows: usize,
    /// Never scale in below this many serving devices.
    pub min_devices: usize,
}

impl Default for AutoscaleCfg {
    fn default() -> Self {
        AutoscaleCfg {
            high_water: 0.85,
            low_water: 0.30,
            patience: 2,
            control_windows: 2,
            min_devices: 1,
        }
    }
}

impl AutoscaleCfg {
    pub fn validate(&self) -> Result<(), String> {
        if !(self.low_water > 0.0 && self.high_water > self.low_water) {
            return Err(format!(
                "water marks must satisfy 0 < low ({}) < high ({})",
                self.low_water, self.high_water
            ));
        }
        if self.patience == 0 || self.control_windows == 0 {
            return Err("patience and control_windows must be >= 1".into());
        }
        if self.min_devices == 0 {
            return Err("min_devices must be >= 1".into());
        }
        Ok(())
    }
}

/// Knobs of the predictive pre-warm path
/// ([`simulate_autoscale_predictive`]): a Holt double-exponential
/// (level + trend) filter over the fleet-aggregate observed rate, run
/// once per control interval. Kept separate from [`AutoscaleCfg`] on
/// purpose — the reactive controller's config (and therefore its
/// behavior) is untouched when forecasting is off.
#[derive(Clone, Copy, Debug)]
pub struct ForecastCfg {
    /// Level smoothing in (0, 1]: `level += alpha * (rate - level)`.
    pub alpha: f64,
    /// Trend smoothing in [0, 1]: `trend += beta * (Δlevel - trend)`.
    pub beta: f64,
    /// Control intervals of lead time the forecast projects ahead:
    /// `forecast = level + horizon * trend`. This is what buys the
    /// pre-warm — it should cover at least the reactive path's
    /// `patience * control_windows` lag.
    pub horizon: f64,
}

impl Default for ForecastCfg {
    fn default() -> Self {
        ForecastCfg { alpha: 0.5, beta: 0.5, horizon: 3.0 }
    }
}

impl ForecastCfg {
    pub fn validate(&self) -> Result<(), String> {
        if !(self.alpha > 0.0 && self.alpha <= 1.0) {
            return Err(format!("forecast alpha {} must be in (0, 1]", self.alpha));
        }
        if !(self.beta >= 0.0 && self.beta <= 1.0) {
            return Err(format!("forecast beta {} must be in [0, 1]", self.beta));
        }
        if !(self.horizon.is_finite() && self.horizon >= 0.0) {
            return Err(format!("forecast horizon {} must be finite and >= 0", self.horizon));
        }
        Ok(())
    }
}

/// Holt filter state: primed by the first observation (level = rate,
/// trend = 0), then smoothed each control interval.
struct ForecastState {
    cfg: ForecastCfg,
    level: f64,
    trend: f64,
    primed: bool,
}

impl ForecastState {
    fn new(cfg: ForecastCfg) -> ForecastState {
        ForecastState { cfg, level: 0.0, trend: 0.0, primed: false }
    }

    /// Fold in one observed fleet rate; return the rate projected
    /// `horizon` control intervals ahead.
    fn observe(&mut self, rate: f64) -> f64 {
        if !self.primed {
            self.level = rate;
            self.trend = 0.0;
            self.primed = true;
        } else {
            let prev = self.level;
            self.level = self.cfg.alpha * rate + (1.0 - self.cfg.alpha) * self.level;
            self.trend =
                self.cfg.beta * (self.level - prev) + (1.0 - self.cfg.beta) * self.trend;
        }
        self.level + self.cfg.horizon * self.trend
    }
}

/// One scheduled device kill.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultEvent {
    /// Fleet-clock time of the kill (applied at the first decision-window
    /// boundary at or after it; events past the run's last window never
    /// fire).
    pub at_s: f64,
    /// Device id to kill; `None` picks uniformly among live devices via
    /// the [`FAULT_STREAM`] RNG. A named device that is no longer live is
    /// skipped.
    pub device: Option<String>,
}

/// Deterministic failure-injection schedule.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    pub events: Vec<FaultEvent>,
}

impl FaultSpec {
    pub fn none() -> FaultSpec {
        FaultSpec { events: Vec::new() }
    }

    /// Kills at the given times, victims drawn from the fault RNG stream.
    pub fn at(times: &[f64]) -> FaultSpec {
        FaultSpec {
            events: times.iter().map(|&t| FaultEvent { at_s: t, device: None }).collect(),
        }
    }

    /// Parse a CLI schedule like `"0.8,1.2"` (seconds, random victims).
    pub fn parse(csv: &str) -> Result<FaultSpec, String> {
        let mut times = Vec::new();
        for part in csv.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let t: f64 = part.parse().map_err(|e| format!("bad fault time '{part}': {e}"))?;
            if !(t.is_finite() && t >= 0.0) {
                return Err(format!("fault time {t} must be finite and >= 0"));
            }
            times.push(t);
        }
        Ok(FaultSpec::at(&times))
    }

    fn validate(&self) -> Result<(), String> {
        for e in &self.events {
            if !(e.at_s.is_finite() && e.at_s >= 0.0) {
                return Err(format!("fault time {} must be finite and >= 0", e.at_s));
            }
        }
        Ok(())
    }
}

/// A fleet-level plan-front update, rolled out one device at a time
/// (cross-device drain-and-swap): every serving device of `model` is
/// drained onto its peers, retired, and replaced by a fresh device
/// carrying its platform's entry from `fronts`. When the device up next
/// is the model's *last* serving one, its replacement is surged up before
/// the drain so there is never a routing gap. Pool candidates of the same
/// model are updated too, so later scale-outs come up on the new front.
/// Devices of a platform with no entry in `fronts` keep serving the old
/// front.
#[derive(Clone, Debug)]
pub struct FrontSwap {
    /// Fleet-clock time the rollout starts.
    pub at_s: f64,
    /// Model whose fronts are being replaced.
    pub model: String,
    /// Replacement front per platform name.
    pub fronts: BTreeMap<String, PlanFront>,
}

impl FrontSwap {
    fn validate(&self) -> Result<(), String> {
        if !(self.at_s.is_finite() && self.at_s >= 0.0) {
            return Err(format!("swap time {} must be finite and >= 0", self.at_s));
        }
        for (p, f) in &self.fronts {
            if f.model != self.model {
                return Err(format!(
                    "swap front for platform '{p}' serves model '{}', want '{}'",
                    f.model, self.model
                ));
            }
        }
        Ok(())
    }
}

/// Everything an autoscaled run needs beyond the traffic itself.
#[derive(Clone, Debug)]
pub struct AutoscaleSpec {
    /// Devices serving at t = 0.
    pub fleet: FleetSpec,
    /// Scale-out candidates, consumed front to back (typically from
    /// [`crate::cluster::provision::ProvisionResult::scale_pool`]).
    pub pool: Vec<DeviceSpec>,
    pub faults: FaultSpec,
    pub swap: Option<FrontSwap>,
}

// ---------------------------------------------------------------------------
// Control events (the audit log of the run)
// ---------------------------------------------------------------------------

// The audit-event vocabulary (`ScaleOut` / `DrainStart` / `Retired` /
// `Failed` / `SwapReplace`, plus `DrainReason`) was a bespoke private
// enum here; it is now the controller-facing subset of the one
// observability vocabulary, [`crate::obs::TraceEvent`]. The old names
// keep working — `FleetEvent` is the same enum (variants, field names,
// and `describe()` strings unchanged), so `AutoscaleReport::events`
// consumers and the pinned tests in `rust/tests/fleet_autoscale.rs`
// compile and behave as before. The unification buys one audit trail:
// `obs::merge_audit` splices these events into a recorded trace stream
// at their window boundaries.

pub use crate::obs::DrainReason;
/// The controller's audit-event alias of [`crate::obs::TraceEvent`]:
/// `AutoscaleReport::events` only ever holds the audit variants.
pub use crate::obs::TraceEvent as FleetEvent;

// ---------------------------------------------------------------------------
// The controller
// ---------------------------------------------------------------------------

struct DevMeta {
    spec: DeviceSpec,
    added_s: f64,
    /// When the device stopped being live (retired or failed); billed at
    /// window granularity.
    ended_s: Option<f64>,
}

/// The [`FleetControl`] implementation behind [`simulate_autoscale`]:
/// holds the scale-decision hysteresis, the candidate pool, the fault
/// schedule, and the rolling-swap state machine.
struct Controller {
    ctl: AutoscaleCfg,
    sched_cfg: SchedulerCfg,
    /// Distinct models the traffic mix offers — what recovery must keep
    /// covered.
    models: Vec<String>,
    meta: Vec<DevMeta>,
    pool: Vec<DeviceSpec>,
    faults: Vec<FaultEvent>,
    next_fault: usize,
    fault_rng: Rng,
    swap: Option<FrontSwap>,
    /// `None` until the swap triggers; then the captured rollout queue.
    swap_queue: Option<VecDeque<usize>>,
    /// Device currently lifecycle-draining for the swap.
    swap_active: Option<usize>,
    /// The draining device's replacement was surged up *before* the drain
    /// (it was the model's last serving device), so its retirement must
    /// not spawn a second one.
    swap_surged: bool,
    hi_streak: usize,
    lo_streak: usize,
    /// `Some` only on the predictive path
    /// ([`simulate_autoscale_predictive`]); `None` leaves the reactive
    /// controller byte-identical to the pre-forecast one.
    forecast: Option<ForecastState>,
    /// Per-model service distribution from the trace (first class serving
    /// the model wins), applied to every device brought up mid-run.
    services: Vec<(String, ServiceModel)>,
    /// The SERVICE_STREAM split of the base seed; device `i` (its stable
    /// index in the append-only device vector) draws from
    /// `service_base.split(i)` — identical to the static fleet sim's
    /// discipline, extended to scale-outs and swap replacements.
    service_base: Rng,
    events: Vec<FleetEvent>,
}

impl Controller {
    fn new(
        spec: &AutoscaleSpec,
        models: Vec<String>,
        ctl: AutoscaleCfg,
        sched_cfg: SchedulerCfg,
        forecast: Option<ForecastCfg>,
        fault_rng: Rng,
        services: Vec<(String, ServiceModel)>,
        service_base: Rng,
    ) -> Controller {
        let meta = spec
            .fleet
            .devices
            .iter()
            .map(|d| DevMeta { spec: d.clone(), added_s: 0.0, ended_s: None })
            .collect();
        let mut faults = spec.faults.events.clone();
        faults.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        Controller {
            ctl,
            sched_cfg,
            models,
            meta,
            pool: spec.pool.clone(),
            faults,
            next_fault: 0,
            fault_rng,
            swap: spec.swap.clone(),
            swap_queue: None,
            swap_active: None,
            swap_surged: false,
            hi_streak: 0,
            lo_streak: 0,
            forecast: forecast.map(ForecastState::new),
            services,
            service_base,
            events: Vec::new(),
        }
    }

    /// Bring `spec` up as a fresh serving device — the one bring-up path
    /// shared by scale-out, disaster recovery, and swap replacements (the
    /// caller logs its own event). The new device's service stream splits
    /// off its stable index, so a mid-run bring-up draws the same factor
    /// sequence regardless of *when* it appeared.
    fn add_device(&mut self, devs: &mut Vec<DeviceSim>, spec: DeviceSpec, end_s: f64) {
        let service = self
            .services
            .iter()
            .find(|(m, _)| *m == spec.front.model)
            .map(|(_, s)| s.clone())
            .unwrap_or(ServiceModel::Deterministic);
        let service_rng = self.service_base.split(devs.len() as u64);
        devs.push(
            DeviceSim::new(spec.front.clone(), self.sched_cfg).with_service(service, service_rng),
        );
        self.meta.push(DevMeta { spec, added_s: end_s, ended_s: None });
    }

    /// Bring up `old`'s swap replacement (`{id}+swap`) on `front`.
    fn spawn_replacement(
        &mut self,
        devs: &mut Vec<DeviceSim>,
        old: &DeviceSpec,
        front: &PlanFront,
        w: usize,
        end_s: f64,
    ) {
        let new_id = format!("{}+swap", old.id);
        self.events.push(FleetEvent::SwapReplace {
            at_s: end_s,
            window: w,
            old: old.id.clone(),
            new: new_id.clone(),
        });
        let spec =
            DeviceSpec { id: new_id, platform: old.platform.clone(), front: front.clone() };
        self.add_device(devs, spec, end_s);
    }

    /// Drain device `i` and log it (and its immediate retirement, when it
    /// was idle and the drain completes on the spot).
    fn do_drain(
        &mut self,
        devs: &mut [DeviceSim],
        i: usize,
        reason: DrainReason,
        w: usize,
        end_s: f64,
        moved: &mut Vec<Req>,
    ) {
        moved.extend(devs[i].begin_drain());
        let id = self.meta[i].spec.id.clone();
        self.events.push(FleetEvent::DrainStart { at_s: end_s, window: w, id: id.clone(), reason });
        if devs[i].state() == DeviceState::Retired {
            self.meta[i].ended_s = Some(end_s);
            self.events.push(FleetEvent::Retired { at_s: end_s, window: w, id });
        }
    }

    /// Apply every fault event due by `end_s`.
    fn apply_faults(
        &mut self,
        devs: &mut [DeviceSim],
        w: usize,
        end_s: f64,
        moved: &mut Vec<Req>,
    ) {
        while self.next_fault < self.faults.len() && self.faults[self.next_fault].at_s <= end_s {
            let ev = self.faults[self.next_fault].clone();
            self.next_fault += 1;
            let victim = match &ev.device {
                Some(id) => (0..devs.len())
                    .find(|&i| self.meta[i].spec.id == *id && devs[i].is_live()),
                None => {
                    let live: Vec<usize> =
                        (0..devs.len()).filter(|&i| devs[i].is_live()).collect();
                    if live.is_empty() {
                        None
                    } else {
                        Some(live[self.fault_rng.usize_below(live.len())])
                    }
                }
            };
            let Some(v) = victim else { continue };
            let reqs = devs[v].fail();
            self.meta[v].ended_s = Some(end_s);
            self.events.push(FleetEvent::Failed {
                at_s: end_s,
                window: w,
                id: self.meta[v].spec.id.clone(),
                requeued: reqs.len(),
            });
            moved.extend(reqs);
            if self.swap_active == Some(v) {
                // the hardware died mid-swap-drain: no replacement appears
                self.swap_active = None;
            }
        }
    }

    /// Log drains that completed at a launch inside the last window.
    fn sweep_retired(&mut self, devs: &[DeviceSim], w: usize, end_s: f64) {
        for i in 0..devs.len() {
            if devs[i].state() == DeviceState::Retired && self.meta[i].ended_s.is_none() {
                self.meta[i].ended_s = Some(end_s);
                self.events.push(FleetEvent::Retired {
                    at_s: end_s,
                    window: w,
                    id: self.meta[i].spec.id.clone(),
                });
            }
        }
    }

    /// Advance the rolling front swap by at most one step: replace a
    /// finished drain, then start the next device's drain. Strictly one
    /// device down at a time.
    fn step_swap(
        &mut self,
        devs: &mut Vec<DeviceSim>,
        w: usize,
        end_s: f64,
        moved: &mut Vec<Req>,
    ) {
        let Some(swap) = self.swap.take() else { return };
        if self.swap_queue.is_none() {
            if swap.at_s > end_s {
                self.swap = Some(swap);
                return;
            }
            // Trigger: capture the serving devices of the model (rollout
            // order = device order), and refresh matching pool candidates
            // so later scale-outs come up on the new front.
            self.swap_queue = Some(
                (0..devs.len())
                    .filter(|&i| devs[i].is_serving() && devs[i].model() == swap.model)
                    .collect(),
            );
            for p in &mut self.pool {
                if p.front.model == swap.model {
                    if let Some(f) = swap.fronts.get(&p.platform) {
                        p.front = f.clone();
                    }
                }
            }
        }
        // A finished drain brings up its replacement on the new front
        // (unless the replacement was already surged up before the drain).
        if let Some(slot) = self.swap_active {
            match devs[slot].state() {
                DeviceState::Retired => {
                    if !self.swap_surged {
                        let old = self.meta[slot].spec.clone();
                        if let Some(front) = swap.fronts.get(&old.platform) {
                            self.spawn_replacement(devs, &old, front, w, end_s);
                        }
                    }
                    self.swap_active = None;
                    self.swap_surged = false;
                }
                DeviceState::Failed => {
                    // dead hardware: no replacement (a surged one stays)
                    self.swap_active = None;
                    self.swap_surged = false;
                }
                _ => {
                    self.swap = Some(swap);
                    return; // still draining: one at a time
                }
            }
        }
        // Start the next drain of the rollout.
        while self.swap_active.is_none() {
            let Some(i) = self.swap_queue.as_mut().and_then(VecDeque::pop_front) else {
                break;
            };
            if !devs[i].is_serving() {
                continue; // drained or failed since the capture
            }
            let old = self.meta[i].spec.clone();
            let Some(front) = swap.fronts.get(&old.platform) else {
                continue; // no replacement front: keep it on the old plan
            };
            // Hitless even when `i` is the model's last serving device:
            // surge the replacement up *before* draining, so the drain's
            // requeues and subsequent arrivals always have a serving peer.
            let alone = !devs
                .iter()
                .enumerate()
                .any(|(j, d)| j != i && d.is_serving() && d.model() == swap.model);
            if alone {
                self.spawn_replacement(devs, &old, front, w, end_s);
                self.swap_surged = true;
            }
            self.do_drain(devs, i, DrainReason::Swap, w, end_s, moved);
            self.swap_active = Some(i);
        }
        self.swap = Some(swap);
    }

    /// The scale-out / scale-in decision, once per control interval.
    ///
    /// Signals are fleet-aggregate across models: adequate for the
    /// single-model mixes the CLI drives, and per-model *coverage* is
    /// guaranteed separately by [`Controller::recover`] — but one model's
    /// partial overload can be averaged away by another's idle capacity.
    /// Per-model control loops are a ROADMAP follow-on ("Per-model
    /// fleets / placement").
    fn scale(&mut self, devs: &mut Vec<DeviceSim>, w: usize, end_s: f64, moved: &mut Vec<Req>) {
        let active: Vec<usize> = (0..devs.len()).filter(|&i| devs[i].is_serving()).collect();
        if active.is_empty() {
            return; // handled by recover() in after_window
        }
        let cap: f64 = active.iter().map(|&i| devs[i].committed_entry().rps).sum();
        let rate: f64 =
            active.iter().map(|&i| devs[i].load_estimate(end_s).rate_rps).sum();
        let depth: usize = active.iter().map(|&i| devs[i].depth()).sum();
        let util = rate / cap.max(1e-9);
        // Backlog signal: time to drain the standing queue at the fleet's
        // committed capacity. More than one SLO of backlog is overload no
        // matter what the utilization average says.
        let backlog_s = depth as f64 / cap.max(1e-9);
        let slo_s = self.sched_cfg.slo_ms * 1e-3;
        let draining_now = devs.iter().any(|d| d.state() == DeviceState::Draining);

        // Predictive pre-warm: project the fleet rate `horizon` control
        // intervals ahead; a projected high-water breach scales out *now*
        // — waiting out the reactive patience would eat exactly the lead
        // time the forecast bought. Scale-in still goes through the
        // reactive hysteresis below, so the pre-warmed capacity drains
        // once the spike has passed.
        if let Some(f) = self.forecast.as_mut() {
            let projected = f.observe(rate);
            if projected / cap.max(1e-9) > self.ctl.high_water && !self.pool.is_empty() {
                let spec = self.pool.remove(0);
                self.events.push(FleetEvent::ScaleOut {
                    at_s: end_s,
                    window: w,
                    id: spec.id.clone(),
                });
                self.add_device(devs, spec, end_s);
                self.hi_streak = 0;
                self.lo_streak = 0;
                return;
            }
        }

        if util > self.ctl.high_water || backlog_s > slo_s {
            self.hi_streak += 1;
            self.lo_streak = 0;
            if self.hi_streak >= self.ctl.patience && !self.pool.is_empty() {
                let spec = self.pool.remove(0);
                self.events.push(FleetEvent::ScaleOut {
                    at_s: end_s,
                    window: w,
                    id: spec.id.clone(),
                });
                self.add_device(devs, spec, end_s);
                self.hi_streak = 0;
            }
        } else if util < self.ctl.low_water && backlog_s <= slo_s {
            self.lo_streak += 1;
            self.hi_streak = 0;
            if self.lo_streak >= self.ctl.patience
                && active.len() > self.ctl.min_devices
                && !draining_now
            {
                // Least-utilized device leaves; ties prefer the highest
                // index (the most recently added device).
                let victim = active
                    .iter()
                    .copied()
                    .map(|i| {
                        let cap_i = devs[i].committed_entry().rps.max(1e-9);
                        (devs[i].load_estimate(end_s).rate_rps / cap_i, i)
                    })
                    .min_by(|a, b| a.0.total_cmp(&b.0).then(b.1.cmp(&a.1)))
                    .map(|(_, i)| i)
                    .expect("non-empty active set");
                self.do_drain(devs, victim, DrainReason::ScaleIn, w, end_s, moved);
                self.lo_streak = 0;
            }
        } else {
            self.hi_streak = 0;
            self.lo_streak = 0;
        }
    }

    /// Disaster recovery, per traffic model: a model with zero serving
    /// devices must not wait out the patience — bring up a pool device
    /// *of that model* in the same window (this runs every window, not
    /// just control ticks, and before requeues are re-dispatched, so a
    /// lone device's failover work still finds a survivor; and the
    /// fleet-aggregate utilization signal in [`Controller::scale`] can
    /// never average a fully-dead model away).
    fn recover(&mut self, devs: &mut Vec<DeviceSim>, w: usize, end_s: f64) {
        for mi in 0..self.models.len() {
            let covered = devs
                .iter()
                .any(|d| d.is_serving() && d.model() == self.models[mi]);
            if covered {
                continue;
            }
            let Some(pi) =
                self.pool.iter().position(|p| p.front.model == self.models[mi])
            else {
                continue;
            };
            let spec = self.pool.remove(pi);
            self.events.push(FleetEvent::ScaleOut { at_s: end_s, window: w, id: spec.id.clone() });
            self.add_device(devs, spec, end_s);
            self.hi_streak = 0;
            self.lo_streak = 0;
        }
    }
}

impl FleetControl for Controller {
    fn after_window(
        &mut self,
        devs: &mut Vec<DeviceSim>,
        window: usize,
        end_s: f64,
    ) -> Vec<Req> {
        let mut moved = Vec::new();
        self.apply_faults(devs, window, end_s, &mut moved);
        self.sweep_retired(devs, window, end_s);
        self.step_swap(devs, window, end_s, &mut moved);
        self.recover(devs, window, end_s); // no-op while every model is covered
        if (window + 1) % self.ctl.control_windows == 0 {
            self.scale(devs, window, end_s, &mut moved);
        }
        moved
    }
}

// ---------------------------------------------------------------------------
// Report
// ---------------------------------------------------------------------------

/// Per-device outcome of an autoscaled run, lifecycle included.
#[derive(Clone, Debug)]
pub struct AutoscaleDevice {
    pub id: String,
    pub platform: String,
    /// When the device joined the fleet (0 for the initial devices).
    pub added_s: f64,
    /// When it stopped being live (retired/failed); `None` = ran to the
    /// end. Billed at decision-window granularity.
    pub ended_s: Option<f64>,
    pub final_state: DeviceState,
    pub routed: usize,
    pub served: usize,
    pub shed: usize,
    pub requeued_away: usize,
    pub requeued_in: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub max_queue_depth: usize,
    pub switches: usize,
    pub windows: Vec<WindowStat>,
    pub final_committed: usize,
}

/// Outcome of [`simulate_autoscale`].
#[derive(Clone, Debug)]
pub struct AutoscaleReport {
    pub arrivals: usize,
    pub served: usize,
    /// Everything not served: per-device admission shedding + unroutable
    /// arrivals + requeues no survivor could take.
    pub shed: usize,
    /// Arrivals whose model no serving device carried at dispatch time.
    pub unroutable: usize,
    /// Requests displaced by drains and failures (internal re-dispatches;
    /// each still terminates as served or shed exactly once).
    pub requeued: usize,
    /// Displaced requests with no eligible survivor (subset of `shed`).
    pub requeue_lost: usize,
    /// Fleet-wide per-request sojourn times (served requests).
    pub latency: Summary,
    /// `(completion time, sojourn)` per served request, completion order —
    /// use [`AutoscaleReport::latency_for_arrivals_in`] to slice by phase.
    pub completions: Vec<(f64, f64)>,
    pub slo_violations: usize,
    pub makespan_s: f64,
    /// Offered-traffic duration the run was billed over.
    pub duration_s: f64,
    /// Controller actions in commit order.
    pub events: Vec<FleetEvent>,
    /// Every device that ever existed, initial fleet first, then
    /// scale-outs and swap replacements in creation order.
    pub devices: Vec<AutoscaleDevice>,
}

impl AutoscaleReport {
    /// `(p50, p99)` sojourn in ms, from one sort.
    pub fn latency_ms(&self) -> (f64, f64) {
        let p = self.latency.percentiles(&[0.50, 0.99]);
        (p[0] * 1e3, p[1] * 1e3)
    }

    pub fn p99_ms(&self) -> f64 {
        self.latency_ms().1
    }

    pub fn slo_attainment(&self) -> f64 {
        if self.served == 0 {
            return 1.0;
        }
        1.0 - self.slo_violations as f64 / self.served as f64
    }

    /// Total device-seconds billed: the sum of every device's live span
    /// (serving + draining — a draining board is still powered).
    pub fn device_seconds(&self) -> f64 {
        self.devices
            .iter()
            .map(|d| (d.ended_s.unwrap_or(self.duration_s) - d.added_s).max(0.0))
            .sum()
    }

    pub fn device_hours(&self) -> f64 {
        self.device_seconds() / 3600.0
    }

    /// Most devices live at any instant (what static provisioning would
    /// have to buy for the whole run).
    pub fn peak_live_devices(&self) -> usize {
        let mut deltas: Vec<(f64, i32)> = Vec::new();
        for d in &self.devices {
            deltas.push((d.added_s, 1));
            deltas.push((d.ended_s.unwrap_or(self.duration_s), -1));
        }
        // ends sort before starts on ties: a swap's retire + replace at
        // the same boundary counts as one device, not two
        deltas.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let (mut live, mut peak) = (0i32, 0i32);
        for (_, d) in deltas {
            live += d;
            peak = peak.max(live);
        }
        peak.max(0) as usize
    }

    /// Sojourn summary of the served requests that *arrived* within
    /// `[t0, t1)` — per-phase SLO accounting (a request's arrival time is
    /// its completion minus its sojourn).
    pub fn latency_for_arrivals_in(&self, t0: f64, t1: f64) -> Summary {
        let mut s = Summary::new();
        for &(done, sojourn) in &self.completions {
            let arrived = done - sojourn;
            if arrived >= t0 && arrived < t1 {
                s.push(sojourn);
            }
        }
        s
    }

    pub fn summary_line(&self) -> String {
        // Empty-latency runs yield NaN percentiles; fmt_ms prints "-".
        let pct = self.latency.percentiles(&[0.50, 0.99]);
        let (p50, p99) = (fmt_ms(pct[0]), fmt_ms(pct[1]));
        format!(
            "{} arrivals | {} served, {} shed ({} unroutable, {} requeue-lost) | {} requeued \
             | p50 {p50} ms p99 {p99} ms | SLO attainment {:.1}% | {} control events | \
             {:.2} device-s (peak {} live)",
            self.arrivals,
            self.served,
            self.shed,
            self.unroutable,
            self.requeue_lost,
            self.requeued,
            self.slo_attainment() * 100.0,
            self.events.len(),
            self.device_seconds(),
            self.peak_live_devices()
        )
    }
}

// ---------------------------------------------------------------------------
// The autoscaled fleet simulation
// ---------------------------------------------------------------------------

/// Simulate serving `traffic` (anything `Into<`[`TraceSpec`]`>`: a
/// [`crate::cluster::TrafficMix`], a bare ramp, or a full workload trace)
/// on an autoscaled fleet: the same deterministic per-device core and
/// event loop as [`crate::cluster::sim::simulate_fleet`], plus the
/// [`Controller`] acting at window boundaries. Fully deterministic for a
/// given seed (arrival streams, router sampling, and fault victims all
/// derive from it via [`Rng::split`]).
///
/// ```
/// use ssr::cluster::controller::{simulate_autoscale, AutoscaleCfg, AutoscaleSpec, FaultSpec};
/// use ssr::cluster::fleet::{parse_mix, synth_fleet};
/// use ssr::cluster::{RoutePolicy, TrafficMix};
/// use ssr::coordinator::scheduler::{RampSpec, SchedulerCfg};
///
/// let fleet = synth_fleet("f", "deit_t", &parse_mix("vck190:1").unwrap(), &[1, 6]).unwrap();
/// let pool = synth_fleet("p", "deit_t", &parse_mix("vck190:1").unwrap(), &[1, 6]).unwrap();
/// let spec = AutoscaleSpec {
///     fleet,
///     pool: pool.devices.into_iter().map(|mut d| { d.id = "vck190-pool0".into(); d }).collect(),
///     faults: FaultSpec::none(),
///     swap: None,
/// };
/// let mix = TrafficMix::single("deit_t", RampSpec::parse("2000:4000:2000", 0.2).unwrap());
/// let cfg = SchedulerCfg { slo_ms: 25.0, ..Default::default() };
/// let r = simulate_autoscale(&spec, &mix, &cfg, &AutoscaleCfg::default(),
///                            RoutePolicy::PowerOfTwoSlo, 7).unwrap();
/// assert_eq!(r.served + r.shed, r.arrivals); // nothing is ever lost
/// ```
pub fn simulate_autoscale(
    spec: &AutoscaleSpec,
    traffic: impl Into<TraceSpec>,
    cfg: &SchedulerCfg,
    ctl_cfg: &AutoscaleCfg,
    policy: RoutePolicy,
    seed: u64,
) -> Result<AutoscaleReport, String> {
    let mut rec = NoopRecorder;
    simulate_autoscale_inner(spec, traffic.into(), cfg, ctl_cfg, None, policy, seed, &mut rec)
}

/// [`simulate_autoscale`] with a [`Recorder`] observing the run. The
/// report (including its audit `events`) is bit-identical to the
/// unobserved run; the recorder additionally captures the hot-path
/// stream, which [`crate::obs::merge_audit`] can then splice the audit
/// events into for one unified trace.
pub fn simulate_autoscale_observed(
    spec: &AutoscaleSpec,
    traffic: impl Into<TraceSpec>,
    cfg: &SchedulerCfg,
    ctl_cfg: &AutoscaleCfg,
    policy: RoutePolicy,
    seed: u64,
    rec: &mut impl Recorder,
) -> Result<AutoscaleReport, String> {
    simulate_autoscale_inner(spec, traffic.into(), cfg, ctl_cfg, None, policy, seed, rec)
}

/// [`simulate_autoscale`] with the Holt-forecast pre-warm enabled: the
/// controller additionally projects the fleet rate
/// [`ForecastCfg::horizon`] control intervals ahead each control tick and
/// scales out immediately on a projected high-water breach. Everything
/// else — reactive hysteresis, scale-in, faults, swaps, recovery, RNG
/// streams — is byte-identical to the reactive run, so the two reports
/// are directly comparable at equal seeds
/// (`benches/trace_serving.rs` pins predictive shedding strictly less on
/// a flash-crowd trace).
pub fn simulate_autoscale_predictive(
    spec: &AutoscaleSpec,
    traffic: impl Into<TraceSpec>,
    cfg: &SchedulerCfg,
    ctl_cfg: &AutoscaleCfg,
    forecast: &ForecastCfg,
    policy: RoutePolicy,
    seed: u64,
) -> Result<AutoscaleReport, String> {
    forecast.validate()?;
    let mut rec = NoopRecorder;
    simulate_autoscale_inner(
        spec,
        traffic.into(),
        cfg,
        ctl_cfg,
        Some(*forecast),
        policy,
        seed,
        &mut rec,
    )
}

/// [`simulate_autoscale_predictive`] with a [`Recorder`] (see
/// [`simulate_autoscale_observed`]).
#[allow(clippy::too_many_arguments)]
pub fn simulate_autoscale_predictive_observed(
    spec: &AutoscaleSpec,
    traffic: impl Into<TraceSpec>,
    cfg: &SchedulerCfg,
    ctl_cfg: &AutoscaleCfg,
    forecast: &ForecastCfg,
    policy: RoutePolicy,
    seed: u64,
    rec: &mut impl Recorder,
) -> Result<AutoscaleReport, String> {
    forecast.validate()?;
    simulate_autoscale_inner(spec, traffic.into(), cfg, ctl_cfg, Some(*forecast), policy, seed, rec)
}

#[allow(clippy::too_many_arguments)]
fn simulate_autoscale_inner(
    spec: &AutoscaleSpec,
    trace: TraceSpec,
    cfg: &SchedulerCfg,
    ctl_cfg: &AutoscaleCfg,
    forecast: Option<ForecastCfg>,
    policy: RoutePolicy,
    seed: u64,
    rec: &mut impl Recorder,
) -> Result<AutoscaleReport, String> {
    if trace.classes.is_empty() {
        return Err("traffic trace has no classes".into());
    }
    ctl_cfg.validate()?;
    spec.faults.validate()?;
    if let Some(swap) = &spec.swap {
        swap.validate()?;
    }
    // One validation pass over initial fleet + pool together: at least one
    // device, globally unique ids, known platforms.
    let mut all = spec.fleet.devices.clone();
    all.extend(spec.pool.iter().cloned());
    FleetSpec::new(&spec.fleet.name, all)?;

    // Arrivals stream lazily from per-class split RNGs — same merged
    // order the materialized timeline had, O(classes) memory.
    let mut arrivals = ArrivalStream::from_trace(&trace, seed);
    let base = Rng::new(seed);
    let mut router = Router::new(policy, base.split(ROUTER_STREAM));
    let mut model_set: Vec<String> = trace.classes.iter().map(|c| c.model.clone()).collect();
    model_set.sort();
    model_set.dedup();
    // Per-model service distributions (first class serving a model wins)
    // and the dedicated service draw stream — split per stable device
    // index, shared between the initial fleet below and every device the
    // controller brings up later.
    let service_base = base.split(SERVICE_STREAM);
    let services: Vec<(String, ServiceModel)> = trace
        .models()
        .into_iter()
        .map(|m| {
            let s = trace.service_for(&m);
            (m, s)
        })
        .collect();
    let mut ctl = Controller::new(
        spec,
        model_set,
        *ctl_cfg,
        *cfg,
        forecast,
        base.split(FAULT_STREAM),
        services,
        service_base.clone(),
    );
    let mut devs: Vec<DeviceSim> = spec
        .fleet
        .devices
        .iter()
        .enumerate()
        .map(|(i, d)| {
            DeviceSim::new(d.front.clone(), *cfg)
                .with_service(trace.service_for(&d.front.model), service_base.split(i as u64))
        })
        .collect();
    let models: Vec<&str> = trace.classes.iter().map(|c| c.model.as_str()).collect();
    let duration_s = trace.duration_s();

    let outcome = run_timeline_controlled(
        &mut devs,
        &mut arrivals,
        duration_s,
        cfg.window_s,
        |devs, class, _t| {
            // Eligibility is dynamic: only *serving* devices of the
            // class's model — a draining device takes no new traffic, and
            // scale-outs become routable the window they appear.
            let eligible: Vec<usize> = devs
                .iter()
                .enumerate()
                .filter(|(_, d)| d.is_serving() && d.model() == models[class])
                .map(|(i, _)| i)
                .collect();
            let views: Vec<DeviceView> = devs
                .iter()
                .map(|d| {
                    let e = d.committed_entry();
                    DeviceView { depth: d.depth(), latency_ms: e.latency_ms, rps: e.rps }
                })
                .collect();
            router.pick(&views, class, &eligible, cfg.slo_ms)
        },
        &mut ctl,
        rec,
    );

    let devices: Vec<AutoscaleDevice> = ctl
        .meta
        .iter()
        .zip(devs)
        .map(|(m, d)| {
            let r = d.into_report();
            let p = r.latency.percentiles(&[0.50, 0.99]);
            AutoscaleDevice {
                id: m.spec.id.clone(),
                platform: m.spec.platform.clone(),
                added_s: m.added_s,
                ended_s: m.ended_s,
                final_state: r.lifecycle,
                routed: r.routed,
                served: r.served,
                shed: r.shed,
                requeued_away: r.requeued_away,
                requeued_in: r.requeued_in,
                p50_ms: p[0] * 1e3,
                p99_ms: p[1] * 1e3,
                max_queue_depth: r.max_queue_depth,
                switches: r.switches.len(),
                windows: r.windows,
                final_committed: r.final_committed,
            }
        })
        .collect();
    let served: usize = devices.iter().map(|d| d.served).sum();
    let dev_shed: usize = devices.iter().map(|d| d.shed).sum();
    let slo_violations = served - outcome.latency.count_leq(cfg.slo_ms * 1e-3);

    Ok(AutoscaleReport {
        arrivals: outcome.arrivals,
        served,
        shed: dev_shed + outcome.unroutable + outcome.requeue_lost,
        unroutable: outcome.unroutable,
        requeued: outcome.requeued,
        requeue_lost: outcome.requeue_lost,
        latency: outcome.latency,
        completions: outcome.completions,
        slo_violations,
        makespan_s: outcome.makespan_s,
        duration_s,
        events: ctl.events,
        devices,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::router::{TrafficClass, TrafficMix};
    use crate::coordinator::scheduler::RampSpec;
    use crate::plan::front::FrontEntry;

    fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
        FrontEntry {
            assign: vec![0; 8],
            batch,
            latency_ms: lat_ms,
            tops: rps * 2.5e-3,
            rps,
            nacc: 1,
            label: label.to_string(),
        }
    }

    fn front(model: &str) -> PlanFront {
        PlanFront::new(
            model,
            12,
            vec![entry("seq", 1, 0.2, 5000.0), entry("spatial", 24, 2.0, 12000.0)],
        )
        .unwrap()
    }

    fn dev(id: &str, model: &str) -> DeviceSpec {
        DeviceSpec {
            id: id.to_string(),
            platform: "vck190".to_string(),
            front: front(model),
        }
    }

    fn cfg() -> SchedulerCfg {
        SchedulerCfg { slo_ms: 20.0, ..Default::default() }
    }

    fn spec_n(n: usize, pool: usize) -> AutoscaleSpec {
        AutoscaleSpec {
            fleet: FleetSpec::new(
                "t",
                (0..n).map(|i| dev(&format!("d{i}"), "m")).collect(),
            )
            .unwrap(),
            pool: (0..pool).map(|i| dev(&format!("p{i}"), "m")).collect(),
            faults: FaultSpec::none(),
            swap: None,
        }
    }

    #[test]
    fn cfg_and_spec_validation() {
        assert!(AutoscaleCfg::default().validate().is_ok());
        assert!(AutoscaleCfg { low_water: 0.9, ..Default::default() }.validate().is_err());
        assert!(AutoscaleCfg { patience: 0, ..Default::default() }.validate().is_err());
        assert!(AutoscaleCfg { min_devices: 0, ..Default::default() }.validate().is_err());
        let mix = TrafficMix::single("m", RampSpec::parse("1000", 0.2).unwrap());
        // duplicate id across fleet + pool is rejected
        let mut s = spec_n(1, 1);
        s.pool[0].id = "d0".to_string();
        assert!(simulate_autoscale(&s, &mix, &cfg(), &AutoscaleCfg::default(),
                                   RoutePolicy::RoundRobin, 1).is_err());
        // bad fault time
        let mut s = spec_n(1, 0);
        s.faults = FaultSpec { events: vec![FaultEvent { at_s: -1.0, device: None }] };
        assert!(simulate_autoscale(&s, &mix, &cfg(), &AutoscaleCfg::default(),
                                   RoutePolicy::RoundRobin, 1).is_err());
        // swap front for a different model is rejected
        let mut s = spec_n(1, 0);
        s.swap = Some(FrontSwap {
            at_s: 0.1,
            model: "m".to_string(),
            fronts: [("vck190".to_string(), front("other"))].into_iter().collect(),
        });
        assert!(simulate_autoscale(&s, &mix, &cfg(), &AutoscaleCfg::default(),
                                   RoutePolicy::RoundRobin, 1).is_err());
    }

    #[test]
    fn fault_spec_parse() {
        assert_eq!(FaultSpec::parse("").unwrap(), FaultSpec::none());
        let f = FaultSpec::parse("0.8, 1.2").unwrap();
        assert_eq!(f.events.len(), 2);
        assert_eq!(f.events[0], FaultEvent { at_s: 0.8, device: None });
        assert!(FaultSpec::parse("x").is_err());
        assert!(FaultSpec::parse("-1").is_err());
    }

    #[test]
    fn steady_feasible_load_takes_no_control_actions() {
        // 3000 req/s on one device whose seq point serves 5000: util 0.6
        // sits between the water marks; the controller must stay quiet.
        let s = spec_n(1, 2);
        let mix = TrafficMix::single("m", RampSpec::parse("3000:3000:3000", 0.3).unwrap());
        let r = simulate_autoscale(&s, &mix, &cfg(), &AutoscaleCfg::default(),
                                   RoutePolicy::PowerOfTwoSlo, 11).unwrap();
        assert!(r.events.is_empty(), "spurious control events: {:?}", r.events);
        assert_eq!(r.devices.len(), 1);
        assert_eq!(r.requeued, 0);
        assert_eq!(r.served + r.shed, r.arrivals);
        assert!((r.device_seconds() - 0.9).abs() < 1e-9);
    }

    #[test]
    fn forecast_cfg_validation() {
        assert!(ForecastCfg::default().validate().is_ok());
        assert!(ForecastCfg { alpha: 0.0, ..Default::default() }.validate().is_err());
        assert!(ForecastCfg { alpha: 1.5, ..Default::default() }.validate().is_err());
        assert!(ForecastCfg { beta: -0.1, ..Default::default() }.validate().is_err());
        assert!(ForecastCfg { beta: 1.1, ..Default::default() }.validate().is_err());
        assert!(ForecastCfg { horizon: -1.0, ..Default::default() }.validate().is_err());
        assert!(ForecastCfg { horizon: 0.0, ..Default::default() }.validate().is_ok());
    }

    #[test]
    fn holt_filter_tracks_level_and_extrapolates_trend() {
        // alpha = beta = 1 degenerates to level = rate, trend = Δrate, so
        // the projection is exactly linear extrapolation.
        let mut f = ForecastState::new(ForecastCfg { alpha: 1.0, beta: 1.0, horizon: 2.0 });
        assert_eq!(f.observe(100.0), 100.0); // primed: trend 0
        assert_eq!(f.observe(200.0), 400.0); // 200 + 2 * 100
        assert_eq!(f.observe(300.0), 500.0); // 300 + 2 * 100
        // a flat series forecasts itself regardless of smoothing
        let mut f = ForecastState::new(ForecastCfg { alpha: 0.3, beta: 0.2, horizon: 5.0 });
        for _ in 0..50 {
            f.observe(800.0);
        }
        assert!((f.observe(800.0) - 800.0).abs() < 1e-9);
    }

    #[test]
    fn predictive_on_steady_feasible_load_takes_no_control_actions() {
        // Flat 3000 req/s on a 5000-capacity device: the forecast settles
        // on the observed rate, projects no breach, and the predictive run
        // stays as quiet (and as cheap) as the reactive one.
        let s = spec_n(1, 2);
        let mix = TrafficMix::single("m", RampSpec::parse("3000:3000:3000", 0.3).unwrap());
        let r = simulate_autoscale_predictive(
            &s, &mix, &cfg(), &AutoscaleCfg::default(), &ForecastCfg::default(),
            RoutePolicy::PowerOfTwoSlo, 11,
        )
        .unwrap();
        assert!(r.events.is_empty(), "spurious control events: {:?}", r.events);
        assert_eq!(r.devices.len(), 1);
        assert_eq!(r.served + r.shed, r.arrivals);
        let reactive = simulate_autoscale(&s, &mix, &cfg(), &AutoscaleCfg::default(),
                                          RoutePolicy::PowerOfTwoSlo, 11).unwrap();
        assert_eq!(r.served, reactive.served);
        assert_eq!(r.makespan_s, reactive.makespan_s);
        assert_eq!(r.device_seconds(), reactive.device_seconds());
    }

    #[test]
    fn unroutable_class_is_counted_not_lost() {
        let s = spec_n(1, 0);
        let ramp = RampSpec::parse("1000", 0.2).unwrap();
        let mix = TrafficMix {
            classes: vec![
                TrafficClass { model: "m".to_string(), ramp: ramp.clone() },
                TrafficClass { model: "ghost".to_string(), ramp },
            ],
        };
        let r = simulate_autoscale(&s, &mix, &cfg(), &AutoscaleCfg::default(),
                                   RoutePolicy::RoundRobin, 5).unwrap();
        assert!(r.unroutable > 0);
        assert_eq!(r.served + r.shed, r.arrivals);
    }
}
