//! Fleet specification: which devices exist, what board each one is, and
//! the plan front each serves.
//!
//! A [`FleetSpec`] is the cluster-level analog of a single device's
//! [`PlanFront`] — the interchange artifact between provisioning and
//! serving:
//!
//! ```text
//!   ssr cluster provision --ramp ... --slo-ms 2 --out fleet.json
//!   ssr cluster simulate  --fleet fleet.json --ramp ...   # deterministic
//!   ssr cluster serve     --fleet fleet.json --ramp ...   # live PJRT
//! ```
//!
//! Devices reference their board by `arch` name (`vck190`, `stratix10nx`,
//! `zcu102`, `u250`, ...), so the power model can be re-derived after a
//! JSON round-trip without serializing platform constants.

use std::collections::BTreeMap;
use std::path::Path;

use crate::analytical::Calib;
use crate::arch::{self, AnyPlatform};
use crate::baselines::heatvit;
use crate::dse::Assignment;
use crate::graph::{builder, vit_graph};
use crate::plan::front::{analytical_front, FrontEntry, PlanFront};
use crate::util::json::Json;

/// One device of the fleet: a board identity plus the front it serves.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceSpec {
    /// Unique device id (e.g. `vck190-0`).
    pub id: String,
    /// Board name resolvable via [`arch::by_name`].
    pub platform: String,
    /// The latency-throughput front this device holds live.
    pub front: PlanFront,
}

impl DeviceSpec {
    /// The board behind this device (validated at fleet construction).
    pub fn board(&self) -> AnyPlatform {
        arch::by_name(&self.platform).expect("platform validated at fleet construction")
    }
}

/// A named set of devices — possibly heterogeneous in both board and
/// front shape.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetSpec {
    pub name: String,
    pub devices: Vec<DeviceSpec>,
}

impl FleetSpec {
    /// Validating constructor: at least one device, unique ids, known
    /// platform names (fronts are validated by [`PlanFront`] itself).
    pub fn new(name: &str, devices: Vec<DeviceSpec>) -> Result<FleetSpec, String> {
        if devices.is_empty() {
            return Err("fleet has no devices".into());
        }
        let mut seen = std::collections::BTreeSet::new();
        for d in &devices {
            if !seen.insert(d.id.clone()) {
                return Err(format!("duplicate device id '{}'", d.id));
            }
            if arch::by_name(&d.platform).is_none() {
                return Err(format!("device '{}' has unknown platform '{}'", d.id, d.platform));
            }
        }
        Ok(FleetSpec { name: name.to_string(), devices })
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Distinct models served anywhere in the fleet.
    pub fn models(&self) -> Vec<String> {
        let mut out: Vec<String> = self.devices.iter().map(|d| d.front.model.clone()).collect();
        out.sort();
        out.dedup();
        out
    }

    pub fn to_json(&self) -> Json {
        let devices: Vec<Json> = self
            .devices
            .iter()
            .map(|d| {
                let mut m = BTreeMap::new();
                m.insert("id".to_string(), Json::Str(d.id.clone()));
                m.insert("platform".to_string(), Json::Str(d.platform.clone()));
                m.insert("front".to_string(), d.front.to_json());
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("devices".to_string(), Json::Arr(devices));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<FleetSpec, String> {
        let name = j.get("name").and_then(Json::as_str).ok_or("fleet missing 'name'")?;
        let mut devices = Vec::new();
        for (i, d) in j
            .get("devices")
            .and_then(Json::as_arr)
            .ok_or("fleet missing 'devices'")?
            .iter()
            .enumerate()
        {
            devices.push(DeviceSpec {
                id: d
                    .get("id")
                    .and_then(Json::as_str)
                    .ok_or_else(|| format!("device {i} missing 'id'"))?
                    .to_string(),
                platform: d
                    .get("platform")
                    .and_then(Json::as_str)
                    .ok_or_else(|| format!("device {i} missing 'platform'"))?
                    .to_string(),
                front: PlanFront::from_json(
                    d.get("front").ok_or_else(|| format!("device {i} missing 'front'"))?,
                )?,
            });
        }
        FleetSpec::new(name, devices)
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string() + "\n")
    }

    pub fn load(path: &Path) -> Result<FleetSpec, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        FleetSpec::from_json(&Json::parse(&text)?)
    }

    /// One line per device, for CLI output.
    pub fn describe(&self) -> String {
        let mut out = format!("fleet '{}' ({} devices):\n", self.name, self.len());
        for d in &self.devices {
            let lat_lo = d.front.entries.first().map(|e| e.latency_ms).unwrap_or(0.0);
            let lat_hi = d.front.entries.last().map(|e| e.latency_ms).unwrap_or(0.0);
            let rps_hi = d.front.entries.last().map(|e| e.rps).unwrap_or(0.0);
            out.push_str(&format!(
                "  {:<14} {:<12} {:<10} {} plans, {:.2}-{:.2} ms, up to {:.0} img/s\n",
                d.id,
                d.platform,
                d.front.model,
                d.front.len(),
                lat_lo,
                lat_hi,
                rps_hi
            ));
        }
        out
    }
}

/// Serving front of one device of `platform` for `model`, synthesized
/// from the analytical models: Versal-class boards get the three
/// canonical SSR strategies (sequential / spatial / hybrid) evaluated
/// across `batches` — the same construction as the adaptive bench —
/// while monolithic FPGA boards get their HeatViT-style engine at each
/// batch depth (sequential-only: every class on acc 0).
pub fn device_front(platform: &str, model: &str, batches: &[usize]) -> Result<PlanFront, String> {
    let board =
        arch::by_name(platform).ok_or_else(|| format!("unknown platform '{platform}'"))?;
    let cfg = builder::by_name(model).ok_or_else(|| format!("unknown model '{model}'"))?;
    let g = vit_graph(cfg);
    match board {
        AnyPlatform::Versal(p) => {
            let candidates = vec![
                ("sequential".to_string(), Assignment::sequential()),
                ("spatial".to_string(), Assignment::spatial()),
                ("hybrid".to_string(), Assignment::new(vec![0, 1, 1, 1, 0, 2, 2, 0])),
            ];
            analytical_front(&p, &Calib::default(), &g, &candidates, batches)
        }
        AnyPlatform::Fpga(f) => {
            let cal = heatvit::calib_for(&f);
            let entries: Vec<FrontEntry> = batches
                .iter()
                .map(|&b| {
                    let lat_s = heatvit::latency_s(&f, &cal, &g, b);
                    FrontEntry {
                        assign: vec![0; 8],
                        batch: b,
                        latency_ms: lat_s * 1e3,
                        tops: heatvit::tops(&f, &cal, &g, b),
                        rps: b as f64 / lat_s,
                        nacc: 1,
                        label: "monolithic".to_string(),
                    }
                })
                .collect();
            PlanFront::new(&g.model, g.depth, entries)
        }
    }
}

/// Front of one named strategy on a Versal platform, evaluated across
/// `batches` — the honest homogeneous-policy baseline for provisioning
/// comparisons. (Restricting the *pruned* full front would understate a
/// pure strategy: e.g. sequential b6 is dominated by hybrid points and
/// pruned there, yet it is the best a seq-only fleet can do.)
pub fn strategy_front(
    platform: &str,
    model: &str,
    strategy: &str,
    batches: &[usize],
) -> Result<PlanFront, String> {
    let board =
        arch::by_name(platform).ok_or_else(|| format!("unknown platform '{platform}'"))?;
    let AnyPlatform::Versal(p) = board else {
        return Err(format!("'{platform}' is a monolithic board; it has no strategy choice"));
    };
    let assignment = match strategy {
        "sequential" => Assignment::sequential(),
        "spatial" => Assignment::spatial(),
        "hybrid" => Assignment::new(vec![0, 1, 1, 1, 0, 2, 2, 0]),
        other => return Err(format!("unknown strategy '{other}'")),
    };
    let cfg = builder::by_name(model).ok_or_else(|| format!("unknown model '{model}'"))?;
    let g = vit_graph(cfg);
    analytical_front(
        &p,
        &Calib::default(),
        &g,
        &[(strategy.to_string(), assignment)],
        batches,
    )
}

/// Restrict a front to entries with provenance `label` — the homogeneous
/// policy baselines ("sequential"-only / "spatial"-only fleets) that the
/// provisioning comparisons run against.
pub fn restrict_front(front: &PlanFront, label: &str) -> Result<PlanFront, String> {
    PlanFront::new(
        &front.model,
        front.depth,
        front.entries.iter().filter(|e| e.label == label).cloned().collect(),
    )
}

/// Synthesize a heterogeneous fleet from `(platform, count)` pairs, each
/// device carrying that platform's analytical front for `model`. Device
/// ids are `{platform}-{k}`.
///
/// ```
/// use ssr::cluster::fleet::{parse_mix, synth_fleet};
///
/// let mix = parse_mix("vck190:2,u250:1").unwrap();
/// let fleet = synth_fleet("edge", "deit_t", &mix, &[1, 6]).unwrap();
/// assert_eq!(fleet.len(), 3);
/// assert_eq!(fleet.devices[0].id, "vck190-0");
/// assert_eq!(fleet.models(), vec!["deit_t".to_string()]);
/// // round-trips through JSON unchanged — the provision -> serve artifact
/// let back = ssr::cluster::FleetSpec::from_json(&fleet.to_json()).unwrap();
/// assert_eq!(back, fleet);
/// ```
pub fn synth_fleet(
    name: &str,
    model: &str,
    mix: &[(String, usize)],
    batches: &[usize],
) -> Result<FleetSpec, String> {
    // Aggregate repeated platforms (e.g. "vck190:1,vck190:2") so device
    // numbering stays unique, preserving first-appearance order.
    let mut totals: Vec<(String, usize)> = Vec::new();
    for (platform, count) in mix {
        match totals.iter_mut().find(|(p, _)| p == platform) {
            Some((_, c)) => *c += count,
            None => totals.push((platform.clone(), *count)),
        }
    }
    let mut devices = Vec::new();
    for (platform, count) in &totals {
        if *count == 0 {
            continue;
        }
        let front = device_front(platform, model, batches)?;
        for k in 0..*count {
            devices.push(DeviceSpec {
                id: format!("{platform}-{k}"),
                platform: platform.clone(),
                front: front.clone(),
            });
        }
    }
    FleetSpec::new(name, devices)
}

/// Parse a CLI fleet mix like `"vck190:2,u250:1"`.
pub fn parse_mix(s: &str) -> Result<Vec<(String, usize)>, String> {
    let mut out = Vec::new();
    for part in s.split(',').filter(|p| !p.trim().is_empty()) {
        let (name, count) = part
            .split_once(':')
            .ok_or_else(|| format!("bad mix part '{part}' (want platform:count)"))?;
        let count: usize =
            count.trim().parse().map_err(|e| format!("bad count in '{part}': {e}"))?;
        out.push((name.trim().to_string(), count));
    }
    if out.is_empty() {
        return Err(format!("empty fleet mix '{s}'"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn versal_front_spans_the_tradeoff_and_fpga_front_is_monolithic() {
        let v = device_front("vck190", "deit_t", &[1, 3, 6]).unwrap();
        assert!(v.len() >= 2);
        // the tradeoff's corners: lowest latency is the 1-acc sequential
        // point, highest rate is a multi-acc (spatial/hybrid) point
        assert_eq!(v.entries.first().unwrap().label, "sequential");
        assert!(v.entries.last().unwrap().nacc >= 3);
        let f = device_front("u250", "deit_t", &[1, 3, 6]).unwrap();
        assert!(f.entries.iter().all(|e| e.label == "monolithic" && e.nacc == 1));
        // a monolithic U250 cannot touch the Versal front's throughput
        let v_best = v.entries.last().unwrap().rps;
        let f_best = f.entries.last().unwrap().rps;
        assert!(v_best > 5.0 * f_best, "vck {v_best} vs u250 {f_best}");
        assert!(device_front("tpu_v9", "deit_t", &[1]).is_err());
        assert!(device_front("vck190", "nope", &[1]).is_err());
    }

    #[test]
    fn strategy_front_is_pure_and_fpga_boards_reject_it() {
        let seq = strategy_front("vck190", "deit_t", "sequential", &[1, 3, 6]).unwrap();
        assert!(seq.entries.iter().all(|e| e.label == "sequential" && e.nacc == 1));
        let spa = strategy_front("vck190", "deit_t", "spatial", &[1, 3, 6]).unwrap();
        assert!(spa.entries.iter().all(|e| e.label == "spatial" && e.nacc == 8));
        // the pure-strategy capacities bracket the paper's tradeoff
        let seq_best = seq.entries.last().unwrap().rps;
        let spa_best = spa.entries.last().unwrap().rps;
        assert!(spa_best > seq_best, "spatial {spa_best} <= sequential {seq_best}");
        assert!(strategy_front("zcu102", "deit_t", "sequential", &[1]).is_err());
        assert!(strategy_front("vck190", "deit_t", "nope", &[1]).is_err());
    }

    #[test]
    fn restrict_front_keeps_only_the_label() {
        let v = device_front("vck190", "deit_t", &[1, 3, 6]).unwrap();
        let seq = restrict_front(&v, "sequential").unwrap();
        assert!(seq.entries.iter().all(|e| e.label == "sequential"));
        assert!(restrict_front(&v, "no-such-label").is_err());
    }

    #[test]
    fn synth_fleet_ids_and_validation() {
        let mix = parse_mix("vck190:2,u250:1").unwrap();
        let fleet = synth_fleet("edge", "deit_t", &mix, &[1, 6]).unwrap();
        assert_eq!(fleet.len(), 3);
        assert_eq!(fleet.devices[0].id, "vck190-0");
        assert_eq!(fleet.devices[2].id, "u250-0");
        assert_eq!(fleet.models(), vec!["deit_t".to_string()]);
        // a platform listed twice aggregates instead of colliding on ids
        let dup = parse_mix("vck190:1,vck190:2").unwrap();
        let fleet = synth_fleet("dup", "deit_t", &dup, &[1]).unwrap();
        assert_eq!(fleet.len(), 3);
        assert_eq!(fleet.devices[2].id, "vck190-2");
        // zero-count platforms are dropped, empty fleets rejected
        assert!(synth_fleet("x", "deit_t", &[("vck190".to_string(), 0)], &[1]).is_err());
        assert!(parse_mix("vck190").is_err());
        assert!(parse_mix("").is_err());
        assert!(parse_mix("vck190:x").is_err());
    }

    #[test]
    fn fleet_json_round_trip() {
        let mix = parse_mix("vck190:1,zcu102:1").unwrap();
        let fleet = synth_fleet("rt", "deit_t", &mix, &[1, 6]).unwrap();
        let back = FleetSpec::from_json(&Json::parse(&fleet.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back, fleet);
        let path = std::env::temp_dir().join("ssr_fleet_roundtrip.json");
        fleet.save(&path).unwrap();
        let loaded = FleetSpec::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(loaded, fleet);
    }

    #[test]
    fn fleet_validation_rejects_bad_specs() {
        let front = device_front("vck190", "deit_t", &[1]).unwrap();
        let dev = |id: &str, platform: &str| DeviceSpec {
            id: id.to_string(),
            platform: platform.to_string(),
            front: front.clone(),
        };
        assert!(FleetSpec::new("empty", vec![]).is_err());
        assert!(FleetSpec::new("dup", vec![dev("a", "vck190"), dev("a", "vck190")]).is_err());
        assert!(FleetSpec::new("bad", vec![dev("a", "tpu_v9")]).is_err());
        assert!(FleetSpec::new("ok", vec![dev("a", "vck190"), dev("b", "u250")]).is_ok());
    }
}
