//! Provisioning optimizer: turn a traffic forecast + SLO into a fleet.
//!
//! Given the platform options (each a board name plus the plan front one
//! device of it can serve), a workload forecast (anything
//! `Into<`[`TraceSpec`]`>` — a bare [`RampSpec`](crate::traffic::RampSpec)
//! ramp, a multi-class mix, a diurnal or flash-crowd trace), and a latency
//! SLO, pick the platform mix and per-device serving point that covers the
//! forecast peak ([`TraceSpec::peak_rps`]) with the fewest devices,
//! breaking ties by total power:
//!
//! 1. per platform, the serving point is the Table 6 cell
//!    ([`PlanFront::best_under`]) derated by the scheduler's target
//!    utilization (`headroom`), so provisioned devices run below
//!    saturation and the adaptive scheduler can absorb transients;
//! 2. an exact bounded DFS enumerates every mix whose capacity covers the
//!    peak, pruned by the best device count found so far (a capacity
//!    lower bound keeps it exact);
//! 3. the feasible mixes are Pareto-pruned on (devices, watts) via
//!    [`pareto_indices`] — the same machinery that prunes the DSE's
//!    latency-throughput front — and the min-device / min-power corner is
//!    emitted as a ready-to-serve [`FleetSpec`].
//!
//! Power per device comes from [`power_w_generic`] with the board
//! constants from [`arch::by_name`], evaluated at the derated operating
//! point (utilization = headroom of the chosen entry's throughput).

use crate::analytical::energy::power_w_generic;
use crate::arch;
use crate::cluster::fleet::{DeviceSpec, FleetSpec};
use crate::dse::pareto::{pareto_indices, Point};
use crate::traffic::TraceSpec;
use crate::plan::front::PlanFront;

/// One platform the provisioner may buy devices of.
#[derive(Clone, Debug)]
pub struct PlatformOption {
    /// Board name resolvable via [`arch::by_name`].
    pub platform: String,
    /// Front one device of this platform serves.
    pub front: PlanFront,
}

impl PlatformOption {
    /// Synthesize the option from the analytical models
    /// ([`crate::cluster::fleet::device_front`]).
    pub fn synth(platform: &str, model: &str, batches: &[usize]) -> Result<PlatformOption, String> {
        Ok(PlatformOption {
            platform: platform.to_string(),
            front: crate::cluster::fleet::device_front(platform, model, batches)?,
        })
    }
}

/// Per-platform slice of a provisioned fleet.
#[derive(Clone, Debug)]
pub struct ProvisionChoice {
    pub platform: String,
    pub count: usize,
    /// Front entry each device of this platform serves at the peak.
    pub entry_idx: usize,
    pub entry_label: String,
    /// Headroom-derated per-device service rate (req/s).
    pub capacity_rps: f64,
    /// Per-device watts at the derated operating point.
    pub device_w: f64,
}

/// Outcome of [`provision`].
#[derive(Clone, Debug)]
pub struct ProvisionResult {
    pub peak_rps: f64,
    pub slo_ms: f64,
    /// Platforms with non-zero counts, in option order.
    pub choices: Vec<ProvisionChoice>,
    pub devices: usize,
    /// Total derated capacity (req/s).
    pub capacity_rps: f64,
    /// Total fleet power at the provisioned operating point (watts).
    pub power_w: f64,
    /// The ready-to-serve fleet (full fronts — the per-device scheduler
    /// still adapts below the provisioned peak).
    pub fleet: FleetSpec,
}

impl ProvisionResult {
    /// Scale-out candidate pool for the online controller
    /// ([`crate::cluster::controller`]): `extra` more devices cycling
    /// through the provisioned platform mix, with ids
    /// (`{platform}-scale{k}`) disjoint from the fleet's own
    /// (`{platform}-{k}`).
    pub fn scale_pool(&self, extra: usize) -> Vec<DeviceSpec> {
        (0..extra)
            .map(|k| {
                let d = &self.fleet.devices[k % self.fleet.len()];
                DeviceSpec {
                    id: format!("{}-scale{k}", d.platform),
                    platform: d.platform.clone(),
                    front: d.front.clone(),
                }
            })
            .collect()
    }

    pub fn describe(&self) -> String {
        let mut out = format!(
            "provisioned {} devices for {:.0} req/s peak under {} ms SLO \
             ({:.0} req/s capacity, {:.1} W):\n",
            self.devices, self.peak_rps, self.slo_ms, self.capacity_rps, self.power_w
        );
        for c in &self.choices {
            out.push_str(&format!(
                "  {:>2} x {:<12} serving [{}] {:<12} {:.0} req/s/device, {:.1} W/device\n",
                c.count, c.platform, c.entry_idx, c.entry_label, c.capacity_rps, c.device_w
            ));
        }
        out
    }
}

/// One SLO-feasible platform candidate, with its derated serving point.
struct Cand {
    opt_idx: usize,
    entry_idx: usize,
    cap_rps: f64,
    device_w: f64,
}

/// Enumerate counts per candidate (DFS). Exact within the per-platform
/// bound `ceil(peak / cap)` (more of one platform than covers the peak
/// alone is never count-optimal): prunes only branches that provably
/// cannot tie the best device count, so every count-minimal mix is kept
/// for the power tie-break.
#[allow(clippy::too_many_arguments)]
fn search(
    cands: &[Cand],
    i: usize,
    counts: &mut Vec<usize>,
    used: usize,
    cap: f64,
    watts: f64,
    peak: f64,
    max_cap: f64,
    best: &mut usize,
    out: &mut Vec<(Vec<usize>, usize, f64)>,
) {
    let deficit = (peak - cap).max(0.0);
    let lower_bound = (deficit / max_cap).ceil() as usize;
    if used + lower_bound > *best {
        return;
    }
    if i == cands.len() {
        if cap + 1e-9 >= peak {
            *best = (*best).min(used);
            out.push((counts.clone(), used, watts));
        }
        return;
    }
    let enough_alone = (peak / cands[i].cap_rps).ceil() as usize;
    let bound = enough_alone.min(*best - used);
    for n in 0..=bound {
        counts.push(n);
        search(
            cands,
            i + 1,
            counts,
            used + n,
            cap + n as f64 * cands[i].cap_rps,
            watts + n as f64 * cands[i].device_w,
            peak,
            max_cap,
            best,
            out,
        );
        counts.pop();
    }
}

/// Provision a fleet for the `forecast` workload under `slo_ms`: minimum
/// device count first, minimum power among count-minimal mixes second.
/// The sizing peak is [`TraceSpec::peak_rps`] — for a ramp forecast the
/// exact max-fold over phase rates this function always used.
/// `headroom` is the target utilization the devices are sized at
/// (matching [`crate::coordinator::scheduler::SchedulerCfg::headroom`]).
pub fn provision(
    name: &str,
    options: &[PlatformOption],
    forecast: impl Into<TraceSpec>,
    slo_ms: f64,
    headroom: f64,
) -> Result<ProvisionResult, String> {
    if options.is_empty() {
        return Err("no platform options to provision from".into());
    }
    {
        let mut names: Vec<&str> = options.iter().map(|o| o.platform.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        if names.len() != options.len() {
            return Err("duplicate platform in provisioning options".into());
        }
    }
    let peak = forecast.into().peak_rps();
    if peak <= 0.0 {
        return Err("forecast offers no load".into());
    }
    let headroom = headroom.clamp(0.05, 1.0);

    let mut cands = Vec::new();
    for (i, o) in options.iter().enumerate() {
        let board = arch::by_name(&o.platform)
            .ok_or_else(|| format!("unknown platform '{}'", o.platform))?;
        let Some(entry_idx) = o.front.best_under(slo_ms) else {
            continue; // this platform cannot meet the SLO at all
        };
        let e = &o.front.entries[entry_idx];
        cands.push(Cand {
            opt_idx: i,
            entry_idx,
            cap_rps: e.rps * headroom,
            device_w: power_w_generic(
                board.static_w(),
                board.dyn_w(),
                board.peak_int8_tops(),
                e.tops * headroom,
            ),
        });
    }
    if cands.is_empty() {
        return Err(format!("no platform option meets the {slo_ms} ms SLO"));
    }

    let max_cap = cands.iter().map(|c| c.cap_rps).fold(0.0, f64::max);
    // A feasible upper bound: the best single-platform fleet.
    let mut best = cands
        .iter()
        .map(|c| (peak / c.cap_rps).ceil() as usize)
        .min()
        .expect("non-empty candidates");
    let mut feasible: Vec<(Vec<usize>, usize, f64)> = Vec::new();
    search(
        &cands,
        0,
        &mut Vec::with_capacity(cands.len()),
        0,
        0.0,
        0.0,
        peak,
        max_cap,
        &mut best,
        &mut feasible,
    );
    if feasible.is_empty() {
        return Err("provisioning search found no feasible mix".into());
    }

    // Pareto on (devices, watts): encode devices as the latency axis and
    // negated watts as the throughput axis so pareto_indices' ordering
    // (latency asc, ties by tops desc) surfaces the min-count / min-power
    // corner at index 0.
    let points: Vec<Point> = feasible
        .iter()
        .map(|(_, n, w)| Point { latency_ms: *n as f64, tops: -*w, batch: 0, nacc: 0 })
        .collect();
    let idx = pareto_indices(&points);
    let (counts, devices, power_w) = feasible[idx[0]].clone();

    let mut choices = Vec::new();
    let mut fleet_devices = Vec::new();
    let mut capacity_rps = 0.0;
    for (ci, c) in cands.iter().enumerate() {
        let n = counts[ci];
        if n == 0 {
            continue;
        }
        let o = &options[c.opt_idx];
        let e = &o.front.entries[c.entry_idx];
        choices.push(ProvisionChoice {
            platform: o.platform.clone(),
            count: n,
            entry_idx: c.entry_idx,
            entry_label: e.label.clone(),
            capacity_rps: c.cap_rps,
            device_w: c.device_w,
        });
        capacity_rps += n as f64 * c.cap_rps;
        for k in 0..n {
            fleet_devices.push(DeviceSpec {
                id: format!("{}-{k}", o.platform),
                platform: o.platform.clone(),
                front: o.front.clone(),
            });
        }
    }
    let fleet = FleetSpec::new(name, fleet_devices)?;
    Ok(ProvisionResult { peak_rps: peak, slo_ms, choices, devices, capacity_rps, power_w, fleet })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::front::FrontEntry;
    use crate::traffic::RampSpec;

    /// Synthetic single-entry option with controlled capacity/tops (the
    /// platform name only feeds the power constants).
    fn option(platform: &str, rps: f64, tops: f64, lat_ms: f64) -> PlatformOption {
        PlatformOption {
            platform: platform.to_string(),
            front: PlanFront::new(
                "m",
                12,
                vec![FrontEntry {
                    assign: vec![0; 8],
                    batch: 1,
                    latency_ms: lat_ms,
                    tops,
                    rps,
                    nacc: 1,
                    label: "pt".to_string(),
                }],
            )
            .unwrap(),
        }
    }

    fn ramp(peak: f64) -> RampSpec {
        RampSpec::parse(&format!("100:{peak}:100"), 0.5).unwrap()
    }

    #[test]
    fn single_platform_count_is_the_ceiling() {
        let opts = [option("vck190", 10_000.0, 20.0, 1.0)];
        let r = provision("f", &opts, &ramp(24_000.0), 5.0, 1.0).unwrap();
        assert_eq!(r.devices, 3);
        assert_eq!(r.choices.len(), 1);
        assert_eq!(r.fleet.len(), 3);
        assert!(r.capacity_rps + 1e-9 >= 24_000.0);
        // headroom derates capacity: at 0.5 the same peak needs double
        let r = provision("f", &opts, &ramp(24_000.0), 5.0, 0.5).unwrap();
        assert_eq!(r.devices, 5);
    }

    #[test]
    fn equal_count_breaks_ties_by_power() {
        // both cover the peak with one device; zcu102 burns far less
        let opts =
            [option("vck190", 10_000.0, 20.0, 1.0), option("zcu102", 5_000.0, 0.63, 1.0)];
        let r = provision("f", &opts, &ramp(4_000.0), 5.0, 1.0).unwrap();
        assert_eq!(r.devices, 1);
        assert_eq!(r.choices[0].platform, "zcu102");
        // count still dominates power: at 9000 only vck190 manages 1 device
        let r = provision("f", &opts, &ramp(9_000.0), 5.0, 1.0).unwrap();
        assert_eq!(r.devices, 1);
        assert_eq!(r.choices[0].platform, "vck190");
    }

    #[test]
    fn heterogeneous_mix_beats_homogeneous_on_power() {
        // peak 12000: 2x vck190 (108 W) vs 1x vck190 + 1x zcu102 (~66 W);
        // both are 2 devices, the mixed fleet wins the power tie-break
        let opts =
            [option("vck190", 10_000.0, 20.0, 1.0), option("zcu102", 5_000.0, 0.63, 1.0)];
        let r = provision("f", &opts, &ramp(12_000.0), 5.0, 1.0).unwrap();
        assert_eq!(r.devices, 2);
        let platforms: Vec<&str> = r.choices.iter().map(|c| c.platform.as_str()).collect();
        assert_eq!(platforms, vec!["vck190", "zcu102"]);
        assert_eq!(r.fleet.len(), 2);
        assert!(r.fleet.devices.iter().any(|d| d.platform == "zcu102"));
    }

    #[test]
    fn slo_filters_platforms_and_can_make_provisioning_infeasible() {
        let opts =
            [option("vck190", 10_000.0, 20.0, 1.0), option("zcu102", 50_000.0, 0.63, 30.0)];
        // 2 ms SLO excludes the 30 ms zcu102 point despite its huge rate
        let r = provision("f", &opts, &ramp(9_000.0), 2.0, 1.0).unwrap();
        assert_eq!(r.choices[0].platform, "vck190");
        assert!(provision("f", &opts, &ramp(9_000.0), 0.5, 1.0).is_err());
    }

    #[test]
    fn headroom_derates_the_best_under_serving_point() {
        let opts = [option("vck190", 10_000.0, 20.0, 1.0)];
        // the Table 6 cell serves 10k req/s; sized at 60% utilization a
        // device only counts for 6k, so a 9k peak needs two of them
        let r = provision("f", &opts, &ramp(9_000.0), 5.0, 0.6).unwrap();
        assert_eq!(r.devices, 2);
        assert!((r.choices[0].capacity_rps - 6_000.0).abs() < 1e-9);
        // power is evaluated at the derated operating point, so it sits
        // strictly below the same entry's full-tilt power
        let full = provision("f", &opts, &ramp(9_000.0), 5.0, 1.0).unwrap();
        assert!(
            r.choices[0].device_w < full.choices[0].device_w,
            "derated {} W !< full {} W",
            r.choices[0].device_w,
            full.choices[0].device_w
        );
        // out-of-range headroom clamps instead of corrupting capacity
        let hi = provision("f", &opts, &ramp(9_000.0), 5.0, 7.0).unwrap();
        assert!((hi.choices[0].capacity_rps - 10_000.0).abs() < 1e-9);
        let lo = provision("f", &opts, &ramp(900.0), 5.0, 0.0).unwrap();
        assert!((lo.choices[0].capacity_rps - 500.0).abs() < 1e-9, "clamps to 0.05");
    }

    #[test]
    fn scale_pool_ids_disjoint_and_fronts_match_the_fleet() {
        let opts = [option("vck190", 10_000.0, 20.0, 1.0)];
        let r = provision("f", &opts, &ramp(24_000.0), 5.0, 1.0).unwrap();
        assert_eq!(r.devices, 3);
        let pool = r.scale_pool(2);
        assert_eq!(pool.len(), 2);
        let mut ids: Vec<String> = r.fleet.devices.iter().map(|d| d.id.clone()).collect();
        ids.extend(pool.iter().map(|d| d.id.clone()));
        let n = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n, "pool ids collide with the fleet");
        assert_eq!(pool[0].front, r.fleet.devices[0].front);
        assert!(r.scale_pool(0).is_empty());
    }

    #[test]
    fn rejects_degenerate_inputs() {
        let o = option("vck190", 10_000.0, 20.0, 1.0);
        assert!(provision("f", &[], &ramp(1000.0), 5.0, 1.0).is_err());
        assert!(provision("f", &[o.clone(), o.clone()], &ramp(1000.0), 5.0, 1.0).is_err());
        let idle = RampSpec::parse("0:0", 0.5).unwrap();
        assert!(provision("f", &[o], &idle, 5.0, 1.0).is_err());
    }
}
