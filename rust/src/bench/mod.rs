//! Criterion-style bench harness (offline substitute).
//!
//! All `benches/*.rs` use `harness = false` and drive this: warmup, timed
//! iterations, summary stats, and aligned table printing so each bench
//! reproduces its paper table/figure as rows on stdout.

use crate::util::json::Json;
use crate::util::stats::Summary;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Timing result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

/// Run `f` repeatedly: `warmup` untimed iterations, then timed iterations.
///
/// Loop-termination contract (unit-tested below):
/// * exactly one timed iteration always runs, even with `max_iters == 0`
///   or `max_seconds <= 0` — the stats are never empty (no NaN means);
/// * never more than `max(max_iters, 1)` iterations run;
/// * no new iteration starts once `max_seconds` has elapsed — the time
///   budget binds as soon as one sample exists, so a slow case stops at
///   its first over-budget iteration instead of grinding out a minimum.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, max_iters: usize, max_seconds: f64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    let t0 = Instant::now();
    let max_iters = max_iters.max(1);
    let mut iters = 0;
    loop {
        let it = Instant::now();
        f();
        s.push(it.elapsed().as_secs_f64());
        iters += 1;
        if iters >= max_iters || t0.elapsed().as_secs_f64() >= max_seconds {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: s.mean(),
        p50_s: s.p50(),
        p99_s: s.p99(),
        min_s: s.min(),
    }
}

impl BenchResult {
    /// Machine-readable form for the CI perf-regression artifact.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("mean_s".to_string(), Json::Num(self.mean_s));
        m.insert("p50_s".to_string(), Json::Num(self.p50_s));
        m.insert("p99_s".to_string(), Json::Num(self.p99_s));
        m.insert("min_s".to_string(), Json::Num(self.min_s));
        Json::Obj(m)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>6} iters  mean {:>10}  p50 {:>10}  p99 {:>10}",
            self.name,
            self.iters,
            fmt_s(self.mean_s),
            fmt_s(self.p50_s),
            fmt_s(self.p99_s)
        )
    }
}

/// Write bench results as a JSON artifact (`{"results": [...]}`), the
/// machine-readable output behind every bench's `--json <path>` flag. CI
/// uploads these so the perf trajectory is tracked per commit instead of
/// scrolling away in logs.
pub fn write_json(path: &Path, results: &[BenchResult]) -> std::io::Result<()> {
    write_json_with_metrics(path, results, &[])
}

/// [`write_json`] plus a flat `"metrics"` object of named scalars —
/// throughput counters, allocation tallies, and other numbers a timing
/// row can't carry (`{"results": [...], "metrics": {...}}`). An empty
/// `metrics` slice omits the object, so plain callers keep the old shape.
pub fn write_json_with_metrics(
    path: &Path,
    results: &[BenchResult],
    metrics: &[(String, f64)],
) -> std::io::Result<()> {
    let mut m = std::collections::BTreeMap::new();
    m.insert(
        "results".to_string(),
        Json::Arr(results.iter().map(BenchResult::to_json).collect()),
    );
    if !metrics.is_empty() {
        let mm = metrics.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect();
        m.insert("metrics".to_string(), Json::Obj(mm));
    }
    std::fs::write(path, Json::Obj(m).to_string() + "\n")
}

/// `--json <path>` / `--json=<path>` from the process args (shared by the
/// `benches/*.rs` mains, which run with `harness = false`).
pub fn json_path_from_args() -> Option<PathBuf> {
    let args: Vec<String> = std::env::args().collect();
    for (i, a) in args.iter().enumerate() {
        if let Some(v) = a.strip_prefix("--json=") {
            return Some(PathBuf::from(v));
        }
        if a == "--json" {
            return args.get(i + 1).map(PathBuf::from);
        }
    }
    None
}

/// Human time formatting.
pub fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.1} us", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Simple fixed-width table printer for paper-style tables.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String> + Clone>(headers: &[S]) -> Self {
        Table {
            headers: headers.iter().cloned().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String> + Clone>(&mut self, cells: &[S]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.iter().cloned().map(Into::into).collect());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            w[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str(&format!("{:<width$}  ", c, width = w[i]));
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        out.push_str(&format!(
            "{}\n",
            w.iter().map(|n| "-".repeat(*n + 2)).collect::<String>()
        ));
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_to_max_iters_within_budget() {
        let mut count = 0;
        let r = bench("noop", 1, 5, 100.0, || count += 1);
        assert_eq!(r.iters, 5);
        assert!(r.mean_s >= 0.0);
        assert_eq!(count, r.iters + 1); // +1 warmup
    }

    #[test]
    fn bench_single_iteration_mode() {
        let mut count = 0;
        let r = bench("once", 0, 1, 100.0, || count += 1);
        assert_eq!(r.iters, 1);
        assert_eq!(count, 1);
    }

    #[test]
    fn bench_time_budget_stops_slow_cases_after_one_sample() {
        // A case slower than the whole budget must stop at its first
        // iteration instead of grinding toward a minimum count.
        let mut count = 0;
        let r = bench("slow", 0, 1000, 0.0, || {
            count += 1;
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert_eq!(r.iters, 1);
        assert_eq!(count, 1);
        assert!(r.mean_s.is_finite());
    }

    #[test]
    fn bench_zero_max_iters_still_samples_once() {
        // max_iters == 0 clamps to one iteration: stats stay well-defined.
        let mut count = 0;
        let r = bench("zero", 0, 0, 100.0, || count += 1);
        assert_eq!(r.iters, 1);
        assert_eq!(count, 1);
        assert!(r.mean_s.is_finite() && r.p99_s.is_finite());
    }

    #[test]
    fn json_artifact_round_trips() {
        let r = bench("json-case", 0, 2, 100.0, || {});
        let path = std::env::temp_dir().join("ssr_bench_json_test.json");
        write_json(&path, std::slice::from_ref(&r)).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let j = Json::parse(&text).unwrap();
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("json-case"));
        assert_eq!(results[0].get("iters").unwrap().as_usize(), Some(2));
        assert!(results[0].get("p99_s").unwrap().as_f64().is_some());
    }

    #[test]
    fn json_metrics_round_trip_and_plain_shape_is_unchanged() {
        let r = bench("metrics-case", 0, 1, 100.0, || {});
        let path = std::env::temp_dir().join("ssr_bench_json_metrics_test.json");
        let metrics = vec![("events_per_s".to_string(), 1.25e7), ("peak_bytes".to_string(), 4096.0)];
        write_json_with_metrics(&path, std::slice::from_ref(&r), &metrics).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("metrics").unwrap().get("events_per_s").unwrap().as_f64(), Some(1.25e7));
        assert_eq!(j.get("metrics").unwrap().get("peak_bytes").unwrap().as_f64(), Some(4096.0));
        // Empty metrics keeps the legacy single-key shape.
        write_json_with_metrics(&path, std::slice::from_ref(&r), &[]).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(j.get("metrics").is_none());
        assert!(j.get("results").is_some());
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_s(2.5).ends_with(" s"));
        assert!(fmt_s(2.5e-3).ends_with(" ms"));
        assert!(fmt_s(2.5e-6).ends_with(" us"));
        assert!(fmt_s(2.5e-9).ends_with(" ns"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "long_header"]);
        t.row(&["x", "1"]);
        t.row(&["yyyy", "2"]);
        let s = t.render();
        assert!(s.contains("long_header"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one"]);
    }
}
