//! Criterion-style bench harness (offline substitute).
//!
//! All `benches/*.rs` use `harness = false` and drive this: warmup, timed
//! iterations, summary stats, and aligned table printing so each bench
//! reproduces its paper table/figure as rows on stdout.

use crate::util::stats::Summary;
use std::time::Instant;

/// Timing result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

/// Run `f` repeatedly: `warmup` untimed iterations, then timed iterations
/// until `max_iters` or `max_seconds` elapses (at least 3).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, max_iters: usize, max_seconds: f64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    let t0 = Instant::now();
    let mut iters = 0;
    let min_iters = max_iters.clamp(1, 3);
    while iters < max_iters.max(1)
        && (iters < min_iters || t0.elapsed().as_secs_f64() < max_seconds)
    {
        let it = Instant::now();
        f();
        s.push(it.elapsed().as_secs_f64());
        iters += 1;
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: s.mean(),
        p50_s: s.p50(),
        p99_s: s.p99(),
        min_s: s.min(),
    }
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>6} iters  mean {:>10}  p50 {:>10}  p99 {:>10}",
            self.name,
            self.iters,
            fmt_s(self.mean_s),
            fmt_s(self.p50_s),
            fmt_s(self.p99_s)
        )
    }
}

/// Human time formatting.
pub fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.1} us", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Simple fixed-width table printer for paper-style tables.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String> + Clone>(headers: &[S]) -> Self {
        Table {
            headers: headers.iter().cloned().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String> + Clone>(&mut self, cells: &[S]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.iter().cloned().map(Into::into).collect());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            w[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str(&format!("{:<width$}  ", c, width = w[i]));
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        out.push_str(&format!(
            "{}\n",
            w.iter().map(|n| "-".repeat(*n + 2)).collect::<String>()
        ));
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_minimum_iters() {
        let mut count = 0;
        let r = bench("noop", 1, 5, 0.0, || count += 1);
        assert!(r.iters >= 3);
        assert!(r.mean_s >= 0.0);
        assert_eq!(count, r.iters + 1); // +1 warmup
    }

    #[test]
    fn bench_single_iteration_mode() {
        let mut count = 0;
        let r = bench("once", 0, 1, 100.0, || count += 1);
        assert_eq!(r.iters, 1);
        assert_eq!(count, 1);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_s(2.5).ends_with(" s"));
        assert!(fmt_s(2.5e-3).ends_with(" ms"));
        assert!(fmt_s(2.5e-6).ends_with(" us"));
        assert!(fmt_s(2.5e-9).ends_with(" ns"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "long_header"]);
        t.row(&["x", "1"]);
        t.row(&["yyyy", "2"]);
        let s = t.render();
        assert!(s.contains("long_header"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one"]);
    }
}
