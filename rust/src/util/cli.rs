//! Declarative flag parser for the `ssr` binary (offline clap substitute).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and generated `--help` text.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub takes_value: bool,
}

#[derive(Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    flags: Vec<FlagSpec>,
}

#[derive(Debug, Default)]
pub struct Matches {
    values: BTreeMap<String, String>,
    pub positionals: Vec<String>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, flags: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, default: Option<&'static str>, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default, takes_value: true });
        self
    }

    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None, takes_value: false });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nflags:\n", self.name, self.about);
        for f in &self.flags {
            let d = f
                .default
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<22} {}{}\n", f.name, f.help, d));
        }
        s
    }

    /// Parse `args` (excluding argv[0]); returns Err with usage on problems.
    pub fn parse(&self, args: &[String]) -> Result<Matches, String> {
        let mut m = Matches::default();
        for f in &self.flags {
            if let Some(d) = f.default {
                m.values.insert(f.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n\n{}", self.usage()))?;
                let value = if !spec.takes_value {
                    if inline.is_some() {
                        return Err(format!("flag --{name} takes no value"));
                    }
                    "true".to_string()
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    args.get(i)
                        .cloned()
                        .ok_or_else(|| format!("flag --{name} needs a value"))?
                };
                m.values.insert(name.to_string(), value);
            } else {
                m.positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(m)
    }
}

impl Matches {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> String {
        self.get(name).unwrap_or_default().to_string()
    }

    pub fn usize(&self, name: &str) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("flag --{name} is not a usize"))
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("flag --{name} is not a number"))
    }

    pub fn bool(&self, name: &str) -> bool {
        self.get(name) == Some("true")
    }

    /// Comma-separated list of usizes (`--batches 1,3,6`).
    pub fn usize_list(&self, name: &str) -> Vec<usize> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().expect("bad list element"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("t", "test")
            .flag("model", Some("deit_t"), "model name")
            .flag("batch", Some("1"), "batch size")
            .switch("verbose", "chatty")
    }

    fn parse(args: &[&str]) -> Result<Matches, String> {
        cmd().parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn defaults_apply() {
        let m = parse(&[]).unwrap();
        assert_eq!(m.str("model"), "deit_t");
        assert_eq!(m.usize("batch"), 1);
        assert!(!m.bool("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let m = parse(&["--model", "lv_vit_t", "--batch=6", "--verbose"]).unwrap();
        assert_eq!(m.str("model"), "lv_vit_t");
        assert_eq!(m.usize("batch"), 6);
        assert!(m.bool("verbose"));
    }

    #[test]
    fn positionals_collected() {
        let m = parse(&["serve", "--batch", "3", "extra"]).unwrap();
        assert_eq!(m.positionals, vec!["serve", "extra"]);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(parse(&["--nope"]).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = parse(&["--help"]).unwrap_err();
        assert!(err.contains("--model"));
    }

    #[test]
    fn usize_list_parses() {
        let c = Command::new("t", "t").flag("batches", Some("1,3,6"), "");
        let m = c.parse(&[]).unwrap();
        assert_eq!(m.usize_list("batches"), vec![1, 3, 6]);
    }
}
