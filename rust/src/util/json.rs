//! Minimal JSON: enough to read the artifact manifest and write reports.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs are passed
//! through unvalidated. Numbers are parsed as f64 (the manifest only holds
//! shapes/ids, all exactly representable).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Render compactly (reports) — deterministic key order via BTreeMap.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 char
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e1").unwrap(), Json::Num(-25.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"empty":[],"nested":{"k":false}}"#;
        let j = Json::parse(src).unwrap();
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".to_string())
        );
    }

    #[test]
    fn whitespace_tolerant() {
        let j = Json::parse(" {\n \"a\" : [ 1 , 2 ] }\n").unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 2);
    }
}
