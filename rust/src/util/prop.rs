//! Mini property-testing driver (offline proptest substitute).
//!
//! `check` runs a property over `cases` randomly generated inputs; on
//! failure it attempts a bounded greedy shrink (caller-provided shrinker)
//! and panics with the seed + minimal counterexample so the failure replays
//! deterministically.

use super::rng::Rng;

pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, seed: 0x55AA_1234, max_shrink_steps: 200 }
    }
}

/// Run `prop` on `cases` inputs drawn by `gen`. On failure, greedily shrink
/// with `shrink` (returns candidate smaller inputs) and panic with context.
pub fn check_with<T, G, P, S>(cfg: &Config, name: &str, mut gen: G, prop: P, shrink: S)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // greedy shrink: first failing candidate wins, repeat
            let mut cur = input.clone();
            let mut cur_msg = msg;
            let mut steps = 0;
            'outer: while steps < cfg.max_shrink_steps {
                for cand in shrink(&cur) {
                    steps += 1;
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        cur_msg = m;
                        continue 'outer;
                    }
                    if steps >= cfg.max_shrink_steps {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (seed={:#x}, case {case}):\n  \
                 input: {cur:?}\n  error: {cur_msg}",
                cfg.seed
            );
        }
    }
}

/// Convenience: no shrinking.
pub fn check<T, G, P>(cfg: &Config, name: &str, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    check_with(cfg, name, gen, prop, |_| Vec::new());
}

/// Standard shrinker for Vec<usize>-like assignment genomes: try removing
/// tail elements and halving values.
pub fn shrink_usize_vec(v: &Vec<usize>) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    if v.len() > 1 {
        out.push(v[..v.len() - 1].to_vec());
        out.push(v[..v.len() / 2].to_vec());
    }
    for (i, &x) in v.iter().enumerate() {
        if x > 0 {
            let mut c = v.clone();
            c[i] = x / 2;
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(
            &Config { cases: 64, ..Default::default() },
            "sum-commutes",
            |r| (r.below(100), r.below(100)),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn failing_property_panics_with_name() {
        check(
            &Config { cases: 4, ..Default::default() },
            "always-fails",
            |r| r.below(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn shrinker_reduces_counterexample() {
        // Property: all elements < 7. Gen produces some >= 7; shrunk failure
        // should still violate, and halving drives elements toward 7's
        // minimal violator.
        let result = std::panic::catch_unwind(|| {
            check_with(
                &Config { cases: 32, seed: 9, ..Default::default() },
                "small-elems",
                |r| vec![r.usize_below(20), r.usize_below(20)],
                |v: &Vec<usize>| {
                    if v.iter().all(|&x| x < 7) {
                        Ok(())
                    } else {
                        Err(format!("{v:?} has elem >= 7"))
                    }
                },
                shrink_usize_vec,
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("small-elems"));
        // the shrunk vector should be short
        assert!(msg.contains("input: ["));
    }
}
