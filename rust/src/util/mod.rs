//! Build-from-scratch utility substrates.
//!
//! The build environment is fully offline (only the `xla` crate and its
//! transitive deps are vendored), so the usual ecosystem crates are
//! reimplemented here as small, tested modules:
//!
//! * [`rng`] — deterministic SplitMix64 / xoshiro256** PRNG (EA search,
//!   property tests, synthetic inputs),
//! * [`stats`] — streaming summary statistics (mean/percentiles) for the
//!   bench harness and coordinator metrics,
//! * [`json`] — minimal JSON parser/writer (artifact manifest, reports),
//! * [`cli`] — declarative flag parser for the `ssr` binary,
//! * [`threadpool`] — fixed thread pool (DSE fan-out, coordinator stages),
//! * [`prop`] — mini property-testing driver used by invariant tests.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
