//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Determinism is load-bearing: the evolutionary search (Algorithm 1) must be
//! reproducible run-to-run for EXPERIMENTS.md, and the property tests must
//! replay failures from a printed seed.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm),
/// seeded through SplitMix64 as the authors recommend.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-thread / per-island search).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Derive a deterministic independent stream keyed by `stream_id`
    /// *without* advancing this generator: stream `i` of a given state is
    /// the same no matter how many other streams were split off before it.
    /// This is what per-device serving wants (device k's load stream must
    /// not shift when a fleet adds device k+1); [`Rng::fork`] is for
    /// consume-and-go forking inside one search.
    pub fn split(&self, stream_id: u64) -> Rng {
        // Golden-ratio-stride the id (a bijection on u64, so distinct ids
        // can never collapse to one seed) and mix in the full parent state.
        let mut sm = self.s[0]
            ^ self.s[1].rotate_left(17)
            ^ self.s[2].rotate_left(31)
            ^ self.s[3].rotate_left(47)
            ^ stream_id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` — provably unbiased via Lemire's multiply-shift
    /// rejection (never the plain-modulo reduction, which over-weights the
    /// low residues for any `n` that does not divide 2^64).
    ///
    /// Why this is exact: `x * n` maps the 2^64 inputs onto `n` buckets of
    /// `hi = floor(x*n / 2^64)`; bucket `hi` holds either `floor(2^64/n)`
    /// or `ceil(2^64/n)` inputs, and the inputs whose low half `lo` falls
    /// below `t = 2^64 mod n` are exactly the surplus ones. Rejecting
    /// `lo < t` (the `lo >= n` arm only short-circuits the `%` for the
    /// common case, since `t < n`) leaves every bucket with exactly
    /// `floor(2^64/n)` accepted inputs — uniform. P2c pair sampling over
    /// non-power-of-two fleets depends on this; pinned by the chi-square
    /// tests below.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn below_covers_all_residues() {
        let mut r = Rng::new(9);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            seen[r.below(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    /// Pearson chi-square statistic of `hit` against a uniform expectation.
    fn chi_square(hit: &[usize]) -> f64 {
        let draws: usize = hit.iter().sum();
        let expect = draws as f64 / hit.len() as f64;
        hit.iter()
            .map(|&h| {
                let d = h as f64 - expect;
                d * d / expect
            })
            .sum()
    }

    #[test]
    fn below_is_chi_square_uniform_for_non_power_of_two_n() {
        // n = 7 (2^64 mod 7 != 0, so plain modulo WOULD be biased) over
        // 70k draws. Deterministic seed, so the statistic is a constant;
        // 33.0 is roughly the p = 1e-5 critical value at df = 6 — a
        // healthy rejection-sampled generator sits far under it, while a
        // real bug (say an off-by-one in the rejection threshold skewing
        // one bucket by a few percent) lands in the hundreds.
        let mut r = Rng::new(0xD1CE);
        let mut hit = [0usize; 7];
        for _ in 0..70_000 {
            hit[r.below(7) as usize] += 1;
        }
        let chi2 = chi_square(&hit);
        assert!(chi2 < 33.0, "below(7) non-uniform: chi2 = {chi2:.2}, counts {hit:?}");
    }

    #[test]
    fn p2c_pair_sampling_is_chi_square_uniform() {
        // The router's pair draw over a non-power-of-two fleet: i from
        // usize_below(n), j from usize_below(n-1) shifted past i. All
        // n*(n-1) ordered pairs of a 5-device fleet must be equally
        // likely; 56.0 is roughly the p = 1e-5 critical value at df = 19.
        let n = 5usize;
        let mut r = Rng::new(0xFA1E);
        let mut hit = vec![0usize; n * n];
        for _ in 0..40_000 {
            let i = r.usize_below(n);
            let mut j = r.usize_below(n - 1);
            if j >= i {
                j += 1;
            }
            hit[i * n + j] += 1;
        }
        // diagonal cells must be structurally impossible
        for i in 0..n {
            assert_eq!(hit[i * n + i], 0, "pair sampler produced (i, i)");
        }
        let off_diag: Vec<usize> = (0..n * n)
            .filter(|k| k / n != k % n)
            .map(|k| hit[k])
            .collect();
        let chi2 = chi_square(&off_diag);
        assert!(chi2 < 56.0, "pair sampling non-uniform: chi2 = {chi2:.2}");
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn range_inclusive_bounds_hit() {
        let mut r = Rng::new(11);
        let (mut lo_hit, mut hi_hit) = (false, false);
        for _ in 0..1000 {
            let x = r.range(-2, 2);
            assert!((-2..=2).contains(&x));
            lo_hit |= x == -2;
            hi_hit |= x == 2;
        }
        assert!(lo_hit && hi_hit);
    }

    #[test]
    fn split_streams_disjoint_on_first_1k_draws() {
        // 8 per-device streams, 1k draws each: all 8000 u64s distinct (a
        // collision among random 64-bit values at this count would be a
        // ~2e-13 event, i.e. a correlated-stream bug).
        let base = Rng::new(0xC1u64);
        let mut seen = std::collections::HashSet::new();
        for dev in 0..8u64 {
            let mut s = base.split(dev);
            for _ in 0..1000 {
                seen.insert(s.next_u64());
            }
        }
        assert_eq!(seen.len(), 8000, "split streams overlap");
    }

    #[test]
    fn split_is_stable_and_does_not_advance_parent() {
        let base = Rng::new(7);
        let a: Vec<u64> = {
            let mut s = base.split(3);
            (0..10).map(|_| s.next_u64()).collect()
        };
        // splitting other ids in between must not move stream 3
        let _ = base.split(0);
        let _ = base.split(99);
        let b: Vec<u64> = {
            let mut s = base.split(3);
            (0..10).map(|_| s.next_u64()).collect()
        };
        assert_eq!(a, b);
        // and the parent state is untouched: same draws as a fresh twin
        let mut parent = base.clone();
        let mut twin = Rng::new(7);
        for _ in 0..10 {
            assert_eq!(parent.next_u64(), twin.next_u64());
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(1);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        let a: Vec<u64> = (0..10).map(|_| f1.next_u64()).collect();
        let b: Vec<u64> = (0..10).map(|_| f2.next_u64()).collect();
        assert_ne!(a, b);
    }
}
