//! Fixed-size thread pool (offline rayon/tokio substitute).
//!
//! Used by the DSE to evaluate EA populations in parallel and by the
//! coordinator for background work. Plain `std::thread` + channel fan-out;
//! `scope_map` provides the only primitive the hot paths need: parallel map
//! over a slice with deterministic output order.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Long-lived pool of worker threads pulling jobs from a shared queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("ssr-pool-{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().unwrap().recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool workers gone");
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Parallel map preserving input order, using scoped threads (no 'static
/// bound on inputs). Chunks the work across at most `threads` workers.
pub fn scope_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let mut out: Vec<Option<R>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);
    let chunk = items.len().div_ceil(threads);
    thread::scope(|s| {
        for (ci, (in_chunk, out_chunk)) in
            items.chunks(chunk).zip(out.chunks_mut(chunk)).enumerate()
        {
            let f = &f;
            thread::Builder::new()
                .name(format!("ssr-map-{ci}"))
                .spawn_scoped(s, move || {
                    for (x, slot) in in_chunk.iter().zip(out_chunk.iter_mut()) {
                        *slot = Some(f(x));
                    }
                })
                .expect("spawn scoped worker");
        }
    });
    out.into_iter().map(|r| r.expect("worker filled slot")).collect()
}

/// Default parallelism: physical cores (capped — DSE workloads are compute
/// bound and oversubscription only adds scheduler noise).
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_map_preserves_order() {
        let xs: Vec<u64> = (0..1000).collect();
        let ys = scope_map(&xs, 8, |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_single_item() {
        assert_eq!(scope_map(&[5u32], 8, |x| x + 1), vec![6]);
    }

    #[test]
    fn scope_map_empty() {
        let e: Vec<u32> = vec![];
        assert!(scope_map(&e, 4, |x| *x).is_empty());
    }

    #[test]
    fn scope_map_threads_exceed_items() {
        let xs = [1, 2, 3];
        assert_eq!(scope_map(&xs, 64, |x| x * x), vec![1, 4, 9]);
    }
}
