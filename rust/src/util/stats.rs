//! Summary statistics for benches and coordinator metrics.
//!
//! Two latency rollups live here, with one convention shared by every sim
//! and report path:
//!
//! * [`Summary`] — exact, full-sample: keeps every sample, so quantiles
//!   are bit-reproducible and memory is O(samples). All pinned reports
//!   and bit-identity tests use this.
//! * [`LatencySketch`] — streaming, O(1) memory: a fixed grid of
//!   log-spaced bins with exact count/sum/min/max and bounded-relative-
//!   error quantiles. The sweep/bench replay path uses this by default so
//!   memory stays flat no matter how many requests a replay serves.

use std::sync::OnceLock;

/// Collects samples and reports mean / percentiles / min / max.
///
/// Percentile queries sort a cached copy of the samples exactly once: the
/// first call after any `push` pays the O(n log n) sort, repeated calls
/// (p50 then p99 then a full sweep) are O(1) in sorting cost.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
    /// Lazily built sorted copy of `samples`; invalidated by `push`.
    sorted: OnceLock<Vec<f64>>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { samples: Vec::new(), sorted: OnceLock::new() }
    }

    pub fn push(&mut self, x: f64) {
        debug_assert!(x.is_finite(), "non-finite sample {x}");
        self.samples.push(x);
        self.sorted.take(); // cached order is stale now
    }

    /// Append every sample of `other` (shard-merge path; keeps the same
    /// "multiset of samples" semantics as pushing them one by one).
    pub fn extend_from(&mut self, other: &Summary) {
        self.samples.extend_from_slice(&other.samples);
        self.sorted.take();
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Raw samples in insertion order (not sorted).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var =
            self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64;
        var.sqrt()
    }

    /// The sorted sample buffer, built on first use after a `push`.
    fn sorted_samples(&self) -> &[f64] {
        self.sorted.get_or_init(|| {
            let mut v = self.samples.clone();
            v.sort_by(f64::total_cmp);
            v
        })
    }

    /// Percentile via linear interpolation between closest ranks (`q` in 0..=1).
    pub fn percentile(&self, q: f64) -> f64 {
        self.percentiles(&[q])[0]
    }

    /// All requested percentiles from the (cached) single sort. The
    /// serving loops ask for p50+p99 per window/report; batching the
    /// quantiles — or any repeated call after the first — costs one sort
    /// total, not one per quantile.
    pub fn percentiles(&self, qs: &[f64]) -> Vec<f64> {
        if self.samples.is_empty() {
            return vec![f64::NAN; qs.len()];
        }
        let v = self.sorted_samples();
        qs.iter()
            .map(|&q| {
                let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
                let lo = pos.floor() as usize;
                let hi = pos.ceil() as usize;
                if lo == hi {
                    v[lo]
                } else {
                    v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
                }
            })
            .collect()
    }

    /// Number of samples at or below `x` (SLO-attainment accounting).
    pub fn count_leq(&self, x: f64) -> usize {
        // Binary search when the sorted cache already exists (a report
        // computing percentiles first gets this for free); a linear scan
        // otherwise, so a lone count never forces a sort.
        match self.sorted.get() {
            Some(v) => v.partition_point(|&s| s <= x),
            None => self.samples.iter().filter(|&&s| s <= x).count(),
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }
}

// ---------------------------------------------------------------------------
// Streaming quantile sketch
// ---------------------------------------------------------------------------

/// Log-spaced bin ratio: adjacent bin edges differ by this factor, so any
/// sample and its bin's representative differ by at most `GAMMA` (~2%).
pub const SKETCH_GAMMA: f64 = 1.02;
/// ln(SKETCH_GAMMA), precomputed (no const `ln` in stable rust).
const LN_GAMMA: f64 = 0.019_802_627_296_179_712;
/// Smallest resolvable sample (seconds); everything below lands in bin 0.
const SKETCH_FLOOR: f64 = 1e-7;
/// Bin count: covers `SKETCH_FLOOR * GAMMA^i` up to ~10^3 s (ten decades,
/// ceil(ln(1e10)/ln(1.02)) = 1163 bins); larger samples clamp to the top
/// bin. Sojourn times in every sim here are micro- to low-seconds, far
/// inside the grid.
const SKETCH_BINS: usize = 1164;

/// Fixed-memory streaming latency sketch: log-spaced bin counts with
/// exact count/sum/min/max. Quantiles carry a bounded relative error —
/// the returned representative lies in the *same bin* as the
/// nearest-rank sample, so it is within a factor of [`SKETCH_GAMMA`] of
/// it (pinned by a property test in `tests/simcore_fastpath.rs`).
/// Sketches merge by bin-wise addition, which is associative and
/// commutative — but shard merges still run in fixed shard-index order
/// (see `sim::sweep`) so float `sum`/`min`/`max` folds are reproducible
/// across thread counts.
#[derive(Clone, Debug)]
pub struct LatencySketch {
    bins: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LatencySketch {
    fn default() -> Self {
        LatencySketch {
            bins: vec![0; SKETCH_BINS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

/// Bin index of sample `x` (clamped into the grid).
fn sketch_bin(x: f64) -> usize {
    if x < SKETCH_FLOOR {
        return 0;
    }
    (((x / SKETCH_FLOOR).ln() / LN_GAMMA) as usize).min(SKETCH_BINS - 1)
}

/// Midpoint representative of bin `i` (geometric center).
fn sketch_rep(i: usize) -> f64 {
    SKETCH_FLOOR * ((i as f64 + 0.5) * LN_GAMMA).exp()
}

impl LatencySketch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, x: f64) {
        debug_assert!(x.is_finite(), "non-finite sample {x}");
        self.bins[sketch_bin(x)] += 1;
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact mean (the running sum is exact, not binned).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum / self.count as f64
    }

    /// Exact minimum sample.
    pub fn min_s(&self) -> f64 {
        self.min
    }

    /// Exact maximum sample.
    pub fn max_s(&self) -> f64 {
        self.max
    }

    /// Nearest-rank quantile with bounded relative error: the
    /// representative of the bin holding the rank-`round(q*(n-1))`
    /// sample, clamped into the exact `[min, max]` envelope.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = (q.clamp(0.0, 1.0) * (self.count - 1) as f64).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            cum += c;
            if cum > rank {
                return sketch_rep(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Approximate count of samples ≤ `x`, at bin granularity: full bins
    /// strictly below `x`'s bin, plus `x`'s own bin once `x` reaches its
    /// representative. Exact SLO accounting stays on the [`Summary`]
    /// path; this is for sweep-scale reporting where ±one bin (±2%)
    /// around the threshold is acceptable.
    pub fn count_leq(&self, x: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        if x >= self.max {
            return self.count;
        }
        let xb = sketch_bin(x);
        let mut n: u64 = self.bins[..xb].iter().sum();
        if x >= sketch_rep(xb) {
            n += self.bins[xb];
        }
        n
    }

    /// Bin-wise merge (same fixed grid on both sides by construction).
    pub fn merge(&mut self, other: &LatencySketch) {
        for (a, b) in self.bins.iter_mut().zip(&other.bins) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Format a duration in seconds as milliseconds with two decimals, or
/// `-` when the value is not finite — [`Summary::percentiles`] and
/// [`LatencySketch::quantile`] return NaN on empty inputs, and report
/// summary lines must not print "NaN ms" for a run that served nothing.
pub fn fmt_ms(seconds: f64) -> String {
    if seconds.is_finite() {
        format!("{:.2}", seconds * 1e3)
    } else {
        "-".to_string()
    }
}

/// Relative error |got - want| / |want| (used for Table 7 error rates).
pub fn rel_err(got: f64, want: f64) -> f64 {
    if want == 0.0 {
        return if got == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (got - want).abs() / want.abs()
}

/// Geometric mean (used for "average gains" rows like Table 5's summary).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentiles_sorted_interp() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(1.0), 4.0);
        assert!((s.p50() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn single_sample() {
        let mut s = Summary::new();
        s.push(3.25);
        assert_eq!(s.mean(), 3.25);
        assert_eq!(s.p50(), 3.25);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn percentiles_batch_matches_single_calls() {
        let mut s = Summary::new();
        for x in [5.0, 1.0, 4.0, 2.0, 3.0] {
            s.push(x);
        }
        let batch = s.percentiles(&[0.0, 0.5, 0.99, 1.0]);
        assert_eq!(batch, vec![
            s.percentile(0.0),
            s.percentile(0.5),
            s.percentile(0.99),
            s.percentile(1.0),
        ]);
        assert!(Summary::new().percentiles(&[0.5, 0.99]).iter().all(|x| x.is_nan()));
        assert!(s.percentiles(&[]).is_empty());
    }

    #[test]
    fn sorted_cache_invalidated_by_push() {
        // The cache must never serve a stale order: query, push a new
        // extreme, query again — the new sample must be visible.
        let mut s = Summary::new();
        for x in [2.0, 1.0, 3.0] {
            s.push(x);
        }
        assert_eq!(s.percentile(1.0), 3.0);
        assert_eq!(s.count_leq(2.5), 2); // sorted cache path
        s.push(10.0);
        assert_eq!(s.percentile(1.0), 10.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.count_leq(2.5), 2); // linear path (cache invalidated)
        // cloning carries the (valid) cache along
        let _ = s.percentiles(&[0.5]);
        let c = s.clone();
        assert_eq!(c.percentile(1.0), 10.0);
    }

    #[test]
    fn extend_from_matches_individual_pushes() {
        let (mut a, mut b, mut both) = (Summary::new(), Summary::new(), Summary::new());
        for x in [4.0, 1.0, 3.0] {
            a.push(x);
            both.push(x);
        }
        for x in [2.0, 5.0] {
            b.push(x);
            both.push(x);
        }
        a.extend_from(&b);
        let qs = [0.0, 0.25, 0.5, 0.75, 1.0];
        assert_eq!(a.percentiles(&qs), both.percentiles(&qs));
        assert_eq!(a.len(), 5);
    }

    #[test]
    fn count_leq_boundaries() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0] {
            s.push(x);
        }
        assert_eq!(s.count_leq(0.5), 0);
        assert_eq!(s.count_leq(2.0), 2);
        assert_eq!(s.count_leq(10.0), 3);
        assert_eq!(Summary::new().count_leq(1.0), 0);
        // sorted-cache path gives the same answers
        let _ = s.p50();
        assert_eq!(s.count_leq(0.5), 0);
        assert_eq!(s.count_leq(2.0), 2);
        assert_eq!(s.count_leq(10.0), 3);
    }

    #[test]
    fn sketch_exact_moments_and_bounded_quantiles() {
        let mut sk = LatencySketch::new();
        let mut exact = Summary::new();
        // deterministic log-uniform-ish spread over realistic sojourns
        for i in 0..5000u64 {
            let x = 1e-4 * (1.0 + (i as f64 * 0.7).sin().abs()) * (1 + i % 37) as f64;
            sk.record(x);
            exact.push(x);
        }
        assert_eq!(sk.count() as usize, exact.len());
        assert!((sk.mean() - exact.mean()).abs() < 1e-15);
        assert_eq!(sk.min_s(), exact.min());
        assert_eq!(sk.max_s(), exact.max());
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            let got = sk.quantile(q);
            let want = exact.percentile(q);
            // same-bin guarantee => within one GAMMA factor
            assert!(
                got / want <= SKETCH_GAMMA && want / got <= SKETCH_GAMMA,
                "q{q}: sketch {got} vs exact {want}"
            );
        }
    }

    #[test]
    fn sketch_merge_equals_single_stream() {
        let (mut a, mut b, mut one) = (LatencySketch::new(), LatencySketch::new(), LatencySketch::new());
        for i in 0..300 {
            let x = 1e-3 * (1 + i % 23) as f64;
            if i % 2 == 0 { a.record(x) } else { b.record(x) }
            one.record(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), one.count());
        assert_eq!(a.bins, one.bins);
        assert_eq!(a.min_s(), one.min_s());
        assert_eq!(a.max_s(), one.max_s());
        for q in [0.1, 0.5, 0.99] {
            assert_eq!(a.quantile(q).to_bits(), one.quantile(q).to_bits());
        }
    }

    #[test]
    fn sketch_edges_and_count_leq() {
        let mut sk = LatencySketch::new();
        assert!(sk.quantile(0.5).is_nan());
        assert_eq!(sk.count_leq(1.0), 0);
        sk.record(5e-8); // below the floor: bin 0
        sk.record(1e9); // beyond the grid: clamps to the top bin
        assert_eq!(sk.count(), 2);
        assert_eq!(sk.min_s(), 5e-8);
        assert_eq!(sk.max_s(), 1e9);
        // quantiles stay inside the exact [min, max] envelope despite the clamped bins
        assert!(sk.quantile(0.0) >= 5e-8 && sk.quantile(1.0) <= 1e9);
        assert_eq!(sk.count_leq(1e10), 2);
        let mut m = LatencySketch::new();
        for x in [1e-3, 2e-3, 3e-3] {
            m.record(x);
        }
        // bin-granular: everything below 2.5e-3's bin, i.e. the first two samples
        assert_eq!(m.count_leq(2.5e-3), 2);
        assert_eq!(m.count_leq(1e-5), 0);
    }

    #[test]
    fn rel_err_basic() {
        assert!((rel_err(1.05, 1.0) - 0.05).abs() < 1e-12);
        assert_eq!(rel_err(0.0, 0.0), 0.0);
    }

    #[test]
    fn geomean_of_equal_ratios() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }
}
