//! Summary statistics for benches and coordinator metrics.

/// Collects samples and reports mean / percentiles / min / max.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { samples: Vec::new() }
    }

    pub fn push(&mut self, x: f64) {
        debug_assert!(x.is_finite(), "non-finite sample {x}");
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var =
            self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64;
        var.sqrt()
    }

    /// Percentile via linear interpolation between closest ranks (`q` in 0..=1).
    pub fn percentile(&self, q: f64) -> f64 {
        self.percentiles(&[q])[0]
    }

    /// All requested percentiles from a single sort. The serving loops ask
    /// for p50+p99 per window/report; `percentile` clones and re-sorts the
    /// sample vector on every call, which doubles the sort cost for every
    /// such pair — batch the quantiles instead.
    pub fn percentiles(&self, qs: &[f64]) -> Vec<f64> {
        if self.samples.is_empty() {
            return vec![f64::NAN; qs.len()];
        }
        let mut v = self.samples.clone();
        v.sort_by(f64::total_cmp);
        qs.iter()
            .map(|&q| {
                let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
                let lo = pos.floor() as usize;
                let hi = pos.ceil() as usize;
                if lo == hi {
                    v[lo]
                } else {
                    v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
                }
            })
            .collect()
    }

    /// Number of samples at or below `x` (SLO-attainment accounting).
    pub fn count_leq(&self, x: f64) -> usize {
        self.samples.iter().filter(|&&s| s <= x).count()
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }
}

/// Relative error |got - want| / |want| (used for Table 7 error rates).
pub fn rel_err(got: f64, want: f64) -> f64 {
    if want == 0.0 {
        return if got == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (got - want).abs() / want.abs()
}

/// Geometric mean (used for "average gains" rows like Table 5's summary).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentiles_sorted_interp() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(1.0), 4.0);
        assert!((s.p50() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn single_sample() {
        let mut s = Summary::new();
        s.push(3.25);
        assert_eq!(s.mean(), 3.25);
        assert_eq!(s.p50(), 3.25);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn percentiles_batch_matches_single_calls() {
        let mut s = Summary::new();
        for x in [5.0, 1.0, 4.0, 2.0, 3.0] {
            s.push(x);
        }
        let batch = s.percentiles(&[0.0, 0.5, 0.99, 1.0]);
        assert_eq!(batch, vec![
            s.percentile(0.0),
            s.percentile(0.5),
            s.percentile(0.99),
            s.percentile(1.0),
        ]);
        assert!(Summary::new().percentiles(&[0.5, 0.99]).iter().all(|x| x.is_nan()));
        assert!(s.percentiles(&[]).is_empty());
    }

    #[test]
    fn count_leq_boundaries() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0] {
            s.push(x);
        }
        assert_eq!(s.count_leq(0.5), 0);
        assert_eq!(s.count_leq(2.0), 2);
        assert_eq!(s.count_leq(10.0), 3);
        assert_eq!(Summary::new().count_leq(1.0), 0);
    }

    #[test]
    fn rel_err_basic() {
        assert!((rel_err(1.05, 1.0) - 0.05).abs() < 1e-12);
        assert_eq!(rel_err(0.0, 0.0), 0.0);
    }

    #[test]
    fn geomean_of_equal_ratios() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }
}
