//! The one queueing truth: the per-device discrete-event serving core.
//!
//! Both deterministic serving replays — the single-device
//! [`crate::sim::serving::serve_ramp`] and the fleet-level
//! [`crate::cluster::sim::simulate_fleet`] — used to carry hand-duplicated
//! copies of the same ~80 lines of launch/drain-and-swap/admission
//! machinery, so any semantic drift between them was a silent correctness
//! bug in every off-hardware latency-throughput claim. This module is the
//! merge: one [`DeviceSim`] holds a device's queue, in-flight launch,
//! [`LoadEstimator`] + [`AdaptiveScheduler`] wiring, admission control,
//! per-window [`WindowStat`] snapshots, and tallies; one [`run_timeline`]
//! event loop owns the tie order. The two public sims are thin adapters
//! over these and can no longer fork. The fleet autoscaler
//! ([`crate::cluster::controller`]) drives the same core through
//! [`run_timeline_controlled`], adding device lifecycle transitions
//! without forking the queueing semantics either.
//!
//! ## The contract
//!
//! * **Event tie order** (deterministic): launch **completion** (lowest
//!   device index first on exact time ties), then the decision **window**
//!   tick (all devices, index order, then the fleet-control hook), then
//!   the **arrival**.
//! * **Drain-and-swap** (plan level): a switch committed by the scheduler
//!   while a launch is in flight becomes `draining` and is applied to
//!   `committed` at that launch's completion; queued requests carry over
//!   to the new plan and are never dropped. With no launch in flight the
//!   switch applies immediately.
//! * **Admission before queueing**: every routed arrival is recorded with
//!   the estimator (shed ones included — the estimator sees offered load),
//!   then either queued or explicitly shed. `served + shed +
//!   requeued_away == routed` per device, always (`requeued_away` is zero
//!   unless a fleet controller drains or fails the device).
//! * **Admission is judged against the scheduler's active plan** (the
//!   switch target while draining), not the plan still executing — the
//!   queue being admitted will drain on the new plan.
//!
//! ## Two kinds of "draining"
//!
//! The word shows up at two different levels; the code keeps them apart:
//!
//! * **plan drain** — `DeviceSim::draining: Option<usize>`: a committed
//!   *plan switch* waiting for the in-flight launch to finish. The device
//!   keeps serving throughout.
//! * **lifecycle drain** — [`DeviceState::Draining`]: the *device itself*
//!   is leaving the fleet (scale-in or a rolling front swap). The router
//!   stops sending it traffic, its queued requests are requeued onto
//!   peers, and the in-flight launch finishes before the device retires —
//!   hitless decommission.
//!
//! ## Divergences the unification fixed
//!
//! Extracting the core surfaced (and removed) two reporting divergences
//! between the forked copies:
//!
//! 1. the single-device sim recorded per-window [`WindowStat`]s while the
//!    fleet sim recorded none — now every device records them;
//! 2. the per-window "active" plan was the lagging executing index while
//!    the end-of-run `active_final`/`final_active` was the scheduler's
//!    committed choice — two different notions of "current plan" mid-drain
//!    under one name. Both reports now expose `{committed, draining}`
//!    explicitly, per window and at end of run.

use std::collections::VecDeque;

use crate::coordinator::scheduler::{
    AdaptiveScheduler, LoadEstimate, LoadEstimator, SchedulerCfg, SwitchRecord,
};
use crate::plan::front::{FrontEntry, PlanFront};
use crate::util::stats::Summary;

/// Lifecycle of one simulated device (distinct from the *plan*-level
/// drain-and-swap; see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceState {
    /// Serving: the router may send it traffic.
    Active,
    /// Leaving the fleet: no new traffic, queue already requeued onto
    /// peers, in-flight launch still completing.
    Draining,
    /// Decommissioned cleanly (drain finished). Terminal.
    Retired,
    /// Killed by fault injection; its queue and in-flight work were
    /// requeued onto survivors. Terminal.
    Failed,
}

/// One request in the system: when it arrived (fleet clock) and which
/// traffic class it belongs to. The class travels with the request so a
/// drain or failover can re-route it to an eligible peer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Req {
    pub arrived_s: f64,
    pub class: usize,
}

/// Per-window snapshot of one device's simulated state.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WindowStat {
    pub window: usize,
    pub end_s: f64,
    /// Estimated arrival rate at the window boundary (req/s).
    pub rate_rps: f64,
    pub queue_depth: usize,
    /// p99 completion latency over the estimator horizon (seconds).
    pub p99_s: f64,
    /// Plan executing at the window boundary (lags the scheduler's choice
    /// while a committed switch drains).
    pub committed: usize,
    /// Switch target still draining at the boundary, when one is pending.
    pub draining: Option<usize>,
}

/// One in-flight launch: the requests it serves and its completion time.
struct Launch {
    done_s: f64,
    arrivals: Vec<Req>,
}

/// Outcome of one launch completion, for fleet-level rollups.
pub struct Completed {
    /// Completion time (the launch's `done_s`).
    pub done_s: f64,
    /// Per-request sojourn times of the requests this launch served.
    pub sojourns: Vec<f64>,
}

/// End-of-run tally of one device — the single source both public report
/// shapes ([`crate::sim::serving::ServeSimReport`] and
/// [`crate::cluster::sim::DeviceStat`]) are assembled from.
#[derive(Clone, Debug)]
pub struct DeviceSimReport {
    /// Requests routed to this device (`served + shed + requeued_away`),
    /// including requeues that landed here from a drained/failed peer.
    pub routed: usize,
    pub served: usize,
    pub shed: usize,
    /// Requests handed off to peers when this device drained or failed.
    pub requeued_away: usize,
    /// Requests that landed here after a peer drained or failed.
    pub requeued_in: usize,
    /// Per-request sojourn time (queue wait + service), served requests.
    pub latency: Summary,
    pub max_queue_depth: usize,
    pub switches: Vec<SwitchRecord>,
    pub windows: Vec<WindowStat>,
    /// Plan executing when the run ended.
    pub final_committed: usize,
    /// Switch target still draining when the run ended (`None` after a
    /// clean drain: the event loop always completes in-flight launches).
    pub final_draining: Option<usize>,
    /// Lifecycle state when the run ended ([`DeviceState::Active`] for
    /// every device of a static, uncontrolled fleet).
    pub lifecycle: DeviceState,
}

/// One device's complete simulation state: queue, in-flight launch, the
/// exact drain-and-swap point, scheduler + estimator wiring, admission,
/// window snapshots, lifecycle, and tallies. Drive it only through
/// [`run_timeline`] / [`run_timeline_controlled`] (or mirror their tie
/// order exactly).
pub struct DeviceSim {
    sched: AdaptiveScheduler,
    est: LoadEstimator,
    queue: VecDeque<Req>,
    in_flight: Option<Launch>,
    /// Plan executing the current launch — lags `sched.active()` while a
    /// committed switch drains.
    committed: usize,
    /// Committed switch target waiting for the in-flight launch to drain.
    draining: Option<usize>,
    lifecycle: DeviceState,
    routed: usize,
    served: usize,
    shed: usize,
    requeued_away: usize,
    requeued_in: usize,
    latency: Summary,
    max_queue_depth: usize,
    windows: Vec<WindowStat>,
}

impl DeviceSim {
    pub fn new(front: PlanFront, cfg: SchedulerCfg) -> DeviceSim {
        let sched = AdaptiveScheduler::new(front, cfg);
        let committed = sched.active();
        DeviceSim {
            est: LoadEstimator::new(cfg.horizon_s()),
            sched,
            queue: VecDeque::new(),
            in_flight: None,
            committed,
            draining: None,
            lifecycle: DeviceState::Active,
            routed: 0,
            served: 0,
            shed: 0,
            requeued_away: 0,
            requeued_in: 0,
            latency: Summary::new(),
            max_queue_depth: 0,
            windows: Vec::new(),
        }
    }

    /// Front entry of the plan currently *executing* (the router-visible
    /// service curve; lags the scheduler's choice while a switch drains).
    pub fn committed_entry(&self) -> &FrontEntry {
        &self.sched.front.entries[self.committed]
    }

    /// Model this device serves (its front's model).
    pub fn model(&self) -> &str {
        &self.sched.front.model
    }

    pub fn state(&self) -> DeviceState {
        self.lifecycle
    }

    /// Routable: the dispatcher may send this device new traffic.
    pub fn is_serving(&self) -> bool {
        self.lifecycle == DeviceState::Active
    }

    /// Powered: the board is still occupied (serving or finishing its
    /// drain) — what device-hour accounting bills for.
    pub fn is_live(&self) -> bool {
        matches!(self.lifecycle, DeviceState::Active | DeviceState::Draining)
    }

    /// Per-window snapshots recorded so far.
    pub fn window_stats(&self) -> &[WindowStat] {
        &self.windows
    }

    pub fn last_window(&self) -> Option<&WindowStat> {
        self.windows.last()
    }

    /// Current load estimate without mutating the estimator — what a
    /// fleet controller polls between decision windows (see
    /// [`LoadEstimator::peek`]).
    pub fn load_estimate(&self, now_s: f64) -> LoadEstimate {
        self.est.peek(now_s, self.queue.len())
    }

    /// Requests queued or in flight — the router-visible depth.
    pub fn depth(&self) -> usize {
        self.queue.len() + self.in_flight.as_ref().map_or(0, |l| l.arrivals.len())
    }

    /// Completion time of the in-flight launch (`INFINITY` when idle).
    pub fn next_completion_s(&self) -> f64 {
        self.in_flight.as_ref().map_or(f64::INFINITY, |l| l.done_s)
    }

    /// Start the next launch from the queue if the device is idle: take up
    /// to `batch` queued requests onto the committed plan.
    fn start_launch(&mut self, t: f64) {
        if self.queue.is_empty() || self.in_flight.is_some() {
            return;
        }
        let e = &self.sched.front.entries[self.committed];
        let take = e.batch.min(self.queue.len());
        let batch: Vec<Req> = self.queue.drain(..take).collect();
        self.in_flight = Some(Launch { done_s: t + e.latency_s(), arrivals: batch });
    }

    /// Handle the in-flight launch's completion — the drain point: tally
    /// each request's sojourn, apply a draining switch, start the next
    /// launch on the (possibly new) committed plan, and retire the device
    /// if it was lifecycle-draining and is now empty.
    pub fn on_completion(&mut self) -> Completed {
        let launch = self.in_flight.take().expect("on_completion with no launch in flight");
        let done_s = launch.done_s;
        let mut sojourns = Vec::with_capacity(launch.arrivals.len());
        for req in &launch.arrivals {
            let sojourn = done_s - req.arrived_s;
            self.latency.push(sojourn);
            self.est.record_completion(done_s, sojourn);
            self.served += 1;
            sojourns.push(sojourn);
        }
        if let Some(to) = self.draining.take() {
            self.committed = to; // drain complete: swap now
        }
        self.start_launch(done_s);
        if self.lifecycle == DeviceState::Draining && self.in_flight.is_none() {
            // queue was requeued at begin_drain, the last launch just
            // landed: hitless decommission complete
            self.lifecycle = DeviceState::Retired;
        }
        Completed { done_s, sojourns }
    }

    /// Run one decision window: estimate the load, let the scheduler
    /// decide (drain-and-swap when a launch is in flight, immediate swap
    /// when idle), and record the [`WindowStat`]. Retired/failed devices
    /// are inert; lifecycle-draining devices record stats but make no
    /// plan decisions (no new work will arrive).
    pub fn on_window(&mut self, window: usize, end_s: f64) {
        if !self.is_live() {
            return;
        }
        let snapshot = self.est.estimate(end_s, self.queue.len());
        if self.lifecycle == DeviceState::Active && self.draining.is_none() {
            if let Some(to) = self.sched.on_window(window, end_s, &snapshot) {
                if self.in_flight.is_some() {
                    self.draining = Some(to); // drain-and-swap
                } else {
                    self.committed = to;
                }
            }
        }
        self.windows.push(WindowStat {
            window,
            end_s,
            rate_rps: snapshot.rate_rps,
            queue_depth: snapshot.queue_depth,
            p99_s: snapshot.p99_s,
            committed: self.committed,
            draining: self.draining,
        });
    }

    /// Handle one routed arrival: record it with the estimator (offered
    /// load includes what admission sheds), then admit into the queue or
    /// shed explicitly. Returns whether the request was admitted.
    pub fn on_arrival(&mut self, t: f64, class: usize) -> bool {
        self.routed += 1;
        self.est.record_arrival(t);
        if self.sched.admit(self.queue.len()) {
            self.queue.push_back(Req { arrived_s: t, class });
            self.max_queue_depth = self.max_queue_depth.max(self.queue.len());
            self.start_launch(t);
            true
        } else {
            self.shed += 1;
            false
        }
    }

    /// Accept a request requeued from a drained/failed peer at `now_s`.
    /// The request keeps its original arrival time (its sojourn honestly
    /// includes the time lost on the old device), but the estimator and
    /// any fresh launch run on the fleet clock — a launch can never start
    /// in the past. Requeues pass the same admission control as fresh
    /// arrivals: a saturated survivor sheds rather than queueing
    /// unboundedly.
    pub fn on_requeue(&mut self, req: Req, now_s: f64) -> bool {
        self.routed += 1;
        self.requeued_in += 1;
        self.est.record_arrival(now_s);
        if self.sched.admit(self.queue.len()) {
            self.queue.push_back(req);
            self.max_queue_depth = self.max_queue_depth.max(self.queue.len());
            self.start_launch(now_s);
            true
        } else {
            self.shed += 1;
            false
        }
    }

    /// Begin hitless decommission (scale-in, or one step of a rolling
    /// front swap): stop being routable, hand the queued requests back for
    /// re-dispatch onto peers, and keep only the in-flight launch, which
    /// retires the device at its completion. A device with nothing in
    /// flight retires immediately. No-op (empty) unless currently active.
    pub fn begin_drain(&mut self) -> Vec<Req> {
        if self.lifecycle != DeviceState::Active {
            return Vec::new();
        }
        let moved: Vec<Req> = self.queue.drain(..).collect();
        self.requeued_away += moved.len();
        self.lifecycle = if self.in_flight.is_some() {
            DeviceState::Draining
        } else {
            DeviceState::Retired
        };
        moved
    }

    /// Kill the device (fault injection): the in-flight launch dies
    /// mid-service and both it and the queue are handed back for
    /// re-dispatch onto survivors, original arrival times preserved.
    /// No-op (empty) unless the device is live.
    pub fn fail(&mut self) -> Vec<Req> {
        if !self.is_live() {
            return Vec::new();
        }
        // FIFO by arrival: the killed launch's requests precede the queue.
        let mut moved: Vec<Req> =
            self.in_flight.take().map(|l| l.arrivals).unwrap_or_default();
        moved.extend(self.queue.drain(..));
        self.requeued_away += moved.len();
        self.draining = None;
        self.lifecycle = DeviceState::Failed;
        moved
    }

    /// Consume the device into its end-of-run tally.
    pub fn into_report(self) -> DeviceSimReport {
        DeviceSimReport {
            routed: self.routed,
            served: self.served,
            shed: self.shed,
            requeued_away: self.requeued_away,
            requeued_in: self.requeued_in,
            latency: self.latency,
            max_queue_depth: self.max_queue_depth,
            switches: self.sched.switches,
            windows: self.windows,
            final_committed: self.committed,
            final_draining: self.draining,
            lifecycle: self.lifecycle,
        }
    }
}

/// Fleet-level rollup of one [`run_timeline`] run.
pub struct TimelineOutcome {
    /// Sojourn times across every device, in completion order.
    pub latency: Summary,
    /// `(completion time, sojourn)` per served request, in completion
    /// order — lets a caller attribute latency back to arrival time
    /// (`arrived = done - sojourn`), e.g. per ramp phase.
    pub completions: Vec<(f64, f64)>,
    /// Arrivals the `route` callback declined (no eligible device).
    pub unroutable: usize,
    /// Requests handed back by the control hook (drains + failures).
    pub requeued: usize,
    /// Requeued requests no eligible device could take — terminally lost
    /// to the caller's accounting (a fleet report counts them as shed).
    pub requeue_lost: usize,
    /// Completion time of the last served request (0 when nothing served).
    pub makespan_s: f64,
    /// Decision windows ticked (`round(duration_s / window_s)` — rounded,
    /// not truncated, so a `3 * 0.6 / 0.05 = 35.999…` ramp keeps its
    /// final window).
    pub n_windows: usize,
}

/// Fleet-level control consulted once per decision window, after every
/// device ticked. The hook may mutate the fleet — push scale-out devices,
/// [`DeviceSim::begin_drain`] one, [`DeviceSim::fail`] one — and returns
/// the requests those transitions displaced; the event loop re-dispatches
/// them through the router at the window boundary. [`NoControl`] is the
/// static-fleet no-op.
pub trait FleetControl {
    fn after_window(&mut self, devs: &mut Vec<DeviceSim>, window: usize, end_s: f64)
        -> Vec<Req>;
}

/// The do-nothing control: a static fleet.
pub struct NoControl;

impl FleetControl for NoControl {
    fn after_window(&mut self, _: &mut Vec<DeviceSim>, _: usize, _: f64) -> Vec<Req> {
        Vec::new()
    }
}

/// The shared discrete-event loop for a static fleet: replay a merged
/// `(arrival time, class)` timeline against `devs`, dispatching each
/// arrival through `route` (`route(devs, class, t)` returns the device
/// index, or `None` for an unroutable class). Every tie-order decision
/// lives in [`run_timeline_controlled`] and only there: completion
/// (lowest device index first), then window tick, then arrival.
pub fn run_timeline(
    devs: &mut Vec<DeviceSim>,
    timeline: &[(f64, usize)],
    duration_s: f64,
    window_s: f64,
    route: impl FnMut(&[DeviceSim], usize, f64) -> Option<usize>,
) -> TimelineOutcome {
    run_timeline_controlled(devs, timeline, duration_s, window_s, route, &mut NoControl)
}

/// [`run_timeline`] plus a [`FleetControl`] hook: the autoscaling /
/// failover / rolling-swap face of the same event loop. With
/// [`NoControl`] the behavior is bit-identical to the static loop — the
/// hook runs after all devices ticked a window and its displaced requests
/// are re-dispatched through `route` at the window boundary, in the order
/// the hook returned them.
pub fn run_timeline_controlled(
    devs: &mut Vec<DeviceSim>,
    timeline: &[(f64, usize)],
    duration_s: f64,
    window_s: f64,
    mut route: impl FnMut(&[DeviceSim], usize, f64) -> Option<usize>,
    ctl: &mut impl FleetControl,
) -> TimelineOutcome {
    let n_windows = (duration_s / window_s).round() as usize;
    let mut latency = Summary::new();
    let mut completions = Vec::new();
    let mut unroutable = 0usize;
    let mut requeued = 0usize;
    let mut requeue_lost = 0usize;
    let mut makespan_s = 0.0f64;
    let mut ai = 0usize; // next arrival index
    let mut w = 0usize; // next window index

    loop {
        let t_arr = timeline.get(ai).map(|&(t, _)| t).unwrap_or(f64::INFINITY);
        // Earliest completion across devices (tie: lowest device index).
        let mut t_done = f64::INFINITY;
        let mut done_dev = 0usize;
        for (i, d) in devs.iter().enumerate() {
            let td = d.next_completion_s();
            if td < t_done {
                t_done = td;
                done_dev = i;
            }
        }
        let t_win = if w < n_windows { (w + 1) as f64 * window_s } else { f64::INFINITY };
        if t_arr == f64::INFINITY && t_done == f64::INFINITY && t_win == f64::INFINITY {
            break;
        }

        if t_done <= t_win && t_done <= t_arr {
            // -- launch completion (and switch drain point) --------------
            let done = devs[done_dev].on_completion();
            for &s in &done.sojourns {
                latency.push(s);
                completions.push((done.done_s, s));
            }
            makespan_s = makespan_s.max(done.done_s);
        } else if t_win <= t_arr {
            // -- decision window boundary (all devices, then control) ----
            for d in devs.iter_mut() {
                d.on_window(w, t_win);
            }
            let moved = ctl.after_window(devs, w, t_win);
            requeued += moved.len();
            for req in moved {
                match route(devs, req.class, t_win) {
                    Some(di) => {
                        devs[di].on_requeue(req, t_win);
                    }
                    None => requeue_lost += 1,
                }
            }
            w += 1;
        } else {
            // -- arrival: route, then per-device admission ---------------
            let (t, class) = timeline[ai];
            match route(devs, class, t) {
                None => unroutable += 1,
                Some(di) => {
                    devs[di].on_arrival(t, class);
                }
            }
            ai += 1;
        }
    }

    TimelineOutcome {
        latency,
        completions,
        unroutable,
        requeued,
        requeue_lost,
        makespan_s,
        n_windows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::front::FrontEntry;

    fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
        FrontEntry {
            assign: vec![0; 8],
            batch,
            latency_ms: lat_ms,
            tops: rps * 2.5e-3,
            rps,
            nacc: 1,
            label: label.to_string(),
        }
    }

    fn front() -> PlanFront {
        PlanFront::new(
            "m",
            12,
            vec![entry("seq", 1, 0.2, 5000.0), entry("spatial", 24, 2.0, 12000.0)],
        )
        .unwrap()
    }

    fn cfg() -> SchedulerCfg {
        SchedulerCfg { slo_ms: 20.0, ..Default::default() }
    }

    #[test]
    fn launch_batches_and_completes_in_fifo_order() {
        let mut d = DeviceSim::new(front(), cfg());
        assert_eq!(d.next_completion_s(), f64::INFINITY);
        assert!(d.on_arrival(0.0, 0)); // starts a batch-1 launch immediately
        assert!(d.on_arrival(0.00005, 0));
        assert_eq!(d.depth(), 2);
        let done = d.on_completion();
        assert_eq!(done.sojourns.len(), 1);
        assert!((done.done_s - 0.2e-3).abs() < 1e-12);
        // the queued request started its own launch at the completion
        assert_eq!(d.depth(), 1);
        let r = {
            d.on_completion();
            d.into_report()
        };
        assert_eq!(r.served, 2);
        assert_eq!(r.shed, 0);
        assert_eq!(r.routed, 2);
        assert_eq!(r.final_draining, None);
        assert_eq!(r.lifecycle, DeviceState::Active);
    }

    #[test]
    fn drain_and_swap_applies_at_completion_not_at_the_window() {
        // Force a switch decision while a launch is in flight: the window
        // must record {committed: old, draining: Some(new)} and the swap
        // must land exactly at the completion.
        let mut d = DeviceSim::new(front(), cfg());
        // saturate the estimator with arrivals so the scheduler wants the
        // throughput point (demand >> seq capacity)
        for i in 0..600 {
            d.on_arrival(i as f64 * 1e-4, 0); // 10k req/s offered
        }
        let c = cfg();
        // patience windows of sustained overload commit the switch
        let mut committed_window = None;
        for w in 0..4 {
            d.on_window(w, (w + 1) as f64 * c.window_s);
            let ws = *d.windows.last().unwrap();
            if ws.draining.is_some() {
                committed_window = Some(w);
                break;
            }
        }
        let ws = *d.windows.last().unwrap();
        assert!(
            committed_window.is_some(),
            "sustained overload never committed a switch: {:?}",
            d.windows
        );
        assert_eq!(ws.committed, 0, "swap applied before the drain completed");
        assert_eq!(ws.draining, Some(1));
        d.on_completion();
        assert_eq!(d.committed, 1, "drain completion must apply the pending switch");
        assert_eq!(d.draining, None);
    }

    #[test]
    fn run_timeline_counts_unroutable_and_windows() {
        let mut devs = vec![DeviceSim::new(front(), cfg())];
        let timeline = vec![(0.01, 0), (0.02, 1), (0.03, 0)];
        let out = run_timeline(&mut devs, &timeline, 0.5, 0.05, |_, class, _| {
            (class == 0).then_some(0)
        });
        assert_eq!(out.unroutable, 1);
        assert_eq!(out.requeued, 0);
        assert_eq!(out.requeue_lost, 0);
        assert_eq!(out.n_windows, 10);
        assert_eq!(out.completions.len(), out.latency.len());
        let r = devs.pop().unwrap().into_report();
        assert_eq!(r.routed, 2);
        assert_eq!(r.served + r.shed, r.routed);
        assert_eq!(r.windows.len(), 10);
    }

    #[test]
    fn begin_drain_requeues_queue_and_retires_at_completion() {
        let mut d = DeviceSim::new(front(), cfg());
        for i in 0..5 {
            d.on_arrival(i as f64 * 1e-5, 0); // 1 in flight + 4 queued
        }
        assert_eq!(d.depth(), 5);
        let moved = d.begin_drain();
        assert_eq!(moved.len(), 4, "queued requests move to peers");
        assert_eq!(d.state(), DeviceState::Draining);
        assert!(d.is_live() && !d.is_serving());
        assert_eq!(d.depth(), 1, "in-flight launch keeps draining");
        d.on_completion();
        assert_eq!(d.state(), DeviceState::Retired);
        // idempotent: draining/retired devices hand back nothing more
        assert!(d.begin_drain().is_empty());
        let r = d.into_report();
        assert_eq!(r.requeued_away, 4);
        assert_eq!(r.served + r.shed + r.requeued_away, r.routed);
        assert_eq!(r.lifecycle, DeviceState::Retired);
    }

    #[test]
    fn drain_with_idle_device_retires_immediately() {
        let mut d = DeviceSim::new(front(), cfg());
        assert!(d.begin_drain().is_empty());
        assert_eq!(d.state(), DeviceState::Retired);
    }

    #[test]
    fn fail_requeues_in_flight_and_queue_fifo() {
        let mut d = DeviceSim::new(front(), cfg());
        for i in 0..3 {
            d.on_arrival(i as f64 * 1e-5, 7);
        }
        let moved = d.fail();
        assert_eq!(moved.len(), 3, "in-flight + queued all move");
        // FIFO by arrival: the killed launch's request first
        assert!(moved.windows(2).all(|w| w[0].arrived_s <= w[1].arrived_s));
        assert!(moved.iter().all(|r| r.class == 7), "class travels with the request");
        assert_eq!(d.state(), DeviceState::Failed);
        assert_eq!(d.next_completion_s(), f64::INFINITY, "killed launch never completes");
        assert!(d.fail().is_empty(), "failing a dead device is a no-op");
        let r = d.into_report();
        assert_eq!(r.served, 0);
        assert_eq!(r.requeued_away, 3);
        assert_eq!(r.served + r.shed + r.requeued_away, r.routed);
    }

    #[test]
    fn requeue_keeps_original_arrival_time_but_launches_on_the_fleet_clock() {
        let mut d = DeviceSim::new(front(), cfg());
        // request arrived at t=0.01 elsewhere, requeued here at t=0.05
        assert!(d.on_requeue(Req { arrived_s: 0.01, class: 0 }, 0.05));
        let done = d.on_completion();
        // launch started at 0.05 (not in the past), sojourn spans from 0.01
        assert!((done.done_s - (0.05 + 0.2e-3)).abs() < 1e-12);
        assert!((done.sojourns[0] - (0.04 + 0.2e-3)).abs() < 1e-12);
        let r = d.into_report();
        assert_eq!(r.requeued_in, 1);
        assert_eq!(r.served, 1);
    }

    #[test]
    fn retired_and_failed_devices_record_no_further_windows() {
        let mut d = DeviceSim::new(front(), cfg());
        d.on_window(0, 0.05);
        assert_eq!(d.window_stats().len(), 1);
        d.fail();
        d.on_window(1, 0.10);
        assert_eq!(d.window_stats().len(), 1, "failed device must be inert");
    }

    #[test]
    fn controlled_timeline_redispatches_a_failed_devices_work() {
        // Two devices; a control hook kills device 0 at the first window.
        // Its queued work must land on device 1 and be served — nothing
        // lost, conservation across the handoff.
        struct KillAtWindow(usize, bool);
        impl FleetControl for KillAtWindow {
            fn after_window(
                &mut self,
                devs: &mut Vec<DeviceSim>,
                w: usize,
                _end_s: f64,
            ) -> Vec<Req> {
                if w == self.0 && !self.1 {
                    self.1 = true;
                    return devs[0].fail();
                }
                Vec::new()
            }
        }
        let mut devs = vec![DeviceSim::new(front(), cfg()), DeviceSim::new(front(), cfg())];
        // 10k req/s against device 0's 5k req/s seq point: a standing
        // queue is guaranteed at the kill (window 1, t = 0.1 s), and after
        // the kill only serving devices are eligible
        let timeline: Vec<(f64, usize)> = (0..5000).map(|i| (i as f64 * 1e-4, 0)).collect();
        let out = run_timeline_controlled(
            &mut devs,
            &timeline,
            0.5,
            0.05,
            |devs, _class, _t| devs.iter().position(|d| d.is_serving()),
            &mut KillAtWindow(1, false),
        );
        assert!(out.requeued > 0, "the kill must displace queued work");
        assert_eq!(out.requeue_lost, 0, "device 1 takes the requeues");
        let r0 = devs.remove(0).into_report();
        let r1 = devs.remove(0).into_report();
        assert_eq!(r0.lifecycle, DeviceState::Failed);
        assert_eq!(r1.lifecycle, DeviceState::Active);
        assert_eq!(r1.requeued_in, out.requeued);
        assert_eq!(r0.served + r0.shed + r0.requeued_away, r0.routed);
        assert_eq!(r1.served + r1.shed + r1.requeued_away, r1.routed);
        // every arrival is terminally served or shed across the fleet
        assert_eq!(r0.served + r1.served + r0.shed + r1.shed, timeline.len());
        assert_eq!(out.latency.len(), r0.served + r1.served);
    }
}
