//! The one queueing truth: the per-device discrete-event serving core.
//!
//! Both deterministic serving replays — the single-device
//! [`crate::sim::serving::serve_ramp`] and the fleet-level
//! [`crate::cluster::sim::simulate_fleet`] — used to carry hand-duplicated
//! copies of the same ~80 lines of launch/drain-and-swap/admission
//! machinery, so any semantic drift between them was a silent correctness
//! bug in every off-hardware latency-throughput claim. This module is the
//! merge: one [`DeviceSim`] holds a device's queue, in-flight launch,
//! [`LoadEstimator`] + [`AdaptiveScheduler`] wiring, admission control,
//! per-window [`WindowStat`] snapshots, and tallies; one [`run_timeline`]
//! event loop owns the tie order. The two public sims are thin adapters
//! over these and can no longer fork.
//!
//! ## The contract
//!
//! * **Event tie order** (deterministic): launch **completion** (lowest
//!   device index first on exact time ties), then the decision **window**
//!   tick, then the **arrival**.
//! * **Drain-and-swap**: a switch committed by the scheduler while a
//!   launch is in flight becomes `draining` and is applied to `committed`
//!   at that launch's completion; queued requests carry over to the new
//!   plan and are never dropped. With no launch in flight the switch
//!   applies immediately.
//! * **Admission before queueing**: every routed arrival is recorded with
//!   the estimator (shed ones included — the estimator sees offered load),
//!   then either queued or explicitly shed. `served + shed == routed` per
//!   device, always.
//! * **Admission is judged against the scheduler's active plan** (the
//!   switch target while draining), not the plan still executing — the
//!   queue being admitted will drain on the new plan.
//!
//! ## Divergences the unification fixed
//!
//! Extracting the core surfaced (and removed) two reporting divergences
//! between the forked copies:
//!
//! 1. the single-device sim recorded per-window [`WindowStat`]s while the
//!    fleet sim recorded none — now every device records them;
//! 2. the per-window "active" plan was the lagging executing index while
//!    the end-of-run `active_final`/`final_active` was the scheduler's
//!    committed choice — two different notions of "current plan" mid-drain
//!    under one name. Both reports now expose `{committed, draining}`
//!    explicitly, per window and at end of run.

use std::collections::VecDeque;

use crate::coordinator::scheduler::{
    AdaptiveScheduler, LoadEstimator, SchedulerCfg, SwitchRecord,
};
use crate::plan::front::{FrontEntry, PlanFront};
use crate::util::stats::Summary;

/// Per-window snapshot of one device's simulated state.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WindowStat {
    pub window: usize,
    pub end_s: f64,
    /// Estimated arrival rate at the window boundary (req/s).
    pub rate_rps: f64,
    pub queue_depth: usize,
    /// p99 completion latency over the estimator horizon (seconds).
    pub p99_s: f64,
    /// Plan executing at the window boundary (lags the scheduler's choice
    /// while a committed switch drains).
    pub committed: usize,
    /// Switch target still draining at the boundary, when one is pending.
    pub draining: Option<usize>,
}

/// One in-flight launch: the arrival times it serves and its completion.
struct Launch {
    done_s: f64,
    arrivals: Vec<f64>,
}

/// Outcome of one launch completion, for fleet-level rollups.
pub struct Completed {
    /// Completion time (the launch's `done_s`).
    pub done_s: f64,
    /// Per-request sojourn times of the requests this launch served.
    pub sojourns: Vec<f64>,
}

/// End-of-run tally of one device — the single source both public report
/// shapes ([`crate::sim::serving::ServeSimReport`] and
/// [`crate::cluster::sim::DeviceStat`]) are assembled from.
#[derive(Clone, Debug)]
pub struct DeviceSimReport {
    /// Requests routed to this device (`served + shed`).
    pub routed: usize,
    pub served: usize,
    pub shed: usize,
    /// Per-request sojourn time (queue wait + service), served requests.
    pub latency: Summary,
    pub max_queue_depth: usize,
    pub switches: Vec<SwitchRecord>,
    pub windows: Vec<WindowStat>,
    /// Plan executing when the run ended.
    pub final_committed: usize,
    /// Switch target still draining when the run ended (`None` after a
    /// clean drain: the event loop always completes in-flight launches).
    pub final_draining: Option<usize>,
}

/// One device's complete simulation state: queue, in-flight launch, the
/// exact drain-and-swap point, scheduler + estimator wiring, admission,
/// window snapshots, and tallies. Drive it only through [`run_timeline`]
/// (or mirror its tie order exactly).
pub struct DeviceSim {
    sched: AdaptiveScheduler,
    est: LoadEstimator,
    queue: VecDeque<f64>,
    in_flight: Option<Launch>,
    /// Plan executing the current launch — lags `sched.active()` while a
    /// committed switch drains.
    committed: usize,
    /// Committed switch target waiting for the in-flight launch to drain.
    draining: Option<usize>,
    routed: usize,
    served: usize,
    shed: usize,
    latency: Summary,
    max_queue_depth: usize,
    windows: Vec<WindowStat>,
}

impl DeviceSim {
    pub fn new(front: PlanFront, cfg: SchedulerCfg) -> DeviceSim {
        let sched = AdaptiveScheduler::new(front, cfg);
        let committed = sched.active();
        DeviceSim {
            est: LoadEstimator::new(cfg.horizon_s()),
            sched,
            queue: VecDeque::new(),
            in_flight: None,
            committed,
            draining: None,
            routed: 0,
            served: 0,
            shed: 0,
            latency: Summary::new(),
            max_queue_depth: 0,
            windows: Vec::new(),
        }
    }

    /// Front entry of the plan currently *executing* (the router-visible
    /// service curve; lags the scheduler's choice while a switch drains).
    pub fn committed_entry(&self) -> &FrontEntry {
        &self.sched.front.entries[self.committed]
    }

    /// Requests queued or in flight — the router-visible depth.
    pub fn depth(&self) -> usize {
        self.queue.len() + self.in_flight.as_ref().map_or(0, |l| l.arrivals.len())
    }

    /// Completion time of the in-flight launch (`INFINITY` when idle).
    pub fn next_completion_s(&self) -> f64 {
        self.in_flight.as_ref().map_or(f64::INFINITY, |l| l.done_s)
    }

    /// Start the next launch from the queue if the device is idle: take up
    /// to `batch` queued requests onto the committed plan.
    fn start_launch(&mut self, t: f64) {
        if self.queue.is_empty() || self.in_flight.is_some() {
            return;
        }
        let e = &self.sched.front.entries[self.committed];
        let take = e.batch.min(self.queue.len());
        let batch: Vec<f64> = self.queue.drain(..take).collect();
        self.in_flight = Some(Launch { done_s: t + e.latency_s(), arrivals: batch });
    }

    /// Handle the in-flight launch's completion — the drain point: tally
    /// each request's sojourn, apply a draining switch, start the next
    /// launch on the (possibly new) committed plan.
    pub fn on_completion(&mut self) -> Completed {
        let launch = self.in_flight.take().expect("on_completion with no launch in flight");
        let done_s = launch.done_s;
        let mut sojourns = launch.arrivals;
        for a in sojourns.iter_mut() {
            let sojourn = done_s - *a;
            self.latency.push(sojourn);
            self.est.record_completion(done_s, sojourn);
            self.served += 1;
            *a = sojourn;
        }
        if let Some(to) = self.draining.take() {
            self.committed = to; // drain complete: swap now
        }
        self.start_launch(done_s);
        Completed { done_s, sojourns }
    }

    /// Run one decision window: estimate the load, let the scheduler
    /// decide (drain-and-swap when a launch is in flight, immediate swap
    /// when idle), and record the [`WindowStat`].
    pub fn on_window(&mut self, window: usize, end_s: f64) {
        let snapshot = self.est.estimate(end_s, self.queue.len());
        if self.draining.is_none() {
            if let Some(to) = self.sched.on_window(window, end_s, &snapshot) {
                if self.in_flight.is_some() {
                    self.draining = Some(to); // drain-and-swap
                } else {
                    self.committed = to;
                }
            }
        }
        self.windows.push(WindowStat {
            window,
            end_s,
            rate_rps: snapshot.rate_rps,
            queue_depth: snapshot.queue_depth,
            p99_s: snapshot.p99_s,
            committed: self.committed,
            draining: self.draining,
        });
    }

    /// Handle one routed arrival: record it with the estimator (offered
    /// load includes what admission sheds), then admit into the queue or
    /// shed explicitly. Returns whether the request was admitted.
    pub fn on_arrival(&mut self, t: f64) -> bool {
        self.routed += 1;
        self.est.record_arrival(t);
        if self.sched.admit(self.queue.len()) {
            self.queue.push_back(t);
            self.max_queue_depth = self.max_queue_depth.max(self.queue.len());
            self.start_launch(t);
            true
        } else {
            self.shed += 1;
            false
        }
    }

    /// Consume the device into its end-of-run tally.
    pub fn into_report(self) -> DeviceSimReport {
        DeviceSimReport {
            routed: self.routed,
            served: self.served,
            shed: self.shed,
            latency: self.latency,
            max_queue_depth: self.max_queue_depth,
            switches: self.sched.switches,
            windows: self.windows,
            final_committed: self.committed,
            final_draining: self.draining,
        }
    }
}

/// Fleet-level rollup of one [`run_timeline`] run.
pub struct TimelineOutcome {
    /// Sojourn times across every device, in completion order.
    pub latency: Summary,
    /// Arrivals the `route` callback declined (no eligible device).
    pub unroutable: usize,
    /// Completion time of the last served request (0 when nothing served).
    pub makespan_s: f64,
    /// Decision windows ticked (`round(duration_s / window_s)` — rounded,
    /// not truncated, so a `3 * 0.6 / 0.05 = 35.999…` ramp keeps its
    /// final window).
    pub n_windows: usize,
}

/// The shared discrete-event loop: replay a merged `(arrival time, class)`
/// timeline against `devs`, dispatching each arrival through `route`
/// (`route(devs, class, t)` returns the device index, or `None` for an
/// unroutable class). Every tie-order decision lives here and only here:
/// completion (lowest device index first), then window tick, then arrival.
pub fn run_timeline(
    devs: &mut [DeviceSim],
    timeline: &[(f64, usize)],
    duration_s: f64,
    window_s: f64,
    mut route: impl FnMut(&[DeviceSim], usize, f64) -> Option<usize>,
) -> TimelineOutcome {
    let n_windows = (duration_s / window_s).round() as usize;
    let mut latency = Summary::new();
    let mut unroutable = 0usize;
    let mut makespan_s = 0.0f64;
    let mut ai = 0usize; // next arrival index
    let mut w = 0usize; // next window index

    loop {
        let t_arr = timeline.get(ai).map(|&(t, _)| t).unwrap_or(f64::INFINITY);
        // Earliest completion across devices (tie: lowest device index).
        let mut t_done = f64::INFINITY;
        let mut done_dev = 0usize;
        for (i, d) in devs.iter().enumerate() {
            let td = d.next_completion_s();
            if td < t_done {
                t_done = td;
                done_dev = i;
            }
        }
        let t_win = if w < n_windows { (w + 1) as f64 * window_s } else { f64::INFINITY };
        if t_arr == f64::INFINITY && t_done == f64::INFINITY && t_win == f64::INFINITY {
            break;
        }

        if t_done <= t_win && t_done <= t_arr {
            // -- launch completion (and switch drain point) --------------
            let done = devs[done_dev].on_completion();
            for &s in &done.sojourns {
                latency.push(s);
            }
            makespan_s = makespan_s.max(done.done_s);
        } else if t_win <= t_arr {
            // -- decision window boundary (all devices) ------------------
            for d in devs.iter_mut() {
                d.on_window(w, t_win);
            }
            w += 1;
        } else {
            // -- arrival: route, then per-device admission ---------------
            let (t, class) = timeline[ai];
            match route(devs, class, t) {
                None => unroutable += 1,
                Some(di) => {
                    devs[di].on_arrival(t);
                }
            }
            ai += 1;
        }
    }

    TimelineOutcome { latency, unroutable, makespan_s, n_windows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::front::FrontEntry;

    fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
        FrontEntry {
            assign: vec![0; 8],
            batch,
            latency_ms: lat_ms,
            tops: rps * 2.5e-3,
            rps,
            nacc: 1,
            label: label.to_string(),
        }
    }

    fn front() -> PlanFront {
        PlanFront::new(
            "m",
            12,
            vec![entry("seq", 1, 0.2, 5000.0), entry("spatial", 24, 2.0, 12000.0)],
        )
        .unwrap()
    }

    fn cfg() -> SchedulerCfg {
        SchedulerCfg { slo_ms: 20.0, ..Default::default() }
    }

    #[test]
    fn launch_batches_and_completes_in_fifo_order() {
        let mut d = DeviceSim::new(front(), cfg());
        assert_eq!(d.next_completion_s(), f64::INFINITY);
        assert!(d.on_arrival(0.0)); // starts a batch-1 launch immediately
        assert!(d.on_arrival(0.00005));
        assert_eq!(d.depth(), 2);
        let done = d.on_completion();
        assert_eq!(done.sojourns.len(), 1);
        assert!((done.done_s - 0.2e-3).abs() < 1e-12);
        // the queued request started its own launch at the completion
        assert_eq!(d.depth(), 1);
        let r = {
            d.on_completion();
            d.into_report()
        };
        assert_eq!(r.served, 2);
        assert_eq!(r.shed, 0);
        assert_eq!(r.routed, 2);
        assert_eq!(r.final_draining, None);
    }

    #[test]
    fn drain_and_swap_applies_at_completion_not_at_the_window() {
        // Force a switch decision while a launch is in flight: the window
        // must record {committed: old, draining: Some(new)} and the swap
        // must land exactly at the completion.
        let mut d = DeviceSim::new(front(), cfg());
        // saturate the estimator with arrivals so the scheduler wants the
        // throughput point (demand >> seq capacity)
        for i in 0..600 {
            d.on_arrival(i as f64 * 1e-4); // 10k req/s offered
        }
        let c = cfg();
        // patience windows of sustained overload commit the switch
        let mut committed_window = None;
        for w in 0..4 {
            d.on_window(w, (w + 1) as f64 * c.window_s);
            let ws = *d.windows.last().unwrap();
            if ws.draining.is_some() {
                committed_window = Some(w);
                break;
            }
        }
        let ws = *d.windows.last().unwrap();
        assert!(
            committed_window.is_some(),
            "sustained overload never committed a switch: {:?}",
            d.windows
        );
        assert_eq!(ws.committed, 0, "swap applied before the drain completed");
        assert_eq!(ws.draining, Some(1));
        d.on_completion();
        assert_eq!(d.committed, 1, "drain completion must apply the pending switch");
        assert_eq!(d.draining, None);
    }

    #[test]
    fn run_timeline_counts_unroutable_and_windows() {
        let mut devs = vec![DeviceSim::new(front(), cfg())];
        let timeline = vec![(0.01, 0), (0.02, 1), (0.03, 0)];
        let out = run_timeline(&mut devs, &timeline, 0.5, 0.05, |_, class, _| {
            (class == 0).then_some(0)
        });
        assert_eq!(out.unroutable, 1);
        assert_eq!(out.n_windows, 10);
        let r = devs.pop().unwrap().into_report();
        assert_eq!(r.routed, 2);
        assert_eq!(r.served + r.shed, r.routed);
        assert_eq!(r.windows.len(), 10);
    }
}
