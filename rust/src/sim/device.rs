//! The one queueing truth: the per-device discrete-event serving core.
//!
//! Both deterministic serving replays — the single-device
//! [`crate::sim::serving::serve_ramp`] and the fleet-level
//! [`crate::cluster::sim::simulate_fleet`] — used to carry hand-duplicated
//! copies of the same ~80 lines of launch/drain-and-swap/admission
//! machinery, so any semantic drift between them was a silent correctness
//! bug in every off-hardware latency-throughput claim. This module is the
//! merge: one [`DeviceSim`] holds a device's queue, in-flight launch,
//! [`LoadEstimator`] + [`AdaptiveScheduler`] wiring, admission control,
//! per-window [`WindowStat`] snapshots, and tallies; one [`run_timeline`]
//! event loop owns the tie order. The two public sims are thin adapters
//! over these and can no longer fork. The fleet autoscaler
//! ([`crate::cluster::controller`]) drives the same core through
//! [`run_timeline_controlled`], adding device lifecycle transitions
//! without forking the queueing semantics either.
//!
//! ## The contract
//!
//! * **Event tie order** (deterministic): launch **completion** (lowest
//!   device index first on exact time ties), then the decision **window**
//!   tick (all devices, index order, then the fleet-control hook), then
//!   the **arrival**.
//! * **Drain-and-swap** (plan level): a switch committed by the scheduler
//!   while a launch is in flight becomes `draining` and is applied to
//!   `committed` at that launch's completion; queued requests carry over
//!   to the new plan and are never dropped. With no launch in flight the
//!   switch applies immediately.
//! * **Admission before queueing**: every routed arrival is recorded with
//!   the estimator (shed ones included — the estimator sees offered load),
//!   then either queued or explicitly shed. `served + shed +
//!   requeued_away == routed` per device, always (`requeued_away` is zero
//!   unless a fleet controller drains or fails the device).
//! * **Admission is judged against the scheduler's active plan** (the
//!   switch target while draining), not the plan still executing — the
//!   queue being admitted will drain on the new plan.
//!
//! ## The event calendar
//!
//! Earliest-completion selection runs on an indexed calendar: a min-heap
//! of `(completion time, device index)` keys with *lazy invalidation*,
//! not a per-event O(D) scan over the fleet. The rules that keep it
//! bit-identical to the scan it replaced (the scan survives as a
//! `#[cfg(test)]` reference implementation, pinned by a differential
//! test):
//!
//! * Keys order by time then device index. For the non-negative finite
//!   times a sim produces, IEEE-754 bit patterns order exactly like the
//!   values, so keys store `f64::to_bits` and derive plain integer
//!   ordering — ties pop the lowest device index, matching the old
//!   first-minimum scan.
//! * A key is *valid* iff its time still bit-equals the device's
//!   `next_completion_s()`. Anything can invalidate a device's key
//!   (completion, failure) without touching the heap; stale tops are
//!   discarded on peek. Duplicate valid keys are harmless — "device `d`
//!   completes at `t`" is true however many copies exist.
//! * Every state change that can *create* a finite completion pushes a
//!   key: device init, a completion starting the next launch, an
//!   admitted arrival/requeue starting a launch on an idle device. A
//!   [`FleetControl`] hook that reports `mutates_fleet()` additionally
//!   triggers a full O(D) resync after it runs — belt and braces for
//!   controllers that mutate devices in ways the loop can't see.
//! * Device indices are stable: controllers only ever push onto `devs`
//!   (retired/failed devices stay in place), so a key's index never
//!   dangles.
//!
//! Arrivals stream through the [`ArrivalSource`] trait — a slice-backed
//! adapter ([`SliceArrivals`]) for tests and pre-materialized timelines,
//! and the lazily-generated
//! [`crate::traffic::ArrivalStream`] for O(1)-memory
//! replay. Latency lands in either an exact [`Summary`]+completions pair
//! ([`run_timeline_controlled`]) or an O(1)-memory [`LatencySketch`]
//! ([`run_timeline_sketched`]); the event sequence is identical either
//! way.
//!
//! ## Two kinds of "draining"
//!
//! The word shows up at two different levels; the code keeps them apart:
//!
//! * **plan drain** — `DeviceSim::draining: Option<usize>`: a committed
//!   *plan switch* waiting for the in-flight launch to finish. The device
//!   keeps serving throughout.
//! * **lifecycle drain** — [`DeviceState::Draining`]: the *device itself*
//!   is leaving the fleet (scale-in or a rolling front swap). The router
//!   stops sending it traffic, its queued requests are requeued onto
//!   peers, and the in-flight launch finishes before the device retires —
//!   hitless decommission.
//!
//! ## Divergences the unification fixed
//!
//! Extracting the core surfaced (and removed) two reporting divergences
//! between the forked copies:
//!
//! 1. the single-device sim recorded per-window [`WindowStat`]s while the
//!    fleet sim recorded none — now every device records them;
//! 2. the per-window "active" plan was the lagging executing index while
//!    the end-of-run `active_final`/`final_active` was the scheduler's
//!    committed choice — two different notions of "current plan" mid-drain
//!    under one name. Both reports now expose `{committed, draining}`
//!    explicitly, per window and at end of run.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::coordinator::scheduler::{
    AdaptiveScheduler, LoadEstimate, LoadEstimator, SchedulerCfg, SwitchRecord,
};
use crate::obs::{NoopRecorder, Recorder, TraceEvent};
use crate::plan::front::{FrontEntry, PlanFront};
use crate::sim::service::ServiceModel;
use crate::util::rng::Rng;
use crate::util::stats::{LatencySketch, Summary};

/// Lifecycle of one simulated device (distinct from the *plan*-level
/// drain-and-swap; see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceState {
    /// Serving: the router may send it traffic.
    Active,
    /// Leaving the fleet: no new traffic, queue already requeued onto
    /// peers, in-flight launch still completing.
    Draining,
    /// Decommissioned cleanly (drain finished). Terminal.
    Retired,
    /// Killed by fault injection; its queue and in-flight work were
    /// requeued onto survivors. Terminal.
    Failed,
}

/// One request in the system: when it arrived (fleet clock) and which
/// traffic class it belongs to. The class travels with the request so a
/// drain or failover can re-route it to an eligible peer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Req {
    pub arrived_s: f64,
    pub class: usize,
}

/// Per-window snapshot of one device's simulated state.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WindowStat {
    pub window: usize,
    pub end_s: f64,
    /// Estimated arrival rate at the window boundary (req/s).
    pub rate_rps: f64,
    pub queue_depth: usize,
    /// p99 completion latency over the estimator horizon (seconds).
    pub p99_s: f64,
    /// Plan executing at the window boundary (lags the scheduler's choice
    /// while a committed switch drains).
    pub committed: usize,
    /// Switch target still draining at the boundary, when one is pending.
    pub draining: Option<usize>,
}

/// One in-flight launch: the requests it serves and its completion time.
struct Launch {
    done_s: f64,
    arrivals: Vec<Req>,
}

/// Outcome of one launch completion, for fleet-level rollups.
pub struct Completed {
    /// Completion time (the launch's `done_s`).
    pub done_s: f64,
    /// Per-request sojourn times of the requests this launch served.
    pub sojourns: Vec<f64>,
}

/// End-of-run tally of one device — the single source both public report
/// shapes ([`crate::sim::serving::ServeSimReport`] and
/// [`crate::cluster::sim::DeviceStat`]) are assembled from.
#[derive(Clone, Debug)]
pub struct DeviceSimReport {
    /// Requests routed to this device (`served + shed + requeued_away`),
    /// including requeues that landed here from a drained/failed peer.
    pub routed: usize,
    pub served: usize,
    pub shed: usize,
    /// Requests handed off to peers when this device drained or failed.
    pub requeued_away: usize,
    /// Requests that landed here after a peer drained or failed.
    pub requeued_in: usize,
    /// Per-request sojourn time (queue wait + service), served requests.
    /// Empty when the device was built
    /// [`DeviceSim::without_latency_samples`].
    pub latency: Summary,
    pub max_queue_depth: usize,
    pub switches: Vec<SwitchRecord>,
    pub windows: Vec<WindowStat>,
    /// Plan executing when the run ended.
    pub final_committed: usize,
    /// Switch target still draining when the run ended (`None` after a
    /// clean drain: the event loop always completes in-flight launches).
    pub final_draining: Option<usize>,
    /// Lifecycle state when the run ended ([`DeviceState::Active`] for
    /// every device of a static, uncontrolled fleet).
    pub lifecycle: DeviceState,
}

/// One device's complete simulation state: queue, in-flight launch, the
/// exact drain-and-swap point, scheduler + estimator wiring, admission,
/// window snapshots, lifecycle, and tallies. Drive it only through
/// [`run_timeline`] / [`run_timeline_controlled`] (or mirror their tie
/// order exactly).
pub struct DeviceSim {
    sched: AdaptiveScheduler,
    est: LoadEstimator,
    queue: VecDeque<Req>,
    in_flight: Option<Launch>,
    /// Plan executing the current launch — lags `sched.active()` while a
    /// committed switch drains.
    committed: usize,
    /// Committed switch target waiting for the in-flight launch to drain.
    draining: Option<usize>,
    lifecycle: DeviceState,
    /// Recycled launch buffer: the request Vec of the last completed
    /// launch, cleared, waiting to carry the next one — the steady-state
    /// serve loop allocates nothing per event.
    spare: Vec<Req>,
    /// Record per-request sojourns into `latency` (exact reports need
    /// them; the O(1)-memory sweep path turns them off).
    keep_samples: bool,
    /// Per-launch service-time distribution ([`ServiceModel::Deterministic`]
    /// unless built [`DeviceSim::with_service`]).
    service: ServiceModel,
    /// Dedicated service-draw stream (see [`crate::sim::service`]); never
    /// advanced on the `Deterministic` path.
    service_rng: Rng,
    /// `(plan, factor)` of the most recent stochastic launch, for the
    /// recorder: `run_core` takes it when emitting the `Launch` event and
    /// prepends a `ServiceDraw`. Stays `None` forever under
    /// `Deterministic`; silently overwritten when no recorder is attached.
    pending_draw: Option<(usize, f64)>,
    routed: usize,
    served: usize,
    shed: usize,
    requeued_away: usize,
    requeued_in: usize,
    latency: Summary,
    max_queue_depth: usize,
    windows: Vec<WindowStat>,
}

impl DeviceSim {
    pub fn new(front: PlanFront, cfg: SchedulerCfg) -> DeviceSim {
        let sched = AdaptiveScheduler::new(front, cfg);
        let committed = sched.active();
        DeviceSim {
            est: LoadEstimator::new(cfg.horizon_s()),
            sched,
            queue: VecDeque::new(),
            in_flight: None,
            committed,
            draining: None,
            lifecycle: DeviceState::Active,
            spare: Vec::new(),
            keep_samples: true,
            service: ServiceModel::Deterministic,
            service_rng: Rng::new(0),
            pending_draw: None,
            routed: 0,
            served: 0,
            shed: 0,
            requeued_away: 0,
            requeued_in: 0,
            latency: Summary::new(),
            max_queue_depth: 0,
            windows: Vec::new(),
        }
    }

    /// Drop per-request latency samples: tallies, windows, and switches
    /// are still recorded, but `latency` stays empty so memory is O(1) in
    /// requests served. The sweep/bench replay path uses this and reads
    /// latency from the event loop's [`LatencySketch`] sink instead.
    pub fn without_latency_samples(mut self) -> DeviceSim {
        self.keep_samples = false;
        self
    }

    /// Attach a stochastic service-time model: every launch's duration is
    /// `entry.latency_s() * model.sample(rng)`. Pass the device's slice of
    /// the dedicated [`crate::sim::service::SERVICE_STREAM`] — arrival,
    /// routing, and control streams must never see a service draw. With
    /// [`ServiceModel::Deterministic`] this is a no-op by construction:
    /// the RNG is stored but never advanced and the launch expression is
    /// exactly the pre-noise `t + e.latency_s()`.
    pub fn with_service(mut self, model: ServiceModel, rng: Rng) -> DeviceSim {
        self.service = model;
        self.service_rng = rng;
        self
    }

    /// The p99-aware scheduler's derating source: quantile `q` of this
    /// device's service-time factor distribution.
    pub fn service_tail_q(&self, q: f64) -> f64 {
        self.service.tail_q(q)
    }

    /// Front entry of the plan currently *executing* (the router-visible
    /// service curve; lags the scheduler's choice while a switch drains).
    pub fn committed_entry(&self) -> &FrontEntry {
        &self.sched.front.entries[self.committed]
    }

    /// Model this device serves (its front's model).
    pub fn model(&self) -> &str {
        &self.sched.front.model
    }

    pub fn state(&self) -> DeviceState {
        self.lifecycle
    }

    /// Routable: the dispatcher may send this device new traffic.
    pub fn is_serving(&self) -> bool {
        self.lifecycle == DeviceState::Active
    }

    /// Powered: the board is still occupied (serving or finishing its
    /// drain) — what device-hour accounting bills for.
    pub fn is_live(&self) -> bool {
        matches!(self.lifecycle, DeviceState::Active | DeviceState::Draining)
    }

    /// Per-window snapshots recorded so far.
    pub fn window_stats(&self) -> &[WindowStat] {
        &self.windows
    }

    pub fn last_window(&self) -> Option<&WindowStat> {
        self.windows.last()
    }

    /// Current load estimate without mutating the estimator — what a
    /// fleet controller polls between decision windows (see
    /// [`LoadEstimator::peek`]).
    pub fn load_estimate(&self, now_s: f64) -> LoadEstimate {
        self.est.peek(now_s, self.queue.len())
    }

    /// Requests queued or in flight — the router-visible depth.
    pub fn depth(&self) -> usize {
        self.queue.len() + self.in_flight.as_ref().map_or(0, |l| l.arrivals.len())
    }

    /// Completion time of the in-flight launch (`INFINITY` when idle).
    pub fn next_completion_s(&self) -> f64 {
        self.in_flight.as_ref().map_or(f64::INFINITY, |l| l.done_s)
    }

    /// Start the next launch from the queue if the device is idle: take up
    /// to `batch` queued requests onto the committed plan. Reuses the
    /// recycled `spare` buffer — no allocation once the sim is warm.
    fn start_launch(&mut self, t: f64) {
        if self.queue.is_empty() || self.in_flight.is_some() {
            return;
        }
        let e = &self.sched.front.entries[self.committed];
        let take = e.batch.min(self.queue.len());
        let mut batch = std::mem::take(&mut self.spare);
        batch.extend(self.queue.drain(..take));
        // Deterministic keeps the exact pre-noise expression (no draw, no
        // multiply) so bit-identity holds by construction.
        let done_s = if self.service.is_deterministic() {
            t + e.latency_s()
        } else {
            let factor = self.service.sample(&mut self.service_rng);
            self.pending_draw = Some((self.committed, factor));
            t + e.latency_s() * factor
        };
        self.in_flight = Some(Launch { done_s, arrivals: batch });
    }

    /// Handle the in-flight launch's completion — the drain point: tally
    /// each request's sojourn, apply a draining switch, start the next
    /// launch on the (possibly new) committed plan, and retire the device
    /// if it was lifecycle-draining and is now empty.
    pub fn on_completion(&mut self) -> Completed {
        let mut sojourns = Vec::new();
        let done_s = self.on_completion_into(&mut sojourns);
        Completed { done_s, sojourns }
    }

    /// Allocation-free [`DeviceSim::on_completion`]: sojourns land in the
    /// caller's buffer (cleared first), and the completed launch's request
    /// Vec is recycled for the next launch. Returns the completion time.
    pub fn on_completion_into(&mut self, sojourns: &mut Vec<f64>) -> f64 {
        let launch = self.in_flight.take().expect("on_completion with no launch in flight");
        let done_s = launch.done_s;
        sojourns.clear();
        sojourns.reserve(launch.arrivals.len());
        for req in &launch.arrivals {
            let sojourn = done_s - req.arrived_s;
            if self.keep_samples {
                self.latency.push(sojourn);
            }
            self.est.record_completion(done_s, sojourn);
            self.served += 1;
            sojourns.push(sojourn);
        }
        if let Some(to) = self.draining.take() {
            self.committed = to; // drain complete: swap now
        }
        let mut spare = launch.arrivals;
        spare.clear();
        self.spare = spare;
        self.start_launch(done_s);
        if self.lifecycle == DeviceState::Draining && self.in_flight.is_none() {
            // queue was requeued at begin_drain, the last launch just
            // landed: hitless decommission complete
            self.lifecycle = DeviceState::Retired;
        }
        done_s
    }

    /// Run one decision window: estimate the load, let the scheduler
    /// decide (drain-and-swap when a launch is in flight, immediate swap
    /// when idle), and record the [`WindowStat`]. Retired/failed devices
    /// are inert; lifecycle-draining devices record stats but make no
    /// plan decisions (no new work will arrive).
    pub fn on_window(&mut self, window: usize, end_s: f64) {
        if !self.is_live() {
            return;
        }
        let snapshot = self.est.estimate(end_s, self.queue.len());
        if self.lifecycle == DeviceState::Active && self.draining.is_none() {
            if let Some(to) = self.sched.on_window(window, end_s, &snapshot) {
                if self.in_flight.is_some() {
                    self.draining = Some(to); // drain-and-swap
                } else {
                    self.committed = to;
                }
            }
        }
        self.windows.push(WindowStat {
            window,
            end_s,
            rate_rps: snapshot.rate_rps,
            queue_depth: snapshot.queue_depth,
            p99_s: snapshot.p99_s,
            committed: self.committed,
            draining: self.draining,
        });
    }

    /// Handle one routed arrival: record it with the estimator (offered
    /// load includes what admission sheds), then admit into the queue or
    /// shed explicitly. Returns whether the request was admitted.
    pub fn on_arrival(&mut self, t: f64, class: usize) -> bool {
        self.routed += 1;
        self.est.record_arrival(t);
        if self.sched.admit(self.queue.len()) {
            self.queue.push_back(Req { arrived_s: t, class });
            self.max_queue_depth = self.max_queue_depth.max(self.queue.len());
            self.start_launch(t);
            true
        } else {
            self.shed += 1;
            false
        }
    }

    /// Accept a request requeued from a drained/failed peer at `now_s`.
    /// The request keeps its original arrival time (its sojourn honestly
    /// includes the time lost on the old device), but the estimator and
    /// any fresh launch run on the fleet clock — a launch can never start
    /// in the past. Requeues pass the same admission control as fresh
    /// arrivals: a saturated survivor sheds rather than queueing
    /// unboundedly.
    pub fn on_requeue(&mut self, req: Req, now_s: f64) -> bool {
        self.routed += 1;
        self.requeued_in += 1;
        self.est.record_arrival(now_s);
        if self.sched.admit(self.queue.len()) {
            self.queue.push_back(req);
            self.max_queue_depth = self.max_queue_depth.max(self.queue.len());
            self.start_launch(now_s);
            true
        } else {
            self.shed += 1;
            false
        }
    }

    /// Begin hitless decommission (scale-in, or one step of a rolling
    /// front swap): stop being routable, hand the queued requests back for
    /// re-dispatch onto peers, and keep only the in-flight launch, which
    /// retires the device at its completion. A device with nothing in
    /// flight retires immediately. No-op (empty) unless currently active.
    pub fn begin_drain(&mut self) -> Vec<Req> {
        if self.lifecycle != DeviceState::Active {
            return Vec::new();
        }
        let moved: Vec<Req> = self.queue.drain(..).collect();
        self.requeued_away += moved.len();
        self.lifecycle = if self.in_flight.is_some() {
            DeviceState::Draining
        } else {
            DeviceState::Retired
        };
        moved
    }

    /// Kill the device (fault injection): the in-flight launch dies
    /// mid-service and both it and the queue are handed back for
    /// re-dispatch onto survivors, original arrival times preserved.
    /// No-op (empty) unless the device is live.
    pub fn fail(&mut self) -> Vec<Req> {
        if !self.is_live() {
            return Vec::new();
        }
        // FIFO by arrival: the killed launch's requests precede the queue.
        let mut moved: Vec<Req> =
            self.in_flight.take().map(|l| l.arrivals).unwrap_or_default();
        moved.extend(self.queue.drain(..));
        self.requeued_away += moved.len();
        self.draining = None;
        self.lifecycle = DeviceState::Failed;
        moved
    }

    /// Consume the device into its end-of-run tally.
    pub fn into_report(self) -> DeviceSimReport {
        DeviceSimReport {
            routed: self.routed,
            served: self.served,
            shed: self.shed,
            requeued_away: self.requeued_away,
            requeued_in: self.requeued_in,
            latency: self.latency,
            max_queue_depth: self.max_queue_depth,
            switches: self.sched.switches,
            windows: self.windows,
            final_committed: self.committed,
            final_draining: self.draining,
            lifecycle: self.lifecycle,
        }
    }
}

// ---------------------------------------------------------------------------
// Arrival sources
// ---------------------------------------------------------------------------

/// A nondecreasing stream of `(arrival time, class)` events. The event
/// loop peeks the head to arbitrate against completions and windows, and
/// pops exactly the events it consumes — a lazy source generates each
/// arrival on demand and never materializes the timeline.
pub trait ArrivalSource {
    /// Time of the next arrival, `INFINITY` when exhausted.
    fn peek_s(&self) -> f64;
    /// Consume and return the next arrival.
    fn pop(&mut self) -> Option<(f64, usize)>;
}

/// [`ArrivalSource`] over a pre-materialized, sorted timeline slice.
pub struct SliceArrivals<'a> {
    timeline: &'a [(f64, usize)],
    next: usize,
}

impl<'a> SliceArrivals<'a> {
    pub fn new(timeline: &'a [(f64, usize)]) -> SliceArrivals<'a> {
        SliceArrivals { timeline, next: 0 }
    }
}

impl ArrivalSource for SliceArrivals<'_> {
    fn peek_s(&self) -> f64 {
        self.timeline.get(self.next).map_or(f64::INFINITY, |&(t, _)| t)
    }

    fn pop(&mut self) -> Option<(f64, usize)> {
        let item = self.timeline.get(self.next).copied();
        if item.is_some() {
            self.next += 1;
        }
        item
    }
}

// ---------------------------------------------------------------------------
// Outcomes and control
// ---------------------------------------------------------------------------

/// Fleet-level rollup of one [`run_timeline`] run (exact-stats mode:
/// every sojourn sample retained).
pub struct TimelineOutcome {
    /// Sojourn times across every device, in completion order.
    pub latency: Summary,
    /// `(completion time, sojourn)` per served request, in completion
    /// order — lets a caller attribute latency back to arrival time
    /// (`arrived = done - sojourn`), e.g. per ramp phase.
    pub completions: Vec<(f64, f64)>,
    /// Arrivals consumed from the source (the loop always drains it).
    pub arrivals: usize,
    /// Arrivals the `route` callback declined (no eligible device).
    pub unroutable: usize,
    /// Requests handed back by the control hook (drains + failures).
    pub requeued: usize,
    /// Requeued requests no eligible device could take — terminally lost
    /// to the caller's accounting (a fleet report counts them as shed).
    pub requeue_lost: usize,
    /// Completion time of the last served request (0 when nothing served).
    pub makespan_s: f64,
    /// Decision windows ticked (`round(duration_s / window_s)` — rounded,
    /// not truncated, so a `3 * 0.6 / 0.05 = 35.999…` ramp keeps its
    /// final window).
    pub n_windows: usize,
    /// Discrete events processed (completions + window ticks + arrivals)
    /// — the denominator of the events/sec bench metric.
    pub events: u64,
}

/// [`TimelineOutcome`]'s O(1)-memory sibling ([`run_timeline_sketched`]):
/// latency lives in a fixed-size [`LatencySketch`] instead of full
/// samples + completions, so replay memory does not grow with request
/// count. Same event sequence, same tallies.
pub struct SketchOutcome {
    /// Streaming sojourn rollup across every device.
    pub latency: LatencySketch,
    /// Arrivals consumed from the source.
    pub arrivals: usize,
    pub unroutable: usize,
    pub requeued: usize,
    pub requeue_lost: usize,
    pub makespan_s: f64,
    pub n_windows: usize,
    /// Discrete events processed (completions + window ticks + arrivals).
    pub events: u64,
}

/// Fleet-level control consulted once per decision window, after every
/// device ticked. The hook may mutate the fleet — push scale-out devices,
/// [`DeviceSim::begin_drain`] one, [`DeviceSim::fail`] one — and returns
/// the requests those transitions displaced; the event loop re-dispatches
/// them through the router at the window boundary. [`NoControl`] is the
/// static-fleet no-op.
pub trait FleetControl {
    fn after_window(&mut self, devs: &mut Vec<DeviceSim>, window: usize, end_s: f64)
        -> Vec<Req>;

    /// Whether `after_window` may change device state at all. When true
    /// (the conservative default), the event loop resyncs its completion
    /// calendar after every hook call; [`NoControl`] opts out so the
    /// static-fleet path pays nothing.
    fn mutates_fleet(&self) -> bool {
        true
    }
}

/// The do-nothing control: a static fleet.
pub struct NoControl;

impl FleetControl for NoControl {
    fn after_window(&mut self, _: &mut Vec<DeviceSim>, _: usize, _: f64) -> Vec<Req> {
        Vec::new()
    }

    fn mutates_fleet(&self) -> bool {
        false
    }
}

// ---------------------------------------------------------------------------
// The event loop
// ---------------------------------------------------------------------------

/// Where a served request's sojourn goes: the exact path keeps every
/// sample (and its completion time), the sketch path streams it into
/// fixed bins. Monomorphized per loop, so the exact path pays nothing
/// for the abstraction.
trait LatencySink {
    fn on_sojourn(&mut self, done_s: f64, sojourn_s: f64);
}

/// Exact sink: full samples + completion times (the pinned-test mode).
#[derive(Default)]
struct ExactSink {
    latency: Summary,
    completions: Vec<(f64, f64)>,
}

impl LatencySink for ExactSink {
    fn on_sojourn(&mut self, done_s: f64, sojourn_s: f64) {
        self.latency.push(sojourn_s);
        self.completions.push((done_s, sojourn_s));
    }
}

impl LatencySink for LatencySketch {
    fn on_sojourn(&mut self, _done_s: f64, sojourn_s: f64) {
        self.record(sojourn_s);
    }
}

/// Calendar key: completion time (as raw bits) then device index. For
/// non-negative finite f64s — the only times a sim produces — `to_bits`
/// ordering equals numeric ordering, so a derived lexicographic `Ord`
/// reproduces `total_cmp(t).then(dev.cmp)` exactly and ties break toward
/// the lowest device index, like the linear scan's first-minimum rule.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct CalKey {
    t_bits: u64,
    dev: usize,
}

/// Push a calendar key for device `dev` completing at `t` (no-op when
/// idle: `INFINITY` never enters the heap).
fn push_key(cal: &mut BinaryHeap<Reverse<CalKey>>, dev: usize, t: f64) {
    if t.is_finite() {
        debug_assert!(t >= 0.0, "negative completion time {t}");
        cal.push(Reverse(CalKey { t_bits: t.to_bits(), dev }));
    }
}

/// Re-key every device's current completion (init, and the post-control
/// resync). Duplicates of still-valid keys are harmless by construction.
fn resync_calendar(cal: &mut BinaryHeap<Reverse<CalKey>>, devs: &[DeviceSim]) {
    for (i, d) in devs.iter().enumerate() {
        push_key(cal, i, d.next_completion_s());
    }
}

/// Tallies shared by both outcome shapes.
struct CoreTallies {
    arrivals: usize,
    unroutable: usize,
    requeued: usize,
    requeue_lost: usize,
    makespan_s: f64,
    n_windows: usize,
    events: u64,
}

/// The shared event loop, generic over where latency samples go and over
/// the [`Recorder`] observing it. Event selection runs on the indexed
/// calendar (see the module docs); the branch structure and tie order are
/// verbatim from the linear-scan loop it replaced, pinned by
/// `calendar_matches_linear_reference` below. With [`NoopRecorder`] every
/// `rec.record(..)` call and the event constructions feeding it
/// monomorphize to nothing (`enabled()` is a constant `false`), so the
/// recorder-off loop is the pre-observability loop — pinned bit-identical
/// in `tests/obs_trace.rs` and by the allocation counters in
/// `benches/simcore.rs`.
#[allow(clippy::too_many_arguments)]
fn run_core<S: LatencySink, R: Recorder>(
    devs: &mut Vec<DeviceSim>,
    arrivals: &mut impl ArrivalSource,
    duration_s: f64,
    window_s: f64,
    mut route: impl FnMut(&[DeviceSim], usize, f64) -> Option<usize>,
    ctl: &mut impl FleetControl,
    sink: &mut S,
    rec: &mut R,
) -> CoreTallies {
    let n_windows = (duration_s / window_s).round() as usize;
    let mut tallies = CoreTallies {
        arrivals: 0,
        unroutable: 0,
        requeued: 0,
        requeue_lost: 0,
        makespan_s: 0.0,
        n_windows,
        events: 0,
    };
    let mut cal: BinaryHeap<Reverse<CalKey>> = BinaryHeap::new();
    resync_calendar(&mut cal, devs);
    let mut sojourns: Vec<f64> = Vec::new(); // recycled per completion
    let mut w = 0usize; // next window index

    loop {
        let t_arr = arrivals.peek_s();
        // Earliest valid completion: discard stale tops (device no longer
        // completes at that exact time), keep the valid top in the heap —
        // it only pops if the completion branch wins this iteration.
        let (t_done, done_dev) = loop {
            match cal.peek() {
                None => break (f64::INFINITY, usize::MAX),
                Some(&Reverse(CalKey { t_bits, dev })) => {
                    if devs[dev].next_completion_s().to_bits() == t_bits {
                        break (f64::from_bits(t_bits), dev);
                    }
                    cal.pop();
                }
            }
        };
        let t_win = if w < n_windows { (w + 1) as f64 * window_s } else { f64::INFINITY };
        if t_arr == f64::INFINITY && t_done == f64::INFINITY && t_win == f64::INFINITY {
            break;
        }

        if t_done <= t_win && t_done <= t_arr {
            // -- launch completion (and switch drain point) --------------
            cal.pop(); // the valid top we just selected
            let committed_before = devs[done_dev].committed;
            let done_s = devs[done_dev].on_completion_into(&mut sojourns);
            for &s in &sojourns {
                sink.on_sojourn(done_s, s);
                if rec.enabled() {
                    rec.record(TraceEvent::Served { at_s: done_s, dev: done_dev, sojourn_s: s });
                }
            }
            tallies.makespan_s = tallies.makespan_s.max(done_s);
            // completing may have started the next launch from the queue
            let next = devs[done_dev].next_completion_s();
            if rec.enabled() {
                if devs[done_dev].committed != committed_before {
                    rec.record(TraceEvent::PlanApplied {
                        at_s: done_s,
                        dev: done_dev,
                        plan: devs[done_dev].committed,
                    });
                }
                if next.is_finite() {
                    if let Some((plan, factor)) = devs[done_dev].pending_draw.take() {
                        rec.record(TraceEvent::ServiceDraw {
                            at_s: done_s,
                            dev: done_dev,
                            plan,
                            factor,
                        });
                    }
                    rec.record(TraceEvent::Launch {
                        at_s: done_s,
                        dev: done_dev,
                        plan: devs[done_dev].committed,
                        done_s: next,
                    });
                }
            }
            push_key(&mut cal, done_dev, next);
        } else if t_win <= t_arr {
            // -- decision window boundary (all devices, then control) ----
            // on_window never starts or finishes launches, so no re-keying.
            for (i, d) in devs.iter_mut().enumerate() {
                let switches_before = d.sched.switches.len();
                d.on_window(w, t_win);
                if rec.enabled() {
                    if let Some(ws) = d.windows.last() {
                        if ws.window == w {
                            rec.record(TraceEvent::DeviceWindow {
                                window: w,
                                end_s: t_win,
                                dev: i,
                                rate_rps: ws.rate_rps,
                                queue_depth: ws.queue_depth,
                                p99_s: ws.p99_s,
                                committed: ws.committed,
                            });
                        }
                    }
                    if d.sched.switches.len() > switches_before {
                        let sr = d.sched.switches.last().expect("switch just recorded");
                        rec.record(TraceEvent::PlanSwitch {
                            at_s: sr.at_s,
                            window: w,
                            dev: i,
                            from: sr.from,
                            to: sr.to,
                            draining: d.draining.is_some(),
                        });
                    }
                }
            }
            if rec.enabled() {
                rec.record(TraceEvent::Window { window: w, end_s: t_win });
            }
            let moved = ctl.after_window(devs, w, t_win);
            if ctl.mutates_fleet() {
                // The hook may have failed devices (stale keys — handled
                // lazily) or mutated them in ways that create completions;
                // re-key everything finite so the calendar invariant holds
                // for any controller, not just the ones written today.
                resync_calendar(&mut cal, devs);
            }
            tallies.requeued += moved.len();
            for req in moved {
                let class = req.class;
                match route(devs, class, t_win) {
                    Some(di) => {
                        let before = devs[di].next_completion_s().to_bits();
                        let admitted = devs[di].on_requeue(req, t_win);
                        let after = devs[di].next_completion_s();
                        if rec.enabled() {
                            rec.record(TraceEvent::Requeue {
                                at_s: t_win,
                                window: w,
                                dev: di,
                                class,
                                admitted,
                            });
                        }
                        if after.to_bits() != before {
                            if rec.enabled() {
                                if let Some((plan, factor)) = devs[di].pending_draw.take() {
                                    rec.record(TraceEvent::ServiceDraw {
                                        at_s: t_win,
                                        dev: di,
                                        plan,
                                        factor,
                                    });
                                }
                                rec.record(TraceEvent::Launch {
                                    at_s: t_win,
                                    dev: di,
                                    plan: devs[di].committed,
                                    done_s: after,
                                });
                            }
                            push_key(&mut cal, di, after); // idle device launched
                        }
                    }
                    None => {
                        tallies.requeue_lost += 1;
                        if rec.enabled() {
                            rec.record(TraceEvent::RequeueLost { at_s: t_win, window: w, class });
                        }
                    }
                }
            }
            w += 1;
        } else {
            // -- arrival: route, then per-device admission ---------------
            let (t, class) = arrivals.pop().expect("peeked arrival vanished");
            match route(devs, class, t) {
                None => {
                    tallies.unroutable += 1;
                    if rec.enabled() {
                        rec.record(TraceEvent::Unroutable { at_s: t, class });
                    }
                }
                Some(di) => {
                    let before = devs[di].next_completion_s().to_bits();
                    let admitted = devs[di].on_arrival(t, class);
                    let after = devs[di].next_completion_s();
                    if rec.enabled() {
                        if admitted {
                            rec.record(TraceEvent::Arrival { at_s: t, dev: di, class });
                        } else {
                            rec.record(TraceEvent::Shed { at_s: t, dev: di, class });
                        }
                    }
                    if after.to_bits() != before {
                        if rec.enabled() {
                            if let Some((plan, factor)) = devs[di].pending_draw.take() {
                                rec.record(TraceEvent::ServiceDraw {
                                    at_s: t,
                                    dev: di,
                                    plan,
                                    factor,
                                });
                            }
                            rec.record(TraceEvent::Launch {
                                at_s: t,
                                dev: di,
                                plan: devs[di].committed,
                                done_s: after,
                            });
                        }
                        push_key(&mut cal, di, after); // idle device launched
                    }
                }
            }
            tallies.arrivals += 1;
        }
        tallies.events += 1;
    }

    tallies
}

/// The shared discrete-event loop for a static fleet: replay a merged
/// `(arrival time, class)` timeline against `devs`, dispatching each
/// arrival through `route` (`route(devs, class, t)` returns the device
/// index, or `None` for an unroutable class). Every tie-order decision
/// lives in [`run_core`] and only there: completion (lowest device index
/// first), then window tick, then arrival.
pub fn run_timeline(
    devs: &mut Vec<DeviceSim>,
    timeline: &[(f64, usize)],
    duration_s: f64,
    window_s: f64,
    route: impl FnMut(&[DeviceSim], usize, f64) -> Option<usize>,
) -> TimelineOutcome {
    run_timeline_controlled(
        devs,
        &mut SliceArrivals::new(timeline),
        duration_s,
        window_s,
        route,
        &mut NoControl,
    )
}

/// [`run_timeline`] plus a lazy [`ArrivalSource`] and a [`FleetControl`]
/// hook: the autoscaling / failover / rolling-swap face of the same event
/// loop. With [`NoControl`] the behavior is bit-identical to the static
/// loop — the hook runs after all devices ticked a window and its
/// displaced requests are re-dispatched through `route` at the window
/// boundary, in the order the hook returned them. Exact-stats mode:
/// every sojourn sample and completion time is retained.
pub fn run_timeline_controlled(
    devs: &mut Vec<DeviceSim>,
    arrivals: &mut impl ArrivalSource,
    duration_s: f64,
    window_s: f64,
    route: impl FnMut(&[DeviceSim], usize, f64) -> Option<usize>,
    ctl: &mut impl FleetControl,
) -> TimelineOutcome {
    run_timeline_recorded(devs, arrivals, duration_s, window_s, route, ctl, &mut NoopRecorder)
}

/// [`run_timeline_controlled`] with a [`Recorder`] observing the run:
/// every loop decision (arrival/shed/launch/completion/requeue, per-device
/// window rollups, plan switches, window boundaries) is emitted as a
/// structured [`TraceEvent`] in deterministic order. Recording never
/// changes behavior — the outcome is bit-identical to the unrecorded run
/// (pinned in `tests/obs_trace.rs`).
pub fn run_timeline_recorded(
    devs: &mut Vec<DeviceSim>,
    arrivals: &mut impl ArrivalSource,
    duration_s: f64,
    window_s: f64,
    route: impl FnMut(&[DeviceSim], usize, f64) -> Option<usize>,
    ctl: &mut impl FleetControl,
    rec: &mut impl Recorder,
) -> TimelineOutcome {
    let mut sink = ExactSink::default();
    let t = run_core(devs, arrivals, duration_s, window_s, route, ctl, &mut sink, rec);
    TimelineOutcome {
        latency: sink.latency,
        completions: sink.completions,
        arrivals: t.arrivals,
        unroutable: t.unroutable,
        requeued: t.requeued,
        requeue_lost: t.requeue_lost,
        makespan_s: t.makespan_s,
        n_windows: t.n_windows,
        events: t.events,
    }
}

/// [`run_timeline_controlled`] with an O(1)-memory [`LatencySketch`] sink
/// instead of full samples: the default for sweeps and benches, where
/// replay memory must not grow with request count. Pair with
/// [`DeviceSim::without_latency_samples`] on each device — the event
/// sequence and every tally stay identical to the exact path.
pub fn run_timeline_sketched(
    devs: &mut Vec<DeviceSim>,
    arrivals: &mut impl ArrivalSource,
    duration_s: f64,
    window_s: f64,
    route: impl FnMut(&[DeviceSim], usize, f64) -> Option<usize>,
    ctl: &mut impl FleetControl,
) -> SketchOutcome {
    run_timeline_sketched_recorded(
        devs,
        arrivals,
        duration_s,
        window_s,
        route,
        ctl,
        &mut NoopRecorder,
    )
}

/// [`run_timeline_sketched`] plus a [`Recorder`] — the sweep/bench face
/// of [`run_timeline_recorded`].
pub fn run_timeline_sketched_recorded(
    devs: &mut Vec<DeviceSim>,
    arrivals: &mut impl ArrivalSource,
    duration_s: f64,
    window_s: f64,
    route: impl FnMut(&[DeviceSim], usize, f64) -> Option<usize>,
    ctl: &mut impl FleetControl,
    rec: &mut impl Recorder,
) -> SketchOutcome {
    let mut sink = LatencySketch::new();
    let t = run_core(devs, arrivals, duration_s, window_s, route, ctl, &mut sink, rec);
    SketchOutcome {
        latency: sink,
        arrivals: t.arrivals,
        unroutable: t.unroutable,
        requeued: t.requeued,
        requeue_lost: t.requeue_lost,
        makespan_s: t.makespan_s,
        n_windows: t.n_windows,
        events: t.events,
    }
}

/// The pre-calendar event loop, kept verbatim as the differential
/// reference: earliest completion by O(D) linear scan, first minimum
/// wins. `calendar_matches_linear_reference` pins the heap loop to this
/// bit for bit.
#[cfg(test)]
pub fn run_timeline_linear_reference(
    devs: &mut Vec<DeviceSim>,
    timeline: &[(f64, usize)],
    duration_s: f64,
    window_s: f64,
    mut route: impl FnMut(&[DeviceSim], usize, f64) -> Option<usize>,
    ctl: &mut impl FleetControl,
) -> TimelineOutcome {
    let n_windows = (duration_s / window_s).round() as usize;
    let mut latency = Summary::new();
    let mut completions = Vec::new();
    let mut unroutable = 0usize;
    let mut requeued = 0usize;
    let mut requeue_lost = 0usize;
    let mut makespan_s = 0.0f64;
    let mut events = 0u64;
    let mut ai = 0usize; // next arrival index
    let mut w = 0usize; // next window index

    loop {
        let t_arr = timeline.get(ai).map(|&(t, _)| t).unwrap_or(f64::INFINITY);
        // Earliest completion across devices (tie: lowest device index).
        let mut t_done = f64::INFINITY;
        let mut done_dev = 0usize;
        for (i, d) in devs.iter().enumerate() {
            let td = d.next_completion_s();
            if td < t_done {
                t_done = td;
                done_dev = i;
            }
        }
        let t_win = if w < n_windows { (w + 1) as f64 * window_s } else { f64::INFINITY };
        if t_arr == f64::INFINITY && t_done == f64::INFINITY && t_win == f64::INFINITY {
            break;
        }

        if t_done <= t_win && t_done <= t_arr {
            let done = devs[done_dev].on_completion();
            for &s in &done.sojourns {
                latency.push(s);
                completions.push((done.done_s, s));
            }
            makespan_s = makespan_s.max(done.done_s);
        } else if t_win <= t_arr {
            for d in devs.iter_mut() {
                d.on_window(w, t_win);
            }
            let moved = ctl.after_window(devs, w, t_win);
            requeued += moved.len();
            for req in moved {
                match route(devs, req.class, t_win) {
                    Some(di) => {
                        devs[di].on_requeue(req, t_win);
                    }
                    None => requeue_lost += 1,
                }
            }
            w += 1;
        } else {
            let (t, class) = timeline[ai];
            match route(devs, class, t) {
                None => unroutable += 1,
                Some(di) => {
                    devs[di].on_arrival(t, class);
                }
            }
            ai += 1;
        }
        events += 1;
    }

    TimelineOutcome {
        latency,
        completions,
        arrivals: ai,
        unroutable,
        requeued,
        requeue_lost,
        makespan_s,
        n_windows,
        events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::front::FrontEntry;
    use crate::util::rng::Rng;

    fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
        FrontEntry {
            assign: vec![0; 8],
            batch,
            latency_ms: lat_ms,
            tops: rps * 2.5e-3,
            rps,
            nacc: 1,
            label: label.to_string(),
        }
    }

    fn front() -> PlanFront {
        PlanFront::new(
            "m",
            12,
            vec![entry("seq", 1, 0.2, 5000.0), entry("spatial", 24, 2.0, 12000.0)],
        )
        .unwrap()
    }

    fn cfg() -> SchedulerCfg {
        SchedulerCfg { slo_ms: 20.0, ..Default::default() }
    }

    #[test]
    fn launch_batches_and_completes_in_fifo_order() {
        let mut d = DeviceSim::new(front(), cfg());
        assert_eq!(d.next_completion_s(), f64::INFINITY);
        assert!(d.on_arrival(0.0, 0)); // starts a batch-1 launch immediately
        assert!(d.on_arrival(0.00005, 0));
        assert_eq!(d.depth(), 2);
        let done = d.on_completion();
        assert_eq!(done.sojourns.len(), 1);
        assert!((done.done_s - 0.2e-3).abs() < 1e-12);
        // the queued request started its own launch at the completion
        assert_eq!(d.depth(), 1);
        let r = {
            d.on_completion();
            d.into_report()
        };
        assert_eq!(r.served, 2);
        assert_eq!(r.shed, 0);
        assert_eq!(r.routed, 2);
        assert_eq!(r.final_draining, None);
        assert_eq!(r.lifecycle, DeviceState::Active);
    }

    #[test]
    fn drain_and_swap_applies_at_completion_not_at_the_window() {
        // Force a switch decision while a launch is in flight: the window
        // must record {committed: old, draining: Some(new)} and the swap
        // must land exactly at the completion.
        let mut d = DeviceSim::new(front(), cfg());
        // saturate the estimator with arrivals so the scheduler wants the
        // throughput point (demand >> seq capacity)
        for i in 0..600 {
            d.on_arrival(i as f64 * 1e-4, 0); // 10k req/s offered
        }
        let c = cfg();
        // patience windows of sustained overload commit the switch
        let mut committed_window = None;
        for w in 0..4 {
            d.on_window(w, (w + 1) as f64 * c.window_s);
            let ws = *d.windows.last().unwrap();
            if ws.draining.is_some() {
                committed_window = Some(w);
                break;
            }
        }
        let ws = *d.windows.last().unwrap();
        assert!(
            committed_window.is_some(),
            "sustained overload never committed a switch: {:?}",
            d.windows
        );
        assert_eq!(ws.committed, 0, "swap applied before the drain completed");
        assert_eq!(ws.draining, Some(1));
        d.on_completion();
        assert_eq!(d.committed, 1, "drain completion must apply the pending switch");
        assert_eq!(d.draining, None);
    }

    #[test]
    fn run_timeline_counts_unroutable_and_windows() {
        let mut devs = vec![DeviceSim::new(front(), cfg())];
        let timeline = vec![(0.01, 0), (0.02, 1), (0.03, 0)];
        let out = run_timeline(&mut devs, &timeline, 0.5, 0.05, |_, class, _| {
            (class == 0).then_some(0)
        });
        assert_eq!(out.arrivals, 3);
        assert_eq!(out.unroutable, 1);
        assert_eq!(out.requeued, 0);
        assert_eq!(out.requeue_lost, 0);
        assert_eq!(out.n_windows, 10);
        assert_eq!(out.completions.len(), out.latency.len());
        // events = arrivals + windows + completions (one launch per served
        // request here: batch-1 seq plan, 2 routable arrivals)
        assert_eq!(out.events, 3 + 10 + 2);
        let r = devs.pop().unwrap().into_report();
        assert_eq!(r.routed, 2);
        assert_eq!(r.served + r.shed, r.routed);
        assert_eq!(r.windows.len(), 10);
    }

    #[test]
    fn begin_drain_requeues_queue_and_retires_at_completion() {
        let mut d = DeviceSim::new(front(), cfg());
        for i in 0..5 {
            d.on_arrival(i as f64 * 1e-5, 0); // 1 in flight + 4 queued
        }
        assert_eq!(d.depth(), 5);
        let moved = d.begin_drain();
        assert_eq!(moved.len(), 4, "queued requests move to peers");
        assert_eq!(d.state(), DeviceState::Draining);
        assert!(d.is_live() && !d.is_serving());
        assert_eq!(d.depth(), 1, "in-flight launch keeps draining");
        d.on_completion();
        assert_eq!(d.state(), DeviceState::Retired);
        // idempotent: draining/retired devices hand back nothing more
        assert!(d.begin_drain().is_empty());
        let r = d.into_report();
        assert_eq!(r.requeued_away, 4);
        assert_eq!(r.served + r.shed + r.requeued_away, r.routed);
        assert_eq!(r.lifecycle, DeviceState::Retired);
    }

    #[test]
    fn drain_with_idle_device_retires_immediately() {
        let mut d = DeviceSim::new(front(), cfg());
        assert!(d.begin_drain().is_empty());
        assert_eq!(d.state(), DeviceState::Retired);
    }

    #[test]
    fn fail_requeues_in_flight_and_queue_fifo() {
        let mut d = DeviceSim::new(front(), cfg());
        for i in 0..3 {
            d.on_arrival(i as f64 * 1e-5, 7);
        }
        let moved = d.fail();
        assert_eq!(moved.len(), 3, "in-flight + queued all move");
        // FIFO by arrival: the killed launch's request first
        assert!(moved.windows(2).all(|w| w[0].arrived_s <= w[1].arrived_s));
        assert!(moved.iter().all(|r| r.class == 7), "class travels with the request");
        assert_eq!(d.state(), DeviceState::Failed);
        assert_eq!(d.next_completion_s(), f64::INFINITY, "killed launch never completes");
        assert!(d.fail().is_empty(), "failing a dead device is a no-op");
        let r = d.into_report();
        assert_eq!(r.served, 0);
        assert_eq!(r.requeued_away, 3);
        assert_eq!(r.served + r.shed + r.requeued_away, r.routed);
    }

    #[test]
    fn requeue_keeps_original_arrival_time_but_launches_on_the_fleet_clock() {
        let mut d = DeviceSim::new(front(), cfg());
        // request arrived at t=0.01 elsewhere, requeued here at t=0.05
        assert!(d.on_requeue(Req { arrived_s: 0.01, class: 0 }, 0.05));
        let done = d.on_completion();
        // launch started at 0.05 (not in the past), sojourn spans from 0.01
        assert!((done.done_s - (0.05 + 0.2e-3)).abs() < 1e-12);
        assert!((done.sojourns[0] - (0.04 + 0.2e-3)).abs() < 1e-12);
        let r = d.into_report();
        assert_eq!(r.requeued_in, 1);
        assert_eq!(r.served, 1);
    }

    #[test]
    fn retired_and_failed_devices_record_no_further_windows() {
        let mut d = DeviceSim::new(front(), cfg());
        d.on_window(0, 0.05);
        assert_eq!(d.window_stats().len(), 1);
        d.fail();
        d.on_window(1, 0.10);
        assert_eq!(d.window_stats().len(), 1, "failed device must be inert");
    }

    #[test]
    fn controlled_timeline_redispatches_a_failed_devices_work() {
        // Two devices; a control hook kills device 0 at the first window.
        // Its queued work must land on device 1 and be served — nothing
        // lost, conservation across the handoff.
        struct KillAtWindow(usize, bool);
        impl FleetControl for KillAtWindow {
            fn after_window(
                &mut self,
                devs: &mut Vec<DeviceSim>,
                w: usize,
                _end_s: f64,
            ) -> Vec<Req> {
                if w == self.0 && !self.1 {
                    self.1 = true;
                    return devs[0].fail();
                }
                Vec::new()
            }
        }
        let mut devs = vec![DeviceSim::new(front(), cfg()), DeviceSim::new(front(), cfg())];
        // 10k req/s against device 0's 5k req/s seq point: a standing
        // queue is guaranteed at the kill (window 1, t = 0.1 s), and after
        // the kill only serving devices are eligible
        let timeline: Vec<(f64, usize)> = (0..5000).map(|i| (i as f64 * 1e-4, 0)).collect();
        let out = run_timeline_controlled(
            &mut devs,
            &mut SliceArrivals::new(&timeline),
            0.5,
            0.05,
            |devs, _class, _t| devs.iter().position(|d| d.is_serving()),
            &mut KillAtWindow(1, false),
        );
        assert!(out.requeued > 0, "the kill must displace queued work");
        assert_eq!(out.requeue_lost, 0, "device 1 takes the requeues");
        assert_eq!(out.arrivals, timeline.len());
        let r0 = devs.remove(0).into_report();
        let r1 = devs.remove(0).into_report();
        assert_eq!(r0.lifecycle, DeviceState::Failed);
        assert_eq!(r1.lifecycle, DeviceState::Active);
        assert_eq!(r1.requeued_in, out.requeued);
        assert_eq!(r0.served + r0.shed + r0.requeued_away, r0.routed);
        assert_eq!(r1.served + r1.shed + r1.requeued_away, r1.routed);
        // every arrival is terminally served or shed across the fleet
        assert_eq!(r0.served + r1.served + r0.shed + r1.shed, timeline.len());
        assert_eq!(out.latency.len(), r0.served + r1.served);
    }

    // -- differential: heap calendar vs the linear-scan reference --------

    /// Deterministic chaos controller: per window, a seeded draw may fail
    /// a live device, drain an active one, or add a fresh device (capped).
    /// Two instances with the same seed make identical decisions, so the
    /// heap loop and the reference loop see the same control sequence.
    struct ChaosControl {
        rng: Rng,
        spawned: usize,
    }

    impl ChaosControl {
        fn new(seed: u64) -> ChaosControl {
            ChaosControl { rng: Rng::new(seed), spawned: 0 }
        }
    }

    impl FleetControl for ChaosControl {
        fn after_window(
            &mut self,
            devs: &mut Vec<DeviceSim>,
            _w: usize,
            _end_s: f64,
        ) -> Vec<Req> {
            let roll = self.rng.f64();
            if roll < 0.15 {
                let live: Vec<usize> = (0..devs.len())
                    .filter(|&i| devs[i].is_live())
                    .collect();
                if let Some(&i) = (!live.is_empty()).then(|| self.rng.choose(&live)) {
                    return devs[i].fail();
                }
            } else if roll < 0.30 {
                let active: Vec<usize> = (0..devs.len())
                    .filter(|&i| devs[i].is_serving())
                    .collect();
                // keep at least one serving device so work stays routable
                if active.len() > 1 {
                    let i = *self.rng.choose(&active);
                    return devs[i].begin_drain();
                }
            } else if roll < 0.45 && self.spawned < 3 {
                self.spawned += 1;
                devs.push(DeviceSim::new(front(), cfg()));
            }
            Vec::new()
        }
    }

    /// Poisson-ish sorted single-class timeline at roughly `rate` req/s.
    fn chaos_timeline(rng: &mut Rng, rate: f64, duration_s: f64) -> Vec<(f64, usize)> {
        let mut t = 0.0;
        let mut out = Vec::new();
        loop {
            t += -(1.0 - rng.f64()).ln() / rate;
            if t >= duration_s {
                break out;
            }
            out.push((t, 0));
        }
    }

    #[test]
    fn calendar_matches_linear_reference() {
        // The tentpole pin: over randomized fleets, loads, and a chaos
        // controller (failures, drains, scale-out — every calendar
        // invalidation path), the indexed-calendar loop must reproduce the
        // linear-scan loop bit for bit: same tallies, same makespan and
        // quantile bits, same per-device reports.
        let qs = [0.0, 0.01, 0.25, 0.50, 0.75, 0.90, 0.99, 1.0];
        for seed in [1u64, 42, 0xBEEF, 7777] {
            let mut g = Rng::new(seed);
            let n_devs = 1 + g.usize_below(3);
            let rate = 3000.0 + g.f64() * 9000.0;
            let timeline = chaos_timeline(&mut g, rate, 0.6);
            let ctl_seed = g.next_u64();
            let route = |devs: &[DeviceSim], _class: usize, _t: f64| {
                devs.iter().position(|d| d.is_serving())
            };

            let mut devs_a: Vec<DeviceSim> =
                (0..n_devs).map(|_| DeviceSim::new(front(), cfg())).collect();
            let a = run_timeline_controlled(
                &mut devs_a,
                &mut SliceArrivals::new(&timeline),
                0.6,
                0.05,
                route,
                &mut ChaosControl::new(ctl_seed),
            );

            let mut devs_b: Vec<DeviceSim> =
                (0..n_devs).map(|_| DeviceSim::new(front(), cfg())).collect();
            let b = run_timeline_linear_reference(
                &mut devs_b,
                &timeline,
                0.6,
                0.05,
                route,
                &mut ChaosControl::new(ctl_seed),
            );

            let ctx = format!("seed {seed}");
            assert_eq!(a.arrivals, b.arrivals, "{ctx}: arrivals");
            assert_eq!(a.unroutable, b.unroutable, "{ctx}: unroutable");
            assert_eq!(a.requeued, b.requeued, "{ctx}: requeued");
            assert_eq!(a.requeue_lost, b.requeue_lost, "{ctx}: requeue_lost");
            assert_eq!(a.n_windows, b.n_windows, "{ctx}: n_windows");
            assert_eq!(a.events, b.events, "{ctx}: events");
            assert_eq!(
                a.makespan_s.to_bits(),
                b.makespan_s.to_bits(),
                "{ctx}: makespan"
            );
            assert_eq!(a.completions, b.completions, "{ctx}: completion sequence");
            let (pa, pb) = (a.latency.percentiles(&qs), b.latency.percentiles(&qs));
            for (x, y) in pa.iter().zip(&pb) {
                assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: quantiles");
            }
            assert_eq!(devs_a.len(), devs_b.len(), "{ctx}: fleet size");
            for (da, db) in devs_a.into_iter().zip(devs_b) {
                let (ra, rb) = (da.into_report(), db.into_report());
                assert_eq!(ra.routed, rb.routed, "{ctx}: routed");
                assert_eq!(ra.served, rb.served, "{ctx}: served");
                assert_eq!(ra.shed, rb.shed, "{ctx}: shed");
                assert_eq!(ra.requeued_away, rb.requeued_away, "{ctx}: requeued_away");
                assert_eq!(ra.requeued_in, rb.requeued_in, "{ctx}: requeued_in");
                assert_eq!(ra.switches, rb.switches, "{ctx}: switches");
                assert_eq!(ra.windows, rb.windows, "{ctx}: windows");
                assert_eq!(ra.lifecycle, rb.lifecycle, "{ctx}: lifecycle");
                assert_eq!(ra.max_queue_depth, rb.max_queue_depth, "{ctx}: depth");
            }
        }
    }

    #[test]
    fn sketched_run_matches_exact_tallies_and_event_sequence() {
        // The sketch sink changes where sojourns land, not what happens:
        // identical tallies, makespan bits, event count, and sample count.
        let mut g = Rng::new(0xFEED);
        let timeline = chaos_timeline(&mut g, 8000.0, 0.5);
        let route =
            |_: &[DeviceSim], _: usize, _: f64| -> Option<usize> { Some(0) };

        let mut exact_devs = vec![DeviceSim::new(front(), cfg())];
        let exact = run_timeline_controlled(
            &mut exact_devs,
            &mut SliceArrivals::new(&timeline),
            0.5,
            0.05,
            route,
            &mut NoControl,
        );
        let mut sk_devs = vec![DeviceSim::new(front(), cfg()).without_latency_samples()];
        let sk = run_timeline_sketched(
            &mut sk_devs,
            &mut SliceArrivals::new(&timeline),
            0.5,
            0.05,
            route,
            &mut NoControl,
        );
        assert_eq!(sk.arrivals, exact.arrivals);
        assert_eq!(sk.unroutable, exact.unroutable);
        assert_eq!(sk.events, exact.events);
        assert_eq!(sk.makespan_s.to_bits(), exact.makespan_s.to_bits());
        assert_eq!(sk.latency.count() as usize, exact.latency.len());
        assert_eq!(sk.latency.max_s().to_bits(), exact.latency.max().to_bits());
        let r = sk_devs.pop().unwrap().into_report();
        assert!(r.latency.is_empty(), "sketch mode keeps no per-device samples");
        assert_eq!(r.served, exact_devs.pop().unwrap().into_report().served);
    }
}
