//! Event-driven pipeline simulator — the "on-board measurement" substrate.
//!
//! The paper validates its analytical model against the physical VCK190
//! (Table 7, <5% error). Our board substitute is this simulator: it replays
//! a design's per-node costs through an explicit resource model —
//! accelerator occupancy, per-edge communication, and the shared DDR link —
//! with none of the closed-form approximations the analytical estimate
//! makes (no steady-state assumption, real slack between dependent stages,
//! DDR serialization).
//!
//! Tasks are (node, batch-index) instances, materialized from the design's
//! [`ExecutionPlan`] (the same IR the live pipeline server executes, so
//! simulated and served schedules cannot drift apart). Scheduling is
//! non-preemptive
//! earliest-start-first, which models the paper's greedy runtime ("assign
//! a layer to the pipeline as soon as its accelerator is available and its
//! dependencies are resolved", Sec. 4.4).

pub mod device;
pub mod service;
pub mod serving;
pub mod sweep;

use crate::analytical::comm::CommPath;
use crate::arch::Platform;
use crate::dse::eval::Evaluated;
use crate::graph::Graph;
use crate::plan::{ExecutionPlan, Granularity};

/// One schedulable task instance.
#[derive(Clone, Debug)]
pub struct Task {
    /// Graph node id.
    pub node: usize,
    /// Batch (image) index.
    pub batch: usize,
    /// Accelerator executing it.
    pub acc: usize,
    /// Busy seconds on the accelerator.
    pub dur: f64,
    /// Dependencies as task indices into the task vector.
    pub deps: Vec<usize>,
    /// Exposed comm seconds per dependency edge (same order as `deps`),
    /// plus whether the edge crosses the shared DDR link.
    pub comm: Vec<(f64, bool)>,
    /// Input bytes loaded from DDR before this task can start (image
    /// loads for Embed nodes) — contends on the shared link. The
    /// analytical estimate ignores this, which is (part of) the Table 7
    /// residual.
    pub input_bytes: u64,
}

/// Simulation outcome.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Completion time of the last task of each batch.
    pub batch_done_s: Vec<f64>,
    /// Total makespan (seconds).
    pub makespan_s: f64,
    /// Busy seconds per accelerator.
    pub acc_busy_s: Vec<f64>,
    /// Utilization per accelerator (busy / makespan).
    pub acc_util: Vec<f64>,
    /// Effective TOPS over the whole run.
    pub tops: f64,
}

/// Simulate `ev` on `platform` with `batches` images launched at t=0.
/// Replays the design's own [`ExecutionPlan`] (`ev.plan`) — the same IR the
/// pipeline server executes live.
pub fn simulate(
    platform: &Platform,
    ev: &Evaluated,
    graph: &Graph,
    batches: usize,
) -> SimResult {
    simulate_plan(platform, ev, graph, &ev.plan, batches)
}

/// Simulate an explicit class-granular plan (one step per graph node).
pub fn simulate_plan(
    platform: &Platform,
    ev: &Evaluated,
    graph: &Graph,
    plan: &ExecutionPlan,
    batches: usize,
) -> SimResult {
    let tasks = tasks_from_plan(platform, ev, graph, plan, batches);
    run(platform, &tasks, plan.nacc, graph, batches)
}

/// Materialize the (node, batch) task instances of `plan`: step schedules
/// and forwarding edges come from the plan, per-node busy/comm costs from
/// the evaluated design. Requires a class-granular plan whose steps cover
/// the graph 1:1.
pub fn tasks_from_plan(
    platform: &Platform,
    ev: &Evaluated,
    graph: &Graph,
    plan: &ExecutionPlan,
    batches: usize,
) -> Vec<Task> {
    assert_eq!(
        plan.granularity,
        Granularity::Class,
        "simulation needs a class-granular plan"
    );
    let n = plan.steps.len();
    assert_eq!(n, graph.nodes.len(), "plan does not cover the graph");

    // Incoming forwarding edges per step, in plan edge order.
    let mut incoming: Vec<Vec<&crate::plan::ForwardEdge>> = vec![Vec::new(); n];
    for e in &plan.edges {
        incoming[e.to].push(e);
    }

    let calib = crate::analytical::Calib::default();
    let mut tasks = Vec::with_capacity(n * batches);
    for b in 0..batches {
        for (si, step) in plan.steps.iter().enumerate() {
            let node_id = step.node.expect("class-granular step carries its node");
            let cost = &ev.node_costs[node_id];
            let mut deps: Vec<usize> = Vec::with_capacity(incoming[si].len() + 1);
            let mut comm: Vec<(f64, bool)> = Vec::with_capacity(incoming[si].len() + 1);
            for e in &incoming[si] {
                deps.push(b * n + e.from);
                // Exposed comm cost of this edge, looked up by producer node.
                let prod_node = plan.steps[e.from].node.unwrap();
                let (t, is_ddr) = cost
                    .comm_paths
                    .iter()
                    .find(|(p, _, _)| *p == prod_node)
                    .map(|(_, path, bytes)| {
                        (
                            crate::analytical::comm::comm_time(platform, &calib, *path, *bytes),
                            *path == CommPath::Ddr,
                        )
                    })
                    .unwrap_or((0.0, false));
                comm.push((t, is_ddr));
            }
            if b > 0 {
                // Stream order through the shared executable/acc state.
                deps.push((b - 1) * n + si);
                comm.push((0.0, false));
            }
            // Embed nodes load the raw image over DDR (INT8 HxWx3).
            let input_bytes = if graph.nodes[node_id].class == crate::graph::LayerClass::Embed {
                224 * 224 * 3
            } else {
                0
            };
            tasks.push(Task {
                node: node_id,
                batch: b,
                acc: step.acc,
                dur: cost.busy_s(),
                deps,
                comm,
                input_bytes,
            });
        }
    }
    tasks
}

/// Core event loop over prepared tasks: readiness-FIFO per accelerator
/// (a streaming dataflow engine consumes inputs in arrival order), global
/// completion events, and the DDR link as a serialized shared resource.
/// This is the same greedy discipline the paper's runtime uses (Sec. 4.4)
/// and the same policy as `Evaluated::evaluate` — the residual between the
/// two is exactly the explicitly-modeled contention (DDR) plus comm-edge
/// interleaving, which is what Table 7 quantifies.
pub fn run(
    _platform: &Platform,
    tasks: &[Task],
    nacc: usize,
    graph: &Graph,
    batches: usize,
) -> SimResult {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    let nt = tasks.len();
    let key = |s: f64| (s * 1e15) as u64;

    // successor lists
    let mut succs: Vec<Vec<(usize, usize)>> = vec![Vec::new(); nt]; // (succ task, dep slot)
    let mut pending: Vec<u32> = vec![0; nt];
    for (ti, t) in tasks.iter().enumerate() {
        pending[ti] = t.deps.len() as u32;
        for (slot, &d) in t.deps.iter().enumerate() {
            succs[d].push((ti, slot));
        }
    }

    let mut ready_time = vec![0.0f64; nt];
    let mut done = vec![0.0f64; nt];
    let mut acc_queue: Vec<BinaryHeap<Reverse<(u64, usize)>>> =
        (0..nacc).map(|_| BinaryHeap::new()).collect();
    let mut acc_idle = vec![true; nacc];
    let mut acc_busy = vec![0.0f64; nacc];
    let mut ddr_free = 0.0f64;
    let mut events: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    let mut now = 0.0f64;

    let ddr_rate = _platform.ddr_gbs * 1e9 * 0.6; // achieved strided BW
    for (ti, t) in tasks.iter().enumerate() {
        if pending[ti] == 0 {
            // serialize any DDR input load on the shared link
            if t.input_bytes > 0 {
                let xfer = t.input_bytes as f64 / ddr_rate;
                ready_time[ti] = ddr_free;
                ddr_free += xfer;
                ready_time[ti] = ddr_free;
            }
            acc_queue[tasks[ti].acc].push(Reverse((key(ready_time[ti]), ti)));
        }
    }
    let mut completed = 0usize;
    while completed < nt {
        // start work on every idle acc with queued tasks
        for acc in 0..nacc {
            if acc_idle[acc] {
                if let Some(Reverse((_, ti))) = acc_queue[acc].pop() {
                    let start = ready_time[ti].max(now);
                    let end = start + tasks[ti].dur;
                    acc_idle[acc] = false;
                    acc_busy[acc] += tasks[ti].dur;
                    events.push(Reverse((key(end), ti)));
                }
            }
        }
        let Some(Reverse((ek, ti))) = events.pop() else {
            panic!("deadlock: {completed}/{nt} tasks completed");
        };
        let end = ek as f64 / 1e15;
        now = end;
        done[ti] = end;
        completed += 1;
        acc_idle[tasks[ti].acc] = true;
        // release successors; DDR edges serialize on the shared link
        for &(succ, slot) in &succs[ti] {
            let (c, is_ddr) = tasks[succ].comm[slot];
            let arrive = if is_ddr && c > 0.0 {
                let xfer_start = end.max(ddr_free);
                ddr_free = xfer_start + c;
                xfer_start + c
            } else {
                end + c
            };
            ready_time[succ] = ready_time[succ].max(arrive);
            pending[succ] -= 1;
            if pending[succ] == 0 {
                acc_queue[tasks[succ].acc]
                    .push(Reverse((key(ready_time[succ]), succ)));
            }
        }
    }

    let n = graph.nodes.len();
    let batch_done: Vec<f64> = (0..batches)
        .map(|b| (0..n).map(|i| done[b * n + i]).fold(0.0f64, f64::max))
        .collect();
    let makespan = batch_done.iter().copied().fold(0.0f64, f64::max);
    let ops = (batches as u64 * graph.ops_per_image()) as f64;
    SimResult {
        batch_done_s: batch_done,
        makespan_s: makespan,
        acc_util: acc_busy.iter().map(|b| b / makespan.max(1e-30)).collect(),
        acc_busy_s: acc_busy,
        tops: ops / makespan / 1e12,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytical::{Calib, Features};
    use crate::arch::vck190;
    use crate::dse::eval::build_design;
    use crate::dse::Assignment;
    use crate::graph::{vit_graph, DEIT_T};
    use crate::util::stats::rel_err;

    fn sim_of(a: Assignment, batches: usize) -> (SimResult, f64) {
        let p = vck190();
        let cal = Calib::default();
        let g = vit_graph(&DEIT_T);
        let ev = build_design(&p, &cal, &g, &a, Features::all(), true).unwrap();
        let analytical = ev.evaluate(&p, &g, batches).latency_s;
        (simulate(&p, &ev, &g, batches), analytical)
    }

    #[test]
    fn sequential_sim_matches_analytical_closely() {
        // One acc, pure serial: the closed form is exact modulo comm edges.
        let (sim, ana) = sim_of(Assignment::sequential(), 6);
        assert!(
            rel_err(sim.makespan_s, ana) < 0.05,
            "sim {} vs analytical {}",
            sim.makespan_s,
            ana
        );
    }

    #[test]
    fn spatial_sim_within_table7_error_band() {
        // Table 7: analytical vs board <= ~6% across acc counts.
        let (sim, ana) = sim_of(Assignment::spatial(), 6);
        assert!(
            rel_err(sim.makespan_s, ana) < 0.15,
            "sim {} vs analytical {}",
            sim.makespan_s,
            ana
        );
    }

    #[test]
    fn batch_completion_monotone() {
        let (sim, _) = sim_of(Assignment::spatial(), 4);
        for w in sim.batch_done_s.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn pipelining_beats_serial_scaling() {
        // Spatial with 6 batches must finish well before 6x the 1-batch time.
        let (s1, _) = sim_of(Assignment::spatial(), 1);
        let (s6, _) = sim_of(Assignment::spatial(), 6);
        assert!(
            s6.makespan_s < 6.0 * s1.makespan_s * 0.7,
            "{} vs {}",
            s6.makespan_s,
            s1.makespan_s
        );
    }

    #[test]
    fn sequential_no_pipelining() {
        let (s1, _) = sim_of(Assignment::sequential(), 1);
        let (s6, _) = sim_of(Assignment::sequential(), 6);
        assert!(rel_err(s6.makespan_s, 6.0 * s1.makespan_s) < 0.05);
    }

    #[test]
    fn utilization_bounded() {
        let (sim, _) = sim_of(Assignment::spatial(), 6);
        for &u in &sim.acc_util {
            assert!((0.0..=1.0 + 1e-9).contains(&u), "util {u}");
        }
    }

    #[test]
    fn hybrid_runs_and_produces_finite_numbers() {
        let (sim, _) = sim_of(Assignment::new(vec![0, 1, 1, 1, 0, 2, 2, 0]), 6);
        assert!(sim.makespan_s.is_finite() && sim.makespan_s > 0.0);
        assert!(sim.tops.is_finite() && sim.tops > 0.0);
    }

    #[test]
    fn plan_sim_execution_model_ordering() {
        // The plan-driven simulator must reproduce the paper's Fig. 2
        // ordering between execution models — the same qualitative relations
        // the plan-driven pipeline server is held to (see
        // tests/plan_roundtrip.rs): sequential wins latency at batch 1,
        // spatial wins throughput at large batch.
        let (seq1, _) = sim_of(Assignment::sequential(), 1);
        let (spa1, _) = sim_of(Assignment::spatial(), 1);
        assert!(
            seq1.makespan_s <= spa1.makespan_s,
            "sequential b1 latency {} must not exceed spatial {}",
            seq1.makespan_s,
            spa1.makespan_s
        );
        let (seq6, _) = sim_of(Assignment::sequential(), 6);
        let (spa6, _) = sim_of(Assignment::spatial(), 6);
        assert!(
            spa6.tops >= seq6.tops,
            "spatial b6 throughput {} must not trail sequential {}",
            spa6.tops,
            seq6.tops
        );
    }

    #[test]
    fn explicit_plan_equals_builtin_plan() {
        // simulate() routes through ev.plan; an independently materialized
        // plan for the same assignment must give the identical schedule.
        let p = vck190();
        let cal = Calib::default();
        let g = vit_graph(&DEIT_T);
        let a = Assignment::new(vec![0, 1, 2, 2, 1, 3, 4, 0]); // nacc = 5 hybrid
        let ev = build_design(&p, &cal, &g, &a, Features::all(), true).unwrap();
        let external = crate::plan::ExecutionPlan::from_graph(&g, &a, 1);
        let s1 = simulate(&p, &ev, &g, 4);
        let s2 = simulate_plan(&p, &ev, &g, &external, 4);
        assert_eq!(s1.makespan_s, s2.makespan_s);
        assert_eq!(s1.acc_busy_s, s2.acc_busy_s);
        assert_eq!(s1.acc_busy_s.len(), 5);
    }
}
