//! Deterministic serving simulation of the adaptive scheduler.
//!
//! The artifact-free counterpart of
//! [`crate::coordinator::scheduler::AdaptiveServer`]: a discrete-event
//! queueing replay that drives the *same* [`AdaptiveScheduler`] policy
//! (same hysteresis, same admission control) against any
//! [`crate::traffic::TraceSpec`] workload (a bare
//! [`crate::traffic::RampSpec`] embeds as the single-class Poisson
//! case), with the service model taken from each
//! front entry's analytical metrics — one launch serves up to
//! `entry.batch` images and occupies the server for `entry.latency_ms`.
//!
//! All queueing semantics — drain-and-swap at launch completion, the
//! completion → window → arrival tie order, admission shedding — live in
//! the shared per-device core, [`crate::sim::device`]. [`serve_ramp`] is
//! literally a 1-device [`crate::cluster::sim::simulate_fleet`]: it turns
//! the trace into a lazy [`ArrivalStream`] and drives one [`DeviceSim`]
//! through the same [`run_timeline_recorded`] event loop the fleet sim
//! uses, so the two entry points cannot diverge
//! (`rust/tests/sim_unification.rs` pins them bit-identical).
//!
//! Note on seeds: since the unification, `serve_ramp` derives its arrival
//! stream from class stream 0 split off the base seed, exactly as a
//! 1-device fleet would — not from the raw seed as the pre-unification
//! sim did. Same distribution, different draw; every seeded assertion in
//! this module and `tests/adaptive_scheduler.rs` was revalidated against
//! the new streams with a bit-faithful offline replay of the PRNG + sim
//! core (the authoring container has no rust toolchain). The later
//! ramp→trace generalization kept that stream bit-identical for
//! ramp-shaped traffic (`rust/tests/traffic_trace.rs` pins it).
//!
//! The only way a request is lost is explicit admission-control shedding,
//! which the report accounts separately — so `served + shed == arrivals`
//! is an invariant, asserted by `tests/adaptive_scheduler.rs`.
//!
//! [`AdaptiveScheduler`]: crate::coordinator::scheduler::AdaptiveScheduler

use crate::coordinator::scheduler::{SchedulerCfg, SwitchRecord};
use crate::obs::{NoopRecorder, Recorder};
use crate::plan::front::PlanFront;
use crate::sim::device::{run_timeline_recorded, DeviceSim, NoControl};
use crate::sim::service::SERVICE_STREAM;
use crate::traffic::{ArrivalStream, TraceSpec};
use crate::util::rng::Rng;
use crate::util::stats::{fmt_ms, Summary};

pub use crate::sim::device::WindowStat;

/// Outcome of a simulated adaptive serving run.
#[derive(Clone, Debug)]
pub struct ServeSimReport {
    pub arrivals: usize,
    pub served: usize,
    pub shed: usize,
    /// Per-request sojourn time (queue wait + service), served requests.
    pub latency: Summary,
    /// Served requests whose sojourn exceeded the SLO.
    pub slo_violations: usize,
    pub switches: Vec<SwitchRecord>,
    pub windows: Vec<WindowStat>,
    pub max_queue_depth: usize,
    /// Completion time of the last served request.
    pub makespan_s: f64,
    /// Plan executing when the run ended.
    pub final_committed: usize,
    /// Switch target still draining at the end (`None` after a clean
    /// drain; the event loop always completes in-flight launches).
    pub final_draining: Option<usize>,
}

impl ServeSimReport {
    pub fn p50_ms(&self) -> f64 {
        self.latency.p50() * 1e3
    }

    pub fn p99_ms(&self) -> f64 {
        self.latency.p99() * 1e3
    }

    pub fn slo_attainment(&self) -> f64 {
        if self.served == 0 {
            return 1.0;
        }
        1.0 - self.slo_violations as f64 / self.served as f64
    }

    pub fn summary_line(&self) -> String {
        // An empty latency summary (nothing served) yields NaN
        // percentiles; fmt_ms prints those as "-" instead of "NaN ms".
        let pct = self.latency.percentiles(&[0.50, 0.99]);
        let draining = match self.final_draining {
            Some(d) => format!(" (draining -> [{d}])"),
            None => String::new(),
        };
        format!(
            "{} arrivals | {} served, {} shed | p50 {} ms p99 {} ms | SLO attainment \
             {:.1}% | {} plan switches | max queue {} | final plan committed [{}]{draining}",
            self.arrivals,
            self.served,
            self.shed,
            fmt_ms(pct[0]),
            fmt_ms(pct[1]),
            self.slo_attainment() * 100.0,
            self.switches.len(),
            self.max_queue_depth,
            self.final_committed
        )
    }
}

/// Simulate serving `traffic` (anything `Into<TraceSpec>`: a bare
/// `&RampSpec`, a `&TrafficMix`, or a full trace) over `front` with the
/// adaptive policy in `cfg`. Fully deterministic for a given seed, and
/// bit-identical to a 1-device
/// [`crate::cluster::sim::simulate_fleet`] over a single-class mix with
/// the same seed — both are the same [`run_timeline_recorded`] over the
/// same core.
pub fn serve_ramp(
    front: &PlanFront,
    traffic: impl Into<TraceSpec>,
    cfg: &SchedulerCfg,
    seed: u64,
) -> ServeSimReport {
    serve_ramp_observed(front, traffic, cfg, seed, &mut NoopRecorder)
}

/// [`serve_ramp`] with a [`Recorder`] observing the run (the report is
/// bit-identical either way; see `crate::obs`).
pub fn serve_ramp_observed(
    front: &PlanFront,
    traffic: impl Into<TraceSpec>,
    cfg: &SchedulerCfg,
    seed: u64,
    rec: &mut impl Recorder,
) -> ServeSimReport {
    let trace: TraceSpec = traffic.into();
    // Arrivals stream lazily (same split-seeded draws the materialized
    // timeline produced), so the replay never holds the whole timeline.
    let mut stream = ArrivalStream::from_trace(&trace, seed);
    // One device serves every class; its service model is class 0's (the
    // only sensible choice for a single queue). The draw stream splits
    // off SERVICE_STREAM without advancing the base, so arrivals and
    // routing never see a service draw.
    let service = trace
        .classes
        .first()
        .map(|c| c.service.clone())
        .unwrap_or(crate::sim::service::ServiceModel::Deterministic);
    let service_rng = Rng::new(seed).split(SERVICE_STREAM).split(0);
    let mut devs = vec![DeviceSim::new(front.clone(), *cfg).with_service(service, service_rng)];
    // One device: every arrival routes to it regardless of class/model.
    let outcome = run_timeline_recorded(
        &mut devs,
        &mut stream,
        trace.duration_s(),
        cfg.window_s,
        |_, _, _| Some(0),
        &mut NoControl,
        rec,
    );
    let dev = devs.pop().expect("one device").into_report();
    let slo_s = cfg.slo_ms * 1e-3;
    let slo_violations = dev.served - dev.latency.count_leq(slo_s);
    ServeSimReport {
        arrivals: outcome.arrivals,
        served: dev.served,
        shed: dev.shed,
        latency: dev.latency,
        slo_violations,
        switches: dev.switches,
        windows: dev.windows,
        max_queue_depth: dev.max_queue_depth,
        makespan_s: outcome.makespan_s,
        final_committed: dev.final_committed,
        final_draining: dev.final_draining,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::front::FrontEntry;
    use crate::traffic::RampSpec;

    fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
        FrontEntry {
            assign: vec![0; 8],
            batch,
            latency_ms: lat_ms,
            tops: rps * 2.5e-3,
            rps,
            nacc: 1,
            label: label.to_string(),
        }
    }

    fn front() -> PlanFront {
        PlanFront::new(
            "synthetic",
            12,
            vec![
                entry("seq", 1, 0.2, 5000.0),
                entry("hybrid", 6, 1.0, 6000.0),
                entry("spatial", 24, 2.0, 12000.0),
            ],
        )
        .unwrap()
    }

    fn cfg() -> SchedulerCfg {
        SchedulerCfg { slo_ms: 20.0, ..Default::default() }
    }

    #[test]
    fn conservation_served_plus_shed_equals_arrivals() {
        let ramp = RampSpec::parse("1000:4000:1000", 0.4).unwrap();
        let r = serve_ramp(&front(), &ramp, &cfg(), 7);
        assert_eq!(r.served + r.shed, r.arrivals);
        assert_eq!(r.latency.len(), r.served);
        assert!(r.arrivals > 1000, "load generator produced {}", r.arrivals);
    }

    #[test]
    fn deterministic_given_seed() {
        let ramp = RampSpec::parse("1000:4000", 0.3).unwrap();
        let a = serve_ramp(&front(), &ramp, &cfg(), 11);
        let b = serve_ramp(&front(), &ramp, &cfg(), 11);
        assert_eq!(a.served, b.served);
        assert_eq!(a.shed, b.shed);
        assert_eq!(a.switches, b.switches);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.latency.p99(), b.latency.p99());
        assert_eq!(a.windows, b.windows);
    }

    #[test]
    fn idle_ramp_serves_nothing_without_panicking() {
        let ramp = RampSpec::parse("0:0", 0.1).unwrap();
        let r = serve_ramp(&front(), &ramp, &cfg(), 3);
        assert_eq!(r.arrivals, 0);
        assert_eq!(r.served, 0);
        assert_eq!(r.shed, 0);
        assert!(r.switches.is_empty());
        assert_eq!(r.slo_attainment(), 1.0);
    }

    #[test]
    fn empty_run_summary_line_prints_dashes_not_nan() {
        // Percentiles of an empty Summary are NaN; the summary line must
        // guard them instead of printing "NaN ms".
        let ramp = RampSpec::parse("0:0", 0.1).unwrap();
        let r = serve_ramp(&front(), &ramp, &cfg(), 3);
        let line = r.summary_line();
        assert!(!line.contains("NaN"), "summary line leaked NaN: {line}");
        assert!(line.contains("p50 - ms p99 - ms"), "missing dash guard: {line}");
    }

    #[test]
    fn observed_run_is_bit_identical_to_unobserved() {
        use crate::obs::{trace_tallies, TraceRecorder};
        let ramp = RampSpec::parse("1000:4400:1000", 0.4).unwrap();
        let plain = serve_ramp(&front(), &ramp, &cfg(), 77);
        let mut rec = TraceRecorder::new();
        let observed = serve_ramp_observed(&front(), &ramp, &cfg(), 77, &mut rec);
        assert_eq!(plain.arrivals, observed.arrivals);
        assert_eq!(plain.served, observed.served);
        assert_eq!(plain.shed, observed.shed);
        assert_eq!(plain.switches, observed.switches);
        assert_eq!(plain.windows, observed.windows);
        assert_eq!(plain.makespan_s, observed.makespan_s);
        // and the trace alone reconstructs the report's tallies
        let t = trace_tallies(&rec.events);
        assert_eq!(t.arrivals as usize, observed.arrivals);
        assert_eq!(t.served as usize, observed.served);
        assert_eq!(t.shed as usize, observed.shed);
        assert_eq!(t.plan_switches as usize, observed.switches.len());
    }

    #[test]
    fn stochastic_service_conserves_and_stays_deterministic() {
        use crate::sim::service::ServiceModel;
        use crate::traffic::{ArrivalProcess, RateCurve};
        let trace = TraceSpec::single(
            "synthetic",
            RateCurve::Constant { rate_rps: 3000.0, duration_s: 0.6 },
            ArrivalProcess::Poisson,
        )
        .with_service(&ServiceModel::LognormalFactor { sigma: 1.0 });
        let a = serve_ramp(&front(), &trace, &cfg(), 7);
        let b = serve_ramp(&front(), &trace, &cfg(), 7);
        assert_eq!(a.served + a.shed, a.arrivals);
        assert_eq!((a.served, a.shed, a.makespan_s.to_bits()), (b.served, b.shed, b.makespan_s.to_bits()));
        // and turning noise on cannot perturb the arrival stream: the
        // deterministic twin sees the identical offered load
        let det = serve_ramp(&front(), trace.clone().with_service(&ServiceModel::Deterministic), &cfg(), 7);
        assert_eq!(det.arrivals, a.arrivals);
    }

    #[test]
    fn low_load_never_switches_off_the_latency_point() {
        let ramp = RampSpec::parse("500:500:500", 0.2).unwrap();
        let r = serve_ramp(&front(), &ramp, &cfg(), 5);
        assert!(r.switches.is_empty(), "switched under trivial load: {:?}", r.switches);
        assert_eq!(r.final_committed, 0);
        assert_eq!(r.final_draining, None);
        assert_eq!(r.shed, 0);
        // one launch at a time, batch 1: queue stays tiny
        assert!(r.max_queue_depth < 50);
    }

    #[test]
    fn windows_cover_the_ramp() {
        let c = cfg();
        let ramp = RampSpec::parse("1000:1000", 0.25).unwrap();
        let r = serve_ramp(&front(), &ramp, &c, 9);
        assert_eq!(r.windows.len(), 10); // 0.5 s of ramp / 50 ms windows
        // the float-truncation trap: 3 * 0.6 / 0.05 is 35.999..., and the
        // final decision window must not be lost to it
        let ramp = RampSpec::parse("1000:1000:1000", 0.6).unwrap();
        let r = serve_ramp(&front(), &ramp, &c, 9);
        assert_eq!(r.windows.len(), 36);
        for (i, ws) in r.windows.iter().enumerate() {
            assert_eq!(ws.window, i);
        }
    }

    #[test]
    fn windows_expose_committed_and_draining_consistently() {
        // While a window reports a draining target, the committed index
        // must still be the pre-switch plan; once no window drains, the
        // committed index matches the scheduler's final choice.
        let ramp = RampSpec::parse("1000:4400:1000", 0.6).unwrap();
        let r = serve_ramp(&front(), &ramp, &cfg(), 1234);
        for ws in &r.windows {
            if let Some(d) = ws.draining {
                assert_ne!(d, ws.committed, "draining toward the already-committed plan");
            }
        }
        assert_eq!(r.final_draining, None, "event loop must drain all launches");
    }
}
