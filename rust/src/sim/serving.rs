//! Deterministic serving simulation of the adaptive scheduler.
//!
//! The artifact-free counterpart of
//! [`crate::coordinator::scheduler::AdaptiveServer`]: a discrete-event
//! queueing replay that drives the *same* [`AdaptiveScheduler`] policy
//! (same hysteresis, same admission control) against Poisson arrivals from
//! a [`RampSpec`], with the service model taken from each front entry's
//! analytical metrics — one launch serves up to `entry.batch` images and
//! occupies the server for `entry.latency_ms`.
//!
//! Drain-and-swap is modeled exactly: a committed switch while a launch is
//! in flight is applied at that launch's completion; queued requests carry
//! over to the new plan and are never dropped. The only way a request is
//! lost is explicit admission-control shedding, which the report accounts
//! separately — so `served + shed == arrivals` is an invariant, asserted
//! by `tests/adaptive_scheduler.rs`.

use std::collections::VecDeque;

use crate::coordinator::scheduler::{
    AdaptiveScheduler, LoadEstimator, RampSpec, SchedulerCfg, SwitchRecord,
};
use crate::plan::front::PlanFront;
use crate::util::stats::Summary;

/// Per-window snapshot of the simulated run.
#[derive(Clone, Copy, Debug)]
pub struct WindowStat {
    pub window: usize,
    pub end_s: f64,
    /// Estimated arrival rate at the window boundary (req/s).
    pub rate_rps: f64,
    pub queue_depth: usize,
    /// p99 completion latency over the estimator horizon (seconds).
    pub p99_s: f64,
    /// Front entry actually serving at the window boundary (lags the
    /// scheduler's choice while a committed switch drains).
    pub active: usize,
}

/// Outcome of a simulated adaptive serving run.
#[derive(Clone, Debug)]
pub struct ServeSimReport {
    pub arrivals: usize,
    pub served: usize,
    pub shed: usize,
    /// Per-request sojourn time (queue wait + service), served requests.
    pub latency: Summary,
    /// Served requests whose sojourn exceeded the SLO.
    pub slo_violations: usize,
    pub switches: Vec<SwitchRecord>,
    pub windows: Vec<WindowStat>,
    pub max_queue_depth: usize,
    /// Completion time of the last served request.
    pub makespan_s: f64,
    pub active_final: usize,
}

impl ServeSimReport {
    pub fn p50_ms(&self) -> f64 {
        self.latency.p50() * 1e3
    }

    pub fn p99_ms(&self) -> f64 {
        self.latency.p99() * 1e3
    }

    pub fn slo_attainment(&self) -> f64 {
        if self.served == 0 {
            return 1.0;
        }
        1.0 - self.slo_violations as f64 / self.served as f64
    }

    pub fn summary_line(&self) -> String {
        let pct = self.latency.percentiles(&[0.50, 0.99]);
        format!(
            "{} arrivals | {} served, {} shed | p50 {:.2} ms p99 {:.2} ms | SLO attainment \
             {:.1}% | {} plan switches | max queue {}",
            self.arrivals,
            self.served,
            self.shed,
            pct[0] * 1e3,
            pct[1] * 1e3,
            self.slo_attainment() * 100.0,
            self.switches.len(),
            self.max_queue_depth
        )
    }
}

/// One in-flight launch: the arrival times it serves and its completion.
struct Launch {
    done_s: f64,
    arrivals: Vec<f64>,
}

/// Simulate serving `ramp` over `front` with the adaptive policy in `cfg`.
/// Fully deterministic for a given seed.
pub fn serve_ramp(
    front: &PlanFront,
    ramp: &RampSpec,
    cfg: &SchedulerCfg,
    seed: u64,
) -> ServeSimReport {
    let arrivals = ramp.arrivals(seed);
    let duration = ramp.duration_s();
    // round(): `duration / window_s` is float (3 * 0.6 / 0.05 = 35.999...),
    // and truncation would silently drop the final decision window.
    let n_windows = (duration / cfg.window_s).round() as usize;

    let mut sched = AdaptiveScheduler::new(front.clone(), *cfg);
    let mut est = LoadEstimator::new(cfg.horizon_s());
    // Plan executing the current launch — lags `sched.active()` while a
    // committed switch drains.
    let mut serving = sched.active();
    let mut pending_switch: Option<usize> = None;

    let mut queue: VecDeque<f64> = VecDeque::new();
    let mut in_flight: Option<Launch> = None;
    let mut latency = Summary::new();
    let mut served = 0usize;
    let mut shed = 0usize;
    let mut max_queue_depth = 0usize;
    let mut makespan_s = 0.0f64;
    let mut windows = Vec::with_capacity(n_windows);

    let slo_s = cfg.slo_ms * 1e-3;
    let mut ai = 0usize; // next arrival index
    let mut w = 0usize; // next window index

    // Start the next launch from the queue on the serving plan at time `t`.
    let start_launch = |t: f64,
                        serving: usize,
                        queue: &mut VecDeque<f64>,
                        in_flight: &mut Option<Launch>,
                        front: &PlanFront| {
        if queue.is_empty() {
            return;
        }
        let e = &front.entries[serving];
        let take = e.batch.min(queue.len());
        let batch: Vec<f64> = queue.drain(..take).collect();
        *in_flight = Some(Launch { done_s: t + e.latency_s(), arrivals: batch });
    };

    loop {
        let t_arr = arrivals.get(ai).copied().unwrap_or(f64::INFINITY);
        let t_done = in_flight.as_ref().map(|l| l.done_s).unwrap_or(f64::INFINITY);
        let t_win = if w < n_windows { (w + 1) as f64 * cfg.window_s } else { f64::INFINITY };
        if t_arr == f64::INFINITY && t_done == f64::INFINITY && t_win == f64::INFINITY {
            break;
        }

        // Deterministic event order on ties: completion, then window tick,
        // then arrival.
        if t_done <= t_win && t_done <= t_arr {
            // -- launch completion (and switch drain point) --------------
            let launch = in_flight.take().unwrap();
            for &a in &launch.arrivals {
                let sojourn = launch.done_s - a;
                latency.push(sojourn);
                est.record_completion(launch.done_s, sojourn);
                served += 1;
            }
            makespan_s = makespan_s.max(launch.done_s);
            if let Some(to) = pending_switch.take() {
                serving = to; // drain complete: swap now
            }
            start_launch(launch.done_s, serving, &mut queue, &mut in_flight, front);
        } else if t_win <= t_arr {
            // -- decision window boundary --------------------------------
            let snapshot = est.estimate(t_win, queue.len());
            if pending_switch.is_none() {
                if let Some(to) = sched.on_window(w, t_win, &snapshot) {
                    if in_flight.is_some() {
                        pending_switch = Some(to); // drain-and-swap
                    } else {
                        serving = to;
                    }
                }
            }
            windows.push(WindowStat {
                window: w,
                end_s: t_win,
                rate_rps: snapshot.rate_rps,
                queue_depth: snapshot.queue_depth,
                p99_s: snapshot.p99_s,
                active: serving,
            });
            w += 1;
        } else {
            // -- arrival -------------------------------------------------
            est.record_arrival(t_arr);
            if sched.admit(queue.len()) {
                queue.push_back(t_arr);
                max_queue_depth = max_queue_depth.max(queue.len());
                if in_flight.is_none() {
                    start_launch(t_arr, serving, &mut queue, &mut in_flight, front);
                }
            } else {
                shed += 1;
            }
            ai += 1;
        }
    }

    let active_final = sched.active();
    let slo_violations = served - latency.count_leq(slo_s);
    ServeSimReport {
        arrivals: arrivals.len(),
        served,
        shed,
        latency,
        slo_violations,
        switches: sched.switches,
        windows,
        max_queue_depth,
        makespan_s,
        active_final,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::front::FrontEntry;

    fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
        FrontEntry {
            assign: vec![0; 8],
            batch,
            latency_ms: lat_ms,
            tops: rps * 2.5e-3,
            rps,
            nacc: 1,
            label: label.to_string(),
        }
    }

    fn front() -> PlanFront {
        PlanFront::new(
            "synthetic",
            12,
            vec![
                entry("seq", 1, 0.2, 5000.0),
                entry("hybrid", 6, 1.0, 6000.0),
                entry("spatial", 24, 2.0, 12000.0),
            ],
        )
        .unwrap()
    }

    fn cfg() -> SchedulerCfg {
        SchedulerCfg { slo_ms: 20.0, ..Default::default() }
    }

    #[test]
    fn conservation_served_plus_shed_equals_arrivals() {
        let ramp = RampSpec::parse("1000:4000:1000", 0.4).unwrap();
        let r = serve_ramp(&front(), &ramp, &cfg(), 7);
        assert_eq!(r.served + r.shed, r.arrivals);
        assert_eq!(r.latency.len(), r.served);
        assert!(r.arrivals > 1000, "load generator produced {}", r.arrivals);
    }

    #[test]
    fn deterministic_given_seed() {
        let ramp = RampSpec::parse("1000:4000", 0.3).unwrap();
        let a = serve_ramp(&front(), &ramp, &cfg(), 11);
        let b = serve_ramp(&front(), &ramp, &cfg(), 11);
        assert_eq!(a.served, b.served);
        assert_eq!(a.shed, b.shed);
        assert_eq!(a.switches, b.switches);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.latency.p99(), b.latency.p99());
    }

    #[test]
    fn idle_ramp_serves_nothing_without_panicking() {
        let ramp = RampSpec::parse("0:0", 0.1).unwrap();
        let r = serve_ramp(&front(), &ramp, &cfg(), 3);
        assert_eq!(r.arrivals, 0);
        assert_eq!(r.served, 0);
        assert_eq!(r.shed, 0);
        assert!(r.switches.is_empty());
        assert_eq!(r.slo_attainment(), 1.0);
    }

    #[test]
    fn low_load_never_switches_off_the_latency_point() {
        let ramp = RampSpec::parse("500:500:500", 0.2).unwrap();
        let r = serve_ramp(&front(), &ramp, &cfg(), 5);
        assert!(r.switches.is_empty(), "switched under trivial load: {:?}", r.switches);
        assert_eq!(r.active_final, 0);
        assert_eq!(r.shed, 0);
        // one launch at a time, batch 1: queue stays tiny
        assert!(r.max_queue_depth < 50);
    }

    #[test]
    fn windows_cover_the_ramp() {
        let c = cfg();
        let ramp = RampSpec::parse("1000:1000", 0.25).unwrap();
        let r = serve_ramp(&front(), &ramp, &c, 9);
        assert_eq!(r.windows.len(), 10); // 0.5 s of ramp / 50 ms windows
        // the float-truncation trap: 3 * 0.6 / 0.05 is 35.999..., and the
        // final decision window must not be lost to it
        let ramp = RampSpec::parse("1000:1000:1000", 0.6).unwrap();
        let r = serve_ramp(&front(), &ramp, &c, 9);
        assert_eq!(r.windows.len(), 36);
        for (i, ws) in r.windows.iter().enumerate() {
            assert_eq!(ws.window, i);
        }
    }
}
