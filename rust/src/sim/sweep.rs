//! Sharded parallel replay: fan a (seeds × traffic-shards) grid of
//! independent single-device sims across the thread pool and merge the
//! results into one roll-up.
//!
//! The grid answers "what does this plan front do under this trace?" with
//! statistical weight a single seeded replay cannot give: `seeds`
//! independent arrival processes, each split into `shards` traffic slices
//! (every shard offers `rate / shards` via [`TraceSpec::shard`], so the
//! *aggregate* offered load per seed equals the original trace while each
//! cell stays a cheap 1-device replay). Any `impl Into<TraceSpec>` works —
//! a bare [`RampSpec`](crate::traffic::RampSpec) ramp, a diurnal or
//! flash-crowd curve, heavy-tail bursts. Cells are embarrassingly
//! parallel — every cell derives its own RNG stream from the base seed via
//! [`Rng::split`], so the grid is bit-deterministic regardless of thread
//! count.
//!
//! **Merge order is fixed**: cells merge in cell-index order
//! (`seed_idx * shards + shard_idx`), never in thread-completion order.
//! [`scope_map`] preserves input order, so `run_sweep` with 1 thread and
//! with 16 threads produce byte-identical reports
//! (`rust/tests/simcore_fastpath.rs` pins this).
//!
//! By default each cell runs the O(1)-memory fast path
//! ([`run_timeline_sketched_recorded`] over a device built
//! [`DeviceSim::without_latency_samples`]): per-request sojourns go into a
//! [`LatencySketch`] (log-spaced bins, γ = [`SKETCH_GAMMA`]) instead of a
//! `Vec`, so replay memory is bounded by the bin count, not the request
//! count. `SweepCfg::exact` switches every cell to the exact
//! [`run_timeline_recorded`] path (full sample vectors, interpolated
//! percentiles) for calibration runs and the fastpath differential tests.
//!
//! [`run_sweep_observed`] additionally collects each cell's
//! [`TraceEvent`] stream (device ids retagged to the cell index) and
//! concatenates them in cell-index order, so the merged trace is as
//! thread-count-independent as the report.
//!
//! [`Rng::split`]: crate::util::rng::Rng::split
//! [`scope_map`]: crate::util::threadpool::scope_map
//! [`SKETCH_GAMMA`]: crate::util::stats::SKETCH_GAMMA

use crate::coordinator::scheduler::SchedulerCfg;
use crate::obs::{NoopRecorder, Recorder, TraceEvent, TraceRecorder};
use crate::plan::front::PlanFront;
use crate::sim::device::{
    run_timeline_recorded, run_timeline_sketched_recorded, DeviceSim, NoControl,
};
use crate::sim::service::{ServiceModel, SERVICE_STREAM};
use crate::traffic::{ArrivalStream, TraceSpec};
use crate::util::rng::Rng;
use crate::util::stats::{LatencySketch, Summary};
use crate::util::threadpool::{default_threads, scope_map};

/// Grid shape and execution mode for [`run_sweep`].
#[derive(Clone, Copy, Debug)]
pub struct SweepCfg {
    /// Independent arrival-process replications (outer grid axis).
    pub seeds: usize,
    /// Traffic slices per seed; each shard offers `rate / shards`.
    pub shards: usize,
    /// Worker threads (`0` = [`default_threads`]).
    pub threads: usize,
    /// Run the exact full-sample path instead of the sketched fast path.
    pub exact: bool,
}

impl Default for SweepCfg {
    fn default() -> Self {
        SweepCfg { seeds: 4, shards: 8, threads: 0, exact: false }
    }
}

/// Per-cell tallies, reported in cell-index order.
#[derive(Clone, Debug)]
pub struct SweepCell {
    pub seed_idx: usize,
    pub shard_idx: usize,
    /// The cell's derived RNG seed (`base.split(cell_index)`).
    pub seed: u64,
    pub arrivals: usize,
    pub served: usize,
    pub shed: usize,
    pub makespan_s: f64,
    /// Discrete events the cell's replay processed.
    pub events: u64,
}

/// Merged outcome of a sharded sweep.
#[derive(Clone, Debug)]
pub struct SweepReport {
    /// Per-cell tallies in cell-index order (the merge order).
    pub cells: Vec<SweepCell>,
    pub arrivals: usize,
    pub served: usize,
    pub shed: usize,
    pub unroutable: usize,
    /// Total discrete events across all cells (the bench's events/sec
    /// numerator).
    pub events: u64,
    /// Max cell makespan (cells replay the same wall-clock span).
    pub makespan_s: f64,
    /// Decision windows per cell (identical across cells by construction).
    pub n_windows: usize,
    /// Bounded-error latency roll-up, always populated (in exact mode it
    /// is rebuilt from the exact samples, so the two stay comparable).
    pub latency: LatencySketch,
    /// Full per-request sojourns, only in [`SweepCfg::exact`] mode.
    pub exact_latency: Option<Summary>,
    /// Served requests whose sojourn exceeded the SLO. Exact in exact
    /// mode; bin-granular (error bounded by the sketch γ) otherwise.
    pub slo_violations: usize,
}

impl SweepReport {
    pub fn slo_attainment(&self) -> f64 {
        if self.served == 0 {
            return 1.0;
        }
        1.0 - self.slo_violations as f64 / self.served as f64
    }

    pub fn summary_line(&self) -> String {
        let (p50, p99) = match &self.exact_latency {
            Some(s) => {
                let pct = s.percentiles(&[0.50, 0.99]);
                (pct[0], pct[1])
            }
            None => (self.latency.p50(), self.latency.p99()),
        };
        format!(
            "{} cells | {} arrivals | {} served, {} shed | p50 {:.2} ms p99 {:.2} ms ({}) | \
             SLO attainment {:.1}% | {} events",
            self.cells.len(),
            self.arrivals,
            self.served,
            self.shed,
            p50 * 1e3,
            p99 * 1e3,
            if self.exact_latency.is_some() { "exact" } else { "sketch" },
            self.slo_attainment() * 100.0,
            self.events,
        )
    }
}

/// Outcome of one grid cell, merged in cell-index order by [`run_sweep`].
struct CellOutcome {
    cell: SweepCell,
    unroutable: usize,
    n_windows: usize,
    sketch: LatencySketch,
    exact: Option<Summary>,
}

/// Replay the `(seeds × shards)` grid of single-device sims over `front`
/// and merge in cell-index order. Bit-deterministic for a given
/// `base_seed` and grid shape, independent of `sweep.threads`.
pub fn run_sweep(
    front: &PlanFront,
    traffic: impl Into<TraceSpec>,
    cfg: &SchedulerCfg,
    sweep: &SweepCfg,
    base_seed: u64,
) -> SweepReport {
    run_sweep_inner(front, traffic.into(), cfg, sweep, base_seed, false).0
}

/// [`run_sweep`] that also returns the concatenated [`TraceEvent`]
/// stream: each cell records its own replay (single device, so every
/// event carries `dev == 0`), then its events are retagged to the cell
/// index and spliced in cell-index order — the trace, like the report,
/// is byte-identical regardless of `sweep.threads`. The report itself is
/// bit-identical to the unobserved [`run_sweep`] at equal inputs.
pub fn run_sweep_observed(
    front: &PlanFront,
    traffic: impl Into<TraceSpec>,
    cfg: &SchedulerCfg,
    sweep: &SweepCfg,
    base_seed: u64,
) -> (SweepReport, Vec<TraceEvent>) {
    run_sweep_inner(front, traffic.into(), cfg, sweep, base_seed, true)
}

fn run_sweep_inner(
    front: &PlanFront,
    traffic: TraceSpec,
    cfg: &SchedulerCfg,
    sweep: &SweepCfg,
    base_seed: u64,
    record: bool,
) -> (SweepReport, Vec<TraceEvent>) {
    assert!(sweep.seeds >= 1, "sweep needs at least one seed");
    assert!(sweep.shards >= 1, "sweep needs at least one shard");
    // Each shard carries an equal slice of the offered load, so one seed
    // row in aggregate offers the original trace. `TraceSpec::shard`
    // divides every rate by the shard count exactly as the historical
    // per-rate `r / shards` did, so ramp sweeps stay bit-identical.
    let shard_trace = traffic.shard(sweep.shards);
    let base = Rng::new(base_seed);
    let n_cells = sweep.seeds * sweep.shards;
    // Cell seeds derive by keyed split, not by advancing a shared stream:
    // cell i's arrivals are a pure function of (base_seed, i), so a wider
    // grid never perturbs existing cells.
    let cells: Vec<(usize, u64)> =
        (0..n_cells).map(|i| (i, base.split(i as u64).next_u64())).collect();
    let threads = if sweep.threads == 0 { default_threads() } else { sweep.threads };
    let slo_s = cfg.slo_ms * 1e-3;

    let outcomes = scope_map(&cells, threads, |&(idx, seed)| {
        let (seed_idx, shard_idx) = (idx / sweep.shards, idx % sweep.shards);
        if record {
            let mut rec = TraceRecorder::new();
            let out =
                run_cell(front, &shard_trace, cfg, sweep, seed_idx, shard_idx, seed, &mut rec);
            // Single-device cells record dev 0; retag to the cell index
            // so the merged trace keeps one track per cell.
            let mut evs = rec.into_events();
            for ev in &mut evs {
                ev.set_dev(idx);
            }
            (out, evs)
        } else {
            let mut rec = NoopRecorder;
            let out =
                run_cell(front, &shard_trace, cfg, sweep, seed_idx, shard_idx, seed, &mut rec);
            (out, Vec::new())
        }
    });

    // Merge strictly in cell-index order (scope_map preserves input
    // order), never thread-completion order — thread count must not be
    // observable in the report.
    let mut report = SweepReport {
        cells: Vec::with_capacity(n_cells),
        arrivals: 0,
        served: 0,
        shed: 0,
        unroutable: 0,
        events: 0,
        makespan_s: 0.0,
        n_windows: 0,
        latency: LatencySketch::new(),
        exact_latency: sweep.exact.then(Summary::new),
        slo_violations: 0,
    };
    let mut events: Vec<TraceEvent> = Vec::new();
    for (out, evs) in outcomes {
        report.arrivals += out.cell.arrivals;
        report.served += out.cell.served;
        report.shed += out.cell.shed;
        report.unroutable += out.unroutable;
        report.events += out.cell.events;
        report.makespan_s = report.makespan_s.max(out.cell.makespan_s);
        report.n_windows = out.n_windows;
        report.latency.merge(&out.sketch);
        if let (Some(total), Some(cell)) = (report.exact_latency.as_mut(), out.exact.as_ref()) {
            total.extend_from(cell);
        }
        report.cells.push(out.cell);
        events.extend(evs);
    }
    report.slo_violations = match &report.exact_latency {
        Some(s) => report.served - s.count_leq(slo_s),
        None => report.served - report.latency.count_leq(slo_s) as usize,
    };
    (report, events)
}

/// One grid cell: a single-device replay of the shard's traffic slice.
#[allow(clippy::too_many_arguments)]
fn run_cell(
    front: &PlanFront,
    shard_trace: &TraceSpec,
    cfg: &SchedulerCfg,
    sweep: &SweepCfg,
    seed_idx: usize,
    shard_idx: usize,
    seed: u64,
    rec: &mut impl Recorder,
) -> CellOutcome {
    // Single device: every arrival routes to it, so the trace's class
    // models never matter here — only the curves, burst processes, and
    // (class 0's) service-time distribution. The service stream splits
    // off the cell's own seed, so noisy cells stay independent and the
    // arrival draws are untouched.
    let mut stream = ArrivalStream::from_trace(shard_trace, seed);
    let duration_s = shard_trace.duration_s();
    let service = shard_trace
        .classes
        .first()
        .map(|c| c.service.clone())
        .unwrap_or(ServiceModel::Deterministic);
    let service_rng = Rng::new(seed).split(SERVICE_STREAM).split(0);
    if sweep.exact {
        let mut devs =
            vec![DeviceSim::new(front.clone(), *cfg).with_service(service, service_rng)];
        let outcome = run_timeline_recorded(
            &mut devs,
            &mut stream,
            duration_s,
            cfg.window_s,
            |_, _, _| Some(0),
            &mut NoControl,
            rec,
        );
        let dev = devs.pop().expect("one device").into_report();
        // Rebuild the sketch from the exact samples so exact and default
        // sweeps expose the same roll-up shape.
        let mut sketch = LatencySketch::new();
        for &s in outcome.latency.samples() {
            sketch.record(s);
        }
        CellOutcome {
            cell: SweepCell {
                seed_idx,
                shard_idx,
                seed,
                arrivals: outcome.arrivals,
                served: dev.served,
                shed: dev.shed,
                makespan_s: outcome.makespan_s,
                events: outcome.events,
            },
            unroutable: outcome.unroutable,
            n_windows: outcome.n_windows,
            sketch,
            exact: Some(outcome.latency),
        }
    } else {
        // Fast path: no per-request Vec anywhere — the device drops its
        // sample log and the sink is the fixed-size sketch.
        let mut devs = vec![DeviceSim::new(front.clone(), *cfg)
            .without_latency_samples()
            .with_service(service, service_rng)];
        let outcome = run_timeline_sketched_recorded(
            &mut devs,
            &mut stream,
            duration_s,
            cfg.window_s,
            |_, _, _| Some(0),
            &mut NoControl,
            rec,
        );
        let dev = devs.pop().expect("one device").into_report();
        CellOutcome {
            cell: SweepCell {
                seed_idx,
                shard_idx,
                seed,
                arrivals: outcome.arrivals,
                served: dev.served,
                shed: dev.shed,
                makespan_s: outcome.makespan_s,
                events: outcome.events,
            },
            unroutable: outcome.unroutable,
            n_windows: outcome.n_windows,
            sketch: outcome.latency,
            exact: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::front::FrontEntry;
    use crate::traffic::RampSpec;

    fn entry(label: &str, batch: usize, lat_ms: f64, rps: f64) -> FrontEntry {
        FrontEntry {
            assign: vec![0; 8],
            batch,
            latency_ms: lat_ms,
            tops: rps * 2.5e-3,
            rps,
            nacc: 1,
            label: label.to_string(),
        }
    }

    fn front() -> PlanFront {
        PlanFront::new(
            "synthetic",
            12,
            vec![
                entry("seq", 1, 0.2, 5000.0),
                entry("hybrid", 6, 1.0, 6000.0),
                entry("spatial", 24, 2.0, 12000.0),
            ],
        )
        .unwrap()
    }

    fn cfg() -> SchedulerCfg {
        SchedulerCfg { slo_ms: 20.0, ..Default::default() }
    }

    #[test]
    fn sweep_conserves_requests_per_cell_and_in_total() {
        let ramp = RampSpec::parse("2000:6000:2000", 0.3).unwrap();
        let sweep = SweepCfg { seeds: 2, shards: 3, threads: 2, exact: false };
        let r = run_sweep(&front(), &ramp, &cfg(), &sweep, 42);
        assert_eq!(r.cells.len(), 6);
        assert_eq!(r.served + r.shed, r.arrivals);
        for c in &r.cells {
            assert_eq!(c.served + c.shed, c.arrivals, "cell {}/{}", c.seed_idx, c.shard_idx);
        }
        assert_eq!(r.latency.count(), r.served as u64);
        assert!(r.events >= r.arrivals as u64, "events must count every arrival");
    }

    #[test]
    fn cells_enumerate_the_grid_in_merge_order() {
        let ramp = RampSpec::parse("1000", 0.2).unwrap();
        let sweep = SweepCfg { seeds: 3, shards: 2, threads: 1, exact: false };
        let r = run_sweep(&front(), &ramp, &cfg(), &sweep, 7);
        let coords: Vec<(usize, usize)> =
            r.cells.iter().map(|c| (c.seed_idx, c.shard_idx)).collect();
        assert_eq!(coords, vec![(0, 0), (0, 1), (1, 0), (1, 1), (2, 0), (2, 1)]);
        // Keyed derivation: cell seeds are distinct and reproducible.
        let base = Rng::new(7);
        for (i, c) in r.cells.iter().enumerate() {
            assert_eq!(c.seed, base.split(i as u64).next_u64());
        }
    }

    #[test]
    fn exact_mode_populates_both_rollups_consistently() {
        let ramp = RampSpec::parse("3000:3000", 0.25).unwrap();
        let sweep = SweepCfg { seeds: 2, shards: 2, threads: 1, exact: true };
        let r = run_sweep(&front(), &ramp, &cfg(), &sweep, 11);
        let exact = r.exact_latency.as_ref().expect("exact mode keeps samples");
        assert_eq!(exact.len(), r.served);
        assert_eq!(r.latency.count(), r.served as u64);
        // The rebuilt sketch quantile must bracket the exact percentile
        // within the sketch's relative-error bound.
        let p99 = exact.percentile(0.99);
        let sk99 = r.latency.quantile(0.99);
        assert!(
            sk99 / p99 < crate::util::stats::SKETCH_GAMMA * 1.001
                && p99 / sk99 < crate::util::stats::SKETCH_GAMMA * 1.001,
            "sketch p99 {sk99} vs exact {p99}"
        );
    }

    #[test]
    fn observed_sweep_trace_is_thread_count_invariant() {
        let ramp = RampSpec::parse("2000:6000", 0.3).unwrap();
        let one = SweepCfg { seeds: 2, shards: 2, threads: 1, exact: false };
        let four = SweepCfg { seeds: 2, shards: 2, threads: 4, exact: false };
        let (r1, t1) = run_sweep_observed(&front(), &ramp, &cfg(), &one, 42);
        let (r4, t4) = run_sweep_observed(&front(), &ramp, &cfg(), &four, 42);
        // Cells merge in cell-index order, so neither the report nor the
        // trace may depend on the worker-thread count.
        assert_eq!(t1, t4);
        assert_eq!(r1.served, r4.served);
        // Observing must not perturb the replay itself.
        let r = run_sweep(&front(), &ramp, &cfg(), &one, 42);
        assert_eq!(r.arrivals, r1.arrivals);
        assert_eq!(r.served, r1.served);
        assert_eq!(r.shed, r1.shed);
        assert_eq!(r.events, r1.events);
        // Retagging gives every cell its own device track.
        let devs: std::collections::BTreeSet<usize> = t1.iter().filter_map(|e| e.dev()).collect();
        assert_eq!(devs, (0..4).collect::<std::collections::BTreeSet<usize>>());
    }

    #[test]
    fn a_sharded_row_offers_the_full_ramp_in_aggregate() {
        let ramp = RampSpec::parse("4000:4000", 0.5).unwrap();
        let one = SweepCfg { seeds: 1, shards: 1, threads: 1, exact: false };
        let eight = SweepCfg { seeds: 1, shards: 8, threads: 1, exact: false };
        let r1 = run_sweep(&front(), &ramp, &cfg(), &one, 3);
        let r8 = run_sweep(&front(), &ramp, &cfg(), &eight, 3);
        // Different draws, same offered load: totals agree statistically.
        let (a, b) = (r1.arrivals as f64, r8.arrivals as f64);
        assert!((a - b).abs() / a < 0.15, "1-shard {a} vs 8-shard {b}");
    }
}
