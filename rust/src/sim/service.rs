//! Input-dynamic service times: per-plan service-time *distributions*.
//!
//! Every launch in the sim used to complete in exactly
//! `entry.latency_s()` — but the workloads the paper targets are
//! input-dynamic: HeatViT prunes tokens per input, and DynaTran-style
//! activation sparsity (AccelTran) makes transformer latency a per-input
//! distribution, not a constant. A [`ServiceModel`] makes that
//! first-class: it is a serializable distribution over a *multiplicative
//! service-time factor*, sampled once per launch, so a launch under plan
//! entry `e` completes at `t + e.latency_s() * factor`.
//!
//! ## Sampling stream discipline
//!
//! Service draws consume their own non-advancing [`Rng::split`] stream,
//! [`SERVICE_STREAM`], split again per device index. Arrivals (per-class
//! streams), routing (`ROUTER_STREAM`), and fault injection
//! (`FAULT_STREAM`) never see a service draw: turning noise on or off
//! cannot perturb any other random sequence in the run.
//!
//! ## The `Deterministic` bit-identity guarantee
//!
//! [`ServiceModel::Deterministic`] does not *sample at all* — the device
//! keeps computing `t + e.latency_s()` through the exact same expression
//! as before this module existed, and the service RNG is never advanced.
//! Bit-identity with the pre-noise sims holds by construction, not by
//! `factor == 1.0` luck; `tests/service_noise.rs` pins it differentially.

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Dedicated non-advancing split stream for service-time draws (distinct
/// from the router stream `u64::MAX`, the live per-device streams
/// `u64::MAX - 1 - dev`, and the controller's fault stream `u64::MAX / 2`).
pub const SERVICE_STREAM: u64 = u64::MAX / 2 - 1;

/// A per-class (hence per-plan-front) service-time distribution. Sampled
/// once per launch into a multiplicative factor on the committed entry's
/// `latency_s()`.
#[derive(Clone, Debug, PartialEq)]
pub enum ServiceModel {
    /// Every launch takes exactly `entry.latency_s()` — the pre-noise
    /// behavior, bit-identical by construction (no RNG draw happens).
    Deterministic,
    /// Token pruning (HeatViT-style): the kept-token ratio follows a
    /// Kumaraswamy(α, β) distribution on (0, 1]; the factor is the kept
    /// ratio, floored at 0.05 (a launch never becomes free). Mean < 1:
    /// pruning only ever speeds a launch up.
    TokenPruning { alpha: f64, beta: f64 },
    /// Early exit: with probability `exit_probs[k]` the input exits after
    /// stage `k`, costing `stage_fractions[k]` of the full latency;
    /// otherwise (probability `1 - Σ exit_probs`) it runs to completion
    /// (factor 1.0). One uniform draw per launch.
    EarlyExit { exit_probs: Vec<f64>, stage_fractions: Vec<f64> },
    /// Activation-sparsity-style heavy tail: factor `exp(σZ − σ²/2)` for
    /// standard-normal `Z` — lognormal with mean exactly 1, so the
    /// entry's advertised rate stays the *mean* rate while the tail
    /// stretches with σ. Two uniform draws per launch (Box–Muller).
    LognormalFactor { sigma: f64 },
}

impl ServiceModel {
    /// True when sampling never draws from the RNG and the factor is
    /// identically 1 — the bit-identity fast path.
    pub fn is_deterministic(&self) -> bool {
        matches!(self, ServiceModel::Deterministic)
    }

    /// Draw one service-time factor. `Deterministic` returns 1.0 without
    /// touching `rng` (callers on the hot path skip even that — see
    /// `sim::device::DeviceSim::start_launch`).
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            ServiceModel::Deterministic => 1.0,
            ServiceModel::TokenPruning { alpha, beta } => {
                // Kumaraswamy inverse CDF: r = (1 − (1 − u)^(1/β))^(1/α).
                let u = rng.f64();
                let r = (1.0 - (1.0 - u).powf(1.0 / beta)).powf(1.0 / alpha);
                r.max(0.05)
            }
            ServiceModel::EarlyExit { exit_probs, stage_fractions } => {
                let u = rng.f64();
                let mut cum = 0.0;
                for (p, f) in exit_probs.iter().zip(stage_fractions) {
                    cum += p;
                    if u < cum {
                        return *f;
                    }
                }
                1.0
            }
            ServiceModel::LognormalFactor { sigma } => {
                // Box–Muller, same idiom as ArrivalProcess::mean1_gap:
                // 1 - u1 keeps the log argument in (0, 1].
                let u1 = rng.f64();
                let u2 = rng.f64();
                let z =
                    (-2.0 * (1.0 - u1).ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
                (sigma * z - sigma * sigma / 2.0).exp()
            }
        }
    }

    /// Quantile `q` of the factor distribution (analytic; no sampling).
    /// The p99-aware scheduler and the slack-aware batcher budget against
    /// `tail_q(0.99)` instead of the mean.
    pub fn tail_q(&self, q: f64) -> f64 {
        let q = q.clamp(1e-9, 1.0 - 1e-9);
        match self {
            ServiceModel::Deterministic => 1.0,
            ServiceModel::TokenPruning { alpha, beta } => {
                // Monotone transform of the uniform: quantile = sample(q).
                let r = (1.0 - (1.0 - q).powf(1.0 / beta)).powf(1.0 / alpha);
                r.max(0.05)
            }
            ServiceModel::EarlyExit { exit_probs, stage_fractions } => {
                // Discrete: smallest factor x with P(factor <= x) >= q.
                let mut pairs: Vec<(f64, f64)> = exit_probs
                    .iter()
                    .zip(stage_fractions)
                    .map(|(p, f)| (*f, *p))
                    .collect();
                let run_full: f64 = 1.0 - exit_probs.iter().sum::<f64>();
                pairs.push((1.0, run_full.max(0.0)));
                pairs.sort_by(|a, b| a.0.total_cmp(&b.0));
                let mut cum = 0.0;
                for (f, p) in &pairs {
                    cum += p;
                    if cum >= q {
                        return *f;
                    }
                }
                1.0
            }
            ServiceModel::LognormalFactor { sigma } => {
                (sigma * inv_norm_cdf(q) - sigma * sigma / 2.0).exp()
            }
        }
    }

    /// Domain check, mirrored by the `S5xx` static `ssr check` passes —
    /// `TraceSpec::validate` calls this per class.
    pub fn validate(&self) -> Result<(), String> {
        match self {
            ServiceModel::Deterministic => Ok(()),
            ServiceModel::TokenPruning { alpha, beta } => {
                for (name, v) in [("alpha", *alpha), ("beta", *beta)] {
                    if !v.is_finite() || v <= 0.0 {
                        return Err(format!("token-pruning {name} must be finite and > 0, got {v}"));
                    }
                }
                Ok(())
            }
            ServiceModel::EarlyExit { exit_probs, stage_fractions } => {
                if exit_probs.len() != stage_fractions.len() {
                    return Err(format!(
                        "early-exit has {} exit_probs but {} stage_fractions",
                        exit_probs.len(),
                        stage_fractions.len()
                    ));
                }
                if exit_probs.is_empty() {
                    return Err("early-exit needs at least one stage".to_string());
                }
                for p in exit_probs {
                    if !p.is_finite() || !(0.0..=1.0).contains(p) {
                        return Err(format!("early-exit probability {p} outside [0, 1]"));
                    }
                }
                let sum: f64 = exit_probs.iter().sum();
                if sum > 1.0 {
                    return Err(format!("early-exit probabilities sum to {sum} > 1"));
                }
                for f in stage_fractions {
                    if !f.is_finite() || *f <= 0.0 || *f > 1.0 {
                        return Err(format!("early-exit stage fraction {f} outside (0, 1]"));
                    }
                }
                Ok(())
            }
            ServiceModel::LognormalFactor { sigma } => {
                if !sigma.is_finite() || *sigma <= 0.0 || *sigma > 4.0 {
                    return Err(format!(
                        "lognormal sigma must be finite, > 0 and <= 4, got {sigma}"
                    ));
                }
                Ok(())
            }
        }
    }

    /// CLI shorthand: `det` | `lognormal:SIGMA` | `prune:ALPHA:BETA` |
    /// `exit:P@F,P@F,...` (probability@fraction pairs).
    pub fn parse(s: &str) -> Result<ServiceModel, String> {
        let s = s.trim();
        let model = if s.is_empty() || s == "det" || s == "deterministic" {
            ServiceModel::Deterministic
        } else if let Some(rest) = s.strip_prefix("lognormal:") {
            let sigma: f64 =
                rest.parse().map_err(|e| format!("bad lognormal sigma '{rest}': {e}"))?;
            ServiceModel::LognormalFactor { sigma }
        } else if let Some(rest) = s.strip_prefix("prune:") {
            let (a, b) = rest
                .split_once(':')
                .ok_or_else(|| format!("bad prune spec '{rest}' (want prune:ALPHA:BETA)"))?;
            let alpha: f64 = a.parse().map_err(|e| format!("bad prune alpha '{a}': {e}"))?;
            let beta: f64 = b.parse().map_err(|e| format!("bad prune beta '{b}': {e}"))?;
            ServiceModel::TokenPruning { alpha, beta }
        } else if let Some(rest) = s.strip_prefix("exit:") {
            let mut exit_probs = Vec::new();
            let mut stage_fractions = Vec::new();
            for pair in rest.split(',').map(str::trim).filter(|p| !p.is_empty()) {
                let (p, f) = pair
                    .split_once('@')
                    .ok_or_else(|| format!("bad exit stage '{pair}' (want PROB@FRACTION)"))?;
                exit_probs
                    .push(p.parse().map_err(|e| format!("bad exit probability '{p}': {e}"))?);
                stage_fractions
                    .push(f.parse().map_err(|e| format!("bad stage fraction '{f}': {e}"))?);
            }
            ServiceModel::EarlyExit { exit_probs, stage_fractions }
        } else {
            return Err(format!(
                "unknown service model '{s}' (want det | lognormal:SIGMA | prune:ALPHA:BETA \
                 | exit:P@F,...)"
            ));
        };
        model.validate()?;
        Ok(model)
    }

    /// Short human label for `describe()` lines.
    pub fn label(&self) -> String {
        match self {
            ServiceModel::Deterministic => "deterministic".to_string(),
            ServiceModel::TokenPruning { alpha, beta } => format!("prune(α={alpha}, β={beta})"),
            ServiceModel::EarlyExit { exit_probs, .. } => {
                format!("early-exit({} stages)", exit_probs.len())
            }
            ServiceModel::LognormalFactor { sigma } => format!("lognormal(σ={sigma})"),
        }
    }

    /// Serialize as a kind-tagged JSON object. `TraceSpec::to_json` omits
    /// the `service` key entirely for `Deterministic`, keeping pre-noise
    /// trace artifacts byte-identical.
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        match self {
            ServiceModel::Deterministic => {
                o.insert("kind".to_string(), Json::Str("deterministic".to_string()));
            }
            ServiceModel::TokenPruning { alpha, beta } => {
                o.insert("kind".to_string(), Json::Str("token-pruning".to_string()));
                o.insert("alpha".to_string(), Json::Num(*alpha));
                o.insert("beta".to_string(), Json::Num(*beta));
            }
            ServiceModel::EarlyExit { exit_probs, stage_fractions } => {
                o.insert("kind".to_string(), Json::Str("early-exit".to_string()));
                o.insert(
                    "exit_probs".to_string(),
                    Json::Arr(exit_probs.iter().map(|p| Json::Num(*p)).collect()),
                );
                o.insert(
                    "stage_fractions".to_string(),
                    Json::Arr(stage_fractions.iter().map(|f| Json::Num(*f)).collect()),
                );
            }
            ServiceModel::LognormalFactor { sigma } => {
                o.insert("kind".to_string(), Json::Str("lognormal".to_string()));
                o.insert("sigma".to_string(), Json::Num(*sigma));
            }
        }
        Json::Obj(o)
    }

    /// Deserialize a kind-tagged object (the shape `to_json` writes). An
    /// absent `service` key in a trace class means `Deterministic` — old
    /// artifacts load unchanged.
    pub fn from_json(j: &Json) -> Result<ServiceModel, String> {
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| "service model needs a string `kind`".to_string())?;
        let num = |key: &str| -> Result<f64, String> {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("service model `{kind}` needs numeric `{key}`"))
        };
        let model = match kind {
            "deterministic" => ServiceModel::Deterministic,
            "token-pruning" => {
                ServiceModel::TokenPruning { alpha: num("alpha")?, beta: num("beta")? }
            }
            "lognormal" => ServiceModel::LognormalFactor { sigma: num("sigma")? },
            "early-exit" => {
                let arr = |key: &str| -> Result<Vec<f64>, String> {
                    j.get(key)
                        .and_then(Json::as_arr)
                        .ok_or_else(|| format!("service model `early-exit` needs array `{key}`"))?
                        .iter()
                        .map(|v| {
                            v.as_f64().ok_or_else(|| format!("non-numeric entry in `{key}`"))
                        })
                        .collect()
                };
                ServiceModel::EarlyExit {
                    exit_probs: arr("exit_probs")?,
                    stage_fractions: arr("stage_fractions")?,
                }
            }
            other => return Err(format!("unknown service model kind '{other}'")),
        };
        model.validate()?;
        Ok(model)
    }
}

/// Inverse standard-normal CDF (Acklam's rational approximation,
/// relative error < 1.2e-9 over (0, 1)) — enough precision that the
/// scheduler's tail inflation is stable to far more digits than any
/// latency estimate feeding it.
fn inv_norm_cdf(p: f64) -> f64 {
    const A: [f64; 6] = [
        -3.969_683_028_665_376e1,
        2.209_460_984_245_205e2,
        -2.759_285_104_469_687e2,
        1.383_577_518_672_69e2,
        -3.066_479_806_614_716e1,
        2.506_628_277_459_239,
    ];
    const B: [f64; 5] = [
        -5.447_609_879_822_406e1,
        1.615_858_368_580_409e2,
        -1.556_989_798_598_866e2,
        6.680_131_188_771_972e1,
        -1.328_068_155_288_572e1,
    ];
    const C: [f64; 6] = [
        -7.784_894_002_430_293e-3,
        -3.223_964_580_411_365e-1,
        -2.400_758_277_161_838,
        -2.549_732_539_343_734,
        4.374_664_141_464_968,
        2.938_163_982_698_783,
    ];
    const D: [f64; 4] = [
        7.784_695_709_041_462e-3,
        3.224_671_290_700_398e-1,
        2.445_134_137_142_996,
        3.754_408_661_907_416,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -((((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_never_touches_the_rng() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let f = ServiceModel::Deterministic.sample(&mut a);
        assert_eq!(f, 1.0);
        assert_eq!(a.next_u64(), b.next_u64(), "sample() advanced the RNG");
    }

    #[test]
    fn lognormal_factor_has_mean_one_and_a_heavy_tail() {
        let m = ServiceModel::LognormalFactor { sigma: 1.0 };
        let mut rng = Rng::new(7);
        let n = 200_000;
        let mut sum = 0.0;
        let mut over2 = 0usize;
        for _ in 0..n {
            let f = m.sample(&mut rng);
            assert!(f > 0.0);
            sum += f;
            if f > 2.0 {
                over2 += 1;
            }
        }
        let mean = sum / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "lognormal mean {mean} != 1");
        assert!(over2 > n / 100, "tail too light: {over2} / {n} samples above 2x");
    }

    #[test]
    fn token_pruning_only_speeds_up() {
        let m = ServiceModel::TokenPruning { alpha: 2.0, beta: 3.0 };
        let mut rng = Rng::new(9);
        for _ in 0..10_000 {
            let f = m.sample(&mut rng);
            assert!((0.05..=1.0).contains(&f), "pruning factor {f} outside (0, 1]");
        }
    }

    #[test]
    fn early_exit_hits_each_stage_with_about_its_probability() {
        let m = ServiceModel::EarlyExit {
            exit_probs: vec![0.3, 0.2],
            stage_fractions: vec![0.25, 0.5],
        };
        let mut rng = Rng::new(11);
        let n = 100_000;
        let (mut s0, mut s1, mut full) = (0usize, 0usize, 0usize);
        for _ in 0..n {
            match m.sample(&mut rng) {
                f if f == 0.25 => s0 += 1,
                f if f == 0.5 => s1 += 1,
                f => {
                    assert_eq!(f, 1.0);
                    full += 1;
                }
            }
        }
        let frac = |c: usize| c as f64 / n as f64;
        assert!((frac(s0) - 0.3).abs() < 0.01);
        assert!((frac(s1) - 0.2).abs() < 0.01);
        assert!((frac(full) - 0.5).abs() < 0.01);
    }

    #[test]
    fn tail_q_matches_the_empirical_quantile() {
        let models = [
            ServiceModel::LognormalFactor { sigma: 0.8 },
            ServiceModel::TokenPruning { alpha: 2.0, beta: 2.0 },
            ServiceModel::EarlyExit {
                exit_probs: vec![0.4, 0.3],
                stage_fractions: vec![0.2, 0.6],
            },
        ];
        for m in &models {
            let mut rng = Rng::new(0xACE);
            let mut xs: Vec<f64> = (0..100_000).map(|_| m.sample(&mut rng)).collect();
            xs.sort_by(|a, b| a.total_cmp(b));
            for q in [0.5, 0.9, 0.99] {
                let emp = xs[((xs.len() - 1) as f64 * q) as usize];
                let ana = m.tail_q(q);
                assert!(
                    (emp - ana).abs() / ana.max(1e-9) < 0.05,
                    "{m:?} q={q}: empirical {emp} vs analytic {ana}"
                );
            }
        }
    }

    #[test]
    fn tail_q_is_monotone_and_deterministic_is_flat() {
        let m = ServiceModel::LognormalFactor { sigma: 1.5 };
        assert!(m.tail_q(0.5) < m.tail_q(0.9));
        assert!(m.tail_q(0.9) < m.tail_q(0.99));
        assert_eq!(ServiceModel::Deterministic.tail_q(0.99), 1.0);
        // σZ − σ²/2 at the median is below 0: the heavy tail pulls the
        // mean above the median, so tail_q(0.5) < 1 while mean == 1.
        assert!(m.tail_q(0.5) < 1.0);
    }

    #[test]
    fn inv_norm_cdf_hits_known_points() {
        assert!(inv_norm_cdf(0.5).abs() < 1e-9);
        assert!((inv_norm_cdf(0.975) - 1.959_963_984_540_054).abs() < 1e-6);
        assert!((inv_norm_cdf(0.99) - 2.326_347_874_040_841).abs() < 1e-6);
        assert!((inv_norm_cdf(0.01) + 2.326_347_874_040_841).abs() < 1e-6);
    }

    #[test]
    fn parse_round_trips_through_json() {
        for s in ["det", "lognormal:0.8", "prune:2:3", "exit:0.3@0.25,0.2@0.5"] {
            let m = ServiceModel::parse(s).unwrap();
            let j = m.to_json();
            let back = ServiceModel::from_json(&j).unwrap();
            assert_eq!(m, back, "{s} round trip");
            // and the JSON text itself round-trips
            let reparsed = Json::parse(&j.to_string()).unwrap();
            assert_eq!(ServiceModel::from_json(&reparsed).unwrap(), m);
        }
    }

    #[test]
    fn parse_rejects_bad_specs() {
        for s in [
            "lognormal:-1",
            "lognormal:nan",
            "lognormal:9",
            "prune:0:1",
            "prune:1",
            "exit:1.5@0.5",
            "exit:0.6@0.5,0.6@0.7",
            "exit:0.5@0.0",
            "exit:0.5@2.0",
            "gamma:1",
        ] {
            assert!(ServiceModel::parse(s).is_err(), "'{s}' must be rejected");
        }
    }

    #[test]
    fn from_json_rejects_nan_and_bad_domains() {
        let bad = [
            r#"{"kind":"lognormal"}"#,
            r#"{"kind":"lognormal","sigma":-0.5}"#,
            r#"{"kind":"token-pruning","alpha":0,"beta":1}"#,
            r#"{"kind":"early-exit","exit_probs":[0.5],"stage_fractions":[0.5,0.6]}"#,
            r#"{"kind":"early-exit","exit_probs":[],"stage_fractions":[]}"#,
            r#"{"kind":"mystery"}"#,
            r#"{"sigma":1.0}"#,
        ];
        for s in bad {
            let j = Json::parse(s).unwrap();
            assert!(ServiceModel::from_json(&j).is_err(), "{s} must be rejected");
        }
    }
}
