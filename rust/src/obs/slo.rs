//! SLO error-budget monitor: windowed attainment and multi-window burn
//! rates over the trace-event stream.
//!
//! Replayed post-hoc over a collected (and audit-merged) stream rather
//! than inline in the controller: the steady-load invariants pinned in
//! `rust/tests/fleet_autoscale.rs` guarantee the controller's audit log
//! stays empty on feasible load, so alerts live in the *observability*
//! stream — [`annotate_slo`] inserts a [`TraceEvent::SloAlert`] right
//! after the window that tripped it, which `ssr cluster autoscale` then
//! surfaces alongside the audit log.
//!
//! Error accounting follows the SRE burn-rate convention: the budget is
//! `1 - target`; a request burns budget when it is shed, lost, or served
//! over the SLO. Burn rate is the observed error rate over a trailing
//! window divided by the budget — a burn of 1.0 spends the budget exactly
//! at the sustainable pace; alerts require both a fast (spiky) and a slow
//! (sustained) window over the threshold, which suppresses one-window
//! blips without missing real regressions.

use std::collections::VecDeque;

use super::event::TraceEvent;

/// Burn-rate alerting policy.
#[derive(Clone, Copy, Debug)]
pub struct SloCfg {
    /// Attainment target in (0, 1); the error budget is `1 - target`.
    pub target: f64,
    /// Trailing windows for the fast (page-worthy spike) burn rate.
    pub fast_windows: usize,
    /// Trailing windows for the slow (sustained) burn rate.
    pub slow_windows: usize,
    /// Alert when BOTH burn rates exceed this multiple of budget pace.
    pub burn_threshold: f64,
}

impl Default for SloCfg {
    fn default() -> Self {
        SloCfg { target: 0.999, fast_windows: 3, slow_windows: 12, burn_threshold: 4.0 }
    }
}

impl SloCfg {
    pub fn validate(&self) -> Result<(), String> {
        if !(self.target > 0.0 && self.target < 1.0) {
            return Err(format!("slo target must be in (0,1), got {}", self.target));
        }
        if self.fast_windows == 0 || self.slow_windows < self.fast_windows {
            return Err(format!(
                "burn windows must satisfy 0 < fast ({}) <= slow ({})",
                self.fast_windows, self.slow_windows
            ));
        }
        if self.burn_threshold <= 0.0 {
            return Err(format!("burn threshold must be > 0, got {}", self.burn_threshold));
        }
        Ok(())
    }
}

/// Streaming SLO monitor: feed it the event stream in order; it rolls
/// per-window (requests, errors) tallies and emits an alert event at the
/// window boundary where both burn rates cross the threshold.
#[derive(Clone, Debug)]
pub struct SloMonitor {
    cfg: SloCfg,
    /// SLO threshold in seconds; a served request over this is an error.
    slo_s: f64,
    /// Per-closed-window (requests, errors), newest last, capped at
    /// `cfg.slow_windows`.
    ring: VecDeque<(u64, u64)>,
    cur_total: u64,
    cur_err: u64,
}

impl SloMonitor {
    pub fn new(slo_s: f64, cfg: SloCfg) -> Self {
        SloMonitor { cfg, slo_s, ring: VecDeque::new(), cur_total: 0, cur_err: 0 }
    }

    /// Error rate over the trailing `n` closed windows, divided by the
    /// error budget (0.0 when those windows saw no traffic).
    fn burn(&self, n: usize) -> f64 {
        let budget = (1.0 - self.cfg.target).max(1e-12);
        let take = n.min(self.ring.len());
        let (mut t, mut e) = (0u64, 0u64);
        for &(wt, we) in self.ring.iter().rev().take(take) {
            t += wt;
            e += we;
        }
        if t == 0 {
            0.0
        } else {
            (e as f64 / t as f64) / budget
        }
    }

    /// Attainment over the trailing `n` closed windows (1.0 on no traffic).
    pub fn attainment(&self, n: usize) -> f64 {
        let budget = (1.0 - self.cfg.target).max(1e-12);
        1.0 - self.burn(n) * budget
    }

    /// Observe one event; at a [`TraceEvent::Window`] boundary, returns
    /// the alert to splice in (if both burn rates crossed the threshold).
    pub fn observe(&mut self, ev: &TraceEvent) -> Option<TraceEvent> {
        match ev {
            TraceEvent::Served { sojourn_s, .. } => {
                self.cur_total += 1;
                if *sojourn_s > self.slo_s {
                    self.cur_err += 1;
                }
                None
            }
            // A request counts exactly once: served requests at their
            // completion, everything that never completes at the moment
            // it is dropped.
            TraceEvent::Shed { .. }
            | TraceEvent::Unroutable { .. }
            | TraceEvent::RequeueLost { .. } => {
                self.cur_total += 1;
                self.cur_err += 1;
                None
            }
            TraceEvent::Requeue { admitted: false, .. } => {
                self.cur_total += 1;
                self.cur_err += 1;
                None
            }
            TraceEvent::Window { window, end_s } => {
                self.ring.push_back((self.cur_total, self.cur_err));
                while self.ring.len() > self.cfg.slow_windows {
                    self.ring.pop_front();
                }
                self.cur_total = 0;
                self.cur_err = 0;
                let fast = self.burn(self.cfg.fast_windows);
                let slow = self.burn(self.cfg.slow_windows);
                if self.ring.len() >= self.cfg.fast_windows
                    && fast > self.cfg.burn_threshold
                    && slow > self.cfg.burn_threshold
                {
                    Some(TraceEvent::SloAlert {
                        at_s: *end_s,
                        window: *window,
                        fast_burn: fast,
                        slow_burn: slow,
                    })
                } else {
                    None
                }
            }
            _ => None,
        }
    }
}

/// Replay the stream through an [`SloMonitor`], splicing each alert in
/// immediately after the window event that tripped it. Call after
/// [`merge_audit`](crate::obs::merge_audit) so alerts land between the
/// window marker's audit block and the next window's events — the order
/// is fixed either way, keeping output byte-stable.
pub fn annotate_slo(events: Vec<TraceEvent>, slo_s: f64, cfg: &SloCfg) -> Vec<TraceEvent> {
    let mut mon = SloMonitor::new(slo_s, *cfg);
    let mut out = Vec::with_capacity(events.len());
    for ev in events {
        let alert = mon.observe(&ev);
        out.push(ev);
        if let Some(a) = alert {
            out.push(a);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn served(at_s: f64, sojourn_s: f64) -> TraceEvent {
        TraceEvent::Served { at_s, dev: 0, sojourn_s }
    }

    fn window(w: usize, end_s: f64) -> TraceEvent {
        TraceEvent::Window { window: w, end_s }
    }

    #[test]
    fn no_traffic_never_alerts() {
        let cfg = SloCfg::default();
        let mut mon = SloMonitor::new(0.002, cfg);
        for w in 0..20 {
            assert!(mon.observe(&window(w, w as f64)).is_none());
        }
        assert_eq!(mon.attainment(12), 1.0);
    }

    #[test]
    fn sustained_violations_alert_and_blips_do_not() {
        let cfg = SloCfg { target: 0.9, fast_windows: 2, slow_windows: 4, burn_threshold: 3.0 };
        // One half-bad window among good ones burns 5x alone but only
        // 2.5x over the 2-window fast horizon — under the 3x threshold,
        // so the blip is suppressed.
        let mut mon = SloMonitor::new(0.002, cfg);
        for w in 0..4 {
            for i in 0..10 {
                let lat = if w == 1 && i < 5 { 0.01 } else { 0.001 };
                mon.observe(&served(w as f64 + 0.01 * i as f64, lat));
            }
            let alert = mon.observe(&window(w, (w + 1) as f64));
            assert!(alert.is_none(), "blip alerted at window {w}");
        }
        // All-bad traffic: error rate 1.0, budget 0.1 => burn 10x on both
        // windows, over the 3x threshold.
        let mut mon = SloMonitor::new(0.002, cfg);
        let mut alerted = false;
        for w in 0..4 {
            for i in 0..10 {
                mon.observe(&served(w as f64 + 0.01 * i as f64, 0.01));
            }
            if let Some(TraceEvent::SloAlert { fast_burn, slow_burn, .. }) =
                mon.observe(&window(w, (w + 1) as f64))
            {
                assert!(fast_burn > 3.0 && slow_burn > 3.0);
                alerted = true;
            }
        }
        assert!(alerted, "sustained violations never alerted");
    }

    #[test]
    fn annotate_inserts_alert_after_its_window() {
        let cfg = SloCfg { target: 0.9, fast_windows: 1, slow_windows: 1, burn_threshold: 2.0 };
        let stream = vec![served(0.5, 0.05), window(0, 1.0), served(1.5, 0.001), window(1, 2.0)];
        let out = annotate_slo(stream, 0.002, &cfg);
        assert_eq!(out.len(), 5);
        assert!(matches!(out[1], TraceEvent::Window { window: 0, .. }));
        assert!(matches!(out[2], TraceEvent::SloAlert { window: 0, .. }));
        assert!(matches!(out[4], TraceEvent::Window { window: 1, .. }));
    }
}
