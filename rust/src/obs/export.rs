//! Trace export: Chrome trace-event JSON and event-stream tallies.
//!
//! [`chrome_trace_json`] renders a collected stream in the Chrome
//! trace-event format (load in `chrome://tracing` or Perfetto):
//! instants for point events, complete ("X") slices for launches with
//! their batch duration, one track (`tid`) per device. Rendering goes
//! through [`util::json::Json`](crate::util::json::Json), whose `BTreeMap`
//! object keys and fixed number formatting make the output byte-stable —
//! the same seeded run always writes the identical file (pinned in CI by
//! running `ssr cluster autoscale --trace-out` twice and comparing).
//!
//! [`trace_tallies`] / [`tallies_from_json`] reconstruct end-of-run
//! tallies purely from events — `tests/obs_trace.rs` pins them equal to
//! the sim reports' own counters (conservation: served + shed ==
//! arrivals), and `ssr obs report` prints them for any saved trace.

use std::collections::BTreeMap;

use super::event::TraceEvent;
use crate::util::json::Json;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn unum(x: usize) -> Json {
    Json::Num(x as f64)
}

/// Per-variant `args` payload for the Chrome trace event.
fn args_of(ev: &TraceEvent) -> Json {
    match ev {
        TraceEvent::Arrival { class, .. } | TraceEvent::Shed { class, .. } => {
            obj(vec![("class", unum(*class))])
        }
        TraceEvent::Unroutable { class, .. } => obj(vec![("class", unum(*class))]),
        TraceEvent::Launch { plan, .. } => obj(vec![("plan", unum(*plan))]),
        TraceEvent::ServiceDraw { plan, factor, .. } => {
            obj(vec![("factor", num(*factor)), ("plan", unum(*plan))])
        }
        TraceEvent::Served { sojourn_s, .. } => obj(vec![("sojourn_ms", num(sojourn_s * 1e3))]),
        TraceEvent::Requeue { window, class, admitted, .. } => obj(vec![
            ("admitted", Json::Bool(*admitted)),
            ("class", unum(*class)),
            ("window", unum(*window)),
        ]),
        TraceEvent::RequeueLost { window, class, .. } => {
            obj(vec![("class", unum(*class)), ("window", unum(*window))])
        }
        TraceEvent::PlanSwitch { window, from, to, draining, .. } => obj(vec![
            ("draining", Json::Bool(*draining)),
            ("from", unum(*from)),
            ("to", unum(*to)),
            ("window", unum(*window)),
        ]),
        TraceEvent::PlanApplied { plan, .. } => obj(vec![("plan", unum(*plan))]),
        TraceEvent::DeviceWindow { window, rate_rps, queue_depth, p99_s, committed, .. } => {
            // p99 of an empty window is NaN, which JSON cannot carry.
            let p99_ms = if p99_s.is_finite() { p99_s * 1e3 } else { -1.0 };
            obj(vec![
                ("committed", unum(*committed)),
                ("p99_ms", num(p99_ms)),
                ("queue_depth", unum(*queue_depth)),
                ("rate_rps", num(*rate_rps)),
                ("window", unum(*window)),
            ])
        }
        TraceEvent::Window { window, .. } => obj(vec![("window", unum(*window))]),
        TraceEvent::ScaleOut { window, id, .. } => {
            obj(vec![("id", Json::Str(id.clone())), ("window", unum(*window))])
        }
        TraceEvent::DrainStart { window, id, reason, .. } => obj(vec![
            ("id", Json::Str(id.clone())),
            ("reason", Json::Str(format!("{reason:?}"))),
            ("window", unum(*window)),
        ]),
        TraceEvent::Retired { window, id, .. } => {
            obj(vec![("id", Json::Str(id.clone())), ("window", unum(*window))])
        }
        TraceEvent::Failed { window, id, requeued, .. } => obj(vec![
            ("id", Json::Str(id.clone())),
            ("requeued", unum(*requeued)),
            ("window", unum(*window)),
        ]),
        TraceEvent::SwapReplace { window, old, new, .. } => obj(vec![
            ("new", Json::Str(new.clone())),
            ("old", Json::Str(old.clone())),
            ("window", unum(*window)),
        ]),
        TraceEvent::SloAlert { window, fast_burn, slow_burn, .. } => obj(vec![
            ("fast_burn", num(*fast_burn)),
            ("slow_burn", num(*slow_burn)),
            ("window", unum(*window)),
        ]),
    }
}

/// Render one event as a Chrome trace-event object.
fn trace_obj(ev: &TraceEvent) -> Json {
    let ts_us = ev.at_s() * 1e6;
    let tid = ev.dev().unwrap_or(0);
    let mut fields = vec![
        ("args", args_of(ev)),
        ("name", Json::Str(ev.name().to_string())),
        ("pid", unum(0)),
        ("tid", unum(tid)),
    ];
    if let TraceEvent::Launch { at_s, done_s, .. } = ev {
        fields.push(("ph", Json::Str("X".to_string())));
        fields.push(("ts", num(at_s * 1e6)));
        fields.push(("dur", num((done_s - at_s) * 1e6)));
    } else {
        fields.push(("ph", Json::Str("i".to_string())));
        fields.push(("ts", num(ts_us)));
        fields.push(("s", Json::Str("t".to_string())));
    }
    obj(fields)
}

/// Render a stream as Chrome trace-event JSON (the
/// `{"displayTimeUnit":"ms","traceEvents":[...]}` object form).
pub fn chrome_trace_json(events: &[TraceEvent]) -> String {
    let evs: Vec<Json> = events.iter().map(trace_obj).collect();
    obj(vec![
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("traceEvents", Json::Arr(evs)),
    ])
    .to_string()
}

/// End-of-run tallies reconstructed purely from an event stream.
///
/// Field conventions mirror the sim reports so equality checks are
/// direct: `shed` counts admission sheds *and* unroutables *and*
/// requeue-losses (what `AutoscaleReport.shed` reports), `requeued`
/// counts every re-dispatch attempt including lost ones (what
/// `TimelineOutcome.requeued` reports).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceTallies {
    /// Offered requests: admitted + shed + unroutable.
    pub arrivals: u64,
    /// Requests admitted to a device queue on first arrival.
    pub admitted: u64,
    /// Completions.
    pub served: u64,
    /// Admission sheds (first arrival + requeue) + unroutable + lost.
    pub shed: u64,
    /// Requests no serving device could take.
    pub unroutable: u64,
    /// Re-dispatch attempts at window boundaries (admitted or not + lost).
    pub requeued: u64,
    /// Re-dispatches that found no eligible target.
    pub requeue_lost: u64,
    /// Batch launches.
    pub launches: u64,
    /// Committed plan switches.
    pub plan_switches: u64,
    /// Fleet window boundaries observed.
    pub windows: u64,
    /// Controller audit events (scale/drain/retire/fail/swap).
    pub audit: u64,
    /// SLO burn-rate alerts.
    pub slo_alerts: u64,
    /// Latest completion timestamp (0 when nothing completed).
    pub makespan_s: f64,
    /// Event counts by [`TraceEvent::name`].
    pub by_name: BTreeMap<String, u64>,
}

impl TraceTallies {
    fn absorb(&mut self, name: &str, admitted_flag: bool, at_s: f64) {
        *self.by_name.entry(name.to_string()).or_insert(0) += 1;
        match name {
            "arrival" => {
                self.arrivals += 1;
                self.admitted += 1;
            }
            "shed" => {
                self.arrivals += 1;
                self.shed += 1;
            }
            "unroutable" => {
                self.arrivals += 1;
                self.shed += 1;
                self.unroutable += 1;
            }
            "served" => {
                self.served += 1;
                if at_s > self.makespan_s {
                    self.makespan_s = at_s;
                }
            }
            "requeue" => {
                self.requeued += 1;
                if !admitted_flag {
                    self.shed += 1;
                }
            }
            "requeue-lost" => {
                self.requeued += 1;
                self.requeue_lost += 1;
                self.shed += 1;
            }
            "launch" => self.launches += 1,
            "plan-switch" => self.plan_switches += 1,
            "window" => self.windows += 1,
            "scale-out" | "drain-start" | "retired" | "failed" | "swap-replace" => {
                self.audit += 1;
            }
            "slo-alert" => self.slo_alerts += 1,
            _ => {}
        }
    }

    /// The conservation identity every run must satisfy: each offered
    /// request leaves the system exactly once — served, or dropped (shed
    /// at admission or requeue, unroutable, requeue-lost; all folded into
    /// `shed`). Requeued requests were already admitted once, so they do
    /// not re-enter `arrivals`. A run cut off mid-flight leaves requests
    /// in queues, so `served + shed` may undercount `arrivals` but must
    /// never exceed it.
    pub fn conserved(&self) -> bool {
        self.served + self.shed <= self.arrivals
    }

    /// Requests still in-system when the trace ended (admitted, neither
    /// served nor dropped).
    pub fn in_flight(&self) -> u64 {
        self.arrivals.saturating_sub(self.served + self.shed)
    }
}

/// Tally a collected in-memory stream.
pub fn trace_tallies(events: &[TraceEvent]) -> TraceTallies {
    let mut t = TraceTallies::default();
    for ev in events {
        let admitted = !matches!(ev, TraceEvent::Requeue { admitted: false, .. });
        t.absorb(ev.name(), admitted, ev.at_s());
    }
    t
}

/// Tally a trace previously written by [`chrome_trace_json`], from its
/// parsed JSON. Only the fields the tally needs are read, so traces from
/// other writers work as long as they carry `name`, `ts`, and (for
/// requeue events) `args.admitted`.
pub fn tallies_from_json(root: &Json) -> Result<TraceTallies, String> {
    let evs = root
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or("missing traceEvents array")?;
    let mut t = TraceTallies::default();
    for (i, ev) in evs.iter().enumerate() {
        let name = ev
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("traceEvents[{i}]: missing name"))?;
        let ts_us = ev
            .get("ts")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("traceEvents[{i}]: missing ts"))?;
        let admitted = ev
            .get("args")
            .and_then(|a| a.get("admitted"))
            .and_then(Json::as_bool)
            .unwrap_or(true);
        t.absorb(name, admitted, ts_us / 1e6);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_stream() -> Vec<TraceEvent> {
        vec![
            TraceEvent::Arrival { at_s: 0.001, dev: 0, class: 0 },
            TraceEvent::Launch { at_s: 0.001, dev: 0, plan: 2, done_s: 0.004 },
            TraceEvent::Shed { at_s: 0.002, dev: 0, class: 1 },
            TraceEvent::Served { at_s: 0.004, dev: 0, sojourn_s: 0.003 },
            TraceEvent::Window { window: 0, end_s: 0.05 },
            TraceEvent::ScaleOut { at_s: 0.05, window: 0, id: "d1".to_string() },
            TraceEvent::SloAlert { at_s: 0.05, window: 0, fast_burn: 5.0, slow_burn: 4.5 },
        ]
    }

    #[test]
    fn chrome_trace_parses_and_tallies_round_trip() {
        let stream = sample_stream();
        let text = chrome_trace_json(&stream);
        let root = Json::parse(&text).expect("trace json parses");
        let mut from_json = tallies_from_json(&root).expect("tallies");
        let direct = trace_tallies(&stream);
        // Timestamps ride through the file in microseconds; the µs→s
        // conversion can differ from the original by an ulp, so compare
        // the float field with a tolerance and the counters exactly.
        assert!((from_json.makespan_s - direct.makespan_s).abs() < 1e-9);
        from_json.makespan_s = direct.makespan_s;
        assert_eq!(from_json, direct);
        assert_eq!(direct.arrivals, 2);
        assert_eq!(direct.served, 1);
        assert_eq!(direct.shed, 1);
        assert_eq!(direct.audit, 1);
        assert_eq!(direct.slo_alerts, 1);
        assert!(direct.conserved());
        assert!((direct.makespan_s - 0.004).abs() < 1e-12);
    }

    #[test]
    fn launch_renders_as_complete_slice() {
        let text = chrome_trace_json(&sample_stream());
        let root = Json::parse(&text).expect("parses");
        let evs = root.get("traceEvents").and_then(Json::as_arr).unwrap();
        let launch = evs
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("launch"))
            .expect("launch event present");
        assert_eq!(launch.get("ph").and_then(Json::as_str), Some("X"));
        let dur = launch.get("dur").and_then(Json::as_f64).unwrap();
        assert!((dur - 3000.0).abs() < 1e-9, "dur {dur} != 3000 us");
    }

    #[test]
    fn rendering_is_deterministic() {
        let a = chrome_trace_json(&sample_stream());
        let b = chrome_trace_json(&sample_stream());
        assert_eq!(a, b);
    }
}
