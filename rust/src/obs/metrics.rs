//! Metrics registry: counters, gauges, and a log-binned latency summary
//! sampled per window into a time series, built by replaying a trace.
//!
//! The registry never sits on the hot path — it is a deterministic fold
//! over a collected [`TraceEvent`] stream (`reg.observe(ev)` per event),
//! so the same trace always yields byte-identical exports. Latency
//! quantiles reuse [`LatencySketch`] (log-binned, O(1) memory).
//!
//! Two export formats:
//! * [`MetricsRegistry::to_prometheus`] — text exposition (`# TYPE`
//!   lines, counter/gauge/summary families). [`parse_prometheus`] /
//!   [`render_prometheus`] round-trip it byte-identically (pinned in CI).
//! * [`MetricsRegistry::to_json`] — the same data as a JSON tree,
//!   including the per-window time series.

use std::collections::BTreeMap;

use super::event::TraceEvent;
use crate::util::json::Json;
use crate::util::stats::LatencySketch;

/// One per-window snapshot in the registry's time series.
///
/// "Offered" counts requests at arrival (admitted + shed + unroutable);
/// "served"/"errors" follow the SLO monitor's convention — served
/// requests count at completion, drops at the moment they are dropped,
/// and a served request over the SLO is an error.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WindowSample {
    pub window: usize,
    pub end_s: f64,
    pub offered: u64,
    pub served: u64,
    pub errors: u64,
    /// Devices that reported a window rollup (i.e. live this window).
    pub live_devices: u64,
    /// Sum of per-device queue depths at the window boundary.
    pub queue_depth: u64,
    /// Sum of per-device estimated arrival rates.
    pub rate_rps: f64,
    /// Within-window attainment: non-error completions over completions
    /// plus drops (1.0 when the window saw no traffic).
    pub attainment: f64,
}

#[derive(Clone, Copy, Debug, Default)]
struct WinAccum {
    offered: u64,
    served: u64,
    /// Drops plus over-SLO completions (always <= served + drops).
    errors: u64,
    /// Requests dropped this window (shed / unroutable / requeue-lost).
    drops: u64,
    live_devices: u64,
    queue_depth: u64,
    rate_rps: f64,
}

/// Counter / gauge / summary registry over one trace stream.
#[derive(Clone, Debug)]
pub struct MetricsRegistry {
    slo_s: f64,
    counters: BTreeMap<&'static str, u64>,
    latency: LatencySketch,
    /// Stochastic service-time factors seen in `ServiceDraw` events —
    /// feeds the `ssr_service_factor_p99` tail gauge. Empty on a
    /// deterministic run (the gauge then reads exactly 1).
    service_factors: LatencySketch,
    series: Vec<WindowSample>,
    win: WinAccum,
}

/// Every counter key, in the fixed order they appear in exports.
/// (BTreeMap iteration is alphabetical; this constant exists so tests and
/// readers see the full vocabulary in one place.)
pub const COUNTER_KEYS: &[&str] = &[
    "admitted_total",
    "drain_start_total",
    "failed_total",
    "launches_total",
    "plan_applied_total",
    "plan_switches_total",
    "requests_total",
    "requeue_lost_total",
    "requeued_total",
    "retired_total",
    "scale_out_total",
    "served_total",
    "service_draws_total",
    "shed_total",
    "slo_alerts_total",
    "slo_violations_total",
    "swap_replace_total",
    "unroutable_total",
    "windows_total",
];

impl MetricsRegistry {
    /// `slo_s`: the latency SLO in seconds (a served request over it
    /// counts into `slo_violations_total` and window errors).
    pub fn new(slo_s: f64) -> Self {
        let mut counters = BTreeMap::new();
        for &k in COUNTER_KEYS {
            counters.insert(k, 0);
        }
        MetricsRegistry {
            slo_s,
            counters,
            latency: LatencySketch::new(),
            service_factors: LatencySketch::new(),
            series: Vec::new(),
            win: WinAccum::default(),
        }
    }

    fn bump(&mut self, key: &'static str) {
        *self.counters.get_mut(key).expect("counter key registered in new()") += 1;
    }

    /// Fold one event into the registry.
    pub fn observe(&mut self, ev: &TraceEvent) {
        match ev {
            TraceEvent::Arrival { .. } => {
                self.bump("requests_total");
                self.bump("admitted_total");
                self.win.offered += 1;
            }
            TraceEvent::Shed { .. } => {
                self.bump("requests_total");
                self.bump("shed_total");
                self.win.offered += 1;
                self.win.errors += 1;
                self.win.drops += 1;
            }
            TraceEvent::Unroutable { .. } => {
                self.bump("requests_total");
                self.bump("unroutable_total");
                self.win.offered += 1;
                self.win.errors += 1;
                self.win.drops += 1;
            }
            TraceEvent::Launch { .. } => self.bump("launches_total"),
            TraceEvent::ServiceDraw { factor, .. } => {
                self.bump("service_draws_total");
                self.service_factors.record(*factor);
            }
            TraceEvent::Served { sojourn_s, .. } => {
                self.bump("served_total");
                self.latency.record(*sojourn_s);
                self.win.served += 1;
                if *sojourn_s > self.slo_s {
                    self.bump("slo_violations_total");
                    self.win.errors += 1;
                }
            }
            TraceEvent::Requeue { admitted, .. } => {
                self.bump("requeued_total");
                if !admitted {
                    self.bump("shed_total");
                    self.win.errors += 1;
                    self.win.drops += 1;
                }
            }
            TraceEvent::RequeueLost { .. } => {
                self.bump("requeued_total");
                self.bump("requeue_lost_total");
                self.win.errors += 1;
                self.win.drops += 1;
            }
            TraceEvent::PlanSwitch { .. } => self.bump("plan_switches_total"),
            TraceEvent::PlanApplied { .. } => self.bump("plan_applied_total"),
            TraceEvent::DeviceWindow { queue_depth, rate_rps, .. } => {
                self.win.live_devices += 1;
                self.win.queue_depth += *queue_depth as u64;
                self.win.rate_rps += *rate_rps;
            }
            TraceEvent::Window { window, end_s } => {
                self.bump("windows_total");
                let a = self.win;
                // Outcomes this window: completions plus drops. Errors are
                // drops plus over-SLO completions, so errors <= total.
                let total = a.served + a.drops;
                let attainment = if total == 0 {
                    1.0
                } else {
                    (total - a.errors.min(total)) as f64 / total as f64
                };
                self.series.push(WindowSample {
                    window: *window,
                    end_s: *end_s,
                    offered: a.offered,
                    served: a.served,
                    errors: a.errors,
                    live_devices: a.live_devices,
                    queue_depth: a.queue_depth,
                    rate_rps: a.rate_rps,
                    attainment,
                });
                self.win = WinAccum::default();
            }
            TraceEvent::SloAlert { .. } => self.bump("slo_alerts_total"),
            TraceEvent::ScaleOut { .. } => self.bump("scale_out_total"),
            TraceEvent::DrainStart { .. } => self.bump("drain_start_total"),
            TraceEvent::Retired { .. } => self.bump("retired_total"),
            TraceEvent::Failed { .. } => self.bump("failed_total"),
            TraceEvent::SwapReplace { .. } => self.bump("swap_replace_total"),
        }
    }

    /// Fold a whole stream (convenience for `observe` in a loop).
    pub fn observe_all(&mut self, events: &[TraceEvent]) {
        for ev in events {
            self.observe(ev);
        }
    }

    pub fn counter(&self, key: &str) -> u64 {
        self.counters.get(key).copied().unwrap_or(0)
    }

    /// The per-window time series (one entry per `Window` event seen).
    pub fn series(&self) -> &[WindowSample] {
        &self.series
    }

    /// p99 of the stochastic service-time factors observed via
    /// `ServiceDraw` events; exactly 1.0 on a deterministic run (which
    /// emits no draws — every launch ran at 1x).
    pub fn service_factor_p99(&self) -> f64 {
        if self.service_factors.count() == 0 {
            1.0
        } else {
            self.service_factors.quantile(0.99)
        }
    }

    /// Overall attainment: non-error outcomes over all request outcomes
    /// (served + shed + unroutable + requeue-lost); 1.0 with no traffic.
    pub fn attainment(&self) -> f64 {
        let served = self.counter("served_total");
        let drops = self.counter("shed_total")
            + self.counter("unroutable_total")
            + self.counter("requeue_lost_total");
        let total = served + drops;
        if total == 0 {
            return 1.0;
        }
        let good = served - self.counter("slo_violations_total").min(served);
        good as f64 / total as f64
    }

    /// Build the export families (shared by the text and JSON paths, and
    /// by the CI round-trip check).
    pub fn families(&self) -> Vec<PromFamily> {
        let mut out = Vec::with_capacity(self.counters.len() + 4);
        for (&k, &v) in &self.counters {
            out.push(PromFamily {
                name: format!("ssr_{k}"),
                kind: "counter",
                samples: vec![PromSample { key: format!("ssr_{k}"), value: v as f64 }],
            });
        }
        let last = self.series.last();
        out.push(gauge("ssr_live_devices", last.map_or(0.0, |s| s.live_devices as f64)));
        out.push(gauge("ssr_queue_depth", last.map_or(0.0, |s| s.queue_depth as f64)));
        out.push(gauge("ssr_slo_attainment", self.attainment()));
        out.push(gauge("ssr_service_factor_p99", self.service_factor_p99()));
        let n = self.latency.count();
        let q = |p: f64| if n == 0 { 0.0 } else { self.latency.quantile(p) };
        let sum = if n == 0 { 0.0 } else { self.latency.mean() * n as f64 };
        out.push(PromFamily {
            name: "ssr_latency_seconds".into(),
            kind: "summary",
            samples: vec![
                PromSample { key: "ssr_latency_seconds{quantile=\"0.5\"}".into(), value: q(0.5) },
                PromSample { key: "ssr_latency_seconds{quantile=\"0.99\"}".into(), value: q(0.99) },
                PromSample { key: "ssr_latency_seconds_sum".into(), value: sum },
                PromSample { key: "ssr_latency_seconds_count".into(), value: n as f64 },
            ],
        });
        out
    }

    /// Prometheus text exposition (one `# TYPE` line per family).
    pub fn to_prometheus(&self) -> String {
        render_prometheus(&self.families())
    }

    /// The registry as a JSON tree: counters, gauges, latency summary,
    /// and the per-window series.
    pub fn to_json(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (&k, &v) in &self.counters {
            counters.insert(k.to_string(), Json::Num(v as f64));
        }
        let n = self.latency.count();
        let q = |p: f64| Json::Num(if n == 0 { 0.0 } else { self.latency.quantile(p) });
        let latency = Json::Obj(BTreeMap::from([
            ("count".to_string(), Json::Num(n as f64)),
            ("mean_s".to_string(), Json::Num(if n == 0 { 0.0 } else { self.latency.mean() })),
            ("p50_s".to_string(), q(0.5)),
            ("p99_s".to_string(), q(0.99)),
        ]));
        let series: Vec<Json> = self
            .series
            .iter()
            .map(|s| {
                Json::Obj(BTreeMap::from([
                    ("window".to_string(), Json::Num(s.window as f64)),
                    ("end_s".to_string(), Json::Num(s.end_s)),
                    ("offered".to_string(), Json::Num(s.offered as f64)),
                    ("served".to_string(), Json::Num(s.served as f64)),
                    ("errors".to_string(), Json::Num(s.errors as f64)),
                    ("live_devices".to_string(), Json::Num(s.live_devices as f64)),
                    ("queue_depth".to_string(), Json::Num(s.queue_depth as f64)),
                    ("rate_rps".to_string(), Json::Num(s.rate_rps)),
                    ("attainment".to_string(), Json::Num(s.attainment)),
                ]))
            })
            .collect();
        Json::Obj(BTreeMap::from([
            ("counters".to_string(), Json::Obj(counters)),
            ("slo_attainment".to_string(), Json::Num(self.attainment())),
            ("service_factor_p99".to_string(), Json::Num(self.service_factor_p99())),
            ("latency".to_string(), latency),
            ("series".to_string(), Json::Arr(series)),
        ]))
    }
}

fn gauge(name: &str, value: f64) -> PromFamily {
    PromFamily {
        name: name.into(),
        kind: "gauge",
        samples: vec![PromSample { key: name.into(), value }],
    }
}

/// One sample line of a Prometheus family; `key` is the metric name
/// including any `{label="..."}` suffix, verbatim.
#[derive(Clone, Debug, PartialEq)]
pub struct PromSample {
    pub key: String,
    pub value: f64,
}

/// One `# TYPE` family of the text exposition.
#[derive(Clone, Debug, PartialEq)]
pub struct PromFamily {
    pub name: String,
    pub kind: &'static str,
    pub samples: Vec<PromSample>,
}

/// Number formatting shared by render and re-render: integers without a
/// fraction print as integers, everything else as shortest round-trip
/// (the same rule `util::json` uses), so parse → render is a fixed point.
fn fmt_num(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

/// Render families as Prometheus text exposition.
pub fn render_prometheus(families: &[PromFamily]) -> String {
    let mut out = String::new();
    for f in families {
        out.push_str("# TYPE ");
        out.push_str(&f.name);
        out.push(' ');
        out.push_str(f.kind);
        out.push('\n');
        for s in &f.samples {
            out.push_str(&s.key);
            out.push(' ');
            out.push_str(&fmt_num(s.value));
            out.push('\n');
        }
    }
    out
}

/// Parse the subset of the text exposition this crate emits (`# TYPE`
/// headers plus `key value` sample lines). Returns the families in file
/// order; [`render_prometheus`] of the result reproduces a file this
/// crate wrote byte-for-byte (pinned in CI and `tests/obs_trace.rs`).
pub fn parse_prometheus(text: &str) -> Result<Vec<PromFamily>, String> {
    let mut out: Vec<PromFamily> = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().ok_or_else(|| format!("line {}: missing family name", i + 1))?;
            let kind = match it.next() {
                Some("counter") => "counter",
                Some("gauge") => "gauge",
                Some("summary") => "summary",
                Some("histogram") => "histogram",
                other => return Err(format!("line {}: bad family kind {:?}", i + 1, other)),
            };
            out.push(PromFamily { name: name.to_string(), kind, samples: Vec::new() });
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP or comment lines: accepted, not re-rendered
        }
        let (key, val) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {}: expected `key value`", i + 1))?;
        let value: f64 = val
            .parse()
            .map_err(|e| format!("line {}: bad value {val:?}: {e}", i + 1))?;
        let fam = out
            .last_mut()
            .ok_or_else(|| format!("line {}: sample before any # TYPE header", i + 1))?;
        fam.samples.push(PromSample { key: key.to_string(), value });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_registry_exports_without_nan() {
        let reg = MetricsRegistry::new(0.002);
        let text = reg.to_prometheus();
        assert!(!text.contains("NaN"), "exposition contains NaN:\n{text}");
        assert!(text.contains("# TYPE ssr_requests_total counter"));
        assert!(text.contains("ssr_slo_attainment 1\n"));
        let js = reg.to_json().to_string();
        assert!(!js.contains("NaN"), "json contains NaN:\n{js}");
        assert!(Json::parse(&js).is_ok());
    }

    #[test]
    fn counts_and_attainment_follow_the_stream() {
        let mut reg = MetricsRegistry::new(0.002);
        reg.observe_all(&[
            TraceEvent::Arrival { at_s: 0.1, dev: 0, class: 0 },
            TraceEvent::Arrival { at_s: 0.2, dev: 0, class: 0 },
            TraceEvent::Shed { at_s: 0.3, dev: 0, class: 1 },
            TraceEvent::Served { at_s: 0.4, dev: 0, sojourn_s: 0.001 },
            TraceEvent::Served { at_s: 0.5, dev: 0, sojourn_s: 0.010 },
            TraceEvent::Window { window: 0, end_s: 1.0 },
        ]);
        assert_eq!(reg.counter("requests_total"), 3);
        assert_eq!(reg.counter("served_total"), 2);
        assert_eq!(reg.counter("shed_total"), 1);
        assert_eq!(reg.counter("slo_violations_total"), 1);
        // 1 good of (2 served + 1 shed) outcomes.
        assert!((reg.attainment() - 1.0 / 3.0).abs() < 1e-12);
        let s = reg.series();
        assert_eq!(s.len(), 1);
        assert_eq!((s[0].offered, s[0].served, s[0].errors), (3, 2, 2));
    }

    #[test]
    fn exposition_round_trips_byte_identically() {
        let mut reg = MetricsRegistry::new(0.002);
        reg.observe_all(&[
            TraceEvent::Arrival { at_s: 0.1, dev: 0, class: 0 },
            TraceEvent::Served { at_s: 0.4, dev: 0, sojourn_s: 0.0013 },
            TraceEvent::DeviceWindow {
                window: 0,
                end_s: 1.0,
                dev: 0,
                rate_rps: 123.456,
                queue_depth: 3,
                p99_s: 0.0013,
                committed: 1,
            },
            TraceEvent::Window { window: 0, end_s: 1.0 },
        ]);
        let text = reg.to_prometheus();
        let fams = parse_prometheus(&text).expect("own output parses");
        assert_eq!(render_prometheus(&fams), text);
    }
}
