//! Recorder hook: how the event loop hands observations out.
//!
//! `run_timeline`'s core is generic over a [`Recorder`], mirroring its
//! latency-sink pattern: callers that don't observe pass
//! [`NoopRecorder`], which monomorphizes `record` to an empty inlined
//! body — the event construction feeding it is dead code the optimizer
//! erases, so the 10M req/s single-core replay target and the flat-memory
//! proof in `benches/simcore.rs` survive untouched (both are guarded
//! there by a recorder-on vs recorder-off row).

use super::event::TraceEvent;

/// Sink for structured [`TraceEvent`]s from a simulation run.
///
/// Implementations must not change simulation behavior: the event loop
/// calls [`record`](Recorder::record) with already-computed values and
/// never reads anything back.
pub trait Recorder {
    /// Observe one event. Called in deterministic emission order.
    fn record(&mut self, ev: TraceEvent);

    /// False for the no-op recorder; guards event constructions that
    /// would otherwise read state just to be thrown away.
    #[inline]
    fn enabled(&self) -> bool {
        true
    }
}

/// The default recorder: does nothing, costs nothing.
///
/// `record` is `#[inline(always)]` with an empty body and `enabled()` is
/// a constant `false`, so every emission site in the hot loop folds away
/// under monomorphization.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoopRecorder;

impl Recorder for NoopRecorder {
    #[inline(always)]
    fn record(&mut self, _ev: TraceEvent) {}

    #[inline(always)]
    fn enabled(&self) -> bool {
        false
    }
}

/// Collects every event into a `Vec`, in emission order.
///
/// Pure collection — all analysis (metrics, SLO burn rates, export) runs
/// post-hoc over the collected stream, so recording adds only a push per
/// event to the hot loop.
#[derive(Clone, Debug, Default)]
pub struct TraceRecorder {
    /// Events in emission order.
    pub events: Vec<TraceEvent>,
}

impl TraceRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Consume the recorder, yielding the collected stream.
    pub fn into_events(self) -> Vec<TraceEvent> {
        self.events
    }
}

impl Recorder for TraceRecorder {
    #[inline]
    fn record(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }
}

/// Splice controller audit events into a sim event stream.
///
/// The controller acts at window boundaries and keeps its audit log
/// (`AutoscaleReport::events`) separate from the hot-path stream; this
/// merges the two deterministically: each audit event lands immediately
/// after the [`TraceEvent::Window`] marker for its window, in the
/// controller's own (already chronological) order. Any audit event whose
/// window never rolled (there are none today) is appended at the end.
pub fn merge_audit(events: Vec<TraceEvent>, audit: &[TraceEvent]) -> Vec<TraceEvent> {
    let mut out = Vec::with_capacity(events.len() + audit.len());
    let mut ai = 0;
    for ev in events {
        let win = match ev {
            TraceEvent::Window { window, .. } => Some(window),
            _ => None,
        };
        out.push(ev);
        if let Some(w) = win {
            while ai < audit.len() && audit[ai].window().is_some_and(|aw| aw <= w) {
                out.push(audit[ai].clone());
                ai += 1;
            }
        }
    }
    out.extend(audit[ai..].iter().cloned());
    out
}
