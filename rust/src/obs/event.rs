//! The structured trace-event vocabulary shared by every layer.
//!
//! One enum covers the whole stack: per-request hot-path events emitted
//! inside `sim::device::run_timeline`'s event loop (`Arrival`, `Shed`,
//! `Launch`, `Served`, ...), per-window scheduler events (`DeviceWindow`,
//! `PlanSwitch`), the autoscaling controller's audit events (`ScaleOut`,
//! `DrainStart`, `Retired`, `Failed`, `SwapReplace` — previously a
//! bespoke private enum in `cluster::controller`, now re-exported from
//! there as `FleetEvent` for backward compatibility), and the SLO
//! monitor's `SloAlert`.
//!
//! Hot-path variants carry only `Copy` scalars so constructing one in the
//! event loop is free to erase when the recorder is a
//! [`NoopRecorder`](crate::obs::NoopRecorder). The `String`-bearing audit
//! variants are only ever built by the controller, once per control
//! action — never on the per-request path.
//!
//! Every event carries its simulation timestamp; serialization order is
//! the emission order of the one event loop (deterministic per seed), so
//! trace output is byte-stable across runs and — for the sweep path,
//! which merges per-cell streams in cell-index order — across thread
//! counts.

/// Why a device began draining (audit detail on `DrainStart`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DrainReason {
    /// Low-water scale-in decision.
    ScaleIn,
    /// Rolling fleet-wide front swap.
    Swap,
}

/// One structured observation from a simulation run.
///
/// `dev` fields are fleet device indices (the sweep path re-tags them to
/// the sweep-cell index so merged traces stay unambiguous). All
/// timestamps are simulation seconds.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    // -- hot path (Copy scalars only) ------------------------------------
    /// A request was routed to `dev` and admitted to its queue.
    Arrival { at_s: f64, dev: usize, class: usize },
    /// A request was routed to `dev` but shed by admission control.
    Shed { at_s: f64, dev: usize, class: usize },
    /// No serving device could take the request's model class.
    Unroutable { at_s: f64, class: usize },
    /// `dev` started executing a batch under plan `plan`; it completes at
    /// `done_s` (rendered as a Chrome-trace complete event).
    Launch { at_s: f64, dev: usize, plan: usize, done_s: f64 },
    /// A stochastic [`ServiceModel`](crate::sim::service::ServiceModel)
    /// draw stretched (or shrank) the launch that follows: its duration is
    /// `factor` times plan `plan`'s deterministic latency. Emitted
    /// immediately before the corresponding `Launch`; never emitted on
    /// the `Deterministic` path.
    ServiceDraw { at_s: f64, dev: usize, plan: usize, factor: f64 },
    /// One request finished on `dev` with the given sojourn time.
    Served { at_s: f64, dev: usize, sojourn_s: f64 },
    /// A drained/failed device's request was re-dispatched at a window
    /// boundary; `admitted` is false when the target shed it.
    Requeue { at_s: f64, window: usize, dev: usize, class: usize, admitted: bool },
    /// A re-dispatched request found no eligible target and was dropped.
    RequeueLost { at_s: f64, window: usize, class: usize },
    /// `dev`'s adaptive scheduler committed a plan switch this window;
    /// `draining` means the old plan is still finishing in-flight work.
    PlanSwitch { at_s: f64, window: usize, dev: usize, from: usize, to: usize, draining: bool },
    /// A pending drain-and-swap completed: `dev` now executes `plan`.
    PlanApplied { at_s: f64, dev: usize, plan: usize },
    /// Per-device window rollup (mirrors `sim::device::WindowStat`).
    DeviceWindow {
        window: usize,
        end_s: f64,
        dev: usize,
        rate_rps: f64,
        queue_depth: usize,
        p99_s: f64,
        committed: usize,
    },
    /// Fleet-wide window boundary marker; controller audit events for
    /// this window splice in immediately after it (see
    /// [`merge_audit`](crate::obs::merge_audit)).
    Window { window: usize, end_s: f64 },

    // -- controller audit (cold path; one per control action) ------------
    /// Scale-out: pool device `id` was activated.
    ScaleOut { at_s: f64, window: usize, id: String },
    /// Device `id` began a hitless drain.
    DrainStart { at_s: f64, window: usize, id: String, reason: DrainReason },
    /// Hitless decommission finished (billed to the window boundary that
    /// observed it).
    Retired { at_s: f64, window: usize, id: String },
    /// Fault injection killed `id`; its queue was requeued.
    Failed { at_s: f64, window: usize, id: String, requeued: usize },
    /// Rolling front swap brought up `new` to replace `old` (surge path).
    SwapReplace { at_s: f64, window: usize, old: String, new: String },

    // -- SLO monitor ------------------------------------------------------
    /// Both burn-rate windows exceeded the alert threshold (see
    /// [`SloMonitor`](crate::obs::SloMonitor)).
    SloAlert { at_s: f64, window: usize, fast_burn: f64, slow_burn: f64 },
}

impl TraceEvent {
    /// Fixed kebab-case name used in trace JSON and `ssr obs report`.
    pub fn name(&self) -> &'static str {
        match self {
            TraceEvent::Arrival { .. } => "arrival",
            TraceEvent::Shed { .. } => "shed",
            TraceEvent::Unroutable { .. } => "unroutable",
            TraceEvent::Launch { .. } => "launch",
            TraceEvent::ServiceDraw { .. } => "service-draw",
            TraceEvent::Served { .. } => "served",
            TraceEvent::Requeue { .. } => "requeue",
            TraceEvent::RequeueLost { .. } => "requeue-lost",
            TraceEvent::PlanSwitch { .. } => "plan-switch",
            TraceEvent::PlanApplied { .. } => "plan-applied",
            TraceEvent::DeviceWindow { .. } => "device-window",
            TraceEvent::Window { .. } => "window",
            TraceEvent::ScaleOut { .. } => "scale-out",
            TraceEvent::DrainStart { .. } => "drain-start",
            TraceEvent::Retired { .. } => "retired",
            TraceEvent::Failed { .. } => "failed",
            TraceEvent::SwapReplace { .. } => "swap-replace",
            TraceEvent::SloAlert { .. } => "slo-alert",
        }
    }

    /// Simulation timestamp of the event in seconds.
    pub fn at_s(&self) -> f64 {
        match self {
            TraceEvent::Arrival { at_s, .. }
            | TraceEvent::Shed { at_s, .. }
            | TraceEvent::Unroutable { at_s, .. }
            | TraceEvent::Launch { at_s, .. }
            | TraceEvent::ServiceDraw { at_s, .. }
            | TraceEvent::Served { at_s, .. }
            | TraceEvent::Requeue { at_s, .. }
            | TraceEvent::RequeueLost { at_s, .. }
            | TraceEvent::PlanSwitch { at_s, .. }
            | TraceEvent::PlanApplied { at_s, .. }
            | TraceEvent::ScaleOut { at_s, .. }
            | TraceEvent::DrainStart { at_s, .. }
            | TraceEvent::Retired { at_s, .. }
            | TraceEvent::Failed { at_s, .. }
            | TraceEvent::SwapReplace { at_s, .. }
            | TraceEvent::SloAlert { at_s, .. } => *at_s,
            TraceEvent::DeviceWindow { end_s, .. } | TraceEvent::Window { end_s, .. } => *end_s,
        }
    }

    /// Window index, for events tied to a window boundary.
    pub fn window(&self) -> Option<usize> {
        match self {
            TraceEvent::Requeue { window, .. }
            | TraceEvent::RequeueLost { window, .. }
            | TraceEvent::PlanSwitch { window, .. }
            | TraceEvent::DeviceWindow { window, .. }
            | TraceEvent::Window { window, .. }
            | TraceEvent::ScaleOut { window, .. }
            | TraceEvent::DrainStart { window, .. }
            | TraceEvent::Retired { window, .. }
            | TraceEvent::Failed { window, .. }
            | TraceEvent::SwapReplace { window, .. }
            | TraceEvent::SloAlert { window, .. } => Some(*window),
            _ => None,
        }
    }

    /// Device index, for events attributed to one device.
    pub fn dev(&self) -> Option<usize> {
        match self {
            TraceEvent::Arrival { dev, .. }
            | TraceEvent::Shed { dev, .. }
            | TraceEvent::Launch { dev, .. }
            | TraceEvent::ServiceDraw { dev, .. }
            | TraceEvent::Served { dev, .. }
            | TraceEvent::Requeue { dev, .. }
            | TraceEvent::PlanSwitch { dev, .. }
            | TraceEvent::PlanApplied { dev, .. }
            | TraceEvent::DeviceWindow { dev, .. } => Some(*dev),
            _ => None,
        }
    }

    /// Re-tag the device index (sweep cells all simulate device 0; the
    /// merged trace re-tags each cell's events to its cell index).
    pub fn set_dev(&mut self, new_dev: usize) {
        match self {
            TraceEvent::Arrival { dev, .. }
            | TraceEvent::Shed { dev, .. }
            | TraceEvent::Launch { dev, .. }
            | TraceEvent::ServiceDraw { dev, .. }
            | TraceEvent::Served { dev, .. }
            | TraceEvent::Requeue { dev, .. }
            | TraceEvent::PlanSwitch { dev, .. }
            | TraceEvent::PlanApplied { dev, .. }
            | TraceEvent::DeviceWindow { dev, .. } => *dev = new_dev,
            _ => {}
        }
    }

    /// True for controller audit events (the old `FleetEvent` vocabulary).
    pub fn is_audit(&self) -> bool {
        matches!(
            self,
            TraceEvent::ScaleOut { .. }
                | TraceEvent::DrainStart { .. }
                | TraceEvent::Retired { .. }
                | TraceEvent::Failed { .. }
                | TraceEvent::SwapReplace { .. }
        )
    }

    /// One audit line. Audit variants keep the exact strings the
    /// controller printed before the unification; the sim-level variants
    /// get the same `at (window): verb detail` shape.
    pub fn describe(&self) -> String {
        match self {
            TraceEvent::ScaleOut { at_s, window, id } => {
                format!("{at_s:.2} s (window {window}): scale-out  + {id}")
            }
            TraceEvent::DrainStart { at_s, window, id, reason } => {
                let r = match reason {
                    DrainReason::ScaleIn => "scale-in",
                    DrainReason::Swap => "front-swap",
                };
                format!("{at_s:.2} s (window {window}): drain      - {id} ({r})")
            }
            TraceEvent::Retired { at_s, window, id } => {
                format!("{at_s:.2} s (window {window}): retired    - {id}")
            }
            TraceEvent::Failed { at_s, window, id, requeued } => {
                format!("{at_s:.2} s (window {window}): FAILED     x {id} ({requeued} requeued)")
            }
            TraceEvent::SwapReplace { at_s, window, old, new } => {
                format!("{at_s:.2} s (window {window}): swapped    {old} -> {new}")
            }
            TraceEvent::SloAlert { at_s, window, fast_burn, slow_burn } => {
                format!(
                    "{at_s:.2} s (window {window}): SLO BURN   fast {fast_burn:.1}x slow {slow_burn:.1}x"
                )
            }
            TraceEvent::PlanSwitch { at_s, window, dev, from, to, draining } => {
                let d = if *draining { " (draining)" } else { "" };
                format!("{at_s:.2} s (window {window}): dev {dev} plan [{from}] -> [{to}]{d}")
            }
            other => format!("{:.6} s: {}", other.at_s(), other.name()),
        }
    }
}
