//! Deterministic observability: structured event tracing, a metrics
//! registry, and SLO burn-rate monitoring over the one event loop.
//!
//! Everything observable in this crate flows through one vocabulary,
//! [`TraceEvent`] ([`event`]): per-request hot-path events emitted by
//! `sim::device::run_timeline`'s core, per-window scheduler events, the
//! autoscaling controller's audit actions (re-exported from
//! `cluster::controller` as `FleetEvent` for backward compatibility),
//! and SLO alerts. The hook is the [`Recorder`] trait ([`recorder`]):
//! the event-loop core is generic over it, and the default
//! [`NoopRecorder`] monomorphizes to nothing — recorder-off runs are
//! bit-identical to pre-observability builds and pay zero cost (guarded
//! by the counting-allocator rows in `benches/simcore.rs`).
//!
//! Analysis is post-hoc replay, never hot-path work: a [`TraceRecorder`]
//! collects events, [`merge_audit`] splices the controller's audit log
//! in at window boundaries, [`annotate_slo`] inserts burn-rate alerts
//! ([`slo`]), and [`MetricsRegistry`] folds the stream into counters,
//! per-window series, Prometheus text, and JSON ([`metrics`]).
//! [`chrome_trace_json`] writes the stream for `chrome://tracing` /
//! Perfetto, and [`trace_tallies`] reconstructs end-of-run tallies from
//! events alone ([`export`]) — pinned equal to the sim reports in
//! `tests/obs_trace.rs`.
//!
//! CLI: `--trace-out` / `--metrics-out` on `ssr simulate` and
//! `ssr cluster simulate|autoscale`; `ssr obs report <trace.json>`
//! summarizes a saved trace.

pub mod event;
pub mod export;
pub mod metrics;
pub mod recorder;
pub mod slo;

pub use event::{DrainReason, TraceEvent};
pub use export::{chrome_trace_json, tallies_from_json, trace_tallies, TraceTallies};
pub use metrics::{
    parse_prometheus, render_prometheus, MetricsRegistry, PromFamily, PromSample, WindowSample,
};
pub use recorder::{merge_audit, NoopRecorder, Recorder, TraceRecorder};
pub use slo::{annotate_slo, SloCfg, SloMonitor};
