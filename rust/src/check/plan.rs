//! Execution-plan pass: forwarding topology, stage coverage, schedule
//! monotonicity, and platform resource budgets over the raw JSON.
//!
//! A serialized plan is a per-image step schedule plus forwarding edges.
//! The invariants checked here are the ones `sim::device`, the scheduler,
//! and the PJRT pipeline all assume:
//!
//! * edges are topological (`from < to`) — acyclicity by construction —
//!   and reference real steps (no dangling endpoints);
//! * the schedule covers the full chain: embed first, head last, and for
//!   every transformer block each layer class exactly once (class
//!   granularity) or attn+mlp exactly once (fused);
//! * every accelerator's step subsequence visits blocks monotonically —
//!   a schedule that revisits an earlier block would deadlock the
//!   forwarding pipeline;
//! * resource budgets against a named board: a monolithic FPGA baseline
//!   cannot host a multi-accelerator spatial plan; on Versal-class boards
//!   the accelerator count is bounded by the AIE array and the forwarded
//!   working set should fit on-chip memory.
//!
//! Codes: `P101` structure, `P102` bad enum/assignment value, `P103`
//! dangling edge endpoint, `P104` non-topological edge, `P105` `cross_acc`
//! flag mismatch, `P106` stage coverage, `P107` accelerator-id domain /
//! density, `P108` schedule monotonicity, `P109` chain ends, `P110`
//! platform budget.

use super::{req_str, req_uint, Diagnostic};
use crate::arch::AnyPlatform;
use crate::util::json::Json;

/// The six per-block layer classes, in chain order (matches
/// `ExecutionPlan::from_depth`).
const BLOCK_UNITS: [&str; 6] = ["qkv", "bmm0", "bmm1", "proj", "fc1", "fc2"];
const FUSED_BLOCK_UNITS: [&str; 2] = ["attn", "mlp"];
const ALL_UNITS: [&str; 10] =
    ["embed", "qkv", "bmm0", "bmm1", "proj", "fc1", "fc2", "head", "attn", "mlp"];

struct Step {
    idx: usize,
    unit: String,
    block: Option<usize>,
    acc: usize,
}

pub fn check(j: &Json, board: Option<&AnyPlatform>, diags: &mut Vec<Diagnostic>) {
    req_str(j, "model", "", "P101", diags);
    let depth = req_uint(j, "depth", "", "P101", diags).filter(|&d| d >= 1).or_else(|| {
        // req_uint reported missing/non-integer; a present zero needs its
        // own message.
        if j.get("depth").and_then(Json::as_usize) == Some(0) {
            diags.push(Diagnostic::error("P101", "/depth", "'depth' must be at least 1"));
        }
        None
    });
    if let Some(mb) = req_uint(j, "micro_batch", "", "P101", diags) {
        if mb == 0 {
            diags.push(Diagnostic::error(
                "P101",
                "/micro_batch",
                "'micro_batch' must be at least 1",
            ));
        }
    }
    let micro_batch = j.get("micro_batch").and_then(Json::as_usize).unwrap_or(1).max(1);
    let granularity = match j.get("granularity").and_then(Json::as_str) {
        Some(g @ ("class" | "fused")) => Some(g),
        Some(g) => {
            diags.push(Diagnostic::error(
                "P102",
                "/granularity",
                format!("unknown granularity '{g}' (known: class, fused)"),
            ));
            None
        }
        None => {
            diags.push(Diagnostic::error(
                "P102",
                "/granularity",
                "missing or non-string 'granularity'",
            ));
            None
        }
    };
    check_assignment(j, diags);
    let nacc = req_uint(j, "nacc", "", "P107", diags).filter(|&n| {
        if !(1..=8).contains(&n) {
            diags.push(Diagnostic::error(
                "P107",
                "/nacc",
                format!("'nacc' is {n}; must be in 1..=8"),
            ));
            return false;
        }
        true
    });

    let Some(steps_json) = j.get("steps").and_then(Json::as_arr) else {
        diags.push(Diagnostic::error("P101", "/steps", "missing or non-array 'steps'"));
        return;
    };
    if steps_json.is_empty() {
        diags.push(Diagnostic::error("P101", "/steps", "plan has no steps"));
        return;
    }

    let mut steps: Vec<Step> = Vec::new();
    for (i, s) in steps_json.iter().enumerate() {
        let base = format!("/steps/{i}");
        let unit = match s.get("unit").and_then(Json::as_str) {
            Some(u) if ALL_UNITS.contains(&u) => {
                let fused_unit = u == "attn" || u == "mlp";
                if let Some(g) = granularity {
                    if fused_unit != (g == "fused") {
                        diags.push(Diagnostic::error(
                            "P102",
                            format!("{base}/unit"),
                            format!("step unit '{u}' contradicts granularity '{g}'"),
                        ));
                    }
                }
                u.to_string()
            }
            Some(u) => {
                diags.push(Diagnostic::error(
                    "P102",
                    format!("{base}/unit"),
                    format!("unknown stage unit '{u}'"),
                ));
                continue;
            }
            None => {
                diags.push(Diagnostic::error(
                    "P102",
                    format!("{base}/unit"),
                    "missing or non-string 'unit'",
                ));
                continue;
            }
        };
        let Some(acc) = req_uint(s, "acc", &base, "P107", diags) else { continue };
        if let Some(n) = nacc {
            if acc >= n {
                diags.push(Diagnostic::error(
                    "P107",
                    format!("{base}/acc"),
                    format!("step runs on acc {acc} but the plan declares nacc {n}"),
                ));
                continue;
            }
        }
        let block = s.get("block").and_then(Json::as_usize);
        steps.push(Step { idx: i, unit, block, acc });
    }

    // Chain ends: the per-image pipeline always starts at embed and
    // finishes at head.
    if let Some(first) = steps.first() {
        if first.unit != "embed" {
            diags.push(Diagnostic::error(
                "P109",
                format!("/steps/{}/unit", first.idx),
                format!("plan must start at 'embed', found '{}'", first.unit),
            ));
        }
    }
    if let Some(last) = steps.last() {
        if last.unit != "head" {
            diags.push(Diagnostic::error(
                "P109",
                format!("/steps/{}/unit", last.idx),
                format!("plan must end at 'head', found '{}'", last.unit),
            ));
        }
    }

    // Accelerator density: declared nacc must be exactly the ids in use.
    if let Some(n) = nacc {
        let mut used = vec![false; n];
        for s in &steps {
            used[s.acc] = true;
        }
        for (a, u) in used.iter().enumerate() {
            if !u {
                diags.push(Diagnostic::error(
                    "P107",
                    "/steps",
                    format!("acc ids not dense: acc {a} of nacc {n} schedules no step"),
                ));
            }
        }
    }

    if let (Some(d), Some(g)) = (depth, granularity) {
        check_coverage(&steps, d, g, diags);
    }
    check_monotonic(&steps, diags);
    check_edges(j, &steps, diags);
    if let Some(b) = board {
        check_budget(j, b, nacc, micro_batch, diags);
    }
}

/// The 8-class assignment: one integer accelerator id in 0..8 per class.
fn check_assignment(j: &Json, diags: &mut Vec<Diagnostic>) {
    let Some(assign) = j.get("assignment").and_then(Json::as_arr) else {
        diags.push(Diagnostic::error("P102", "/assignment", "missing or non-array 'assignment'"));
        return;
    };
    if assign.len() != 8 {
        diags.push(Diagnostic::error(
            "P102",
            "/assignment",
            format!("'assignment' has {} entries; must map all 8 layer classes", assign.len()),
        ));
        return;
    }
    for (k, a) in assign.iter().enumerate() {
        match a.as_f64() {
            Some(v) if v.is_finite() && v.fract() == 0.0 && (0.0..8.0).contains(&v) => {}
            _ => diags.push(Diagnostic::error(
                "P102",
                format!("/assignment/{k}"),
                "accelerator id must be an integer in 0..8",
            )),
        }
    }
}

/// Full stage coverage: every block carries each of its units exactly once.
fn check_coverage(steps: &[Step], depth: usize, granularity: &str, diags: &mut Vec<Diagnostic>) {
    let block_units: &[&str] =
        if granularity == "fused" { &FUSED_BLOCK_UNITS } else { &BLOCK_UNITS };
    for (unit, want) in [("embed", 1usize), ("head", 1)] {
        let n = steps.iter().filter(|s| s.unit == unit).count();
        if n != want {
            diags.push(Diagnostic::error(
                "P106",
                "/steps",
                format!("plan schedules '{unit}' {n} times; expected {want}"),
            ));
        }
    }
    for b in 0..depth {
        for unit in block_units {
            let n = steps.iter().filter(|s| s.unit == *unit && s.block == Some(b)).count();
            if n != 1 {
                let what = if n == 0 { "is missing" } else { "duplicates" };
                diags.push(Diagnostic::error(
                    "P106",
                    "/steps",
                    format!("block {b} {what} its '{unit}' step"),
                ));
            }
        }
    }
    for s in steps {
        if let Some(b) = s.block {
            if b >= depth {
                diags.push(Diagnostic::error(
                    "P106",
                    format!("/steps/{}/block", s.idx),
                    format!("step references block {b} of a depth-{depth} model"),
                ));
            }
        }
    }
}

/// Per-accelerator schedule monotonicity: an acc's step subsequence must
/// visit blocks in non-decreasing order or the forwarding pipeline stalls.
fn check_monotonic(steps: &[Step], diags: &mut Vec<Diagnostic>) {
    let naccs = steps.iter().map(|s| s.acc + 1).max().unwrap_or(0);
    for acc in 0..naccs {
        let mut last: Option<usize> = None;
        for s in steps.iter().filter(|s| s.acc == acc) {
            let Some(b) = s.block else { continue };
            if let Some(prev) = last {
                if b < prev {
                    diags.push(Diagnostic::error(
                        "P108",
                        format!("/steps/{}", s.idx),
                        format!("acc {acc} schedule revisits block {b} after block {prev}"),
                    ));
                }
            }
            last = Some(b);
        }
    }
}

/// Forwarding edges: real endpoints, topological order, honest `cross_acc`.
fn check_edges(j: &Json, steps: &[Step], diags: &mut Vec<Diagnostic>) {
    let Some(edges) = j.get("edges").and_then(Json::as_arr) else {
        diags.push(Diagnostic::error("P101", "/edges", "missing or non-array 'edges'"));
        return;
    };
    let nsteps = j.get("steps").and_then(Json::as_arr).map_or(0, <[Json]>::len);
    // acc by original step index (steps dropped by earlier passes are
    // absent; their edges skip the cross_acc comparison).
    let acc_of = |idx: usize| steps.iter().find(|s| s.idx == idx).map(|s| s.acc);
    for (i, e) in edges.iter().enumerate() {
        let base = format!("/edges/{i}");
        let from = req_uint(e, "from", &base, "P103", diags);
        let to = req_uint(e, "to", &base, "P103", diags);
        let (Some(from), Some(to)) = (from, to) else { continue };
        let mut dangling = false;
        for (end, key) in [(from, "from"), (to, "to")] {
            if end >= nsteps {
                diags.push(Diagnostic::error(
                    "P103",
                    format!("{base}/{key}"),
                    format!("edge {key} references step {end}, but the plan has {nsteps} steps"),
                ));
                dangling = true;
            }
        }
        if dangling {
            continue;
        }
        if from >= to {
            diags.push(Diagnostic::error(
                "P104",
                format!("{base}/to"),
                format!(
                    "edge {from} -> {to} violates topological order (forwarding must flow to a later step)"
                ),
            ));
            continue;
        }
        if let Some(bytes) = e.get("bytes").and_then(Json::as_f64) {
            if !bytes.is_finite() || bytes < 0.0 {
                diags.push(Diagnostic::error(
                    "P103",
                    format!("{base}/bytes"),
                    format!("'bytes' is {bytes}; must be finite and non-negative"),
                ));
            }
        }
        if let (Some(fa), Some(ta), Some(flag)) =
            (acc_of(from), acc_of(to), e.get("cross_acc").and_then(Json::as_bool))
        {
            if flag != (fa != ta) {
                diags.push(Diagnostic::error(
                    "P105",
                    format!("{base}/cross_acc"),
                    format!(
                        "edge {from} -> {to} links acc {fa} to acc {ta} but is flagged cross_acc={flag}"
                    ),
                ));
            }
        }
    }
}

/// Resource budgets against the named board.
fn check_budget(
    j: &Json,
    board: &AnyPlatform,
    nacc: Option<usize>,
    micro_batch: usize,
    diags: &mut Vec<Diagnostic>,
) {
    match board {
        AnyPlatform::Fpga(f) => {
            if let Some(n) = nacc {
                if n > 1 {
                    diags.push(Diagnostic::error(
                        "P110",
                        "/nacc",
                        format!(
                            "monolithic board '{}' runs one sequential engine; it cannot host a {n}-accelerator spatial plan",
                            f.name
                        ),
                    ));
                }
            }
        }
        AnyPlatform::Versal(p) => {
            if let Some(n) = nacc {
                if n as u64 > p.aie_total {
                    diags.push(Diagnostic::error(
                        "P110",
                        "/nacc",
                        format!(
                            "plan wants {n} accelerators but '{}' has {} AIE tiles",
                            p.name, p.aie_total
                        ),
                    ));
                }
            }
            // Forwarded working set vs the AIE array's on-chip memory: a
            // heuristic ceiling (the mapper also uses PL BRAM), so exceeding
            // it is a warning, not an error.
            let on_chip = p.aie_total * p.aie_local_mem;
            if let Some(edges) = j.get("edges").and_then(Json::as_arr) {
                for (i, e) in edges.iter().enumerate() {
                    let cross = e.get("cross_acc").and_then(Json::as_bool).unwrap_or(false);
                    let bytes = e.get("bytes").and_then(Json::as_f64).unwrap_or(0.0);
                    if cross && bytes.is_finite() && bytes >= 0.0 {
                        let working_set = bytes * micro_batch as f64;
                        if working_set > on_chip as f64 {
                            diags.push(Diagnostic::warning(
                                "P110",
                                format!("/edges/{i}/bytes"),
                                format!(
                                    "cross-acc forwarding of {working_set:.0} B (micro-batch {micro_batch}) exceeds '{}' on-chip AIE memory ({on_chip} B)",
                                    p.name
                                ),
                            ));
                        }
                    }
                }
            }
        }
    }
}
