//! Trace-spec pass: curve / process parameter domains over the raw JSON.
//!
//! Mirrors the private `RateCurve::validate` / `ArrivalProcess::validate`
//! domains in [`crate::traffic::trace`], but walks the raw tree so every
//! rejection carries a `json_path` into the file (`/classes/0/curve/
//! rate_rps`), which the typed constructors cannot provide.
//!
//! Codes: `T401` missing/empty classes, `T402` bad model name, `T403`
//! curve structure/kind, `T404` curve parameter domain, `T405` process
//! parameter domain, `T406` (warning) trace offers zero load.
//!
//! Service-model passes (the optional per-class `service` object; an
//! absent key is `Deterministic` and always clean): `S500` missing/unknown
//! kind, `S501` lognormal `sigma` domain, `S502` token-pruning
//! `alpha`/`beta` domain, `S503` early-exit probability element domain,
//! `S504` early-exit probabilities sum above 1, `S505` early-exit stage
//! fraction domain / length mismatch. All numeric passes reject NaN and
//! infinities (via the shared finite-number requirement).

use super::{req_str, Diagnostic};
use crate::util::json::Json;

pub fn check(j: &Json, diags: &mut Vec<Diagnostic>) {
    let Some(classes) = j.get("classes").and_then(Json::as_arr) else {
        diags.push(Diagnostic::error("T401", "/classes", "trace must carry a 'classes' array"));
        return;
    };
    if classes.is_empty() {
        diags.push(Diagnostic::error("T401", "/classes", "trace has no traffic classes"));
        return;
    }
    let mut total_peak = 0.0;
    for (i, c) in classes.iter().enumerate() {
        let base = format!("/classes/{i}");
        req_str(c, "model", &base, "T402", diags);
        match c.get("curve") {
            Some(curve) => {
                if let Some(peak) = check_curve(curve, &format!("{base}/curve"), diags) {
                    total_peak += peak;
                }
            }
            None => diags.push(Diagnostic::error(
                "T403",
                format!("{base}/curve"),
                "class is missing its 'curve' object",
            )),
        }
        match c.get("process") {
            Some(process) => check_process(process, &format!("{base}/process"), diags),
            None => diags.push(Diagnostic::error(
                "T405",
                format!("{base}/process"),
                "class is missing its 'process' object",
            )),
        }
        // `service` is optional: absent means Deterministic (pre-noise
        // artifacts carry no key at all and stay clean by construction).
        if let Some(service) = c.get("service") {
            check_service(service, &format!("{base}/service"), diags);
        }
    }
    if total_peak == 0.0 && !super::has_errors(diags) {
        diags.push(Diagnostic::warning(
            "T406",
            "/classes",
            "trace offers zero load (every class peaks at 0 rps)",
        ));
    }
}

/// Finite and non-negative, the domain of every rate-like parameter.
fn rate(curve: &Json, key: &str, path: &str, diags: &mut Vec<Diagnostic>) -> Option<f64> {
    let v = super::req_num(curve, key, path, "T404", diags)?;
    if v < 0.0 {
        diags.push(Diagnostic::error(
            "T404",
            format!("{path}/{key}"),
            format!("'{key}' is {v}; rates must be finite and non-negative"),
        ));
        return None;
    }
    Some(v)
}

/// Finite and strictly positive, the domain of every duration-like
/// parameter (`duration_s`, `phase_s`, `period_s`, `decay_s`).
fn duration(curve: &Json, key: &str, path: &str, diags: &mut Vec<Diagnostic>) -> Option<f64> {
    let v = super::req_num(curve, key, path, "T404", diags)?;
    if v <= 0.0 {
        diags.push(Diagnostic::error(
            "T404",
            format!("{path}/{key}"),
            format!("'{key}' is {v}; must be finite and positive"),
        ));
        return None;
    }
    Some(v)
}

/// Validate one curve object; returns its peak rate when the parameters
/// parse (used for the zero-load warning).
fn check_curve(curve: &Json, path: &str, diags: &mut Vec<Diagnostic>) -> Option<f64> {
    match curve.get("kind").and_then(Json::as_str) {
        Some("constant") => {
            let r = rate(curve, "rate_rps", path, diags);
            duration(curve, "duration_s", path, diags);
            r
        }
        Some("piecewise") => {
            duration(curve, "phase_s", path, diags);
            let Some(rates) = curve.get("rates_rps").and_then(Json::as_arr) else {
                diags.push(Diagnostic::error(
                    "T404",
                    format!("{path}/rates_rps"),
                    "missing or non-array 'rates_rps'",
                ));
                return None;
            };
            if rates.is_empty() {
                diags.push(Diagnostic::error(
                    "T404",
                    format!("{path}/rates_rps"),
                    "piecewise curve has no phases",
                ));
                return None;
            }
            let mut peak: f64 = 0.0;
            let mut ok = true;
            for (k, r) in rates.iter().enumerate() {
                match r.as_f64() {
                    Some(v) if v.is_finite() && v >= 0.0 => peak = peak.max(v),
                    _ => {
                        ok = false;
                        diags.push(Diagnostic::error(
                            "T404",
                            format!("{path}/rates_rps/{k}"),
                            "phase rate must be a finite non-negative number",
                        ));
                    }
                }
            }
            ok.then_some(peak)
        }
        Some("diurnal") => {
            let b = rate(curve, "base_rps", path, diags);
            let a = rate(curve, "amplitude_rps", path, diags);
            duration(curve, "period_s", path, diags);
            duration(curve, "duration_s", path, diags);
            Some(b? + a?)
        }
        Some("flash") => {
            let b = rate(curve, "base_rps", path, diags);
            let p = rate(curve, "peak_rps", path, diags);
            rate(curve, "at_s", path, diags);
            rate(curve, "ramp_s", path, diags);
            duration(curve, "decay_s", path, diags);
            duration(curve, "duration_s", path, diags);
            Some(b?.max(p?))
        }
        Some(k) => {
            diags.push(Diagnostic::error(
                "T403",
                format!("{path}/kind"),
                format!("unknown curve kind '{k}' (known: constant, piecewise, diurnal, flash)"),
            ));
            None
        }
        None => {
            diags.push(Diagnostic::error(
                "T403",
                format!("{path}/kind"),
                "curve is missing its 'kind'",
            ));
            None
        }
    }
}

/// Validate one service-model object against the same domains as
/// `ServiceModel::validate` in [`crate::sim::service`], with a pointing
/// `json_path` per field.
fn check_service(service: &Json, path: &str, diags: &mut Vec<Diagnostic>) {
    match service.get("kind").and_then(Json::as_str) {
        Some("deterministic") => {}
        Some("lognormal") => {
            if let Some(sigma) = super::req_num(service, "sigma", path, "S501", diags) {
                if sigma <= 0.0 || sigma > 4.0 {
                    diags.push(Diagnostic::error(
                        "S501",
                        format!("{path}/sigma"),
                        format!("lognormal 'sigma' is {sigma}; must be in (0, 4]"),
                    ));
                }
            }
        }
        Some("token-pruning") => {
            for key in ["alpha", "beta"] {
                if let Some(v) = super::req_num(service, key, path, "S502", diags) {
                    if v <= 0.0 {
                        diags.push(Diagnostic::error(
                            "S502",
                            format!("{path}/{key}"),
                            format!("token-pruning '{key}' is {v}; must be finite and positive"),
                        ));
                    }
                }
            }
        }
        Some("early-exit") => check_early_exit(service, path, diags),
        Some(k) => diags.push(Diagnostic::error(
            "S500",
            format!("{path}/kind"),
            format!(
                "unknown service-model kind '{k}' (known: deterministic, lognormal, \
                 token-pruning, early-exit)"
            ),
        )),
        None => diags.push(Diagnostic::error(
            "S500",
            format!("{path}/kind"),
            "service model is missing its 'kind'",
        )),
    }
}

fn check_early_exit(service: &Json, path: &str, diags: &mut Vec<Diagnostic>) {
    let probs = match service.get("exit_probs").and_then(Json::as_arr) {
        Some(a) => a,
        None => {
            diags.push(Diagnostic::error(
                "S503",
                format!("{path}/exit_probs"),
                "missing or non-array 'exit_probs'",
            ));
            return;
        }
    };
    let fracs = match service.get("stage_fractions").and_then(Json::as_arr) {
        Some(a) => a,
        None => {
            diags.push(Diagnostic::error(
                "S505",
                format!("{path}/stage_fractions"),
                "missing or non-array 'stage_fractions'",
            ));
            return;
        }
    };
    if probs.len() != fracs.len() {
        diags.push(Diagnostic::error(
            "S505",
            format!("{path}/stage_fractions"),
            format!("{} exit_probs but {} stage_fractions", probs.len(), fracs.len()),
        ));
    }
    if probs.is_empty() {
        diags.push(Diagnostic::error(
            "S503",
            format!("{path}/exit_probs"),
            "early-exit needs at least one stage",
        ));
        return;
    }
    let mut sum = 0.0;
    let mut all_ok = true;
    for (k, p) in probs.iter().enumerate() {
        match p.as_f64() {
            Some(v) if v.is_finite() && (0.0..=1.0).contains(&v) => sum += v,
            _ => {
                all_ok = false;
                diags.push(Diagnostic::error(
                    "S503",
                    format!("{path}/exit_probs/{k}"),
                    "exit probability must be a finite number in [0, 1]",
                ));
            }
        }
    }
    if all_ok && sum > 1.0 {
        diags.push(Diagnostic::error(
            "S504",
            format!("{path}/exit_probs"),
            format!("exit probabilities sum to {sum} > 1"),
        ));
    }
    for (k, f) in fracs.iter().enumerate() {
        match f.as_f64() {
            Some(v) if v.is_finite() && v > 0.0 && v <= 1.0 => {}
            _ => diags.push(Diagnostic::error(
                "S505",
                format!("{path}/stage_fractions/{k}"),
                "stage fraction must be a finite number in (0, 1]",
            )),
        }
    }
}

fn check_process(process: &Json, path: &str, diags: &mut Vec<Diagnostic>) {
    match process.get("kind").and_then(Json::as_str) {
        Some("poisson") => {}
        Some("lognormal") => {
            if let Some(sigma) = super::req_num(process, "sigma", path, "T405", diags) {
                if sigma <= 0.0 {
                    diags.push(Diagnostic::error(
                        "T405",
                        format!("{path}/sigma"),
                        format!("lognormal 'sigma' is {sigma}; must be positive"),
                    ));
                }
            }
        }
        Some("pareto") => {
            if let Some(alpha) = super::req_num(process, "alpha", path, "T405", diags) {
                if alpha <= 1.0 {
                    diags.push(Diagnostic::error(
                        "T405",
                        format!("{path}/alpha"),
                        format!("pareto 'alpha' is {alpha}; must exceed 1 for a finite mean"),
                    ));
                }
            }
        }
        Some(k) => diags.push(Diagnostic::error(
            "T405",
            format!("{path}/kind"),
            format!("unknown process kind '{k}' (known: poisson, lognormal, pareto)"),
        )),
        None => diags.push(Diagnostic::error(
            "T405",
            format!("{path}/kind"),
            "process is missing its 'kind'",
        )),
    }
}
