//! Plan-front pass: metric domains, ordering, and Pareto consistency.
//!
//! Dominance is computed on `(latency_ms, rps)` — exactly the projection
//! [`FrontEntry::point`](crate::plan::front::FrontEntry) feeds to
//! [`crate::dse::pareto`], where a front is pruned on *delivered* rps, not
//! raw TOPS. A serialized front must already be pruned: latency sorted
//! ascending, rps strictly increasing, no duplicate metric pairs.
//!
//! `prefix` scopes the paths when a front is nested inside a fleet
//! (`/devices/2/front/entries/0/...`); it is empty for a standalone file.
//!
//! Codes: `F201` structure, `F202` metric domain (NaN/negative), `F203`
//! malformed assignment, `F204` dominated entry, `F205` not latency-sorted,
//! `F206` (warning) duplicate metrics with differing provenance, `F207`
//! claimed TOPS exceeds the platform peak, `F208` (warning) `nacc`
//! disagrees with the assignment.

use super::{req_str, req_uint, Diagnostic};
use crate::arch::AnyPlatform;
use crate::util::json::Json;

/// Metrics of one entry that survived domain checks, kept for the
/// cross-entry Pareto passes.
struct EntryMetrics {
    idx: usize,
    latency_ms: f64,
    rps: f64,
    label: String,
}

pub fn check(j: &Json, prefix: &str, board: Option<&AnyPlatform>, diags: &mut Vec<Diagnostic>) {
    req_str(j, "model", prefix, "F201", diags);
    if let Some(depth) = req_uint(j, "depth", prefix, "F201", diags) {
        if depth == 0 {
            diags.push(Diagnostic::error(
                "F201",
                format!("{prefix}/depth"),
                "'depth' must be at least 1",
            ));
        }
    }
    let entries_path = format!("{prefix}/entries");
    let Some(entries) = j.get("entries").and_then(Json::as_arr) else {
        diags.push(Diagnostic::error("F201", entries_path, "missing or non-array 'entries'"));
        return;
    };
    if entries.is_empty() {
        diags.push(Diagnostic::error("F201", entries_path, "front has no entries"));
        return;
    }

    let mut metrics: Vec<EntryMetrics> = Vec::new();
    for (i, e) in entries.iter().enumerate() {
        let base = format!("{entries_path}/{i}");
        if e.as_obj().is_none() {
            diags.push(Diagnostic::error("F201", base, "entry must be an object"));
            continue;
        }
        let nacc_of_assign = check_assign(e, &base, diags);
        if let Some(batch) = req_uint(e, "batch", &base, "F202", diags) {
            if batch == 0 {
                diags.push(Diagnostic::error(
                    "F202",
                    format!("{base}/batch"),
                    "'batch' must be at least 1",
                ));
            }
        }
        let lat = check_metric(e, "latency_ms", &base, diags);
        let rps = check_metric(e, "rps", &base, diags);
        // Optional fields: `from_json` defaults tops → 0, nacc → 1,
        // label → "plan"; only validate them when present.
        if let Some(tops) = e.get("tops").and_then(Json::as_f64) {
            if !tops.is_finite() || tops < 0.0 {
                diags.push(Diagnostic::error(
                    "F202",
                    format!("{base}/tops"),
                    format!("'tops' is {tops}; must be finite and non-negative"),
                ));
            } else if let Some(b) = board {
                // Relative slack absorbs the round-trip through decimal
                // JSON floats; a real budget violation is far larger.
                if tops > b.peak_int8_tops() * (1.0 + 1e-6) {
                    diags.push(Diagnostic::error(
                        "F207",
                        format!("{base}/tops"),
                        format!(
                            "claimed {tops:.2} TOPS exceeds {} peak {:.2} INT8 TOPS",
                            b.name(),
                            b.peak_int8_tops()
                        ),
                    ));
                }
            }
        }
        if let Some(nacc) = e.get("nacc").and_then(Json::as_f64) {
            if nacc.fract() != 0.0 || !(1.0..=8.0).contains(&nacc) {
                diags.push(Diagnostic::error(
                    "F202",
                    format!("{base}/nacc"),
                    format!("'nacc' is {nacc}; must be an integer in 1..=8"),
                ));
            } else if let Some(expect) = nacc_of_assign {
                if nacc as usize != expect {
                    diags.push(Diagnostic::warning(
                        "F208",
                        format!("{base}/nacc"),
                        format!("'nacc' is {nacc} but the assignment uses {expect} accelerators"),
                    ));
                }
            }
        }
        let label = e
            .get("label")
            .and_then(Json::as_str)
            .unwrap_or("plan")
            .to_string();
        if let (Some(latency_ms), Some(rps)) = (lat, rps) {
            metrics.push(EntryMetrics { idx: i, latency_ms, rps, label });
        }
    }

    // Ordering: a serialized front is latency-ascending by construction
    // (pareto_indices sorts before emit).
    for w in metrics.windows(2) {
        if w[1].latency_ms < w[0].latency_ms {
            diags.push(Diagnostic::error(
                "F205",
                format!("{entries_path}/{}/latency_ms", w[1].idx),
                format!(
                    "front is not sorted by latency: entry {} ({:.3} ms) follows entry {} ({:.3} ms)",
                    w[1].idx, w[1].latency_ms, w[0].idx, w[0].latency_ms
                ),
            ));
        }
    }

    // Pareto consistency: pairwise dominance on (latency, rps); exact
    // duplicates are a provenance warning (the pruner dedups them, so a
    // generated front never carries two).
    for a in &metrics {
        for b in &metrics {
            if a.idx == b.idx {
                continue;
            }
            let dominates = b.latency_ms <= a.latency_ms
                && b.rps >= a.rps
                && (b.latency_ms < a.latency_ms || b.rps > a.rps);
            if dominates {
                diags.push(Diagnostic::error(
                    "F204",
                    format!("{entries_path}/{}", a.idx),
                    format!(
                        "entry {} ('{}') is dominated by entry {} ('{}'): {:.3} ms / {:.0} rps vs {:.3} ms / {:.0} rps",
                        a.idx, a.label, b.idx, b.label, a.latency_ms, a.rps, b.latency_ms, b.rps
                    ),
                ));
            } else if a.idx < b.idx
                && a.latency_ms.to_bits() == b.latency_ms.to_bits()
                && a.rps.to_bits() == b.rps.to_bits()
            {
                diags.push(Diagnostic::warning(
                    "F206",
                    format!("{entries_path}/{}", b.idx),
                    format!(
                        "entry {} duplicates the metrics of entry {} under a different provenance ('{}' vs '{}')",
                        b.idx, a.idx, b.label, a.label
                    ),
                ));
            }
        }
    }
}

/// `latency_ms` / `rps`: finite and strictly positive.
fn check_metric(e: &Json, key: &str, base: &str, diags: &mut Vec<Diagnostic>) -> Option<f64> {
    let v = super::req_num(e, key, base, "F202", diags)?;
    if v <= 0.0 {
        diags.push(Diagnostic::error(
            "F202",
            format!("{base}/{key}"),
            format!("'{key}' is {v}; must be finite and positive"),
        ));
        return None;
    }
    Some(v)
}

/// Validate the 8-class accelerator assignment; returns `max(acc)+1` (the
/// accelerator count it implies) when well-formed.
fn check_assign(e: &Json, base: &str, diags: &mut Vec<Diagnostic>) -> Option<usize> {
    let path = format!("{base}/assign");
    let Some(assign) = e.get("assign").and_then(Json::as_arr) else {
        diags.push(Diagnostic::error("F203", path, "missing or non-array 'assign'"));
        return None;
    };
    if assign.len() != 8 {
        diags.push(Diagnostic::error(
            "F203",
            path,
            format!("'assign' has {} entries; must map all 8 layer classes", assign.len()),
        ));
        return None;
    }
    let mut max_acc = 0usize;
    for (k, a) in assign.iter().enumerate() {
        match a.as_f64() {
            Some(v) if v.is_finite() && v.fract() == 0.0 && (0.0..8.0).contains(&v) => {
                max_acc = max_acc.max(v as usize);
            }
            _ => {
                diags.push(Diagnostic::error(
                    "F203",
                    format!("{path}/{k}"),
                    "accelerator id must be an integer in 0..8",
                ));
                return None;
            }
        }
    }
    Some(max_acc + 1)
}
