//! Static verification for serialized SSR artifacts.
//!
//! Every artifact the CLI exchanges as JSON — [`PlanFront`], [`FleetSpec`],
//! [`TraceSpec`], [`ExecutionPlan`] — can be verified *before* its typed
//! `from_json` runs, by a pass-based analyzer over the raw [`Json`] tree.
//! Working on the raw tree (rather than the typed value) is what lets every
//! diagnostic carry a `json_path` pointing at the offending field — a typed
//! constructor rejects the file before any field-level location exists.
//!
//! The passes mirror (and extend) the invariants the typed constructors
//! enforce:
//!
//! * [`plan`] — forwarding-edge topology (acyclicity as `from < to`),
//!   dangling step references, full stage coverage across the 8 layer
//!   classes per block, per-accelerator schedule monotonicity, and resource
//!   budgets against a named [`arch`](crate::arch) platform.
//! * [`front`] — per-entry metric domains (no NaN / negative latency or
//!   rps), latency-sorted order, Pareto consistency (no dominated entries,
//!   dominance on `(latency_ms, rps)` exactly as
//!   [`FrontEntry::point`](crate::plan::front::FrontEntry) maps it),
//!   duplicate-metric provenance, and claimed TOPS vs platform peak.
//! * [`fleet`] — known board names, unique device ids, nested front checks
//!   per device, and model coverage against an optional trace.
//! * [`trace`] — curve/process parameter domains (finite non-negative
//!   rates, positive durations, lognormal `sigma > 0`, Pareto `alpha > 1`)
//!   plus the optional per-class service-time model (kind, sigma /
//!   keep-ratio / exit-probability domains, probabilities summing to at
//!   most 1, NaN rejection everywhere).
//!
//! Diagnostic codes are stable and grouped by family: `E0xx` structural,
//! `P1xx` plan, `F2xx` front, `C3xx` fleet, `T4xx` trace, `S5xx`
//! service model (see ARCHITECTURE.md § Static verification for the full
//! table).
//!
//! The CLI exposes the analyzer as `ssr check <artifact.json>` and every
//! artifact-load boundary in `main.rs` routes through the `load_*` helpers
//! here, so a corrupt file fails at load with a pointing diagnostic instead
//! of a panic deep in `sim::device`.

pub mod fleet;
pub mod front;
pub mod plan;
pub mod trace;

use std::path::Path;

use crate::cluster::fleet::FleetSpec;
use crate::plan::front::PlanFront;
use crate::plan::ExecutionPlan;
use crate::traffic::trace::TraceSpec;
use crate::util::json::Json;

/// How bad a finding is. `Error` fails the check (nonzero exit, load
/// refused); `Warning` is advisory unless `--strict` promotes it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One finding: a stable code, a JSON-Pointer-style path into the artifact
/// (`/entries/3/latency_ms`), and a human message. Rendered as text or JSON.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub severity: Severity,
    pub code: &'static str,
    pub json_path: String,
    pub message: String,
}

impl Diagnostic {
    pub fn error(code: &'static str, path: impl Into<String>, msg: impl Into<String>) -> Self {
        Diagnostic { severity: Severity::Error, code, json_path: path.into(), message: msg.into() }
    }

    pub fn warning(code: &'static str, path: impl Into<String>, msg: impl Into<String>) -> Self {
        Diagnostic {
            severity: Severity::Warning,
            code,
            json_path: path.into(),
            message: msg.into(),
        }
    }

    /// One text line: `error[F202] front.json /entries/1/latency_ms: ...`.
    pub fn render(&self, source: &str) -> String {
        let path = if self.json_path.is_empty() { "/" } else { self.json_path.as_str() };
        format!("{}[{}] {} {}: {}", self.severity.name(), self.code, source, path, self.message)
    }
}

/// Which artifact a JSON tree is, keyed on its distinguishing top-level
/// field (`steps` → plan, `entries` → front, `devices` → fleet, `classes`
/// → trace).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    Plan,
    Front,
    Fleet,
    Trace,
}

impl ArtifactKind {
    pub fn name(self) -> &'static str {
        match self {
            ArtifactKind::Plan => "execution-plan",
            ArtifactKind::Front => "plan-front",
            ArtifactKind::Fleet => "fleet-spec",
            ArtifactKind::Trace => "trace-spec",
        }
    }
}

/// Sniff the artifact kind from top-level object keys. `None` means the
/// tree is not a recognized SSR artifact.
pub fn detect(j: &Json) -> Option<ArtifactKind> {
    let o = j.as_obj()?;
    if o.contains_key("steps") {
        Some(ArtifactKind::Plan)
    } else if o.contains_key("entries") {
        Some(ArtifactKind::Front)
    } else if o.contains_key("devices") {
        Some(ArtifactKind::Fleet)
    } else if o.contains_key("classes") {
        Some(ArtifactKind::Trace)
    } else {
        None
    }
}

/// Cross-artifact context for a check run: a platform name for resource
/// budgets (plan / standalone front) and a trace for fleet model coverage.
#[derive(Default)]
pub struct CheckOpts<'a> {
    pub arch: Option<&'a str>,
    pub trace: Option<&'a Json>,
}

/// Run every pass that applies to `kind` and return the findings. Errors
/// never panic — a malformed tree yields diagnostics, not unwraps.
pub fn check_artifact(j: &Json, kind: ArtifactKind, opts: &CheckOpts) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let board = match opts.arch {
        None => None,
        Some(name) => match crate::arch::by_name(name) {
            Some(b) => Some(b),
            None => {
                diags.push(Diagnostic::error(
                    "E002",
                    "",
                    format!(
                        "unknown platform '{name}' (known: {})",
                        crate::arch::KNOWN_BOARDS.join(", ")
                    ),
                ));
                None
            }
        },
    };
    match kind {
        ArtifactKind::Plan => plan::check(j, board.as_ref(), &mut diags),
        ArtifactKind::Front => front::check(j, "", board.as_ref(), &mut diags),
        ArtifactKind::Fleet => fleet::check(j, opts.trace, &mut diags),
        ArtifactKind::Trace => trace::check(j, &mut diags),
    }
    diags
}

pub fn has_errors(diags: &[Diagnostic]) -> bool {
    diags.iter().any(|d| d.severity == Severity::Error)
}

/// Render all findings as text lines, one per diagnostic, errors first
/// (stable within each severity — pass order is deterministic).
pub fn render_text(diags: &[Diagnostic], source: &str) -> String {
    let mut ordered: Vec<&Diagnostic> = diags.iter().collect();
    ordered.sort_by(|a, b| b.severity.cmp(&a.severity));
    ordered.iter().map(|d| d.render(source)).collect::<Vec<_>>().join("\n")
}

/// Render findings as a JSON array of `{severity, code, json_path,
/// message}` objects (machine-readable `--json` output).
pub fn render_json(diags: &[Diagnostic]) -> Json {
    Json::Arr(
        diags
            .iter()
            .map(|d| {
                let mut o = std::collections::BTreeMap::new();
                o.insert("severity".into(), Json::Str(d.severity.name().into()));
                o.insert("code".into(), Json::Str(d.code.into()));
                o.insert("json_path".into(), Json::Str(d.json_path.clone()));
                o.insert("message".into(), Json::Str(d.message.clone()));
                Json::Obj(o)
            })
            .collect(),
    )
}

/// Read and parse a JSON file, prefixing any I/O or syntax error with the
/// path so the CLI can print it verbatim.
pub fn load_json(path: &Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: cannot read: {e}", path.display()))?;
    Json::parse(&text).map_err(|e| format!("{}: invalid JSON: {e}", path.display()))
}

/// Load + kind-check + verify; the common front half of every `load_*`.
fn load_checked(path: &Path, want: ArtifactKind) -> Result<Json, String> {
    let j = load_json(path)?;
    let kind = detect(&j).ok_or_else(|| {
        format!(
            "{}: not a recognized SSR artifact (expected a {} file)",
            path.display(),
            want.name()
        )
    })?;
    if kind != want {
        return Err(format!(
            "{}: this is a {} artifact, expected a {}",
            path.display(),
            kind.name(),
            want.name()
        ));
    }
    let diags = check_artifact(&j, kind, &CheckOpts::default());
    if has_errors(&diags) {
        let source = path.display().to_string();
        let errors: Vec<String> = diags
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .map(|d| d.render(&source))
            .collect();
        return Err(format!(
            "{}\n{} failed verification ({} error{}); run `ssr check {}` for the full report",
            errors.join("\n"),
            source,
            errors.len(),
            if errors.len() == 1 { "" } else { "s" },
            source,
        ));
    }
    Ok(j)
}

/// Verified load of a [`PlanFront`]: parse, run the front passes, then the
/// typed `from_json`. Used by every `--front` CLI boundary.
pub fn load_front(path: &Path) -> Result<PlanFront, String> {
    let j = load_checked(path, ArtifactKind::Front)?;
    PlanFront::from_json(&j).map_err(|e| format!("{}: {e}", path.display()))
}

/// Verified load of a [`FleetSpec`] (per-device fronts checked against
/// their board's budget). Used by every `--fleet` CLI boundary.
pub fn load_fleet(path: &Path) -> Result<FleetSpec, String> {
    let j = load_checked(path, ArtifactKind::Fleet)?;
    FleetSpec::from_json(&j).map_err(|e| format!("{}: {e}", path.display()))
}

/// Verified load of a [`TraceSpec`]. Used by every `--trace` CLI boundary.
pub fn load_trace(path: &Path) -> Result<TraceSpec, String> {
    let j = load_checked(path, ArtifactKind::Trace)?;
    TraceSpec::from_json(&j).map_err(|e| format!("{}: {e}", path.display()))
}

/// Verified load of an [`ExecutionPlan`].
pub fn load_plan(path: &Path) -> Result<ExecutionPlan, String> {
    let j = load_checked(path, ArtifactKind::Plan)?;
    ExecutionPlan::from_json(&j).map_err(|e| format!("{}: {e}", path.display()))
}

/// Require `key` to be a finite number; missing / wrong-type / non-finite
/// pushes an error with `code` at `{path}/{key}` and returns `None`.
pub(crate) fn req_num(
    j: &Json,
    key: &str,
    path: &str,
    code: &'static str,
    diags: &mut Vec<Diagnostic>,
) -> Option<f64> {
    match j.get(key).and_then(Json::as_f64) {
        Some(v) if v.is_finite() => Some(v),
        Some(v) => {
            diags.push(Diagnostic::error(
                code,
                format!("{path}/{key}"),
                format!("'{key}' is {v}; must be finite"),
            ));
            None
        }
        None => {
            diags.push(Diagnostic::error(
                code,
                format!("{path}/{key}"),
                format!("missing or non-numeric '{key}'"),
            ));
            None
        }
    }
}

/// Require `key` to be a non-negative integer (JSON numbers with zero
/// fractional part). Same error convention as [`req_num`].
pub(crate) fn req_uint(
    j: &Json,
    key: &str,
    path: &str,
    code: &'static str,
    diags: &mut Vec<Diagnostic>,
) -> Option<usize> {
    match j.get(key).and_then(Json::as_f64) {
        Some(v) if v.is_finite() && v.fract() == 0.0 && v >= 0.0 => Some(v as usize),
        Some(v) => {
            diags.push(Diagnostic::error(
                code,
                format!("{path}/{key}"),
                format!("'{key}' is {v}; must be a non-negative integer"),
            ));
            None
        }
        None => {
            diags.push(Diagnostic::error(
                code,
                format!("{path}/{key}"),
                format!("missing or non-numeric '{key}'"),
            ));
            None
        }
    }
}

/// Require `key` to be a non-empty string.
pub(crate) fn req_str<'j>(
    j: &'j Json,
    key: &str,
    path: &str,
    code: &'static str,
    diags: &mut Vec<Diagnostic>,
) -> Option<&'j str> {
    match j.get(key).and_then(Json::as_str) {
        Some(s) if !s.is_empty() => Some(s),
        Some(_) => {
            diags.push(Diagnostic::error(
                code,
                format!("{path}/{key}"),
                format!("'{key}' must be a non-empty string"),
            ));
            None
        }
        None => {
            diags.push(Diagnostic::error(
                code,
                format!("{path}/{key}"),
                format!("missing or non-string '{key}'"),
            ));
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detect_sniffs_every_kind_and_rejects_unknown() {
        let plan = Json::parse(r#"{"steps": [], "edges": []}"#).unwrap();
        let front = Json::parse(r#"{"entries": []}"#).unwrap();
        let fleet = Json::parse(r#"{"devices": []}"#).unwrap();
        let trace = Json::parse(r#"{"classes": []}"#).unwrap();
        assert_eq!(detect(&plan), Some(ArtifactKind::Plan));
        assert_eq!(detect(&front), Some(ArtifactKind::Front));
        assert_eq!(detect(&fleet), Some(ArtifactKind::Fleet));
        assert_eq!(detect(&trace), Some(ArtifactKind::Trace));
        assert_eq!(detect(&Json::parse(r#"{"foo": 1}"#).unwrap()), None);
        assert_eq!(detect(&Json::parse("[1,2]").unwrap()), None);
    }

    #[test]
    fn unknown_arch_name_is_a_structural_error() {
        let front = Json::parse(r#"{"model":"m","depth":1,"entries":[]}"#).unwrap();
        let opts = CheckOpts { arch: Some("tpu_v9"), trace: None };
        let diags = check_artifact(&front, ArtifactKind::Front, &opts);
        assert!(diags.iter().any(|d| d.code == "E002" && d.message.contains("tpu_v9")));
    }

    #[test]
    fn render_is_stable_and_points() {
        let d = Diagnostic::error("F202", "/entries/1/latency_ms", "latency_ms is NaN");
        assert_eq!(
            d.render("front.json"),
            "error[F202] front.json /entries/1/latency_ms: latency_ms is NaN"
        );
        let j = render_json(&[d]);
        let arr = j.as_arr().unwrap();
        assert_eq!(arr[0].get("code").unwrap().as_str().unwrap(), "F202");
        assert_eq!(arr[0].get("json_path").unwrap().as_str().unwrap(), "/entries/1/latency_ms");
    }
}
