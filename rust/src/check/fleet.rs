//! Fleet-spec pass: board names, device identity, nested fronts, and model
//! coverage against an optional trace.
//!
//! Each device's front is checked by [`super::front`] with the path prefix
//! `/devices/{i}/front` and that device's board, so a per-device budget
//! violation points into the right front of the right device.
//!
//! Codes: `C301` structure, `C302` duplicate device id, `C303` unknown
//! platform, `C305` a trace model no device serves. Nested front findings
//! keep their `F2xx` codes.

use super::{req_str, Diagnostic};
use crate::util::json::Json;

pub fn check(j: &Json, trace: Option<&Json>, diags: &mut Vec<Diagnostic>) {
    req_str(j, "name", "", "C301", diags);
    let Some(devices) = j.get("devices").and_then(Json::as_arr) else {
        diags.push(Diagnostic::error("C301", "/devices", "missing or non-array 'devices'"));
        return;
    };
    if devices.is_empty() {
        diags.push(Diagnostic::error("C301", "/devices", "fleet has no devices"));
        return;
    }

    let mut seen_ids: Vec<&str> = Vec::new();
    let mut served: Vec<String> = Vec::new();
    for (i, d) in devices.iter().enumerate() {
        let base = format!("/devices/{i}");
        if d.as_obj().is_none() {
            diags.push(Diagnostic::error("C301", base, "device must be an object"));
            continue;
        }
        if let Some(id) = req_str(d, "id", &base, "C301", diags) {
            if seen_ids.contains(&id) {
                diags.push(Diagnostic::error(
                    "C302",
                    format!("{base}/id"),
                    format!("duplicate device id '{id}'"),
                ));
            } else {
                seen_ids.push(id);
            }
        }
        let board = match req_str(d, "platform", &base, "C301", diags) {
            Some(name) => match crate::arch::by_name(name) {
                Some(b) => Some(b),
                None => {
                    diags.push(Diagnostic::error(
                        "C303",
                        format!("{base}/platform"),
                        format!(
                            "unknown platform '{name}' (known: {})",
                            crate::arch::KNOWN_BOARDS.join(", ")
                        ),
                    ));
                    None
                }
            },
            None => None,
        };
        match d.get("front") {
            Some(front) => {
                super::front::check(front, &format!("{base}/front"), board.as_ref(), diags);
                if let Some(model) = front.get("model").and_then(Json::as_str) {
                    if !served.iter().any(|m| m == model) {
                        served.push(model.to_string());
                    }
                }
            }
            None => diags.push(Diagnostic::error(
                "C301",
                format!("{base}/front"),
                "device is missing its 'front'",
            )),
        }
    }

    // Model coverage: every model the trace offers must have at least one
    // device whose front serves it, or that traffic is unroutable.
    if let Some(t) = trace {
        if let Some(classes) = t.get("classes").and_then(Json::as_arr) {
            for (ci, c) in classes.iter().enumerate() {
                if let Some(model) = c.get("model").and_then(Json::as_str) {
                    if !model.is_empty() && !served.iter().any(|m| m == model) {
                        diags.push(Diagnostic::error(
                            "C305",
                            "/devices",
                            format!(
                                "no device serves model '{model}' required by trace class {ci}"
                            ),
                        ));
                    }
                }
            }
        }
    }
}
