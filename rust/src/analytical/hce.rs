//! HCE (PL-side nonlinear/elementwise engine) timing — paper Fig. 7.
//!
//! Elementwise ops (Transpose/Reformat/Add, reuse distance 1) fuse into the
//! HMM stream for free when the fine-grained pipeline is on. Reduction ops
//! (Softmax/LayerNorm, reuse distance > 1) serialize into multiple passes
//! unless the bypass line buffer overlaps the mu/sigma stages, which
//! "reduces its latency to nearly half" (Sec. 4.3).

use super::calib::Calib;
use crate::arch::Platform;
use crate::graph::HceOp;
#[cfg(test)]
use crate::graph::HceKind;

/// Time (seconds) for one HCE op on `lanes` parallel PL lanes.
pub fn hce_op_time(
    platform: &Platform,
    calib: &Calib,
    op: &HceOp,
    lanes: u64,
    pipelined: bool,
) -> f64 {
    let lanes = lanes.max(1) as f64;
    let passes = if op.kind.is_reduction() {
        if pipelined {
            calib.reduction_pipelined_passes
        } else {
            calib.reduction_naive_passes
        }
    } else {
        1.0
    };
    let cycles = op.elems as f64 * passes / (lanes * calib.hce_elems_per_lane_cycle);
    cycles / (platform.pl_mhz * 1e6)
}

/// Total HCE time for a node's attached ops.
pub fn hce_total(
    platform: &Platform,
    calib: &Calib,
    ops: &[HceOp],
    lanes: u64,
    pipelined: bool,
) -> f64 {
    ops.iter()
        .map(|op| hce_op_time(platform, calib, op, lanes, pipelined))
        .sum()
}

/// Exposed (non-overlapped) HCE seconds given the co-resident MM time.
///
/// With the fine-grained pipeline the HCE engine consumes the HMM output
/// stream as it is produced, so only the excess beyond the MM time is
/// exposed; without it the HCE time fully serializes after the MM
/// (Fig. 7c vs 7d). Elementwise ops additionally vanish entirely when
/// pipelined (they fuse into the stream).
pub fn exposed_hce(
    platform: &Platform,
    calib: &Calib,
    ops: &[HceOp],
    lanes: u64,
    mm_seconds: f64,
    fine_grained_pipeline: bool,
) -> f64 {
    if !fine_grained_pipeline {
        return hce_total(platform, calib, ops, lanes, false);
    }
    // Pipelined: elementwise ops fuse (zero exposed); reductions overlap
    // with the MM, exposing only their tail.
    let reduction_time: f64 = ops
        .iter()
        .filter(|op| op.kind.is_reduction())
        .map(|op| hce_op_time(platform, calib, op, lanes, true))
        .sum();
    (reduction_time - mm_seconds).max(0.0)
}

/// DSP cost of provisioning `lanes` HCE lanes (feeds Eq. 1's DSP_util).
pub fn hce_dsp(calib: &Calib, lanes: u64) -> u64 {
    (lanes as f64 * calib.dsp_per_lane).ceil() as u64
}

/// Lanes affordable with a DSP budget.
pub fn lanes_for_dsp(calib: &Calib, dsp_budget: u64) -> u64 {
    ((dsp_budget as f64) / calib.dsp_per_lane).floor().max(1.0) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck190;

    fn op(kind: HceKind, elems: u64) -> HceOp {
        HceOp { kind, elems }
    }

    #[test]
    fn pipeline_halves_reduction_latency() {
        let p = vck190();
        let c = Calib::default();
        let sm = op(HceKind::Softmax, 197 * 197);
        let naive = hce_op_time(&p, &c, &sm, 64, false);
        let piped = hce_op_time(&p, &c, &sm, 64, true);
        let ratio = naive / piped;
        // "reduces its latency to nearly half"
        assert!(ratio > 1.7 && ratio < 2.1, "ratio={ratio}");
    }

    #[test]
    fn elementwise_unaffected_by_pipeline_flag() {
        let p = vck190();
        let c = Calib::default();
        let tp = op(HceKind::Transpose, 10_000);
        assert_eq!(
            hce_op_time(&p, &c, &tp, 32, false),
            hce_op_time(&p, &c, &tp, 32, true)
        );
    }

    #[test]
    fn exposed_zero_when_mm_dominates() {
        let p = vck190();
        let c = Calib::default();
        let ops = [op(HceKind::LayerNorm, 1000), op(HceKind::Add, 1000)];
        let exposed = exposed_hce(&p, &c, &ops, 64, 1.0 /* 1s of MM */, true);
        assert_eq!(exposed, 0.0);
    }

    #[test]
    fn unpipelined_serializes_everything() {
        let p = vck190();
        let c = Calib::default();
        let ops = [op(HceKind::LayerNorm, 4096), op(HceKind::Add, 4096)];
        let exposed = exposed_hce(&p, &c, &ops, 8, 1.0, false);
        let total = hce_total(&p, &c, &ops, 8, false);
        assert_eq!(exposed, total);
        assert!(exposed > 0.0);
    }

    #[test]
    fn more_lanes_faster() {
        let p = vck190();
        let c = Calib::default();
        let sm = op(HceKind::Softmax, 100_000);
        assert!(
            hce_op_time(&p, &c, &sm, 128, true) < hce_op_time(&p, &c, &sm, 16, true)
        );
    }

    #[test]
    fn dsp_lane_roundtrip() {
        let c = Calib::default();
        let lanes = lanes_for_dsp(&c, 1024);
        assert!(hce_dsp(&c, lanes) <= 1024);
        assert!(hce_dsp(&c, lanes + 1) > 1024);
    }
}
