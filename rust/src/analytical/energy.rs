//! Power / energy-efficiency model (Table 5's GOPS/W columns).
//!
//! Simple utilization-scaled model: P = static + dyn * utilization, where
//! utilization = achieved_tops / peak_tops. This is the standard
//! DSE-time surrogate for board power telemetry (the paper measured via
//! AMD BEAM); constants are calibrated so DeiT-T b6 lands near the paper's
//! 453 GOPS/W at 26.7 TOPS.

use crate::arch::Platform;

/// Watts drawn at a given achieved throughput.
pub fn power_w(platform: &Platform, achieved_tops: f64) -> f64 {
    let util = (achieved_tops / platform.peak_int8_tops()).clamp(0.0, 1.0);
    platform.static_w + platform.dyn_w * util
}

/// Energy efficiency in GOPS/W.
pub fn gops_per_w(platform: &Platform, achieved_tops: f64) -> f64 {
    achieved_tops * 1e3 / power_w(platform, achieved_tops)
}

/// Same model for GPU/FPGA baselines expressed as (static, dyn, peak):
/// watts at a given achieved throughput. The fleet provisioner sums this
/// across heterogeneous devices, so it must agree with [`power_w`] for
/// Versal platforms (it does: `power_w` is this with the platform's
/// constants plugged in).
pub fn power_w_generic(static_w: f64, dyn_w: f64, peak_tops: f64, achieved_tops: f64) -> f64 {
    let util = (achieved_tops / peak_tops).clamp(0.0, 1.0);
    static_w + dyn_w * util
}

/// Energy efficiency of the generic model, in GOPS/W.
pub fn gops_per_w_generic(static_w: f64, dyn_w: f64, peak_tops: f64, achieved_tops: f64) -> f64 {
    achieved_tops * 1e3 / power_w_generic(static_w, dyn_w, peak_tops, achieved_tops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck190;

    #[test]
    fn idle_power_is_static() {
        let p = vck190();
        assert_eq!(power_w(&p, 0.0), p.static_w);
    }

    #[test]
    fn peak_power_is_static_plus_dyn() {
        let p = vck190();
        let full = power_w(&p, p.peak_int8_tops());
        assert!((full - (p.static_w + p.dyn_w)).abs() < 1e-9);
    }

    #[test]
    fn deit_t_b6_efficiency_near_paper() {
        // Paper Table 5: SSR DeiT-T batch 6 = 26.70 TOPS at 453 GOPS/W.
        let p = vck190();
        let eff = gops_per_w(&p, 26.70);
        let rel = (eff - 453.3) / 453.3;
        assert!(rel.abs() < 0.10, "eff={eff}");
    }

    #[test]
    fn generic_power_agrees_with_platform_power() {
        let p = vck190();
        for tops in [0.0, 10.0, 26.7, 200.0] {
            let generic =
                power_w_generic(p.static_w, p.dyn_w, p.peak_int8_tops(), tops);
            assert!((generic - power_w(&p, tops)).abs() < 1e-12);
        }
    }

    #[test]
    fn efficiency_monotonic_in_throughput() {
        let p = vck190();
        assert!(gops_per_w(&p, 20.0) > gops_per_w(&p, 10.0));
    }
}
