//! Calibration constants for the analytical model, with provenance.
//!
//! Absolute cycle counts cannot be re-measured without the board; these
//! constants are tuned (see EXPERIMENTS.md §Calibration) so that the
//! paper's *shape* reproduces: sequential ~11 TOPS flat vs batch, spatial
//! 5.7 -> 26.7 TOPS with batch, hybrid dominating at mid-latency. Each
//! constant is physically motivated and the tuning test
//! (`report::calibration`) prints the residuals against the paper's
//! anchor points.

/// Tunable model constants (defaults = calibrated values).
#[derive(Clone, Copy, Debug)]
pub struct Calib {
    /// Single-AIE kernel MAC efficiency (DAC'23 MM kernels reach ~85-95%).
    pub eff_kernel: f64,
    /// Array-pass fill/drain overhead, AIE cycles (DMA descriptor + lock
    /// handshake per (TM,TK,TN) pass through the array).
    pub pass_overhead_cycles: f64,
    /// Per-node launch overhead (us) on an acc that runs MULTIPLE layer
    /// classes: buffer re-pointering + control sync when the monolithic
    /// acc switches shapes (the paper's sequential design pays this).
    pub reconfig_us: f64,
    /// Per-node overhead (us) on a single-class dataflow acc (stream
    /// handshake only).
    pub persist_us: f64,
    /// HMM-type1 (two streamed activation operands) halves effective PLIO
    /// input bandwidth vs type0 (weights pinned).
    pub type1_bw_factor: f64,
    /// PL-side HCE lanes: elements per DSP-lane per PL cycle.
    pub hce_elems_per_lane_cycle: f64,
    /// DSPs consumed per HCE lane (nonlinear processors are DSP-heavy:
    /// Table 8 shows 1024 DSP for LayerNorm alone).
    pub dsp_per_lane: f64,
    /// Reduction ops (Softmax/LayerNorm) take 2 passes without the
    /// line-buffer pipeline, `reduction_pipelined_passes` with it
    /// (paper: "reduces its latency to nearly half").
    pub reduction_naive_passes: f64,
    pub reduction_pipelined_passes: f64,
    /// Fraction of a node's DDR traffic that overlaps compute when
    /// on-chip forwarding is DISABLED (CHARM overlaps poorly: Sec. 2).
    pub ddr_overlap: f64,
    /// Achieved fraction of peak DDR bandwidth (strided tile accesses).
    pub ddr_efficiency: f64,
    /// Bytes per element for DDR round-trips without the co-designed
    /// requant path: intermediates travel in accumulator precision (INT32).
    pub ddr_elem_bytes: f64,
    /// Bank-conflict repack throughput penalty when producer/consumer
    /// parallelism is misaligned and force-partition is off (Fig. 8c):
    /// data moves RAM->RAM at one element per bank per cycle.
    pub repack_bytes_per_cycle: f64,
    /// BRAM bank capacity (bytes) for Eq. 1 RAM counting (18Kb BRAM).
    pub bram_bytes: f64,
}

impl Default for Calib {
    fn default() -> Self {
        Calib {
            eff_kernel: 0.85,
            pass_overhead_cycles: 96.0,
            reconfig_us: 1.95,
            persist_us: 0.25,
            type1_bw_factor: 0.5,
            hce_elems_per_lane_cycle: 4.0,
            dsp_per_lane: 4.0,
            reduction_naive_passes: 2.0,
            reduction_pipelined_passes: 1.05,
            ddr_overlap: 0.15,
            ddr_efficiency: 0.6,
            ddr_elem_bytes: 3.0,
            repack_bytes_per_cycle: 256.0,
            bram_bytes: 2304.0, // 18 Kb
        }
    }
}
