//! Inter-accelerator communication model — paper Sec. 2 obs. 5-6 & Fig. 8.
//!
//! Three regimes:
//! * **DDR round-trip** (on-chip forwarding off, the CHARM baseline):
//!   producer writes the tensor to DDR, consumer reads it back, mostly
//!   serialized with compute — this is what made CHARM 8.4x slower than
//!   the A10G on DeiT-T.
//! * **On-chip forwarding, aligned**: producer's (A, C) parallelism is
//!   divisibility-aligned with the consumer's (A, B) and force-partition
//!   banks absorb the stream — the transfer fully overlaps the producer's
//!   next pass (Fig. 8d): zero exposed latency beyond the PLIO bound.
//! * **On-chip forwarding, misaligned**: bank conflicts force a RAM->RAM
//!   repack at `repack_bytes_per_cycle` (Fig. 8c) — exposed in the
//!   pipeline.

use super::calib::Calib;
use super::hmm::AccConfig;
use crate::arch::Platform;

/// How a producer->consumer edge is realized.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommPath {
    /// Same accelerator: intermediate stays in the acc's ping-pong RAM.
    Local,
    /// On-chip forwarding, force-partition aligned (Fig. 8d).
    OnChipAligned,
    /// On-chip forwarding with bank-conflict repack (Fig. 8c).
    OnChipRepack,
    /// Off-chip DDR round-trip (forwarding disabled).
    Ddr,
}

/// Classify the edge given the feature flag and the two acc configs.
pub fn classify(
    on_chip_forwarding: bool,
    same_acc: bool,
    producer: &AccConfig,
    consumer: &AccConfig,
    force_partition: bool,
) -> CommPath {
    if !on_chip_forwarding {
        // CHARM semantics (Sec. 2): without forwarding every inter-layer
        // tensor round-trips through DDR, even on the same accelerator
        // (no on-chip ping-pong reuse between layer invocations).
        return CommPath::Ddr;
    }
    if same_acc {
        return CommPath::Local;
    }
    if force_partition || producer.aligned_with(consumer) {
        CommPath::OnChipAligned
    } else {
        CommPath::OnChipRepack
    }
}

/// DDR round-trip seconds for an INT8 tensor of `bytes`: write + read in
/// accumulator (INT32) precision at the achieved (strided) bandwidth, with
/// a small compute-overlap credit. Shared by the per-edge cost and the
/// whole-image DDR serialization bound.
pub fn ddr_seconds(platform: &Platform, calib: &Calib, bytes: u64) -> f64 {
    let b = bytes as f64 * calib.ddr_elem_bytes;
    let t = 2.0 * b / (platform.ddr_gbs * 1e9 * calib.ddr_efficiency);
    t * (1.0 - calib.ddr_overlap)
}

/// Exposed seconds to move `bytes` over `path`.
pub fn comm_time(platform: &Platform, calib: &Calib, path: CommPath, bytes: u64) -> f64 {
    let b = bytes as f64;
    match path {
        CommPath::Local => 0.0,
        CommPath::OnChipAligned => 0.0, // absorbed by the force-partition banks
        CommPath::OnChipRepack => {
            // RAM -> RAM move at repack rate on the PL clock.
            b / calib.repack_bytes_per_cycle / (platform.pl_mhz * 1e6)
        }
        CommPath::Ddr => ddr_seconds(platform, calib, bytes),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck190;

    fn cfg(a: u64, b: u64, c: u64) -> AccConfig {
        AccConfig { h1: 32, w1: 32, w2: 32, a, b, c, part: (a, 1, c) }
    }

    #[test]
    fn same_acc_is_local_and_free() {
        let p = vck190();
        let cal = Calib::default();
        let path = classify(true, true, &cfg(2, 2, 2), &cfg(4, 1, 1), false);
        assert_eq!(path, CommPath::Local);
        assert_eq!(comm_time(&p, &cal, path, 1 << 20), 0.0);
    }

    #[test]
    fn ddr_roundtrip_dominates() {
        let p = vck190();
        let cal = Calib::default();
        let t_ddr = comm_time(&p, &cal, CommPath::Ddr, 1 << 20);
        let t_repack = comm_time(&p, &cal, CommPath::OnChipRepack, 1 << 20);
        assert!(t_ddr > t_repack, "ddr {t_ddr} vs repack {t_repack}");
        // 1 MB int8 -> 3 MB int32-ish, write+read at 60% of 25.6 GB/s
        // with a 15% overlap credit ~ 350 us.
        assert!(t_ddr > 2e-4 && t_ddr < 6e-4, "t_ddr {t_ddr}");
    }

    #[test]
    fn aligned_forwarding_is_free() {
        let p = vck190();
        let cal = Calib::default();
        // (a=2,c=2) into (a=4,b=2): 2|4 and 2|2 -> aligned
        let path = classify(true, false, &cfg(2, 2, 2), &cfg(4, 2, 1), false);
        assert_eq!(path, CommPath::OnChipAligned);
        assert_eq!(comm_time(&p, &cal, path, 123_456), 0.0);
    }

    #[test]
    fn misaligned_pays_repack_unless_forced() {
        // (a=2,c=2) into (a=3,b=5): misaligned
        let prod = cfg(2, 2, 2);
        let cons = cfg(3, 5, 1);
        assert_eq!(
            classify(true, false, &prod, &cons, false),
            CommPath::OnChipRepack
        );
        assert_eq!(
            classify(true, false, &prod, &cons, true),
            CommPath::OnChipAligned
        );
    }

    #[test]
    fn forwarding_off_always_ddr() {
        let prod = cfg(2, 2, 2);
        let cons = cfg(4, 2, 1);
        assert_eq!(classify(false, false, &prod, &cons, true), CommPath::Ddr);
    }
}
