//! HMM (AIE matrix-multiply) resource + timing model — paper Eq. 1 & Eq. 2.
//!
//! Eq. 1:  AIE  = A * B * C
//!         PLIO = (A + C) * B
//!         RAM  = Part_A * Part_B * Part_C * RAM_util
//!         DSP  = A * C * DSP_util
//!
//! Eq. 2:  Cycle = M*N*K / (A*B*C*MAC/Eff);  Throughput = #OPs/(Cycle/Freq)
//!
//! Our cycle model refines Eq. 2 with the three effects that produce the
//! paper's observed ~11% monolithic-acc utilization: tile-granularity
//! padding (ceil of each dim over the array pass), per-pass fill/drain
//! overhead, and the PLIO bandwidth bound (HMM-type1 halves it because two
//! activation operands share the input streams).

use super::calib::Calib;
use crate::arch::Platform;
use crate::graph::MmDims;

/// Accelerator configuration vector — the paper's
/// `config_vector (h1, w1, w2, A, B, C, Part_A, Part_B, Part_C)`.
///
/// `(h1, w1, w2)` is the per-AIE workload (an h1 x w1 x w2 sub-matmul out of
/// local memory); `(a, b, c)` the AIE array parallelism along M/K/N; `part`
/// the RAM bank partitioning for inter-acc forwarding (Fig. 8).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct AccConfig {
    pub h1: u64,
    pub w1: u64,
    pub w2: u64,
    pub a: u64,
    pub b: u64,
    pub c: u64,
    pub part: (u64, u64, u64),
}

impl AccConfig {
    /// AIEs consumed (Eq. 1).
    pub fn aie(&self) -> u64 {
        self.a * self.b * self.c
    }

    /// PLIO streams consumed (Eq. 1): A*B inputs + C*B weights/2nd operand
    /// in, A*C out — the paper folds this to (A+C)*B.
    pub fn plio(&self) -> u64 {
        (self.a + self.c) * self.b
    }

    /// Tile of the operand space covered by one array pass.
    pub fn tile(&self) -> (u64, u64, u64) {
        (self.a * self.h1, self.b * self.w1, self.c * self.w2)
    }

    /// AIE local memory needed (bytes): INT8 input panels + INT32
    /// accumulator, double-buffered (ping-pong) — the paper's 32 KB fit
    /// constraint.
    pub fn local_mem_bytes(&self) -> u64 {
        let ins = self.h1 * self.w1 + self.w1 * self.w2; // int8
        let acc = 4 * self.h1 * self.w2; // int32 accumulator
        2 * ins + acc
    }

    /// RAM banks (Eq. 1): partitions x banks-per-partition, where a
    /// partition must buffer one output tile slice.
    pub fn ram_banks(&self, calib: &Calib) -> u64 {
        let (tm, _, tn) = self.tile();
        let tile_bytes = (tm * tn * 4) as f64; // int32 before requant
        let parts = self.part.0 * self.part.1 * self.part.2;
        let ram_util = (tile_bytes / parts.max(1) as f64 / calib.bram_bytes).ceil();
        parts * ram_util as u64
    }

    /// DSPs for the attached nonlinear processors (Eq. 1: A*C*DSP_util).
    pub fn dsp(&self, dsp_util: u64) -> u64 {
        self.a * self.c * dsp_util
    }

    /// Does this config fit the platform's per-tile local memory?
    pub fn fits_local_mem(&self, platform: &Platform) -> bool {
        self.local_mem_bytes() <= platform.aie_local_mem
    }

    /// Divisibility alignment for force-partition (Fig. 8): producer (A, C)
    /// output parallelism must divide or be divided by consumer (A, B)
    /// input parallelism.
    pub fn aligned_with(&self, consumer: &AccConfig) -> bool {
        fn div_ok(x: u64, y: u64) -> bool {
            x % y == 0 || y % x == 0
        }
        div_ok(self.a, consumer.a) && div_ok(self.c, consumer.b)
    }
}

/// Timing result for one MM node on one accelerator config.
#[derive(Clone, Copy, Debug)]
pub struct MmTime {
    /// AIE compute cycles (granularity-padded, eff-derated).
    pub compute_cycles: f64,
    /// PLIO-stream-bound cycles (AIE clock domain).
    pub io_cycles: f64,
    /// Exposed total seconds (max of the two + pass overhead).
    pub seconds: f64,
}

/// Eq. 2 refined: cycles for `dims` on config `cfg`.
///
/// `pinned == true` -> HMM-type0 (weights in AIE local memory; only the
/// activation operand streams). `pinned == false` -> HMM-type1 (both
/// operands stream; input bandwidth halves).
pub fn mm_time(
    platform: &Platform,
    calib: &Calib,
    cfg: &AccConfig,
    dims: &MmDims,
    pinned: bool,
) -> MmTime {
    let (tm, tk, tn) = cfg.tile();
    let (nm, nk, nn) = (
        div_ceil(dims.m, tm) as f64,
        div_ceil(dims.k, tk) as f64,
        div_ceil(dims.n, tn) as f64,
    );
    let mult = dims.bmm_mult as f64;
    let passes = nm * nk * nn * mult;

    // compute: each pass runs the per-AIE (h1,w1,w2) kernel.
    let kernel_cycles =
        (cfg.h1 * cfg.w1 * cfg.w2) as f64 / platform.macs_per_aie_cycle as f64;
    let compute_cycles =
        passes * (kernel_cycles / calib.eff_kernel + calib.pass_overhead_cycles);

    // io: bytes streamed over this acc's PLIOs (packet-switched: the PLIO
    // set is shared between operand and result streams, as in CHARM's
    // broadcast-select network). Reuse structure:
    //   * the X tile streams once per (i, k) and is rebroadcast from the
    //     PL banks across the nn output-column blocks,
    //   * the second operand (weights if pinned -> free; activations for
    //     HMM-type1) streams once per (k, j),
    //   * each INT32->INT8-requantized output tile leaves once per (i, j).
    // HMM-type1's stream interleaving derates bandwidth by
    // `type1_bw_factor`.
    let x_bytes = nm * nk * (tm * tk) as f64;
    let y_bytes = if pinned { 0.0 } else { nk * nn * (tk * tn) as f64 };
    let out_bytes = nm * nn * (tm * tn) as f64;
    let bytes_per_plio_aie_cycle = cfg_plio_rate(platform) * calib.bw_derate(pinned);
    let io_cycles =
        mult * (x_bytes + y_bytes + out_bytes) / (cfg.plio() as f64 * bytes_per_plio_aie_cycle);

    let cycles = compute_cycles.max(io_cycles);
    MmTime {
        compute_cycles,
        io_cycles,
        seconds: cycles / (platform.aie_ghz * 1e9),
    }
}

impl Calib {
    /// Bandwidth derate: type1 shares input streams between two operands.
    fn bw_derate(&self, pinned: bool) -> f64 {
        if pinned {
            1.0
        } else {
            self.type1_bw_factor
        }
    }
}

/// Bytes per PLIO per AIE cycle (PLIO runs in the PL clock domain).
fn cfg_plio_rate(platform: &Platform) -> f64 {
    platform.plio_bytes_per_cycle as f64 * (platform.pl_mhz * 1e6)
        / (platform.aie_ghz * 1e9)
}

pub fn div_ceil(x: u64, y: u64) -> u64 {
    x.div_ceil(y.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck190;

    fn cfg(h1: u64, w1: u64, w2: u64, a: u64, b: u64, c: u64) -> AccConfig {
        AccConfig { h1, w1, w2, a, b, c, part: (a, 1, c) }
    }

    #[test]
    fn eq1_resource_counts() {
        let c = cfg(32, 32, 32, 4, 2, 4);
        assert_eq!(c.aie(), 32);
        assert_eq!(c.plio(), (4 + 4) * 2);
        assert_eq!(c.tile(), (128, 64, 128));
    }

    #[test]
    fn local_mem_within_32k() {
        let c = cfg(32, 32, 32, 4, 2, 4);
        // 2*(1024+1024) + 4*1024 = 8192
        assert_eq!(c.local_mem_bytes(), 8192);
        assert!(c.fits_local_mem(&vck190()));
        let big = cfg(128, 128, 128, 1, 1, 1);
        assert!(!big.fits_local_mem(&vck190()));
    }

    #[test]
    fn perfect_fit_efficiency_near_kernel_eff() {
        // A workload that exactly tiles: granularity waste = 0, io light
        // enough to stay compute bound at large h1*w1*w2.
        let p = vck190();
        let cal = Calib::default();
        let c = cfg(64, 64, 64, 2, 2, 2);
        let dims = MmDims { m: 128, k: 128, n: 128, bmm_mult: 1 };
        let t = mm_time(&p, &cal, &c, &dims, true);
        let ideal_cycles = dims.macs() as f64 / (c.aie() * p.macs_per_aie_cycle) as f64;
        let eff = ideal_cycles / t.compute_cycles;
        assert!(eff > 0.5 && eff <= cal.eff_kernel + 1e-9, "eff={eff}");
    }

    #[test]
    fn granularity_padding_hurts_ragged_m() {
        // M=197 on TM=256 wastes ~23%: time equals M=256's time.
        let p = vck190();
        let cal = Calib::default();
        let c = cfg(64, 32, 32, 4, 6, 2);
        let ragged = MmDims { m: 197, k: 192, n: 192, bmm_mult: 1 };
        let padded = MmDims { m: 256, k: 192, n: 192, bmm_mult: 1 };
        let t1 = mm_time(&p, &cal, &c, &ragged, true);
        let t2 = mm_time(&p, &cal, &c, &padded, true);
        assert!((t1.seconds - t2.seconds).abs() < 1e-12);
    }

    #[test]
    fn type1_more_io_bound_than_type0() {
        let p = vck190();
        let cal = Calib::default();
        let c = cfg(32, 32, 32, 4, 2, 4);
        let dims = MmDims { m: 197, k: 64, n: 197, bmm_mult: 3 };
        let t0 = mm_time(&p, &cal, &c, &dims, true);
        let t1 = mm_time(&p, &cal, &c, &dims, false);
        assert!(t1.io_cycles > t0.io_cycles);
        assert!(t1.seconds >= t0.seconds);
    }

    #[test]
    fn more_aies_reduce_time_until_io_bound() {
        let p = vck190();
        let cal = Calib::default();
        let dims = MmDims { m: 197, k: 192, n: 576, bmm_mult: 1 };
        let small = mm_time(&p, &cal, &cfg(32, 32, 32, 2, 2, 2), &dims, true);
        let big = mm_time(&p, &cal, &cfg(32, 32, 32, 4, 2, 4), &dims, true);
        assert!(big.seconds < small.seconds);
    }

    #[test]
    fn alignment_divisibility() {
        let producer = cfg(32, 32, 32, 2, 2, 2);
        let consumer_ok = cfg(32, 32, 32, 4, 2, 1);
        let consumer_bad = cfg(32, 32, 32, 3, 5, 1);
        assert!(producer.aligned_with(&consumer_ok));
        assert!(!producer.aligned_with(&consumer_bad));
    }

    #[test]
    fn bmm_mult_scales_passes() {
        let p = vck190();
        let cal = Calib::default();
        let c = cfg(32, 32, 32, 2, 2, 2);
        let one = MmDims { m: 197, k: 64, n: 197, bmm_mult: 1 };
        let three = MmDims { m: 197, k: 64, n: 197, bmm_mult: 3 };
        let t1 = mm_time(&p, &cal, &c, &one, false);
        let t3 = mm_time(&p, &cal, &c, &three, false);
        assert!((t3.seconds / t1.seconds - 3.0).abs() < 1e-9);
    }
}
