//! Analytical performance/resource models (paper Eq. 1, Eq. 2, Figs. 7-8).
//!
//! The paper validates this class of model against the physical VCK190 at
//! <5% error (Table 7); here it is both the DSE cost function and the
//! reference the event-driven simulator (`sim`) is checked against.
//!
//! Submodules:
//! * [`hmm`]   — Eq. 1 resource usage + Eq. 2 MM/BMM cycle model with PLIO
//!   bandwidth bounds (the AIE side),
//! * [`hce`]   — PL-side nonlinear/elementwise engine timing with and
//!   without the fine-grained line-buffer pipeline (Fig. 7),
//! * [`comm`]  — inter-accelerator communication: DDR round-trips vs
//!   on-chip forwarding, bank-conflict repack penalty (Fig. 8),
//! * [`energy`]— power/energy-efficiency model (Table 5's GOPS/W columns),
//! * [`calib`] — the calibration constants, in one place, with provenance.

pub mod calib;
pub mod comm;
pub mod energy;
pub mod hce;
pub mod hmm;

pub use calib::Calib;
pub use hmm::AccConfig;

/// The three step-by-step optimizations of §5.2.6, as feature flags.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Features {
    /// (1) on-chip data forwarding between accelerators (vs DDR round-trip).
    pub on_chip_forwarding: bool,
    /// (2) spatial accelerators allowed (vs one monolithic acc) — consumed
    /// by the DSE, carried here for reporting.
    pub spatial: bool,
    /// (3) fine-grained pipeline hiding HCE time behind HMM time.
    pub fine_grained_pipeline: bool,
}

impl Features {
    pub fn all() -> Self {
        Features { on_chip_forwarding: true, spatial: true, fine_grained_pipeline: true }
    }

    /// The CHARM-like baseline of §5.2.6 (none of the three enabled).
    pub fn baseline() -> Self {
        Features {
            on_chip_forwarding: false,
            spatial: false,
            fine_grained_pipeline: false,
        }
    }
}
