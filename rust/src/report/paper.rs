//! Published numbers from the paper, used as comparison anchors in the
//! benches and EXPERIMENTS.md. Source: Tables 3-8, Figs. 2-3, §5.2.6, §6.

/// Fig. 2 anchor points for DeiT-T on VCK190 (latency ms, TOPS).
pub const FIG2_SEQ_A: (f64, f64) = (0.22, 10.90); // sequential, batch 1
pub const FIG2_SEQ_B: (f64, f64) = (1.30, 11.17); // sequential, batch 6
pub const FIG2_SPATIAL_C_TOPS: f64 = 5.66; // spatial, batch 1
pub const FIG2_SPATIAL_D: (f64, f64) = (0.58, 26.70); // spatial, batch 6 (lat ~= 0.54-0.58)
pub const FIG2_HYBRID_E: (f64, f64) = (0.43, 18.56); // hybrid under 0.43 ms

/// Fig. 3 observations (DeiT-T on A10G, batch 6).
pub const FIG3_TOTAL_MS: f64 = 1.43;
pub const FIG3_MM_EFF_TOPS: f64 = 18.0;
pub const FIG3_MM_UTIL: f64 = 0.13;
pub const FIG3_NONLINEAR_SHARE: f64 = 0.28;
pub const FIG3_TRANSPOSE_SHARE: f64 = 0.08;
pub const FIG3_REFORMAT_SHARE: f64 = 0.05;

/// One Table 5 cell: (latency ms, TOPS, GOPS/W).
pub type T5Cell = (f64, f64, f64);

/// Table 5 rows: model -> [platform][batch {1,3,6}].
pub struct Table5Row {
    pub model: &'static str,
    pub a10g: [T5Cell; 3],
    pub zcu102: [T5Cell; 3],
    pub u250: [T5Cell; 3],
    pub ssr: [T5Cell; 3],
}

pub const TABLE5: [Table5Row; 4] = [
    Table5Row {
        model: "deit_t",
        a10g: [(0.76, 3.19, 26.54), (1.03, 7.05, 40.76), (1.43, 10.16, 48.37)],
        zcu102: [(5.50, 0.44, 46.82), (15.14, 0.48, 48.96), (29.79, 0.49, 49.25)],
        u250: [(2.23, 1.09, 14.02), (5.60, 1.30, 16.66), (10.66, 1.36, 17.04)],
        ssr: [(0.22, 10.90, 246.15), (0.39, 18.62, 368.75), (0.54, 26.70, 453.32)],
    },
    Table5Row {
        model: "deit_t_160",
        a10g: [(0.73, 2.39, 20.05), (1.05, 4.98, 28.59), (1.45, 7.21, 34.98)],
        zcu102: [(4.22, 0.41, 44.86), (11.81, 0.44, 46.58), (23.18, 0.45, 46.94)],
        u250: [(2.21, 0.79, 10.44), (5.67, 0.92, 12.13), (10.88, 0.96, 12.57)],
        ssr: [(0.21, 8.19, 196.03), (0.37, 14.92, 296.11), (0.50, 20.90, 360.90)],
    },
    Table5Row {
        model: "deit_t_256",
        a10g: [(0.81, 5.09, 38.53), (1.17, 10.56, 51.78), (1.69, 14.63, 66.78)],
        zcu102: [(9.10, 0.45, 46.48), (25.56, 0.48, 46.48), (50.51, 0.49, 46.16)],
        u250: [(3.52, 1.17, 15.05), (9.07, 1.36, 17.43), (17.24, 1.43, 18.27)],
        ssr: [(0.40, 10.30, 229.37), (0.66, 18.73, 363.59), (0.98, 25.22, 423.89)],
    },
    Table5Row {
        model: "lv_vit_t",
        a10g: [(0.92, 3.39, 21.34), (1.37, 6.84, 35.79), (1.91, 9.81, 45.19)],
        zcu102: [(7.24, 0.43, 43.97), (20.27, 0.46, 46.20), (39.95, 0.47, 45.52)],
        u250: [(3.11, 1.01, 12.53), (7.91, 1.18, 14.69), (15.11, 1.24, 15.32)],
        ssr: [(0.38, 8.21, 181.74), (0.62, 15.10, 296.74), (0.85, 22.03, 360.04)],
    },
];

/// Table 6: optimal TOPS under latency constraints for DeiT-T.
/// (constraint ms, GPU, SSR-sequential, SSR-spatial, SSR-hybrid); None = "x".
pub const TABLE6: [(f64, Option<f64>, Option<f64>, Option<f64>, Option<f64>); 4] = [
    (2.0, Some(11.32), Some(11.17), Some(26.70), Some(26.70)),
    (1.0, Some(5.28), Some(11.12), Some(26.70), Some(26.70)),
    (0.5, None, Some(11.05), Some(19.37), Some(19.37)),
    (0.4, None, Some(10.90), None, Some(18.56)),
];

/// Table 7: (n accs, estimated ms, on-board ms) for DeiT-T, batch 6.
pub const TABLE7: [(usize, f64, f64); 6] = [
    (1, 1.29, 1.30),
    (2, 1.14, 1.08),
    (3, 0.88, 0.85),
    (4, 0.81, 0.83),
    (5, 0.77, 0.79),
    (6, 0.54, 0.54),
];

/// Table 8: SSR-spatial resource totals for DeiT-T (INT8).
pub struct Table8 {
    pub reg: u64,
    pub lut: u64,
    pub bram: u64,
    pub uram: u64,
    pub dsp: u64,
    pub plio: u64,
    pub aie: u64,
}

pub const TABLE8_TOTAL: Table8 = Table8 {
    reg: 849_527,
    lut: 619_956,
    bram: 624,
    uram: 104,
    dsp: 1797,
    plio: 199,
    aie: 394,
};

/// §5.2.6 step-by-step latency-reduction factors (batch 6, DeiT-T):
/// baseline 12 ms; +forwarding 3.4x; +spatial 2.4x; +pipeline 2.7x; 0.54 ms.
pub const STEP_BASELINE_MS: f64 = 12.0;
pub const STEP_FACTORS: [f64; 3] = [3.4, 2.4, 2.7];
pub const STEP_FINAL_MS: f64 = 0.54;

/// §6 Q1: modeled DeiT-T latency on Stratix 10 NX and VCK190+HBM.
pub const STRATIX_DEIT_T_MS: f64 = 0.49;
pub const VCK190_HBM_DEIT_T_MS: f64 = 0.41;

/// §6 Q2: scale-out assumptions.
pub const SCALEOUT_BOARDS: usize = 12;
pub const SCALEOUT_HOP_MS: f64 = 0.1;

/// Table 5 aggregate claims (average gains vs SSR across models/batches).
pub const AVG_THROUGHPUT_GAIN_VS_A10G: f64 = 2.53;
pub const AVG_THROUGHPUT_GAIN_VS_ZCU102: f64 = 35.71;
pub const AVG_THROUGHPUT_GAIN_VS_U250: f64 = 14.20;
pub const AVG_ENERGY_GAIN_VS_A10G: f64 = 8.51;
pub const AVG_ENERGY_GAIN_VS_ZCU102: f64 = 6.75;
pub const AVG_ENERGY_GAIN_VS_U250: f64 = 21.22;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table7_error_rates_under_6_percent() {
        for (_, est, board) in TABLE7 {
            let err = (est - board).abs() / board;
            assert!(err < 0.065, "paper's own table err {err}");
        }
    }

    #[test]
    fn step_factors_compose_to_final() {
        let product: f64 = STEP_FACTORS.iter().product();
        let derived = STEP_BASELINE_MS / product;
        // 12 / (3.4*2.4*2.7) = 0.545 ~ 0.54
        assert!((derived - STEP_FINAL_MS).abs() < 0.02);
    }

    #[test]
    fn table5_has_all_models() {
        let names: Vec<_> = TABLE5.iter().map(|r| r.model).collect();
        assert_eq!(names, vec!["deit_t", "deit_t_160", "deit_t_256", "lv_vit_t"]);
    }
}
