//! Generators for every table and figure in the paper's evaluation.
//!
//! Each function runs the actual system (graph -> DSE -> analytical model /
//! simulator / baselines) and returns both structured data (asserted on by
//! tests, recorded in EXPERIMENTS.md) and a printable table.

use crate::analytical::{Calib, Features};
use crate::arch::{self, Platform};
use crate::baselines::{charm, gpu, heatvit};
use crate::bench::Table;
use crate::dse::ea::{run_ea, EaParams};
use crate::dse::enumerate;
use crate::dse::eval::{build_design, Evaluated};
use crate::dse::pareto::{best_under, pareto_front, Point};
use crate::dse::Assignment;
use crate::graph::{builder, vit_graph, Graph};
use crate::sim;
use crate::util::threadpool::{default_threads, scope_map};

/// Shared context for the generators.
pub struct Ctx {
    pub platform: Platform,
    pub calib: Calib,
    /// Trim sweeps for unit tests.
    pub quick: bool,
}

impl Ctx {
    pub fn vck190() -> Self {
        Ctx { platform: arch::vck190(), calib: Calib::default(), quick: false }
    }

    pub fn quick() -> Self {
        Ctx { quick: true, ..Ctx::vck190() }
    }

    fn graph(&self, model: &str) -> Graph {
        vit_graph(builder::by_name(model).expect("unknown model"))
    }
}

fn eval_assignment(
    ctx: &Ctx,
    graph: &Graph,
    a: &Assignment,
    features: Features,
    batch: usize,
) -> Option<(Evaluated, crate::dse::Eval)> {
    let ev = build_design(&ctx.platform, &ctx.calib, graph, a, features, true)?;
    let e = ev.evaluate(&ctx.platform, graph, batch);
    Some((ev, e))
}

/// Best hybrid design at `batch` under `lat_cons` via exhaustive assignment
/// enumeration (the ground-truth optimum the EA is compared against).
pub fn best_hybrid_exhaustive(
    ctx: &Ctx,
    graph: &Graph,
    batch: usize,
    lat_cons: f64,
    max_acc: usize,
) -> Option<(Evaluated, crate::dse::Eval)> {
    let assignments = enumerate::all_up_to(max_acc);
    let assignments = if ctx.quick {
        assignments.into_iter().step_by(16).collect::<Vec<_>>()
    } else {
        assignments
    };
    let evals = scope_map(&assignments, default_threads(), |a| {
        eval_assignment(ctx, graph, a, Features::all(), batch)
    });
    evals
        .into_iter()
        .flatten()
        .filter(|(_, e)| e.latency_s <= lat_cons)
        .max_by(|(_, a), (_, b)| a.tops.total_cmp(&b.tops))
}

// ---------------------------------------------------------------------------
// Fig. 2 — latency/throughput scatter + Pareto fronts for DeiT-T.
// ---------------------------------------------------------------------------

pub struct Fig2 {
    pub seq: Vec<Point>,
    pub spatial: Vec<Point>,
    pub hybrid: Vec<Point>,
}

impl Fig2 {
    pub fn hybrid_front(&self) -> Vec<Point> {
        let all: Vec<Point> = self
            .seq
            .iter()
            .chain(&self.spatial)
            .chain(&self.hybrid)
            .copied()
            .collect();
        pareto_front(&all)
    }
}

pub fn fig2(ctx: &Ctx) -> Fig2 {
    let g = ctx.graph("deit_t");
    let batches: Vec<usize> = if ctx.quick { vec![1, 6] } else { vec![1, 2, 3, 4, 5, 6] };
    let mut seq = Vec::new();
    let mut spatial = Vec::new();
    for &b in &batches {
        if let Some((ev, e)) = eval_assignment(ctx, &g, &Assignment::sequential(), Features::all(), b) {
            seq.push(Point {
                latency_ms: e.latency_s * 1e3,
                tops: e.tops,
                batch: b,
                nacc: ev.design.assignment.nacc(),
            });
        }
        if let Some((ev, e)) = eval_assignment(ctx, &g, &Assignment::spatial(), Features::all(), b) {
            spatial.push(Point {
                latency_ms: e.latency_s * 1e3,
                tops: e.tops,
                batch: b,
                nacc: ev.design.assignment.nacc(),
            });
        }
    }
    // Hybrid points: best exhaustive design per (nacc, batch) slice. Each
    // design is built ONCE and then evaluated at every batch size (the
    // evaluation is closed-form and cheap; the customization is not).
    let mut hybrid = Vec::new();
    let naccs: Vec<usize> = if ctx.quick { vec![2, 4] } else { vec![2, 3, 4, 5, 6, 7] };
    for &n in &naccs {
        let assignments = enumerate::with_exactly(n);
        let assignments = if ctx.quick {
            assignments.into_iter().step_by(8).collect::<Vec<_>>()
        } else {
            assignments
        };
        let designs = scope_map(&assignments, default_threads(), |a| {
            build_design(&ctx.platform, &ctx.calib, &g, a, Features::all(), true)
        });
        for &b in &batches {
            if let Some((ev, e)) = designs
                .iter()
                .flatten()
                .map(|ev| (ev, ev.evaluate(&ctx.platform, &g, b)))
                .max_by(|(_, x), (_, y)| x.tops.total_cmp(&y.tops))
            {
                hybrid.push(Point {
                    latency_ms: e.latency_s * 1e3,
                    tops: e.tops,
                    batch: b,
                    nacc: ev.design.assignment.nacc(),
                });
            }
        }
    }
    Fig2 { seq, spatial, hybrid }
}

pub fn fig2_table(f: &Fig2) -> Table {
    let mut t = Table::new(&["strategy", "batch", "nacc", "latency (ms)", "TOPS"]);
    for (name, pts) in [("sequential", &f.seq), ("spatial", &f.spatial), ("hybrid", &f.hybrid)] {
        for p in pts.iter() {
            t.row(&[
                name.to_string(),
                p.batch.to_string(),
                p.nacc.to_string(),
                format!("{:.3}", p.latency_ms),
                format!("{:.2}", p.tops),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// Fig. 3 — GPU kernel breakdown.
// ---------------------------------------------------------------------------

pub fn fig3_table(batch: usize) -> (gpu::GpuBreakdown, Table) {
    let g = vit_graph(&builder::DEIT_T);
    let bd = gpu::breakdown(&arch::a10g(), &gpu::GpuCalib::default(), &g, batch);
    let total = bd.total_s();
    let mut t = Table::new(&["kernel", "time (ms)", "share"]);
    for (name, s) in [
        ("MM/BMM/patch-embed", bd.mm_s),
        ("Softmax", bd.softmax_s),
        ("LayerNorm", bd.layernorm_s),
        ("GELU", bd.gelu_s),
        ("Transpose", bd.transpose_s),
        ("Reformat", bd.reformat_s),
        ("launch/occupancy floor", bd.launch_floor_s),
    ] {
        t.row(&[
            name.to_string(),
            format!("{:.3}", s * 1e3),
            format!("{:.1}%", 100.0 * s / total),
        ]);
    }
    t.row(&["TOTAL".into(), format!("{:.3}", total * 1e3), "100%".into()]);
    (bd, t)
}

// ---------------------------------------------------------------------------
// Table 5 — cross-platform comparison.
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Table5Cell {
    pub latency_ms: f64,
    pub tops: f64,
    pub gops_w: f64,
}

#[derive(Clone, Debug)]
pub struct Table5Row {
    pub model: String,
    pub batch: usize,
    pub a10g: Table5Cell,
    pub zcu102: Table5Cell,
    pub u250: Table5Cell,
    pub ssr: Table5Cell,
}

pub fn table5(ctx: &Ctx, models: &[&str]) -> Vec<Table5Row> {
    let gpu_spec = arch::a10g();
    let gpu_cal = gpu::GpuCalib::default();
    let z = arch::zcu102();
    let u = arch::u250();
    let mut rows = Vec::new();
    for model in models {
        let g = ctx.graph(model);
        // SSR: build every candidate design ONCE per model, then pick the
        // best per batch (the paper sets #accs = batch count; we let the
        // exhaustive search pick).
        let max_acc = if ctx.quick { 4 } else { 8 };
        let assignments = enumerate::all_up_to(max_acc);
        let assignments = if ctx.quick {
            assignments.into_iter().step_by(16).collect::<Vec<_>>()
        } else {
            assignments
        };
        let designs = scope_map(&assignments, default_threads(), |a| {
            build_design(&ctx.platform, &ctx.calib, &g, a, Features::all(), true)
        });
        for &batch in &[1usize, 3, 6] {
            let (_, ssr_eval) = designs
                .iter()
                .flatten()
                .map(|ev| (ev, ev.evaluate(&ctx.platform, &g, batch)))
                .max_by(|(_, a), (_, b)| a.tops.total_cmp(&b.tops))
                .expect("feasible SSR design");
            let cell = |l: f64, t: f64, e: f64| Table5Cell { latency_ms: l, tops: t, gops_w: e };
            rows.push(Table5Row {
                model: model.to_string(),
                batch,
                a10g: cell(
                    gpu::latency_s(&gpu_spec, &gpu_cal, &g, batch) * 1e3,
                    gpu::tops(&gpu_spec, &gpu_cal, &g, batch),
                    gpu::gops_per_w(&gpu_spec, &gpu_cal, &g, batch),
                ),
                zcu102: cell(
                    heatvit::latency_s(&z, &heatvit::calib_for(&z), &g, batch) * 1e3,
                    heatvit::tops(&z, &heatvit::calib_for(&z), &g, batch),
                    heatvit::gops_per_w(&z, &heatvit::calib_for(&z), &g, batch),
                ),
                u250: cell(
                    heatvit::latency_s(&u, &heatvit::calib_for(&u), &g, batch) * 1e3,
                    heatvit::tops(&u, &heatvit::calib_for(&u), &g, batch),
                    heatvit::gops_per_w(&u, &heatvit::calib_for(&u), &g, batch),
                ),
                ssr: cell(ssr_eval.latency_s * 1e3, ssr_eval.tops, ssr_eval.gops_per_w),
            });
        }
    }
    rows
}

pub fn table5_table(rows: &[Table5Row]) -> Table {
    let mut t = Table::new(&[
        "model", "batch", "A10G ms", "A10G TOPS", "ZCU102 ms", "ZCU102 TOPS",
        "U250 ms", "U250 TOPS", "SSR ms", "SSR TOPS", "SSR GOPS/W",
    ]);
    for r in rows {
        t.row(&[
            r.model.clone(),
            r.batch.to_string(),
            format!("{:.2}", r.a10g.latency_ms),
            format!("{:.2}", r.a10g.tops),
            format!("{:.2}", r.zcu102.latency_ms),
            format!("{:.2}", r.zcu102.tops),
            format!("{:.2}", r.u250.latency_ms),
            format!("{:.2}", r.u250.tops),
            format!("{:.2}", r.ssr.latency_ms),
            format!("{:.2}", r.ssr.tops),
            format!("{:.0}", r.ssr.gops_w),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Table 6 — optimal throughput under latency constraints.
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Table6Row {
    pub lat_cons_ms: f64,
    pub gpu: Option<f64>,
    pub seq: Option<f64>,
    pub spatial: Option<f64>,
    pub hybrid: Option<f64>,
}

pub fn table6(ctx: &Ctx, constraints_ms: &[f64]) -> Vec<Table6Row> {
    let g = ctx.graph("deit_t");
    let f2 = fig2(ctx);
    // GPU: sweep batch sizes, latency = model latency.
    let gpu_spec = arch::a10g();
    let gpu_cal = gpu::GpuCalib::default();
    let gpu_points: Vec<Point> = (1..=64)
        .map(|b| Point {
            latency_ms: gpu::latency_s(&gpu_spec, &gpu_cal, &g, b) * 1e3,
            tops: gpu::tops(&gpu_spec, &gpu_cal, &g, b),
            batch: b,
            nacc: 1,
        })
        .collect();
    let hybrid_all: Vec<Point> = f2
        .seq
        .iter()
        .chain(&f2.spatial)
        .chain(&f2.hybrid)
        .copied()
        .collect();
    constraints_ms
        .iter()
        .map(|&c| Table6Row {
            lat_cons_ms: c,
            gpu: best_under(&gpu_points, c).map(|p| p.tops),
            seq: best_under(&f2.seq, c).map(|p| p.tops),
            spatial: best_under(&f2.spatial, c).map(|p| p.tops),
            hybrid: best_under(&hybrid_all, c).map(|p| p.tops),
        })
        .collect()
}

pub fn table6_table(rows: &[Table6Row]) -> Table {
    let fmt = |x: Option<f64>| x.map(|v| format!("{v:.2}")).unwrap_or_else(|| "x".into());
    let mut t = Table::new(&["constraint", "GPU", "SSR-seq", "SSR-spatial", "SSR-hybrid"]);
    for r in rows {
        t.row(&[
            format!("{} ms", r.lat_cons_ms),
            fmt(r.gpu),
            fmt(r.seq),
            fmt(r.spatial),
            fmt(r.hybrid),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Table 7 — analytical model vs event-driven simulator per #accs.
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Table7Row {
    pub naccs: usize,
    pub analytical_ms: f64,
    pub sim_ms: f64,
    pub err: f64,
}

pub fn table7(ctx: &Ctx, batch: usize) -> Vec<Table7Row> {
    let g = ctx.graph("deit_t");
    let counts: Vec<usize> = if ctx.quick { vec![1, 4, 6] } else { vec![1, 2, 3, 4, 5, 6] };
    counts
        .into_iter()
        .map(|n| {
            // Best design with exactly n accs (latency-optimal at `batch`).
            let assignments = enumerate::with_exactly(n);
            let assignments = if ctx.quick && assignments.len() > 64 {
                assignments.into_iter().step_by(8).collect::<Vec<_>>()
            } else {
                assignments
            };
            let evals = scope_map(&assignments, default_threads(), |a| {
                eval_assignment(ctx, &g, a, Features::all(), batch)
            });
            let (ev, e) = evals
                .into_iter()
                .flatten()
                .min_by(|(_, a), (_, b)| a.latency_s.total_cmp(&b.latency_s))
                .expect("feasible design");
            let sim = sim::simulate(&ctx.platform, &ev, &g, batch);
            Table7Row {
                naccs: n,
                analytical_ms: e.latency_s * 1e3,
                sim_ms: sim.makespan_s * 1e3,
                err: (e.latency_s - sim.makespan_s) / sim.makespan_s,
            }
        })
        .collect()
}

pub fn table7_table(rows: &[Table7Row]) -> Table {
    let mut t = Table::new(&["# accs", "analytical (ms)", "sim 'board' (ms)", "error"]);
    for r in rows {
        t.row(&[
            r.naccs.to_string(),
            format!("{:.3}", r.analytical_ms),
            format!("{:.3}", r.sim_ms),
            format!("{:+.1}%", r.err * 100.0),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Table 8 — resource utilization of the SSR-spatial design.
// ---------------------------------------------------------------------------

pub struct Table8 {
    pub aie: u64,
    pub plio: u64,
    pub bram_banks: u64,
    pub dsp: u64,
    pub per_acc: Vec<(String, u64, u64)>, // (classes, aie, plio)
}

pub fn table8(ctx: &Ctx) -> Table8 {
    let g = ctx.graph("deit_t");
    let ev = build_design(
        &ctx.platform,
        &ctx.calib,
        &g,
        &Assignment::spatial(),
        Features::all(),
        true,
    )
    .expect("spatial design");
    let mut per_acc = Vec::new();
    let mut aie = 0;
    let mut plio = 0;
    let mut bram = 0;
    let mut dsp = 0;
    for (i, cfg) in ev.design.configs.iter().enumerate() {
        let classes: Vec<String> = ev
            .design
            .assignment
            .classes_on(i)
            .iter()
            .map(|c| format!("{c:?}"))
            .collect();
        per_acc.push((classes.join("+"), cfg.aie(), cfg.plio()));
        aie += cfg.aie();
        plio += cfg.plio();
        bram += cfg.ram_banks(&ctx.calib);
        dsp += crate::analytical::hce::hce_dsp(&ctx.calib, ev.design.hce_lanes[i]);
    }
    Table8 { aie, plio, bram_banks: bram, dsp, per_acc }
}

pub fn table8_table(t8: &Table8, platform: &Platform) -> Table {
    let mut t = Table::new(&["acc (classes)", "AIE", "PLIO"]);
    for (name, aie, plio) in &t8.per_acc {
        t.row(&[name.clone(), aie.to_string(), plio.to_string()]);
    }
    t.row(&[
        format!(
            "TOTAL (of {} AIE / {} PLIO)",
            platform.aie_total, platform.plio_total
        ),
        t8.aie.to_string(),
        t8.plio.to_string(),
    ]);
    t
}

// ---------------------------------------------------------------------------
// §5.2.6 — step-by-step optimization.
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct StepRow {
    pub name: String,
    pub latency_ms: f64,
    pub factor: f64,
}

pub fn step_opt(ctx: &Ctx, batch: usize) -> Vec<StepRow> {
    let g = ctx.graph("deit_t");
    let mut rows: Vec<StepRow> = Vec::new();
    for (name, feats, assign) in charm::step_features() {
        let ev = build_design(&ctx.platform, &ctx.calib, &g, &assign, feats, true)
            .expect("step design");
        let lat = ev.evaluate(&ctx.platform, &g, batch).latency_s * 1e3;
        let factor = rows.last().map(|p: &StepRow| p.latency_ms / lat).unwrap_or(1.0);
        rows.push(StepRow { name: name.to_string(), latency_ms: lat, factor });
    }
    rows
}

pub fn step_table(rows: &[StepRow]) -> Table {
    let mut t = Table::new(&["configuration", "latency (ms)", "step gain"]);
    for r in rows {
        t.row(&[r.name.clone(), format!("{:.2}", r.latency_ms), format!("{:.2}x", r.factor)]);
    }
    t
}

// ---------------------------------------------------------------------------
// Fig. 10 — search efficiency: EA+inter-acc-aware vs exhaustive.
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Fig10 {
    pub aware_secs: f64,
    pub aware_best_tops: f64,
    pub aware_configs: usize,
    pub exhaustive_secs: f64,
    pub exhaustive_best_tops: f64,
    pub exhaustive_configs: usize,
}

pub fn fig10(ctx: &Ctx, batch: usize, lat_cons: f64) -> Fig10 {
    let g = ctx.graph("deit_t");
    let quick = ctx.quick;
    let params = EaParams {
        batch,
        lat_cons,
        n_pop: if quick { 8 } else { 24 },
        n_child: if quick { 8 } else { 24 },
        n_iter: if quick { 3 } else { 12 },
        seed: 0xF16,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let aware = run_ea(&ctx.platform, &ctx.calib, &g, Features::all(), true, &params);
    let aware_secs = t0.elapsed().as_secs_f64();

    // Exhaustive baseline: enumerate assignments with the non-aware
    // (post-verify) customization.
    let assignments = enumerate::all_up_to(8);
    let assignments = if quick {
        assignments.into_iter().step_by(64).collect::<Vec<_>>()
    } else {
        assignments
    };
    let t1 = std::time::Instant::now();
    let evals = scope_map(&assignments, default_threads(), |a| {
        build_design(&ctx.platform, &ctx.calib, &g, a, Features::all(), false).map(|ev| {
            let e = ev.evaluate(&ctx.platform, &g, batch);
            (ev.stats.configs_evaluated, e)
        })
    });
    let exhaustive_secs = t1.elapsed().as_secs_f64();
    let mut exhaustive_best = 0.0f64;
    let mut exhaustive_configs = 0usize;
    for r in evals.into_iter().flatten() {
        exhaustive_configs += r.0;
        if r.1.latency_s <= lat_cons {
            exhaustive_best = exhaustive_best.max(r.1.tops);
        }
    }
    Fig10 {
        aware_secs,
        aware_best_tops: aware.best.as_ref().map(|(_, e)| e.tops).unwrap_or(0.0),
        aware_configs: aware.configs_evaluated,
        exhaustive_secs,
        exhaustive_best_tops: exhaustive_best,
        exhaustive_configs,
    }
}

// ---------------------------------------------------------------------------
// §6 Q1/Q2 — other platforms + scale-out.
// ---------------------------------------------------------------------------

pub struct PlatformRow {
    pub platform: String,
    pub latency_ms: f64,
    pub tops: f64,
}

/// DeiT-T (batch 6) mapped by SSR onto each platform (§6 Q1 + Table 1).
pub fn multi_platform(quick: bool) -> Vec<PlatformRow> {
    let mut rows = Vec::new();
    for p in [arch::vck190(), arch::vck190_hbm(), arch::stratix10nx()] {
        let ctx = Ctx { platform: p, calib: Calib::default(), quick };
        let g = ctx.graph("deit_t");
        let (_, e) = best_hybrid_exhaustive(&ctx, &g, 6, f64::INFINITY, 8)
            .expect("feasible design");
        rows.push(PlatformRow {
            platform: ctx.platform.name.to_string(),
            latency_ms: e.latency_s * 1e3,
            tops: e.tops,
        });
    }
    rows
}

/// §6 Q2: scale a `size_factor`x-DeiT-T model (e.g. DeiT-Base = 16x in
/// parameters) across `boards` pipeline-parallel boards with `hop_ms`
/// inter-board latency (the paper assumes 12 VCK190s over 100Gb QSFP28
/// with 0.1 ms hops). Returns (batch-1 latency ms, steady-state imgs/s).
pub fn scaleout(ctx: &Ctx, size_factor: usize, boards: usize, hop_ms: f64) -> (f64, f64) {
    let g = ctx.graph("deit_t");
    let (_, e) = best_hybrid_exhaustive(ctx, &g, 1, f64::INFINITY, if ctx.quick { 4 } else { 8 })
        .expect("feasible design");
    let total_work_ms = e.latency_s * 1e3 * size_factor as f64;
    let stage_ms = total_work_ms / boards as f64;
    let latency_ms = total_work_ms + (boards - 1) as f64 * hop_ms;
    let throughput = 1e3 / stage_ms.max(hop_ms); // images/s at steady state
    (latency_ms, throughput)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rel_err;

    #[test]
    fn fig2_hybrid_front_dominates_pure_strategies() {
        let ctx = Ctx::quick();
        let f = fig2(&ctx);
        let front = f.hybrid_front();
        assert!(!front.is_empty());
        assert!(crate::dse::pareto::front_dominates(&front, &f.seq));
        assert!(crate::dse::pareto::front_dominates(&front, &f.spatial));
    }

    #[test]
    fn fig3_total_near_paper() {
        let (bd, _) = fig3_table(6);
        assert!(rel_err(bd.total_s() * 1e3, super::super::paper::FIG3_TOTAL_MS) < 0.25);
    }

    #[test]
    fn table6_hybrid_geq_both() {
        let ctx = Ctx::quick();
        let rows = table6(&ctx, &[2.0, 0.5]);
        for r in &rows {
            if let (Some(h), Some(s)) = (r.hybrid, r.seq) {
                assert!(h >= s - 1e-9);
            }
            if let (Some(h), Some(s)) = (r.hybrid, r.spatial) {
                assert!(h >= s - 1e-9);
            }
        }
    }

    #[test]
    fn table7_error_small() {
        let ctx = Ctx::quick();
        for r in table7(&ctx, 6) {
            assert!(r.err.abs() < 0.18, "nacc {}: err {}", r.naccs, r.err);
        }
    }

    #[test]
    fn table8_fits_platform() {
        let ctx = Ctx::quick();
        let t8 = table8(&ctx);
        assert!(t8.aie <= ctx.platform.aie_total);
        assert!(t8.plio <= ctx.platform.plio_total);
        assert_eq!(t8.per_acc.len(), 8);
    }

    #[test]
    fn step_opt_strictly_improves() {
        let ctx = Ctx::quick();
        let rows = step_opt(&ctx, 6);
        assert_eq!(rows.len(), 4);
        for r in &rows[1..] {
            assert!(r.factor > 1.0, "{}: {}", r.name, r.factor);
        }
    }
}
