//! Report generation: one function per paper table/figure, shared by the
//! `benches/` regenerators, the `ssr report` CLI subcommand, and tests.
//!
//! * [`paper`]  — the published numbers (comparison anchors),
//! * [`tables`] — generators that run the models/DSE and build rows,
//! * [`tpu`]    — the §Perf real-TPU estimate (VMEM footprint + MXU
//!   utilization per kernel config), since interpret-mode Pallas gives no
//!   hardware timings.

pub mod paper;
pub mod tables;
pub mod tpu;
