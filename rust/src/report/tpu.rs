//! Real-TPU performance estimate for the L1 Pallas kernels (§Perf).
//!
//! Interpret-mode Pallas gives CPU-numpy timings, which say nothing about
//! TPU behaviour; per DESIGN.md §Hardware-Adaptation we estimate instead:
//! VMEM footprint of the (TM, TK, TN) working set (double-buffered) and MXU
//! utilization from tile alignment to the 128x128 systolic array — the TPU
//! analog of the paper's Eq. 1 local-memory / Eq. 2 efficiency accounting.

/// TPU-v4-ish per-core envelope used for the estimate.
#[derive(Clone, Copy, Debug)]
pub struct TpuSpec {
    pub vmem_bytes: u64,
    pub mxu_dim: u64,
    pub peak_bf16_tflops: f64,
    pub hbm_gbs: f64,
}

impl Default for TpuSpec {
    fn default() -> Self {
        TpuSpec {
            vmem_bytes: 16 * 1024 * 1024,
            mxu_dim: 128,
            peak_bf16_tflops: 275.0,
            hbm_gbs: 1200.0,
        }
    }
}

/// Estimate for one matmul kernel config (block sizes in elements).
#[derive(Clone, Copy, Debug)]
pub struct KernelEstimate {
    pub vmem_bytes: u64,
    pub vmem_fits: bool,
    /// MXU utilization from tile alignment (1.0 = every dim a multiple of
    /// the systolic dim).
    pub mxu_util: f64,
    /// Arithmetic intensity (flops / HBM byte moved per output tile).
    pub arith_intensity: f64,
    /// Roofline-limited TFLOPS.
    pub roofline_tflops: f64,
}

/// Estimate for a (bm, bk, bn) f32/bf16 Pallas matmul block over an
/// (M, K, N) problem.
pub fn estimate_matmul(
    spec: &TpuSpec,
    bm: u64,
    bk: u64,
    bn: u64,
    m: u64,
    k: u64,
    n: u64,
    bytes_per_elem: u64,
) -> KernelEstimate {
    // Double-buffered input blocks + f32 accumulator.
    let vmem = 2 * (bm * bk + bk * bn) * bytes_per_elem + bm * bn * 4;
    let fits = vmem <= spec.vmem_bytes;

    // MXU utilization: problem-coverage waste (padding the last block in
    // each dim) times sublane alignment of the M block.
    let cover = |x: u64, b: u64| x as f64 / (x.div_ceil(b) * b) as f64;
    let sublane = (bm.min(8) as f64) / 8.0;
    let mxu_util = sublane.min(1.0) * cover(m, bm) * cover(k, bk) * cover(n, bn);
    let _ = spec.mxu_dim;

    // Arithmetic intensity per output block pass: 2*bm*bk*bn flops over
    // (bm*bk + bk*bn) input bytes (weights revisit amortized by pinning).
    let flops = 2.0 * (bm * bk * bn) as f64;
    let bytes = ((bm * bk + bk * bn) * bytes_per_elem) as f64;
    let ai = flops / bytes;
    let roofline = (spec.hbm_gbs * 1e9 * ai / 1e12).min(spec.peak_bf16_tflops) * mxu_util;

    KernelEstimate {
        vmem_bytes: vmem,
        vmem_fits: fits,
        mxu_util,
        arith_intensity: ai,
        roofline_tflops: roofline,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_blocks_high_util() {
        let e = estimate_matmul(&TpuSpec::default(), 128, 128, 128, 256, 256, 256, 2);
        assert!(e.vmem_fits);
        assert!(e.mxu_util > 0.99, "util {}", e.mxu_util);
    }

    #[test]
    fn ragged_m_penalized() {
        // 197 tokens on 128-blocks: covers 256 rows -> ~77% util.
        let e = estimate_matmul(&TpuSpec::default(), 128, 64, 128, 197, 192, 576, 2);
        assert!(e.mxu_util < 0.85 && e.mxu_util > 0.5, "util {}", e.mxu_util);
    }

    #[test]
    fn oversized_blocks_dont_fit_vmem() {
        let e = estimate_matmul(&TpuSpec::default(), 2048, 2048, 2048, 4096, 4096, 4096, 2);
        assert!(!e.vmem_fits);
    }

    #[test]
    fn bigger_blocks_better_intensity() {
        let small = estimate_matmul(&TpuSpec::default(), 32, 32, 32, 1024, 1024, 1024, 2);
        let big = estimate_matmul(&TpuSpec::default(), 256, 256, 256, 1024, 1024, 1024, 2);
        assert!(big.arith_intensity > small.arith_intensity);
    }

    #[test]
    fn roofline_capped_at_peak() {
        let s = TpuSpec::default();
        let e = estimate_matmul(&s, 512, 512, 512, 4096, 4096, 4096, 2);
        assert!(e.roofline_tflops <= s.peak_bf16_tflops);
    }
}
