//! PJRT serving runtime: load `artifacts/*.hlo.txt`, compile once, execute
//! from the rust hot path. Python is never invoked here.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (executables, arg
//!   schemas, weight blobs) written by `python/compile/aot.py`.
//! * [`weights`]  — loads the f32 weight binaries into host arrays.
//! * [`exec`]     — compiles HLO text on the PJRT CPU client and wraps
//!   execution: weights are uploaded to device buffers once at load time,
//!   so a request pays only its input upload + execute + output download.

pub mod exec;
pub mod manifest;
pub mod weights;

pub use exec::{Engine, Stage};
pub use manifest::{ArgSpec, ExeSpec, Manifest};
pub use weights::WeightStore;

/// Default artifacts directory (relative to the repo root / cwd).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts dir: explicit arg > $SSR_ARTIFACTS > ./artifacts.
pub fn artifacts_dir(explicit: Option<&str>) -> std::path::PathBuf {
    if let Some(p) = explicit {
        return p.into();
    }
    if let Ok(p) = std::env::var("SSR_ARTIFACTS") {
        return p.into();
    }
    ARTIFACTS_DIR.into()
}
