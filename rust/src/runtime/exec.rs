//! PJRT execution engine: compile HLO text once, serve many requests.
//!
//! Follows the `/opt/xla-example/load_hlo` pattern: `HloModuleProto::
//! from_text_file` -> `XlaComputation::from_proto` -> `client.compile`.
//! Weight arguments are uploaded to device buffers at stage-load time;
//! per-request work is input upload + `execute_b` + output download, which
//! keeps the serve hot path allocation-light.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{ArgSpec, ExeSpec, Manifest};
use super::weights::WeightStore;

/// The PJRT client + artifact index. One per process.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    pub weights: WeightStore,
}

/// SAFETY: the PJRT CPU client is internally synchronized (XLA's PJRT API
/// is documented thread-safe for compilation and execution); the raw
/// pointers inside `xla::PjRtClient`/`PjRtLoadedExecutable`/`PjRtBuffer`
/// are reference-counted handles owned by the client. We only share
/// `Engine`/`Stage` behind `Arc` and never mutate through them.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

/// A compiled stage: executable + pre-uploaded weight buffers.
pub struct Stage {
    // (fields below; Debug is manual because PJRT handles aren't Debug)
    pub spec: ExeSpec,
    exe: xla::PjRtLoadedExecutable,
    /// For plain-weight args: arg position -> uploaded buffer.
    fixed: BTreeMap<usize, xla::PjRtBuffer>,
    /// For block-weight args: per block, arg position -> buffer.
    per_block: Vec<BTreeMap<usize, xla::PjRtBuffer>>,
    /// Positions of runtime inputs, in order.
    input_pos: Vec<usize>,
}

unsafe impl Send for Stage {}
unsafe impl Sync for Stage {}

impl std::fmt::Debug for Stage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Stage")
            .field("name", &self.spec.name)
            .field("inputs", &self.input_pos.len())
            .field("blocks", &self.per_block.len())
            .finish()
    }
}

impl Engine {
    /// Load the artifact directory and create the PJRT CPU client.
    pub fn load(dir: &std::path::Path) -> Result<Arc<Engine>> {
        let manifest = Manifest::load(dir)?;
        let weights = WeightStore::load(&manifest)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Arc::new(Engine { client, manifest, weights }))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn upload(&self, data: &[f32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, shape, None)
            .map_err(|e| anyhow!("upload: {e:?}"))
    }

    /// Compile an executable by manifest name and pre-upload its weights.
    pub fn compile(&self, name: &str) -> Result<Stage> {
        let spec = self.manifest.find(name)?.clone();
        let proto = xla::HloModuleProto::from_text_file(
            spec.hlo.to_str().context("hlo path utf8")?,
        )
        .map_err(|e| anyhow!("hlo parse {}: {e:?}", spec.hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile: {e:?}"))?;

        let mut fixed = BTreeMap::new();
        let mut input_pos = Vec::new();
        let mut block_fields: Vec<(usize, String)> = Vec::new();
        for (pos, arg) in spec.args.iter().enumerate() {
            match arg {
                ArgSpec::Weight(id) => {
                    let w = self.weights.get(*id)?;
                    fixed.insert(pos, self.upload(&w.data, &w.shape)?);
                }
                ArgSpec::BlockWeight(field) => block_fields.push((pos, field.clone())),
                ArgSpec::Input { .. } => input_pos.push(pos),
            }
        }

        let depth = spec
            .block_weights
            .values()
            .map(|v| v.len())
            .next()
            .unwrap_or(0);
        let mut per_block = Vec::with_capacity(depth);
        for blk in 0..depth {
            let mut m = BTreeMap::new();
            for (pos, field) in &block_fields {
                let ids = spec
                    .block_weights
                    .get(field)
                    .ok_or_else(|| anyhow!("missing block weights for {field}"))?;
                let w = self.weights.get(ids[blk])?;
                m.insert(*pos, self.upload(&w.data, &w.shape)?);
            }
            per_block.push(m);
        }
        if !block_fields.is_empty() && per_block.is_empty() {
            bail!("{name}: block-weight args but no block_weights map");
        }

        Ok(Stage { spec, exe, fixed, per_block, input_pos })
    }
}

/// A host tensor (input or output of a stage).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }
}

impl Stage {
    /// Number of runtime inputs.
    pub fn num_inputs(&self) -> usize {
        self.input_pos.len()
    }

    /// Whether this stage resolves per-block weights (and so must be run
    /// with `block: Some(..)`). Weight-free stages (e.g. the attention
    /// BMMs) and fixed-weight stages (embed/head/full) take `None`.
    pub fn needs_block(&self) -> bool {
        !self.per_block.is_empty()
    }

    /// Expected shape of runtime input `i`.
    pub fn input_shape(&self, i: usize) -> &[usize] {
        match &self.spec.args[self.input_pos[i]] {
            ArgSpec::Input { shape, .. } => shape,
            _ => unreachable!("input_pos indexes inputs"),
        }
    }

    /// Execute with `inputs`; `block` selects the per-block weights for the
    /// shared attn/mlp stage executables (None for fixed-weight stages).
    pub fn run(
        &self,
        engine: &Engine,
        inputs: &[Tensor],
        block: Option<usize>,
    ) -> Result<Tensor> {
        if inputs.len() != self.input_pos.len() {
            bail!(
                "{}: {} inputs given, {} expected",
                self.spec.name,
                inputs.len(),
                self.input_pos.len()
            );
        }
        let blk_map = match (block, self.per_block.is_empty()) {
            (Some(b), false) => Some(
                self.per_block
                    .get(b)
                    .ok_or_else(|| anyhow!("block {b} out of range"))?,
            ),
            (None, false) => bail!("{}: stage needs a block index", self.spec.name),
            (Some(_), true) => bail!("{}: stage takes no block index", self.spec.name),
            (None, true) => None,
        };

        // Upload inputs, then assemble the positional arg list.
        let mut input_bufs = Vec::with_capacity(inputs.len());
        for (i, t) in inputs.iter().enumerate() {
            let expect = self.input_shape(i);
            if expect != t.shape.as_slice() {
                bail!(
                    "{}: input {i} shape {:?} != expected {:?}",
                    self.spec.name,
                    t.shape,
                    expect
                );
            }
            input_bufs.push(engine.upload(&t.data, &t.shape)?);
        }
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.spec.args.len());
        let mut next_input = 0;
        for pos in 0..self.spec.args.len() {
            if let Some(b) = self.fixed.get(&pos) {
                args.push(b);
            } else if let Some(b) = blk_map.and_then(|m| m.get(&pos)) {
                args.push(b);
            } else {
                args.push(&input_bufs[next_input]);
                next_input += 1;
            }
        }

        let result = self
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.spec.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("download: {e:?}"))?;
        // aot.py lowers with return_tuple=True -> unwrap the 1-tuple.
        let out = lit.to_tuple1().map_err(|e| anyhow!("tuple1: {e:?}"))?;
        let data = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        let shape = self
            .spec
            .outputs
            .first()
            .cloned()
            .unwrap_or_else(|| vec![data.len()]);
        Ok(Tensor::new(shape, data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;
    use std::sync::OnceLock;

    fn engine() -> &'static Arc<Engine> {
        static E: OnceLock<Arc<Engine>> = OnceLock::new();
        E.get_or_init(|| Engine::load(&PathBuf::from("artifacts")).expect("make artifacts"))
    }

    #[test]
    fn smoke_executes_correctly() {
        let e = engine();
        let stage = e.compile("smoke").unwrap();
        let x = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let y = Tensor::new(vec![2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let out = stage.run(e, &[x, y], None).unwrap();
        // matmul([[1,2],[3,4]], ones) + 2 = [[5,5],[9,9]]
        assert_eq!(out.data, vec![5.0, 5.0, 9.0, 9.0]);
    }

    #[test]
    fn smoke_pallas_matches_smoke() {
        // The Pallas kernel lowered into HLO must agree with plain jnp.
        let e = engine();
        let a = e.compile("smoke").unwrap();
        let b = e.compile("smoke_pallas").unwrap();
        let x = Tensor::new(vec![2, 2], vec![0.5, -1.0, 2.0, 3.5]);
        let y = Tensor::new(vec![2, 2], vec![1.5, 0.0, -2.0, 1.0]);
        let ra = a.run(e, &[x.clone(), y.clone()], None).unwrap();
        let rb = b.run(e, &[x, y], None).unwrap();
        for (u, v) in ra.data.iter().zip(&rb.data) {
            assert!((u - v).abs() < 1e-5, "{u} vs {v}");
        }
    }

    #[test]
    fn wrong_shape_rejected() {
        let e = engine();
        let stage = e.compile("smoke").unwrap();
        let bad = Tensor::new(vec![4], vec![1.0; 4]);
        let y = Tensor::new(vec![2, 2], vec![1.0; 4]);
        assert!(stage.run(e, &[bad, y], None).is_err());
    }

    #[test]
    fn wrong_arity_rejected() {
        let e = engine();
        let stage = e.compile("smoke").unwrap();
        let x = Tensor::new(vec![2, 2], vec![1.0; 4]);
        assert!(stage.run(e, &[x], None).is_err());
    }

    #[test]
    fn block_index_validation() {
        let e = engine();
        let attn = e.compile("deit_t_attn_b1").unwrap();
        let x = Tensor::zeros(vec![1, 197, 192]);
        assert!(attn.run(e, &[x.clone()], None).is_err()); // needs block
        assert!(attn.run(e, &[x], Some(99)).is_err()); // out of range
    }
}
