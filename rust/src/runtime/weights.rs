//! Weight blob loading: f32 little-endian binaries written by `aot.py`.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::{Manifest, WeightSpec};

/// A host-resident weight tensor.
#[derive(Clone, Debug)]
pub struct Weight {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Weight {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// All weight blobs, indexed by weight id.
#[derive(Debug, Default)]
pub struct WeightStore {
    weights: Vec<Weight>,
}

fn read_f32_le(path: &Path, expect_elems: usize) -> Result<Vec<f32>> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() != expect_elems * 4 {
        bail!(
            "{}: {} bytes, expected {} ({} f32)",
            path.display(),
            bytes.len(),
            expect_elems * 4,
            expect_elems
        );
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

impl WeightStore {
    pub fn load(manifest: &Manifest) -> Result<WeightStore> {
        let mut weights = Vec::with_capacity(manifest.weights.len());
        for spec in &manifest.weights {
            weights.push(Self::load_one(spec)?);
        }
        Ok(WeightStore { weights })
    }

    fn load_one(spec: &WeightSpec) -> Result<Weight> {
        let elems: usize = spec.shape.iter().product();
        let data = read_f32_le(&spec.file, elems)?;
        Ok(Weight { name: spec.name.clone(), shape: spec.shape.clone(), data })
    }

    pub fn get(&self, id: usize) -> Result<&Weight> {
        self.weights
            .get(id)
            .with_context(|| format!("weight id {id} out of range"))
    }

    pub fn len(&self) -> usize {
        self.weights.len()
    }

    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }

    /// Total bytes resident.
    pub fn bytes(&self) -> usize {
        self.weights.iter().map(|w| w.data.len() * 4).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn loads_all_weights() {
        let m = Manifest::load(&PathBuf::from("artifacts")).unwrap();
        let s = WeightStore::load(&m).unwrap();
        assert_eq!(s.len(), m.weights.len());
        // DeiT-T has 5.6M params; full + stage blobs dedup to ~5.7M floats.
        let total_elems: usize = (0..s.len()).map(|i| s.get(i).unwrap().elems()).sum();
        assert!(total_elems > 5_000_000, "{total_elems}");
    }

    #[test]
    fn shapes_match_data() {
        let m = Manifest::load(&PathBuf::from("artifacts")).unwrap();
        let s = WeightStore::load(&m).unwrap();
        for i in 0..s.len() {
            let w = s.get(i).unwrap();
            assert_eq!(w.elems(), w.data.len(), "{}", w.name);
        }
    }

    #[test]
    fn values_look_quantized_and_finite() {
        let m = Manifest::load(&PathBuf::from("artifacts")).unwrap();
        let s = WeightStore::load(&m).unwrap();
        let w = s.get(0).unwrap();
        assert!(w.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn out_of_range_id_errors() {
        let m = Manifest::load(&PathBuf::from("artifacts")).unwrap();
        let s = WeightStore::load(&m).unwrap();
        assert!(s.get(usize::MAX).is_err());
    }
}
